"""Babel parallel metadata prefetch (paper: 36x, 6h -> 10min on 190M
files).  We measure parallel vs serial listing on a local tree and report
the ratio; the absolute 36x needs object-store latency (each List call is
network-bound), so we also model it: with per-List latency L and W
concurrent workers the expected speedup is ~W."""
import os
import tempfile
import time

from repro.checkpoint.babel import list_parallel, list_serial


def run(fast=False):
    n_dirs, files_per = (32, 20) if fast else (64, 50)
    with tempfile.TemporaryDirectory() as root:
        for d in range(n_dirs):
            p = os.path.join(root, f"p{d:03d}")
            os.makedirs(p)
            for f in range(files_per):
                open(os.path.join(p, f"f{f}.bin"), "wb").write(b"x")
        t0 = time.perf_counter()
        a = list_serial(root)
        t_serial = time.perf_counter() - t0
        t0 = time.perf_counter()
        b = list_parallel(root, workers=16)
        t_par = time.perf_counter() - t0
        assert a == b
    # object-store model: serial = N*L; parallel = N*L/W (+ scheduling)
    n_files, latency, workers = 190e6, 120e-6, 48
    model_serial_h = n_files * latency / 3600
    model_par_min = n_files * latency / workers / 60
    rows = [
        ("babel_list_local", f"{t_par*1e6:.0f}",
         f"local_ratio={t_serial/max(t_par,1e-9):.2f}x"),
        ("babel_list_model", "0",
         f"{model_serial_h:.1f}h->{model_par_min:.0f}min="
         f"{model_serial_h*60/model_par_min:.0f}x_paper=36x"),
    ]
    return rows, {"local_serial_s": t_serial, "local_parallel_s": t_par,
                  "model": {"serial_h": model_serial_h,
                            "parallel_min": model_par_min,
                            "speedup": workers},
                  "paper_claim": 36}
