"""Table 3: Flood vs synchronous-baseline inference throughput.

Fair comparison in *virtual device time* (pipeline stages are separate
accelerators in the real deployment, so 1-CPU wall clock is meaningless):

  baseline (TP-style): every token step runs all S stages sequentially for
  one micro-batch and pays a global synchronization of `sync_ticks` (the
  inter-node communication the paper attributes to TP without NVLINK —
  "more than half of the total execution time");  throughput =
  micro / (S + sync) tokens per stage-tick.

  Flood: S+1 micro-batches in flight; stages execute concurrently, so
  throughput = micro * utilization tokens per tick, with utilization
  measured from the real event-driven scheduler — then normalized by S to
  compare per-device.

Also exercises the segment cache (extend/append under growth).
"""
import numpy as np

from repro.serving.flood import FloodEngine, GenRequest
from repro.serving.segment_cache import SegmentCache

S_STAGES = 4


def _stub():
    def embed(reqs):
        return {"n": len(reqs)}

    def head(x, reqs):
        return [1] * len(reqs)

    return embed, [lambda x: x] * S_STAGES, head


def run(fast=False):
    n_req, max_new = (32, 24) if fast else (128, 48)
    micro = 4   # n_req/micro >= S+1 keeps the pipeline full
    embed, stages, head = _stub()
    cache = SegmentCache(1 << 18, initial_segment=8, extend_chunk=8)
    eng = FloodEngine(stages, head, embed, cache=cache, microbatch=micro)
    reqs = [GenRequest(i, np.arange(4, dtype=np.int32), max_new)
            for i in range(n_req)]
    eng.submit(reqs)
    stats = eng.run()

    util = stats.utilization
    # Flood throughput per stage-tick: micro * utilization (S devices).
    # Baseline throughput: micro tokens every (S + sync) ticks on the same
    # S devices.  Speedup = util * (S + sync) / S.
    sp_hi = util * (S_STAGES + 0.5 * S_STAGES) / S_STAGES   # sync = 50%
    sp_lo = util * (S_STAGES + 0.1 * S_STAGES) / S_STAGES   # sync = 10%
    rows = [
        ("flood_pipeline_utilization", "0", f"{util:.2%}"),
        ("flood_vs_tp_no_nvlink", "0",
         f"speedup={sp_hi:.2f}x_paper=1.35-2.40x"),
        ("flood_vs_tp_fast_link", "0", f"speedup={sp_lo:.2f}x"),
        ("flood_cache", "0",
         f"extends={cache.stats['extends']}_appends="
         f"{cache.stats['appends']}_waits={cache.stats['waits']}"),
    ]
    return rows, {"utilization": util,
                  "speedup_no_nvlink": sp_hi, "speedup_fast_link": sp_lo,
                  "cache_stats": cache.stats, "tokens": stats.tokens_out,
                  "paper_speedups": [1.35, 1.52, 2.08, 2.40]}
