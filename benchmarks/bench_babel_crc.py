"""Babel verification: content-sampled CRC vs full MD5 (paper: 100GB file
verified in ~3s instead of tens-to-hundreds of seconds)."""
import os
import tempfile
import time

from repro.checkpoint.babel import crc_sampled, md5_full


def run(fast=False):
    size = (16 << 20) if fast else (128 << 20)
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "big.bin")
        with open(p, "wb") as f:
            f.write(os.urandom(size))
        t0 = time.perf_counter()
        md5_full(p)
        t_md5 = time.perf_counter() - t0
        t0 = time.perf_counter()
        crc_sampled(p)
        t_crc = time.perf_counter() - t0
    ratio = t_md5 / max(t_crc, 1e-9)
    # extrapolate to the paper's 100GB file (md5 scales, sampled CRC ~O(1))
    md5_100g = t_md5 * (100 << 30) / size
    rows = [("babel_crc_sampled", f"{t_crc*1e6:.0f}",
             f"speedup={ratio:.0f}x_on_{size>>20}MB"),
            ("babel_verify_100GB_model", "0",
             f"md5~{md5_100g:.0f}s_vs_sampled~{t_crc:.2f}s_paper=3s")]
    return rows, {"file_mb": size >> 20, "md5_s": t_md5,
                  "crc_sampled_s": t_crc, "speedup": ratio,
                  "md5_100gb_extrapolated_s": md5_100g}
