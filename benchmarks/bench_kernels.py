"""Pallas kernel micro-timings (interpret mode on CPU: correctness-path
cost, NOT TPU performance) + the analytic HBM-traffic saving of the fused
NormHead (the kernel's reason to exist)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def run(fast=False):
    rs = np.random.RandomState(0)
    rows = []
    # grouped_matmul
    lhs = jnp.asarray(rs.randn(256, 128), jnp.float32)
    rhs = jnp.asarray(rs.randn(8, 128, 128) * 0.1, jnp.float32)
    gs = jnp.asarray([32] * 8, jnp.int32)
    us = _time(lambda: ops.grouped_matmul(lhs, rhs, gs, interpret=True))
    rows.append(("kernel_grouped_matmul_256x128x128", f"{us:.0f}",
                 "interpret_mode"))
    # normhead
    x = jnp.asarray(rs.randn(128, 256), jnp.float32)
    w = jnp.asarray(rs.randn(512, 256), jnp.float32)
    us = _time(lambda: ops.normhead_logits(x, w, interpret=True))
    rows.append(("kernel_normhead_128x256x512", f"{us:.0f}",
                 "interpret_mode"))
    # analytic HBM saving for Ling-Plus head: unfused reads W, writes W_n,
    # reads W_n; fused reads W once.
    V, d = 126464, 8192
    saved = 2 * V * d * 2 / 1e9
    rows.append(("kernel_normhead_hbm_saving", "0",
                 f"{saved:.1f}GB_per_step_ling_plus"))
    # wkv6
    B, T, H, hd = 2, 128, 2, 64
    args = [jnp.asarray(rs.randn(B, T, H, hd) * 0.3, jnp.float32)
            for _ in range(3)]
    w = jnp.asarray(rs.uniform(0.8, 0.99, (B, T, H, hd)), jnp.float32)
    u = jnp.asarray(rs.randn(H, hd) * 0.2, jnp.float32)
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    us = _time(lambda: ops.wkv6(args[0], args[1], args[2], w, u, s0,
                                interpret=True))
    rows.append((f"kernel_wkv6_{B}x{T}x{H}x{hd}", f"{us:.0f}",
                 "interpret_mode"))
    return rows, {"note": "interpret-mode timings validate correctness "
                          "path; TPU perf comes from the Mosaic build"}


def _time(fn, reps=2):
    r = fn()
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn()
        jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6
