"""Pallas kernel micro-timings (interpret mode on CPU: correctness-path
cost, NOT TPU performance) + the analytic HBM-traffic savings of the two
fused kernels (their reason to exist) + an expert-parallel dispatch case
(fused tp=1 vs ep tp=2 on a 2-device forced host-platform mesh — the
device count must be forced before jax initializes, so it runs in a
subprocess):

  * NormHead: unfused reads W, writes W_n, reads W_n; fused reads W once.
  * Fused MoE FFN: composing gather + 3x grouped_matmul (wrapper) +
    scatter pays an aligned-lhs relayout per GEMM, a (cap, ff) hidden
    round-trip, and a separate combine; the fused pipeline reads x and
    the weights once and writes the combined (T, d) output once.

Timed cases use interpret-safe shapes (Ling-Lite MoE structure — 64
experts, top-6, expert_d_ff=1408 — with d scaled down); the analytic
rows use the real Ling-Lite / Ling-Plus dimensions.
"""
import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

_EP_BENCH_SCRIPT = textwrap.dedent("""
    import os, sys, time, json
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs.base import get_smoke_config
    from repro.launch.mesh import make_local_mesh
    from repro import sharding
    from repro.sharding import make_axis_env
    from repro.core import moe as moe_lib

    fast = sys.argv[1] == "fast"
    reps, warmup = (2, 1) if fast else (5, 2)
    cfg = get_smoke_config("deepseek-moe-16b")
    T = 64 if fast else 128
    x = jnp.asarray(np.random.RandomState(0).randn(T, cfg.d_model) * 0.3,
                    jnp.float32)

    def build(tp, dispatch):
        mesh = make_local_mesh(1, tp)
        env = make_axis_env(mesh)
        params, specs = moe_lib.init_moe(jax.random.PRNGKey(3), cfg, env)
        def fn(p, xx):
            y, _, _ = moe_lib.moe_ffn(cfg, env, p, xx, train=False,
                                      dispatch=dispatch)
            return env.sp_scatter(y.astype(jnp.float32))
        call = jax.jit(sharding.shard_map(
            fn, mesh=mesh, in_specs=(specs, P()),
            out_specs=P("model")))
        return lambda: call(params, x)

    out = {}
    ys = {}
    for name, tp, dispatch in [("fused_tp1", 1, "fused"), ("ep_tp2", 2, "ep")]:
        f = build(tp, dispatch)
        for _ in range(warmup):
            jax.block_until_ready(f())
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            ys[name] = jax.block_until_ready(f())
            ts.append(time.perf_counter() - t0)
        out[name + "_us"] = float(np.median(ts)) * 1e6
    out["maxdiff"] = float(np.max(np.abs(
        np.asarray(ys["fused_tp1"]) - np.asarray(ys["ep_tp2"]))))
    out["T"] = T
    print("EPBENCH " + json.dumps(out))
""")


def _ep_dispatch_case(fast):
    """moe_ffn end-to-end: fused tp=1 vs expert-parallel tp=2 on a forced
    2-device host mesh.  Returns bench rows + the parsed measurement."""
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = (src + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else src)
    res = subprocess.run(
        [sys.executable, "-c", _EP_BENCH_SCRIPT, "fast" if fast else "full"],
        capture_output=True, text=True, timeout=900, env=env)
    line = next((l for l in res.stdout.splitlines()
                 if l.startswith("EPBENCH ")), None)
    if res.returncode != 0 or line is None:
        raise RuntimeError(f"ep bench subprocess failed: "
                           f"{res.stdout[-500:]}{res.stderr[-1500:]}")
    d = json.loads(line[len("EPBENCH "):])
    tag = f"T{d['T']}_deepseek_moe_smoke"
    rows = [
        (f"moe_ffn_fused_tp1_{tag}", f"{d['fused_tp1_us']:.0f}",
         "interpret_2dev_host_mesh"),
        (f"moe_ffn_ep_tp2_{tag}", f"{d['ep_tp2_us']:.0f}",
         f"all_to_all_dispatch_maxdiff_{d['maxdiff']:.1e}"),
    ]
    return rows, d


def moe_ffn_hbm_bytes(T, d, ff, cap, n_groups, bm=128, dtype_bytes=2,
                      gated=True):
    """Analytic HBM traffic (activation bytes; weights identical in both
    pipelines) of one MoE FFN forward.

    unfused = gather xs + [align, gemm, unalign-scatter] x 3 + act +
    combine; fused = read x once, write (T, d) fp32 once (+ index/gate
    arrays).  M_pad is the bm-aligned dispatch size the relayout
    materializes."""
    B = dtype_bytes
    m_pad = cap + n_groups * (bm - 1)
    n_in_gemms = 2 if gated else 1
    unfused = T * d * B + cap * d * B                 # x read + xs write
    for _ in range(n_in_gemms):                       # xs @ w1 (and w3)
        unfused += (cap * d + m_pad * d) * B          # align read+write
        unfused += (m_pad * d + m_pad * ff) * B       # gemm read+write
        unfused += (m_pad * ff + cap * ff) * B        # unalign read+write
    if gated:
        unfused += 3 * cap * ff * B                   # act(h1)*h3 rd2+wr1
    unfused += (cap * ff + m_pad * ff) * B            # h align
    unfused += (m_pad * ff + m_pad * d) * B           # h @ w2
    unfused += (m_pad * d + cap * d) * B              # out unalign
    unfused += (cap * d + T * d) * B                  # gate*out scatter
    fused = T * d * B + T * d * 4                     # x read, fp32 y write
    fused += cap * (4 + 4)                            # row_idx + gates
    return unfused, fused


def _moe_case(rs, T, d, ff, E, k):
    """Random MoE-shaped dispatch: cap = T*k slots sorted by expert."""
    cap = T * k
    counts = rs.multinomial(cap, [1.0 / E] * E)
    gs = jnp.asarray(counts, jnp.int32)
    tok = jnp.asarray(rs.randint(0, T, cap), jnp.int32)
    gate = jnp.asarray(rs.rand(cap).astype(np.float32) / k)
    x = jnp.asarray(rs.randn(T, d), jnp.float32)
    w1 = jnp.asarray(rs.randn(E, d, ff) * 0.05, jnp.float32)
    w3 = jnp.asarray(rs.randn(E, d, ff) * 0.05, jnp.float32)
    w2 = jnp.asarray(rs.randn(E, ff, d) * 0.05, jnp.float32)
    return x, w1, w2, w3, tok, gate, gs


def run(fast=False):
    rs = np.random.RandomState(0)
    rows = []
    # grouped_matmul (unfused kernel wrapper)
    lhs = jnp.asarray(rs.randn(256, 128), jnp.float32)
    rhs = jnp.asarray(rs.randn(8, 128, 128) * 0.1, jnp.float32)
    gs = jnp.asarray([32] * 8, jnp.int32)
    us = _time(lambda: ops.grouped_matmul(lhs, rhs, gs, interpret=True),
               fast=fast)
    rows.append(("kernel_grouped_matmul_256x128x128", f"{us:.0f}",
                 "interpret_mode"))

    # ---- fused MoE FFN pipeline vs the two unfused compositions --------
    # Ling-Lite MoE routing structure (64 experts, top-6, gated); d and
    # ff scaled down so the interpret-mode python grid stays tractable —
    # the analytic row below uses the real dimensions.
    # bf == ff keeps the interpret grid at one ff-step per tile (the
    # per-grid-step python cost dominates interpret timings; on TPU the
    # tile sweep picks bf for VMEM instead — see ROADMAP)
    T, d, ff, E, k = (64, 64, 176, 8, 2) if fast else (64, 128, 352, 64, 6)
    bm, bf = (32, 176) if fast else (16, 352)
    x, w1, w2, w3, tok, gate, gsz = _moe_case(rs, T, d, ff, E, k)
    tag = f"T{T}_d{d}_ff{ff}_E{E}_k{k}"

    us = _time(lambda: ops.moe_fused_ffn(
        x, w1, w2, w3, tok, gate, gsz, bm=bm, bf=bf, interpret=True),
        fast=fast)
    rows.append((f"kernel_moe_ffn_fused_{tag}", f"{us:.0f}",
                 "interpret_mode"))

    def ragged_ffn():
        xs = jnp.take(x, tok, axis=0)
        h = jax.nn.silu(jax.lax.ragged_dot(xs, w1, gsz)) \
            * jax.lax.ragged_dot(xs, w3, gsz)
        out = jax.lax.ragged_dot(h, w2, gsz) * gate[:, None]
        return jnp.zeros((T, d), jnp.float32).at[tok].add(out)

    us = _time(ragged_ffn, fast=fast)
    rows.append((f"kernel_moe_ffn_ragged_dot_{tag}", f"{us:.0f}",
                 "xla_reference"))

    def unfused_kernel_ffn():
        xs = jnp.take(x, tok, axis=0)
        h = jax.nn.silu(ops.grouped_matmul(xs, w1, gsz, bm=bm, bn=ff,
                                           interpret=True)) \
            * ops.grouped_matmul(xs, w3, gsz, bm=bm, bn=ff, interpret=True)
        out = ops.grouped_matmul(h, w2, gsz, bm=bm, bn=d, interpret=True)
        return jnp.zeros((T, d), jnp.float32).at[tok].add(
            out * gate[:, None])

    us = _time(unfused_kernel_ffn, fast=fast)
    rows.append((f"kernel_moe_ffn_unfused_gmm_{tag}", f"{us:.0f}",
                 "interpret_mode_3x_aligned_wrapper"))

    # ---- expert-parallel dispatch: fused tp=1 vs ep tp=2 ----------------
    ep_rows, ep_detail = _ep_dispatch_case(fast)
    rows.extend(ep_rows)

    # analytic HBM traffic at REAL Ling-Lite shapes (bf16, per dp shard
    # of 4096 tokens, one MoE layer forward)
    T_r, d_r, ff_r, E_r, k_r = 4096, 2048, 1408, 64, 6
    unf, fus = moe_ffn_hbm_bytes(T_r, d_r, ff_r, T_r * k_r, E_r)
    rows.append(("kernel_moe_ffn_hbm_saving", "0",
                 f"{(unf - fus) / 1e9:.2f}GB_per_layer_fwd_ling_lite_"
                 f"{unf / max(fus, 1):.1f}x_less_traffic"))

    # normhead
    x2 = jnp.asarray(rs.randn(128, 256), jnp.float32)
    w = jnp.asarray(rs.randn(512, 256), jnp.float32)
    us = _time(lambda: ops.normhead_logits(x2, w, interpret=True),
               fast=fast)
    rows.append(("kernel_normhead_128x256x512", f"{us:.0f}",
                 "interpret_mode"))
    # analytic HBM saving for Ling-Plus head: unfused reads W, writes W_n,
    # reads W_n; fused reads W once.
    V, dd = 126464, 8192
    saved = 2 * V * dd * 2 / 1e9
    rows.append(("kernel_normhead_hbm_saving", "0",
                 f"{saved:.1f}GB_per_step_ling_plus"))
    # paged attention: fused page-table-walking kernel vs the gathered
    # oracle on a small decode tick (the full sweep + committed JSON is
    # benchmarks/bench_paged_attn.py)
    from benchmarks.bench_paged_attn import (_decode_case, _fused_attn,
                                             _gathered_attn)
    pq, pk, pv, ptab, pmask = _decode_case(
        rs, B=4, n_lp=6, page_size=8, Hp=4, KV=2, hd=32,
        page_counts=[4, 2, 1, 1])
    us = _time(lambda: _fused_attn(pq, pk, pv, ptab, pmask), fast=fast)
    rows.append(("kernel_paged_attn_fused_B4_lp6_ps8", f"{us:.0f}",
                 "interpret_mode_decode_Q1"))
    pd = float(jnp.max(jnp.abs(
        _fused_attn(pq, pk, pv, ptab, pmask)
        - _gathered_attn(pq, pk, pv, ptab, pmask))))
    rows.append(("kernel_paged_attn_maxdiff_vs_gathered", "0",
                 f"{pd:.1e}_f32_summation_order"))

    # wkv6
    B, T3, H, hd = 2, 128, 2, 64
    args = [jnp.asarray(rs.randn(B, T3, H, hd) * 0.3, jnp.float32)
            for _ in range(3)]
    wv = jnp.asarray(rs.uniform(0.8, 0.99, (B, T3, H, hd)), jnp.float32)
    u = jnp.asarray(rs.randn(H, hd) * 0.2, jnp.float32)
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    us = _time(lambda: ops.wkv6(args[0], args[1], args[2], wv, u, s0,
                                interpret=True), fast=fast)
    rows.append((f"kernel_wkv6_{B}x{T3}x{H}x{hd}", f"{us:.0f}",
                 "interpret_mode"))
    return rows, {"note": "interpret-mode timings validate correctness "
                          "path; TPU perf comes from the Mosaic build",
                  "ep_dispatch": ep_detail}


def _time(fn, reps=5, warmup=2, fast=False):
    """Median of `reps` timed calls after `warmup` untimed ones (the
    first call includes jit tracing)."""
    if fast:
        reps, warmup = 2, 1
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6
