"""§Dry-run / §Roofline: aggregate the per-(arch x shape x mesh) dry-run
artifacts into the roofline table (also rendered into EXPERIMENTS.md),
plus an analytic roofline for the fused MoE FFN Pallas pipeline (the
dispatch="fused" hot path) at real Ling-Lite shapes."""
import glob
import json
import os

from repro import roofline as R


def _fused_moe_roofline(rows, table):
    """Analytic three-term view of one Ling-Lite MoE FFN layer (per
    dp-shard forward, bf16).  The HBM saving (no aligned-lhs relayout,
    no (cap, ff) hidden round-trip, no separate combine) is counted for
    the fused pipeline; FLOPs are counted honestly per variant — the
    as-written kernel pays 4*cap*T*d extra one-hot gather/scatter FLOPs
    (dominant at training T), the "fused_dma" row is the ROADMAP target
    where dynamic-slice DMA removes them and only the HBM saving
    remains."""
    from benchmarks.bench_kernels import moe_ffn_hbm_bytes

    T, d, ff, E, k = 4096, 2048, 1408, 64, 6
    cap = T * k
    unfused_b, fused_b = moe_ffn_hbm_bytes(T, d, ff, cap, E)
    weight_b = E * (3 * d * ff) * 2              # read once in both
    gemm_flops = 2 * cap * d * ff * 3            # w1, w3, w2
    onehot_flops = 4 * cap * T * d               # (bm,T) gather + scatter
    variants = (
        ("unfused", unfused_b, gemm_flops),
        ("fused_onehot", fused_b, gemm_flops + onehot_flops),
        ("fused_dma", fused_b, gemm_flops),
    )
    for name, act_bytes, flops in variants:
        compute_s = flops / R.PEAK_FLOPS
        mem_s = (act_bytes + weight_b) / R.HBM_BW
        bottleneck = "compute" if compute_s >= mem_s else "memory"
        rows.append((f"roofline_moe_ffn_{name}_ling_lite",
                     f"{max(compute_s, mem_s) * 1e6:.0f}",
                     f"bn={bottleneck}_hbm={act_bytes / 1e9:.2f}GB_act"))
        table.append({
            "arch": "ling-lite", "shape": f"moe_ffn_{name}",
            "mesh": "analytic", "compute_s": compute_s,
            "memory_s": mem_s, "collective_s": 0.0,
            "bottleneck": bottleneck,
            "useful_ratio": 1.0, "status": "ok",
        })


def run(fast=False):
    rows = []
    table = []
    _fused_moe_roofline(rows, table)
    artifacts = sorted(glob.glob("experiments/dryrun/*.json"))
    for path in artifacts:
        rec = json.load(open(path))
        tag = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
        if rec["status"] == "skipped":
            table.append({**rec})
            continue
        if rec["status"] != "ok":
            rows.append((f"roofline_{tag}", "0", "ERROR"))
            continue
        r = rec["roofline"]
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append((f"roofline_{tag}", f"{dom*1e6:.0f}",
                     f"bn={r['bottleneck']}_useful={r['useful_ratio']:.2f}"))
        table.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "bottleneck": r["bottleneck"],
            "useful_ratio": r["useful_ratio"],
            "status": "ok",
        })
    if not artifacts:
        rows.append(("roofline", "0",
                     "no_dryrun_artifacts_run_repro.launch.dryrun"))
    return rows, {"table": table}
