"""§Dry-run / §Roofline: aggregate the per-(arch x shape x mesh) dry-run
artifacts into the roofline table (also rendered into EXPERIMENTS.md)."""
import glob
import json
import os

from repro import roofline as R


def run(fast=False):
    rows = []
    table = []
    for path in sorted(glob.glob("experiments/dryrun/*.json")):
        rec = json.load(open(path))
        tag = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
        if rec["status"] == "skipped":
            table.append({**rec})
            continue
        if rec["status"] != "ok":
            rows.append((f"roofline_{tag}", "0", "ERROR"))
            continue
        r = rec["roofline"]
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append((f"roofline_{tag}", f"{dom*1e6:.0f}",
                     f"bn={r['bottleneck']}_useful={r['useful_ratio']:.2f}"))
        table.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "bottleneck": r["bottleneck"],
            "useful_ratio": r["useful_ratio"],
            "status": "ok",
        })
    if not table:
        rows.append(("roofline", "0",
                     "no_dryrun_artifacts_run_repro.launch.dryrun"))
    return rows, {"table": table}
