"""Online continuous-batching serving under Poisson load (ROADMAP north
star: serve arriving traffic, not just fixed offline batches).

Drives the `OnlineEngine` (paged device KV cache + slot-based continuous
batching, docs/serving.md) with the Poisson load generator at two arrival
rates and reports TTFT p50/p99, inter-token latency p50/p99, and
sustained tok/s per rate, plus the compile counts (must be exactly one
prefill + one decode trace across all churn).  Two extra cases cover the
newer engine layers: a **speculative decoding** load (self-draft drafter;
token-exact greedy output checked against a non-spec engine, acceptance
rate and decode-ticks-per-emitted-token reported, the full-depth drafter
required to land under 0.7 ticks/token), a **hot-prefix** load (every
prompt opens with a shared system prompt; the content-addressed radix
cache must find it with no caller-supplied key and report a hit rate
above 0.5), and a **scheduler-policy sweep** (the same saturating
hot-prefix load under fcfs / decode-priority / prefill-priority tick
ordering on one engine — policy switches are host bookkeeping, so the
compile counters must stay at one trace per step shape), and an
**SLO-shedding** comparison past the knee (unbounded fcfs vs a static
queue gate vs the `SLOTracker` gate at the same 4x-overload rate: only
the SLO gate keeps admitted-request TTFT p99 inside a machine-relative
deadline — half the better baseline p99 measured on this host — paying
with explicit sheds).

Writes the committed trajectory artifact ``BENCH_serve_online.json`` at
the repo root.  Interpret-mode CPU wall clock: the latency *shape*
(queueing at high rate, flat inter-token latency) is the claim, not the
absolute numbers.
"""
from __future__ import annotations

import json
import os
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run(fast: bool = False):
    import jax  # noqa: F401  (defer heavy imports to run())
    from repro import api
    from repro.configs.base import get_smoke_config
    from repro.launch.mesh import make_local_mesh
    from repro.serving.online import (OnlineConfig, OnlineEngine,
                                      run_poisson_load)

    cfg = get_smoke_config("ling-lite")
    mesh = make_local_mesh(1, 1)
    runner = api.Runner(cfg, mesh, fsdp=False, seq_parallel=False,
                        max_seq=64)
    params = runner.init_params(0)

    n_req, max_new = (12, 6) if fast else (24, 10)
    geometry = dict(max_slots=4, max_context=64, page_size=16,
                    prefill_chunk=8)

    # calibrate the arrival rates to this machine's tick time so the two
    # loads straddle saturation; the first probe run eats the compiles,
    # the second measures warm ticks
    probe = OnlineEngine(runner, params, OnlineConfig(**geometry))
    run_poisson_load(probe, rate=100.0, n_requests=3, prompt_len=8,
                     max_new=3, vocab_size=cfg.vocab_size)
    t_probe = run_poisson_load(probe, rate=100.0, n_requests=6,
                               prompt_len=8, max_new=3,
                               vocab_size=cfg.vocab_size, seed=1)
    tick_s = t_probe["wall_s"] / max(t_probe["ticks"], 1)
    svc_rate = 1.0 / max(tick_s * max_new, 1e-6)  # ~requests/s at full batch
    rates = [0.5 * geometry["max_slots"] * svc_rate,
             2.0 * geometry["max_slots"] * svc_rate]

    rows, cases = [], []
    for rate in rates:
        eng = OnlineEngine(runner, params, OnlineConfig(**geometry))
        # eat the two compiles outside the measured window (the compile
        # counters still prove one-compile-per-shape across the real load)
        run_poisson_load(eng, rate=100.0, n_requests=2, prompt_len=8,
                         max_new=2, vocab_size=cfg.vocab_size, seed=7)
        rep = run_poisson_load(eng, rate=rate, n_requests=n_req,
                               prompt_len=8, max_new=max_new,
                               vocab_size=cfg.vocab_size)
        assert rep["prefill_compiles"] == 1, rep["prefill_compiles"]
        assert rep["decode_compiles"] == 1, rep["decode_compiles"]
        tag = f"rate{rate:.1f}"
        rows.append((f"serve_online_{tag}_tok_s", f"{rep['tok_s']:.1f}",
                     f"n{n_req}_new{max_new}"))
        rows.append((f"serve_online_{tag}_ttft_p50_ms",
                     f"{rep['ttft_p50_ms']:.1f}",
                     f"p99={rep['ttft_p99_ms']:.1f}"))
        rows.append((f"serve_online_{tag}_itl_p50_ms",
                     f"{rep['itl_p50_ms']:.2f}",
                     f"p99={rep['itl_p99_ms']:.2f}"))
        cases.append(rep)

    # -- speculative decoding case --------------------------------------------
    from repro.serving.draft import SelfDrafter
    from repro.serving.online import OnlineRequest
    import numpy as np

    spec_rate = 0.5 * geometry["max_slots"] * svc_rate
    spec_cases = []
    # full-depth self-draft = acceptance upper bound (the <0.7
    # ticks/token claim); 1-layer self-draft = the realistic
    # truncated-drafter row
    for draft_layers in (cfg.n_layers, 1):
        eng = OnlineEngine(runner, params,
                           OnlineConfig(**geometry, spec_k=2),
                           drafter=SelfDrafter(draft_layers=draft_layers))
        run_poisson_load(eng, rate=100.0, n_requests=2, prompt_len=8,
                         max_new=2, vocab_size=cfg.vocab_size, seed=7)
        rep = run_poisson_load(eng, rate=spec_rate, n_requests=n_req,
                               prompt_len=8, max_new=max_new,
                               vocab_size=cfg.vocab_size)
        assert rep["prefill_compiles"] == 1, rep["prefill_compiles"]
        assert rep["draft_compiles"] == 1, rep["draft_compiles"]
        assert rep["verify_compiles"] == 1, rep["verify_compiles"]
        if draft_layers == cfg.n_layers:
            # exact self-copy drafter: every draft accepted, each tick
            # commits k+1 tokens
            assert rep["acceptance_rate"] == 1.0, rep["acceptance_rate"]
            assert rep["decode_ticks_per_token"] < 0.7, \
                rep["decode_ticks_per_token"]
        tag = f"speck2_L{draft_layers}"
        rows.append((f"serve_online_{tag}_ticks_per_tok",
                     f"{rep['decode_ticks_per_token']:.3f}",
                     f"acc={rep['acceptance_rate']:.3f}"))
        rows.append((f"serve_online_{tag}_tok_s", f"{rep['tok_s']:.1f}",
                     f"n{n_req}_new{max_new}"))
        rep["draft_layers"] = draft_layers
        spec_cases.append(rep)

    # greedy spec output is token-exact vs the non-spec engine on a
    # fixed prompt set (acceptance changes speed, never tokens)
    rs = np.random.RandomState(11)
    prompts = [rs.randint(0, cfg.vocab_size, 8).astype(np.int32)
               for _ in range(4)]

    def fixed_run(spec):
        if spec:
            e = OnlineEngine(runner, params,
                             OnlineConfig(**geometry, spec_k=2),
                             drafter=SelfDrafter(draft_layers=1))
        else:
            e = OnlineEngine(runner, params, OnlineConfig(**geometry))
        e.submit_many([OnlineRequest(rid=i, prompt=prompts[i], max_new=6)
                       for i in range(4)])
        e.run(max_ticks=1000)
        return [list(e.reqs[i].out) for i in range(4)]

    assert fixed_run(True) == fixed_run(False), \
        "speculative greedy output diverged from non-spec greedy"

    # -- hot-prefix case (shared system prompt, radix cache) ------------------
    # No caller-supplied prefix_key anywhere: the content-addressed radix
    # cache must find the shared 16-token prefix on its own.
    eng = OnlineEngine(runner, params, OnlineConfig(**geometry))
    run_poisson_load(eng, rate=100.0, n_requests=2, prompt_len=8,
                     max_new=2, vocab_size=cfg.vocab_size, seed=7)
    hot = run_poisson_load(eng, rate=0.5 * geometry["max_slots"] * svc_rate,
                           n_requests=n_req, prompt_len=24, max_new=max_new,
                           vocab_size=cfg.vocab_size,
                           shared_prefix_len=16)
    assert hot["prefix_hit_rate"] > 0.5, hot["prefix_hit_rate"]
    rows.append(("serve_online_hot_prefix_hit_rate",
                 f"{hot['prefix_hit_rate']:.3f}",
                 f"hits={hot['prefix_hits']}_shared16"))
    rows.append(("serve_online_hot_prefix_tok_s", f"{hot['tok_s']:.1f}",
                 f"ttft_p50={hot['ttft_p50_ms']:.1f}ms"))

    # -- scheduler-policy sweep (one engine, set_policy between loads) --------
    # Same hot-prefix workload under each tick-ordering policy.  One
    # engine serves all three: policy is host-side bookkeeping, so the
    # compile counters must stay at 1 prefill + 1 decode across the
    # whole sweep.
    eng = OnlineEngine(runner, params, OnlineConfig(**geometry))
    run_poisson_load(eng, rate=100.0, n_requests=2, prompt_len=8,
                     max_new=2, vocab_size=cfg.vocab_size, seed=7)
    policy_cases = []
    for policy in ("fcfs", "decode-priority", "prefill-priority"):
        eng.set_policy(policy)
        rep = run_poisson_load(
            eng, rate=2.0 * geometry["max_slots"] * svc_rate,
            n_requests=n_req, prompt_len=24, max_new=max_new,
            vocab_size=cfg.vocab_size, shared_prefix_len=16)
        assert rep["prefill_compiles"] == 1, rep["prefill_compiles"]
        assert rep["decode_compiles"] == 1, rep["decode_compiles"]
        rows.append((f"serve_online_{policy}_ttft_p50_ms",
                     f"{rep['ttft_p50_ms']:.1f}",
                     f"itl_p50={rep['itl_p50_ms']:.2f}ms"))
        rows.append((f"serve_online_{policy}_preempts",
                     f"{rep['preemptions']}",
                     f"hit_rate={rep['prefix_hit_rate']:.2f}"))
        policy_cases.append(rep)

    # -- SLO-aware shedding past the knee -------------------------------------
    # 4x-overload rate, three admission responses: unbounded fcfs
    # queueing (every request admitted, TTFT absorbs the overload and
    # breaches any deadline), a static queue gate (sheds on a fixed
    # depth picked without latency knowledge — still breaches), and the
    # SLOTracker gate (sheds on its windowed TTFT estimate — the p99 of
    # ADMITTED requests stays inside the deadline).  The deadline is
    # machine-relative: half the better of the two baseline p99s as
    # measured on this host (unbounded queueing grows with the load,
    # the static gate saturates at its depth — taking min of both keeps
    # every breach assertion a 2x margin at any tick speed).
    from repro.telemetry import SLOConfig

    knee_rate = 4.0 * geometry["max_slots"] * svc_rate
    n_slo = 2 * n_req            # sustained overload, not a short burst

    def slo_case(ocfg):
        eng = OnlineEngine(runner, params, ocfg)
        # eats the compiles AND warms the tick window past
        # min_observations so the gate is armed when the load starts
        run_poisson_load(eng, rate=100.0, n_requests=2, prompt_len=8,
                         max_new=2, vocab_size=cfg.vocab_size, seed=7)
        rep = run_poisson_load(eng, rate=knee_rate, n_requests=n_slo,
                               prompt_len=8, max_new=max_new,
                               vocab_size=cfg.vocab_size)
        assert rep["prefill_compiles"] == 1, rep["prefill_compiles"]
        assert rep["decode_compiles"] == 1, rep["decode_compiles"]
        return rep

    slo_cases = {"fcfs_unbounded": slo_case(OnlineConfig(**geometry))}
    slo_cases["static_gate"] = slo_case(
        OnlineConfig(**geometry, max_queue=3 * geometry["max_slots"],
                     overload="shed"))
    deadline_ms = 0.5 * min(slo_cases["fcfs_unbounded"]["ttft_p99_ms"],
                            slo_cases["static_gate"]["ttft_p99_ms"])
    slo_cases["slo_gate"] = slo_case(
        OnlineConfig(**geometry, overload="slo",
                     slo=SLOConfig(ttft_p99_ms=deadline_ms, window=64,
                                   min_observations=4, headroom=5.0)))
    for mode, rep in slo_cases.items():
        rep["ttft_deadline_ms"] = deadline_ms
        rows.append((f"serve_online_{mode}_ttft_p99_ms",
                     f"{rep['ttft_p99_ms']:.1f}",
                     f"deadline={deadline_ms:.1f}_shed={rep['shed']}"))
    assert slo_cases["fcfs_unbounded"]["shed"] == 0
    assert slo_cases["fcfs_unbounded"]["ttft_p99_ms"] > deadline_ms, \
        (slo_cases["fcfs_unbounded"]["ttft_p99_ms"], deadline_ms)
    # a depth-only gate sheds a little but admits deep queues anyway
    assert slo_cases["static_gate"]["ttft_p99_ms"] > deadline_ms, \
        (slo_cases["static_gate"]["ttft_p99_ms"], deadline_ms)
    assert slo_cases["slo_gate"]["shed"] > 0, "gate never fired"
    assert slo_cases["slo_gate"]["ttft_p99_ms"] <= deadline_ms, \
        (slo_cases["slo_gate"]["ttft_p99_ms"], deadline_ms)

    detail = {
        "bench": "online continuous-batching serving engine "
                 "(paged KV + Poisson load)",
        "arch": "ling-lite smoke",
        "engine": geometry,
        "probe_tick_s": tick_s,
        "rates": cases,
        "speculative": spec_cases,
        "hot_prefix": hot,
        "policies": policy_cases,
        "slo_shedding": slo_cases,
        "claim": "continuous batching holds inter-token latency roughly "
                 "flat while TTFT absorbs overload (queueing), with one "
                 "compile per step shape across all churn; speculative "
                 "decoding pushes decode ticks per emitted token under "
                 "0.7 at full acceptance while staying token-exact under "
                 "greedy; a shared system prompt turns into radix "
                 "prefix-cache hits (no caller-supplied key) that skip "
                 "prefill work at >0.5 hit rate; scheduler policies "
                 "reorder the same jitted steps with zero recompiles; "
                 "past the knee the SLO gate sheds on its windowed TTFT "
                 "estimate and keeps admitted-request TTFT p99 inside "
                 "the deadline that unbounded fcfs queueing breaches",
    }
    with open(os.path.join(ROOT, "BENCH_serve_online.json"), "w") as f:
        json.dump({**detail, "date": time.strftime("%Y-%m-%d"),
                   "command": "PYTHONPATH=src python -m benchmarks.run "
                              "--only serve_online",
                   "environment": "single-process CPU jax, Pallas "
                                  "interpret mode - latency shape, NOT "
                                  "TPU performance"},
                  f, indent=1)
    return rows, detail
