"""Fig. 14: train-loss comparison with vs without the skip-loss-spikes +
sample-retry mechanism.  A tiny model trains on synthetic data with
periodically injected poison batches (the data/optimizer interaction that
causes spikes); with the mechanism ON, poison updates are skipped and the
final loss is strictly better."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.configs.base import get_smoke_config
from repro.core.spikes import SpikeConfig, SpikeDetector
from repro.data.pipeline import DataPipeline, PipelineConfig
from repro.launch.mesh import make_local_mesh
from repro.optim import adamw


def run(fast=False):
    import dataclasses
    cfg = dataclasses.replace(
        get_smoke_config("phi3-mini-3.8b"), n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=512)
    mesh = make_local_mesh(1, 1)
    runner = api.Runner(cfg, mesh, max_seq=64)
    step = jax.jit(runner.make_train_step(4))
    pipe_cfg = PipelineConfig(vocab_size=cfg.vocab_size, seq_len=64,
                              batch_size=4, seed=0)
    n_steps = 60 if fast else 160
    poison_every = 8

    def train(with_skip: bool):
        pipe = DataPipeline(pipe_cfg)
        params = runner.init_params(0)
        opt = adamw.init_opt_state(params)
        det = SpikeDetector(SpikeConfig(warmup_steps=10,
                                        sigma_threshold=4.0,
                                        abs_threshold=2.5))
        losses = []
        rs = np.random.RandomState(0)
        for i in range(n_steps):
            batch = pipe.next_batch()
            lr_i = 1e-3
            if i % poison_every == poison_every - 1:
                # poison: constant-label batch + gradient surge (the paper
                # attributes wide spikes to "abrupt gradient surges" from
                # specific data/optimizer-state interactions, §6.1; the lr
                # multiplier models the surge's effect on Adam's moments)
                batch = dict(batch)
                batch["labels"] = np.full(batch["labels"].shape,
                                          rs.randint(cfg.vocab_size),
                                          dtype="int32")
                lr_i = 2e-2
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            p2, o2, m = step(params, opt, jb, jnp.int32(i),
                             jax.random.PRNGKey(i), jnp.float32(lr_i))
            loss = float(m["loss"])
            v = det.observe(i, loss, batch=batch) if with_skip else \
                {"skip": False}
            if not v["skip"]:
                params, opt = p2, o2
            losses.append(loss)
        return losses, det

    base, _ = train(False)
    skipped, det = train(True)
    # compare clean-batch loss at the end of training
    clean = [i for i in range(n_steps - 24, n_steps)
             if i % poison_every != poison_every - 1]
    l_base = float(np.mean([base[i] for i in clean]))
    l_skip = float(np.mean([skipped[i] for i in clean]))
    rows = [("spike_skip_final_loss", "0",
             f"with={l_skip:.3f}_without={l_base:.3f}_improvement="
             f"{l_base-l_skip:+.3f}"),
            ("spike_events", "0", f"n={len(det.events)}")]
    return rows, {"loss_with_skip": skipped, "loss_without": base,
                  "final_with": l_skip, "final_without": l_base,
                  "events": len(det.events)}
