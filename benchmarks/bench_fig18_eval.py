"""Fig. 18: optimized (content-target) vs original (label-target)
perplexity evaluation across early-training checkpoints of a real tiny
model — content scoring shows a stable capability-growth trend while
label scoring hovers near chance."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.configs.base import get_smoke_config
from repro.data.pipeline import DataPipeline, PipelineConfig
from repro.evals import harness as H
from repro.launch.mesh import make_local_mesh
from repro.optim import adamw

SEQ = 48   # >= longest eval sequence (label-mode: ctx + K*(1+opt) + 1)


def run(fast=False):
    cfg = dataclasses.replace(
        get_smoke_config("phi3-mini-3.8b"), d_model=128, d_ff=256)
    mesh = make_local_mesh(1, 1)
    runner = api.Runner(cfg, mesh, max_seq=SEQ)
    params = runner.init_params(0)
    opt = adamw.init_opt_state(params)
    step = jax.jit(runner.make_train_step(8))
    score_jit = jax.jit(runner.make_score_fn(batch_size=1, seq_len=SEQ))

    # training stream that CONTAINS the eval task's stride patterns
    items = H.make_mc_dataset(24 if fast else 40, vocab=cfg.vocab_size,
                              seed=0)
    rs = np.random.RandomState(0)

    def pattern_batch():
        toks = np.zeros((8, SEQ), np.int32)
        for r in range(8):
            stride = 7 + rs.randint(5)
            base = rs.randint(cfg.vocab_size - 64)
            toks[r] = (base + stride * np.arange(SEQ)) % cfg.vocab_size
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}

    def score_fn(seq, mask):
        pad = SEQ - len(seq)
        t = np.pad(seq, (0, pad)).astype(np.int32)
        m = np.pad(mask, (0, pad)).astype(np.float32)
        return float(score_jit(params, jnp.asarray(t)[None],
                               jnp.asarray(m)[None])[0])

    curves = {"content": [], "label": []}
    ckpts = 4 if fast else 6
    steps_per = 10 if fast else 20
    i = 0
    for _ in range(ckpts):
        curves["content"].append(
            H.ppl_eval_content(items, score_fn)["accuracy"])
        curves["label"].append(
            H.ppl_eval_label(items, score_fn,
                             label_tokens=[1, 2, 3, 4])["accuracy"])
        for _ in range(steps_per):
            b = pattern_batch()
            # fix seq mismatch: tokens (8, SEQ-1); pad to SEQ? use SEQ-1 step
            params, opt, _ = step(params, opt,
                                  {"tokens": b["tokens"],
                                   "labels": b["labels"]},
                                  jnp.int32(i), jax.random.PRNGKey(i),
                                  jnp.float32(2e-3))
            i += 1
    # consistency: same eval run twice (deterministic scorer) -> 0 deviation
    a = H.ppl_eval_content(items, score_fn)
    b = H.ppl_eval_content(items, score_fn)
    dev = H.consistency(a, b)["mean_abs_deviation"]
    rows = [
        ("eval_content_curve", "0",
         "->".join(f"{x:.2f}" for x in curves["content"])),
        ("eval_label_curve", "0",
         "->".join(f"{x:.2f}" for x in curves["label"])),
        ("eval_consistency_dev", "0", f"{dev:.4f}_paper<0.005"),
    ]
    return rows, {"curves": curves, "consistency_dev": dev}
