"""§4.2: DPO data-packing throughput (paper: 3.7x vs padded pairs).

Speedup = padded rows / packed rows at fixed max_len, using a response
length distribution typical of preference data (long-tailed)."""
import numpy as np

from repro.training.dpo import PairExample, packing_speedup


def run(fast=False):
    rs = np.random.RandomState(0)
    n = 128 if fast else 512
    pairs = []
    for _ in range(n):
        plen = rs.randint(10, 80)
        # long-tailed response lengths, most far below max_len
        cl = int(np.clip(rs.lognormal(4.6, 0.7), 20, 1800))
        rl = int(np.clip(rs.lognormal(4.6, 0.7), 20, 1800))
        pairs.append(PairExample(
            prompt=rs.randint(0, 5000, plen).astype(np.int32),
            chosen=rs.randint(0, 5000, cl).astype(np.int32),
            rejected=rs.randint(0, 5000, rl).astype(np.int32)))
    rep = packing_speedup(pairs, max_len=2048)
    rows = [("dpo_packing", "0",
             f"speedup={rep['speedup']:.2f}x_paper=3.7x"),
            ("dpo_useful_frac", "0",
             f"padded={rep['useful_frac_padded']:.2f}_packed="
             f"{rep['useful_frac_packed']:.2f}")]
    return rows, {**rep, "paper_claim": 3.7}
