"""Fig. 4: XPUTimer memory usage vs full tracing (~90% reduction claim)."""
import time

from repro.telemetry.xputimer import XPUTimer


def run(fast=False):
    t = XPUTimer()
    n = 2000 if fast else 20000
    t0 = time.perf_counter()
    for i in range(n):
        with t.span("fwd"):
            pass
        with t.span("bwd"):
            pass
        with t.span("allreduce"):
            pass
    per_span_us = (time.perf_counter() - t0) / (3 * n) * 1e6
    rep = t.diagnose()
    reduction = 1.0 - rep["log_bytes"] / rep["full_tracing_bytes"]
    rows = [("xputimer_span_overhead", f"{per_span_us:.2f}",
             f"mem_reduction={reduction:.2%}_claim=90%")]
    return rows, {"claim": 0.90, "measured_reduction": reduction,
                  "log_bytes": rep["log_bytes"],
                  "full_tracing_bytes": rep["full_tracing_bytes"],
                  "span_overhead_us": per_span_us}
