"""Self-draft speculative decoding economics: acceptance rate and decode
ticks per emitted token across (spec_k, draft_layers), against the
non-speculative baseline on the same fixed workload.

The smoke target has 2 layers, so `draft_layers=2` is the exact-copy
drafter (acceptance 1.0 — the upper bound: ticks/token = 1/(k+1)) and
`draft_layers=1` is the realistic truncated drafter whose acceptance
depends on how often half the stack agrees with the full stack.  Greedy
outputs are asserted token-exact against the baseline for every
configuration — speculation changes speed, never tokens.

Writes ``BENCH_spec_decode.json`` at the repo root.  Interpret-mode CPU
wall clock: the ticks-per-token ratio is the claim (it transfers to
accelerators), the absolute seconds are not.
"""
from __future__ import annotations

import json
import os
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run(fast: bool = False):
    import numpy as np
    from repro import api
    from repro.configs.base import get_smoke_config
    from repro.launch.mesh import make_local_mesh
    from repro.serving.draft import SelfDrafter
    from repro.serving.online import (OnlineConfig, OnlineEngine,
                                      OnlineRequest)

    cfg = get_smoke_config("ling-lite")
    mesh = make_local_mesh(1, 1)
    runner = api.Runner(cfg, mesh, fsdp=False, seq_parallel=False,
                        max_seq=64)
    params = runner.init_params(0)

    B, P, NEW = (4, 6, 6) if fast else (4, 8, 12)
    geometry = dict(max_slots=B, max_context=64, page_size=16,
                    prefill_chunk=4)
    rs = np.random.RandomState(0)
    prompts = [rs.randint(0, cfg.vocab_size, P).astype(np.int32)
               for _ in range(B)]

    def drive(spec_k=0, draft_layers=None):
        if spec_k > 0:
            eng = OnlineEngine(runner, params,
                               OnlineConfig(**geometry, spec_k=spec_k),
                               drafter=SelfDrafter(
                                   draft_layers=draft_layers))
        else:
            eng = OnlineEngine(runner, params, OnlineConfig(**geometry))
        eng.submit_many([OnlineRequest(rid=i, prompt=prompts[i],
                                       max_new=NEW) for i in range(B)])
        t0 = time.perf_counter()
        eng.run(max_ticks=3000)
        wall = time.perf_counter() - t0
        out = [list(eng.reqs[i].out) for i in range(B)]
        ticks = sum(eng.reqs[i].n_decode_ticks for i in range(B))
        decoded = sum(len(o) - 1 for o in out)
        return {
            "spec_k": spec_k,
            "draft_layers": draft_layers,
            "wall_s": wall,
            "tokens_out": sum(len(o) for o in out),
            "decode_ticks": ticks,
            "ticks_per_token": ticks / max(decoded, 1),
            "acceptance_rate": (eng.spec_accepted
                                / max(eng.spec_proposed, 1)),
            "compiles": {"prefill": eng.prefill_traces,
                         "decode": eng.decode_traces,
                         "draft": eng.draft_traces,
                         "verify": eng.verify_traces},
        }, out

    base, ref = drive()
    assert base["ticks_per_token"] == 1.0, base["ticks_per_token"]

    ks = (2,) if fast else (2, 4)
    rows, sweep = [], [base]
    for k in ks:
        for L in (cfg.n_layers, 1):
            rep, out = drive(spec_k=k, draft_layers=L)
            assert out == ref, f"spec k={k} L={L} diverged from greedy"
            assert rep["compiles"]["draft"] == 1
            assert rep["compiles"]["verify"] == 1
            if L == cfg.n_layers:
                assert rep["acceptance_rate"] == 1.0
                # exact drafter commits k+1 tokens per tick (up to the
                # final partial tick)
                assert rep["ticks_per_token"] <= 1.0 / (k + 1) + 0.15, \
                    rep["ticks_per_token"]
            rows.append((f"spec_decode_k{k}_L{L}_ticks_per_tok",
                         f"{rep['ticks_per_token']:.3f}",
                         f"acc={rep['acceptance_rate']:.3f}"))
            sweep.append(rep)

    detail = {
        "bench": "self-draft speculative decoding (online engine)",
        "arch": "ling-lite smoke",
        "engine": geometry,
        "workload": {"requests": B, "prompt_len": P, "max_new": NEW},
        "baseline": base,
        "sweep": sweep,
        "claim": "greedy spec output is token-exact vs non-spec for every "
                 "(k, draft_layers); the exact-copy drafter reaches "
                 "acceptance 1.0 and ~1/(k+1) decode ticks per token; "
                 "compile counts stay 1 prefill + 1 draft + 1 verify",
    }
    with open(os.path.join(ROOT, "BENCH_spec_decode.json"), "w") as f:
        json.dump({**detail, "date": time.strftime("%Y-%m-%d"),
                   "command": "PYTHONPATH=src python -m benchmarks.run "
                              "--only spec_decode",
                   "environment": "single-process CPU jax, Pallas "
                                  "interpret mode - tick ratios, NOT "
                                  "TPU performance"},
                  f, indent=1)
    return rows, detail
