"""Training-engine step-time bench: the mesh-native train step with and
without buffer donation and microbatch grad accumulation (same tokens per
optimizer step in every variant), plus the host-sync cost of the legacy
per-step `float(loss)` loop vs the engine's async dispatch.

Variants (ling-lite smoke, tp=1, interpret kernels):
  classic        no donation, no accumulation, per-step host sync on loss
  donate         donated params/opt/guard, async dispatch
  accum          2-microbatch lax.scan accumulation, no donation
  donate+accum   the engine default

Plus a batch-size-warmup sweep (§3.4.1): the staged engine walks accum
1 -> 2 -> 4 at a fixed microbatch, recording per-stage step time and the
total compile count (must equal the number of distinct stages).

Writes the committed trajectory artifact ``BENCH_train_step.json`` at the
repo root (plus the harness's experiments/bench/train_step.json detail).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _steps(step_fn, state, batch, n, *, sync_each):
    """Run n chained steps; sync per step (legacy loop) or once at the
    end (engine's async dispatch)."""
    for t in range(n):
        state = step_fn(state, batch, t)
        if sync_each:
            float(state[-1]["loss"])
    jax.block_until_ready(state[:-1])
    return state


def run(fast=False):
    from repro import api
    from repro.configs.base import get_smoke_config
    from repro.core import spikes
    from repro.launch.mesh import make_local_mesh
    from repro.optim import adamw

    cfg = get_smoke_config("ling-lite")
    S, A, Bm = 64, 2, 2
    B = A * Bm                      # total tokens/optimizer step is fixed
    n, warmup = (3, 1) if fast else (6, 2)
    runner = api.Runner(cfg, make_local_mesh(1, 1), max_seq=S)
    rs = np.random.RandomState(0)
    toks = rs.randint(0, cfg.vocab_size, (A, Bm, S)).astype(np.int32)
    labs = rs.randint(0, cfg.vocab_size, (A, Bm, S)).astype(np.int32)
    flat = {"tokens": jnp.asarray(toks.reshape(B, S)),
            "labels": jnp.asarray(labs.reshape(B, S))}
    stacked = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labs)}

    def build(donate, accum):
        step = runner.jit_train_step(
            Bm if accum else B, accum_steps=A if accum else 1,
            spike_guard=spikes.SpikeConfig(), donate=donate)

        def fn(state, batch, t):
            p, o, g, _ = state
            return step(p, o, g, batch, jnp.int32(t),
                        jax.random.PRNGKey(t), jnp.float32(1e-3))

        return fn

    variants = {
        "classic": (False, False, True),
        "donate": (True, False, False),
        "accum": (False, True, False),
        "donate_accum": (True, True, False),
    }
    rows, out = [], {}
    for name, (donate, accum, sync_each) in variants.items():
        fn = build(donate, accum)
        batch = stacked if accum else flat

        def fresh():
            p = runner.init_params(0)
            return (p, adamw.init_opt_state(p), spikes.init_guard_state(),
                    {"loss": jnp.float32(0)})

        state = _steps(fn, fresh(), batch, warmup, sync_each=sync_each)
        t0 = time.perf_counter()
        state = _steps(fn, state, batch, n, sync_each=sync_each)
        us = (time.perf_counter() - t0) / n * 1e6
        out[name + "_us_per_step"] = us
        rows.append((f"train_step_{name}", f"{us:.0f}",
                     f"B{B}xS{S}_accum{A if accum else 1}"
                     f"{'_donated' if donate else ''}"))

    # -- batch-size warmup sweep: staged accum at fixed microbatch --------
    from repro.optim.schedule import AccumWarmup
    warm = AccumWarmup(microbatch=Bm, start=Bm, end=4 * Bm,
                       warmup_steps=3 * max(1, n), increments=2)
    staged = runner.jit_train_step(Bm, accum_steps=warm.stages(),
                                   spike_guard=spikes.SpikeConfig(),
                                   donate=True)
    p = runner.init_params(0)
    state = (p, adamw.init_opt_state(p), spikes.init_guard_state())
    warm_out = {}
    for accum in staged.stages:
        mb = {"tokens": jnp.asarray(
                  rs.randint(0, cfg.vocab_size,
                             ((accum, Bm, S) if accum > 1 else (Bm, S))
                             ).astype(np.int32)),
              "labels": jnp.asarray(
                  rs.randint(0, cfg.vocab_size,
                             ((accum, Bm, S) if accum > 1 else (Bm, S))
                             ).astype(np.int32))}
        fn = staged.for_accum(accum)
        # ≥ 2 warm calls: compile AND the interpret-kernels' expensive
        # first execution both stay out of the timed window
        for t in range(max(2, warmup)):
            state = fn(*state, mb, jnp.int32(t), jax.random.PRNGKey(t),
                       jnp.float32(1e-3))[:3]
        jax.block_until_ready(state)
        t0 = time.perf_counter()
        for t in range(n):
            state = fn(*state, mb, jnp.int32(t), jax.random.PRNGKey(t),
                       jnp.float32(1e-3))[:3]
        jax.block_until_ready(state)
        us = (time.perf_counter() - t0) / n * 1e6
        warm_out[str(accum)] = us
        rows.append((f"train_step_warmup_accum{accum}", f"{us:.0f}",
                     f"B{Bm}xS{S}_staged_donated"))
    assert staged.n_compiles == len(staged.stages), staged.trace_counts
    rows.append(("train_step_warmup_compiles", str(staged.n_compiles),
                 f"stages={list(staged.stages)}"))

    detail = {
        "bench": "mesh-native train step: donation x accumulation x "
                 "host-sync + staged bs-warmup sweep",
        "arch": "ling-lite-smoke", "batch": B, "seq": S,
        "accum_steps": A, "steps_timed": n, **out,
        "warmup_sweep_us_per_step": warm_out,
        "warmup_stages": list(staged.stages),
        "warmup_compiles": staged.n_compiles,
    }
    with open(os.path.join(ROOT, "BENCH_train_step.json"), "w") as f:
        json.dump({**detail, "date": time.strftime("%Y-%m-%d"),
                   "command": "PYTHONPATH=src python -m benchmarks.run "
                              "--only train_step"}, f, indent=1)
    return rows, detail
