"""Benchmark harness — one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--fast]

Prints ``name,us_per_call,derived`` CSV rows (one per measurement) and a
summary of each paper claim vs the measured value.  Detailed JSON lands in
experiments/bench/.

Paper artifacts covered:
  fig4_xputimer      XPUTimer log-memory reduction (~90%)
  fig8_edit          EDiT vs synchronous training speedup curve
  table2_pcache      checkpoint-write dispersal (~2.3-2.7x)
  babel_metadata     parallel metadata prefetch (36x claim shape)
  babel_crc          sampled-CRC vs full-MD5 verification
  table3_flood       Flood pipeline vs synchronous baseline token/s
  serve_online       online continuous batching: TTFT/ITL/tok/s vs load
  dpo_packing        DPO data packing (3.7x claim)
  table1_hetero      heterogeneous cost model (20% savings claim)
  fig12_13_scaling   hyper-param + loss scaling laws, MoE efficiency lever
  fig14_spikes       loss-spike skip + sample-retry training comparison
  kernels            Pallas kernel micro-timings (interpret mode)
  paged_attn         fused page-walking attention vs gathered-KV oracle
  train_step         engine step time: donation x accumulation x host-sync
  roofline           §Dry-run/§Roofline table from experiments/dryrun/
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

BENCHES = [
    "fig4_xputimer", "fig8_edit", "table2_pcache", "babel_metadata",
    "babel_crc", "table3_flood", "serve_online", "spec_decode",
    "dpo_packing", "table1_hetero", "fig12_13_scaling", "fig14_spikes",
    "fig18_eval", "kernels", "paged_attn", "train_step", "roofline",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()

    os.makedirs("experiments/bench", exist_ok=True)
    names = [args.only] if args.only else BENCHES
    print("name,us_per_call,derived")
    failures = []
    for name in names:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.time()
        try:
            rows, detail = mod.run(fast=args.fast)
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"{name},ERROR,{repr(e)[:120]!r}")
            continue
        for r in rows:
            print(",".join(str(x) for x in r))
        detail["bench_seconds"] = round(time.time() - t0, 2)
        with open(f"experiments/bench/{name}.json", "w") as f:
            json.dump(detail, f, indent=1, default=str)
    if failures:
        sys.exit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
