"""Table 2: checkpoint save cost, concentrated vs dispersed writers.

Simulated at the paper's scales (128 and 512 accelerators) with node
bandwidth calibrated to the Table-2 GPFS row, plus a real local measurement
of PCache's threaded sharded save.
"""
import os
import tempfile
import time

import jax.numpy as jnp

from repro.checkpoint.pcache import PCache, simulate_checkpoint_write


def run(fast=False):
    rows = []
    detail = {"paper": {"128acc": {"pcache": 70, "gpfs": 160},
                        "512acc": {"pcache": 90, "gpfs": 240}}}
    # paper config rows: tp=1 ep=8 pp=1 @128  and  tp=2 ep=8 pp=8 @512.
    # Model: t = overhead + worst_node_load * bytes/node_bw.  `overhead`
    # is the non-dispersable part (optimizer-state gather + serialization,
    # calibrated on the Table-2 GPFS/PCache pair at 128 accelerators).
    # per-row calibration (the 512-acc job has tp=2 pp=8 => larger
    # per-group checkpoint chunks): (overhead_s, unit_s)
    CALIB = {"128acc": (57.0, 13.0), "512acc": (69.0, 21.0)}
    for label, n_acc, n_groups in (("128acc", 128, 16), ("512acc", 512, 32)):
        OVERHEAD, UNIT = CALIB[label]
        kw = dict(n_dp_groups=n_groups, ranks_per_group=n_acc // n_groups,
                  n_nodes=n_acc // 8, ranks_per_node=8,
                  bytes_per_group=UNIT * 3e9, node_bw=3e9)
        t_conc = OVERHEAD + simulate_checkpoint_write(disperse=False, **kw)
        t_disp = OVERHEAD + simulate_checkpoint_write(disperse=True, **kw)
        detail[label] = {"concentrated_s": t_conc, "dispersed_s": t_disp,
                         "speedup": t_conc / t_disp}
        rows.append((f"pcache_sim_{label}", f"{t_disp*1e6:.0f}",
                     f"{t_disp:.0f}s_vs_{t_conc:.0f}s_speedup="
                     f"{t_conc/t_disp:.2f}x_paper~2.3-2.7x"))
    # real threaded save on local disk
    with tempfile.TemporaryDirectory() as d:
        pc = PCache(d, n_writers=4)
        n = 8 if fast else 24
        tree = {f"w{i}": jnp.ones((256, 256), jnp.float32) for i in range(n)}
        t0 = time.perf_counter()
        pc.save("ck", tree)
        wall = time.perf_counter() - t0
        rows.append(("pcache_real_save", f"{wall*1e6:.0f}",
                     f"{n}x256x256_leaves"))
        detail["real_save_s"] = wall
    return rows, detail
