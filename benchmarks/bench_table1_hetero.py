"""Table 1 + §1.3: heterogeneous-device training cost (20% savings)."""
from repro.core import hetero


def run(fast=False):
    rep = hetero.savings_report()
    rows = [("hetero_high_perf_cost", "0",
             f"{rep['high_perf_cost_mrmb']:.2f}MRMB_paper=6.35"),
            ("hetero_low_spec_cost", "0",
             f"{rep['low_spec_cost_mrmb']:.2f}MRMB_paper=5.08"),
            ("hetero_savings", "0",
             f"{rep['savings_frac']:.1%}_paper~20%")]
    per_dev = {d: hetero.cost_rmb(dev, hetero.TOKENS_1T) / 1e6
               for d, dev in hetero.DEVICES.items()}
    for d, c in per_dev.items():
        rows.append((f"hetero_device_{d}", "0", f"{c:.2f}MRMB_per_1T"))
    return rows, {**rep, "per_device_mrmb": per_dev}
