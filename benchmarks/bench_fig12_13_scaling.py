"""Fig. 12/13: scaling laws.

Real (tiny) training runs: dense vs fine-grained-MoE models across a range
of compute budgets on the synthetic corpus; fit the FLOPs->loss law per
family and report the MoE efficiency lever (paper: ~3x, growing with C).
Also fits B(C), lr(C) power laws from the per-budget grid winners.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.configs.base import ModelConfig, MoEConfig
from repro.core import scaling_laws as SL
from repro.data.pipeline import DataPipeline, PipelineConfig
from repro.launch.mesh import make_local_mesh
from repro.optim import adamw

VOCAB = 512
SEQ = 64


def _dense(d):
    return ModelConfig(arch_id=f"dense{d}", family="dense", source="bench",
                       n_layers=2, d_model=d, n_heads=4, n_kv_heads=4,
                       d_ff=d * 2, vocab_size=VOCAB, mlp_act="swiglu")


def _moe(d):
    return ModelConfig(arch_id=f"moe{d}", family="moe", source="bench",
                       n_layers=2, d_model=d, n_heads=4, n_kv_heads=4,
                       d_ff=d * 2, vocab_size=VOCAB, mlp_act="swiglu",
                       moe=MoEConfig(n_experts=16, top_k=2, expert_d_ff=d,
                                     n_shared_experts=1,
                                     router_warmup_steps=4))


def _train(cfg, steps, batch, lr, seed=0):
    mesh = make_local_mesh(1, 1)
    runner = api.Runner(cfg, mesh, max_seq=SEQ)
    step = jax.jit(runner.make_train_step(batch))
    pipe = DataPipeline(PipelineConfig(vocab_size=VOCAB, seq_len=SEQ,
                                       batch_size=batch, seed=seed))
    params = runner.init_params(seed)
    opt = adamw.init_opt_state(params)
    last = []
    for i in range(steps):
        jb = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        params, opt, m = step(params, opt, jb, jnp.int32(i),
                              jax.random.PRNGKey(i), jnp.float32(lr))
        last.append(float(m["loss/ce"]))
    return float(np.mean(last[-5:]))


def run(fast=False):
    # compute budget C ~ 6 * N_active * tokens; swept via training steps
    # on a fixed-width model per family (IsoModel slices of the IsoFLOP
    # grid — enough to fit the FLOPs->loss curves on CPU)
    step_grid = [10, 25, 60] if fast else [15, 40, 100, 220]
    rows, detail = [], {"dense": [], "moe": []}
    for fam, mk in (("dense", _dense), ("moe", _moe)):
        cfg = mk(64)
        n_act = cfg.active_param_count()
        for steps in step_grid:
            c = 6.0 * n_act * steps * 8 * SEQ
            loss = _train(cfg, steps, 8, 2e-3)
            detail[fam].append({"steps": steps, "compute": c, "loss": loss})
            rows.append((f"scaling_{fam}_s{steps}", "0",
                         f"C={c:.2e}_loss={loss:.3f}"))
    lever = None
    try:
        moe_law = SL.LossLaw.fit([r["compute"] for r in detail["moe"]],
                                 [r["loss"] for r in detail["moe"]])
        dense_law = SL.LossLaw.fit([r["compute"] for r in detail["dense"]],
                                   [r["loss"] for r in detail["dense"]])
        c_mid = detail["moe"][-2]["compute"]
        lever = SL.efficiency_lever(moe_law, dense_law, c_mid)
        lever = float(min(lever, 100.0))   # tiny-run fits can explode
        rows.append(("scaling_efficiency_lever", "0",
                     f"{lever:.2f}x_paper~3x"))
    except Exception as e:  # fits can fail on tiny noisy runs
        rows.append(("scaling_efficiency_lever", "0", f"fit_failed_{e!r}"))
    # hyper-param law from a small grid at the smallest width
    grid = []
    for b in (4, 8):
        for lr in (1e-3, 2e-3, 4e-3):
            loss = _train(_dense(48), 10 if fast else 25, b, lr, seed=1)
            grid.append(SL.GridResult(6.0 * _dense(48).active_param_count()
                                      * b * SEQ * 25, b, lr, loss))
    cs, bb, ll, _ = SL.best_per_budget(grid)
    detail["grid_best"] = {"compute": cs, "batch": bb, "lr": ll}
    rows.append(("scaling_grid_best", "0",
                 f"best_batch={bb}_best_lr={ll}"))
    return rows, {**detail, "efficiency_lever": lever, "paper_lever": 3.0}
