"""Paged attention: fused page-table-walking kernel vs the gathered
oracle (`ops.paged_gather` + einsum combine) — interpret-mode wall
clock for the correctness path plus the ANALYTIC per-layer HBM traffic
that motivates the kernel: gathered reads AND re-writes the full
table-width `(B, S_g, KV, hd)` view per layer per tick (O(B * S_g)
whether or not the pages are allocated); fused streams only the
physical pages the tables reference (O(pages touched)).

Writes the committed BENCH_paged_attn.json at the repo root.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.roofline import paged_attn_hbm_bytes

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _decode_case(rs, *, B, n_lp, page_size, Hp, KV, hd, page_counts,
                 dtype=jnp.bfloat16):
    """Decode-shaped (Q=1) paged batch with an uneven allocation
    profile; tp=1 so ps_loc == page_size."""
    n_pages = 1 + sum(page_counts)
    q = jnp.asarray(rs.randn(B, 1, Hp, hd), dtype)
    kp = jnp.asarray(rs.randn(n_pages, page_size, KV, hd), dtype)
    vp = jnp.asarray(rs.randn(n_pages, page_size, KV, hd), dtype)
    table = np.zeros((B, n_lp), np.int32)
    nxt = 1
    pos = np.zeros((B,), np.int32)
    for b, c in enumerate(page_counts):
        table[b, :c] = np.arange(nxt, nxt + c)
        nxt += c
        pos[b] = max(c, 1) * page_size - page_size // 2
    table = jnp.asarray(table)
    S_g = n_lp * page_size
    gpos = jnp.arange(S_g)
    valid = (jnp.repeat(table > 0, page_size, axis=1)[:, None, :]
             & (gpos[None, None, :] <= jnp.asarray(pos)[:, None, None]))
    return q, kp, vp, table, valid


@jax.jit
def _gathered_attn(q, kp, vp, table, mask):
    """The oracle path, timed end to end: materialize the gathered view,
    grouped-einsum scores, softmax, PV contraction (the same math
    `_paged_scores_combine` runs per layer)."""
    B, Qn, Hp, hd = q.shape
    _, ps_loc, KV, _ = kp.shape
    S_g = table.shape[1] * ps_loc
    g = Hp // KV
    k_g = ops.paged_gather(kp, table).reshape(B, S_g, KV, hd)
    v_g = ops.paged_gather(vp, table).reshape(B, S_g, KV, hd)
    s = jnp.einsum("bqkgd,bskd->bqkgs", q.reshape(B, Qn, KV, g, hd), k_g,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    s = jnp.where(mask[:, :, None, None, :], s.reshape(B, Qn, KV, g, S_g),
                  -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(jnp.isfinite(s), jnp.exp(s - jnp.where(jnp.isfinite(m),
                                                         m, 0.0)), 0.0)
    num = jnp.einsum("bqkgs,bskd->bqkgd", p.astype(q.dtype), v_g,
                     preferred_element_type=jnp.float32)
    den = jnp.sum(p, axis=-1)
    out = num / jnp.maximum(den, 1e-20)[..., None]   # (B, Q, KV, g, hd)
    return out.reshape(B, Qn, Hp, hd)


@jax.jit
def _fused_attn(q, kp, vp, table, mask):
    m = ops.paged_attention_scores_max(q, kp, table, mask)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    num, den = ops.paged_attention_accumulate(q, kp, vp, table, mask,
                                              m_safe)
    return num / jnp.maximum(den, 1e-20)[..., None]


def _time(fn, reps=5, warmup=2, fast=False):
    if fast:
        reps, warmup = 2, 1
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


def run(fast=False):
    rs = np.random.RandomState(0)
    rows = []

    # serving-shaped decode tick: 8 slots against a 16-page-wide table,
    # most slots holding only a few pages — the regime where gathered
    # traffic (full table width x slots) dwarfs the touched pages
    B, n_lp, page_size, Hp, KV, hd = 8, 16, 16, 8, 2, 64
    page_counts = [16, 12, 8, 6, 4, 3, 2, 1] if not fast \
        else [4, 3, 2, 1, 1, 1, 1, 1]
    q, kp, vp, table, mask = _decode_case(
        rs, B=B, n_lp=n_lp, page_size=page_size, Hp=Hp, KV=KV, hd=hd,
        page_counts=page_counts)
    gus = _time(lambda: _gathered_attn(q, kp, vp, table, mask), fast=fast)
    fus = _time(lambda: _fused_attn(q, kp, vp, table, mask), fast=fast)
    out_g = np.asarray(_gathered_attn(q, kp, vp, table, mask), np.float32)
    out_f = np.asarray(_fused_attn(q, kp, vp, table, mask), np.float32)
    maxdiff = float(np.max(np.abs(out_g - out_f)))
    shape_tag = f"B{B}_lp{n_lp}_ps{page_size}_kv{KV}_hd{hd}"
    rows.append((f"paged_attn_gathered_{shape_tag}", f"{gus:.0f}",
                 "interpret_mode_decode_Q1"))
    rows.append((f"paged_attn_fused_{shape_tag}", f"{fus:.0f}",
                 f"interpret_mode_maxdiff_{maxdiff:.1e}"))

    # analytic HBM traffic per layer per tick (the perf claim: interpret
    # wall clock measures the correctness path, traffic is the TPU story)
    pages_touched = sum(page_counts)
    g_bytes, f_bytes = paged_attn_hbm_bytes(
        B, n_lp, pages_touched, page_size, KV, hd)
    rows.append(("paged_attn_hbm_gathered_bytes", f"{g_bytes:.0f}",
                 f"O(B*S_g)_{B}x{n_lp * page_size}_rows_read+write"))
    rows.append(("paged_attn_hbm_fused_bytes", f"{f_bytes:.0f}",
                 f"O(pages_touched)_{pages_touched}_pages_Kx2_Vx1"))
    rows.append(("paged_attn_hbm_saving", "0",
                 f"{g_bytes / max(f_bytes, 1):.1f}x_less_traffic"))

    # the saving grows with table width at fixed allocation: a long-context
    # pool mostly empty (the steady serving state after admission churn)
    sweep = []
    for width in ([32, 64, 128] if not fast else [32]):
        gb, fb = paged_attn_hbm_bytes(B, width, pages_touched, page_size,
                                      KV, hd)
        sweep.append({"table_width": width, "gathered_bytes": gb,
                      "fused_bytes": fb, "ratio": gb / max(fb, 1)})
        rows.append((f"paged_attn_hbm_saving_lp{width}", "0",
                     f"{gb / max(fb, 1):.1f}x_less_traffic"))

    detail = {
        "bench": "fused paged attention vs gathered oracle",
        "case": {"slots": B, "table_width_pages": n_lp,
                 "page_size": page_size, "kv_heads": KV, "head_dim": hd,
                 "q_heads": Hp, "page_counts": page_counts,
                 "pages_touched": pages_touched, "dtype": "bfloat16"},
        "timings_us": {"gathered": gus, "fused": fus},
        "max_abs_diff": maxdiff,
        "hbm_bytes_per_layer": {"gathered": g_bytes, "fused": f_bytes,
                                "ratio": g_bytes / max(f_bytes, 1)},
        "table_width_sweep": sweep,
        "claim": "fused kernel KV traffic is O(pages touched) per layer "
                 "per tick vs the gathered path's O(B * S_g) read+write "
                 "of the full table-width view; outputs agree to f32 "
                 "summation-order noise",
    }
    with open(os.path.join(ROOT, "BENCH_paged_attn.json"), "w") as f:
        json.dump({**detail, "date": time.strftime("%Y-%m-%d"),
                   "command": "PYTHONPATH=src python -m benchmarks.run "
                              "--only paged_attn",
                   "environment": "single-process CPU jax, Pallas "
                                  "interpret mode - wall clock is the "
                                  "correctness path, NOT TPU performance"},
                  f, indent=1)
    return rows, detail
