"""Fig. 8: EDiT vs synchronous distributed training speedup curve.

The paper: as accelerators increase, baseline speed -> 5.49e-2 step/s and
EDiT's speedup reaches 66.1% time saved.  We sweep worker counts with the
straggler step-time model and report the time-saved fraction curve, plus a
real 2-worker EDiT-vs-sync training run on a tiny model (loss parity).
"""
import numpy as np

from repro.core.edit import simulate_sync_timeline


def run(fast=False):
    rows, curve = [], {}
    for n in (4, 16, 64, 256, 1024):
        r = simulate_sync_timeline(
            n, 200 if fast else 1000, straggler_frac=0.08,
            straggler_slowdown=5.0, sync_every=8, sync_cost_s=0.6,
            layer_sync_overlap=0.8, seed=0)
        curve[n] = r
        rows.append((f"edit_speedup_n{n}", f"{r['edit_wall_s']*1e6:.0f}",
                     f"time_saved={r['time_saved_frac']:.1%}"))
    best = max(v["time_saved_frac"] for v in curve.values())
    rows.append(("edit_best_time_saved", "0",
                 f"{best:.1%}_paper_claim=66.1%_max"))
    return rows, {"curve": {k: v for k, v in curve.items()},
                  "paper_claim_max_time_saved": 0.661, "best": best}
