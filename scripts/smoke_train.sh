#!/usr/bin/env bash
# CI smoke for the mesh-native training engine: a tiny ling-lite run on
# one device, then the same run data-parallel on two forced host devices
# (dp=2 exercises the sharded/donated step + FSDP specs end-to-end).
#
#     bash scripts/smoke_train.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== smoke: dp=1 tp=1 (accum=2, device spike guard, donation) =="
python -m repro.launch.train --arch ling-lite --smoke \
    --steps 5 --batch 4 --seq 64 --accum 2

echo "== smoke: dp=2 tp=1 (2 forced host devices) =="
XLA_FLAGS="--xla_force_host_platform_device_count=2 ${XLA_FLAGS:-}" \
python -m repro.launch.train --arch ling-lite --smoke \
    --steps 5 --batch 4 --seq 64 --dp 2

echo "== smoke: batch-size warmup 4->8 over 4 steps (staged accum) =="
python -m repro.launch.train --arch ling-lite --smoke \
    --steps 6 --batch 4 --seq 64 --bs-warmup 4:8:4

echo "smoke_train OK"
