#!/usr/bin/env python
"""Run the flopcheck static-analysis pass over the tree.

Usage:
    python scripts/flopcheck.py [--strict] [paths...]

Defaults to `src tests`.  Exit status is non-zero when any unsuppressed
violation is found; `--strict` also prints suppressed violations so the
suppression inventory stays reviewable.  `tests/flopcheck_corpus/` is
always excluded — it holds deliberately-bad fixtures for the rule unit
tests.

Mirrors scripts/check_docs.py: stdlib-only apart from the repo itself,
runnable from the repo root with no PYTHONPATH gymnastics.
"""
import argparse
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis import check_paths  # noqa: E402

EXCLUDE = ("flopcheck_corpus",)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="*", default=["src", "tests"],
                    help="files or directories to check (default: src tests)")
    ap.add_argument("--strict", action="store_true",
                    help="also list suppressed violations")
    args = ap.parse_args()

    paths = [ROOT / p if not Path(p).is_absolute() else Path(p)
             for p in (args.paths or ["src", "tests"])]
    missing = [p for p in paths if not p.exists()]
    if missing:
        print(f"flopcheck: no such path: {missing}", file=sys.stderr)
        return 2

    violations = check_paths(paths, exclude=EXCLUDE)
    active = [v for v in violations if not v.suppressed]
    suppressed = [v for v in violations if v.suppressed]

    for v in active:
        print(v.format())
    if args.strict and suppressed:
        print(f"-- {len(suppressed)} suppressed "
              f"(reviewed, `# flopcheck: disable=` on site):")
        for v in suppressed:
            print(f"   {v.format()}")

    if active:
        print(f"\nflopcheck: {len(active)} violation(s) "
              f"({len(suppressed)} suppressed)")
        return 1
    print(f"flopcheck: OK — 0 violations ({len(suppressed)} suppressed) "
          f"across {len(args.paths or ['src', 'tests'])} path(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
