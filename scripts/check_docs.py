#!/usr/bin/env python
"""Docs link-checker: verifies that README/docs internal links resolve
and that the README repo map names every src/repro subpackage.

    python scripts/check_docs.py

Exit code 0 = clean; 1 = broken links / unlisted subpackages (each
printed).  Wired into the tier-1 run via tests/test_docs.py.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
# pages that must exist (a deleted/renamed doc is an error even though
# DOC_FILES globs whatever is present)
REQUIRED_PAGES = ("architecture.md", "kernels.md", "training.md",
                  "serving.md", "analysis.md", "observability.md")
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def internal_links(md: Path):
    for target in LINK_RE.findall(md.read_text()):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        yield target.split("#", 1)[0]


def check_links() -> list:
    errors = []
    for page in REQUIRED_PAGES:
        if not (ROOT / "docs" / page).exists():
            errors.append(f"missing required doc page: docs/{page}")
    for md in DOC_FILES:
        if not md.exists():
            errors.append(f"missing doc file: {md.relative_to(ROOT)}")
            continue
        for target in internal_links(md):
            if not (md.parent / target).exists():
                errors.append(
                    f"{md.relative_to(ROOT)}: broken link -> {target}")
    return errors


def check_repo_map() -> list:
    readme = (ROOT / "README.md").read_text()
    errors = []
    for pkg in sorted((ROOT / "src" / "repro").iterdir()):
        if not pkg.is_dir() or not (pkg / "__init__.py").exists():
            continue
        if f"src/repro/{pkg.name}" not in readme:
            errors.append(
                f"README repo map is missing subpackage src/repro/{pkg.name}")
    for top in ("benchmarks", "examples", "tests", "docs"):
        if top not in readme:
            errors.append(f"README repo map is missing {top}/")
    return errors


def main() -> int:
    errors = check_links() + check_repo_map()
    for e in errors:
        print(f"check_docs: {e}")
    if not errors:
        n_links = sum(len(list(internal_links(m)))
                      for m in DOC_FILES if m.exists())
        print(f"check_docs: OK ({len(DOC_FILES)} files, "
              f"{n_links} internal links)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
