"""repro subpackage."""
