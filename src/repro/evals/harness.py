"""Model-evaluation efficiency subsystem (paper §2.4 "Flood"-backed
evaluation + §5.1.2 benchmark optimization, the third headline
optimization: C-eval).

Implements the paper's three mechanisms:

  1. **Optimized perplexity-based evaluation** (Luan et al. 2025 as cited):
     score option *content* instead of option *labels* ("A"/"B"/...).
     Early in training the model cannot bind labels to options, so
     label-target accuracy is noisy ~chance; content scoring is
     discriminative from the start (reproduced in bench_fig18_eval).
  2. **Optimized generation-based evaluation**: explicit task
     specification in the prompt, answer prefixes to guide continuation,
     and early stopping on a stop token; an extraction step reads the
     answer out of the continuation (the paper's code/math fixes).
  3. **Cross-cluster consistency** (<0.5% average deviation) and the
     **evaluation -> training-data attribution** loop (Fig. 19): eval
     samples and training domains share ability-dimension tags so a score
     regression pinpoints the responsible data segment.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# datasets (synthetic, generated against the synthetic corpus vocabulary)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MCItem:
    """Multiple-choice item: context + K options (token sequences)."""
    context: np.ndarray
    options: List[np.ndarray]          # content sequences
    answer: int
    ability: str = "knowledge"


@dataclasses.dataclass
class GenItem:
    """Generation item: prompt -> expected answer tokens."""
    prompt: np.ndarray
    answer: np.ndarray
    ability: str = "reasoning"


def make_mc_dataset(n: int, vocab: int, seed: int = 0, k: int = 4,
                    ctx_len: int = 12, opt_len: int = 4) -> List[MCItem]:
    """Learnable synthetic MC: the correct option continues the context's
    pattern (tokens shifted by a fixed stride); distractors are random."""
    rs = np.random.RandomState(seed)
    items = []
    for i in range(n):
        stride = 7 + (i % 5)
        base = rs.randint(0, vocab - 64)
        ctx = (base + stride * np.arange(ctx_len)) % vocab
        correct = (base + stride * (ctx_len + np.arange(opt_len))) % vocab
        options = [rs.randint(0, vocab, opt_len) for _ in range(k)]
        ans = rs.randint(k)
        options[ans] = correct
        items.append(MCItem(ctx.astype(np.int32),
                            [o.astype(np.int32) for o in options], ans,
                            ability=["knowledge", "math", "code"][i % 3]))
    return items


def make_gen_dataset(n: int, vocab: int, seed: int = 1,
                     prompt_len: int = 10, ans_len: int = 3
                     ) -> List[GenItem]:
    rs = np.random.RandomState(seed)
    items = []
    for i in range(n):
        stride = 3 + (i % 4)
        base = rs.randint(0, vocab - 64)
        prompt = (base + stride * np.arange(prompt_len)) % vocab
        ans = (base + stride * (prompt_len + np.arange(ans_len))) % vocab
        items.append(GenItem(prompt.astype(np.int32), ans.astype(np.int32),
                             ability=["math", "code"][i % 2]))
    return items


# ---------------------------------------------------------------------------
# perplexity-based evaluation
# ---------------------------------------------------------------------------

ScoreFn = Callable[[np.ndarray, np.ndarray], float]
# score_fn(tokens (S,), mask (S,)) -> sum log p(tokens[t] | tokens[<t])
# over masked positions.


def ppl_eval_content(items: Sequence[MCItem], score_fn: ScoreFn
                     ) -> Dict[str, float]:
    """Paper-optimized: rank options by (length-normalized) content
    log-likelihood given the context."""
    correct = 0
    per_ability: Dict[str, List[int]] = {}
    for it in items:
        scores = []
        for opt in it.options:
            seq = np.concatenate([it.context, opt])
            mask = np.zeros(len(seq))
            mask[len(it.context):] = 1.0
            scores.append(score_fn(seq, mask) / max(len(opt), 1))
        pred = int(np.argmax(scores))
        hit = int(pred == it.answer)
        correct += hit
        per_ability.setdefault(it.ability, []).append(hit)
    return {"accuracy": correct / len(items),
            **{f"ability/{a}": float(np.mean(v))
               for a, v in per_ability.items()}}


def ppl_eval_label(items: Sequence[MCItem], score_fn: ScoreFn,
                   label_tokens: Sequence[int]) -> Dict[str, float]:
    """Baseline: append all options to the context and score only the
    single *label token* ("A"/"B"/...) — the unstable early-training
    evaluation the paper replaces."""
    correct = 0
    for it in items:
        body = np.concatenate([it.context] + [
            np.concatenate([[label_tokens[j]], o])
            for j, o in enumerate(it.options)])
        scores = []
        for j in range(len(it.options)):
            seq = np.concatenate([body, [label_tokens[j]]]).astype(np.int32)
            mask = np.zeros(len(seq))
            mask[-1] = 1.0
            scores.append(score_fn(seq, mask))
        correct += int(int(np.argmax(scores)) == it.answer)
    return {"accuracy": correct / len(items)}


# ---------------------------------------------------------------------------
# generation-based evaluation
# ---------------------------------------------------------------------------

DecodeFn = Callable[[np.ndarray, int], np.ndarray]
# decode_fn(prompt (S,), max_new) -> generated tokens (<= max_new,)


def gen_eval(items: Sequence[GenItem], decode_fn: DecodeFn, *,
             task_prefix: Optional[np.ndarray] = None,
             stop_token: Optional[int] = None,
             max_new: int = 8) -> Dict[str, float]:
    """Generation eval with the paper's fixes: explicit task prefix,
    early stopping, and answer extraction (first len(answer) tokens)."""
    correct = 0
    for it in items:
        prompt = it.prompt
        if task_prefix is not None:
            prompt = np.concatenate([task_prefix, prompt])
        out = decode_fn(prompt.astype(np.int32), max_new)
        if stop_token is not None:
            stop = np.where(out == stop_token)[0]
            if len(stop):
                out = out[:stop[0]]
        ans = out[:len(it.answer)]
        correct += int(len(ans) == len(it.answer)
                       and np.array_equal(ans, it.answer))
    return {"accuracy": correct / len(items)}


# ---------------------------------------------------------------------------
# cross-cluster consistency (paper: average deviation < 0.5%)
# ---------------------------------------------------------------------------


def consistency(run_a: Dict[str, float], run_b: Dict[str, float]
                ) -> Dict[str, float]:
    keys = sorted(set(run_a) & set(run_b))
    devs = [abs(run_a[k] - run_b[k]) for k in keys]
    return {"mean_abs_deviation": float(np.mean(devs)) if devs else 0.0,
            "max_abs_deviation": float(np.max(devs)) if devs else 0.0}


# ---------------------------------------------------------------------------
# evaluation -> training-data attribution (Fig. 19)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AttributionReport:
    regressed_abilities: List[str]
    suspect_domains: List[str]
    details: Dict[str, float]


# ability dimension -> the training domains that feed it
DOMAIN_ABILITIES = {
    "web": ["knowledge"],
    "books": ["knowledge"],
    "code": ["code"],
    "math": ["math", "reasoning"],
    "encyclopedia": ["knowledge"],
}


def attribute_regression(before: Dict[str, float], after: Dict[str, float],
                         threshold: float = 0.05) -> AttributionReport:
    """Map per-ability score drops back to the training-data domains that
    carry those abilities (the paper's real-time feedback loop)."""
    regressed = []
    details = {}
    for k, v in after.items():
        if not k.startswith("ability/"):
            continue
        drop = before.get(k, v) - v
        details[k] = drop
        if drop > threshold:
            regressed.append(k.split("/", 1)[1])
    suspects = sorted({d for d, abl in DOMAIN_ABILITIES.items()
                       if any(a in abl for a in regressed)})
    return AttributionReport(regressed, suspects, details)
