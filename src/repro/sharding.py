"""Mesh axis environment + manual-collective helpers.

The whole framework runs inside ``shard_map`` (Megatron-style manual
sharding): model code sees *local* shards and calls the helpers below with
logical axis roles instead of hard-coded mesh names.

Axis roles:
  dp  data parallelism   — batch/tokens sharded; ('data',) or ('pod','data')
  tp  tensor parallelism — heads / ff / vocab / experts sharded; ('model',)

Sequence parallelism (SP) reuses the tp axis for activations between blocks,
and FSDP reuses the dp axes for parameter storage (ZeRO-3 style), so the
same 2-3 axis mesh expresses DP x TP x SP x FSDP x EP.

Expert parallelism (EP) also reuses the tp axis: routed-expert weights are
sharded over 'model' on their leading (expert) dim (`ep_spec`), and the
``dispatch="ep"`` MoE path (core/moe.py) exchanges *tokens* over that same
axis with `all_to_all_tp` instead of replicating every token's FFN compute
on every rank.  The dispatch-mode matrix (who computes what, and where the
combine happens):

  mode       token layout per tp rank     expert compute      combine
  "unfused"/ all T tokens (replicated)    local experts,      psum /
  "ragged"/  — Megatron layout            all T tokens        reduce-scatter
  "batched"                                                   (SP boundary)
  "ep"       T/tp owned tokens; routed    local experts,      return
             slots all_to_all'ed to the   received tokens     all_to_all +
             owning expert shard          only                local scatter
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def shard_map(fn, *, mesh, in_specs, out_specs):
    """Version-compat shard_map with replication checking off: newer jax
    exposes jax.shard_map(check_vma=...), older jax has
    jax.experimental.shard_map.shard_map(check_rep=...)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


@dataclasses.dataclass(frozen=True)
class AxisEnv:
    """Static description of the mesh axes a model function runs under."""

    dp_axes: Tuple[str, ...]       # e.g. ('data',) or ('pod', 'data')
    tp_axis: str                   # 'model'
    dp: int                        # product of dp axis sizes (static)
    tp: int                        # tp axis size (static)
    fsdp: bool = True              # ZeRO-3 parameter sharding over dp
    seq_parallel: bool = True      # shard boundary activations over tp
    gather_cast: bool = True       # cast params to compute dtype pre-gather
    sp_comm: str = "native"        # "native" | "int8" SP boundary traffic

    @property
    def all_axes(self) -> Tuple[str, ...]:
        return self.dp_axes + (self.tp_axis,)

    # -- runtime (traced) indices ------------------------------------------
    def tp_index(self):
        return jax.lax.axis_index(self.tp_axis)

    def dp_index(self):
        return jax.lax.axis_index(self.dp_axes)

    # -- collectives --------------------------------------------------------
    def psum_tp(self, x):
        return jax.lax.psum(x, self.tp_axis)

    def psum_dp(self, x):
        return jax.lax.psum(x, self.dp_axes)

    def psum_all(self, x):
        return jax.lax.psum(x, self.all_axes)

    def pmax_tp(self, x):
        return jax.lax.pmax(x, self.tp_axis)

    def pmean_dp(self, x):
        return jax.lax.pmean(x, self.dp_axes)

    def pmean_all(self, x):
        return jax.lax.pmean(x, self.all_axes)

    def all_gather_tp(self, x, axis=0):
        return jax.lax.all_gather(x, self.tp_axis, axis=axis, tiled=True)

    def all_to_all_tp(self, x, split_axis=0, concat_axis=0):
        """Blocked all-to-all over tp: x (tp, ...) -> (tp, ...) where
        out[s] is the block rank s addressed to this rank.  This is the EP
        token exchange primitive; its transpose (for autodiff) is itself —
        see kernels/ops.ep_all_to_all for the custom-vjp wrapper the MoE
        dispatch path uses."""
        return jax.lax.all_to_all(x, self.tp_axis, split_axis=split_axis,
                                  concat_axis=concat_axis, tiled=False)

    def scatter_tp(self, x, axis=0):
        """reduce-scatter over tp (inverse of all_gather_tp under +)."""
        return jax.lax.psum_scatter(x, self.tp_axis, scatter_dimension=axis,
                                    tiled=True)

    # -- FSDP parameter (un)sharding ----------------------------------------
    def gather_fsdp(self, w, axis: int, dtype=None):
        """All-gather an FSDP-sharded weight over dp.  When `dtype` is
        given (and gather_cast is on), the cast happens BEFORE the gather —
        fp32 master weights move over ICI as bf16, halving FSDP parameter
        traffic (EXPERIMENTS.md §Perf; the grad reduce-scatter from this
        gather's transpose is then also bf16, the standard trade)."""
        if dtype is not None and self.gather_cast:
            w = w.astype(dtype)
        if not self.fsdp or self.dp == 1:
            return w if dtype is None else w.astype(dtype)
        out = jax.lax.all_gather(w, self.dp_axes, axis=axis, tiled=True)
        return out if dtype is None else out.astype(dtype)

    # -- sequence parallel boundary conversions ------------------------------
    def sp_gather(self, x_sp):
        """(T_sp, ...) -> (T_dp, ...): gather SP activations before a block."""
        if not self.seq_parallel or self.tp == 1:
            return x_sp
        if self.sp_comm == "int8":
            return _q_sp_fns(self)[0](x_sp)
        return jax.lax.all_gather(x_sp, self.tp_axis, axis=0, tiled=True)

    def sp_scatter(self, partial):
        """(T_dp, ...) partial sums -> (T_sp, ...): combine + return to SP."""
        if not self.seq_parallel or self.tp == 1:
            return jax.lax.psum(partial, self.tp_axis)
        if self.sp_comm == "int8":
            return _q_sp_fns(self)[1](partial)
        return jax.lax.psum_scatter(partial, self.tp_axis,
                                    scatter_dimension=0, tiled=True)


# ---------------------------------------------------------------------------
# int8-compressed sequence-parallel boundary (beyond-paper optimization):
# per-token symmetric int8 quantization on the SP all-gather / reduce-
# scatter halves the dominant collective traffic of Megatron-style SP.
# The reduce-scatter is realized as a quantized all-to-all + local fp32
# sum (int8 cannot be summed in-network); backward communication is
# quantized symmetrically via custom_vjp (the gather/scatter transposes).
# ---------------------------------------------------------------------------


def _quant_rows(x):
    """(..., d) -> (int8 values, f32 per-row scales)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _q_gather_impl(env: "AxisEnv", x_sp, out_dtype):
    q, s = _quant_rows(x_sp)
    qg = jax.lax.all_gather(q, env.tp_axis, axis=0, tiled=True)
    sg = jax.lax.all_gather(s, env.tp_axis, axis=0, tiled=True)
    return (qg.astype(jnp.float32) * sg).astype(out_dtype)


def _q_scatter_impl(env: "AxisEnv", partial, out_dtype):
    T = partial.shape[0]
    xr = partial.reshape((env.tp, T // env.tp) + partial.shape[1:])
    q, s = _quant_rows(xr)
    qt = jax.lax.all_to_all(q, env.tp_axis, split_axis=0, concat_axis=0,
                            tiled=False)
    st = jax.lax.all_to_all(s, env.tp_axis, split_axis=0, concat_axis=0,
                            tiled=False)
    return jnp.sum(qt.astype(jnp.float32) * st, axis=0).astype(out_dtype)


@functools.lru_cache(maxsize=None)
def _q_sp_fns(env: "AxisEnv"):
    @jax.custom_vjp
    def qgather(x_sp):
        return _q_gather_impl(env, x_sp, x_sp.dtype)

    def g_fwd(x_sp):
        return _q_gather_impl(env, x_sp, x_sp.dtype), None

    def g_bwd(_, g):   # transpose of all_gather = reduce-scatter (quantized)
        return (_q_scatter_impl(env, g, g.dtype),)

    qgather.defvjp(g_fwd, g_bwd)

    @jax.custom_vjp
    def qscatter(partial):
        return _q_scatter_impl(env, partial, partial.dtype)

    def s_fwd(partial):
        return _q_scatter_impl(env, partial, partial.dtype), None

    def s_bwd(_, g):   # transpose of reduce-scatter = all-gather (quantized)
        return (_q_gather_impl(env, g, g.dtype),)

    qscatter.defvjp(s_fwd, s_bwd)
    return qgather, qscatter


# ---------------------------------------------------------------------------
# PartitionSpec helpers for parameter trees
# ---------------------------------------------------------------------------


def fsdp_spec(env: AxisEnv, ndim: int, fsdp_dim: Optional[int],
              tp_dim: Optional[int] = None) -> P:
    """Spec for a weight stored FSDP-sharded over dp (dim `fsdp_dim`) and
    TP-sharded over tp (dim `tp_dim`)."""
    parts: list = [None] * ndim
    if fsdp_dim is not None and env.fsdp and env.dp > 1:
        parts[fsdp_dim] = env.dp_axes if len(env.dp_axes) > 1 else env.dp_axes[0]
    if tp_dim is not None:
        parts[tp_dim] = env.tp_axis
    return P(*parts)


def ep_spec(env: AxisEnv, ndim: int, fsdp_dim: Optional[int],
            expert_dim: int = 0) -> P:
    """Spec for an expert-parallel weight: the expert dim is sharded over
    the tp ('model') axis — rank r owns experts [r*E_loc, (r+1)*E_loc) —
    and one non-expert dim may additionally be FSDP-sharded over dp.
    Identical mechanics to `fsdp_spec` with tp on the expert dim; the
    separate name records the *role*: these shards are addressed by the
    EP all-to-all token exchange, not by a column/row-parallel matmul."""
    return fsdp_spec(env, ndim, fsdp_dim, expert_dim)


def replicated_specs(tree) -> Any:
    """Spec tree replicating every leaf (P()) — used for the small
    device-side train-state (spike-guard EMA stats) the engine step
    carries: scalar statistics live on every device so the commit flag is
    computed without any cross-host traffic."""
    return jax.tree.map(lambda _: P(), tree)


def batch_spec(env: AxisEnv, ndim: int, batch_dim: int = 0) -> P:
    parts: list = [None] * ndim
    parts[batch_dim] = env.dp_axes if len(env.dp_axes) > 1 else env.dp_axes[0]
    return P(*parts)


def divide(a: int, b: int, what: str = "") -> int:
    if a % b:
        raise ValueError(f"{what or 'dim'}={a} not divisible by {b}")
    return a // b


def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def make_axis_env(mesh: jax.sharding.Mesh, *, fsdp: bool = True,
                  seq_parallel: bool = True,
                  gather_cast: bool = True) -> AxisEnv:
    """Derive an AxisEnv from a mesh built by launch.mesh helpers."""
    names = mesh.axis_names
    assert names[-1] == "model", f"last mesh axis must be 'model', got {names}"
    dp_axes = tuple(n for n in names if n != "model")
    dp = 1
    for n in dp_axes:
        dp *= mesh.shape[n]
    return AxisEnv(dp_axes=dp_axes, tp_axis="model", dp=dp,
                   tp=mesh.shape["model"], fsdp=fsdp,
                   seq_parallel=seq_parallel, gather_cast=gather_cast)
