"""Three-term roofline model from compiled dry-run artifacts (deliverable g).

    compute    = HLO_FLOPs_per_chip / peak_FLOPs
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / link_bw

Hardware constants (TPU v5e target): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  `compiled.as_text()` describes the per-device SPMD
program, so per-chip quantities over per-chip rates are equivalent to the
brief's global/(chips*rate) formulation.

Why we parse the HLO ourselves instead of trusting cost_analysis():
XLA's HloCostAnalysis visits `while` bodies ONCE, but our layer stacks are
`scan`s — an 80-layer model would under-report FLOPs and collective bytes
80x.  The compiled HLO carries `known_trip_count` in each while op's
backend_config; we build the computation call graph (while bodies weighted
by trip count, calls/fusions by 1), propagate execution-count multipliers
from the entry, and then:

  * FLOPs      = sum over dot/convolution ops of 2*prod(out)*K * multiplier
  * HBM bytes  = sum over non-fused instructions of (operands+result) bytes
                 * multiplier   (fusion bodies excluded: their intermediates
                 live in registers/VMEM, not HBM)
  * collective = sum of operand bytes of all-gather / all-reduce /
                 reduce-scatter / all-to-all / collective-permute
                 * multiplier
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Sequence, Tuple

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")


def _shapes_in(s: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.groups()
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _nbytes(shapes: Sequence[Tuple[str, List[int]]]) -> float:
    total = 0.0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class _Comp:
    name: str
    lines: List[str]
    fused: bool = False      # body of a fusion/wrapped op (no HBM traffic)


_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->\s*(.+)\{\s*$")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\S+)\s+([\w\-]+)\(")


def _parse_module(hlo: str):
    comps: Dict[str, _Comp] = {}
    entry = None
    cur: Optional[_Comp] = None
    defs: Dict[str, Tuple[str, List[int]]] = {}
    for raw in hlo.splitlines():
        line = raw.strip()
        m = _HEADER_RE.match(line)
        if m:
            is_entry, name, args, _ = m.groups()
            cur = _Comp(name, [])
            comps[name] = cur
            if is_entry:
                entry = name
            # computation parameters define shapes too
            for pm in re.finditer(r"%?([\w\.\-]+)\s*:\s*([^,)]+)", args):
                sh = _shapes_in(pm.group(2))
                if sh:
                    defs[pm.group(1)] = sh[0]
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None or not line:
            continue
        cur.lines.append(line)
        dm = _DEF_RE.match(line)
        if dm:
            name, tstr, _op = dm.groups()
            sh = _shapes_in(tstr)
            if len(sh) == 1:
                defs[name] = sh[0]
    return comps, defs, entry


def _call_edges(comp: _Comp):
    """[(callee, weight, via_fusion)] for one computation."""
    edges = []
    for ln in comp.lines:
        if " while(" in ln or ln.startswith("while("):
            tc = 1
            m = re.search(r'known_trip_count[^\d]*(\d+)', ln)
            if m:
                tc = int(m.group(1))
            mb = re.search(r"body=%?([\w\.\-]+)", ln)
            mc = re.search(r"condition=%?([\w\.\-]+)", ln)
            if mb:
                edges.append((mb.group(1), float(tc), False))
            if mc:
                edges.append((mc.group(1), float(tc), False))
            continue
        is_fusion = " fusion(" in ln
        for m in re.finditer(r"(?:calls=|to_apply=|body=|condition=|"
                             r"true_computation=|false_computation=)"
                             r"%?([\w\.\-]+)", ln):
            edges.append((m.group(1), 1.0, is_fusion))
        m = re.search(r"branch_computations=\{([^}]*)\}", ln)
        if m:
            for name in re.findall(r"%?([\w\.\-]+)", m.group(1)):
                edges.append((name, 1.0, False))
    return edges


def _multipliers(comps: Dict[str, _Comp], entry: str):
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    if entry not in comps:
        entry = next(iter(comps))
    mult[entry] = 1.0
    # topological propagation: call graphs are acyclic; iterate to fixpoint
    # over a BFS-ish frontier (small graphs, a few passes suffice)
    edges = {name: _call_edges(c) for name, c in comps.items()}
    order = list(comps)
    for _ in range(len(comps)):
        changed = False
        new = {name: 0.0 for name in comps}
        new[entry] = 1.0
        for name in order:
            w = mult.get(name, 0.0)
            if w == 0.0:
                continue
            for callee, weight, via_fusion in edges[name]:
                if callee in new:
                    new[callee] += w * weight
        if new != mult:
            mult = new
            changed = True
        if not changed:
            break
        # mark fusion bodies
    fused = set()
    for name, es in edges.items():
        for callee, _, via_fusion in es:
            if via_fusion and callee in comps:
                fused.add(callee)
    return mult, fused


_LAYOUT_TOKENS = {"convert", "copy", "transpose", "bitcast", "reshape",
                  "broadcast", "slice", "dynamic", "update", "wrapped",
                  "fusion", "pad", "concatenate"}


def _layout_only_fusion(name: str) -> bool:
    """True if a fusion's name indicates pure dtype/layout movement."""
    toks = re.split(r"[_.]", name)
    return all(t in _LAYOUT_TOKENS or t.isdigit() or t == ""
               for t in toks)


_DOT_LINE = re.compile(
    r"=\s*(\w+)\[([\d,]*)\][^=]*?\b(dot|convolution)\(([^)]*)\)")


def _dot_flops(line: str, defs) -> float:
    m = _DOT_LINE.search(line)
    if not m:
        return 0.0
    dt, dims_s, kind, args = m.groups()
    out = 1
    for d in dims_s.split(","):
        if d:
            out *= int(d)
    if kind == "convolution":
        # small (rglru width-4 conv); approximate K from window string
        mw = re.search(r"window=\{size=([\dx]+)", line)
        k = 1
        if mw:
            for d in mw.group(1).split("x"):
                k *= int(d)
        return 2.0 * out * k
    # contraction size from lhs operand shape + contracting dims
    lhs_dims: Optional[List[int]] = None
    inline = _shapes_in(args)
    if inline:
        lhs_dims = inline[0][1]
    else:
        first = re.match(r"\s*%?([\w\.\-]+)", args)
        if first and first.group(1) in defs:
            lhs_dims = defs[first.group(1)][1]
    k = 1
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    if lhs_dims and mc:
        for i in (int(x) for x in mc.group(1).split(",") if x):
            if i < len(lhs_dims):
                k *= lhs_dims[i]
    elif lhs_dims:
        k = lhs_dims[-1]
    return 2.0 * out * k


def _line_bytes(line: str, defs) -> float:
    """result + operand bytes for a top-level instruction."""
    m = _DEF_RE.match(line)
    if not m:
        return 0.0
    name, tstr, op = m.groups()
    if op in ("parameter", "constant", "tuple", "get-tuple-element",
              "bitcast", "copy-start", "copy-done", "after-all"):
        return 0.0
    args = re.search(rf"{op}\((.*?)\)(?:,|$)", line)

    def operand_bytes():
        if not args:
            return []
        inline = _shapes_in(args.group(1))
        if inline:
            return [_nbytes([s]) for s in inline]
        out = []
        for ref in re.findall(r"%([\w\.\-]+)", args.group(1)):
            if ref in defs:
                out.append(_nbytes([defs[ref]]))
        return out

    if op == "dynamic-update-slice":
        # in-place on TPU/XLA: traffic = read+write of the update slice only
        ops = operand_bytes()
        return 2.0 * (ops[1] if len(ops) > 1 else 0.0)
    if op == "fusion" and "dynamic-update-slice" in name:
        # DUS-rooted fusion: the big carried buffer aliases in place;
        # traffic = 2x the non-carried (small) operands
        ops = operand_bytes()
        if ops:
            return 2.0 * (sum(ops) - max(ops))
    if op in ("convert", "copy", "transpose", "reshape", "broadcast") or \
            (op == "fusion" and _layout_only_fusion(name)):
        # CPU-backend artifacts: XLA:CPU lowers bf16 dots by materializing
        # f32 converts (and hoists them out of loops); on the TPU target
        # these are in-flight dtype/layout changes fused into consumers.
        # Excluded from the TPU memory model (see module docstring).
        return 0.0
    if op in ("dynamic-slice", "slice"):
        return 2.0 * _nbytes(_shapes_in(tstr))   # read slice + write result
    total = _nbytes(_shapes_in(tstr)) + sum(operand_bytes())
    return total


@dataclasses.dataclass
class Roofline:
    flops: float                 # per chip
    hbm_bytes: float             # per chip
    collective_bytes: float      # per chip, operand sizes (brief's metric)
    link_bytes: float            # per chip, ring-traffic model (for time)
    compute_s: float
    memory_s: float
    collective_s: float          # link_bytes / LINK_BW
    bottleneck: str
    model_flops: float           # 6*N_active*D useful flops per chip
    useful_ratio: float          # model_flops / hlo_flops
    collectives: Dict[str, float]
    collective_counts: Dict[str, int]

    def to_dict(self):
        return dataclasses.asdict(self)


def _group_size(line: str, default: int = 16) -> int:
    """Participants per group from the replica_groups attribute."""
    m = re.search(r"replica_groups=\{\{([\d,\s]*)\}", line)
    if m:
        return max(len([x for x in m.group(1).split(",") if x.strip()]), 1)
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]", line)
    if m:
        return max(int(m.group(2)), 1)
    return default


def _ring_traffic(kind: str, operand_bytes: float, g: int) -> float:
    """Per-device ICI send volume under a ring/bidirectional model."""
    if g <= 1:
        return 0.0
    if kind == "all-gather":
        return operand_bytes * (g - 1)          # shard sent (g-1) times
    if kind == "reduce-scatter":
        return operand_bytes * (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * operand_bytes * (g - 1) / g
    if kind == "all-to-all":
        return operand_bytes * (g - 1) / g
    return operand_bytes                         # collective-permute


def analyze_text(hlo: str, *, model_flops_per_chip: float = 0.0) -> Roofline:
    comps, defs, entry = _parse_module(hlo)
    mult, fused = _multipliers(comps, entry)

    flops = 0.0
    hbm = 0.0
    coll_bytes: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    link_bytes: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    coll_counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for name, comp in comps.items():
        w = mult.get(name, 0.0)
        if w == 0.0:
            continue
        in_fusion = name in fused
        for ln in comp.lines:
            f = _dot_flops(ln, defs)
            if f:
                flops += f * w
            if not in_fusion:
                hbm += _line_bytes(ln, defs) * w
            for kind in _COLLECTIVES:
                if re.search(rf"\b{kind}(?:-start)?\(", ln):
                    if f"{kind}-done" in ln:
                        continue
                    args = re.search(rf"{kind}(?:-start)?\((.*?)\)(?:,|$)",
                                     ln)
                    b = 0.0
                    if args:
                        inline = _shapes_in(args.group(1))
                        if inline:
                            b = _nbytes(inline)
                        else:
                            for ref in re.findall(r"%([\w\.\-]+)",
                                                  args.group(1)):
                                if ref in defs:
                                    b += _nbytes([defs[ref]])
                    g = _group_size(ln)
                    coll_bytes[kind] += b * w
                    link_bytes[kind] += _ring_traffic(kind, b, g) * w
                    coll_counts[kind] += 1
                    break

    total_coll = sum(coll_bytes.values())
    total_link = sum(link_bytes.values())
    cs = flops / PEAK_FLOPS
    ms = hbm / HBM_BW
    ls = total_link / LINK_BW
    bn = max((("compute", cs), ("memory", ms), ("collective", ls)),
             key=lambda kv: kv[1])[0]
    return Roofline(
        flops=flops, hbm_bytes=hbm, collective_bytes=total_coll,
        link_bytes=total_link,
        compute_s=cs, memory_s=ms, collective_s=ls, bottleneck=bn,
        model_flops=model_flops_per_chip,
        useful_ratio=(model_flops_per_chip / flops) if flops else 0.0,
        collectives={k: v for k, v in link_bytes.items() if v},
        collective_counts={k: v for k, v in coll_counts.items() if v})


def analyze(compiled, *, model_flops_per_chip: float) -> Roofline:
    """Build the three-term roofline from a compiled executable."""
    return analyze_text(compiled.as_text(),
                        model_flops_per_chip=model_flops_per_chip)


def paged_attn_hbm_bytes(slots: int, n_lp: int, pages_touched: int,
                         page_size: int, kv: int, hd: int,
                         dtype_bytes: int = 2):
    """Analytic per-layer HBM KV traffic of the two paged-attention modes.

    "gathered" (`ops.paged_gather`) materializes each slot's FULL
    table-width view: K and V each read `slots * n_lp * page_size` cache
    rows from the pool AND write them back as the gathered intermediate
    — O(B * S_g) regardless of how many pages are actually allocated.
    "fused" (`kernels/paged_attn.py`) streams only the physical pages the
    tables reference: K twice (max pass + accumulate pass) and V once
    (accumulate pass only) — O(pages touched), independent of table
    width.  Returns (gathered_bytes, fused_bytes).
    """
    row = kv * hd * dtype_bytes
    s_g = n_lp * page_size
    gathered = 2 * 2 * slots * s_g * row      # k+v, pool read + view write
    fused = 3 * pages_touched * page_size * row  # k x2 + v x1, streamed
    return gathered, fused


def fmt_seconds(s: float) -> str:
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s*1e3:.2f}ms"
    return f"{s*1e6:.1f}us"
