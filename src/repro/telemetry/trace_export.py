"""Render telemetry rings as Chrome trace-event JSON + Prometheus text.

Two export paths out of the in-process telemetry layer:

* ``chrome_trace`` / ``write_chrome_trace`` — merge the XPUTimer
  compressed ring (scheduler phases: one track per span name), the
  ``RequestLog`` lifecycle ring (one track per engine slot, with
  prefill/decode spans reconstructed from event pairs and instants for
  first-token/preempt), and the registry's ``Series`` samples (counter
  tracks: page-pool occupancy, queue depth, radix hit rate, spec
  acceptance) into one trace-event JSON file.  Open it at
  https://ui.perfetto.dev (or chrome://tracing) — see
  docs/observability.md for the walkthrough.  Both rings share the
  ``time.perf_counter()``-microsecond timebase, so phases and slots
  line up on one timeline.

* ``MetricsServer`` — a point-in-time Prometheus text scrape
  (``GET /metrics``) on a background daemon thread, behind
  ``launch/serve.py --metrics-port``.  The handler only calls
  ``MetricsRegistry.render_prometheus()`` (host-side dict walks); it
  never touches the engine, so a scrape can never stall a tick.

Trace-event format reference: the "JSON Array/Object Format" consumed
by Perfetto — "X" complete events (ts/dur µs), "i" instants, "C"
counters, "M" metadata for process/thread names.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, List, Optional

from .metrics import MetricsRegistry
from .request_log import EVENTS, RequestLog

__all__ = ["chrome_trace_events", "chrome_trace", "write_chrome_trace",
           "MetricsServer"]

PID_PHASES = 1    # XPUTimer spans: scheduler/engine phases
PID_SLOTS = 2     # RequestLog: one thread per engine slot
PID_COUNTERS = 3  # registry Series -> "C" counter tracks
TID_QUEUE = 10_000      # slot-less request events (enqueue/shed)
TID_ALLOCATOR = 10_001  # allocator events (radix evictions)


def _meta(pid: int, tid: Optional[int], name: str) -> Dict[str, Any]:
    ev: Dict[str, Any] = {
        "ph": "M", "pid": pid, "ts": 0,
        "name": "process_name" if tid is None else "thread_name",
        "args": {"name": name},
    }
    if tid is not None:
        ev["tid"] = tid
    return ev


def _timer_events(timer) -> List[Dict[str, Any]]:
    names = timer.span_names()
    out: List[Dict[str, Any]] = [_meta(PID_PHASES, None, "scheduler phases")]
    for sid, name in enumerate(names):
        out.append(_meta(PID_PHASES, sid, name))
    for rec in timer.records():
        sid = int(rec["sid"])
        out.append({
            "ph": "X", "pid": PID_PHASES, "tid": sid,
            "name": names[sid] if sid < len(names) else f"sid{sid}",
            "ts": int(rec["t0"]), "dur": max(int(rec["dur"]), 1),
        })
    return out


def _slot_events(rlog: RequestLog) -> List[Dict[str, Any]]:
    out: List[Dict[str, Any]] = [
        _meta(PID_SLOTS, None, "engine slots"),
        _meta(PID_SLOTS, TID_QUEUE, "queue"),
        _meta(PID_SLOTS, TID_ALLOCATOR, "allocator"),
    ]
    # open span per slot: (name, rid, start_us)
    open_spans: Dict[int, tuple] = {}
    named_slots = set()
    last_t = 0

    def close(slot: int, end_us: int):
        span = open_spans.pop(slot, None)
        if span is None:
            return
        name, rid, t0 = span
        out.append({
            "ph": "X", "pid": PID_SLOTS, "tid": slot,
            "name": f"{name} r{rid}", "ts": t0,
            "dur": max(end_us - t0, 1), "args": {"rid": rid},
        })

    for rec in rlog.records():
        ev = EVENTS[int(rec["ev"])]
        rid, slot = int(rec["rid"]), int(rec["slot"])
        t = int(rec["t_us"])
        tick, arg = int(rec["tick"]), int(rec["arg"])
        last_t = max(last_t, t)
        if slot >= 0 and slot not in named_slots:
            named_slots.add(slot)
            out.append(_meta(PID_SLOTS, slot, f"slot {slot}"))
        if ev == "admit":
            close(slot, t)
            open_spans[slot] = ("prefill", rid, t)
        elif ev == "prefill_done":
            close(slot, t)
            open_spans[slot] = ("decode", rid, t)
        elif ev in ("complete", "preempt"):
            close(slot, t)
            if ev == "preempt":
                out.append({
                    "ph": "i", "pid": PID_SLOTS, "tid": slot,
                    "name": f"preempt r{rid}", "ts": t, "s": "t",
                    "args": {"rid": rid, "tick": tick},
                })
        elif ev == "first_token":
            out.append({
                "ph": "i", "pid": PID_SLOTS, "tid": slot,
                "name": f"first_token r{rid}", "ts": t, "s": "t",
                "args": {"rid": rid, "tick": tick},
            })
        elif ev in ("enqueue", "shed", "requeue"):
            out.append({
                "ph": "i", "pid": PID_SLOTS, "tid": TID_QUEUE,
                "name": f"{ev} r{rid}", "ts": t, "s": "t",
                "args": {"rid": rid, "tick": tick},
            })
        elif ev == "evict":
            out.append({
                "ph": "i", "pid": PID_SLOTS, "tid": TID_ALLOCATOR,
                "name": "evict", "ts": t, "s": "t",
                "args": {"page": arg, "tick": tick},
            })
        # prefill_chunk / decode stay implicit inside their spans
    for slot in list(open_spans):
        close(slot, last_t + 1)
    return out


def _counter_events(registry: MetricsRegistry) -> List[Dict[str, Any]]:
    out: List[Dict[str, Any]] = [_meta(PID_COUNTERS, None, "counters")]
    for name, key, series in registry.all_series():
        track = name
        if key:
            track += "[" + ",".join(f"{k}={v}" for k, v in key) + "]"
        for t_us, v in series.points():
            out.append({
                "ph": "C", "pid": PID_COUNTERS, "tid": 0,
                "name": track, "ts": int(t_us), "args": {"value": v},
            })
    return out


def chrome_trace_events(timer=None, request_log: Optional[RequestLog] = None,
                        registry: Optional[MetricsRegistry] = None,
                        ) -> List[Dict[str, Any]]:
    """Merge whatever sources are given into one event list, with
    timestamps rebased so the trace starts near t=0."""
    events: List[Dict[str, Any]] = []
    if timer is not None:
        events.extend(_timer_events(timer))
    if request_log is not None:
        events.extend(_slot_events(request_log))
    if registry is not None:
        events.extend(_counter_events(registry))
    real = [e["ts"] for e in events if e["ph"] != "M" and e["ts"] > 0]
    if real:
        t0 = min(real)
        for e in events:
            if e["ph"] != "M":
                e["ts"] = max(e["ts"] - t0, 0)
    return events


def chrome_trace(timer=None, request_log: Optional[RequestLog] = None,
                 registry: Optional[MetricsRegistry] = None,
                 ) -> Dict[str, Any]:
    return {
        "traceEvents": chrome_trace_events(timer, request_log, registry),
        "displayTimeUnit": "ms",
    }


def write_chrome_trace(path, timer=None,
                       request_log: Optional[RequestLog] = None,
                       registry: Optional[MetricsRegistry] = None) -> int:
    """Write the trace JSON; returns the number of events written."""
    trace = chrome_trace(timer, request_log, registry)
    with open(path, "w") as f:
        json.dump(trace, f)
    return len(trace["traceEvents"])


class MetricsServer:
    """Background Prometheus-text scrape endpoint for a registry."""

    def __init__(self, registry: MetricsRegistry, port: int = 0,
                 host: str = "127.0.0.1"):
        self.registry = registry
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 (http.server API)
                if self.path.split("?", 1)[0].rstrip("/") in ("", "/metrics"):
                    body = outer.registry.render_prometheus().encode()
                    self.send_response(200)
                    self.send_header(
                        "Content-Type",
                        "text/plain; version=0.0.4; charset=utf-8")
                    self.send_header("Content-Length", str(len(body)))
                    self.end_headers()
                    self.wfile.write(body)
                else:
                    self.send_error(404)

            def log_message(self, *args):  # silence per-request stderr spam
                pass

        self._server = ThreadingHTTPServer((host, port), Handler)
        self._server.daemon_threads = True
        self._thread: Optional[threading.Thread] = None

    @property
    def port(self) -> int:
        return self._server.server_address[1]

    def start(self) -> int:
        self._thread = threading.Thread(
            target=self._server.serve_forever, name="metrics-server",
            daemon=True)
        self._thread.start()
        return self.port

    def stop(self) -> None:
        self._server.shutdown()
        self._server.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    def __enter__(self):
        self.start()
        return self

    def __exit__(self, *exc):
        self.stop()
