"""XPUTimer — lightweight selective tracing + diagnostic engine (§2.1, C9).

TPU/JAX adaptation (see DESIGN.md §3): CUDA-event interception has no JAX
analogue visible to user code, so we keep the *design* — selective tracing
of critical spans, pooled pre-allocated event records, compressed logs
(only span id + timestamps), asynchronous aggregation — at the host level
around jitted steps, plus a diagnostic engine with the paper's two modules:

  * error diagnosis: every span failure is attributed O(1) via the span
    registry (no log search);
  * performance-degradation diagnosis: per-span latency distributions,
    straggler detection (slow-step attribution), throughput regression.

The ~90% memory reduction claim (Fig. 4) is reproduced in
benchmarks/bench_fig4_xputimer.py by comparing the compressed record
layout against full-event tracing of the same schedule.

When constructed with a ``telemetry.metrics.MetricsRegistry``, every
closed span is also published as an ``xputimer_span_ms{span=...}``
histogram observation (and counters/gauges as
``xputimer_counter_total{counter=...}`` / ``xputimer_gauge{gauge=...}``),
so Prometheus scrapes and ``trace_export`` see the same data as
``diagnose()`` without a second instrumentation pass.  Publishing is
host-side float math only — the zero-host-sync contract in
docs/observability.md applies.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import defaultdict, deque
from contextlib import contextmanager
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

# compressed record: (span_id: u16, t_start_us: u64, dur_us: u32) = 14 bytes
_RECORD_BYTES = 14
# a "full tracing" record keeps name, args/shapes, thread, stack hint, ...
FULL_RECORD_BYTES = 144


@dataclasses.dataclass
class SpanStats:
    count: int = 0
    total_us: float = 0.0
    max_us: float = 0.0
    durations: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=4096))

    def add(self, dur_us: float):
        self.count += 1
        self.total_us += dur_us
        self.max_us = max(self.max_us, dur_us)
        self.durations.append(dur_us)


class EventPool:
    """Reusable pre-allocated event records (paper: 'event pool
    management to reuse pre-allocated CUDA events')."""

    def __init__(self, size: int = 1024):
        self._free: Deque[list] = deque([None, 0.0, 0.0] for _ in range(size))
        self.allocated = size

    def get(self) -> list:
        if self._free:
            return self._free.popleft()
        self.allocated += 1
        return [None, 0.0, 0.0]

    def put(self, ev: list):
        self._free.append(ev)


class XPUTimer:
    """Selective tracing: only registered/used span names are recorded.

    `traced_apis` mirrors the TRACED_PYTHON_API env-var mechanism — when
    non-empty, spans not in the set are no-ops (zero overhead path).
    """

    def __init__(self, traced_apis: Optional[List[str]] = None,
                 ring_size: int = 65536, registry=None):
        self.traced = set(traced_apis) if traced_apis else None
        # optional MetricsRegistry mirror (see module docstring)
        self.registry = registry
        self._reg_hists: Dict[str, Any] = {}
        self._ids: Dict[str, int] = {}
        self._names: List[str] = []
        self.pool = EventPool()
        # compressed ring buffer: fixed dtype, no python objects
        self.ring = np.zeros(ring_size, dtype=[("sid", "u2"),
                                               ("t0", "u8"),
                                               ("dur", "u4")])
        self.head = 0
        self.wrapped = False
        self.stats: Dict[str, SpanStats] = defaultdict(SpanStats)
        self.counters: Dict[str, int] = defaultdict(int)
        self.gauges: Dict[str, float] = {}
        self.errors: List[Dict[str, Any]] = []
        self._lock = threading.Lock()
        self._bg_queue: Deque[Tuple[int, float, float]] = deque()

    def _sid(self, name: str) -> int:
        if name not in self._ids:
            self._ids[name] = len(self._names)
            self._names.append(name)
        return self._ids[name]

    @contextmanager
    def span(self, name: str):
        if self.traced is not None and name not in self.traced:
            yield
            return
        ev = self.pool.get()
        t0 = time.perf_counter()
        try:
            yield
        except Exception as e:
            # O(1) error attribution: the failing span is known directly
            self.errors.append({"span": name, "time": time.time(),
                                "error": repr(e)})
            raise
        finally:
            dur_us = (time.perf_counter() - t0) * 1e6
            # _sid mutates the span registry and SpanStats.add mutates a
            # deque + counters: both must sit under the same lock as the
            # ring write, or spans closing on the Prefetcher/exporter
            # threads race the engine thread's defaultdict insertion.
            with self._lock:
                sid = self._sid(name)
                i = self.head % len(self.ring)
                self.ring[i] = (sid, int(t0 * 1e6), int(dur_us))
                self.head += 1
                if self.head >= len(self.ring):
                    self.wrapped = True
                self.stats[name].add(dur_us)
                self.pool.put(ev)
            self._publish_span(name, dur_us)

    def _publish_span(self, name: str, dur_us: float):
        if self.registry is None:
            return
        h = self._reg_hists.get(name)
        if h is None:
            h = self.registry.histogram(
                "xputimer_span_ms", "XPUTimer span duration", span=name)
            self._reg_hists[name] = h
        h.observe(dur_us / 1e3)

    def count(self, name: str, n: int = 1):
        with self._lock:
            self.counters[name] += n
        if self.registry is not None:
            self.registry.counter(
                "xputimer_counter_total", "XPUTimer counter", counter=name
            ).inc(n)

    def gauge(self, name: str, value: float):
        """Last-value gauge (e.g. commit fraction per metrics drain) —
        updated from the trainer's asynchronous drain, not per step."""
        self.gauges[name] = float(value)
        if self.registry is not None:
            self.registry.gauge(
                "xputimer_gauge", "XPUTimer gauge", gauge=name).set(value)

    # -- ring access (trace_export) -------------------------------------------
    @property
    def n_records(self) -> int:
        """Valid compressed records in the ring (single source of truth
        for the memory-accounting comparison below)."""
        return len(self.ring) if self.wrapped else min(self.head,
                                                       len(self.ring))

    def records(self) -> np.ndarray:
        """Copy of the valid ring region in chronological order."""
        with self._lock:
            if not self.wrapped:
                return self.ring[: self.head].copy()
            start = self.head % len(self.ring)
            return np.concatenate([self.ring[start:], self.ring[:start]])

    def span_names(self) -> List[str]:
        """sid -> name mapping (index == sid)."""
        with self._lock:
            return list(self._names)

    # -- memory accounting (Fig. 4 comparison) --------------------------------
    def memory_bytes(self) -> int:
        return max(self.n_records, 1) * self.ring.itemsize \
            + 64 * len(self._names)

    def full_tracing_bytes(self) -> int:
        return max(self.n_records, 1) * FULL_RECORD_BYTES

    # -- diagnostic engine ------------------------------------------------------
    def diagnose(self, slow_sigma: float = 3.0) -> Dict[str, Any]:
        """Performance-degradation diagnosis: macro (throughput) + micro
        (latency distribution) metrics, straggler attribution."""
        report: Dict[str, Any] = {"spans": {}, "anomalies": [],
                                  "errors": self.errors}
        for name, st in self.stats.items():
            d = np.asarray(st.durations)
            if len(d) == 0:
                continue
            mean, std = float(d.mean()), float(d.std())
            p50, p99 = float(np.percentile(d, 50)), float(np.percentile(d, 99))
            report["spans"][name] = {
                "count": st.count, "mean_us": mean, "p50_us": p50,
                "p99_us": p99, "max_us": st.max_us,
                "total_s": st.total_us / 1e6,
            }
            slow = d[d > mean + slow_sigma * max(std, 1e-9)]
            if len(slow):
                report["anomalies"].append({
                    "span": name, "kind": "latency_outliers",
                    "n": int(len(slow)), "worst_us": float(slow.max()),
                    "mean_us": mean})
        total = sum(s["total_s"] for s in report["spans"].values())
        if total > 0:
            dominant = max(report["spans"].items(),
                           key=lambda kv: kv[1]["total_s"])
            report["dominant_span"] = {"name": dominant[0],
                                       "frac": dominant[1]["total_s"] / total}
        report["counters"] = dict(self.counters)
        report["gauges"] = dict(self.gauges)
        report["log_bytes"] = self.memory_bytes()
        report["full_tracing_bytes"] = self.full_tracing_bytes()
        return report
