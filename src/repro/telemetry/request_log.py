"""Per-request lifecycle event log for the online engine.

Every request admitted to ``OnlineEngine`` leaves a trail:

    enqueue -> admit -> prefill_chunk* -> prefill_done -> first_token
            -> decode* -> (preempt -> requeue -> admit -> prefill_chunk*)*
            -> complete | shed

plus allocator-side ``evict`` events when the radix cache drops pages.
Events live in an XPUTimer-style compressed numpy ring (35 bytes per
record vs. a ~200-byte dict+timestamp tuple for a naive log), so an
always-on log of the last 64Ki events costs ~2 MiB and O(1) per event.

Records carry the request id, the engine tick index, a wall timestamp
(``time.perf_counter()`` microseconds — the same timebase XPUTimer
uses, so ``trace_export`` can merge both onto one Perfetto timeline),
the slot involved (-1 when not slot-bound, e.g. enqueue/shed) and one
free integer argument (tokens in a prefill chunk, tokens committed by
a decode/spec step, page id for evictions).

Host-side only: callers pass ints they already hold (zero-host-sync
contract, see ``telemetry.metrics``).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

import numpy as np

__all__ = ["RequestLog", "EVENTS", "EV"]

# Order is part of the on-ring encoding; append only.
EVENTS = (
    "enqueue",        # submit() accepted into the queue
    "shed",           # submit() rejected by the admission gate
    "admit",          # scheduler bound the request to a slot
    "prefill_chunk",  # one chunked-prefill step fed `arg` tokens
    "prefill_done",   # prompt fully fed; slot enters decode
    "first_token",    # first generated token surfaced (TTFT point)
    "decode",         # decode/spec step committed `arg` tokens
    "preempt",        # slot reclaimed; fed prefix trimmed to page edge
    "requeue",        # preempted request re-entered the queue
    "evict",          # radix cache evicted page `arg` (rid = -1)
    "complete",       # request finished (eos or max_new)
)
EV: Dict[str, int] = {name: i for i, name in enumerate(EVENTS)}

_DTYPE = np.dtype([
    ("rid", "i8"),    # request id (-1 for allocator-level events)
    ("ev", "u1"),     # index into EVENTS
    ("slot", "i2"),   # slot id or -1
    ("tick", "i8"),   # engine tick index at record time
    ("t_us", "u8"),   # perf_counter microseconds (XPUTimer timebase)
    ("arg", "i8"),    # event-specific payload (tokens / page id)
])


class RequestLog:
    """Compressed ring of lifecycle events, queryable per request id."""

    def __init__(self, ring_size: int = 65536):
        self.ring = np.zeros(max(int(ring_size), 1), dtype=_DTYPE)
        self.head = 0
        self.wrapped = False
        self._lock = threading.Lock()

    def record(self, event: str, rid: int, *, slot: int = -1,
               tick: int = -1, arg: int = 0,
               t_us: Optional[int] = None) -> None:
        ev = EV[event]  # KeyError on typo'd event names, by design
        if t_us is None:
            t_us = int(time.perf_counter() * 1e6)
        with self._lock:
            i = self.head % len(self.ring)
            rec = self.ring[i]
            rec["rid"] = rid
            rec["ev"] = ev
            rec["slot"] = slot
            rec["tick"] = tick
            rec["t_us"] = t_us
            rec["arg"] = arg
            self.head += 1
            if self.head > len(self.ring):
                self.wrapped = True

    @property
    def n_records(self) -> int:
        return min(self.head, len(self.ring))

    def records(self) -> np.ndarray:
        """Copy of the valid region in chronological order."""
        with self._lock:
            if not self.wrapped:
                return self.ring[: self.head].copy()
            start = self.head % len(self.ring)
            return np.concatenate([self.ring[start:], self.ring[:start]])

    def events_for(self, rid: int) -> List[dict]:
        """Chronological [{event, slot, tick, t_us, arg}, ...] for one rid."""
        recs = self.records()
        out = []
        for rec in recs[recs["rid"] == rid]:
            out.append({
                "event": EVENTS[int(rec["ev"])],
                "slot": int(rec["slot"]),
                "tick": int(rec["tick"]),
                "t_us": int(rec["t_us"]),
                "arg": int(rec["arg"]),
            })
        return out

    def counts(self) -> Dict[str, int]:
        """Event-name -> occurrence count over the valid region."""
        recs = self.records()
        binc = np.bincount(recs["ev"], minlength=len(EVENTS))
        return {name: int(binc[i]) for i, name in enumerate(EVENTS)
                if binc[i]}

    def memory_bytes(self) -> int:
        return len(self.ring) * self.ring.itemsize
