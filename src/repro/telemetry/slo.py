"""SLO tracking over windowed latency histograms, driving admission.

Closes the loop that ROADMAP item 2 left open: "loads past the knee
with SLO targets (TTFT/ITL deadlines driving shed decisions)".  The
``SLOTracker`` consumes the windowed TTFT/ITL/tick histograms the
engine already feeds into its ``MetricsRegistry`` and answers one
question at submit time: *if we admit this request, will the windowed
p99 stay inside the configured deadlines?*

Two signals combine (both host-side scalars — zero-host-sync):

* **Backward-looking**: the windowed p99 of observed TTFT/ITL.  Once
  the last-N distribution breaches a deadline the system is already
  past the knee; admitting more work only deepens the queue.
* **Forward-looking**: an admission-time TTFT estimate.  Under fcfs
  chunked prefill the engine retires one prefill chunk per tick, so a
  request joining behind ``q`` queued prompt tokens waits roughly
  ``ceil((q + own_prompt) / prefill_chunk)`` ticks before its first
  token; multiplied by the windowed median tick time that is the
  earliest possible TTFT.  Shedding on the *estimate* is what keeps
  the p99 of **admitted** requests inside the deadline — a purely
  reactive gate only sheds after the window has already breached.

Neither signal fires until ``min_observations`` samples are in the
window, so a cold engine admits freely while the histograms warm up.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from .metrics import MetricsRegistry

__all__ = ["SLOConfig", "SLOTracker"]


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Deadlines and window sizing for the ``overload="slo"`` gate.

    ttft_p99_ms      windowed p99 time-to-first-token deadline
    itl_p99_ms       optional windowed p99 inter-token-latency deadline
    window           observations kept per histogram window
    min_observations tick-time samples required before the gate arms
    headroom         safety factor on the forward TTFT estimate
                     (estimate * headroom > deadline => shed)
    """
    ttft_p99_ms: float
    itl_p99_ms: Optional[float] = None
    window: int = 128
    min_observations: int = 8
    headroom: float = 1.0

    def __post_init__(self):
        if self.ttft_p99_ms <= 0:
            raise ValueError(f"ttft_p99_ms must be > 0, got {self.ttft_p99_ms}")
        if self.itl_p99_ms is not None and self.itl_p99_ms <= 0:
            raise ValueError(f"itl_p99_ms must be > 0, got {self.itl_p99_ms}")
        if self.headroom <= 0:
            raise ValueError(f"headroom must be > 0, got {self.headroom}")


class SLOTracker:
    """Windowed-percentile view over the engine's latency histograms."""

    def __init__(self, cfg: SLOConfig, registry: MetricsRegistry):
        self.cfg = cfg
        self.registry = registry
        w = cfg.window
        self.ttft = registry.histogram(
            "serve_ttft_ms", "time to first token (admitted requests)",
            window=w)
        self.itl = registry.histogram(
            "serve_itl_ms", "inter-token latency (decode steps)", window=w)
        self.tick = registry.histogram(
            "serve_tick_ms", "engine tick wall time", window=w)
        self._m_shed = registry.counter(
            "serve_slo_shed_total", "requests shed by the SLO gate")

    # -- observations (engine hot path; host floats only) ---------------
    def observe_ttft(self, ms: float) -> None:
        self.ttft.observe(ms)

    def observe_itl(self, ms: float) -> None:
        self.itl.observe(ms)

    def observe_tick(self, ms: float) -> None:
        self.tick.observe(ms)

    # -- windowed snapshots ---------------------------------------------
    def ttft_p99(self) -> float:
        return self.ttft.percentile(99)

    def itl_p99(self) -> float:
        return self.itl.percentile(99)

    def tick_p50(self) -> float:
        return self.tick.percentile(50)

    def estimate_ttft_ms(self, queued_prompt_tokens: int,
                         prefill_chunk: int) -> float:
        """Earliest-possible TTFT for a request joining the queue now."""
        chunks = math.ceil(max(queued_prompt_tokens, 1)
                           / max(prefill_chunk, 1))
        return chunks * self.tick_p50()

    def should_shed(self, queued_prompt_tokens: int,
                    prefill_chunk: int) -> Optional[str]:
        """Reason string when admitting would breach an SLO, else None."""
        cfg = self.cfg
        if self.tick.window_count() < cfg.min_observations:
            return None  # cold start: gate not armed yet
        est = self.estimate_ttft_ms(queued_prompt_tokens, prefill_chunk)
        if est * cfg.headroom > cfg.ttft_p99_ms:
            return ("ttft_estimate "
                    f"{est:.1f}ms*{cfg.headroom:g} > {cfg.ttft_p99_ms:g}ms")
        if (self.ttft.window_count() >= cfg.min_observations
                and self.ttft_p99() > cfg.ttft_p99_ms):
            return (f"ttft_p99 {self.ttft_p99():.1f}ms "
                    f"> {cfg.ttft_p99_ms:g}ms")
        if (cfg.itl_p99_ms is not None
                and self.itl.window_count() >= cfg.min_observations
                and self.itl_p99() > cfg.itl_p99_ms):
            return (f"itl_p99 {self.itl_p99():.1f}ms "
                    f"> {cfg.itl_p99_ms:g}ms")
        return None

    def on_shed(self) -> None:
        self._m_shed.inc()

    def snapshot(self) -> dict:
        return {
            "ttft_p99_ms": self.ttft_p99(),
            "itl_p99_ms": self.itl_p99(),
            "tick_p50_ms": self.tick_p50(),
            "ttft_deadline_ms": self.cfg.ttft_p99_ms,
            "itl_deadline_ms": self.cfg.itl_p99_ms,
            "shed": int(self._m_shed.value),
        }
