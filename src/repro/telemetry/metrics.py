"""Labeled metrics registry: counters, gauges, fixed-bucket histograms.

The serving/training instrumentation layer (paper §2.1: XPUTimer-style
always-on telemetry; §3.4: anomaly handling presupposes a live metrics
substrate).  One ``MetricsRegistry`` per engine/trainer holds every
metric family; ``XPUTimer`` publishes span durations into it and the
``OnlineEngine`` feeds TTFT/ITL/tick-time histograms plus queue/page
counters.

Zero-host-sync contract
-----------------------
Every method on every metric accepts **plain host-side Python/numpy
scalars only** — values the caller already holds on the host (loop
counters, ``time.perf_counter()`` deltas, allocator bookkeeping ints).
Passing a ``jax.Array`` (or a tracer) is a bug: converting it to a
float would force a device->host sync on the hot path, and doing it
inside a jit-traced body would bake a trace-time constant into the
jaxpr (flopcheck rule FC-TELEMETRY).  ``_as_host_float`` rejects any
value carrying an ``aval`` attribute (tracers and jax Arrays both do;
numpy scalars do not), so the contract is enforced structurally
without importing jax.  Tests additionally run the instrumented engine
under ``contracts.transfer_guard`` / ``compile_guard``: metrics can
never add a device sync or a recompile.

Histograms keep two representations:

* fixed cumulative-style buckets (Prometheus exposition needs
  ``_bucket{le=...}`` counts, ``_sum`` and ``_count``), and
* a bounded sliding window of raw observations for *windowed*
  percentile snapshots (``percentile(99)``), which is what the
  ``SLOTracker`` consumes — an SLO gate must react to the last N
  requests, not the lifetime distribution.

All mutation is guarded by a per-metric lock: spans close on the
Prefetcher/exporter threads while the engine loop observes tick times.
"""
from __future__ import annotations

import bisect
import threading
from collections import deque
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Series",
    "MetricsRegistry",
    "DEFAULT_MS_BUCKETS",
]

# Latency buckets in milliseconds, tuned for interpret-mode tick times
# (tens of ms) through real-deployment TTFTs (seconds).
DEFAULT_MS_BUCKETS = (
    0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0,
    250.0, 500.0, 1000.0, 2500.0, 5000.0, 10000.0,
)
DEFAULT_WINDOW = 256


def _as_host_float(value) -> float:
    """Coerce to float, rejecting device values (zero-host-sync contract)."""
    if hasattr(value, "aval"):  # jax.Array and tracers; never numpy
        raise TypeError(
            "metrics accept host-side scalars only; got a jax value "
            f"({type(value).__name__}) — device_get it outside the hot "
            "path first (see docs/observability.md)")
    return float(value)


def _label_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_labels(key: Tuple[Tuple[str, str], ...]) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


class Counter:
    """Monotonically increasing counter."""

    __slots__ = ("value", "_lock")

    def __init__(self):
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, n=1.0) -> None:
        n = _as_host_float(n)
        if n < 0:
            raise ValueError(f"counters only go up (inc({n}))")
        with self._lock:
            self.value += n


class Gauge:
    """Point-in-time value (queue depth, pages in use, loss)."""

    __slots__ = ("value", "_lock")

    def __init__(self):
        self.value = 0.0
        self._lock = threading.Lock()

    def set(self, v) -> None:
        v = _as_host_float(v)
        with self._lock:
            self.value = v

    def add(self, n) -> None:
        n = _as_host_float(n)
        with self._lock:
            self.value += n


class Histogram:
    """Fixed-bucket histogram plus a bounded window of raw observations.

    Bucket counts are *per-bucket* internally and cumulated only at
    render time (Prometheus ``le`` semantics).  ``percentile(q)``
    interpolates over the sliding window — O(window log window) on a
    bounded deque, host-side only.
    """

    __slots__ = ("buckets", "counts", "sum", "count", "window", "_lock")

    def __init__(self, buckets: Iterable[float] = DEFAULT_MS_BUCKETS,
                 window: int = DEFAULT_WINDOW):
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError("histogram needs at least one bucket bound")
        self.counts = [0] * (len(self.buckets) + 1)  # last = +Inf overflow
        self.sum = 0.0
        self.count = 0
        self.window: deque = deque(maxlen=int(window))
        self._lock = threading.Lock()

    def observe(self, v) -> None:
        v = _as_host_float(v)
        i = bisect.bisect_left(self.buckets, v)
        with self._lock:
            self.counts[i] += 1
            self.sum += v
            self.count += 1
            self.window.append(v)

    def percentile(self, q: float) -> float:
        """Windowed percentile over the last ``window`` observations."""
        with self._lock:
            xs = sorted(self.window)
        if not xs:
            return 0.0
        if len(xs) == 1:
            return xs[0]
        rank = (q / 100.0) * (len(xs) - 1)
        lo = int(rank)
        hi = min(lo + 1, len(xs) - 1)
        frac = rank - lo
        return xs[lo] * (1.0 - frac) + xs[hi] * frac

    def window_count(self) -> int:
        with self._lock:
            return len(self.window)

    def cumulative(self) -> List[Tuple[float, int]]:
        """[(le, cumulative_count), ...] ending with (+inf, count)."""
        with self._lock:
            counts = list(self.counts)
        out, running = [], 0
        for le, c in zip(self.buckets, counts):
            running += c
            out.append((le, running))
        out.append((float("inf"), running + counts[-1]))
        return out


class Series:
    """Bounded time series of (t_us, value) samples for trace counter
    tracks (page-pool occupancy, queue depth, radix hit rate, spec
    acceptance).  Not exposed in Prometheus text — scrapes see the
    matching Gauge; the series feeds ``trace_export`` "C" events."""

    __slots__ = ("name", "t_us", "values", "head", "_lock")

    def __init__(self, name: str, capacity: int = 4096):
        self.name = name
        cap = max(int(capacity), 1)
        self.t_us = [0] * cap
        self.values = [0.0] * cap
        self.head = 0
        self._lock = threading.Lock()

    def sample(self, v, t_us: int) -> None:
        v = _as_host_float(v)
        with self._lock:
            i = self.head % len(self.values)
            self.t_us[i] = int(t_us)
            self.values[i] = v
            self.head += 1

    def points(self) -> List[Tuple[int, float]]:
        """Valid samples in chronological order."""
        with self._lock:
            n = len(self.values)
            if self.head <= n:
                idx = range(self.head)
            else:
                start = self.head % n
                idx = list(range(start, n)) + list(range(start))
            return [(self.t_us[i], self.values[i]) for i in idx]

    def __len__(self) -> int:
        return min(self.head, len(self.values))


class MetricsRegistry:
    """Get-or-create registry of labeled metric families.

    ``registry.counter("serve_shed_total", reason="slo")`` returns the
    child for that label set, creating family and child on first use.
    Children are cached; the hot path is a dict lookup plus a float op.
    """

    def __init__(self):
        self._lock = threading.RLock()
        # name -> (kind, help, {label_key: metric})
        self._families: Dict[str, Tuple[str, str, Dict]] = {}

    def _child(self, kind: str, name: str, help_: str, factory, labels):
        key = _label_key(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = (kind, help_, {})
                self._families[name] = fam
            elif fam[0] != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {fam[0]}, "
                    f"not {kind}")
            child = fam[2].get(key)
            if child is None:
                child = factory()
                fam[2][key] = child
            return child

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._child("counter", name, help, Counter, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._child("gauge", name, help, Gauge, labels)

    def histogram(self, name: str, help: str = "",
                  buckets: Iterable[float] = DEFAULT_MS_BUCKETS,
                  window: int = DEFAULT_WINDOW, **labels) -> Histogram:
        return self._child("histogram", name, help,
                           lambda: Histogram(buckets, window), labels)

    def series(self, name: str, capacity: int = 4096, **labels) -> Series:
        return self._child("series", name, "",
                           lambda: Series(name, capacity), labels)

    def get(self, name: str, **labels):
        """Existing child or None — never creates."""
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                return None
            return fam[2].get(_label_key(labels))

    def all_series(self) -> List[Tuple[str, Tuple[Tuple[str, str], ...], Series]]:
        with self._lock:
            return [(name, key, child)
                    for name, (kind, _h, children) in self._families.items()
                    if kind == "series"
                    for key, child in children.items()]

    def snapshot(self) -> Dict[str, Dict]:
        """Plain-dict snapshot (JSON-friendly) of every non-series metric."""
        out: Dict[str, Dict] = {}
        with self._lock:
            items = [(n, k, h, dict(c))
                     for n, (k, h, c) in self._families.items()]
        for name, kind, _help, children in items:
            if kind == "series":
                continue
            fam_out = out.setdefault(name, {"type": kind, "values": {}})
            for key, child in children.items():
                label_s = _fmt_labels(key) or "{}"
                if kind == "histogram":
                    fam_out["values"][label_s] = {
                        "count": child.count,
                        "sum": child.sum,
                        "p50": child.percentile(50),
                        "p99": child.percentile(99),
                    }
                else:
                    fam_out["values"][label_s] = child.value
        return out

    def render_prometheus(self) -> str:
        """Prometheus text exposition (version 0.0.4) of the registry."""
        lines: List[str] = []
        with self._lock:
            items = [(n, k, h, dict(c))
                     for n, (k, h, c) in sorted(self._families.items())]
        for name, kind, help_, children in items:
            if kind == "series":
                continue
            if help_:
                lines.append(f"# HELP {name} {help_}")
            lines.append(f"# TYPE {name} {kind}")
            for key, child in sorted(children.items()):
                if kind == "histogram":
                    for le, cum in child.cumulative():
                        le_s = "+Inf" if le == float("inf") else _fmt_value(le)
                        lkey = key + (("le", le_s),)
                        lines.append(
                            f"{name}_bucket{_fmt_labels(lkey)} {cum}")
                    lines.append(
                        f"{name}_sum{_fmt_labels(key)} {_fmt_value(child.sum)}")
                    lines.append(
                        f"{name}_count{_fmt_labels(key)} {child.count}")
                else:
                    lines.append(
                        f"{name}{_fmt_labels(key)} {_fmt_value(child.value)}")
        return "\n".join(lines) + "\n"
