"""Telemetry: XPUTimer tracing, metrics registry, lifecycle logs,
Perfetto/Prometheus export, SLO tracking (docs/observability.md)."""
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,  # noqa: F401
                      Series)
from .request_log import EVENTS, RequestLog  # noqa: F401
from .slo import SLOConfig, SLOTracker  # noqa: F401
from .trace_export import (MetricsServer, chrome_trace,  # noqa: F401
                           chrome_trace_events, write_chrome_trace)
from .xputimer import XPUTimer  # noqa: F401
