"""AdamW, written from scratch (no optax in this environment).

Paper recipe (§3.4.1): beta1=0.9, beta2=0.95, eps=1e-8, weight_decay=0.1,
global-norm gradient clipping at 1.0.

Moments are stored with the *same sharding as the parameters* (FSDP+TP), so
the update is purely local — ZeRO-style optimizer-state sharding falls out
of the parameter layout for free.  The only collective is the grad-norm
psum, which must correct for replicated parameters (spec-aware).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding import AxisEnv


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def init_opt_state(params) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.zeros_like, zeros),
            "count": jnp.zeros((), jnp.int32)}


def opt_state_specs(param_specs) -> Dict[str, Any]:
    return {"m": param_specs, "v": param_specs, "count": P()}


def _replication_factor(spec: P, env: AxisEnv, mesh_sizes) -> float:
    """How many mesh devices hold identical copies of this leaf."""
    covered = 1
    for part in spec:
        if part is None:
            continue
        names = part if isinstance(part, tuple) else (part,)
        for n in names:
            covered *= mesh_sizes[n]
    total = env.dp * env.tp
    return total / covered


def reduce_replicated_grads(grads, specs, env: AxisEnv):
    """psum each grad over every mesh axis absent from its spec.

    Semantics: inside shard_map every rank seeds the cotangent of its own
    (identical) loss replica, and collective transposes faithfully compute
    d(sum of all N replicas)/dw — i.e. raw grads are N x the true gradient
    with N = dp*tp.  We rescale by 1/N, then psum over the replication axes
    of each leaf so tied copies receive the sum of their per-copy partials
    (the classic DP grad all-reduce, generalized).  FSDP/TP-sharded dims are
    already exact after the 1/N rescale.
    """
    n = float(env.dp * env.tp)

    def red(g, s):
        g = g / n
        covered = set()
        for part in s:
            if part is None:
                continue
            names = part if isinstance(part, tuple) else (part,)
            covered.update(names)
        missing = tuple(a for a in env.all_axes if a not in covered)
        if missing:
            g = jax.lax.psum(g, missing)
        return g

    spec_tree = jax.tree.unflatten(
        jax.tree.structure(grads),
        jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))
    return jax.tree.map(red, grads, spec_tree)


def global_grad_norm(grads, specs, env: AxisEnv, mesh_sizes) -> jax.Array:
    """Spec-aware global L2 norm: replicated leaves are counted once."""
    leaves = jax.tree.leaves(grads)
    spec_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(spec_leaves), (len(leaves), len(spec_leaves))
    total = jnp.zeros((), jnp.float32)
    for g, s in zip(leaves, spec_leaves):
        rep = _replication_factor(s, env, mesh_sizes)
        total = total + jnp.sum(g.astype(jnp.float32) ** 2) / rep
    return jnp.sqrt(env.psum_all(total))


def apply_updates(params, grads, state, lr: jax.Array,
                  cfg: AdamWConfig = AdamWConfig(), *,
                  grad_scale: Optional[jax.Array] = None,
                  commit: Optional[jax.Array] = None
                  ) -> Tuple[Any, Dict[str, Any]]:
    """One AdamW step.  `grad_scale` multiplies grads (clip factor).

    `commit` (bool scalar, optional) gates the whole update on device: when
    False every param/moment leaf and the count keep their old values
    (§3.4.4 spike skip as a `jnp.where`, no host round-trip).  Because both
    branches are elementwise selects on buffers the step computes anyway,
    the discard path costs no extra FLOPs or collectives.
    """
    count = state["count"] + 1
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        if grad_scale is not None:
            g = g * grad_scale
        new_m = b1 * m + (1 - b1) * g
        new_v = b2 * v + (1 - b2) * g * g
        mhat = new_m / c1
        vhat = new_v / c2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        newp = p.astype(jnp.float32) - lr * (step + cfg.weight_decay
                                             * p.astype(jnp.float32))
        newp = newp.astype(p.dtype)
        if commit is not None:
            newp = jnp.where(commit, newp, p)
            new_m = jnp.where(commit, new_m, m)
            new_v = jnp.where(commit, new_v, v)
        return newp, new_m, new_v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    if commit is not None:
        count = jnp.where(commit, count, state["count"])
    return new_params, {"m": new_m, "v": new_v, "count": count}
