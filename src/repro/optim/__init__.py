"""repro subpackage."""
