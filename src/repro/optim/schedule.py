"""Learning-rate and batch-size schedules (paper §3.4, C13).

* WSD (warmup–stable–decay): linear warmup over the first `warmup_steps`
  (paper: 2K) to `max_lr` (paper: 2.4e-4); held stable; halved once ~60% of
  the training tokens are consumed (§3.4.1).
* Annealing: inverse-square-root decay from 1.2e-4 to 1.2e-8 (§3.4.3).
* Batch-size warmup: 2,560 -> 8,960 sequences, grown stepwise (§3.4.1).
* Spike response: the trainer multiplies the LR by `spike_lr_factor` for
  steps where a persistent loss spike was detected (§3.4.4).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class WSDSchedule:
    max_lr: float = 2.4e-4
    warmup_steps: int = 2_000
    halve_frac: float = 0.6          # halve LR at 60% of total tokens
    total_steps: int = 100_000

    def __call__(self, step):
        step = jnp.asarray(step, jnp.float32)
        warm = self.max_lr * jnp.minimum(step / max(self.warmup_steps, 1), 1.0)
        halved = jnp.where(step >= self.halve_frac * self.total_steps,
                           0.5, 1.0)
        return warm * halved


@dataclasses.dataclass(frozen=True)
class InvSqrtAnnealing:
    """§3.4.3: anneal from lr_start to lr_end with inverse-sqrt decay."""
    lr_start: float = 1.2e-4
    lr_end: float = 1.2e-8
    steps: int = 10_000

    def __call__(self, step):
        step = jnp.asarray(step, jnp.float32)
        # lr(t) = lr_start / sqrt(1 + a*t) with a chosen to land on lr_end
        a = ((self.lr_start / self.lr_end) ** 2 - 1.0) / max(self.steps, 1)
        lr = self.lr_start / jnp.sqrt(1.0 + a * step)
        return jnp.maximum(lr, self.lr_end)


@dataclasses.dataclass(frozen=True)
class BatchSizeWarmup:
    """§3.4.1: batch size grows 2,560 -> 8,960 sequences stepwise."""
    start: int = 2_560
    end: int = 8_960
    warmup_steps: int = 5_000
    increments: int = 8

    def __call__(self, step: int) -> int:
        if step >= self.warmup_steps:
            return self.end
        frac = step / max(self.warmup_steps, 1)
        stage = int(frac * self.increments)
        size = self.start + (self.end - self.start) * stage // self.increments
        # round to a multiple of the starting batch for sharding friendliness
        return max(self.start, (size // 256) * 256)
