"""Learning-rate and batch-size schedules (paper §3.4, C13).

* WSD (warmup–stable–decay): linear warmup over the first `warmup_steps`
  (paper: 2K) to `max_lr` (paper: 2.4e-4); held stable; halved once ~60% of
  the training tokens are consumed (§3.4.1).  The halving point is clamped
  to the end of the warmup ramp so small `total_steps` (test configs)
  never produce a non-monotone warmup.
* Annealing: inverse-square-root decay from 1.2e-4 to 1.2e-8 (§3.4.3).
* Batch-size warmup: 2,560 -> 8,960 sequences, grown stepwise (§3.4.1).
  `BatchSizeWarmup` is the raw size schedule; `AccumWarmup` is the
  engine-facing form — the per-microbatch shape stays fixed and the
  global batch grows by scheduling the number of accumulated microbatches
  per optimizer step, so the warmup costs at most one XLA compilation per
  stage instead of one per batch shape (see `api.Runner.jit_train_step`).
* Spike response: the trainer multiplies the LR by `spike_lr_factor` for
  steps where a persistent loss spike was detected (§3.4.4).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class WSDSchedule:
    max_lr: float = 2.4e-4
    warmup_steps: int = 2_000
    halve_frac: float = 0.6          # halve LR at 60% of total tokens
    total_steps: int = 100_000

    def __call__(self, step):
        step = jnp.asarray(step, jnp.float32)
        warm = self.max_lr * jnp.minimum(step / max(self.warmup_steps, 1), 1.0)
        # never halve inside the warmup ramp: with tiny total_steps the
        # 60%-token point can land mid-warmup, which would make the ramp
        # non-monotone (warm * 0.5 dips below already-visited LRs)
        halve_at = max(self.halve_frac * self.total_steps, self.warmup_steps)
        halved = jnp.where(step >= halve_at, 0.5, 1.0)
        return warm * halved

    def host(self, step: int) -> float:
        """Pure-host evaluation: the trainer loop calls the schedule every
        step before dispatching, and a jnp evaluation there would enqueue
        a device computation whose `float()` blocks behind the in-flight
        train step — a hidden per-step sync defeating async dispatch."""
        warm = self.max_lr * min(step / max(self.warmup_steps, 1), 1.0)
        halve_at = max(self.halve_frac * self.total_steps, self.warmup_steps)
        return warm * (0.5 if step >= halve_at else 1.0)


@dataclasses.dataclass(frozen=True)
class InvSqrtAnnealing:
    """§3.4.3: anneal from lr_start to lr_end with inverse-sqrt decay."""
    lr_start: float = 1.2e-4
    lr_end: float = 1.2e-8
    steps: int = 10_000

    def __call__(self, step):
        step = jnp.asarray(step, jnp.float32)
        # lr(t) = lr_start / sqrt(1 + a*t) with a chosen to land on lr_end
        a = ((self.lr_start / self.lr_end) ** 2 - 1.0) / max(self.steps, 1)
        lr = self.lr_start / jnp.sqrt(1.0 + a * step)
        return jnp.maximum(lr, self.lr_end)

    def host(self, step: int) -> float:
        """Pure-host evaluation (same contract as WSDSchedule.host): the
        trainer evaluates the schedule per step before dispatch, and a
        jnp evaluation there is a hidden per-step device sync."""
        a = ((self.lr_start / self.lr_end) ** 2 - 1.0) / max(self.steps, 1)
        return max(self.lr_start / math.sqrt(1.0 + a * step), self.lr_end)


@dataclasses.dataclass(frozen=True)
class BatchSizeWarmup:
    """§3.4.1: batch size grows 2,560 -> 8,960 sequences stepwise.

    Sizes are rounded down to `round_multiple` for sharding friendliness
    (never below `start`).  When `round_multiple` is None it is derived
    from the endpoints: the largest power of two dividing both `start`
    and `end`, capped at 256 (the paper-scale divisor).  A fixed 256
    would pin any `start < 256` config at `start` for the whole warmup.
    """
    start: int = 2_560
    end: int = 8_960
    warmup_steps: int = 5_000
    increments: int = 8
    round_multiple: Optional[int] = None

    @property
    def multiple(self) -> int:
        if self.round_multiple:
            return self.round_multiple
        g = max(1, math.gcd(self.start, self.end))
        return min(256, g & -g)      # largest power of two dividing both

    def stage_for(self, step: int) -> int:
        if step >= self.warmup_steps:
            return self.increments
        return int(step / max(self.warmup_steps, 1) * self.increments)

    def size_for_stage(self, stage: int) -> int:
        if stage >= self.increments:
            return self.end
        size = self.start + (self.end - self.start) * stage // self.increments
        m = self.multiple
        return max(self.start, (size // m) * m)

    def sizes(self) -> Tuple[int, ...]:
        """Distinct batch sizes the schedule visits, ascending."""
        return tuple(sorted({self.size_for_stage(k)
                             for k in range(self.increments + 1)}))

    def __call__(self, step: int) -> int:
        return self.size_for_stage(self.stage_for(step))


@dataclasses.dataclass(frozen=True)
class AccumWarmup:
    """Engine-facing batch-size warmup (§3.4.1): fixed microbatch shape,
    scheduled accumulation count.

    The jitted train step compiles for a fixed `(B_micro, S)` microbatch;
    growing the batch through the accumulation dimension means the warmup
    needs at most one compilation per distinct accum stage (the
    GSPMD/T5X-style fixed-shape route) instead of recompiling per batch
    size.  `start`/`end` are global batch sizes in sequences and must be
    multiples of `microbatch`; rounding uses `microbatch` as the
    sharding-friendly divisor so every scheduled size maps to a whole
    number of microbatches.
    """
    microbatch: int
    start: int = 2_560
    end: int = 8_960
    warmup_steps: int = 5_000
    increments: int = 8

    def __post_init__(self):
        if self.microbatch < 1:
            raise ValueError(f"microbatch must be >= 1, got {self.microbatch}")
        if self.end < self.start:
            raise ValueError(f"end {self.end} < start {self.start}")
        for name in ("start", "end"):
            v = getattr(self, name)
            if v % self.microbatch:
                raise ValueError(
                    f"AccumWarmup {name}={v} is not a multiple of "
                    f"microbatch={self.microbatch}")

    @property
    def batch_schedule(self) -> BatchSizeWarmup:
        return BatchSizeWarmup(self.start, self.end, self.warmup_steps,
                               self.increments,
                               round_multiple=self.microbatch)

    def batch_for(self, step: int) -> int:
        """Global batch (sequences) consumed by the optimizer step."""
        return self.batch_schedule(step)

    def accum_for(self, step: int) -> int:
        """Microbatches accumulated per optimizer step at `step`."""
        return self.batch_for(step) // self.microbatch

    def stages(self) -> Tuple[int, ...]:
        """Distinct accum counts the warmup visits, ascending — the
        engine compiles one step function per entry."""
        return tuple(s // self.microbatch
                     for s in self.batch_schedule.sizes())
