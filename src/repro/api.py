"""High-level runner: builds jitted, shard_mapped step functions per
(arch config x mesh x mode).  This is the public API used by the trainers,
the serving engine, the dry-run, and the tests.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.analysis import contracts
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import spikes as spikes_lib
from repro.models import model as M
from repro.optim import adamw
from repro import sharding
from repro.sharding import AxisEnv, make_axis_env


def _shard_map(fn, mesh, in_specs, out_specs):
    return sharding.shard_map(fn, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs)


def batch_sharding(env: AxisEnv, global_batch: int) -> Any:
    """Batch dim spec: sharded over dp when divisible, else replicated
    (long_500k has batch=1 < dp and is replicated by design)."""
    if global_batch % env.dp == 0:
        return env.dp_axes if len(env.dp_axes) > 1 else env.dp_axes[0]
    return None


@dataclasses.dataclass
class Runner:
    cfg: ModelConfig
    mesh: jax.sharding.Mesh
    flags: M.RunFlags = M.DEFAULT_FLAGS
    fsdp: bool = True
    seq_parallel: bool = True
    max_seq: int = 4096
    sp_comm: str = "native"            # "native" | "int8"
    gather_cast: bool = True

    def __post_init__(self):
        self.env = make_axis_env(self.mesh, fsdp=self.fsdp,
                                 seq_parallel=self.seq_parallel,
                                 gather_cast=self.gather_cast)
        if self.sp_comm != "native":
            import dataclasses as _dc
            self.env = _dc.replace(self.env, sp_comm=self.sp_comm)
        self.specs, self.shapes = M.param_specs(self.cfg, self.env,
                                                self.max_seq)
        self.mesh_sizes = dict(zip(self.mesh.axis_names,
                                   self.mesh.devices.shape))

    # -- params --------------------------------------------------------------
    def init_params(self, seed: int = 0):
        """Materialize params, sharded per the spec tree."""
        def init_fn():
            p, _ = M.init_model(jax.random.PRNGKey(seed), self.cfg, self.env,
                                self.max_seq)
            return p
        shardings = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(self.mesh, s), self.specs,
            is_leaf=lambda x: isinstance(x, P))
        return jax.jit(init_fn, out_shardings=shardings)()

    def abstract_params(self):
        """ShapeDtypeStructs with shardings attached (dry-run path)."""
        shardings = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(self.mesh, s), self.specs,
            is_leaf=lambda x: isinstance(x, P))
        return jax.tree.map(
            lambda sh, sd: jax.ShapeDtypeStruct(sh.shape, sh.dtype,
                                                sharding=sd),
            self.shapes, shardings)

    # -- batch specs ----------------------------------------------------------
    def train_batch_specs(self, global_batch: int) -> Dict[str, P]:
        b = batch_sharding(self.env, global_batch)
        specs = {"tokens": P(b, None), "labels": P(b, None)}
        if self.cfg.is_encoder_decoder:
            specs["enc_frames"] = P(b, None, None)
        return specs

    def train_batch_shapes(self, shape: ShapeConfig) -> Dict[str, Any]:
        B, S = shape.global_batch, shape.seq_len
        out = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
               "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if self.cfg.is_encoder_decoder:
            out["enc_frames"] = jax.ShapeDtypeStruct(
                (B, self.cfg.encoder_seq_len, self.cfg.d_model), jnp.bfloat16)
        return out

    # -- train step ------------------------------------------------------------
    def make_train_step(self, global_batch: int,
                        opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
                        *, accum_steps: int = 1,
                        spike_guard: Optional["spikes_lib.SpikeConfig"] = None):
        """Mesh-native train step (params/opt sharded per the spec trees,
        EP-aware for expert weights).

        Default (``accum_steps=1``, no guard) keeps the classic signature
        ``(params, opt, batch, step, rng, lr) -> (params, opt, metrics)``.

        ``accum_steps > 1``
            The batch carries a leading microbatch dim — leaves are
            ``(accum, B, S)`` with ``B`` the per-microbatch global batch —
            and a ``lax.scan`` inside the jitted step accumulates fp32
            grads over the microbatches before one optimizer update.

        ``spike_guard=SpikeConfig(...)``
            The step carries a small replicated device-side state
            (`spikes.init_guard_state`) and gates the params/opt commit on
            a `commit` flag computed from the EMA loss statistic — §3.4.4
            skip with no per-step host sync.  Signature becomes
            ``(params, opt, guard, batch, step, rng, lr) ->
            (params, opt, guard, metrics)`` and ``metrics['commit']`` is
            1.0/0.0.  Callers should jit with ``donate_argnums=(0, 1, 2)``
            so params/opt/guard update in place (see `jit_train_step`).
        """
        cfg, env, flags = self.cfg, self.env, self.flags
        pspecs, mesh_sizes = self.specs, self.mesh_sizes
        bspecs = self.train_batch_specs(global_batch)
        if accum_steps > 1:
            bspecs = {k: P(None, *s) for k, s in bspecs.items()}
        ospecs = adamw.opt_state_specs(pspecs)

        def loss_and_grads(params, batch, step, rng):
            def lf(p):
                return M.loss_fn(cfg, env, p, batch, step=step, rng=rng,
                                 flags=flags)
            return jax.value_and_grad(lf, has_aux=True)(params)

        def accum_loss_and_grads(params, batch, step, rng):
            """fp32 grad accumulation over the leading microbatch dim,
            as a scan so peak memory stays one microbatch."""
            def body(g_acc, k):
                mb = jax.tree.map(lambda v: v[k], batch)
                (loss, mets), g = loss_and_grads(
                    params, mb, step, jax.random.fold_in(rng, k))
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return g_acc, (loss, mets)

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            g_acc, (losses, mets) = jax.lax.scan(
                body, g0, jnp.arange(accum_steps))
            grads = jax.tree.map(lambda g: g / accum_steps, g_acc)
            return ((jnp.mean(losses),
                     jax.tree.map(lambda v: jnp.mean(v, axis=0), mets)),
                    grads)

        def core(params, opt_state, guard_state, batch, step, rng, lr):
            rng = jax.random.fold_in(rng, jax.lax.axis_index(env.dp_axes))
            la_g = accum_loss_and_grads if accum_steps > 1 else loss_and_grads
            (loss, metrics), grads = la_g(params, batch, step, rng)
            grads = adamw.reduce_replicated_grads(grads, pspecs, env)
            gnorm = adamw.global_grad_norm(grads, pspecs, env, mesh_sizes)
            scale = jnp.minimum(1.0, opt_cfg.clip_norm
                                / jnp.maximum(gnorm, 1e-12))
            commit = None
            if spike_guard is not None:
                # loss and gnorm are psum'd global statistics -> identical
                # on every rank, so the replicated guard state stays
                # consistent.  gnorm participates only when the config
                # keys the guard on it (§3.4.4 fn2).
                commit, guard_state = spikes_lib.guard_commit(
                    spike_guard, guard_state, loss, gnorm=gnorm)
            params, opt_state = adamw.apply_updates(
                params, grads, opt_state, lr, opt_cfg, grad_scale=scale,
                commit=commit)
            metrics = dict(metrics, **{"grad_norm": gnorm, "loss": loss})
            if commit is not None:
                metrics["commit"] = commit.astype(jnp.float32)
            return params, opt_state, guard_state, metrics

        if spike_guard is None:
            def step_fn(params, opt_state, batch, step, rng, lr):
                params, opt_state, _, metrics = core(
                    params, opt_state, None, batch, step, rng, lr)
                return params, opt_state, metrics

            in_specs = (pspecs, ospecs, bspecs, P(), P(), P())
            out_specs = (pspecs, ospecs, P())
            return _shard_map(step_fn, self.mesh, in_specs, out_specs)

        gspecs = sharding.replicated_specs(
            spikes_lib.init_guard_state(spike_guard))

        def guarded_step_fn(params, opt_state, guard_state, batch, step,
                            rng, lr):
            return core(params, opt_state, guard_state, batch, step, rng, lr)

        in_specs = (pspecs, ospecs, gspecs, bspecs, P(), P(), P())
        out_specs = (pspecs, ospecs, gspecs, P())
        return _shard_map(guarded_step_fn, self.mesh, in_specs, out_specs)

    def jit_train_step(self, global_batch: int,
                       opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
                       *, accum_steps: Union[int, Sequence[int]] = 1,
                       spike_guard: Optional["spikes_lib.SpikeConfig"] = None,
                       donate: bool = True):
        """Jitted engine step with buffer donation: params, opt state (and
        guard state when present) are donated so the update happens in
        place — at Ling-Plus scale the params+moments would otherwise
        double peak HBM every step.

        ``accum_steps`` may also be a *sequence* of accum stages (the
        §3.4.1 batch-size warmup path, `optim.schedule.AccumWarmup
        .stages()`): ``global_batch`` is then the fixed per-microbatch
        batch and the return value is a `StagedTrainStep` caching one
        compiled step per stage — the whole warmup costs at most
        ``len(stages)`` compilations, never a per-step recompile.  Grad
        normalization is correct at every stage because each stage's
        scan divides by its own accum count.
        """
        if not isinstance(accum_steps, int):
            return StagedTrainStep(self, global_batch, opt_cfg,
                                   tuple(accum_steps),
                                   spike_guard=spike_guard, donate=donate)
        fn = self.make_train_step(global_batch, opt_cfg,
                                  accum_steps=accum_steps,
                                  spike_guard=spike_guard)
        if not donate:
            return jax.jit(fn)
        return jax.jit(fn, donate_argnums=(0, 1, 2) if spike_guard
                       is not None else (0, 1))

    # -- eval / grads-only (EDiT workers use this) ------------------------------
    def make_loss_and_grad(self, global_batch: int):
        cfg, env, flags = self.cfg, self.env, self.flags
        bspecs = self.train_batch_specs(global_batch)

        def fn(params, batch, step, rng):
            rng = jax.random.fold_in(rng, jax.lax.axis_index(env.dp_axes))

            def lf(p):
                return M.loss_fn(cfg, env, p, batch, step=step, rng=rng,
                                 flags=flags)

            (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(
                params)
            grads = adamw.reduce_replicated_grads(grads, self.specs, env)
            return loss, grads, metrics

        in_specs = (self.specs, bspecs, P(), P())
        out_specs = (P(), self.specs, P())
        return _shard_map(fn, self.mesh, in_specs, out_specs)

    # -- sequence scoring (evaluation harness) ---------------------------------
    def make_score_fn(self, batch_size: int, seq_len: int):
        """(tokens (B,S), mask (B,S)) -> per-sequence sum log p(token_t |
        tokens_<t) over masked positions (perplexity-based eval)."""
        cfg, env, flags = self.cfg, self.env, self.flags
        b = batch_sharding(env, batch_size)

        def fn(params, tokens, mask):
            labels = jnp.where(mask[:, 1:] > 0, tokens[:, 1:], -1)
            batch = {"tokens": tokens[:, :-1],
                     "labels": labels.astype(jnp.int32)}
            x, _, _, _ = M.forward(cfg, env, params, batch, train=False,
                                   flags=flags)
            from repro.models import embedding as emb
            logits = emb.lm_logits(cfg, env, params["embed"], x)
            B = tokens.shape[0]
            lab = labels.reshape(-1)
            v_loc = logits.shape[-1]
            r = env.tp_index()
            gid = r * v_loc + jnp.arange(v_loc)
            logits = jnp.where(gid[None, :] < cfg.vocab_size, logits, -1e30)
            m = env.pmax_tp(jax.lax.stop_gradient(
                jnp.max(logits, axis=-1)))
            se = env.psum_tp(jnp.sum(jnp.exp(logits - m[:, None]), axis=-1))
            lse = m + jnp.log(se)
            loc = lab - r * v_loc
            in_rng = (loc >= 0) & (loc < v_loc)
            picked = jnp.take_along_axis(
                logits, jnp.clip(loc, 0, v_loc - 1)[:, None], axis=-1)[:, 0]
            corr = env.psum_tp(jnp.where(in_rng, picked, 0.0))
            tok_lp = jnp.where(lab >= 0, corr - lse, 0.0).reshape(B, -1)
            return jnp.sum(tok_lp, axis=-1)

        in_specs = (self.specs, P(b, None), P(b, None))
        return _shard_map(fn, self.mesh, in_specs, P(b))

    # -- prefill -----------------------------------------------------------------
    def make_prefill(self, global_batch: int):
        cfg, env, flags = self.cfg, self.env, self.flags
        bspecs = {k: v for k, v in
                  self.train_batch_specs(global_batch).items()
                  if k != "labels"}
        b = batch_sharding(env, global_batch)

        def fn(params, batch):
            x, _, _, caches = M.forward(cfg, env, params, batch,
                                        train=False, flags=flags,
                                        want_cache=True)
            # last-token hidden state per sequence
            B, S = batch["tokens"].shape
            xB = x.reshape(B, S, -1)[:, -1]
            from repro.models import embedding as emb
            logits = emb.lm_logits(cfg, env, params["embed"], xB)
            nxt = emb.sharded_argmax(env, logits)
            return nxt.astype(jnp.int32), caches

        caches = jax.eval_shape(
            lambda: M.init_caches(cfg, env, 1, 8,
                                  cross_len=cfg.encoder_seq_len or 8))
        cache_specs = cache_partition_specs(cfg, env, caches, b)
        in_specs = (self.specs, bspecs)
        out_specs = (P(b), cache_specs)
        return _shard_map(fn, self.mesh, in_specs, out_specs)

    # -- decode -----------------------------------------------------------------
    def make_decode_step(self, global_batch: int, seq_len: int,
                         sample: bool = False):
        """Dense fixed-batch decode step.  With ``sample=True`` it takes
        four extra per-sequence arrays ``(seeds, temperature, top_p,
        top_k)`` and draws under the same (seed, pos, stream) key
        schedule as the online paged path (offline/online stream parity
        for matching seeds; bitwise greedy at temperature <= 0)."""
        cfg, env, flags = self.cfg, self.env, self.flags
        b = batch_sharding(env, global_batch)
        B_loc = (global_batch // env.dp if b is not None else global_batch)
        caches = jax.eval_shape(
            lambda: M.init_caches(cfg, env, B_loc, seq_len,
                                  cross_len=cfg.encoder_seq_len))
        cache_specs = cache_partition_specs(cfg, env, caches, b)

        if sample:
            def fn(params, caches, token, pos, seeds, temp, top_p, top_k):
                return M.decode_step(cfg, env, params, caches, token, pos,
                                     flags=flags,
                                     sample=(seeds, temp, top_p, top_k))

            in_specs = (self.specs, cache_specs, P(b), P(),
                        P(b), P(b), P(b), P(b))
        else:
            def fn(params, caches, token, pos):
                return M.decode_step(cfg, env, params, caches, token, pos,
                                     flags=flags)

            in_specs = (self.specs, cache_specs, P(b), P())
        out_specs = (P(b), cache_specs)
        return _shard_map(fn, self.mesh, in_specs, out_specs), cache_specs

    # -- paged decode / chunked prefill (online serving) -----------------------
    def init_paged_pools(self, n_pages: int, page_size: int):
        """Materialize the paged KV pools, sharded per `paged_cache_specs`
        (the page_size dim is split over tp: rank r owns in-page offsets
        [r*ps_loc, (r+1)*ps_loc), preserving the dense decode cache's 1/tp
        memory sharding).  Page 0 is the scratch page — the online
        engine's allocator never hands it out.  Also the choke point that
        validates `flags.paged_attn` (every paged serve step builds its
        pools here) before any step traces."""
        if page_size % self.env.tp:
            raise ValueError(f"page_size={page_size} must be divisible by "
                             f"tp={self.env.tp} (in-page offset sharding)")
        if self.flags.paged_attn not in ("auto", "fused", "gathered"):
            raise ValueError("flags.paged_attn must be auto|fused|gathered: "
                             f"{self.flags.paged_attn!r}")
        specs = paged_cache_specs(self.cfg, self.env)
        shardings = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(self.mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))
        return jax.jit(
            lambda: M.init_paged_caches(self.cfg, self.env, n_pages,
                                        page_size),
            out_shardings=shardings)()

    def make_paged_decode_step(self, page_size: int, sample: bool = False):
        """Fixed-shape paged decode tick over the slot batch:
        ``(params, pools, token (B,), pos (B,), table (B, n_lp),
        active (B,)) -> (next (B,), pools)``.  B (= max_slots) and the
        table width are fixed by the arrays the caller jits with; slot
        membership lives entirely in the data (table/active), so the
        online engine admits, finishes, and preempts requests without
        ever recompiling.

        With ``sample=True`` the step takes four extra per-slot arrays
        ``(seeds (B,), temperature (B,), top_p (B,), top_k (B,))`` and
        draws under the (seed, pos, stream) key schedule; slots with
        temperature <= 0 still emit the bitwise greedy token, so one
        compiled step serves mixed greedy/stochastic batches."""
        cfg, env, flags = self.cfg, self.env, self.flags
        pspecs = paged_cache_specs(cfg, env)

        if sample:
            def fn(params, pools, token, pos, table, active, seeds, temp,
                   top_p, top_k):
                return M.paged_decode_step(
                    cfg, env, params, pools, token, pos, table, active,
                    page_size=page_size, flags=flags,
                    sample=(seeds, temp, top_p, top_k))

            in_specs = (self.specs, pspecs) + (P(),) * 8
        else:
            def fn(params, pools, token, pos, table, active):
                return M.paged_decode_step(cfg, env, params, pools, token,
                                           pos, table, active,
                                           page_size=page_size, flags=flags)

            in_specs = (self.specs, pspecs, P(), P(), P(), P())
        out_specs = (P(), pspecs)
        return _shard_map(fn, self.mesh, in_specs, out_specs)

    def make_paged_prefill(self, page_size: int, sample: bool = False):
        """Fixed-shape chunked-prefill step for one request:
        ``(params, pools, tokens (C,), base, n_valid, table_row (n_lp,))
        -> (next_token, pools)`` — C is the fixed chunk size the caller
        jits with (short chunks arrive padded with n_valid < C).

        With ``sample=True`` the step takes four extra scalars
        ``(seed, temperature, top_p, top_k)`` and the returned first
        token is drawn at position base + n_valid - 1 under the shared
        key schedule (bitwise greedy at temperature <= 0)."""
        cfg, env, flags = self.cfg, self.env, self.flags
        pspecs = paged_cache_specs(cfg, env)

        if sample:
            def fn(params, pools, tokens, base, n_valid, table_row, seed,
                   temp, top_p, top_k):
                return M.paged_prefill_chunk(
                    cfg, env, params, pools, tokens, base, n_valid,
                    table_row, page_size=page_size, flags=flags,
                    sample=(seed, temp, top_p, top_k))

            in_specs = (self.specs, pspecs) + (P(),) * 8
        else:
            def fn(params, pools, tokens, base, n_valid, table_row):
                return M.paged_prefill_chunk(
                    cfg, env, params, pools, tokens, base, n_valid,
                    table_row, page_size=page_size, flags=flags)

            in_specs = (self.specs, pspecs, P(), P(), P(), P())
        out_specs = (P(), pspecs)
        return _shard_map(fn, self.mesh, in_specs, out_specs)

    # -- speculative decoding (draft proposals + verify) -----------------------
    def make_paged_draft_propose(self, page_size: int, k: int):
        """Drafter-side propose step (call on the DRAFTER's runner):
        ``(params, pools, token (B,), pos0 (B,), table, active, seeds,
        temperature, top_p, top_k) -> (drafts (B, k),
        draft_probs (B, k, Vp), pools)`` — a scan of k+1 sampled decode
        steps over the drafter's own page pools (stream STREAM_DRAFT)."""
        cfg, env, flags = self.cfg, self.env, self.flags
        pspecs = paged_cache_specs(cfg, env)

        def fn(params, pools, token, pos0, table, active, seeds, temp,
               top_p, top_k):
            return M.paged_draft_propose(
                cfg, env, params, pools, token, pos0, table, active,
                (seeds, temp, top_p, top_k), k=k, page_size=page_size,
                flags=flags)

        in_specs = (self.specs, pspecs) + (P(),) * 8
        out_specs = (P(), P(), pspecs)
        return _shard_map(fn, self.mesh, in_specs, out_specs)

    def make_paged_verify_step(self, page_size: int, k: int):
        """Target-side verify step: ``(params, pools, tokens (B, k+1),
        pos0 (B,), table, active, draft_probs (B, k, Vp), seeds,
        temperature, top_p, top_k) -> (n_acc (B,), out (B, k+1), pools)``
        — one paged-prefill-shaped pass scoring all k+1 positions plus
        on-device spec-sampling accept/reject (model.paged_verify_step)."""
        cfg, env, flags = self.cfg, self.env, self.flags
        pspecs = paged_cache_specs(cfg, env)

        def fn(params, pools, tokens, pos0, table, active, draft_probs,
               seeds, temp, top_p, top_k):
            return M.paged_verify_step(
                cfg, env, params, pools, tokens, pos0, table, active,
                draft_probs, (seeds, temp, top_p, top_k),
                page_size=page_size, flags=flags)

        in_specs = (self.specs, pspecs) + (P(),) * 9
        out_specs = (P(), P(), pspecs)
        return _shard_map(fn, self.mesh, in_specs, out_specs)

    def init_cache_shapes(self, global_batch: int, seq_len: int):
        """GLOBAL cache ShapeDtypeStructs (local shapes scaled up by the
        mesh axis sizes named in each leaf's PartitionSpec)."""
        env = self.env
        b = batch_sharding(env, global_batch)
        B_loc = (global_batch // env.dp if b is not None else global_batch)
        local = jax.eval_shape(
            lambda: M.init_caches(self.cfg, env, B_loc, seq_len,
                                  cross_len=self.cfg.encoder_seq_len))
        specs = cache_partition_specs(self.cfg, env, local, b)
        return globalize_shapes(local, specs, self.mesh_sizes), b


class StagedTrainStep:
    """Per-accum-stage compile cache for the batch-size warmup (§3.4.1).

    Each stage shares the fixed `(B_micro, S)` microbatch shape and
    differs only in the length of the accumulation scan, so one jitted
    function per *distinct* stage suffices for the whole warmup.  Steps
    are built lazily by `for_accum` and reused across stage revisits
    (e.g. after a mid-warmup checkpoint restore).  `compiles` is a
    contracts.CompileCounter keyed by ``"accum<N>"`` — each label's
    count equals that stage's XLA compile count, asserted == 1 per
    visited stage via contracts.compile_guard; `trace_counts` keeps the
    historical `{accum: count}` view.
    """

    def __init__(self, runner: "Runner", micro_batch: int,
                 opt_cfg: adamw.AdamWConfig, stages: Tuple[int, ...],
                 *, spike_guard=None, donate: bool = True):
        stages = tuple(sorted({int(s) for s in stages}))
        if not stages or stages[0] < 1:
            raise ValueError(f"accum stages must be >= 1, got {stages}")
        self.runner = runner
        self.micro_batch = micro_batch
        self.opt_cfg = opt_cfg
        self.stages = stages
        self.spike_guard = spike_guard
        self.donate = donate
        self.compiles = contracts.CompileCounter()
        self._fns: Dict[int, Any] = {}

    def for_accum(self, accum: int):
        """The compiled step for one accum stage (batch leaves are
        ``(B, S)`` at accum 1, ``(accum, B, S)`` otherwise)."""
        accum = int(accum)
        if accum not in self.stages:
            raise ValueError(f"accum {accum} not in declared stages "
                             f"{self.stages}")
        fn = self._fns.get(accum)
        if fn is None:
            fn = self._fns[accum] = self._build(accum)
        return fn

    def _build(self, accum: int):
        raw = self.runner.make_train_step(
            self.micro_batch, self.opt_cfg, accum_steps=accum,
            spike_guard=self.spike_guard)
        donate = () if not self.donate else (
            (0, 1, 2) if self.spike_guard is not None else (0, 1))
        return self.compiles.jit(f"accum{accum}", raw,
                                 donate_argnums=donate)

    @property
    def trace_counts(self) -> Dict[int, int]:
        """Historical `{accum: traces}` view over the CompileCounter
        (labels `accum<N>`), nonzero entries only."""
        return {int(label[5:]): n
                for label, n in self.compiles.counts.items() if n}

    @property
    def n_compiles(self) -> int:
        return self.compiles.total()

    def __call__(self, accum: int, *args):
        return self.for_accum(accum)(*args)


def globalize_shapes(shape_tree, spec_tree, mesh_sizes):
    """Scale local ShapeDtypeStructs to global per their PartitionSpecs."""
    spec_leaves = jax.tree.leaves(spec_tree,
                                  is_leaf=lambda x: isinstance(x, P))
    shape_leaves, treedef = jax.tree.flatten(shape_tree)
    assert len(spec_leaves) == len(shape_leaves)

    def scale(sd, spec):
        dims = list(sd.shape)
        for i, part in enumerate(spec):
            if part is None:
                continue
            names = part if isinstance(part, tuple) else (part,)
            for n in names:
                dims[i] *= mesh_sizes[n]
        return jax.ShapeDtypeStruct(tuple(dims), sd.dtype)

    return jax.tree.unflatten(
        treedef, [scale(sd, sp) for sd, sp in zip(shape_leaves, spec_leaves)])


def paged_cache_specs(cfg, env: AxisEnv):
    """PartitionSpecs for the paged KV pools (serving/online.py).

    Pool layout per layer: (n_pages, page_size, KV, hd) with the page_size
    dim sharded over tp (each rank stores ps_loc = page_size/tp offsets of
    every page); uniform archs carry a leading layer dim.  Pages are
    slot-agnostic and may be referenced by several page tables at once —
    refcounted prefix sharing and the cross-request radix cache retain a
    page across requests; the pools themselves never change shape for it."""
    lead = 1 if (cfg.uniform_blocks and not cfg.is_encoder_decoder) else 0
    one = {"self": {"k": P(*([None] * lead), None, env.tp_axis, None, None),
                    "v": P(*([None] * lead), None, env.tp_axis, None,
                           None)}}
    if lead:
        return one
    return [one for _ in range(cfg.n_layers)]


def cache_partition_specs(cfg, env: AxisEnv, cache_tree, b):
    """Build PartitionSpecs for a decode-cache pytree.

    Local cache layouts (built inside shard_map with local sizes):
      attn k/v   (B_loc, S_loc, KV, hd)   -> P(b, tp, None, None)
      rwkv wkv   (B_loc, H_loc, hd, hd)   -> P(b, tp, None, None)
      rwkv last_x / cmix_prev (B_loc, d)  -> P(b, None)
      rglru h    (B_loc, dr_loc)          -> P(b, tp)
      rglru conv (B_loc, 3, dr_loc)       -> P(b, None, tp)
    Uniform-arch caches carry a leading layer dim (None).
    """
    tp = env.tp_axis
    lead = 1 if (cfg.uniform_blocks and not cfg.is_encoder_decoder) else 0

    def one_layer_spec(layer_cache):
        out = {}
        for k, v in layer_cache.items():
            if k in ("self", "cross"):
                out[k] = {"k": P(*([None] * lead), b, tp, None, None),
                          "v": P(*([None] * lead), b, tp, None, None)}
            elif k == "rwkv":
                out[k] = {"wkv": P(*([None] * lead), b, tp, None, None),
                          "last_x": P(*([None] * lead), b, None)}
            elif k == "cmix_prev":
                out[k] = P(*([None] * lead), b, None)
            elif k == "rglru":
                out[k] = {"h": P(*([None] * lead), b, tp),
                          "conv": P(*([None] * lead), b, None, tp)}
        return out

    if lead:
        return one_layer_spec(cache_tree)
    return [one_layer_spec(c) for c in cache_tree]
