"""repro subpackage."""
