"""PCache — distributed checkpoint I/O (§2.3.1, C10).

What transfers from the paper to this environment:

  * sharded pytree save/load with a manifest (real array I/O);
  * the **AI co-design writer-dispersal strategy**: instead of every DP
    group's rank-0 writing from the same few physical nodes (contention!),
    writers are assigned round-robin across nodes.  `assign_writers` is the
    actual algorithm; `simulate_checkpoint_write` models the contention win
    (Table 2: 70s vs 160s / 90s vs 240s shape) and the threaded benchmark
    measures it for real on local disk;
  * metadata caching for fast repeated loads;
  * asynchronous (background-thread) writes so training continues — the
    FUSE/shm interception of the paper is deployment detail, the overlap
    is the system behaviour.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pickle
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np


# ---------------------------------------------------------------------------
# writer dispersal (the paper's core scheduling idea)
# ---------------------------------------------------------------------------


def assign_writers(n_dp_groups: int, ranks_per_group: int, n_nodes: int,
                   ranks_per_node: int, disperse: bool = True
                   ) -> List[int]:
    """Return the writer *global rank* for each DP group.

    DP groups are strided across the cluster (Megatron layout: group g's
    members are ranks {g + r * n_dp_groups}), so the default rank-0 writers
    (`disperse=False`) all land on the first few physical nodes — the
    contention the paper observed.  PCache (`disperse=True`) picks, per
    group, the member on the least-loaded node (greedy), dispersing writes
    across the cluster.
    """
    writers = []
    load = [0] * n_nodes
    for g in range(n_dp_groups):
        members = [g + r * n_dp_groups for r in range(ranks_per_group)]
        if not disperse:
            w = members[0]
        else:
            w = min(members, key=lambda m: (load[(m // ranks_per_node)
                                                 % n_nodes], m))
        load[(w // ranks_per_node) % n_nodes] += 1
        writers.append(w)
    return writers


def node_load(writers: Sequence[int], ranks_per_node: int) -> Dict[int, int]:
    load: Dict[int, int] = {}
    for w in writers:
        load[w // ranks_per_node] = load.get(w // ranks_per_node, 0) + 1
    return load


def simulate_checkpoint_write(n_dp_groups: int, ranks_per_group: int,
                              n_nodes: int, ranks_per_node: int,
                              bytes_per_group: float,
                              node_bw: float = 3e9,
                              disperse: bool = True) -> float:
    """Write time = max over nodes of (groups_on_node * bytes) / node_bw."""
    writers = assign_writers(n_dp_groups, ranks_per_group, n_nodes,
                             ranks_per_node, disperse)
    load = node_load(writers, ranks_per_node)
    worst = max(load.values())
    return worst * bytes_per_group / node_bw


# ---------------------------------------------------------------------------
# real sharded save/load
# ---------------------------------------------------------------------------


class PCache:
    """Local-filesystem checkpoint store with dispersed parallel writers."""

    def __init__(self, root: str, n_writers: int = 4):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.n_writers = n_writers
        self._meta_cache: Dict[str, Dict] = {}
        self._async_jobs: List[threading.Thread] = []

    # -- save -------------------------------------------------------------
    def save(self, name: str, tree: Any, block: bool = True) -> str:
        path = os.path.join(self.root, name)
        os.makedirs(path, exist_ok=True)
        leaves, treedef = jax.tree.flatten(tree)
        arrays = [np.asarray(jax.device_get(l)) for l in leaves]
        manifest = {
            "treedef": str(treedef),
            "n_leaves": len(arrays),
            "leaves": [{"file": f"leaf_{i}.npy", "shape": list(a.shape),
                        "dtype": str(a.dtype)} for i, a in enumerate(arrays)],
            "time": time.time(),
        }

        def write_all():
            # dispersed parallel writers (one pool worker ~ one node)
            with ThreadPoolExecutor(self.n_writers) as ex:
                futs = [ex.submit(np.save, os.path.join(path, f"leaf_{i}"),
                                  a) for i, a in enumerate(arrays)]
                for f in futs:
                    f.result()
            with open(os.path.join(path, "manifest.json"), "w") as f:
                json.dump(manifest, f)

        if block:
            write_all()
        else:
            t = threading.Thread(target=write_all, daemon=True)
            t.start()
            self._async_jobs.append(t)
        return path

    def wait(self):
        for t in self._async_jobs:
            t.join()
        self._async_jobs.clear()

    # -- load -------------------------------------------------------------
    def manifest(self, name: str) -> Dict:
        if name in self._meta_cache:                 # metadata cache
            return self._meta_cache[name]
        with open(os.path.join(self.root, name, "manifest.json")) as f:
            m = json.load(f)
        self._meta_cache[name] = m
        return m

    def load(self, name: str, like: Any) -> Any:
        m = self.manifest(name)
        path = os.path.join(self.root, name)
        leaves = [np.load(os.path.join(path, e["file"]))
                  for e in m["leaves"]]
        treedef = jax.tree.structure(like)
        assert treedef.num_leaves == len(leaves), "tree mismatch"
        return jax.tree.unflatten(treedef, leaves)

    def list_checkpoints(self) -> List[str]:
        return sorted(d for d in os.listdir(self.root)
                      if os.path.isdir(os.path.join(self.root, d)))

    def latest(self) -> Optional[str]:
        """Newest complete checkpoint (has a manifest), ``step_N``-aware:
        numeric suffixes sort numerically so step_100 beats step_20."""
        def key(name: str):
            # step_N names rank above (and among themselves by N) any
            # manually-named checkpoint, digit-suffixed or not
            tail = name[5:] if name.startswith("step_") else ""
            return (1, int(tail), "") if tail.isdigit() else (0, 0, name)

        done = [d for d in self.list_checkpoints()
                if os.path.exists(os.path.join(self.root, d,
                                               "manifest.json"))]
        return max(done, key=key) if done else None

    # -- host-side state (pipeline / detector / step counter) --------------
    def save_host(self, name: str, obj: Any):
        """Pickle non-array host state next to the array leaves.  Written
        synchronously (it is tiny); the array writers may still be running
        in the background."""
        path = os.path.join(self.root, name)
        os.makedirs(path, exist_ok=True)
        with open(os.path.join(path, "host_state.pkl"), "wb") as f:
            pickle.dump(obj, f)

    def load_host(self, name: str) -> Any:
        with open(os.path.join(self.root, name, "host_state.pkl"),
                  "rb") as f:
            return pickle.load(f)
