"""Babel — cross-cluster data synchronization middleware (§2.3.2, C11).

Implemented against local directories standing in for per-cluster object
stores, with the paper's three mechanisms as real code:

  * **parallel metadata prefetching**: listing sharded by prefix across a
    thread pool with a scheduling queue (paper: ~36x, 6h -> 10min for 190M
    files; the benchmark measures the parallel/serial ratio here);
  * **adaptive data sharding**: large files are split into chunks that
    transfer (copy) concurrently and reassemble;
  * **content-sampling CRC verification**: instead of a full-file hash,
    CRC32 over sampled chunks (head/tail + strided middle samples) —
    the paper's 100GB-in-3s trade; full-MD5 is implemented alongside for
    the comparison benchmark.  Both runtime and post-transfer verification
    modes exist.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import shutil
import time
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# metadata prefetching
# ---------------------------------------------------------------------------


def list_serial(root: str) -> List[str]:
    out = []
    for dirpath, _dirnames, filenames in os.walk(root):
        for fn in filenames:
            out.append(os.path.relpath(os.path.join(dirpath, fn), root))
    return sorted(out)


def list_parallel(root: str, workers: int = 16) -> List[str]:
    """Prefix-sharded parallel listing with an intelligent work queue:
    each top-level prefix is an independent List task (concurrent OSS List
    calls in the paper)."""
    try:
        prefixes = [e for e in os.listdir(root)]
    except FileNotFoundError:
        return []
    files: List[str] = []
    dirs: List[str] = []
    for e in prefixes:
        p = os.path.join(root, e)
        (dirs if os.path.isdir(p) else files).append(e)

    def one(prefix: str) -> List[str]:
        out = []
        base = os.path.join(root, prefix)
        for dirpath, _d, filenames in os.walk(base):
            rel = os.path.relpath(dirpath, root)
            for fn in filenames:
                out.append(os.path.join(rel, fn))
        return out

    with ThreadPoolExecutor(workers) as ex:
        for chunk in ex.map(one, dirs):
            files.extend(chunk)
    return sorted(files)


# ---------------------------------------------------------------------------
# verification
# ---------------------------------------------------------------------------


def md5_full(path: str, chunk: int = 1 << 20) -> str:
    h = hashlib.md5()
    with open(path, "rb") as f:
        while True:
            b = f.read(chunk)
            if not b:
                break
            h.update(b)
    return h.hexdigest()


def crc_sampled(path: str, sample_bytes: int = 1 << 16,
                n_samples: int = 8) -> Tuple[int, int]:
    """Content-sampling CRC: head + tail + strided middle samples + size.

    Returns (crc32, file_size).  Cost is O(n_samples * sample_bytes)
    regardless of file size — the paper's 100GB-in-~3s verification.
    """
    size = os.path.getsize(path)
    crc = 0
    with open(path, "rb") as f:
        offsets = {0, max(size - sample_bytes, 0)}
        if size > 2 * sample_bytes:
            stride = size // (n_samples + 1)
            for i in range(1, n_samples + 1):
                offsets.add(min(i * stride, size - sample_bytes))
        for off in sorted(offsets):
            f.seek(off)
            crc = zlib.crc32(f.read(sample_bytes), crc)
    return crc, size


# ---------------------------------------------------------------------------
# transfer
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SyncReport:
    files_total: int = 0
    files_copied: int = 0
    files_skipped: int = 0
    bytes_copied: int = 0
    verified: int = 0
    verify_failures: List[str] = dataclasses.field(default_factory=list)
    list_seconds: float = 0.0
    copy_seconds: float = 0.0
    verify_seconds: float = 0.0


class Babel:
    """Directory-to-directory synchronizer with sharded transfer and
    sampled-CRC verification."""

    def __init__(self, workers: int = 8, chunk_bytes: int = 8 << 20,
                 verify: str = "sampled"):   # "sampled" | "full" | "off"
        self.workers = workers
        self.chunk_bytes = chunk_bytes
        self.verify = verify

    def _copy_sharded(self, src: str, dst: str):
        """Adaptive sharding: big files move as concurrent chunks."""
        size = os.path.getsize(src)
        os.makedirs(os.path.dirname(dst), exist_ok=True)
        if size <= self.chunk_bytes:
            shutil.copyfile(src, dst)
            return size
        n_chunks = (size + self.chunk_bytes - 1) // self.chunk_bytes
        with open(dst, "wb") as out:
            out.truncate(size)

        def one(i):
            off = i * self.chunk_bytes
            with open(src, "rb") as f, open(dst, "r+b") as out:
                f.seek(off)
                data = f.read(self.chunk_bytes)
                out.seek(off)
                out.write(data)

        with ThreadPoolExecutor(self.workers) as ex:
            list(ex.map(one, range(n_chunks)))
        return size

    def _needs_copy(self, src: str, dst: str) -> bool:
        if not os.path.exists(dst):
            return True
        ss, ds = os.path.getsize(src), os.path.getsize(dst)
        if ss != ds:
            return True
        return os.path.getmtime(src) > os.path.getmtime(dst) + 1e-3

    def sync(self, src_root: str, dst_root: str) -> SyncReport:
        rep = SyncReport()
        t0 = time.time()
        files = list_parallel(src_root, self.workers)
        rep.list_seconds = time.time() - t0
        rep.files_total = len(files)

        t0 = time.time()

        def copy_one(rel):
            s = os.path.join(src_root, rel)
            d = os.path.join(dst_root, rel)
            if not self._needs_copy(s, d):
                return 0, 0
            return 1, self._copy_sharded(s, d)

        with ThreadPoolExecutor(self.workers) as ex:
            for copied, nbytes in ex.map(copy_one, files):
                rep.files_copied += copied
                rep.files_skipped += 1 - copied
                rep.bytes_copied += nbytes
        rep.copy_seconds = time.time() - t0

        if self.verify != "off":
            t0 = time.time()

            def verify_one(rel):
                s = os.path.join(src_root, rel)
                d = os.path.join(dst_root, rel)
                if self.verify == "sampled":
                    ok = crc_sampled(s) == crc_sampled(d)
                else:
                    ok = md5_full(s) == md5_full(d)
                return rel, ok

            with ThreadPoolExecutor(self.workers) as ex:
                for rel, ok in ex.map(verify_one, files):
                    rep.verified += 1
                    if not ok:
                        rep.verify_failures.append(rel)
            rep.verify_seconds = time.time() - t0
        return rep
