"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels execute with interpret=True (the kernel
body runs as jnp ops, validating the tiling logic); on a real TPU the same
call sites compile the Mosaic kernels.  `INTERPRET` flips automatically.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import grouped_matmul as _gm
from repro.kernels import normhead as _nh
from repro.kernels import paged_attn as _pa
from repro.kernels import wkv6 as _wkv

INTERPRET = jax.default_backend() != "tpu"


def _align_groups(lhs, group_sizes, bm: int):
    """Re-layout ragged rows so each group starts at a multiple of bm.

    Returns (lhs_aligned (M_pad, K), tile_group (M_pad/bm,), row_map
    (M_pad,) source row per padded row or -1)."""
    M = lhs.shape[0]
    G = group_sizes.shape[0]
    padded = ((group_sizes + bm - 1) // bm) * bm          # (G,)
    out_starts = jnp.cumsum(padded) - padded
    in_starts = jnp.cumsum(group_sizes) - group_sizes
    M_pad_max = int(M + G * (bm - 1))
    M_pad_max = ((M_pad_max + bm - 1) // bm) * bm
    rows = jnp.arange(M_pad_max)
    # group of each padded row: binary search over the G aligned group-end
    # boundaries (O(M_pad log G), vs the old O(M_pad * G) compare matrix)
    gid = jnp.searchsorted(out_starts + padded, rows, side="right")
    gid_c = jnp.clip(gid, 0, G - 1)
    off = rows - jnp.take(out_starts, gid_c)
    src = jnp.take(in_starts, gid_c) + off
    valid = (gid < G) & (off < jnp.take(group_sizes, gid_c))
    row_map = jnp.where(valid, src, -1)
    lhs_pad = jnp.where(valid[:, None],
                        jnp.take(lhs, jnp.clip(row_map, 0), axis=0), 0)
    tile_group = jnp.where(
        jnp.take(valid, rows[::bm]), gid_c[::bm].astype(jnp.int32),
        jnp.int32(G))
    return lhs_pad, tile_group, row_map


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def grouped_matmul(lhs, rhs, group_sizes, *, bm: int = 128, bk: int = 128,
                   bn: int = 128, interpret: bool | None = None):
    """Drop-in for jax.lax.ragged_dot: lhs (M,K) group-sorted rows,
    rhs (G,K,N), group_sizes (G,).  Handles non-aligned groups by
    re-laying rows out to bm-aligned group starts."""
    interpret = INTERPRET if interpret is None else interpret
    M, K = lhs.shape
    G, _, N = rhs.shape
    bm = min(bm, max(8, M))
    bk_ = min(bk, K)
    bn_ = min(bn, N)
    # shrink tiles to divide the problem (kernel requires exact tiling)
    while K % bk_:
        bk_ //= 2
    while N % bn_:
        bn_ //= 2
    lhs_pad, tile_group, row_map = _align_groups(lhs, group_sizes, bm)
    out_pad = _gm.grouped_matmul_aligned(lhs_pad, rhs, tile_group, bm=bm,
                                         bk=bk_, bn=bn_,
                                         interpret=interpret)
    # scatter rows back to the original ragged layout
    M_pad = lhs_pad.shape[0]
    out = jnp.zeros((M, N), out_pad.dtype)
    ok = row_map >= 0
    out = out.at[jnp.clip(row_map, 0)].add(
        jnp.where(ok[:, None], out_pad, 0))
    return out


def _fused_layout(tok, gate, group_sizes, n_tokens: int, bm: int):
    """Index-only analogue of `_align_groups` for the fused MoE pipeline.

    tok (cap,): source token per expert-sorted dispatch slot; gate (cap,):
    router weight per slot (0 where masked); group_sizes (G,): rows per
    expert among the first sum(group_sizes) slots.  Returns
    (row_idx (n_m, bm) int32 token per padded row, clamped to [0, T);
     gates (n_m, bm) fp32, 0 for padding;  tile_group (n_m,) int32 expert
     per tile, G for all-padding tiles).  Nothing is materialized beyond
    these index/gate arrays — the aligned-lhs copy the unfused wrapper
    writes to HBM simply does not exist here.
    """
    cap = tok.shape[0]
    G = group_sizes.shape[0]
    padded = ((group_sizes + bm - 1) // bm) * bm
    bounds = jnp.cumsum(padded)                          # aligned group ends
    out_starts = bounds - padded
    in_starts = jnp.cumsum(group_sizes) - group_sizes
    M_pad = int(cap + G * (bm - 1))
    M_pad = ((M_pad + bm - 1) // bm) * bm
    rows = jnp.arange(M_pad)
    gid = jnp.searchsorted(bounds, rows, side="right")
    gid_c = jnp.clip(gid, 0, G - 1)
    off = rows - jnp.take(out_starts, gid_c)
    valid = (gid < G) & (off < jnp.take(group_sizes, gid_c))
    src = jnp.clip(jnp.take(in_starts, gid_c) + off, 0, cap - 1)
    row_idx = jnp.where(valid, jnp.take(tok, src), 0)
    row_idx = jnp.clip(row_idx, 0, n_tokens - 1).astype(jnp.int32)
    gates = jnp.where(valid, jnp.take(gate, src), 0.0).astype(jnp.float32)
    tile_group = jnp.where(jnp.take(valid, rows[::bm]),
                           gid_c[::bm].astype(jnp.int32), jnp.int32(G))
    return (row_idx.reshape(-1, bm), gates.reshape(-1, bm), tile_group)


@functools.partial(jax.jit,
                   static_argnames=("act", "bm", "bf", "interpret"))
def moe_fused_ffn(x, w1, w2, w3, tok, gate, group_sizes, *,
                  act: str = "swiglu", bm: int = 128, bf: int = 128,
                  interpret: bool | None = None):
    """Fused MoE FFN pipeline: gather -> w1/(w3) -> act -> w2 -> gate*out
    combine, one Pallas kernel, no HBM intermediates.

    x (T, d) unsorted activations; w1/w3 (G, d, ff), w2 (G, ff, d) (w3
    None for non-gated acts); tok (cap,) token per expert-sorted slot;
    gate (cap,) router weight (0 where masked); group_sizes (G,) rows per
    expert.  Slots beyond sum(group_sizes) are dropped (ragged_dot
    semantics).  Returns the combined (T, d) fp32 partial.
    """
    interpret = INTERPRET if interpret is None else interpret
    T, d = x.shape
    G, _, ff = w1.shape
    cap = tok.shape[0]
    bm = min(bm, max(8, cap))
    bf_ = min(bf, ff)
    while ff % bf_:
        bf_ //= 2
    row_idx, gates, tile_group = _fused_layout(tok, gate, group_sizes,
                                               T, bm)
    return _gm.fused_moe_ffn(x, w1, w2, w3, row_idx, gates, tile_group,
                             act=act, bf=bf_, interpret=interpret)


def paged_gather(pool, table):
    """Gather KV pages for paged-attention decode (serving/online.py).

    pool (n_pages, ps_loc, ...) is a device-resident page pool whose
    in-page offset dim is the tp-local slice of the global page_size;
    table (..., n_lp) holds the physical page id backing each logical
    page (0 = the reserved scratch page, which doubles as the
    "unallocated" sentinel — callers mask those positions).  Returns
    (..., n_lp, ps_loc, ...): each slot's logical KV sequence assembled
    in logical-page order, so reshaping the two page dims together
    yields a dense (S, ...) cache view the standard decode-attention
    einsums consume unchanged.

    This is a pure gather along the page dim — it materializes the full
    table-width view in HBM once per layer per tick.  It backs the
    "gathered" paged-attention mode (the parity oracle and real-TPU
    fallback); the "fused" mode (`paged_attention` below) walks the page
    table inside the attention kernel instead, so this view never
    exists.
    """
    return jnp.take(pool, table, axis=0)


def _pa_group_q(q, KV):
    """(B, Q, Hp, hd) -> (B, KV, g*Q, hd), g-major: one q block per kv
    head so a (Q, ps_loc) mask block broadcasts over the group."""
    B, Qn, Hp, hd = q.shape
    g = Hp // KV
    return q.reshape(B, Qn, KV, g, hd).transpose(0, 2, 3, 1, 4) \
            .reshape(B, KV, g * Qn, hd)


def _pa_ungroup(x, Qn, Hp):
    """(B, KV, g*Q, ...) -> (B, Q, Hp, ...), inverse of `_pa_group_q`."""
    B, KV = x.shape[:2]
    g = Hp // KV
    y = x.reshape((B, KV, g, Qn) + x.shape[3:])
    y = jnp.moveaxis(y, 3, 1)
    return y.reshape((B, Qn, Hp) + x.shape[3:])


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_scores_max(q, k_pool, table, mask, *,
                               interpret: bool | None = None):
    """Pass 1 of fused paged attention: per-rank row max of the masked
    scores, page table walked in-kernel (kernels/paged_attn.py).

    q (B, Q, Hp, hd): query-batched heads — Q=1 decode, Q=C chunked
    prefill, Q=k+1 spec-decode verify;  k_pool (n_pages, ps_loc, KV, hd):
    the layer's K page pool (ps_loc = this tp rank's row-slice of each
    page);  table (B, n_lp) int32 physical page per logical page (0 =
    scratch/unallocated — rows must be masked);  mask (B, Q, S_g) bool
    with S_g = n_lp * ps_loc: page-valid & causal validity per query
    (models/layers.py::paged_valid_mask).

    Requires the grouped GQA layout: Hp % KV == 0 with head h belonging
    to kv head h // (Hp // KV) — the same precondition as the gathered
    path's grouped fast path; `_paged_attention_core` falls back to
    "gathered" otherwise.

    Returns m (B, Q, Hp) f32 — the LOCAL max masked score over this
    rank's pool rows (-inf where nothing valid).  Callers pmax over tp,
    zero the -inf rows, and feed the result to
    `paged_attention_accumulate` so p is computed against the GLOBAL max
    exactly like the gathered oracle.
    """
    interpret = INTERPRET if interpret is None else interpret
    B, Qn, Hp, hd = q.shape
    _, ps_loc, KV, _ = k_pool.shape
    n_lp = table.shape[1]
    m = _pa.paged_attn_scores_max(
        _pa_group_q(q, KV), k_pool, table,
        mask.reshape(B, Qn, n_lp, ps_loc), interpret=interpret)
    return _pa_ungroup(m, Qn, Hp)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_accumulate(q, k_pool, v_pool, table, mask, m_safe, *,
                               interpret: bool | None = None):
    """Pass 2 of fused paged attention: accumulate softmax partials
    against the tp-GLOBAL safe max (kernels/paged_attn.py).

    Operands as in `paged_attention_scores_max` plus v_pool (same shape
    as k_pool) and m_safe (B, Q, Hp) f32 — the pmax'ed row max with -inf
    replaced by 0.  Inside the kernel p = exp(s - m_safe) is rounded to
    the pool dtype before the PV contraction, the gathered combine's
    `p.astype(cdt)` convention, so every softmax term is bitwise the
    oracle's term at any tp.  Returns LOCAL fp32 partials
    (num (B, Q, Hp, hd), den (B, Q, Hp)) over this rank's pool rows;
    callers psum both over tp and normalize.
    """
    interpret = INTERPRET if interpret is None else interpret
    B, Qn, Hp, hd = q.shape
    _, ps_loc, KV, _ = k_pool.shape
    n_lp = table.shape[1]
    num, den = _pa.paged_attn_accumulate(
        _pa_group_q(q, KV), k_pool, v_pool, table,
        mask.reshape(B, Qn, n_lp, ps_loc),
        _pa_group_q(m_safe[..., None], KV)[..., 0], interpret=interpret)
    return _pa_ungroup(num, Qn, Hp), _pa_ungroup(den, Qn, Hp)


# ---------------------------------------------------------------------------
# EP token exchange: custom-vjp all-to-all for the expert-parallel MoE path
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ep_a2a(axis_name, x):
    return jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0,
                              tiled=False)


def _ep_a2a_fwd(axis_name, x):
    return _ep_a2a(axis_name, x), None


def _ep_a2a_bwd(axis_name, _res, g):
    # The exchange permutes blocks as out[dst][src] = in[src][dst] — an
    # involution, so its transpose is the SAME all-to-all: each cotangent
    # block travels straight back to the rank that sent the activation.
    return (_ep_a2a(axis_name, g),)


_ep_a2a.defvjp(_ep_a2a_fwd, _ep_a2a_bwd)


def ep_all_to_all(x, *, axis_name: str):
    """Expert-parallel token exchange over a mesh axis.

    x (tp, cap, ...): block j is this rank's payload addressed to rank j.
    Returns (tp, cap, ...) where block s arrived from rank s.  The
    custom-vjp pins the backward pass to exactly the transposed
    all-to-all — gradient blocks retrace the forward routes, backward
    communication volume equals forward volume — as an explicit contract
    of the EP hot path, independent of how upstream lowers the
    primitive's transpose.  (Reverse-mode only: training never needs
    jvp through the dispatch.)
    """
    return _ep_a2a(axis_name, x)


@functools.partial(jax.jit, static_argnames=("bt", "bv", "bk", "interpret"))
def normhead_logits(x, w, *, bt: int = 128, bv: int = 128, bk: int = 128,
                    interpret: bool | None = None):
    """Fused NormHead: x (T,d) @ normalize_rows(w (V,d)).T -> (T,V) fp32."""
    interpret = INTERPRET if interpret is None else interpret
    T, d = x.shape
    V, _ = w.shape
    bt_, bv_, bk_ = min(bt, T), min(bv, V), min(bk, d)
    while T % bt_:
        bt_ //= 2
    while V % bv_:
        bv_ //= 2
    while d % bk_:
        bk_ //= 2
    return _nh.normhead_matmul(x, w, bt=bt_, bv=bv_, bk=bk_,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, state, *, chunk: int = 256,
         interpret: bool | None = None):
    """RWKV6 recurrence.  r,k,v,w (B,T,H,hd); u (H,hd);
    state (B,H,hd,hd) fp32.  Returns (y (B,T,H,hd), state')."""
    interpret = INTERPRET if interpret is None else interpret
    B, T, H, hd = r.shape
    ck = min(chunk, T)
    while T % ck:
        ck //= 2

    def flat(t):
        return jnp.moveaxis(t, 1, 2).reshape(B * H, T, hd).astype(
            jnp.float32)

    u_f = jnp.tile(u.astype(jnp.float32), (B, 1))
    s_f = state.reshape(B * H, hd, hd).astype(jnp.float32)
    y, sT = _wkv.wkv6_chunked(flat(r), flat(k), flat(v), flat(w), u_f, s_f,
                              chunk=ck, interpret=interpret)
    y = jnp.moveaxis(y.reshape(B, H, T, hd), 2, 1).astype(r.dtype)
    return y, sT.reshape(B, H, hd, hd)
