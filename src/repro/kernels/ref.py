"""Pure-jnp oracles for every Pallas kernel (validated via assert_allclose
in tests/test_kernels.py across shape/dtype sweeps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def grouped_matmul_ref(lhs: jax.Array, rhs: jax.Array,
                       group_sizes: jax.Array) -> jax.Array:
    """lhs (M, K) rows sorted by group; rhs (G, K, N); group_sizes (G,).
    Rows beyond sum(group_sizes) produce zeros (ragged_dot semantics)."""
    M = lhs.shape[0]
    G = rhs.shape[0]
    ends = jnp.cumsum(group_sizes)
    starts = ends - group_sizes
    row = jnp.arange(M)
    # group id per row; rows past the end get G (masked out)
    gid = jnp.sum(row[:, None] >= ends[None, :], axis=1)
    valid = row < ends[-1]
    w = jnp.take(rhs, jnp.clip(gid, 0, G - 1), axis=0)     # (M, K, N)
    out = jnp.einsum("mk,mkn->mn", lhs.astype(jnp.float32),
                     w.astype(jnp.float32))
    return jnp.where(valid[:, None], out, 0.0).astype(lhs.dtype)


def fused_moe_ffn_ref(x, w1, w2, w3, tok, gate, group_sizes,
                      act="swiglu"):
    """Oracle for the fused MoE FFN pipeline: gather x[tok], run the
    grouped expert FFN (fp32 accumulation, gid from group_sizes), combine
    gate-weighted rows back into (T, d).  Slots past sum(group_sizes)
    drop (ragged_dot semantics)."""
    from repro.kernels.grouped_matmul import _ACTS
    act_fn = _ACTS[act]
    T, d = x.shape
    xs = jnp.take(x, tok, axis=0).astype(jnp.float32)        # (cap, d)
    cap = xs.shape[0]
    G = w1.shape[0]
    ends = jnp.cumsum(group_sizes)
    gid = jnp.searchsorted(ends, jnp.arange(cap), side="right")
    valid = jnp.arange(cap) < ends[-1]
    gid_c = jnp.clip(gid, 0, G - 1)
    w1r = jnp.take(w1, gid_c, axis=0).astype(jnp.float32)    # (cap, d, ff)
    h = jnp.einsum("md,mdf->mf", xs, w1r)
    if w3 is not None:
        w3r = jnp.take(w3, gid_c, axis=0).astype(jnp.float32)
        h = act_fn(h) * jnp.einsum("md,mdf->mf", xs, w3r)
    else:
        h = act_fn(h)
    w2r = jnp.take(w2, gid_c, axis=0).astype(jnp.float32)    # (cap, ff, d)
    out = jnp.einsum("mf,mfd->md", h, w2r)
    out = out * (gate.astype(jnp.float32) * valid)[:, None]
    return jnp.zeros((T, d), jnp.float32).at[tok].add(out)


def normhead_ref(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """x (T, d), w (V, d) -> logits (T, V) with L2-normalized rows of w
    (paper Eq. 4), fp32 accumulation."""
    wf = w.astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(wf * wf, axis=-1, keepdims=True))
    wn = wf / jnp.maximum(norm, eps)
    return x.astype(jnp.float32) @ wn.T


def wkv6_ref(r, k, v, w, u, state):
    """RWKV6 recurrence oracle.  r,k,v,w (B,T,H,hd) fp32; u (H,hd);
    state (B,H,hd,hd).  Returns (y, final_state)."""
    def step(S, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhkv,bhk->bhv", S + u[..., None] * kv, rt)
        S = wt[..., :, None] * S + kv
        return S, y

    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, inputs)
    return jnp.moveaxis(ys, 0, 1), state
