"""Pallas TPU kernel: fused NormHead logits (paper Eq. 4, C4).

logits = x @ (W / ||W||_row)^T without ever materializing the normalized
weight matrix in HBM: each (bt, bv, bk) tile accumulates both the partial
dot products AND the partial squared row norms of W in VMEM scratch; the
division happens once per output tile on the last K step.

HBM traffic saved vs the unfused form: one full read + write of W
(normalize) plus one read (matmul) collapses into a single read.  For
Ling-Plus' 126k x 8192 head that is ~2.1 GB less HBM traffic per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, out_ref, acc_ref, nrm_ref, *, n_k: int,
            eps: float):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        nrm_ref[...] = jnp.zeros_like(nrm_ref)

    x = x_ref[...]
    w = w_ref[...]                       # (bv, bk)
    acc_ref[...] += jax.lax.dot_general(
        x, w, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    wf = w.astype(jnp.float32)
    nrm_ref[...] += jnp.sum(wf * wf, axis=1, keepdims=True).T   # (1, bv)

    @pl.when(k_idx == n_k - 1)
    def _done():
        norm = jnp.sqrt(nrm_ref[...])                           # (1, bv)
        out_ref[...] = (acc_ref[...]
                        / jnp.maximum(norm, eps)).astype(out_ref.dtype)


def normhead_matmul(x: jax.Array, w: jax.Array, *, bt: int = 128,
                    bv: int = 128, bk: int = 128, eps: float = 1e-6,
                    interpret: bool = False) -> jax.Array:
    """x (T, d), w (V, d) -> fp32 logits (T, V), rows of w L2-normalized."""
    T, d = x.shape
    V, d2 = w.shape
    assert d == d2 and T % bt == 0 and V % bv == 0 and d % bk == 0
    n_k = d // bk
    fn = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, eps=eps),
        grid=(T // bt, V // bv, n_k),
        in_specs=[
            pl.BlockSpec((bt, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bv, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bt, bv), lambda i, j, k: (i, j)),
        scratch_shapes=[pltpu.VMEM((bt, bv), jnp.float32),
                        pltpu.VMEM((1, bv), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((T, V), jnp.float32),
        interpret=interpret,
    )
    return fn(x, w)
