"""Pallas TPU kernels: fused paged attention — walk the page table
in-kernel instead of materializing a gathered KV view in HBM.

The serving hot path (models/layers.py paged_decode/prefill/verify
attention) previously assembled each slot's logical KV sequence with
`ops.paged_gather` — a `(B, S_g, KV, hd)` HBM intermediate per layer per
tick, where S_g = table_width * page_size covers the FULL table width
whether or not the pages are allocated — and the prefill/verify callers
broadcast that view once per query on top.  These kernels take Q plus
the page table and the page pool directly:

* Two passes, two `pallas_call`s, grid `(B, KV, n_lp)` each — slot x
  kv-head x logical page, page innermost:
  - `paged_attn_scores_max` walks the K pages once and emits each query
    row's max masked score on this rank (no V traffic);
  - the caller `pmax`es those maxes over tp in plain JAX;
  - `paged_attn_accumulate` walks K and V again, computing
    `p = exp(s - m_global)` against the GLOBAL max and accumulating
    `(num, den)` in fp32 VMEM scratch.
  Splitting at the max lets `p` be computed against the final global
  max — not a running or rank-local one — and rounded to the pool dtype
  at exactly the point the gathered oracle's einsum rounds it
  (`p.astype(cdt)`), so every softmax term matches the oracle bitwise
  at ANY tp and fused-vs-gathered differences collapse to f32
  summation-order noise (~1e-7) instead of compute-dtype rounding noise
  (~1e-2 with bf16 pools).  That is what keeps greedy argmax token
  streams identical to the gathered path.  A single-pass online-softmax
  variant would save the second K read at the price of that agreement;
  revisit with the real-TPU tile sweep (ROADMAP item 3).
* The page table is **scalar-prefetched** so the K/V BlockSpec
  index_maps resolve the physical page *before* each grid step runs —
  the same mechanism `grouped_matmul_aligned` uses to select expert
  weight tiles.  Each step DMAs one `(ps_loc, hd)` page row-block into
  VMEM; the Pallas grid pipeline double-buffers these loads against the
  previous step's compute automatically.
* The gathered `(B, S_g, KV, hd)` view and the `(B, Q, Hp, S_g)` score
  matrix never exist in HBM: per-step state is fp32 VMEM scratch.
* Unallocated logical pages (table id 0) index the reserved scratch
  page; their rows are masked by the caller-provided validity mask, so
  the kernel reads garbage harmlessly and needs no branch.
* GQA is the grid's KV dim: the `g = Hp // KV` query heads of a group
  ride one q block `(g*Q, hd)` and contract against the *unexpanded*
  page rows — no head-expanded KV copy either.

The kernels emit LOCAL per-rank partials and leave every collective —
`pmax` of the maxes between the passes, `psum` of (num, den) after,
normalize — to the caller in plain JAX, exactly mirroring the gathered
path's combine tail.  That keeps the kernels collective-free (they
compose with shard_map untouched) and keeps "gathered" a drop-in parity
oracle.  Inference-only: no custom VJP — the serving steps never
differentiate through attention.

One query-batched core serves all three callers: decode (Q=1), chunked
prefill (Q=C), and spec-decode verify (Q=k+1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _masked_scores(q_ref, k_ref, mask_ref, *, g: int, n_q: int,
                   scale: float):
    """Shared per-page score block: (g*Q, ps_loc) masked f32 scores and
    the broadcast (g*Q, ps_loc) mask."""
    q = q_ref[0, 0].astype(jnp.float32)                  # (g*Q, hd)
    kp = k_ref[0, :, 0, :].astype(jnp.float32)           # (ps_loc, hd)
    msk = mask_ref[0, :, 0, :]                           # (Q, ps_loc)
    mskg = jnp.broadcast_to(msk[None], (g, n_q, msk.shape[-1])
                            ).reshape(g * n_q, -1)       # (g*Q, ps_loc)
    s = jax.lax.dot_general(                             # (g*Q, ps_loc)
        q, kp, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale
    return jnp.where(mskg, s, -jnp.inf), mskg


def _max_kernel(table_ref, q_ref, k_ref, mask_ref, m_ref, m_s_ref, *,
                n_lp: int, g: int, n_q: int, scale: float):
    """Pass 1: running max of the masked scores across the page walk."""
    # program_id must be read at the top level: the interpret-mode
    # evaluator does not substitute it inside pl.when sub-jaxprs.
    i = pl.program_id(2)
    s, _ = _masked_scores(q_ref, k_ref, mask_ref, g=g, n_q=n_q,
                          scale=scale)

    @pl.when(i == 0)
    def _init():
        m_s_ref[...] = jnp.full_like(m_s_ref, -jnp.inf)

    m_s_ref[...] = jnp.maximum(m_s_ref[...],
                               jnp.max(s, axis=-1, keepdims=True))

    @pl.when(i == n_lp - 1)
    def _flush():
        m_ref[0, 0] = m_s_ref[...][:, 0]


def _acc_kernel(table_ref, q_ref, k_ref, v_ref, mask_ref, msafe_ref,
                num_ref, den_ref, acc_ref, den_s_ref, *,
                n_lp: int, g: int, n_q: int, scale: float):
    """Pass 2: accumulate (num, den) against the caller-provided GLOBAL
    safe max (already pmax'ed over tp and zeroed where -inf)."""
    i = pl.program_id(2)
    s, mskg = _masked_scores(q_ref, k_ref, mask_ref, g=g, n_q=n_q,
                             scale=scale)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        den_s_ref[...] = jnp.zeros_like(den_s_ref)

    vp = v_ref[0, :, 0, :]                               # (ps_loc, hd)
    m_safe = msafe_ref[0, 0][:, None]                    # (g*Q, 1)
    p = jnp.where(mskg, jnp.exp(s - m_safe), 0.0)        # (g*Q, ps_loc)
    # round p to the pool dtype BEFORE the PV contraction — the same
    # point the gathered combine rounds (`p.astype(cdt)` einsum), so
    # every product is bitwise the oracle's product.
    pv = jax.lax.dot_general(
        p.astype(vp.dtype), vp,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # (g*Q, hd)
    acc_ref[...] = acc_ref[...] + pv
    den_s_ref[...] = den_s_ref[...] + jnp.sum(p, axis=-1, keepdims=True)

    @pl.when(i == n_lp - 1)
    def _flush():
        num_ref[0, 0] = acc_ref[...]
        den_ref[0, 0] = den_s_ref[...][:, 0]


def _check_shapes(q, k_pool, table, mask):
    B, KV, GQ, hd = q.shape
    n_pages, ps_loc, KV2, hd2 = k_pool.shape
    assert (KV, hd) == (KV2, hd2), (q.shape, k_pool.shape)
    n_lp = table.shape[1]
    n_q = mask.shape[1]
    g = GQ // n_q
    assert g * n_q == GQ and mask.shape == (B, n_q, n_lp, ps_loc), \
        (q.shape, mask.shape, table.shape)
    return B, KV, GQ, hd, ps_loc, n_lp, n_q, g


def _qkm_specs(GQ, hd, ps_loc, n_q):
    """BlockSpecs shared by both passes: q block, K page block (physical
    page selected by the scalar-prefetched table — dynamic-slice DMA of
    one page row-block per step), mask block."""
    return [
        pl.BlockSpec((1, 1, GQ, hd), lambda b, k, i, t: (b, k, 0, 0)),
        pl.BlockSpec((1, ps_loc, 1, hd),
                     lambda b, k, i, t: (t[b, i], 0, k, 0)),
        pl.BlockSpec((1, n_q, 1, ps_loc), lambda b, k, i, t: (b, 0, i, 0)),
    ]


def paged_attn_scores_max(q, k_pool, table, mask, *,
                          interpret: bool = False):
    """Pass 1 of fused paged attention: per-rank max masked score.

    q (B, KV, g*Q, hd): per-kv-head query groups, g-major (a (Q, ps_loc)
    mask block broadcasts over the group);  k_pool
    (n_pages, ps_loc, KV, hd): this rank's page-row pool;  table
    (B, n_lp) int32 (0 = scratch/unallocated);  mask
    (B, Q, n_lp, ps_loc) bool.  Returns m (B, KV, g*Q) f32 — the max
    masked score over this rank's pool rows, -inf where nothing is
    valid.  Callers pmax over tp and feed the safe max to
    `paged_attn_accumulate`.
    """
    B, KV, GQ, hd, ps_loc, n_lp, n_q, g = _check_shapes(q, k_pool, table,
                                                        mask)
    qspec, kspec, mspec = _qkm_specs(GQ, hd, ps_loc, n_q)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, n_lp),
        in_specs=[qspec, kspec, mspec],
        out_specs=[pl.BlockSpec((1, 1, GQ), lambda b, k, i, t: (b, k, 0))],
        scratch_shapes=[pltpu.VMEM((GQ, 1), jnp.float32)],
    )
    fn = pl.pallas_call(
        functools.partial(_max_kernel, n_lp=n_lp, g=g, n_q=n_q,
                          scale=hd ** -0.5),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, KV, GQ), jnp.float32)],
        interpret=interpret,
    )
    return fn(table.astype(jnp.int32), q, k_pool, mask)[0]


def paged_attn_accumulate(q, k_pool, v_pool, table, mask, m_safe, *,
                          interpret: bool = False):
    """Pass 2 of fused paged attention: accumulate against the global max.

    Same operands as `paged_attn_scores_max` plus v_pool (same shape as
    k_pool) and m_safe (B, KV, g*Q) f32 — the tp-global row max with
    -inf rows replaced by 0 (`jnp.where(isfinite(m), m, 0)`).  Returns
    LOCAL fp32 partials over this rank's pool rows:
      num (B, KV, g*Q, hd) = sum_s p * v   with p = exp(s - m_safe)
                             rounded to the pool dtype (the oracle's
                             convention)
      den (B, KV, g*Q)     = sum_s p in fp32
    Callers psum both over tp and normalize
    (models/layers.py::_paged_attention_core).
    """
    B, KV, GQ, hd, ps_loc, n_lp, n_q, g = _check_shapes(q, k_pool, table,
                                                        mask)
    assert v_pool.shape == k_pool.shape
    assert m_safe.shape == (B, KV, GQ), (m_safe.shape, q.shape)
    qspec, kspec, mspec = _qkm_specs(GQ, hd, ps_loc, n_q)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, n_lp),
        in_specs=[
            qspec, kspec,
            pl.BlockSpec((1, ps_loc, 1, hd),
                         lambda b, k, i, t: (t[b, i], 0, k, 0)),
            mspec,
            pl.BlockSpec((1, 1, GQ), lambda b, k, i, t: (b, k, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, GQ, hd), lambda b, k, i, t: (b, k, 0, 0)),
            pl.BlockSpec((1, 1, GQ), lambda b, k, i, t: (b, k, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((GQ, hd), jnp.float32),
                        pltpu.VMEM((GQ, 1), jnp.float32)],
    )
    fn = pl.pallas_call(
        functools.partial(_acc_kernel, n_lp=n_lp, g=g, n_q=n_q,
                          scale=hd ** -0.5),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, KV, GQ, hd), jnp.float32),
                   jax.ShapeDtypeStruct((B, KV, GQ), jnp.float32)],
        interpret=interpret,
    )
    num, den = fn(table.astype(jnp.int32), q, k_pool, v_pool, mask,
                  m_safe.reshape(B, KV, GQ))
    return num, den
