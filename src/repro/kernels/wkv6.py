"""Pallas TPU kernel: chunked WKV6 linear recurrence (RWKV6 "Finch").

The recurrence  S_t = diag(w_t) S_{t-1} + k_t v_t^T ;  y_t = (S_{t-1} +
diag(u k_t)) v_t)^T r_t  is sequential in t, but the (hd x hd) state lives
entirely in VMEM: the grid walks (batch*heads, T/chunk) with the state in
a VMEM scratch that persists across the sequential chunk dimension (TPU
grids execute in order), so HBM sees each r/k/v/w element exactly once —
the kernel is bandwidth-optimal for long_500k decode/prefill.

Inside a chunk the per-step update runs on VMEM-resident tiles via
fori_loop; hd=64 keeps every operand in registers/VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref,
            state_ref, *, chunk: int, n_chunks: int):
    t_idx = pl.program_id(1)

    @pl.when(t_idx == 0)
    def _init():
        state_ref[...] = s0_ref[0]

    u = u_ref[0]                                  # (hd,)

    def step(i, _):
        rt = r_ref[0, i]                          # (hd,)
        kt = k_ref[0, i]
        vt = v_ref[0, i]
        wt = w_ref[0, i]
        S = state_ref[...]                        # (hd, hd)
        kv = kt[:, None] * vt[None, :]
        y = ((S + u[:, None] * kv) * rt[:, None]).sum(axis=0)
        y_ref[0, i] = y.astype(y_ref.dtype)
        state_ref[...] = wt[:, None] * S + kv
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(t_idx == n_chunks - 1)
    def _done():
        sT_ref[0] = state_ref[...]


def wkv6_chunked(r, k, v, w, u, s0, *, chunk: int = 256,
                 interpret: bool = False):
    """r,k,v,w (BH, T, hd) fp32; u (BH, hd); s0 (BH, hd, hd).
    Returns (y (BH, T, hd), sT (BH, hd, hd))."""
    BH, T, hd = r.shape
    chunk = min(chunk, T)
    assert T % chunk == 0
    n_chunks = T // chunk
    grid = (BH, n_chunks)
    seq_spec = pl.BlockSpec((1, chunk, hd), lambda b, t: (b, t, 0))
    fn = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks),
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, hd), lambda b, t: (b, 0)),
                  pl.BlockSpec((1, hd, hd), lambda b, t: (b, 0, 0))],
        out_specs=[seq_spec,
                   pl.BlockSpec((1, hd, hd), lambda b, t: (b, 0, 0))],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        out_shape=[jax.ShapeDtypeStruct((BH, T, hd), r.dtype),
                   jax.ShapeDtypeStruct((BH, hd, hd), jnp.float32)],
        interpret=interpret,
    )
    y, sT = fn(r, k, v, w, u, s0)
    return y, sT
