"""Pallas TPU kernels: grouped (ragged) matmul — the paper's `group_gemm`
MoE hot path (§1.2), adapted to the TPU (DESIGN.md §3).

Two kernels live here:

1. `grouped_matmul_aligned` — a single grouped GEMM over a *pre-aligned*
   lhs (every group's rows start at a multiple of `bm`; the wrapper in
   ops.py materializes that layout).  tile_group (M/bm,) is
   scalar-prefetched so the rhs BlockSpec index_map can select the
   expert's weight tile *before* the tile runs — the TPU analogue of the
   CUDA grouped-GEMM pointer array.

2. `fused_moe_ffn` — the full MoE FFN pipeline in one kernel:
   gather token rows straight from the *unsorted* (T, d) activations via a
   per-tile row-index array, run the two (or three, gated) expert GEMMs
   with the (bm, ff) intermediate held tile-by-tile in VMEM, and
   accumulate `gate * out` back into the (T, d) output inside the kernel.
   Compared with composing three `grouped_matmul` calls around the Pallas
   wrapper, this removes every intermediate HBM round-trip: the aligned
   lhs copy, the (cap, ff) hidden activations, and the scatter-add
   combine buffer.  Gather/scatter are expressed as one-hot matmuls
   ((bm, T) @ (T, d) and its transpose), which the MXU executes natively —
   Mosaic has no general dynamic gather, and the one-hot form also keeps
   interpret mode pure-jnp.

   CAVEATS (the ROADMAP "TPU follow-up" items): the kernel keeps the
   full (T, d) input and fp32 output blocks resident, so real-hardware
   VMEM limits it to modest T until the output is T-tiled, and the
   one-hot gather/scatter costs 4*cap*T*d extra FLOPs — cheap at decode
   T, ~2x the FFN GEMMs at training T — until replaced by dynamic-slice
   DMA.  This is why core/moe.py only defaults to "fused" on interpret
   builds.

Dispatch-mode guidance (see core/moe.py for the model-level view; docs/
kernels.md for the tiling contract):
  * "fused"   — this pipeline; wins whenever the MoE FFN is HBM-bound
                (it always is at inference batch sizes, and at training
                shapes once d_ff is small relative to d, the fine-grained
                expert regime of §3.2.1).  Default at tp=1 on interpret
                builds.
  * "ragged"  — jax.lax.ragged_dot composition; exact dropless reference,
                but backends without a grouped-GEMM lowering compute it
                as E_loc dense GEMMs.  Default at tp=1 on real TPUs.
  * "batched" — per-expert capacity blocks + batched einsum; equal MXU
                tiles per expert, the right form when drops are bounded
                per-expert.  Default at tp>1 on real TPUs.
  * "ep"      — expert-parallel all-to-all dispatch (core/moe.py): tokens
                travel to the shard owning their expert and THIS fused
                kernel runs on each shard's expert slice over the received
                rows.  Default at tp>1 on interpret builds; the kernel is
                layout-oblivious — EP just feeds it (tp*cap, d) received
                rows instead of the rank's own (T, d).

All kernels use fp32 VMEM accumulators regardless of input dtype.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Mirrors models/layers._act for the activations the configs use; kernels
# must not import from models (layering).
_ACTS = {
    "swiglu": jax.nn.silu,
    "geglu": jax.nn.gelu,
    "gelu": jax.nn.gelu,
    "squared_relu": lambda x: jax.nn.relu(x) ** 2,
}
GATED_ACTS = ("swiglu", "geglu")


def _kernel(tile_group_ref, lhs_ref, rhs_ref, out_ref, acc_ref, *,
            n_k: int, n_groups: int):
    k_idx = pl.program_id(2)
    # program_id must be read at the top level: the interpret-mode
    # evaluator does not substitute it inside pl.when sub-jaxprs.
    i = pl.program_id(0)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        lhs_ref[...], rhs_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _done():
        gid = tile_group_ref[i]
        # overflow tiles (gid == n_groups) emit zeros
        valid = (gid < n_groups).astype(jnp.float32)
        out_ref[...] = (acc_ref[...] * valid).astype(out_ref.dtype)


def grouped_matmul_aligned(lhs: jax.Array, rhs: jax.Array,
                           tile_group: jax.Array, *,
                           bm: int = 128, bk: int = 128, bn: int = 128,
                           interpret: bool = False) -> jax.Array:
    """lhs (M, K) group-aligned; rhs (G, K, N); tile_group (M/bm,) int32
    (values in [0, G], G = overflow/zero tile).  Returns (M, N)."""
    M, K = lhs.shape
    G, K2, N = rhs.shape
    assert K == K2 and M % bm == 0 and K % bk == 0 and N % bn == 0
    n_m, n_n, n_k = M // bm, N // bn, K // bk
    # pad rhs with a zero overflow group so gid==G is addressable
    rhs_p = jnp.concatenate([rhs, jnp.zeros((1, K, N), rhs.dtype)], axis=0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_m, n_n, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k, tg: (i, k)),
            pl.BlockSpec((1, bk, bn), lambda i, j, k, tg: (tg[i], k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, tg: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, n_groups=G),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), lhs.dtype),
        interpret=interpret,
    )
    return fn(tile_group, lhs, rhs_p)


# ---------------------------------------------------------------------------
# Fused MoE FFN: gather -> grouped two-GEMM FFN -> weighted combine
# ---------------------------------------------------------------------------


def _one_hot_rows(idx, n_rows):
    """(bm,) int32 row indices -> (bm, n_rows) fp32 selection matrix.
    broadcasted_iota keeps the comparison 2D (a Mosaic requirement)."""
    iota = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], n_rows), 1)
    return (idx[:, None] == iota).astype(jnp.float32)


def _fused_kernel(tile_group_ref, row_idx_ref, gates_ref, x_ref,
                  w_refs, out_ref, x_tile_ref, acc_ref, *,
                  n_f: int, act: str, gated: bool):
    """Grid (n_m, n_f): m-tile outer, ff-tile inner.

    Per m-tile: gather bm token rows from x once (f == 0), stream the
    expert's w1/w3/w2 ff-tiles through VMEM accumulating the (bm, d)
    output, then scatter-add `gate * out` into the resident (T, d) output
    block on the last ff step.  The (bm, bf) hidden activations live only
    in registers/VMEM — they never touch HBM.
    """
    if gated:
        w1_ref, w3_ref, w2_ref = w_refs
    else:
        w1_ref, w2_ref = w_refs
        w3_ref = None
    i, f = pl.program_id(0), pl.program_id(1)
    T = x_ref.shape[0]
    act_fn = _ACTS[act]

    @pl.when((i == 0) & (f == 0))
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(f == 0)
    def _gather():
        oh = _one_hot_rows(row_idx_ref[0], T)           # (bm, T)
        x_tile_ref[...] = jax.lax.dot_general(
            oh, x_ref[...].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)         # (bm, d)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x_tile = x_tile_ref[...]
    h = jax.lax.dot_general(                            # (bm, bf)
        x_tile, w1_ref[0].astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    if gated:
        g3 = jax.lax.dot_general(
            x_tile, w3_ref[0].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        h = act_fn(h) * g3
    else:
        h = act_fn(h)
    acc_ref[...] += jax.lax.dot_general(                # (bm, d)
        h, w2_ref[0].astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(f == n_f - 1)
    def _combine():
        # invalid / overflow rows carry gate == 0, so clamped indices that
        # gathered an arbitrary real row contribute nothing.
        contrib = acc_ref[...] * gates_ref[0][:, None]
        oh = _one_hot_rows(row_idx_ref[0], T)           # (bm, T)
        out_ref[...] += jax.lax.dot_general(            # scatter-add (T, d)
            oh, contrib,
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


def fused_moe_ffn(x: jax.Array, w1: jax.Array, w2: jax.Array,
                  w3: jax.Array | None, row_idx: jax.Array,
                  gates: jax.Array, tile_group: jax.Array, *,
                  act: str = "swiglu", bf: int = 128,
                  interpret: bool = False) -> jax.Array:
    """Fused gather -> expert FFN -> weighted combine.

    x (T, d): unsorted token activations;  w1/w3 (G, d, ff), w2 (G, ff, d);
    row_idx (n_m, bm) int32: source token per padded dispatch row (clamped
    to [0, T) — masking is carried by `gates`);  gates (n_m, bm) fp32:
    router gate per row, 0 for padding/overflow;  tile_group (n_m,) int32:
    expert per row tile, G for all-padding tiles.  Returns (T, d) fp32 —
    the combined `sum_e gate * FFN_e(x)` partial.
    """
    T, d = x.shape
    G, d2, ff = w1.shape
    assert d == d2 and w2.shape == (G, ff, d) and ff % bf == 0
    n_m, bm = row_idx.shape
    n_f = ff // bf
    gated = w3 is not None

    # zero overflow expert so tile_group == G is addressable
    w1_p = jnp.concatenate([w1, jnp.zeros((1, d, ff), w1.dtype)], axis=0)
    w2_p = jnp.concatenate([w2, jnp.zeros((1, ff, d), w2.dtype)], axis=0)
    w_in = [w1_p]
    w_specs = [pl.BlockSpec((1, d, bf), lambda i, f, tg: (tg[i], 0, f))]
    if gated:
        w3_p = jnp.concatenate([w3, jnp.zeros((1, d, ff), w3.dtype)],
                               axis=0)
        w_in.append(w3_p)
        w_specs.append(
            pl.BlockSpec((1, d, bf), lambda i, f, tg: (tg[i], 0, f)))
    w_in.append(w2_p)
    w_specs.append(pl.BlockSpec((1, bf, d), lambda i, f, tg: (tg[i], f, 0)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_m, n_f),
        in_specs=[
            pl.BlockSpec((1, bm), lambda i, f, tg: (i, 0)),    # row_idx
            pl.BlockSpec((1, bm), lambda i, f, tg: (i, 0)),    # gates
            pl.BlockSpec((T, d), lambda i, f, tg: (0, 0)),     # x resident
            *w_specs,
        ],
        # the (T, d) output stays resident across the whole grid and is
        # accumulated in place — the combine never round-trips HBM.
        out_specs=pl.BlockSpec((T, d), lambda i, f, tg: (0, 0)),
        scratch_shapes=[pltpu.VMEM((bm, d), jnp.float32),
                        pltpu.VMEM((bm, d), jnp.float32)],
    )

    def kernel(tg_ref, ri_ref, g_ref, x_ref, *rest):
        *w_refs, out_ref, xt_ref, acc_ref = rest
        _fused_kernel(tg_ref, ri_ref, g_ref, x_ref, w_refs, out_ref,
                      xt_ref, acc_ref, n_f=n_f, act=act, gated=gated)

    fn = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, d), jnp.float32),
        interpret=interpret,
    )
    return fn(tile_group, row_idx, gates.astype(jnp.float32), x, *w_in)
