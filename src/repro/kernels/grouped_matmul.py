"""Pallas TPU kernel: grouped (ragged) matmul — the paper's `group_gemm`
MoE hot path (§1.2), adapted to the TPU (DESIGN.md §3).

Contract (Megablox-style, group-aligned):
  lhs (M, K): token rows sorted by expert, with every group's rows starting
  at a multiple of `bm` (the wrapper in ops.py produces this layout);
  rhs (G, K, N): per-expert weights;  tile_group (M/bm,): the expert id of
  each row tile (scalar-prefetched so the rhs BlockSpec index_map can
  select the expert's weight tile *before* the tile runs — this is the TPU
  analogue of the CUDA grouped-GEMM pointer array).

Grid = (M/bm, N/bn, K/bk), MXU-aligned tiles, fp32 VMEM accumulator that
is written back once on the last K step.  Rows whose tile maps to the
overflow group id G produce zeros (ragged_dot semantics).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(tile_group_ref, lhs_ref, rhs_ref, out_ref, acc_ref, *,
            n_k: int, n_groups: int):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        lhs_ref[...], rhs_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _done():
        i = pl.program_id(0)
        gid = tile_group_ref[i]
        # overflow tiles (gid == n_groups) emit zeros
        valid = (gid < n_groups).astype(jnp.float32)
        out_ref[...] = (acc_ref[...] * valid).astype(out_ref.dtype)


def grouped_matmul_aligned(lhs: jax.Array, rhs: jax.Array,
                           tile_group: jax.Array, *,
                           bm: int = 128, bk: int = 128, bn: int = 128,
                           interpret: bool = False) -> jax.Array:
    """lhs (M, K) group-aligned; rhs (G, K, N); tile_group (M/bm,) int32
    (values in [0, G], G = overflow/zero tile).  Returns (M, N)."""
    M, K = lhs.shape
    G, K2, N = rhs.shape
    assert K == K2 and M % bm == 0 and K % bk == 0 and N % bn == 0
    n_m, n_n, n_k = M // bm, N // bn, K // bk
    # pad rhs with a zero overflow group so gid==G is addressable
    rhs_p = jnp.concatenate([rhs, jnp.zeros((1, K, N), rhs.dtype)], axis=0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_m, n_n, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k, tg: (i, k)),
            pl.BlockSpec((1, bk, bn), lambda i, j, k, tg: (tg[i], k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, tg: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, n_groups=G),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), lhs.dtype),
        interpret=(pltpu.InterpretParams()
                   if interpret else False),
    )
    return fn(tile_group, lhs, rhs_p)
