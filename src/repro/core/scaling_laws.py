"""Scaling-law machinery (paper §3.3, C7).

Three pieces, as in the paper:

  1. **Hyper-parameter scaling** (§3.3.1): power-law fits of optimal batch
     size B(C) and learning rate eta(C) against compute budget C from grid
     -search results — `fit_power_law` + `HyperParamLaw`.
  2. **Loss scaling** (§3.3.2): the "logarithmic inverse" FLOPs-to-loss
     curve  L(C) = a * C^(-b) + L_inf  fitted per architecture family.
  3. **Efficiency lever**: the ratio of compute budgets at which MoE and
     dense reach the SAME loss; the paper reports ~3x, growing with C.

`run_grid` actually trains small models (via a caller-supplied train
function) so the benchmark regenerates Figure 12/13-shaped data on CPU;
the fitting code is exact and unit-tested on synthetic power laws.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# fits
# ---------------------------------------------------------------------------


def fit_power_law(x: Sequence[float], y: Sequence[float]
                  ) -> Tuple[float, float]:
    """y = A * x^alpha  ->  (A, alpha), least squares in log space."""
    lx, ly = np.log(np.asarray(x, float)), np.log(np.asarray(y, float))
    alpha, loga = np.polyfit(lx, ly, 1)
    return float(np.exp(loga)), float(alpha)


@dataclasses.dataclass
class HyperParamLaw:
    """B(C) = Ab * C^ab ;  eta(C) = Ae * C^ae   (Figure 12)."""
    batch_coef: float
    batch_exp: float
    lr_coef: float
    lr_exp: float

    @classmethod
    def fit(cls, compute: Sequence[float], best_batch: Sequence[float],
            best_lr: Sequence[float]) -> "HyperParamLaw":
        ab, eb = fit_power_law(compute, best_batch)
        al, el = fit_power_law(compute, best_lr)
        return cls(ab, eb, al, el)

    def batch(self, c: float) -> float:
        return self.batch_coef * c ** self.batch_exp

    def lr(self, c: float) -> float:
        return self.lr_coef * c ** self.lr_exp


@dataclasses.dataclass
class LossLaw:
    """L(C) = a * C^(-b) + L_inf (saturating power law)."""
    a: float
    b: float
    l_inf: float

    def __call__(self, c):
        return self.a * np.asarray(c, float) ** (-self.b) + self.l_inf

    def inverse(self, loss: float) -> float:
        """Compute budget needed to reach `loss`."""
        if loss <= self.l_inf:
            return math.inf
        return ((loss - self.l_inf) / self.a) ** (-1.0 / self.b)

    @classmethod
    def fit(cls, compute: Sequence[float], loss: Sequence[float],
            l_inf_grid: Optional[Sequence[float]] = None) -> "LossLaw":
        c = np.asarray(compute, float)
        y = np.asarray(loss, float)
        best = None
        grid = (np.asarray(l_inf_grid) if l_inf_grid is not None
                else np.linspace(0.0, y.min() * 0.999, 40))
        for _refine in range(3):
            for l_inf in grid:
                resid = y - l_inf
                if (resid <= 0).any():
                    continue
                A, alpha = fit_power_law(c, resid)
                pred = A * c ** alpha + l_inf
                err = float(np.mean((pred - y) ** 2))
                if best is None or err < best[0]:
                    best = (err, A, -alpha, l_inf)
            if best is None:
                break
            step = (grid[1] - grid[0]) if len(grid) > 1 else 0.01
            lo = max(best[3] - step, 0.0)
            grid = np.linspace(lo, min(best[3] + step, y.min() * 0.999), 40)
        assert best is not None, "loss-law fit failed"
        _, A, b, l_inf = best
        return cls(A, b, l_inf)


def efficiency_lever(moe: LossLaw, dense: LossLaw, compute: float) -> float:
    """Compute ratio dense/MoE to reach the loss the MoE reaches at
    `compute` (the paper's ~3x lever, Figure 13)."""
    target = float(moe(compute))
    dense_needed = dense.inverse(target)
    return dense_needed / compute


# ---------------------------------------------------------------------------
# grid runner (used by the scaling-law benchmark to produce real data)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GridResult:
    compute: float
    batch: int
    lr: float
    final_loss: float


def run_grid(train_once: Callable[[int, float, float], float],
             compute_budgets: Sequence[float],
             batches: Sequence[int], lrs: Sequence[float]
             ) -> List[GridResult]:
    """train_once(batch, lr, compute) -> final loss."""
    out = []
    for c in compute_budgets:
        for b in batches:
            for lr in lrs:
                out.append(GridResult(c, b, lr, train_once(b, lr, c)))
    return out


def best_per_budget(results: Sequence[GridResult]
                    ) -> Tuple[List[float], List[float], List[float],
                               List[float]]:
    by_c: Dict[float, GridResult] = {}
    for r in results:
        if r.compute not in by_c or r.final_loss < by_c[r.compute].final_loss:
            by_c[r.compute] = r
    cs = sorted(by_c)
    return (cs, [by_c[c].batch for c in cs], [by_c[c].lr for c in cs],
            [by_c[c].final_loss for c in cs])
