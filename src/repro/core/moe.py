"""Fine-grained Mixture-of-Experts FFN (paper §3.2.1, C1).

Design (TPU adaptation of the paper's group_gemm hot path — see DESIGN.md §3):

* Routed experts are **expert-parallel over the tp ('model') axis**: rank r
  owns experts [r*E_l, (r+1)*E_l).  Activations entering the FFN are full
  per-dp-shard (replicated over tp, the Megatron layout), so no token
  all-to-all is required — each rank computes its experts' contribution for
  all of its dp-shard's tokens and the combine is the same reduce-scatter
  every TP block already performs.
* Within a rank the expert compute runs in one of three dispatch modes
  (see `moe_ffn`):

  - "fused" (default at tp=1): the whole gather -> grouped two-GEMM FFN ->
    gate-weighted combine runs as ONE Pallas kernel
    (`kernels/grouped_matmul.fused_moe_ffn`).  No aligned-lhs relayout, no
    (cap, ff) HBM intermediate, no separate scatter-add — the paper's
    `group_gemm` hot path with dispatch/combine fused in, which is where
    DeepSpeed-MoE-style systems win MoE step time.  The backward pass is a
    custom-vjp that recomputes through the mathematically identical ragged
    composition (the kernel itself is forward-only).
  - "ragged": token slots sorted by local expert id + `jax.lax.ragged_dot`.
    Exactly dropless at tp=1 and fully differentiable end-to-end, but XLA
    backends without a grouped-GEMM lowering compute it as E_loc dense
    GEMMs — the E_loc x FLOP waste the kernel exists to remove.
  - "batched": per-expert capacity blocks + plain batched einsum — equal
    MXU tiles per expert; the right form at tp>1 where drops are bounded
    per-expert anyway.

  With tp=1 the buffer holds all T*k slots — exactly the paper's
  *dropless* routing.  With tp>1 each rank's buffer is
  ceil(T*k/tp * capacity_factor): the Stochastic Routing Warmup plus the
  balance loss keep expert load near-uniform, so cf=2.0 drops ~nothing
  (tracked by the `moe/dropped_frac` metric).
* The always-on **shared expert** (Eq. 2) is an ordinary tensor-parallel
  FFN fused into the same partial-sum.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding
from repro.core import router as router_lib
from repro.kernels import ops as kops
from repro.models import layers as L
from repro.sharding import AxisEnv, fsdp_spec, pad_to_multiple


def padded_experts(cfg, env: AxisEnv) -> Tuple[int, int]:
    """(E_padded, E_local): experts padded to a multiple of tp (dummy
    experts are never routed to — e.g. granite's 40 experts on tp=16)."""
    ep = pad_to_multiple(cfg.moe.n_experts, env.tp)
    return ep, ep // env.tp


def capacity(cfg, env: AxisEnv, n_tokens: int) -> int:
    """Static per-rank dispatch-buffer rows."""
    m = cfg.moe
    slots = n_tokens * m.top_k
    if env.tp == 1:
        return slots                       # dropless
    cap = int(slots * m.capacity_factor / env.tp)
    cap = min(pad_to_multiple(max(cap, 8), 8), slots)
    return cap


def init_moe(key, cfg, env: AxisEnv):
    m = cfg.moe
    d = cfg.d_model
    ep, _ = padded_experts(cfg, env)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    out_scale = 0.02 / max(cfg.n_layers, 1) ** 0.5

    params: Dict = {}
    specs: Dict = {}
    params["router"], specs["router"] = router_lib.init_router(ks[0], cfg, env)
    # routed expert weights: (E_pad, d, ff_e) — experts over tp, FSDP over d
    params["we1"] = L.dense_init(ks[1], (ep, d, m.expert_d_ff), dt)
    params["we2"] = L.dense_init(ks[2], (ep, m.expert_d_ff, d), dt, out_scale)
    specs["we1"] = fsdp_spec(env, 3, 1, 0)
    specs["we2"] = fsdp_spec(env, 3, 2, 0)
    if cfg.mlp_act in L.GATED_ACTS:
        params["we3"] = L.dense_init(ks[3], (ep, d, m.expert_d_ff), dt)
        specs["we3"] = fsdp_spec(env, 3, 1, 0)
    if m.n_shared_experts > 0:
        params["shared"], specs["shared"] = L.init_mlp(
            ks[4], cfg, env, d_ff=m.shared_ff, scale_out=out_scale)
    return params, specs


def grouped_ffn(cfg, w1, w2, w3, xs, group_sizes):
    """Grouped expert FFN over expert-sorted rows.

    xs (cap, d), w* (E_l, d, ff)/(E_l, ff, d), group_sizes (E_l,).
    Rows beyond sum(group_sizes) produce zeros (ragged_dot semantics).
    This is the compute the `kernels/grouped_matmul` Pallas kernel targets.
    """
    h = jax.lax.ragged_dot(xs, w1, group_sizes)
    if cfg.mlp_act in L.GATED_ACTS:
        h = L._act(cfg.mlp_act, h) * jax.lax.ragged_dot(xs, w3, group_sizes)
    else:
        h = L._act(cfg.mlp_act, h)
    return jax.lax.ragged_dot(h, w2, group_sizes)


def _fused_ragged_ref(act, x, w1, w2, w3, tok, gate, group_sizes):
    """Differentiable ragged-dot composition with the exact same math as
    the fused kernel (fp32 accumulation): gather -> FFN -> gated combine.
    Used as the custom-vjp backward of `fused_ffn` and as the exact-parity
    fallback when the fused path is unavailable."""
    T, d = x.shape
    xs = jnp.take(x, tok, axis=0).astype(jnp.float32)
    w1f, w2f = w1.astype(jnp.float32), w2.astype(jnp.float32)
    h = jax.lax.ragged_dot(xs, w1f, group_sizes)
    if w3 is not None:
        h = L._act(act, h) * jax.lax.ragged_dot(
            xs, w3.astype(jnp.float32), group_sizes)
    else:
        h = L._act(act, h)
    out = jax.lax.ragged_dot(h, w2f, group_sizes)      # rows past sum() = 0
    out = out * gate.astype(jnp.float32)[:, None]
    return jnp.zeros((T, d), jnp.float32).at[tok].add(out)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def fused_ffn(act, x, w1, w2, w3, tok, gate, group_sizes):
    """Fused MoE FFN dispatch (forward: one Pallas kernel; backward:
    recompute through the identical ragged composition)."""
    return kops.moe_fused_ffn(x, w1, w2, w3, tok, gate, group_sizes,
                              act=act)


def _fused_ffn_fwd(act, x, w1, w2, w3, tok, gate, group_sizes):
    out = kops.moe_fused_ffn(x, w1, w2, w3, tok, gate, group_sizes,
                             act=act)
    return out, (x, w1, w2, w3, tok, gate, group_sizes)


def _fused_ffn_bwd(act, res, g):
    x, w1, w2, w3, tok, gate, group_sizes = res
    has_w3 = w3 is not None

    def f(x, w1, w2, gate, *maybe_w3):
        w3_ = maybe_w3[0] if maybe_w3 else None
        return _fused_ragged_ref(act, x, w1, w2, w3_, tok, gate,
                                 group_sizes)

    diff_args = (x, w1, w2, gate) + ((w3,) if has_w3 else ())
    _, pull = jax.vjp(f, *diff_args)
    grads = pull(g.astype(jnp.float32))
    dx, dw1, dw2, dgate = grads[:4]
    dw3 = grads[4] if has_w3 else None
    int_zero = lambda a: np.zeros(a.shape, jax.dtypes.float0)
    return (dx.astype(x.dtype), dw1.astype(w1.dtype), dw2.astype(w2.dtype),
            dw3.astype(w3.dtype) if has_w3 else None,
            int_zero(tok), dgate.astype(gate.dtype), int_zero(group_sizes))


fused_ffn.defvjp(_fused_ffn_fwd, _fused_ffn_bwd)


def expert_capacity(cfg, env: AxisEnv, n_tokens: int) -> int:
    """Per-EXPERT dispatch rows for the batched path (global semantics:
    C_e = T*k*cf/E, so total rows match the per-rank ragged capacity)."""
    m = cfg.moe
    ep, _ = padded_experts(cfg, env)
    c = int(n_tokens * m.top_k * m.capacity_factor / m.n_experts)
    return min(pad_to_multiple(max(c, 8), 8), n_tokens * m.top_k)


def moe_ffn(cfg, env: AxisEnv, params, x: jax.Array, *,
            step: Optional[jax.Array] = None,
            rng: Optional[jax.Array] = None,
            train: bool = True,
            dispatch: str = "auto"):
    """x (T, d) full per dp-shard -> (partial (T, d), aux_loss, metrics).

    The partial output must be combined over tp by the caller (sp_scatter),
    exactly like a row-parallel dense FFN.

    dispatch:
      "fused"   ONE Pallas kernel for gather -> grouped two-GEMM FFN ->
                gate-weighted combine (kernels/grouped_matmul.fused_moe_ffn):
                no aligned-lhs relayout, no (cap, ff) HBM round-trip, no
                separate scatter-add; fp32 accumulation throughout.
                Same dropless/capacity semantics as "ragged".
      "ragged"  sort + jax.lax.ragged_dot (exactly dropless at tp=1; XLA
                without a grouped-gemm lowering computes it as a dense
                batched dot over local experts — E_loc x FLOP waste);
      "batched" per-expert-capacity blocks + plain batched einsum — the
                TPU-native form (equal MXU tiles per expert, no waste);
                drops are bounded per-expert instead of per-rank;
      "auto"    tp>1: batched.  tp=1: fused where validated (interpret
                builds), ragged on real TPU hardware until the fused
                kernel tiles its (T, d) blocks (ROADMAP follow-up).
    """
    m = cfg.moe
    T, d = x.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    ep, e_loc = padded_experts(cfg, env)
    cap = capacity(cfg, env, T)
    if dispatch == "auto":
        # fused is the tp=1 default where the pipeline is validated
        # (interpret mode).  On real TPUs the kernel as written keeps the
        # full (T, d) in/out blocks VMEM-resident, which does not fit at
        # training shapes — stay on ragged there until the ROADMAP tile
        # sweep (T-tiled output + DMA gather) lands.
        if env.tp > 1:
            dispatch = "batched"
        else:
            dispatch = "fused" if kops.INTERPRET else "ragged"
    if dispatch not in ("fused", "ragged", "batched"):
        raise ValueError(f"unknown moe dispatch mode: {dispatch!r}")

    top_w, top_i, aux, metrics = router_lib.route(
        cfg, env, params["router"], x, step=step, rng=rng, train=train)

    # ---- local dispatch: sort token-slots by (local) expert --------------
    r = env.tp_index()
    lo = r * e_loc
    flat_i = top_i.reshape(-1)                     # (T*k,)
    flat_w = top_w.reshape(-1)
    local_key = flat_i - lo
    is_local = (local_key >= 0) & (local_key < e_loc)
    sort_key = jnp.where(is_local, local_key, e_loc)   # non-local last
    order = jnp.argsort(sort_key)                  # stable

    w1 = env.gather_fsdp(params["we1"], 1, dtype=cdt)
    w2 = env.gather_fsdp(params["we2"], 2, dtype=cdt)
    w3 = (env.gather_fsdp(params["we3"], 1, dtype=cdt)
          if "we3" in params else None)

    if dispatch in ("ragged", "fused"):
        sel = order[:cap]                          # (cap,) slot indices
        tok = sel // m.top_k                       # token per slot
        skey = sort_key[sel]                       # sorted expert keys
        valid = skey < e_loc
        # rows per local expert (only rows that made it into the buffer)
        group_sizes = jnp.sum(
            jax.nn.one_hot(jnp.where(valid, skey, e_loc), e_loc + 1,
                           dtype=jnp.int32)[:, :e_loc], axis=0)
        gates = (flat_w[sel] * valid).astype(cdt)
        if dispatch == "fused":
            y = fused_ffn(cfg.mlp_act, x.astype(cdt), w1, w2, w3, tok,
                          gates, group_sizes).astype(cdt)
        else:
            xs = jnp.take(x, tok, axis=0).astype(cdt)  # (cap, d) gather
            out = grouped_ffn(cfg, w1, w2, w3, xs, group_sizes)  # (cap, d)
            y = jnp.zeros((T, d), cdt).at[tok].add(out * gates[:, None])
        n_kept = jnp.sum(valid)
    else:
        # per-expert-capacity batched dispatch: expert e's rows live at
        # sorted positions [offset_e, offset_e + count_e); clip to C_e and
        # lay them out as (E_loc, C_e, d) so the expert FFN is a plain
        # batched einsum — equal MXU tiles per expert, no E_loc x dense
        # waste, and the combine stays a scatter-add.
        c_e = expert_capacity(cfg, env, T)
        counts = jnp.sum(
            jax.nn.one_hot(jnp.where(is_local, local_key, e_loc), e_loc + 1,
                           dtype=jnp.int32)[:, :e_loc], axis=0)   # (E_loc,)
        offsets = jnp.cumsum(counts) - counts
        slot_idx = offsets[:, None] + jnp.arange(c_e)[None, :]    # (E,C)
        slot_valid = jnp.arange(c_e)[None, :] < jnp.minimum(counts, c_e)[:, None]
        slot = jnp.take(order, jnp.clip(slot_idx, 0, order.shape[0] - 1))
        tok_e = slot // m.top_k                                   # (E,C)
        xs = jnp.take(x, tok_e.reshape(-1), axis=0).astype(cdt)
        xs = xs.reshape(e_loc, c_e, d)
        h = jnp.einsum("ecd,edf->ecf", xs, w1)
        if cfg.mlp_act in L.GATED_ACTS:
            h = L._act(cfg.mlp_act, h) * jnp.einsum("ecd,edf->ecf", xs, w3)
        else:
            h = L._act(cfg.mlp_act, h)
        out = jnp.einsum("ecf,efd->ecd", h, w2)                   # (E,C,d)
        gates = (jnp.take(flat_w, slot.reshape(-1)).reshape(e_loc, c_e)
                 * slot_valid).astype(cdt)
        y = jnp.zeros((T, d), cdt).at[tok_e.reshape(-1)].add(
            (out * gates[..., None]).reshape(-1, d))
        n_kept = jnp.sum(jnp.minimum(counts, c_e))

    # dropped-token telemetry (paper: dropless; cf headroom makes this ~0)
    n_local = jnp.sum(is_local)
    dropped = jnp.maximum(n_local - n_kept, 0)
    metrics["moe/dropped_frac"] = env.pmean_dp(
        env.psum_tp(dropped.astype(jnp.float32))
        / jnp.maximum(env.psum_tp(n_local.astype(jnp.float32)), 1.0))

    # ---- shared expert (Eq. 2): dense TP FFN fused into the partial ------
    if m.n_shared_experts > 0:
        y = y + L.apply_mlp(cfg, env, params["shared"], x.astype(cdt))

    return y, aux, metrics
