"""Fine-grained Mixture-of-Experts FFN (paper §3.2.1, C1).

Design (TPU adaptation of the paper's group_gemm hot path — see DESIGN.md §3):

* Routed experts are **expert-sharded over the tp ('model') axis**: rank r
  owns experts [r*E_l, (r+1)*E_l) (`sharding.ep_spec` layout).  Two token
  layouts feed those shards:

  - the *Megatron* layout ("fused"/"ragged"/"batched"): activations
    entering the FFN are full per-dp-shard (replicated over tp), so no
    token all-to-all is required — each rank computes its experts'
    contribution for all of its dp-shard's tokens and the combine is the
    same reduce-scatter every TP block already performs.  Zero extra
    communication, but every rank touches every token.
  - the *expert-parallel* layout ("ep"): rank r owns the r-th T/tp token
    slice, routes only those tokens, and two `all_to_all`s move each
    routed slot to the shard that owns its expert and its FFN output back
    (DeepSpeed-MoE / GShard style).  Per-token FFN compute happens exactly
    once in the whole tp group instead of being replicated tp times.

* Dispatch-mode matrix (`moe_ffn(dispatch=...)`; "auto" resolves via the
  per-arch `MoEConfig.dispatch` knob, then the defaults below):

  mode       default where            expert compute               comm
  "fused"    tp=1, interpret builds   ONE Pallas kernel            none
                                      (kernels/grouped_matmul.
                                      fused_moe_ffn): gather ->
                                      grouped two-GEMM FFN ->
                                      gated combine, custom-vjp
                                      ragged-recompute backward
  "ragged"   tp=1, real TPUs (until   sort + jax.lax.ragged_dot;   none
             the ROADMAP tile sweep)  exactly dropless at tp=1
  "batched"  tp>1, real TPUs          per-expert capacity blocks   none
                                      + batched einsum (equal MXU
                                      tiles per expert)
  "ep"       tp>1, interpret builds   token all_to_all -> local    2 (+1
                                      fused/ragged FFN on the      bwd pair)
                                      shard's expert slice ->      all_to_all
                                      combine all_to_all back      over tp

  Capacity semantics: tp=1 buffers hold all T*k slots — exactly the
  paper's *dropless* routing.  "batched"/"ragged" at tp>1 bound the
  per-rank buffer by ceil(T*k/tp * capacity_factor); "ep" bounds each
  (source, destination) shard-pair buffer by `ep_capacity` and drops
  deterministically (earliest slots win).  The Stochastic Routing Warmup
  plus the balance loss keep expert load near-uniform, so cf=2.0 drops
  ~nothing (tracked by the `moe/dropped_frac` metric).
* The always-on **shared expert** (Eq. 2) is an ordinary tensor-parallel
  FFN fused into the same partial-sum in every mode.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding
from repro.core import router as router_lib
from repro.kernels import ops as kops
from repro.models import layers as L
from repro.sharding import AxisEnv, ep_spec, pad_to_multiple


def padded_experts(cfg, env: AxisEnv) -> Tuple[int, int]:
    """(E_padded, E_local): experts padded to a multiple of tp (dummy
    experts are never routed to — e.g. granite's 40 experts on tp=16)."""
    ep = pad_to_multiple(cfg.moe.n_experts, env.tp)
    return ep, ep // env.tp


def capacity(cfg, env: AxisEnv, n_tokens: int) -> int:
    """Static per-rank dispatch-buffer rows."""
    m = cfg.moe
    slots = n_tokens * m.top_k
    if env.tp == 1:
        return slots                       # dropless
    cap = int(slots * m.capacity_factor / env.tp)
    cap = min(pad_to_multiple(max(cap, 8), 8), slots)
    return cap


def ep_capacity(cfg, env: AxisEnv, n_tokens_local: int) -> int:
    """Static rows per (source, destination) shard pair in the EP token
    exchange.  Balanced routing sends T_loc*k/tp slots from each source
    to each destination; `capacity_factor` is the headroom over that mean.
    Slots past the pair capacity are dropped *at the source*, earliest
    slots (token order) win — deterministic for a given routing.  tp=1
    degenerates to the dropless T*k buffer.  Same formula as the per-rank
    `capacity`, just fed the rank's owned token count."""
    return capacity(cfg, env, n_tokens_local)


def init_moe(key, cfg, env: AxisEnv):
    m = cfg.moe
    d = cfg.d_model
    ep, _ = padded_experts(cfg, env)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    out_scale = 0.02 / max(cfg.n_layers, 1) ** 0.5

    params: Dict = {}
    specs: Dict = {}
    params["router"], specs["router"] = router_lib.init_router(ks[0], cfg, env)
    # routed expert weights: (E_pad, d, ff_e) — experts over tp, FSDP over d
    params["we1"] = L.dense_init(ks[1], (ep, d, m.expert_d_ff), dt)
    params["we2"] = L.dense_init(ks[2], (ep, m.expert_d_ff, d), dt, out_scale)
    specs["we1"] = ep_spec(env, 3, 1, 0)
    specs["we2"] = ep_spec(env, 3, 2, 0)
    if cfg.mlp_act in L.GATED_ACTS:
        params["we3"] = L.dense_init(ks[3], (ep, d, m.expert_d_ff), dt)
        specs["we3"] = ep_spec(env, 3, 1, 0)
    if m.n_shared_experts > 0:
        params["shared"], specs["shared"] = L.init_mlp(
            ks[4], cfg, env, d_ff=m.shared_ff, scale_out=out_scale)
    return params, specs


def grouped_ffn(cfg, w1, w2, w3, xs, group_sizes):
    """Grouped expert FFN over expert-sorted rows.

    xs (cap, d), w* (E_l, d, ff)/(E_l, ff, d), group_sizes (E_l,).
    Rows beyond sum(group_sizes) produce zeros (ragged_dot semantics).
    This is the compute the `kernels/grouped_matmul` Pallas kernel targets.
    """
    h = jax.lax.ragged_dot(xs, w1, group_sizes)
    if cfg.mlp_act in L.GATED_ACTS:
        h = L._act(cfg.mlp_act, h) * jax.lax.ragged_dot(xs, w3, group_sizes)
    else:
        h = L._act(cfg.mlp_act, h)
    return jax.lax.ragged_dot(h, w2, group_sizes)


def _fused_ragged_ref(act, x, w1, w2, w3, tok, gate, group_sizes):
    """Differentiable ragged-dot composition with the exact same math as
    the fused kernel (fp32 accumulation): gather -> FFN -> gated combine.
    Used as the custom-vjp backward of `fused_ffn` and as the exact-parity
    fallback when the fused path is unavailable."""
    T, d = x.shape
    xs = jnp.take(x, tok, axis=0).astype(jnp.float32)
    w1f, w2f = w1.astype(jnp.float32), w2.astype(jnp.float32)
    h = jax.lax.ragged_dot(xs, w1f, group_sizes)
    if w3 is not None:
        h = L._act(act, h) * jax.lax.ragged_dot(
            xs, w3.astype(jnp.float32), group_sizes)
    else:
        h = L._act(act, h)
    out = jax.lax.ragged_dot(h, w2f, group_sizes)      # rows past sum() = 0
    out = out * gate.astype(jnp.float32)[:, None]
    return jnp.zeros((T, d), jnp.float32).at[tok].add(out)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def fused_ffn(act, x, w1, w2, w3, tok, gate, group_sizes):
    """Fused MoE FFN dispatch (forward: one Pallas kernel; backward:
    recompute through the identical ragged composition)."""
    return kops.moe_fused_ffn(x, w1, w2, w3, tok, gate, group_sizes,
                              act=act)


def _fused_ffn_fwd(act, x, w1, w2, w3, tok, gate, group_sizes):
    out = kops.moe_fused_ffn(x, w1, w2, w3, tok, gate, group_sizes,
                             act=act)
    return out, (x, w1, w2, w3, tok, gate, group_sizes)


def _fused_ffn_bwd(act, res, g):
    x, w1, w2, w3, tok, gate, group_sizes = res
    has_w3 = w3 is not None

    def f(x, w1, w2, gate, *maybe_w3):
        w3_ = maybe_w3[0] if maybe_w3 else None
        return _fused_ragged_ref(act, x, w1, w2, w3_, tok, gate,
                                 group_sizes)

    diff_args = (x, w1, w2, gate) + ((w3,) if has_w3 else ())
    _, pull = jax.vjp(f, *diff_args)
    grads = pull(g.astype(jnp.float32))
    dx, dw1, dw2, dgate = grads[:4]
    dw3 = grads[4] if has_w3 else None
    int_zero = lambda a: np.zeros(a.shape, jax.dtypes.float0)
    return (dx.astype(x.dtype), dw1.astype(w1.dtype), dw2.astype(w2.dtype),
            dw3.astype(w3.dtype) if has_w3 else None,
            int_zero(tok), dgate.astype(gate.dtype), int_zero(group_sizes))


fused_ffn.defvjp(_fused_ffn_fwd, _fused_ffn_bwd)


def _ep_moe_ffn(cfg, env: AxisEnv, params, x, w1, w2, w3, *,
                step, rng, train):
    """Expert-parallel dispatch: route owned tokens, all-to-all them to
    their experts' shards, run the local fused FFN, all-to-all back.

    x (T, d) replicated over tp (the SP-gathered block activation).  Rank
    r *owns* the r-th T/tp slice: only that slice is routed here, so the
    per-token expert FFN runs exactly once across the tp group (vs tp
    times in the Megatron-layout modes).  Returns (y (T, d) with only the
    owned slice non-zero — the caller's psum/reduce-scatter assembles the
    full tensor — plus aux, metrics, n_kept, n_slots for the shared
    telemetry tail of `moe_ffn`).

    Pipeline per rank:
      1. route the owned slice (aux stats pmean over dp AND tp — parity
         with the tp=1 aux over the full batch);
      2. bucket routed slots by destination shard (stable sort: earliest
         slots win the `ep_capacity` pair budget — deterministic drops);
      3. all_to_all the token payload + local-expert keys
         (`kernels/ops.ep_all_to_all`: custom-vjp, so the backward is the
         transposed all-to-all, never a recompute);
      4. sort received rows by local expert and run the shard's expert
         slice through the fused Pallas FFN (`fused_ffn`, gate=1) —
         ragged composition on real TPUs until the ROADMAP tile sweep;
      5. all_to_all the per-slot FFN outputs back and scatter-add
         `gate * out` into the owned slice (gates stay on the source
         side: the return payload is gate-free, keeping the combine
         numerics identical to the tp=1 fused path).
    """
    m = cfg.moe
    T, d = x.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    tp = env.tp
    _, e_loc = padded_experts(cfg, env)
    T_loc = T // tp
    r = env.tp_index()
    x_loc = jax.lax.dynamic_slice_in_dim(x, r * T_loc, T_loc, axis=0)

    # --- 1. route the owned slice (rank-decorrelated warmup noise) --------
    rng_ep = jax.random.fold_in(rng, r) if rng is not None else None
    top_w, top_i, aux, metrics = router_lib.route(
        cfg, env, params["router"], x_loc, step=step, rng=rng_ep,
        train=train, ep=True)

    # --- 2. bucket slots by destination shard -----------------------------
    S = T_loc * m.top_k
    cap = ep_capacity(cfg, env, T_loc)
    flat_i = top_i.reshape(-1)                     # (S,) global expert ids
    flat_w = top_w.reshape(-1)
    dest = flat_i // e_loc                         # owning shard per slot
    lkey = flat_i - dest * e_loc                   # local expert there
    order = jnp.argsort(dest)                      # stable: token order
    sorted_dest = jnp.take(dest, order)
    # per-destination counts/offsets via binary search over the sorted
    # keys (O(tp log S), no (S, tp) one-hot intermediate — same pattern
    # as kernels/ops._align_groups)
    ids = jnp.arange(tp)
    offsets = jnp.searchsorted(sorted_dest, ids, side="left")
    counts = (jnp.searchsorted(sorted_dest, ids, side="right")
              - offsets).astype(jnp.int32)
    pos = offsets[:, None] + jnp.arange(cap)[None, :]         # (tp, cap)
    pos_valid = (jnp.arange(cap)[None, :]
                 < jnp.minimum(counts, cap)[:, None])
    slot = jnp.take(order, jnp.clip(pos, 0, S - 1))           # (tp, cap)
    tok_send = slot // m.top_k                     # owned-token per slot
    x_send = jnp.take(x_loc, tok_send.reshape(-1), axis=0
                      ).reshape(tp, cap, d).astype(cdt)
    key_send = jnp.where(pos_valid, jnp.take(lkey, slot),
                         e_loc).astype(jnp.int32)

    # --- 3. token + count exchange (bf16 payload, int32 keys) -------------
    x_recv = kops.ep_all_to_all(x_send, axis_name=env.tp_axis)
    key_recv = env.all_to_all_tp(key_send)         # int: no grad needed

    # --- 4. local expert FFN over received rows ---------------------------
    R = tp * cap
    keys = key_recv.reshape(-1)                    # (R,) in [0, e_loc]
    order2 = jnp.argsort(keys)                     # stable; invalid last
    skey = jnp.take(keys, order2)
    valid2 = skey < e_loc
    eids = jnp.arange(e_loc)
    group_sizes = (jnp.searchsorted(skey, eids, side="right")
                   - jnp.searchsorted(skey, eids, side="left")
                   ).astype(jnp.int32)
    xr = x_recv.reshape(R, d)
    ones = valid2.astype(cdt)                      # gate=1: gates stay home
    if kops.INTERPRET:
        y_r = fused_ffn(cfg.mlp_act, xr, w1, w2, w3, order2, ones,
                        group_sizes)
    else:
        xs = jnp.take(xr, order2, axis=0)
        out = grouped_ffn(cfg, w1, w2, w3, xs, group_sizes)
        y_r = jnp.zeros((R, d), jnp.float32).at[order2].add(
            out.astype(jnp.float32) * ones.astype(jnp.float32)[:, None])

    # --- 5. combine exchange + gated scatter into the owned slice ---------
    y_back = kops.ep_all_to_all(y_r.astype(cdt).reshape(tp, cap, d),
                                axis_name=env.tp_axis)
    gates = jnp.where(pos_valid, jnp.take(flat_w, slot), 0.0).astype(cdt)
    y_loc = jnp.zeros((T_loc, d), cdt).at[tok_send.reshape(-1)].add(
        y_back.reshape(R, d) * gates.reshape(R)[:, None])
    y = jax.lax.dynamic_update_slice_in_dim(
        jnp.zeros((T, d), cdt), y_loc, r * T_loc, axis=0)
    n_kept = jnp.sum(pos_valid)
    return y, aux, metrics, n_kept, jnp.int32(S)


def expert_capacity(cfg, env: AxisEnv, n_tokens: int) -> int:
    """Per-EXPERT dispatch rows for the batched path (global semantics:
    C_e = T*k*cf/E, so total rows match the per-rank ragged capacity)."""
    m = cfg.moe
    ep, _ = padded_experts(cfg, env)
    c = int(n_tokens * m.top_k * m.capacity_factor / m.n_experts)
    return min(pad_to_multiple(max(c, 8), 8), n_tokens * m.top_k)


def moe_ffn(cfg, env: AxisEnv, params, x: jax.Array, *,
            step: Optional[jax.Array] = None,
            rng: Optional[jax.Array] = None,
            train: bool = True,
            dispatch: str = "auto"):
    """x (T, d) full per dp-shard -> (partial (T, d), aux_loss, metrics).

    The partial output must be combined over tp by the caller (sp_scatter),
    exactly like a row-parallel dense FFN.

    dispatch:
      "fused"   ONE Pallas kernel for gather -> grouped two-GEMM FFN ->
                gate-weighted combine (kernels/grouped_matmul.fused_moe_ffn):
                no aligned-lhs relayout, no (cap, ff) HBM round-trip, no
                separate scatter-add; fp32 accumulation throughout.
                Same dropless/capacity semantics as "ragged".
      "ragged"  sort + jax.lax.ragged_dot (exactly dropless at tp=1; XLA
                without a grouped-gemm lowering computes it as a dense
                batched dot over local experts — E_loc x FLOP waste);
      "batched" per-expert-capacity blocks + plain batched einsum — the
                TPU-native form (equal MXU tiles per expert, no waste);
                drops are bounded per-expert instead of per-rank;
      "ep"      expert-parallel all-to-all dispatch (`_ep_moe_ffn`): each
                rank routes its T/tp owned tokens, all_to_all's the slots
                to the shard owning each expert, runs the local fused FFN
                there, and all_to_all's the outputs back.  Per-token FFN
                compute happens once per tp group instead of tp times;
                requires T % tp == 0 (slice ownership).
      "auto"    resolves the per-arch `MoEConfig.dispatch` knob first,
                then: tp>1: ep on interpret builds when T % tp == 0 (the
                multi-device fused hot path), batched otherwise/on real
                TPUs.  tp=1: fused where validated (interpret builds),
                ragged on real TPU hardware until the fused kernel tiles
                its (T, d) blocks (ROADMAP follow-up).
    """
    m = cfg.moe
    T, d = x.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    ep, e_loc = padded_experts(cfg, env)
    cap = capacity(cfg, env, T)
    explicit = dispatch != "auto"
    if dispatch == "auto":
        dispatch = m.dispatch              # per-arch config knob
        if dispatch == "ep" and env.tp == 1:
            dispatch = "auto"   # EP buys nothing at tp=1: use tp=1 default
    if dispatch == "auto":
        # fused/ep are the defaults where the pipeline is validated
        # (interpret mode).  On real TPUs the kernel as written keeps the
        # full (T, d) in/out blocks VMEM-resident, which does not fit at
        # training shapes — stay on ragged/batched there until the ROADMAP
        # tile sweep (T-tiled output + DMA gather) lands.
        if env.tp > 1:
            dispatch = ("ep" if kops.INTERPRET and T % env.tp == 0
                        else "batched")
        else:
            dispatch = "fused" if kops.INTERPRET else "ragged"
    if dispatch not in ("fused", "ragged", "batched", "ep"):
        raise ValueError(f"unknown moe dispatch mode: {dispatch!r}")
    if dispatch == "ep" and T % env.tp:
        # slice ownership needs T % tp == 0 (e.g. tiny decode batches):
        # an explicit caller request is an error, the config-knob
        # preference degrades to the Megatron-layout capacity path.
        if explicit:
            raise ValueError(
                f"dispatch='ep' needs T ({T}) divisible by tp ({env.tp})")
        dispatch = "batched"

    w1 = env.gather_fsdp(params["we1"], 1, dtype=cdt)
    w2 = env.gather_fsdp(params["we2"], 2, dtype=cdt)
    w3 = (env.gather_fsdp(params["we3"], 1, dtype=cdt)
          if "we3" in params else None)

    if dispatch == "ep":
        y, aux, metrics, n_kept, n_local = _ep_moe_ffn(
            cfg, env, params, x, w1, w2, w3, step=step, rng=rng,
            train=train)
        return _moe_tail(cfg, env, params, x, y, aux, metrics, n_kept,
                         n_local)

    top_w, top_i, aux, metrics = router_lib.route(
        cfg, env, params["router"], x, step=step, rng=rng, train=train)

    # ---- local dispatch: sort token-slots by (local) expert --------------
    r = env.tp_index()
    lo = r * e_loc
    flat_i = top_i.reshape(-1)                     # (T*k,)
    flat_w = top_w.reshape(-1)
    local_key = flat_i - lo
    is_local = (local_key >= 0) & (local_key < e_loc)
    sort_key = jnp.where(is_local, local_key, e_loc)   # non-local last
    order = jnp.argsort(sort_key)                  # stable

    if dispatch in ("ragged", "fused"):
        sel = order[:cap]                          # (cap,) slot indices
        tok = sel // m.top_k                       # token per slot
        skey = sort_key[sel]                       # sorted expert keys
        valid = skey < e_loc
        # rows per local expert (only rows that made it into the buffer)
        group_sizes = jnp.sum(
            jax.nn.one_hot(jnp.where(valid, skey, e_loc), e_loc + 1,
                           dtype=jnp.int32)[:, :e_loc], axis=0)
        gates = (flat_w[sel] * valid).astype(cdt)
        if dispatch == "fused":
            y = fused_ffn(cfg.mlp_act, x.astype(cdt), w1, w2, w3, tok,
                          gates, group_sizes).astype(cdt)
        else:
            xs = jnp.take(x, tok, axis=0).astype(cdt)  # (cap, d) gather
            out = grouped_ffn(cfg, w1, w2, w3, xs, group_sizes)  # (cap, d)
            y = jnp.zeros((T, d), cdt).at[tok].add(out * gates[:, None])
        n_kept = jnp.sum(valid)
    else:
        # per-expert-capacity batched dispatch: expert e's rows live at
        # sorted positions [offset_e, offset_e + count_e); clip to C_e and
        # lay them out as (E_loc, C_e, d) so the expert FFN is a plain
        # batched einsum — equal MXU tiles per expert, no E_loc x dense
        # waste, and the combine stays a scatter-add.
        c_e = expert_capacity(cfg, env, T)
        counts = jnp.sum(
            jax.nn.one_hot(jnp.where(is_local, local_key, e_loc), e_loc + 1,
                           dtype=jnp.int32)[:, :e_loc], axis=0)   # (E_loc,)
        offsets = jnp.cumsum(counts) - counts
        slot_idx = offsets[:, None] + jnp.arange(c_e)[None, :]    # (E,C)
        slot_valid = jnp.arange(c_e)[None, :] < jnp.minimum(counts, c_e)[:, None]
        slot = jnp.take(order, jnp.clip(slot_idx, 0, order.shape[0] - 1))
        tok_e = slot // m.top_k                                   # (E,C)
        xs = jnp.take(x, tok_e.reshape(-1), axis=0).astype(cdt)
        xs = xs.reshape(e_loc, c_e, d)
        h = jnp.einsum("ecd,edf->ecf", xs, w1)
        if cfg.mlp_act in L.GATED_ACTS:
            h = L._act(cfg.mlp_act, h) * jnp.einsum("ecd,edf->ecf", xs, w3)
        else:
            h = L._act(cfg.mlp_act, h)
        out = jnp.einsum("ecf,efd->ecd", h, w2)                   # (E,C,d)
        gates = (jnp.take(flat_w, slot.reshape(-1)).reshape(e_loc, c_e)
                 * slot_valid).astype(cdt)
        y = jnp.zeros((T, d), cdt).at[tok_e.reshape(-1)].add(
            (out * gates[..., None]).reshape(-1, d))
        n_kept = jnp.sum(jnp.minimum(counts, c_e))

    n_local = jnp.sum(is_local)
    return _moe_tail(cfg, env, params, x, y, aux, metrics, n_kept, n_local)


def _moe_tail(cfg, env: AxisEnv, params, x, y, aux, metrics, n_kept,
              n_local):
    """Shared by every dispatch mode: dropped-token telemetry + the
    always-on shared expert fused into the same partial-sum."""
    m = cfg.moe
    cdt = jnp.dtype(cfg.compute_dtype)

    # dropped-token telemetry (paper: dropless; cf headroom makes this ~0)
    dropped = jnp.maximum(n_local - n_kept, 0)
    metrics["moe/dropped_frac"] = env.pmean_dp(
        env.psum_tp(dropped.astype(jnp.float32))
        / jnp.maximum(env.psum_tp(n_local.astype(jnp.float32)), 1.0))

    # ---- shared expert (Eq. 2): dense TP FFN fused into the partial ------
    if m.n_shared_experts > 0:
        y = y + L.apply_mlp(cfg, env, params["shared"], x.astype(cdt))

    return y, aux, metrics
