"""Heterogeneous-accelerator cost model (Table 1 + §1.3 20%-savings claim,
C8).

The paper's Table 1 (anonymized devices A–E) with peak FLOPS, memory, fair
cost per hour, FP8 support.  The model computes time and cost to train a
given token budget on each device (or a mixed schedule) and reproduces the
headline numbers: ~6.35M RMB per 1T tokens on the high-performance device D
vs ~5.08M RMB on the lower-spec system — a ~20% saving.

Calibration: with Ling-Plus (28.8B activated params), 1T tokens is
6*N_active*D = 1.728e26 FLOPs.  Device D at 989 TFLOPS: the paper's 6.35M
RMB at 27.5 RMB/h implies ~231k device-hours => an effective utilization
(MFU) of ~21%.  Lower-spec devices sustain a somewhat higher MFU (smaller,
better-fed matmul units; the paper's framework work closes the rest of the
gap) — we expose MFU per device and fit the pair of headline numbers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

# -- Table 1 (verbatim) -------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Device:
    name: str
    peak_tflops: float
    memory_gb: int
    cost_per_hour_rmb: float
    supports_fp8: bool
    mfu: float                       # effective utilization (calibrated)
    availability: int                # rank, 1 = most available


DEVICES: Dict[str, Device] = {
    "A": Device("A", 370, 64, 7.0, False, mfu=0.28, availability=1),
    "B": Device("B", 120, 96, 4.5, False, mfu=0.30, availability=2),
    "C": Device("C", 312, 80, 10.0, False, mfu=0.26, availability=3),
    "D": Device("D", 989, 80, 27.5, True, mfu=0.21, availability=4),
    "E": Device("E", 147, 96, 5.64, True, mfu=0.30, availability=5),
}

LING_PLUS_ACTIVE = 28.8e9
TOKENS_1T = 1e12


def train_flops(tokens: float, active_params: float = LING_PLUS_ACTIVE
                ) -> float:
    return 6.0 * active_params * tokens


def device_hours(dev: Device, tokens: float,
                 active_params: float = LING_PLUS_ACTIVE) -> float:
    flops = train_flops(tokens, active_params)
    eff = dev.peak_tflops * 1e12 * dev.mfu
    return flops / eff / 3600.0


def cost_rmb(dev: Device, tokens: float,
             active_params: float = LING_PLUS_ACTIVE) -> float:
    return device_hours(dev, tokens, active_params) * dev.cost_per_hour_rmb


@dataclasses.dataclass
class MixedSchedule:
    """Fractions of the token budget trained on each device type
    (the paper's 'five distinct hardware configurations')."""
    fractions: Dict[str, float]

    def cost(self, tokens: float = TOKENS_1T,
             active_params: float = LING_PLUS_ACTIVE) -> float:
        assert abs(sum(self.fractions.values()) - 1.0) < 1e-6
        return sum(cost_rmb(DEVICES[d], tokens * f, active_params)
                   for d, f in self.fractions.items())

    def hours_by_device(self, tokens: float = TOKENS_1T,
                        active_params: float = LING_PLUS_ACTIVE
                        ) -> Dict[str, float]:
        return {d: device_hours(DEVICES[d], tokens * f, active_params)
                for d, f in self.fractions.items()}


HIGH_PERF = MixedSchedule({"D": 1.0})
# lower-spec system: weighted toward the most-available devices (Table 1 is
# "listed in descending order of availability")
LOW_SPEC = MixedSchedule({"A": 0.55, "B": 0.25, "E": 0.20})


def savings_report(tokens: float = TOKENS_1T,
                   active_params: float = LING_PLUS_ACTIVE) -> Dict:
    hi = HIGH_PERF.cost(tokens, active_params)
    lo = LOW_SPEC.cost(tokens, active_params)
    return {
        "tokens": tokens,
        "high_perf_cost_mrmb": hi / 1e6,
        "low_spec_cost_mrmb": lo / 1e6,
        "savings_frac": 1.0 - lo / hi,
        "paper_claim": {"high": 6.35, "low": 5.08, "savings": 0.20},
    }


def best_single_device(tokens: float = TOKENS_1T, *,
                       memory_needed_gb: Optional[float] = None,
                       need_fp8: bool = False) -> Device:
    """Cost-optimal single device under constraints (the 'choose the
    best-matching architecture for the available resource' loop)."""
    cands = [d for d in DEVICES.values()
             if (not need_fp8 or d.supports_fp8)
             and (memory_needed_gb is None or d.memory_gb >= memory_needed_gb)]
    return min(cands, key=lambda d: cost_rmb(d, tokens))
