"""NormHead (paper §3.2.3, Eq. 4, C4).

The LM-head weight rows are L2-normalized before the logit matmul, removing
weight-magnitude drift as a source of loss spikes / divergence.  The row
norm is over d_model, which is *local* under our vocab-sharded head, so the
normalization costs no communication.  `kernels/normhead.py` provides the
fused Pallas version (normalize-on-the-fly inside the matmul tiles).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import AxisEnv


def normalize_rows(w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """L2-normalize rows (vocab entries) of a (V_local, d) head weight."""
    wf = w.astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(wf * wf, axis=-1, keepdims=True))
    return wf / jnp.maximum(norm, eps)


def normhead_logits(cfg, env: AxisEnv, w_local: jax.Array, x: jax.Array
                    ) -> jax.Array:
    """x (T, d) -> vocab-local logits (T, V_local), fp32.

    With norm_head=False this is a plain LM head (used for the
    paper-faithful ablation of the assigned non-Ling architectures).
    """
    w = env.gather_fsdp(w_local, 1)  # FSDP over d (dim 1)
    if cfg.norm_head:
        wn = normalize_rows(w)
    else:
        wn = w.astype(jnp.float32)
    return x.astype(jnp.float32) @ wn.T
