"""Loss-spike handling (paper §3.4.4 + §6.1, C6).

Mechanisms, exactly as described:
  * spike detection against a running loss statistic (EMA mean/std);
  * narrow vs wide classification (consecutive spiking steps);
  * **skip** the affected update (the step discards params/opt commit);
  * **sample retry** — the spiking batch is saved and randomly re-injected
    into later training;
  * **automatic LR reduction** when a spike persists after retry.

Two cooperating halves:

  * the **device-side guard** (`init_guard_state` / `guard_commit`) carries
    the EMA mean/var in a tiny replicated pytree inside the jitted train
    step and emits a `commit` flag, so the commit-or-discard of §3.4.4 is a
    `jnp.where` on device — no per-step host round-trip;
  * the **host-side `SpikeDetector`** keeps the policy: narrow/wide
    classification, the retry queue, and the LR-halving window.  It is fed
    asynchronously from drained metrics via `ingest` (the trainer drains
    every `log_every` steps); the legacy per-step `observe` entry point
    remains for synchronous callers.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SpikeConfig:
    ema_decay: float = 0.98
    sigma_threshold: float = 4.0     # spike if loss > mean + sigma*std
    abs_threshold: float = 0.75      # ... or loss - mean > abs_threshold
    wide_after: int = 3              # consecutive spikes => wide spike
    lr_reduce_factor: float = 0.5    # persistent spike LR response
    lr_reduce_steps: int = 50        # steps the reduction stays active
    warmup_steps: int = 20           # no detection before stats settle
    # §3.4.4 footnote 2: some spikes show up in the gradient norm before
    # (or without) the loss moving.  When set, the device guard also
    # carries an EMA over the clipped-update grad norm and vetoes the
    # commit when grad_norm > mean + gnorm_sigma_threshold * std (or is
    # non-finite).  None keeps the loss-only guard — and the original
    # 4-leaf guard state, so existing checkpoints/tests are unaffected.
    gnorm_sigma_threshold: Optional[float] = None


# ---------------------------------------------------------------------------
# device-side fast path: EMA state + commit flag inside the jitted step
# ---------------------------------------------------------------------------


def init_guard_state(cfg: Optional["SpikeConfig"] = None
                     ) -> Dict[str, jnp.ndarray]:
    """Replicated device-side EMA state carried through the train step.
    With a gnorm-keyed config the state grows a second EMA pair
    (gmean/gvar) for the grad-norm statistic; the default stays the
    4-leaf loss-only pytree."""
    state = {"mean": jnp.zeros((), jnp.float32),
             "var": jnp.full((), 0.25, jnp.float32),
             "n": jnp.zeros((), jnp.int32),
             "seeded": jnp.zeros((), jnp.int32)}
    if cfg is not None and cfg.gnorm_sigma_threshold is not None:
        state["gmean"] = jnp.zeros((), jnp.float32)
        state["gvar"] = jnp.full((), 0.25, jnp.float32)
    return state


def guard_commit(cfg: "SpikeConfig", state: Dict[str, jnp.ndarray],
                 loss: jnp.ndarray,
                 gnorm: Optional[jnp.ndarray] = None):
    """Pure jnp commit decision (mirrors `SpikeDetector.is_spike`).

    Returns ``(commit, new_state)``: ``commit`` is a bool scalar — False
    when `loss` spikes above the EMA statistic (or is non-finite), in which
    case the step's params/opt update must be discarded via `jnp.where`.
    Spiking losses do NOT update the running stats, exactly like the host
    detector; the first *committed* observation seeds mean=loss, var=0.25
    (`seeded` tracks this so e.g. a non-finite step-0 loss cannot poison
    the EMA or steal the seed).

    With ``cfg.gnorm_sigma_threshold`` set and a `gnorm` passed, a second
    EMA over the grad norm vetoes the commit symmetrically (§3.4.4 fn2:
    grad-norm spikes that precede — or never reach — the loss).  Both
    statistics gate one shared commit flag, and only committed steps
    update either EMA, so a spike in one channel cannot poison the other
    channel's statistics.
    """
    loss = loss.astype(jnp.float32)
    first = state["seeded"] == 0
    mean = jnp.where(first, loss, state["mean"])
    # n counts observations including this one, like the host detector's
    # pre-check increment in `observe`
    warm = (state["n"] + 1) < cfg.warmup_steps
    std = jnp.maximum(jnp.sqrt(state["var"]), 1e-3)
    spike = (~warm) & ((loss > mean + cfg.sigma_threshold * std)
                       | (loss - mean > cfg.abs_threshold))
    commit = (~spike) & jnp.isfinite(loss)

    use_gnorm = (cfg.gnorm_sigma_threshold is not None
                 and "gmean" in state and gnorm is not None)
    if use_gnorm:
        gnorm = gnorm.astype(jnp.float32)
        gmean = jnp.where(first, gnorm, state["gmean"])
        gstd = jnp.maximum(jnp.sqrt(state["gvar"]), 1e-3)
        gspike = (~warm) & (gnorm > gmean
                            + cfg.gnorm_sigma_threshold * gstd)
        commit = commit & (~gspike) & jnp.isfinite(gnorm)

    d = cfg.ema_decay
    delta = loss - mean
    # non-committed losses fall back to the *stored* stats
    new_state = dict(state)
    new_state["mean"] = jnp.where(commit, mean + (1 - d) * delta,
                                  state["mean"])
    new_state["var"] = jnp.where(commit & ~first,
                                 d * state["var"] + (1 - d) * delta * delta,
                                 state["var"])
    new_state["n"] = state["n"] + 1
    new_state["seeded"] = jnp.where(commit, jnp.ones_like(state["seeded"]),
                                    state["seeded"])
    if use_gnorm:
        gdelta = gnorm - gmean
        new_state["gmean"] = jnp.where(commit, gmean + (1 - d) * gdelta,
                                       state["gmean"])
        new_state["gvar"] = jnp.where(
            commit & ~first, d * state["gvar"] + (1 - d) * gdelta * gdelta,
            state["gvar"])
    return commit, new_state


@dataclasses.dataclass
class SpikeEvent:
    step: int
    loss: float
    kind: str                        # "narrow" | "wide"
    action: str                      # "skip" | "skip+retry" | "skip+lr"


class SpikeDetector:
    # `lr_reduced_until` is part of the public contract: the trainer reads
    # it (via `lr_scale_for`) before the first observe/ingest call, so it
    # must exist — explicitly initialized — from construction.
    lr_reduced_until: int

    def __init__(self, cfg: SpikeConfig = SpikeConfig()):
        self.cfg = cfg
        self.mean: Optional[float] = None
        self.var: float = 0.0
        self.n = 0
        self.consecutive = 0
        self.lr_reduced_until = -1
        self.events: List[SpikeEvent] = []
        self.retry_queue: Deque[Any] = deque()

    # -- LR policy ------------------------------------------------------------
    def lr_scale_for(self, step: int) -> float:
        """LR multiplier for `step`: `lr_reduce_factor` while inside the
        reduction window opened by a wide spike, 1.0 otherwise.  Safe to
        call before any observation (the window starts closed)."""
        return (self.cfg.lr_reduce_factor
                if step <= self.lr_reduced_until else 1.0)

    # -- statistics -----------------------------------------------------------
    def _update_stats(self, loss: float):
        d = self.cfg.ema_decay
        if self.mean is None:
            self.mean, self.var = loss, 0.25
        else:
            delta = loss - self.mean
            self.mean += (1 - d) * delta
            self.var = d * self.var + (1 - d) * delta * delta

    def is_spike(self, loss: float) -> bool:
        if self.mean is None or self.n < self.cfg.warmup_steps:
            return False
        std = max(np.sqrt(self.var), 1e-3)
        return (loss > self.mean + self.cfg.sigma_threshold * std
                or loss - self.mean > self.cfg.abs_threshold)

    # -- shared policy block ----------------------------------------------------
    def _record(self, step: int, loss: float, skipped: bool,
                batch: Any = None) -> Dict[str, Any]:
        """Narrow/wide classification, sample-retry queueing, LR-halving
        window, event log — everything downstream of the skip decision."""
        if not skipped:
            self.consecutive = 0
            self._update_stats(loss)
            return {"skip": False, "kind": None}
        self.consecutive += 1
        wide = self.consecutive >= self.cfg.wide_after
        action = "skip+retry"
        if batch is not None:
            self.retry_queue.append(batch)      # re-inject later (§3.4.4)
        if wide:
            # persistent spike: also reduce LR for a window of steps
            self.lr_reduced_until = step + self.cfg.lr_reduce_steps
            action = "skip+lr"
        self.events.append(SpikeEvent(step, loss, "wide" if wide else
                                      "narrow", action))
        # spiking losses do NOT update the running stats
        return {"skip": True, "kind": "wide" if wide else "narrow"}

    # -- synchronous entry: detector decides the skip itself ------------------
    def observe(self, step: int, loss: float, batch: Any = None
                ) -> Dict[str, Any]:
        """Returns {'skip': bool, 'lr_scale': float, 'kind': str|None}."""
        self.n += 1
        spike = self.is_spike(loss)
        out = self._record(step, loss, spike, batch)
        return {**out, "lr_scale": self.lr_scale_for(step)}

    # -- async entry: the skip decision was already made on device -----------
    def ingest(self, step: int, loss: float, skipped: bool,
               batch: Any = None) -> Dict[str, Any]:
        """Record one drained step whose commit/discard already happened on
        device (`guard_commit`).  Mirrors `observe` minus the skip
        decision itself."""
        self.n += 1
        return self._record(step, loss, skipped, batch)

    def pop_retry(self) -> Optional[Any]:
        """Pull a saved batch for random re-injection."""
        if self.retry_queue:
            return self.retry_queue.popleft()
        return None

    # -- checkpoint resume ----------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {"mean": self.mean, "var": self.var, "n": self.n,
                "consecutive": self.consecutive,
                "lr_reduced_until": self.lr_reduced_until,
                "events": list(self.events),
                "retry_queue": list(self.retry_queue)}

    def load_state_dict(self, s: Dict[str, Any]):
        self.mean = s["mean"]
        self.var = s["var"]
        self.n = s["n"]
        self.consecutive = s["consecutive"]
        self.lr_reduced_until = s["lr_reduced_until"]
        self.events = list(s["events"])
        self.retry_queue = deque(s["retry_queue"])


def inject_synthetic_spikes(losses: np.ndarray, steps: List[int],
                            magnitude: float = 3.0) -> np.ndarray:
    """Test/benchmark helper: overlay spikes on a loss curve."""
    out = losses.copy()
    for s in steps:
        for j, decay in enumerate([1.0, 0.6, 0.3]):
            if s + j < len(out):
                out[s + j] += magnitude * decay
    return out
