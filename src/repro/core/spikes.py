"""Loss-spike handling (paper §3.4.4 + §6.1, C6).

Mechanisms, exactly as described:
  * spike detection against a running loss statistic (EMA mean/std);
  * narrow vs wide classification (consecutive spiking steps);
  * **skip** the affected update (the trainer discards the step);
  * **sample retry** — the spiking batch is saved and randomly re-injected
    into later training;
  * **automatic LR reduction** when a spike persists after retry.

The detector is host-side (it consumes scalar losses), which matches the
paper's monitoring system; the *skip* itself is applied by the trainer by
not committing (params, opt_state) of the flagged step.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class SpikeConfig:
    ema_decay: float = 0.98
    sigma_threshold: float = 4.0     # spike if loss > mean + sigma*std
    abs_threshold: float = 0.75      # ... or loss - mean > abs_threshold
    wide_after: int = 3              # consecutive spikes => wide spike
    lr_reduce_factor: float = 0.5    # persistent spike LR response
    lr_reduce_steps: int = 50        # steps the reduction stays active
    warmup_steps: int = 20           # no detection before stats settle


@dataclasses.dataclass
class SpikeEvent:
    step: int
    loss: float
    kind: str                        # "narrow" | "wide"
    action: str                      # "skip" | "skip+retry" | "skip+lr"


class SpikeDetector:
    def __init__(self, cfg: SpikeConfig = SpikeConfig()):
        self.cfg = cfg
        self.mean: Optional[float] = None
        self.var: float = 0.0
        self.n = 0
        self.consecutive = 0
        self.lr_reduced_until = -1
        self.events: List[SpikeEvent] = []
        self.retry_queue: Deque[Any] = deque()

    # -- statistics -----------------------------------------------------------
    def _update_stats(self, loss: float):
        d = self.cfg.ema_decay
        if self.mean is None:
            self.mean, self.var = loss, 0.25
        else:
            delta = loss - self.mean
            self.mean += (1 - d) * delta
            self.var = d * self.var + (1 - d) * delta * delta

    def is_spike(self, loss: float) -> bool:
        if self.mean is None or self.n < self.cfg.warmup_steps:
            return False
        std = max(np.sqrt(self.var), 1e-3)
        return (loss > self.mean + self.cfg.sigma_threshold * std
                or loss - self.mean > self.cfg.abs_threshold)

    # -- main entry -------------------------------------------------------------
    def observe(self, step: int, loss: float, batch: Any = None
                ) -> Dict[str, Any]:
        """Returns {'skip': bool, 'lr_scale': float, 'kind': str|None}."""
        self.n += 1
        spike = self.is_spike(loss)
        lr_scale = (self.cfg.lr_reduce_factor
                    if step <= self.lr_reduced_until else 1.0)
        if not spike:
            self.consecutive = 0
            self._update_stats(loss)
            return {"skip": False, "lr_scale": lr_scale, "kind": None}

        self.consecutive += 1
        wide = self.consecutive >= self.cfg.wide_after
        action = "skip+retry"
        if batch is not None:
            self.retry_queue.append(batch)      # re-inject later (§3.4.4)
        if wide:
            # persistent spike: also reduce LR for a window of steps
            self.lr_reduced_until = step + self.cfg.lr_reduce_steps
            action = "skip+lr"
            lr_scale = self.cfg.lr_reduce_factor
        self.events.append(SpikeEvent(step, loss, "wide" if wide else
                                      "narrow", action))
        # spiking losses do NOT update the running stats
        return {"skip": True, "lr_scale": lr_scale,
                "kind": "wide" if wide else "narrow"}

    def pop_retry(self) -> Optional[Any]:
        """Pull a saved batch for random re-injection."""
        if self.retry_queue:
            return self.retry_queue.popleft()
        return None


def inject_synthetic_spikes(losses: np.ndarray, steps: List[int],
                            magnitude: float = 3.0) -> np.ndarray:
    """Test/benchmark helper: overlay spikes on a loss curve."""
    out = losses.copy()
    for s in steps:
        for j, decay in enumerate([1.0, 0.6, 0.3]):
            if s + j < len(out):
                out[s + j] += magnitude * decay
    return out
