"""Expert router (paper §3.2.2, C2–C3).

Implements the paper's routing stack:
  * softmax gating with top-k selection, Eq. (1) — gate values are the raw
    softmax probabilities of the selected experts (no renormalization);
  * Switch-style load-balance loss and router z-loss (§3.4.1 coefficients:
    balance 0.015, z-loss 1e-4);
  * **Stochastic Routing Warmup**, Eq. (3): during the first W steps the
    routing logits are interpolated with synthesized random logits drawn
    from the running per-expert statistics of the learned logits, which
    keeps expert load uniform at initialization and hands control to the
    learned router as alpha -> 1.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding import AxisEnv, fsdp_spec


def init_router(key, cfg, env: AxisEnv):
    m = cfg.moe
    d = cfg.d_model
    wr = (0.02 * jax.random.normal(key, (d, m.n_experts))
          ).astype(jnp.dtype(cfg.param_dtype))
    return {"wr": wr}, {"wr": fsdp_spec(env, 2, 0, None)}


def stochastic_warmup_logits(logits: jax.Array, step: jax.Array,
                             warmup_steps: int, rng: jax.Array,
                             env: AxisEnv, pmean=None) -> jax.Array:
    """Eq. (3): s_hat = alpha*s + (1-alpha)*(mu_s + sigma_s * eps).

    mu_s/sigma_s are *scalar* statistics of the logit distribution (over
    batch and experts): the synthesized logits are then exchangeable across
    experts, which is what guarantees "balanced expert activation at
    initialization" even when the learned router starts skewed.  (Per-
    expert stats would reproduce the skew in the noise and defeat the
    warmup.)  `pmean` averages over every axis the tokens are sharded on —
    dp by default, dp+tp under EP dispatch (tokens sharded over tp too).
    """
    pmean = pmean or env.pmean_dp
    mu = pmean(jnp.mean(logits))
    var = pmean(jnp.mean((logits - mu) ** 2))
    mu = jax.lax.stop_gradient(mu)
    sigma = jax.lax.stop_gradient(jnp.sqrt(var + 1e-6))
    alpha = jnp.minimum(step.astype(jnp.float32) / max(warmup_steps, 1), 1.0)
    eps = jax.random.normal(rng, logits.shape, jnp.float32)
    return alpha * logits + (1.0 - alpha) * (mu + sigma * eps)


def route(cfg, env: AxisEnv, params, x: jax.Array, *,
          step: Optional[jax.Array] = None,
          rng: Optional[jax.Array] = None,
          train: bool = True,
          ep: bool = False
          ) -> Tuple[jax.Array, jax.Array, jax.Array, Dict[str, jax.Array]]:
    """x (T, d) -> (top_w (T,k), top_i (T,k), aux_loss, metrics).

    `ep=True` means x holds only this tp rank's *owned* token slice
    (expert-parallel dispatch): the per-token statistics behind the balance
    loss, z-loss and warmup noise then average over dp AND tp so the aux
    loss is bitwise-identical on every rank and numerically matches the
    tp=1 value computed over the full batch.
    """
    m = cfg.moe
    pmean = env.pmean_all if ep else env.pmean_dp
    wr = env.gather_fsdp(params["wr"], 0).astype(jnp.float32)
    logits = x.astype(jnp.float32) @ wr                    # (T, E)

    if train and rng is not None and m.router_warmup_steps > 0:
        assert step is not None
        logits = stochastic_warmup_logits(logits, step,
                                          m.router_warmup_steps, rng, env,
                                          pmean=pmean)

    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, m.top_k)           # Eq. (1)

    # -- auxiliary losses ----------------------------------------------------
    # load-balance (Switch): E * sum_e f_e * P_e
    E = m.n_experts
    hits = jax.nn.one_hot(top_i, E, dtype=jnp.float32).sum(axis=1)  # (T, E)
    f = pmean(jnp.mean(hits, axis=0)) / m.top_k            # fraction routed
    p_mean = pmean(jnp.mean(probs, axis=0))
    balance = E * jnp.sum(f * p_mean)
    # router z-loss: mean(logsumexp(logits)^2)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    z = pmean(z)
    aux = m.balance_loss_coef * balance + m.z_loss_coef * z

    metrics = {
        "router/balance_loss": balance,
        "router/z_loss": z,
        "router/max_expert_frac": jnp.max(f),
        "router/min_expert_frac": jnp.min(f),
    }
    return top_w, top_i, aux, metrics
