"""EDiT — Elastic Distributed Training (paper §2.2, C5).

A tailored Local-SGD method: K workers (clusters / pods) run independent
local optimization and synchronize parameters *layer by layer* with a
pseudo-gradient penalty:

  1. **Anomaly elimination** — per-worker pseudo-gradient norms are tracked
     with an exponential moving average; workers whose norm deviates more
     than `anomaly_sigma` standard deviations are excluded from the sync.
  2. **Weighted averaging** — surviving workers are averaged with weights
     inversely proportional to their pseudo-gradient norms, damping noisy
     contributions.
  3. **Gradient clipping** — the aggregated pseudo-gradient is clipped to a
     fixed norm before being applied by the outer optimizer.

Synchronization can be triggered after a fixed number of local steps or by
a **time threshold** (§2.2 "time-based synchronization"), which lets fast
workers take more local steps instead of waiting for stragglers — this is
the mechanism behind the paper's up-to-66.1% step-time win (Fig. 8).

The layer-wise schedule matters on real hardware because parameter sync for
layer L overlaps with forward compute of layer L-1 (prefetch); here we
model it faithfully as a per-layer pipeline in `simulate_sync_timeline` and
use it in the Fig.-8 benchmark, while the math (penalty + averaging) runs
for real on the worker replicas.

Multi-pod mapping: in production the worker axis is the `pod` mesh axis —
local SGD within a pod, EDiT sync across pods (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class EDiTConfig:
    sync_every: int = 8              # local steps between syncs (H)
    time_threshold_s: Optional[float] = None   # if set: time-based sync
    anomaly_sigma: float = 2.0
    ema_decay: float = 0.9
    clip_norm: float = 1.0
    outer_momentum: float = 0.9      # outer (pseudo-gradient) momentum
    outer_lr: float = 1.0


def tree_sub(a, b):
    return jax.tree.map(lambda x, y: x.astype(jnp.float32)
                        - y.astype(jnp.float32), a, b)


def tree_norm(t) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                        for l in jax.tree.leaves(t)))


def layer_names(params: Dict[str, Any]) -> List[str]:
    """Top-level layer-wise sync units (embed / blocks / norms / head)."""
    return sorted(params.keys())


# ---------------------------------------------------------------------------
# the EDiT synchronization step (pure function, jittable)
# ---------------------------------------------------------------------------


def edit_sync(base_params, worker_params: Sequence[Any],
              ema_state: Dict[str, jax.Array],
              outer_m, cfg: EDiTConfig):
    """One EDiT synchronization.

    base_params: global params at the previous sync point.
    worker_params: K worker replicas after their local steps.
    ema_state: {'mean': (K,), 'var': (K,)} EMA of pseudo-grad norms.
    outer_m: outer momentum buffer (pytree like params).

    Returns (new_params, new_ema, new_outer_m, info).
    """
    K = len(worker_params)
    # pseudo gradients: g_i = theta_base - theta_i
    pgs = [tree_sub(base_params, w) for w in worker_params]
    norms = jnp.stack([tree_norm(g) for g in pgs])           # (K,)

    # (1) anomaly elimination: z-score of each worker's pseudo-grad norm
    # against its *previous* EMA statistics (the running history is what
    # detects the anomaly; comparing post-update would hide it).  The very
    # first syncs (no history yet) keep everyone.
    count = ema_state.get("count", jnp.zeros((), jnp.int32))
    sigma = jnp.sqrt(ema_state["var"] + 1e-12)
    z = jnp.abs(norms - ema_state["mean"]) / jnp.maximum(sigma, 1e-6)
    keep = (z <= cfg.anomaly_sigma) | (count < 2)
    # never eliminate everyone
    keep = jnp.where(jnp.any(keep), keep, jnp.ones_like(keep))
    # update the EMA with kept workers only (a faulty worker must not drag
    # its own acceptance threshold up)
    d = cfg.ema_decay
    new_mean = jnp.where(keep, d * ema_state["mean"] + (1 - d) * norms,
                         ema_state["mean"])
    new_var = jnp.where(keep,
                        d * ema_state["var"]
                        + (1 - d) * (norms - new_mean) ** 2,
                        ema_state["var"])
    ema_mean, ema_var = new_mean, new_var

    # (2) weighted averaging: w_i ~ 1 / (norm_i + eps), anomalies get 0
    raw_w = jnp.where(keep, 1.0 / (norms + 1e-8), 0.0)
    weights = raw_w / jnp.sum(raw_w)

    def avg(*leaves):
        return sum(w * l for w, l in zip(weights, leaves))

    pg_avg = jax.tree.map(avg, *pgs)

    # (3) clip the aggregated pseudo gradient
    n = tree_norm(pg_avg)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(n, 1e-12))
    pg_avg = jax.tree.map(lambda g: g * scale, pg_avg)

    # outer update with momentum: theta <- theta_base - lr * m
    outer_m = jax.tree.map(
        lambda m, g: cfg.outer_momentum * m + g, outer_m, pg_avg)
    new_params = jax.tree.map(
        lambda p, m: (p.astype(jnp.float32) - cfg.outer_lr * m).astype(p.dtype),
        base_params, outer_m)

    info = {"pg_norms": norms, "kept": keep, "weights": weights,
            "pg_avg_norm": n}
    new_ema = {"mean": ema_mean, "var": ema_var, "count": count + 1}
    return new_params, new_ema, outer_m, info


def init_ema(num_workers: int) -> Dict[str, jax.Array]:
    return {"mean": jnp.zeros((num_workers,), jnp.float32),
            "var": jnp.ones((num_workers,), jnp.float32),
            "count": jnp.zeros((), jnp.int32)}


def init_outer_momentum(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


# ---------------------------------------------------------------------------
# the EDiT driver: K simulated workers, local AdamW, periodic / timed sync
# ---------------------------------------------------------------------------


class EDiTTrainer:
    """Multi-worker local-SGD driver.

    Each worker is a full model replica trained with its own inner AdamW;
    `worker_speeds` models heterogeneous hardware (steps per unit time) for
    time-based synchronization.
    """

    def __init__(self, init_params, train_step: Callable, cfg: EDiTConfig,
                 num_workers: int,
                 worker_speeds: Optional[Sequence[float]] = None):
        self.cfg = cfg
        self.K = num_workers
        self.speeds = list(worker_speeds or [1.0] * num_workers)
        self.train_step = train_step
        self.base = init_params
        self.workers = [jax.tree.map(jnp.copy, init_params)
                        for _ in range(num_workers)]
        self.opt_states = [None] * num_workers
        self.ema = init_ema(num_workers)
        self.outer_m = init_outer_momentum(init_params)
        self.step = 0
        self.history: List[Dict] = []

    def round(self, batches_per_worker: Sequence[Sequence[Any]],
              lr: float = 1e-3):
        """One sync round: local steps per worker then an EDiT sync.

        With time-based sync, worker i runs round(speed_i * H) local steps;
        with step-based sync every worker runs exactly H.
        """
        cfg = self.cfg
        losses = []
        for i in range(self.K):
            if cfg.time_threshold_s is not None:
                n_local = max(1, int(round(self.speeds[i] * cfg.sync_every)))
            else:
                n_local = cfg.sync_every
            batches = batches_per_worker[i]
            w, opt = self.workers[i], self.opt_states[i]
            for j in range(n_local):
                batch = batches[j % len(batches)]
                w, opt, loss = self.train_step(w, opt, batch,
                                               self.step + j, lr)
                losses.append(float(loss))
            self.workers[i], self.opt_states[i] = w, opt

        self.base, self.ema, self.outer_m, info = edit_sync(
            self.base, self.workers, self.ema, self.outer_m, cfg)
        # workers restart from the synced point
        self.workers = [jax.tree.map(jnp.copy, self.base)
                        for _ in range(self.K)]
        self.step += cfg.sync_every
        rec = {"step": self.step, "mean_loss": float(np.mean(losses)),
               "kept": np.asarray(info["kept"]).tolist(),
               "weights": np.asarray(info["weights"]).round(4).tolist(),
               "pg_avg_norm": float(info["pg_avg_norm"])}
        self.history.append(rec)
        return rec


# ---------------------------------------------------------------------------
# step-time model for the Fig. 8 benchmark (no hardware required)
# ---------------------------------------------------------------------------


def simulate_sync_timeline(n_workers: int, n_steps: int, *,
                           straggler_frac: float = 0.05,
                           straggler_slowdown: float = 3.0,
                           base_step_s: float = 1.0,
                           sync_every: int = 8,
                           layer_sync_overlap: float = 0.8,
                           sync_cost_s: float = 0.5,
                           seed: int = 0) -> Dict[str, float]:
    """Wall-clock comparison: synchronous all-reduce vs EDiT.

    Baseline: every step waits for the slowest worker and pays the full
    gradient all-reduce.  EDiT: workers run locally (no per-step wait);
    every `sync_every` steps a layer-wise sync costs sync_cost_s, of which
    `layer_sync_overlap` is hidden under forward compute (prefetch).
    """
    rng = np.random.RandomState(seed)
    # per-step per-worker times with occasional stragglers
    times = base_step_s * (1 + 0.05 * rng.rand(n_steps, n_workers))
    mask = rng.rand(n_steps, n_workers) < straggler_frac
    times = np.where(mask, times * straggler_slowdown, times)

    sync_wall = float(np.sum(times.max(axis=1) + sync_cost_s))
    # EDiT: each worker proceeds at its own pace between syncs
    edit_wall = 0.0
    for s0 in range(0, n_steps, sync_every):
        seg = times[s0:s0 + sync_every]
        per_worker = seg.sum(axis=0)
        edit_wall += float(per_worker.max()) \
            + sync_cost_s * (1.0 - layer_sync_overlap)
    speedup = sync_wall / edit_wall
    return {"sync_wall_s": sync_wall, "edit_wall_s": edit_wall,
            "speedup": speedup,
            "time_saved_frac": 1.0 - edit_wall / sync_wall}
