"""Runtime tracing contracts shared by the engines and the tier-1 tests.

Three guards, each the mechanical form of an invariant this repo used to
pin by hand:

* `CompileCounter` / `compile_guard` — trace-time compile counting.  The
  engine's 1-prefill/1-decode/1-draft/1-verify contract and the
  bs-warmup one-compile-per-stage contract were previously four separate
  hand-rolled closures; they now share one counter type and one guard.
* `transfer_guard` — a thin wrapper over ``jax.transfer_guard`` for the
  hot loops.  NOTE: jax's transfer guards are enforced on TPU/GPU
  backends but are a no-op on the CPU backend (CPU "transfers" are
  zero-copy), so on CPU CI this wrapper is best-effort: it still
  exercises the code path and catches API misuse, while on real
  hardware it turns any unannounced device→host sync into an error.
* `donation_check` — verifies donated buffers really were consumed
  (``is_deleted()``) after a donating call, catching silently-dropped
  ``donate_argnums`` (e.g. an aliasing mismatch downgraded to a copy).

Debug-mode wiring: `Trainer` and `OnlineEngine` enable `transfer_guard`
around their per-step loops when constructed with ``debug_guards=True``
(default comes from the ``REPRO_DEBUG_GUARDS`` env var), which is how
the engine-parity CI leg runs.
"""
from __future__ import annotations

import contextlib
import os
from typing import Callable, Dict, Mapping, Optional, Union

import jax


class CompileGuardError(AssertionError):
    """A compile_guard limit was violated (unexpected retrace)."""


class DonationError(AssertionError):
    """A donated buffer was not consumed by the donating call."""


def env_debug_guards(default: bool = False) -> bool:
    """Default for the engines' ``debug_guards`` flag: the
    ``REPRO_DEBUG_GUARDS`` env var ("1"/"true"/"yes" enable)."""
    raw = os.environ.get("REPRO_DEBUG_GUARDS")
    if raw is None:
        return default
    return raw.strip().lower() in ("1", "true", "yes", "on")


class CompileCounter:
    """Counts XLA traces per label.

    ``counter.jit(label, fn, **jit_kwargs)`` wraps ``fn`` so the counter
    increments at *trace* time — i.e. exactly once per compilation for
    fixed shapes — then applies ``jax.jit``.  This is the same
    trace-time-closure trick the engines used ad hoc; centralizing it
    means `compile_guard` can assert on any subset of labels.
    """

    def __init__(self):
        self.counts: Dict[str, int] = {}

    def bump(self, label: str) -> None:
        """Record one trace for `label` (for callers that already have a
        traced function and just want the bookkeeping)."""
        self.counts[label] = self.counts.get(label, 0) + 1

    def jit(self, label: str, fn: Callable, **jit_kwargs) -> Callable:
        self.counts.setdefault(label, 0)

        def traced(*args, **kwargs):
            self.bump(label)  # runs at trace time, not per call
            return fn(*args, **kwargs)

        return jax.jit(traced, **jit_kwargs)

    def __getitem__(self, label: str) -> int:
        return self.counts.get(label, 0)

    def total(self) -> int:
        return sum(self.counts.values())

    def snapshot(self) -> Dict[str, int]:
        return dict(self.counts)

    def __repr__(self) -> str:
        return f"CompileCounter({self.counts!r})"


@contextlib.contextmanager
def compile_guard(limit: Union[int, Mapping[str, int]],
                  counter: Optional[CompileCounter] = None,
                  *, exact: bool = False):
    """Assert at most (or with ``exact=True``, exactly) N new traces
    happen inside the block.

    ``limit`` is either a total across all labels (int) or a per-label
    mapping; labels absent from the mapping are unconstrained.  Yields
    the counter so call sites can create one inline::

        with compile_guard({"decode": 1}, eng.compiles, exact=True):
            for _ in range(64):
                eng.tick()
    """
    counter = counter if counter is not None else CompileCounter()
    before = counter.snapshot()
    yield counter
    after = counter.snapshot()
    delta = {k: after.get(k, 0) - before.get(k, 0)
             for k in set(before) | set(after)}
    if isinstance(limit, Mapping):
        for label, lim in limit.items():
            got = delta.get(label, 0)
            bad = got != lim if exact else got > lim
            if bad:
                op = "==" if exact else "<="
                raise CompileGuardError(
                    f"compile_guard: expected {op}{lim} new traces for "
                    f"{label!r}, got {got} (delta={delta})")
    else:
        got = sum(delta.values())
        bad = got != limit if exact else got > limit
        if bad:
            op = "==" if exact else "<="
            raise CompileGuardError(
                f"compile_guard: expected {op}{limit} new traces total, "
                f"got {got} (delta={delta})")


@contextlib.contextmanager
def transfer_guard(level: str = "disallow"):
    """Disallow implicit device→host transfers inside the block.

    Levels are jax's: "allow", "log", "disallow", "disallow_explicit".
    Enforced on TPU/GPU; the CPU backend never fires transfer guards
    (host and device memory are the same), so this is a structural no-op
    there — kept active anyway so the same test code is load-bearing the
    moment it runs on real hardware.
    """
    with jax.transfer_guard_device_to_host(level):
        yield


def donation_check(fn: Callable, donate_argnums, *args, **kwargs):
    """Call ``fn(*args, **kwargs)`` and verify every jax-array leaf of
    the arguments at ``donate_argnums`` positions was consumed
    (``is_deleted()``).  Returns ``fn``'s result.

    Use on a handle jitted with the same ``donate_argnums``: if XLA
    silently downgraded donation to a copy (aliasing/layout mismatch) or
    the wrapper dropped the donate flags, this raises `DonationError`
    instead of letting the train step double its parameter memory.
    """
    if isinstance(donate_argnums, int):
        donate_argnums = (donate_argnums,)
    out = fn(*args, **kwargs)
    jax.block_until_ready(out)
    for i in donate_argnums:
        if i >= len(args):
            continue
        for leaf in jax.tree.leaves(args[i]):
            if isinstance(leaf, jax.Array) and not leaf.is_deleted():
                raise DonationError(
                    f"donation_check: argument #{i} has a live leaf "
                    f"(shape={leaf.shape}, dtype={leaf.dtype}) after the "
                    f"donating call — donation was dropped (aliasing "
                    f"mismatch or missing donate_argnums on the jit)")
    return out
