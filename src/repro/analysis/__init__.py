"""Static analysis + runtime tracing contracts for the JAX/Pallas hot paths.

Two halves, one goal — the paper's "every FLOP counts" discipline held
mechanically instead of re-discovered per PR:

* `flopcheck` — an AST linter with repo-specific rules (hidden per-step
  host syncs, recompile hazards, Pallas tracing pitfalls, donated-buffer
  reuse, unlocked shared state, removed jax APIs).  Run it with
  ``python scripts/flopcheck.py --strict`` or call `check_paths`.
* `contracts` — runtime guards the engines and tier-1 tests share:
  `CompileCounter`/`compile_guard` (the one place the 1-prefill/1-decode
  /1-draft/1-verify and one-compile-per-warmup-stage invariants live),
  `transfer_guard` (jax transfer-guard wrapper for the hot loops), and
  `donation_check` (donated buffers really were consumed).

See docs/analysis.md for the rule catalog and the historical bug each
rule would have caught.
"""
from repro.analysis.flopcheck import (  # noqa: F401
    RULES,
    Violation,
    check_file,
    check_paths,
    check_source,
)
from repro.analysis.contracts import (  # noqa: F401
    CompileCounter,
    CompileGuardError,
    DonationError,
    compile_guard,
    donation_check,
    transfer_guard,
)
