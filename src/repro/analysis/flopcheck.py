"""flopcheck — repo-specific AST linter for the JAX/Pallas hot paths.

Every rule encodes an invariant a past PR broke by hand (docs/analysis.md
carries the catalog with the historical bug each rule would have caught):

  FC-HOSTSYNC    hidden per-step host syncs: ``float()/int()/bool()/
                 .item()/np.asarray`` on values that dataflow from jitted
                 step outputs inside per-step loops, or eager conversion
                 of device computations in the Trainer/OnlineEngine tick
                 paths (the PR-4 ``float(sched(i))`` LR bug).  Values
                 drained through ``jax.device_get`` are host data and
                 never flag.
  FC-RECOMPILE   recompile hazards: ``jax.jit``/``shard_map`` constructed
                 inside a loop (a fresh jit wrapper per iteration defeats
                 the compile cache), and unhashable freshly-constructed
                 objects (lambdas, dict/list/set literals, non-frozen
                 dataclasses) passed in ``static_argnums``/
                 ``static_argnames`` positions.
  FC-PALLAS      Pallas tracing pitfalls: ``pl.program_id`` inside a
                 ``pl.when`` region (the PR-1 interpret-mode bug — the
                 evaluator does not substitute program ids inside the
                 sub-jaxpr), side-effecting host calls (``print``,
                 ``time.time`` ...) inside kernel bodies, and
                 ``pl.pallas_call`` sites that do not plumb ``interpret=``.
  FC-DONATE      reuse of a buffer after it was passed at a
                 ``donate_argnums`` position of a jitted call in the same
                 scope — the buffer is deleted at dispatch.
  FC-LOCK        methods of classes owning a ``threading.Lock/RLock``
                 that WRITE lock-guarded attributes without holding it
                 (the DataPipeline main-thread/prefetcher race fixed by
                 hand in PR 4).  Private (``_``-prefixed) methods are
                 assumed to be called under the lock and are not flagged.
  FC-DEPRECATED  removed/renamed jax APIs (``jax.tree_map`` et al.).
  FC-TELEMETRY   host clock reads (``time.time``/``perf_counter``/
                 ``monotonic``) or telemetry-registry writes
                 (``.observe``/``.inc``/``.sample`` on metric objects)
                 inside a jit-traced body — both run ONCE at trace time,
                 so the compiled step bakes in a stale constant and the
                 metric never updates again.  Time and record around the
                 jitted call on the host (the OnlineEngine/Trainer
                 idiom), never inside it.

Suppression: append ``# flopcheck: disable=FC-RULE`` (comma-separate for
several rules) to the flagged line, or put it on its own line directly
above; ``# flopcheck: disable-file=FC-RULE`` anywhere disables a rule for
the whole file.  ``scripts/flopcheck.py --strict`` requires every
violation to be suppressed *with a comment* — silent violations fail CI.

The analysis is intraprocedural and heuristic by design: it trades
soundness for zero-configuration signal on this repo's idioms (jitted
handles are recognized by ``jax.jit``/``shard_map`` assignments and by
the ``make_*``/``jit_*``/``for_accum`` factory naming convention).
"""
from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

RULES: Dict[str, str] = {
    "FC-HOSTSYNC": "hidden per-step host sync on device values",
    "FC-RECOMPILE": "jit/shard_map recompile hazard",
    "FC-PALLAS": "Pallas kernel tracing pitfall",
    "FC-DONATE": "donated buffer reused after the donating call",
    "FC-LOCK": "lock-guarded attribute written without the lock",
    "FC-DEPRECATED": "removed/renamed jax API",
    "FC-TELEMETRY": "host timing/metrics call inside a jit-traced body",
}

# host clock callees flagged inside traced bodies (module attr or bare
# name imported via `from time import ...`)
HOST_CLOCK_CALLS = {
    "time", "perf_counter", "perf_counter_ns", "monotonic",
    "monotonic_ns", "process_time", "time_ns",
}
# metric-object write methods (MetricsRegistry children + XPUTimer ring)
METRIC_WRITE_ATTRS = {"observe", "inc", "sample"}
# receivers whose `.sample`/`.inc` are NOT metrics (random.sample,
# np.random.sample, jnp-keyed samplers)
METRIC_SAFE_ROOTS = {"np", "numpy", "random", "jax", "jnp", "secrets"}

# jax APIs removed around 0.4.x -> replacement hint
DEPRECATED_APIS: Dict[str, str] = {
    "jax.tree_map": "jax.tree.map (or jax.tree_util.tree_map)",
    "jax.tree_multimap": "jax.tree.map",
    "jax.tree_flatten": "jax.tree.flatten",
    "jax.tree_unflatten": "jax.tree.unflatten",
    "jax.tree_leaves": "jax.tree.leaves",
    "jax.tree_structure": "jax.tree.structure",
    "jax.tree_transpose": "jax.tree_util.tree_transpose",
    "jax.tree_all": "jax.tree.all",
    "jax.xla_computation": "jax.jit(fn).lower(...)",
    "jax.abstract_arrays": "jax.core",
}

# factories whose return value is a jitted/shard_mapped step function
HANDLE_MAKER_RE = re.compile(r"^(make_|jit_)\w+$|^for_accum$")
# repo-known donation signatures: Runner.jit_train_step /
# StagedTrainStep.for_accum donate at least (params, opt_state) unless
# built with a literal donate=False
KNOWN_DONATING_MAKERS = {"jit_train_step": (0, 1), "for_accum": (0, 1)}

# hot per-step loops: the Trainer train loop and the OnlineEngine tick
# paths (plus anything matching the naming convention)
HOT_CLASSES = {"Trainer", "OnlineEngine", "FloodEngine"}
HOT_FUNC_RE = re.compile(r"^(train|tick|_drain)$|_tick$")

# callees whose results are host data (safe to convert per-step)
HOST_SAFE_LAST = {
    "host", "device_get", "len", "min", "max", "abs", "sum", "round",
    "perf_counter", "time", "monotonic", "get", "item_host", "range",
    "lr_scale_for", "stage_for", "accum_for", "batch_for", "int", "float",
    "bool", "str", "enumerate", "zip", "sorted", "count",
}
HOST_SAFE_ROOTS = {"np", "numpy", "math", "time", "os", "random"}

CONVERTERS = {"float", "int", "bool"}
MUTATORS = {"append", "appendleft", "extend", "add", "remove", "discard",
            "pop", "popleft", "clear", "update", "insert", "setdefault"}

SUPPRESS_RE = re.compile(
    r"#\s*flopcheck:\s*(disable|disable-file)\s*=\s*([A-Z0-9,\-\s]+)")


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    path: str
    line: int
    col: int
    message: str
    suppressed: bool = False

    def format(self) -> str:
        tag = " (suppressed)" if self.suppressed else ""
        return f"{self.path}:{self.line}:{self.col}: {self.rule}{tag} " \
               f"{self.message}"


@dataclasses.dataclass
class HandleInfo:
    """What we know about a jitted-callable binding."""
    donate: Tuple[int, ...] = ()
    static_nums: Tuple[int, ...] = ()
    static_names: Tuple[str, ...] = ()


@dataclasses.dataclass
class Registry:
    """Cross-file facts collected in a first pass over every checked file:
    functions jitted with static args (decorator form) and dataclasses
    whose instances are unhashable (would retrace every call as a static
    arg)."""
    static_fns: Dict[str, Tuple[Tuple[str, ...], Tuple[str, ...]]] = \
        dataclasses.field(default_factory=dict)   # name -> (params, static)
    unhashable_dataclasses: Set[str] = dataclasses.field(default_factory=set)


# ---------------------------------------------------------------------------
# small AST helpers
# ---------------------------------------------------------------------------


def dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _last(name: str) -> str:
    return name.rsplit(".", 1)[-1]


def _root(name: str) -> str:
    return name.split(".", 1)[0]


def _tuple_ints(node: ast.AST) -> Tuple[int, ...]:
    """Literal int tuple (handles the `(1,) if donate else ()` idiom by
    taking the non-empty branch)."""
    if isinstance(node, ast.IfExp):
        return _tuple_ints(node.body) or _tuple_ints(node.orelse)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int):
                out.append(e.value)
        return tuple(out)
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    return ()


def _tuple_strs(node: ast.AST) -> Tuple[str, ...]:
    if isinstance(node, ast.IfExp):
        return _tuple_strs(node.body) or _tuple_strs(node.orelse)
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(e.value for e in node.elts
                     if isinstance(e, ast.Constant)
                     and isinstance(e.value, str))
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    return ()


def _is_unhashable_literal(node: ast.AST) -> Optional[str]:
    if isinstance(node, (ast.Dict, ast.DictComp)):
        return "dict"
    if isinstance(node, (ast.List, ast.ListComp)):
        return "list"
    if isinstance(node, (ast.Set, ast.SetComp)):
        return "set"
    if isinstance(node, ast.Lambda):
        return "lambda"
    return None


def _kw(call: ast.Call, name: str) -> Optional[ast.AST]:
    for k in call.keywords:
        if k.arg == name:
            return k.value
    return None


def _has_double_star(call: ast.Call) -> bool:
    return any(k.arg is None for k in call.keywords)


def _names_in(node: ast.AST) -> Set[str]:
    """All dotted names read anywhere inside an expression."""
    out: Set[str] = set()
    for n in ast.walk(node):
        d = dotted(n)
        if d:
            out.add(d)
    return out


def _assign_targets(stmt: ast.AST) -> List[ast.AST]:
    if isinstance(stmt, ast.Assign):
        out: List[ast.AST] = []
        for t in stmt.targets:
            out.extend(t.elts if isinstance(t, (ast.Tuple, ast.List))
                       else [t])
        return out
    if isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        return [stmt.target]
    return []


def _target_names(stmt: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for t in _assign_targets(stmt):
        d = dotted(t)
        if d:
            out.add(d)
    return out


# ---------------------------------------------------------------------------
# suppression comments
# ---------------------------------------------------------------------------


def _suppressions(source: str) -> Tuple[Dict[int, Set[str]], Set[str]]:
    """(line -> rules disabled on that line, rules disabled file-wide).
    A standalone suppression comment also covers the next line."""
    per_line: Dict[int, Set[str]] = {}
    file_wide: Set[str] = set()
    try:
        toks = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenError, IndentationError):
        return per_line, file_wide
    code_lines = {t.start[0] for t in toks
                  if t.type not in (tokenize.COMMENT, tokenize.NL,
                                    tokenize.NEWLINE, tokenize.INDENT,
                                    tokenize.DEDENT, tokenize.ENDMARKER)}
    for t in toks:
        if t.type != tokenize.COMMENT:
            continue
        m = SUPPRESS_RE.search(t.string)
        if not m:
            continue
        rules = {r.strip() for r in m.group(2).split(",") if r.strip()}
        if m.group(1) == "disable-file":
            file_wide |= rules
            continue
        line = t.start[0]
        per_line.setdefault(line, set()).update(rules)
        if line not in code_lines:          # standalone comment line
            per_line.setdefault(line + 1, set()).update(rules)
    return per_line, file_wide


# ---------------------------------------------------------------------------
# registry pass (cross-file)
# ---------------------------------------------------------------------------


def _registry_scan(tree: ast.AST, reg: Registry):
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if not isinstance(dec, ast.Call):
                    continue
                d = dotted(dec.func)
                inner = dec.args[0] if dec.args else None
                if (d and _last(d) == "partial" and inner is not None
                        and (dotted(inner) or "").endswith("jit")):
                    statics = _tuple_strs(_kw(dec, "static_argnames")
                                          or ast.Constant(value=None))
                    nums = _tuple_ints(_kw(dec, "static_argnums")
                                       or ast.Constant(value=None))
                    params = tuple(a.arg for a in node.args.args)
                    names = set(statics) | {params[i] for i in nums
                                            if i < len(params)}
                    if names:
                        reg.static_fns[node.name] = (params, tuple(names))
        elif isinstance(node, ast.ClassDef):
            is_dc = frozen = has_hash = eq_false = False
            for dec in node.decorator_list:
                d = dotted(dec.func) if isinstance(dec, ast.Call) \
                    else dotted(dec)
                if d and _last(d) == "dataclass":
                    is_dc = True
                    if isinstance(dec, ast.Call):
                        fz = _kw(dec, "frozen")
                        eq = _kw(dec, "eq")
                        frozen = (isinstance(fz, ast.Constant)
                                  and fz.value is True)
                        eq_false = (isinstance(eq, ast.Constant)
                                    and eq.value is False)
            for item in node.body:
                if isinstance(item, ast.FunctionDef) \
                        and item.name == "__hash__":
                    has_hash = True
            if is_dc and not frozen and not eq_false and not has_hash:
                reg.unhashable_dataclasses.add(node.name)


def build_registry(sources: Sequence[Tuple[str, str]]) -> Registry:
    """sources: (path, source_text) pairs."""
    reg = Registry()
    for path, src in sources:
        try:
            tree = ast.parse(src, filename=path)
        except SyntaxError:
            continue
        _registry_scan(tree, reg)
    return reg


# ---------------------------------------------------------------------------
# module-level context: jitted handles, pallas alias, class lock info
# ---------------------------------------------------------------------------


def _pallas_aliases(tree: ast.AST) -> Set[str]:
    out = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name.endswith("pallas"):
                    out.add(a.asname or a.name)
        elif isinstance(node, ast.ImportFrom):
            for a in node.names:
                if a.name == "pallas":
                    out.add(a.asname or a.name)
    return out or {"pl"}


def _handle_info_from_call(call: ast.Call) -> Optional[HandleInfo]:
    """HandleInfo when `call` builds a jitted/shard_mapped callable."""
    d = dotted(call.func)
    if d is None:
        # jax.jit(f)(...) chains handled at use sites
        return None
    last = _last(d)
    if last in ("jit", "pjit"):
        donate = _tuple_ints(_kw(call, "donate_argnums")
                             or ast.Constant(value=None))
        nums = _tuple_ints(_kw(call, "static_argnums")
                           or ast.Constant(value=None))
        names = _tuple_strs(_kw(call, "static_argnames")
                            or ast.Constant(value=None))
        return HandleInfo(donate=donate, static_nums=nums,
                          static_names=names)
    if last == "shard_map":
        return HandleInfo()
    if HANDLE_MAKER_RE.match(last):
        donate: Tuple[int, ...] = ()
        if last in KNOWN_DONATING_MAKERS:
            dkw = _kw(call, "donate")
            if not (isinstance(dkw, ast.Constant) and dkw.value is False):
                donate = KNOWN_DONATING_MAKERS[last]
        return HandleInfo(donate=donate)
    return None


def _collect_handles(tree: ast.AST) -> Dict[str, HandleInfo]:
    """Names/self-attrs bound to jitted callables anywhere in the module
    (class-attribute bindings are visible across methods)."""
    handles: Dict[str, HandleInfo] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        value = node.value
        if not isinstance(value, ast.Call):
            continue
        info = _handle_info_from_call(value)
        if info is None:
            continue
        for t in _assign_targets(node):
            d = dotted(t)
            if d:
                handles[d] = info
    return handles


@dataclasses.dataclass
class LockInfo:
    lock_attrs: Set[str]
    guarded: Set[str]          # self-attrs accessed under any lock


def _with_lock_items(stmt: ast.With, lock_attrs: Set[str]) -> bool:
    for item in stmt.items:
        d = dotted(item.context_expr)
        if d and d.startswith("self.") and d[5:] in lock_attrs:
            return True
        # `with self._lock:` spelled via acquire contexts is out of scope
    return False


def _self_attr(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name) \
            and node.value.id == "self":
        return node.attr
    return None


def _attr_accesses(node: ast.AST, writes_only: bool = False
                   ) -> List[Tuple[str, ast.AST]]:
    """(attr, node) for self.<attr> accesses in `node`.  Writes are
    Store/AugAssign targets, subscript-stores (`self.x[k] = v`), and
    mutating method calls (`self.x.append(...)`)."""
    out: List[Tuple[str, ast.AST]] = []
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute):
            a = _self_attr(n)
            if a is None:
                continue
            if isinstance(n.ctx, (ast.Store, ast.Del)):
                out.append((a, n))
            elif not writes_only:
                out.append((a, n))
        if isinstance(n, ast.Subscript):
            a = _self_attr(n.value)
            if a is not None and isinstance(n.ctx, (ast.Store, ast.Del)):
                out.append((a, n))
        if isinstance(n, ast.Call):
            fn = n.func
            if isinstance(fn, ast.Attribute) and fn.attr in MUTATORS:
                a = _self_attr(fn.value)
                if a is not None:
                    out.append((a, n))
    return out


def _class_lock_info(cls: ast.ClassDef) -> Optional[LockInfo]:
    lock_attrs: Set[str] = set()
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            d = dotted(node.value.func)
            if d and _last(d) in ("Lock", "RLock"):
                for t in _assign_targets(node):
                    a = _self_attr(t)
                    if a:
                        lock_attrs.add(a)
    if not lock_attrs:
        return None
    guarded: Set[str] = set()
    for node in ast.walk(cls):
        if isinstance(node, ast.With) and _with_lock_items(node, lock_attrs):
            for a, _ in _attr_accesses(node):
                if a not in lock_attrs:
                    guarded.add(a)
    return LockInfo(lock_attrs, guarded)


# ---------------------------------------------------------------------------
# the checker
# ---------------------------------------------------------------------------


class _FileChecker:
    def __init__(self, path: str, source: str, tree: ast.Module,
                 registry: Registry):
        self.path = path
        self.source = source
        self.tree = tree
        self.registry = registry
        self.pl = _pallas_aliases(tree)
        self.handles = _collect_handles(tree)
        self.violations: List[Violation] = []
        # parent links for class/function context
        self._parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[child] = parent

    def add(self, rule: str, node: ast.AST, msg: str):
        self.violations.append(Violation(
            rule, self.path, getattr(node, "lineno", 0),
            getattr(node, "col_offset", 0), msg))

    # -- dispatch -----------------------------------------------------------
    def run(self) -> List[Violation]:
        self._check_deprecated()
        self._check_pallas()
        self._check_locks()
        self._check_telemetry()
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                cls = self._enclosing_class(node)
                _FunctionChecker(self, node,
                                 cls.name if cls else None).run()
        return self.violations

    def _enclosing_class(self, node: ast.AST) -> Optional[ast.ClassDef]:
        cur = self._parents.get(node)
        while cur is not None:
            if isinstance(cur, ast.ClassDef):
                return cur
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return None      # method-local def: not a method
            cur = self._parents.get(cur)
        return None

    # -- FC-DEPRECATED ------------------------------------------------------
    def _check_deprecated(self):
        for node in ast.walk(self.tree):
            d = dotted(node) if isinstance(node, ast.Attribute) else None
            if d in DEPRECATED_APIS and isinstance(node.ctx, ast.Load):
                self.add("FC-DEPRECATED", node,
                         f"`{d}` was removed from jax; use "
                         f"{DEPRECATED_APIS[d]}")

    # -- FC-PALLAS ----------------------------------------------------------
    def _pl_call(self, node: ast.AST, name: str) -> bool:
        if not isinstance(node, ast.Call):
            return False
        d = dotted(node.func)
        return bool(d) and _root(d) in self.pl and _last(d) == name

    def _check_pallas(self):
        kernel_fns: Set[ast.AST] = set()
        for node in ast.walk(self.tree):
            # pallas_call sites: interpret plumbed + kernel fn collection
            if isinstance(node, ast.Call):
                d = dotted(node.func)
                if d and _last(d) == "pallas_call":
                    if (_kw(node, "interpret") is None
                            and not _has_double_star(node)):
                        self.add(
                            "FC-PALLAS", node,
                            "pl.pallas_call without `interpret=` — this "
                            "repo plumbs interpret mode through every "
                            "kernel entry point (kernels run interpreted "
                            "on CPU builds)")
                    if node.args:
                        kd = dotted(node.args[0])
                        if kd:
                            kernel_fns.add(_last(kd))
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # program_id under a @pl.when decorator
                under_when = any(
                    isinstance(dec, ast.Call) and self._pl_call(dec, "when")
                    for dec in node.decorator_list)
                if under_when:
                    # decorator expressions evaluate OUTSIDE the region
                    # (`@pl.when(k == 0)` reading program_id in the
                    # condition is the legal top-level idiom) — only the
                    # body runs inside the sub-jaxpr
                    for sub in (s for b in node.body for s in ast.walk(b)):
                        if self._pl_call(sub, "program_id"):
                            self.add(
                                "FC-PALLAS", sub,
                                "pl.program_id inside a pl.when region — "
                                "the interpret-mode evaluator does not "
                                "substitute program ids inside sub-jaxprs;"
                                " read it at the kernel top level and "
                                "close over the value")
                # side effects inside kernel bodies
                is_kernel = node.name in kernel_fns or any(
                    self._pl_call(sub, n) for sub in ast.walk(node)
                    for n in ("program_id", "when", "load", "store"))
                if is_kernel:
                    self._check_kernel_side_effects(node)
            elif isinstance(node, ast.Call):
                # pl.when(cond)(lambda: ... program_id ...)
                if isinstance(node.func, ast.Call) \
                        and self._pl_call(node.func, "when"):
                    for arg in node.args:
                        for sub in ast.walk(arg):
                            if self._pl_call(sub, "program_id"):
                                self.add(
                                    "FC-PALLAS", sub,
                                    "pl.program_id inside a pl.when "
                                    "region — hoist it out")

    def _check_kernel_side_effects(self, fn: ast.AST):
        for sub in ast.walk(fn):
            if not isinstance(sub, ast.Call):
                continue
            d = dotted(sub.func)
            if d is None:
                continue
            if d in ("print", "breakpoint", "input") or (
                    _root(d) in ("time", "datetime")
                    and _last(d) in ("time", "perf_counter", "monotonic",
                                     "now", "today", "utcnow")):
                self.add(
                    "FC-PALLAS", sub,
                    f"side-effecting host call `{d}` inside a Pallas "
                    f"kernel body — it runs once at trace time, never "
                    f"per grid step (use pl.debug_print)")

    # -- FC-TELEMETRY -------------------------------------------------------
    def _is_jit_decorator(self, dec: ast.AST) -> bool:
        d = dotted(dec)
        if d and _last(d) in ("jit", "pjit"):
            return True
        if isinstance(dec, ast.Call):
            d = dotted(dec.func)
            if d and _last(d) in ("jit", "pjit"):
                return True        # @jax.jit(donate_argnums=...) form
            if d and _last(d) == "partial" and dec.args:
                inner = dotted(dec.args[0])
                if inner and _last(inner) in ("jit", "pjit"):
                    return True
        return False

    def _jitted_fn_names(self) -> Set[str]:
        """Function names whose bodies run under jax tracing: decorated
        with jit, passed to a jit()/pjit()/shard_map() call, or inner
        defs returned by a ``make_*``/``jit_*`` step factory (the repo
        convention — the caller always jits the returned callable)."""
        jitted: Set[str] = set()
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if any(self._is_jit_decorator(dec)
                       for dec in node.decorator_list):
                    jitted.add(node.name)
            elif isinstance(node, ast.Call):
                d = dotted(node.func)
                if d and _last(d) in ("jit", "pjit", "shard_map") \
                        and node.args:
                    ad = dotted(node.args[0])
                    if ad:
                        jitted.add(_last(ad))
        for node in ast.walk(self.tree):
            if isinstance(node, ast.FunctionDef) \
                    and HANDLE_MAKER_RE.match(node.name):
                inner = {s.name for s in ast.walk(node)
                         if isinstance(s, ast.FunctionDef) and s is not node}
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Return) and sub.value is not None:
                        rd = dotted(sub.value)
                        if rd and rd in inner:
                            jitted.add(rd)
        return jitted

    def _check_telemetry(self):
        jitted = self._jitted_fn_names()
        if not jitted:
            return
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name in jitted:
                self._check_traced_body(node)

    def _check_traced_body(self, fn: ast.AST):
        for sub in ast.walk(fn):
            if not isinstance(sub, ast.Call):
                continue
            d = dotted(sub.func)
            if d is not None and _last(d) in HOST_CLOCK_CALLS and (
                    _root(d) in ("time", "datetime")
                    or "." not in d):
                self.add(
                    "FC-TELEMETRY", sub,
                    f"host clock `{d}()` inside jit-traced `{fn.name}` — "
                    f"it runs once at trace time and bakes a constant "
                    f"timestamp into the compiled graph; time on the "
                    f"host around the jitted call (XPUTimer.span)")
                continue
            if isinstance(sub.func, ast.Attribute) \
                    and sub.func.attr in METRIC_WRITE_ATTRS:
                rd = dotted(sub.func.value)
                if rd and _root(rd) in METRIC_SAFE_ROOTS:
                    continue
                self.add(
                    "FC-TELEMETRY", sub,
                    f"metrics write `.{sub.func.attr}()` inside "
                    f"jit-traced `{fn.name}` — the registry accepts "
                    f"host scalars only and the write executes once at "
                    f"trace time, never per step; record after draining "
                    f"outputs on the host")

    # -- FC-LOCK ------------------------------------------------------------
    def _check_locks(self):
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            info = _class_lock_info(node)
            if info is None:
                continue
            for item in node.body:
                if not isinstance(item, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                if item.name.startswith("_"):
                    # private helpers are assumed called under the lock
                    # (__init__ runs before any concurrency exists)
                    continue
                self._check_method_locking(node, item, info)

    def _check_method_locking(self, cls: ast.ClassDef, method: ast.AST,
                              info: LockInfo):
        locked_spans: List[Tuple[int, int]] = []
        for node in ast.walk(method):
            if isinstance(node, ast.With) \
                    and _with_lock_items(node, info.lock_attrs):
                locked_spans.append((node.lineno, node.end_lineno or
                                     node.lineno))

        def under_lock(n: ast.AST) -> bool:
            ln = getattr(n, "lineno", 0)
            return any(a <= ln <= b for a, b in locked_spans)

        for attr, node in _attr_accesses(method, writes_only=True):
            if attr in info.guarded and not under_lock(node):
                self.add(
                    "FC-LOCK", node,
                    f"{cls.name}.{method.name} writes `self.{attr}` "
                    f"without holding the lock that guards it elsewhere "
                    f"(`self.{sorted(info.lock_attrs)[0]}`)")


class _FunctionChecker:
    """Per-function forward pass: loop depth, jit-output taint, donated
    buffers, hot-path conversion checks, jit-in-loop detection."""

    def __init__(self, file_checker: _FileChecker, fn: ast.AST,
                 cls_name: Optional[str]):
        self.fc = file_checker
        self.fn = fn
        self.cls = cls_name
        self.loop_depth = 0
        self.tainted: Set[str] = set()
        self.donated: Dict[str, int] = {}   # name -> line donated
        self.hot = (cls_name in HOT_CLASSES
                    or bool(HOT_FUNC_RE.match(fn.name)))

    # -- entry --------------------------------------------------------------
    def run(self):
        for stmt in self.fn.body:
            self._stmt(stmt)

    # -- statement walk (source order, loop tracking) ------------------------
    def _stmt(self, stmt: ast.AST):
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return            # nested defs are visited separately
        if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
            header = [stmt.iter] if isinstance(
                stmt, (ast.For, ast.AsyncFor)) else [stmt.test]
            for e in header:
                self._scan_expr(e, stmt)
            self.loop_depth += 1
            for s in stmt.body:
                self._stmt(s)
            self.loop_depth -= 1
            for s in stmt.orelse:
                self._stmt(s)
            return
        if isinstance(stmt, ast.If):
            self._scan_expr(stmt.test, stmt)
            for s in stmt.body + stmt.orelse:
                self._stmt(s)
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._scan_expr(item.context_expr, stmt)
            for s in stmt.body:
                self._stmt(s)
            return
        if isinstance(stmt, ast.Try):
            for field in ("body", "orelse", "finalbody"):
                for s in getattr(stmt, field, []):
                    self._stmt(s)
            for h in stmt.handlers:
                for s in h.body:
                    self._stmt(s)
            return
        # simple statement: run expression checks, then update state
        self._scan_expr(stmt, stmt)
        self._update_state(stmt)

    def _scan_expr(self, expr: ast.AST, stmt: ast.AST):
        """Check calls in one expression (or simple statement)."""
        self._scan_calls(expr, comp_depth=0)
        # donated-buffer reads (any Load of a donated name after donation)
        if self.donated:
            targets = _target_names(stmt)
            reads = _names_in(expr) - targets
            for name in sorted(self.donated):
                if name in reads and not self._is_donation_stmt(stmt, name):
                    self.fc.add(
                        "FC-DONATE", expr,
                        f"`{name}` was donated to a jitted call at line "
                        f"{self.donated[name]} and is read again — the "
                        f"buffer is deleted at dispatch; rebind the "
                        f"result or drop donation")
                    del self.donated[name]

    def _is_donation_stmt(self, stmt: ast.AST, name: str) -> bool:
        """The donating call itself mentions the name as an argument."""
        return getattr(stmt, "lineno", -1) == self.donated.get(name)

    def _scan_calls(self, node: ast.AST, comp_depth: int):
        """Recursive call scan tracking comprehension nesting —
        comprehensions are per-element loops for the host-sync rules,
        but building a bounded handle table `{a: jax.jit(...) for a in
        stages}` before the hot loop is the repo idiom, so they do NOT
        count for the jit-in-loop rule."""
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                             ast.GeneratorExp)):
            comp_depth += 1
        if isinstance(node, ast.Call):
            self._check_call(node, comp_depth)
        for child in ast.iter_child_nodes(node):
            self._scan_calls(child, comp_depth)

    # -- per-call checks ----------------------------------------------------
    def _check_call(self, call: ast.Call, comp_depth: int = 0):
        d = dotted(call.func)
        in_loop = self.loop_depth > 0 or comp_depth > 0

        # FC-RECOMPILE: jit/shard_map built inside a loop (real
        # statement loops only — see _scan_calls on comprehensions)
        if d and _last(d) in ("jit", "pjit", "shard_map") \
                and self.loop_depth > 0:
            self.fc.add(
                "FC-RECOMPILE", call,
                f"`{d}` constructed inside a loop — each iteration builds "
                f"a fresh wrapper with an empty compile cache; hoist it "
                f"out of the loop")

        # FC-RECOMPILE: unhashable values in static positions
        self._check_static_args(call, d)

        # FC-HOSTSYNC: conversions
        if d in CONVERTERS and len(call.args) == 1:
            self._check_conversion(call, call.args[0], d, in_loop)
        elif d and _last(d) in ("asarray", "array") \
                and _root(d) in ("np", "numpy") and call.args:
            if self._is_tainted(call.args[0]) and in_loop:
                self.fc.add(
                    "FC-HOSTSYNC", call,
                    "np.asarray on a jitted-step output inside a loop "
                    "blocks on the device per iteration — drain once "
                    "via jax.device_get at the loop boundary")
        elif isinstance(call.func, ast.Attribute) \
                and call.func.attr == "item" and not call.args:
            if self._is_tainted(call.func.value) and in_loop:
                self.fc.add(
                    "FC-HOSTSYNC", call,
                    ".item() on a jitted-step output inside a loop is a "
                    "per-iteration host sync — batch the drain")

    def _check_conversion(self, call: ast.Call, arg: ast.AST, conv: str,
                          in_loop: bool):
        if self._is_tainted(arg) and in_loop:
            self.fc.add(
                "FC-HOSTSYNC", call,
                f"{conv}() on a value flowing from a jitted step inside "
                f"a per-step loop — an undrained device metric blocks "
                f"the dispatch pipeline every iteration; accumulate and "
                f"drain via jax.device_get every N steps")
            return
        # hot-path form: eager conversion of a fresh call result
        # (the PR-4 `float(sched(i))` hidden LR sync)
        if self.hot and in_loop and isinstance(arg, ast.Call):
            ad = dotted(arg.func)
            if ad is None:
                return
            if _last(ad) in HOST_SAFE_LAST or _root(ad) in HOST_SAFE_ROOTS:
                return
            if self._is_cleansed(arg):
                return
            self.fc.add(
                "FC-HOSTSYNC", call,
                f"{conv}({ad}(...)) inside a hot per-step loop — if "
                f"`{ad}` computes with jnp this is a hidden per-step "
                f"device sync (evaluate host-side, e.g. a .host() "
                f"variant, or drain at the loop boundary)")

    def _check_static_args(self, call: ast.Call, d: Optional[str]):
        reg = self.fc.registry
        info: Optional[HandleInfo] = None
        params: Tuple[str, ...] = ()
        static_names: Tuple[str, ...] = ()
        static_nums: Tuple[int, ...] = ()
        if d is not None and d in self.fc.handles:
            info = self.fc.handles[d]
            static_nums, static_names = info.static_nums, info.static_names
        elif isinstance(call.func, ast.Call):
            inner = _handle_info_from_call(call.func)
            if inner is not None:
                static_nums = inner.static_nums
                static_names = inner.static_names
        elif d is not None and _last(d) in reg.static_fns:
            params, static_names = reg.static_fns[_last(d)]
        if not (static_nums or static_names):
            return

        def flag(node: ast.AST, what: str, where: str):
            self.fc.add(
                "FC-RECOMPILE", node,
                f"{what} passed as static arg {where} — unhashable or "
                f"freshly constructed every call, so the jit cache "
                f"misses and the step recompiles")

        for i, arg in enumerate(call.args):
            is_static = i in static_nums or (
                params and i < len(params) and params[i] in static_names)
            if not is_static:
                continue
            kind = _is_unhashable_literal(arg)
            if kind:
                flag(arg, f"{kind} literal", f"#{i}")
            elif isinstance(arg, ast.Call):
                cd = dotted(arg.func)
                if cd and _last(cd) in reg.unhashable_dataclasses:
                    flag(arg, f"fresh `{_last(cd)}` instance (dataclass "
                         f"without frozen=True/__hash__)", f"#{i}")
        for k in call.keywords:
            if k.arg is None or k.arg not in static_names:
                continue
            kind = _is_unhashable_literal(k.value)
            if kind:
                flag(k.value, f"{kind} literal", f"`{k.arg}=`")
            elif isinstance(k.value, ast.Call):
                cd = dotted(k.value.func)
                if cd and _last(cd) in reg.unhashable_dataclasses:
                    flag(k.value, f"fresh `{_last(cd)}` instance "
                         f"(dataclass without frozen=True/__hash__)",
                         f"`{k.arg}=`")

    # -- taint machinery ----------------------------------------------------
    def _is_cleansed(self, node: ast.AST) -> bool:
        """Expression routed through jax.device_get (an explicit drain)."""
        for n in ast.walk(node):
            if isinstance(n, ast.Call):
                cd = dotted(n.func)
                if cd and _last(cd) == "device_get":
                    return True
        return False

    def _is_tainted(self, node: ast.AST) -> bool:
        if self._is_cleansed(node):
            return False
        return bool(_names_in(node) & self.tainted)

    def _update_state(self, stmt: ast.AST):
        if not isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            return
        value = getattr(stmt, "value", None)
        if value is None:
            return
        targets = _target_names(stmt)
        # donation: calling a donating handle consumes its donated args
        for node in ast.walk(value):
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func)
            if d is None or d not in self.fc.handles:
                continue
            for i in self.fc.handles[d].donate:
                if i < len(node.args):
                    an = dotted(node.args[i])
                    if an and an not in targets:
                        self.donated[an] = getattr(stmt, "lineno", 0)
                    elif an in targets:
                        self.donated.pop(an, None)
        # taint: results of jitted-handle calls, and propagation
        tainted_value = False
        if isinstance(value, ast.Call):
            d = dotted(value.func)
            if d is not None and d in self.fc.handles:
                tainted_value = True
            elif isinstance(value.func, ast.Call):
                if _handle_info_from_call(value.func) is not None:
                    tainted_value = True
        if not tainted_value and self._is_tainted(value):
            tainted_value = True
        for t in targets:
            if tainted_value:
                self.tainted.add(t)
            else:
                self.tainted.discard(t)
            self.donated.pop(t, None)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def check_source(source: str, path: str = "<string>",
                 registry: Optional[Registry] = None) -> List[Violation]:
    """All violations in one source blob (suppressed ones included, with
    `.suppressed` set — filter on it for enforcement)."""
    registry = registry or build_registry([(path, source)])
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Violation("FC-SYNTAX", path, e.lineno or 0, e.offset or 0,
                          f"syntax error: {e.msg}")]
    per_line, file_wide = _suppressions(source)
    raw = _FileChecker(path, source, tree, registry).run()
    out = []
    for v in raw:
        disabled = v.rule in file_wide or v.rule in per_line.get(v.line,
                                                                 set())
        out.append(dataclasses.replace(v, suppressed=disabled))
    return sorted(out, key=lambda v: (v.line, v.col, v.rule))


def check_file(path, registry: Optional[Registry] = None) -> List[Violation]:
    p = Path(path)
    return check_source(p.read_text(), str(p), registry)


def iter_py_files(paths: Sequence, exclude: Sequence[str] = ()):
    for root in paths:
        root = Path(root)
        files = [root] if root.is_file() else sorted(root.rglob("*.py"))
        for f in files:
            s = str(f)
            if any(e in s for e in exclude):
                continue
            yield f


def check_paths(paths: Sequence, exclude: Sequence[str] = ()
                ) -> List[Violation]:
    """Two-phase check: build the cross-file registry (static-arg'd jit
    functions, unhashable dataclasses), then lint every file."""
    files = list(iter_py_files(paths, exclude))
    sources = [(str(f), f.read_text()) for f in files]
    registry = build_registry(sources)
    out: List[Violation] = []
    for path, src in sources:
        out.extend(check_source(src, path, registry))
    return out
