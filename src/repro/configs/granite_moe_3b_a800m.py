"""granite-moe-3b-a800m [moe] — 40 fine-grained experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base family]."""
import dataclasses
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="granite-moe-3b-a800m", family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab_size=49155, block_pattern=("attn",), mlp_act="swiglu",
    moe=MoEConfig(n_experts=40, top_k=8, expert_d_ff=512,
                  n_shared_experts=0),
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=2, expert_d_ff=256,
                      n_shared_experts=0, router_warmup_steps=4))
