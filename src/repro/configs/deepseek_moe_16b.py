"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained
[arXiv:2401.06066].  The closest published design to Ling's own MoE."""
import dataclasses
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek-moe-16b", family="moe", source="arXiv:2401.06066",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab_size=102400, block_pattern=("attn",), mlp_act="swiglu",
    moe=MoEConfig(n_experts=64, top_k=6, expert_d_ff=1408,
                  n_shared_experts=2),
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=2, expert_d_ff=256,
                      n_shared_experts=1, router_warmup_steps=4))
