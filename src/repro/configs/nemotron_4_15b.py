"""nemotron-4-15b [dense] — GQA, squared-ReLU MLP [arXiv:2402.16819]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="nemotron-4-15b", family="dense", source="arXiv:2402.16819",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=24576,
    vocab_size=256000, block_pattern=("attn",), mlp_act="squared_relu",
    norm_type="layernorm",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
        vocab_size=512)
