"""h2o-danube-1.8b [dense] — llama+mistral mix, sliding-window attention
[arXiv:2401.16818].  SWA makes it sub-quadratic, so long_500k runs."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="h2o-danube-1.8b", family="dense", source="arXiv:2401.16818",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8, d_ff=6912,
    vocab_size=32000, block_pattern=("swa",), attn_window=4096,
    mlp_act="swiglu",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
        vocab_size=512, attn_window=64)
