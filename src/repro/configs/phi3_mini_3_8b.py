"""phi3-mini-3.8b [dense] — RoPE SwiGLU GQA [arXiv:2404.14219]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi3-mini-3.8b", family="dense", source="arXiv:2404.14219",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32064, block_pattern=("attn",), mlp_act="swiglu",
    rope_theta=10_000.0,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
        vocab_size=512)
