"""Config system for the Ling reproduction framework.

Every architecture (the paper's own Ling models plus the 10 assigned
public-literature architectures) is described by a single `ModelConfig`
dataclass.  Input shapes are described by `ShapeConfig`.  The registry at the
bottom is what ``--arch <id>`` resolves against.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Fine-grained MoE settings (paper §3.2.1–3.2.2)."""

    n_experts: int                 # routed experts (fine-grained)
    top_k: int                     # experts activated per token
    expert_d_ff: int               # intermediate size of each routed expert
    n_shared_experts: int = 0      # always-on shared experts (Eq. 2)
    shared_d_ff: Optional[int] = None  # defaults to expert_d_ff * n_shared
    capacity_factor: float = 2.0   # EP-path buffer headroom (dropless path ignores)
    # Preferred dispatch mode for this arch: "auto" | "fused" | "ragged" |
    # "batched" | "ep".  "auto" defers to the runtime heuristic in
    # core/moe.py::moe_ffn (interpret builds: fused at tp=1, ep at tp>1;
    # real TPUs: ragged/batched until the ROADMAP tile sweep).  A RunFlags
    # override (models/model.py) takes precedence over this knob.
    dispatch: str = "auto"
    balance_loss_coef: float = 0.015   # paper §3.4.1
    z_loss_coef: float = 1e-4          # paper §3.4.1
    router_warmup_steps: int = 100     # stochastic routing warmup W (Eq. 3)
    first_dense_layers: int = 0    # leading layers that use a dense FFN

    @property
    def shared_ff(self) -> int:
        if self.shared_d_ff is not None:
            return self.shared_d_ff
        return self.expert_d_ff * max(self.n_shared_experts, 1)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A composable decoder (or encoder-decoder) transformer description."""

    arch_id: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    source: str                    # citation for the config numbers

    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None     # defaults to d_model // n_heads
    # Per-layer block kinds, cycled over layers.  Kinds:
    #   "attn"   full causal self attention
    #   "swa"    sliding-window attention (window = attn_window)
    #   "rglru"  RG-LRU recurrent block (RecurrentGemma)
    #   "rwkv"   RWKV6 time-mix block (attention free)
    block_pattern: Tuple[str, ...] = ("attn",)
    attn_window: Optional[int] = None  # sliding/local attention window
    mlp_act: str = "swiglu"            # swiglu | squared_relu | gelu
    norm_type: str = "rmsnorm"         # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    use_rope: bool = True
    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    norm_head: bool = True             # paper §3.2.3 NormHead (C4)

    # Encoder-decoder (whisper-style).  The modality frontend is the one
    # allowed stub: input_specs() provides precomputed frame embeddings.
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq_len: int = 0           # e.g. 1500 audio frames

    # VLM early fusion: image tokens are ordinary vocabulary entries
    # (Chameleon); the VQ image tokenizer is the stubbed frontend.
    early_fusion_vlm: bool = False

    # rwkv6 specifics
    rwkv_head_dim: int = 64

    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # -- derived ------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    def block_kind(self, layer: int) -> str:
        return self.block_pattern[layer % len(self.block_pattern)]

    @property
    def uniform_blocks(self) -> bool:
        return len(set(self.block_pattern)) == 1

    @property
    def sub_quadratic(self) -> bool:
        """True if no block requires O(S^2) full attention (long_500k gate)."""
        return all(k != "attn" for k in self.block_pattern)

    def param_count(self) -> int:
        """Analytic total parameter count (embeddings included once)."""
        d, v = self.d_model, self.vocab_size
        hd = self.head_dim
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d  # lm head
        enc = 0
        if self.is_encoder_decoder:
            enc = self.encoder_layers * (
                (self.n_heads + 2 * self.n_kv_heads) * hd * d + self.n_heads * hd * d
                + self._mlp_params(self.d_ff) + 2 * d)
            # decoder cross attention
            n += self.n_layers * ((self.n_heads + 2 * self.n_kv_heads) * hd * d
                                  + self.n_heads * hd * d + d)
        for layer in range(self.n_layers):
            kind = self.block_kind(layer)
            if kind in ("attn", "swa"):
                n += (self.n_heads + 2 * self.n_kv_heads) * hd * d
                n += self.n_heads * hd * d
            elif kind == "rglru":
                dr = _rglru_dim(d)
                n += 2 * d * dr + dr * d + 3 * dr + 2 * dr * (dr // _RGLRU_BLOCKS)
            elif kind == "rwkv":
                nh = d // self.rwkv_head_dim
                n += 4 * d * d + d * nh * self.rwkv_head_dim  # r,k,v,o,g approx
                n += 2 * (d * 32 + 32 * d)  # lora-style decay/mix
            n += self._ffn_params(layer)
            n += 2 * d  # norms
        return n + enc

    def _mlp_params(self, ff: int) -> int:
        mult = 3 if self.mlp_act == "swiglu" else 2
        return mult * self.d_model * ff

    def _ffn_params(self, layer: int) -> int:
        if self.moe is None or layer < self.moe.first_dense_layers:
            return self._mlp_params(self.d_ff)
        m = self.moe
        n = m.n_experts * self._mlp_params(m.expert_d_ff)
        if m.n_shared_experts:
            n += self._mlp_params(m.shared_ff)
        n += self.d_model * m.n_experts  # router
        return n

    def active_param_count(self) -> int:
        """Parameters activated per token (MoE: top_k + shared only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full_moe_layers = self.n_layers - m.first_dense_layers
        inactive = full_moe_layers * (m.n_experts - m.top_k) * self._mlp_params(m.expert_d_ff)
        return self.param_count() - inactive


_RGLRU_BLOCKS = 1


def _rglru_dim(d_model: int) -> int:
    """RecurrentGemma uses an RNN width slightly larger than d_model."""
    return d_model


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = [
    "phi3-mini-3.8b",
    "rwkv6-3b",
    "chameleon-34b",
    "h2o-danube-1.8b",
    "deepseek-moe-16b",
    "granite-moe-3b-a800m",
    "moonshot-v1-16b-a3b",
    "whisper-tiny",
    "recurrentgemma-2b",
    "nemotron-4-15b",
    "ling-lite",
    "ling-plus",
]

_MODULE_FOR = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULE_FOR)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch_id]}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    """Reduced same-family variant: <=2 layers, d_model<=512, <=4 experts."""
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch_id]}")
    return mod.smoke_config()


def supported_shapes(cfg: ModelConfig) -> Sequence[str]:
    """long_500k only for sub-quadratic (SSM / hybrid / SWA) architectures."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic or all(k in ("swa", "rglru", "rwkv") for k in cfg.block_pattern):
        out.append("long_500k")
    return out
