"""moonshot-v1-16b-a3b — MoE 64e top-6 + 2 shared
[hf:moonshotai/Moonlight-16B-A3B].  Listed [dense] in the pool but the spec
carries `MoE 64e top-6`, so it is built as the published Moonlight MoE."""
import dataclasses
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="moonshot-v1-16b-a3b", family="moe",
    source="hf:moonshotai/Moonlight-16B-A3B",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab_size=163840, block_pattern=("attn",), mlp_act="swiglu",
    moe=MoEConfig(n_experts=64, top_k=6, expert_d_ff=1408,
                  n_shared_experts=2),
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=2, expert_d_ff=256,
                      n_shared_experts=1, router_warmup_steps=4))
