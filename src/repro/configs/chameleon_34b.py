"""chameleon-34b [vlm] — early fusion, VQ image tokens [arXiv:2405.09818].

Early fusion means image patches arrive as ordinary vocabulary tokens from a
VQ tokenizer; that tokenizer is the allowed modality-frontend stub, so the
transformer consumes a plain token stream over the fused 65536 vocab.
"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="chameleon-34b", family="vlm", source="arXiv:2405.09818",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab_size=65536, block_pattern=("attn",), mlp_act="swiglu",
    early_fusion_vlm=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
        vocab_size=512)
