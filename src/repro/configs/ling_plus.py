"""Ling-Plus — the paper's 290B-total / 28.8B-activated MoE.

The paper reports only the total/activated counts; the internal dimensions
below are chosen to match those totals with the paper's fine-grained-expert
design (documented in DESIGN.md):  80L x d8192, 96 routed experts (ff 1408)
top-4 + 1 shared expert  =>  ~283B total, ~28.0B activated.
"""
import dataclasses
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="ling-plus", family="moe", source="Ling paper (this repro)",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=1408,
    vocab_size=126464, block_pattern=("attn",), mlp_act="swiglu",
    norm_head=True,
    # 96 experts over a 16-wide 'model' axis -> 6 experts/rank: the
    # all-to-all EP dispatch (core/moe.py) is the only layout at this
    # scale that does not replicate every token's FFN 16x.
    moe=MoEConfig(n_experts=96, top_k=4, expert_d_ff=1408,
                  n_shared_experts=1, balance_loss_coef=0.015,
                  z_loss_coef=1e-4, router_warmup_steps=2000,
                  dispatch="ep"),
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=2, expert_d_ff=256,
                      n_shared_experts=1, router_warmup_steps=4))
