"""Ling-Lite — the paper's 16.8B-total / 2.75B-activated MoE (§3.2, Table 5).

Internal dimensions follow the published inclusionAI/Ling-lite release:
fine-grained 64-expert top-6 MoE with one shared expert and NormHead.
"""
import dataclasses
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="ling-lite", family="moe", source="Ling paper (this repro)",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=4, d_ff=1408,
    vocab_size=126464, block_pattern=("attn",), mlp_act="swiglu",
    norm_head=True,
    moe=MoEConfig(n_experts=64, top_k=6, expert_d_ff=1408,
                  n_shared_experts=1, balance_loss_coef=0.015,
                  z_loss_coef=1e-4, router_warmup_steps=2000),
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=2, expert_d_ff=256,
                      n_shared_experts=1, router_warmup_steps=4))
