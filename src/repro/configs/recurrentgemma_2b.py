"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 2:1 pattern
[arXiv:2402.19427].  Sub-quadratic, so long_500k runs."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-2b", family="hybrid", source="arXiv:2402.19427",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab_size=256000, block_pattern=("rglru", "rglru", "swa"),
    attn_window=2048, mlp_act="geglu",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=4, n_kv_heads=1, d_ff=512,
        vocab_size=512, attn_window=64, block_pattern=("rglru", "swa"))
