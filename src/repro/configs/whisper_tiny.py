"""whisper-tiny [audio] — enc-dec transformer backbone [arXiv:2212.04356].

The mel-spectrogram + conv frontend is the allowed stub: input_specs()
provides precomputed (B, 1500, d_model) frame embeddings for the encoder.
"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-tiny", family="audio", source="arXiv:2212.04356",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
    vocab_size=51865, block_pattern=("attn",), mlp_act="gelu",
    norm_type="layernorm", use_rope=False,
    # 1500 conv frames, right-padded to 1504 by the stub frontend for
    # tp=16 divisibility of the cross-attention cache (see DESIGN.md)
    is_encoder_decoder=True, encoder_layers=4, encoder_seq_len=1504,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, encoder_layers=2, d_model=256, n_heads=4,
        n_kv_heads=4, d_ff=512, vocab_size=512, encoder_seq_len=64)
