"""rwkv6-3b [ssm] — Finch, data-dependent decay, attention-free [arXiv:2404.05892]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-3b", family="ssm", source="arXiv:2404.05892",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=8960,
    vocab_size=65536, block_pattern=("rwkv",), mlp_act="squared_relu",
    use_rope=False, rwkv_head_dim=64,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
        vocab_size=512)
