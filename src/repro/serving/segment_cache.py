"""Segment KV cache (paper §2.4, C12) — Flood's memory manager.

Instead of vLLM-style small block tables, the KV cache is one contiguous
tensor [max_token_num, ...] carved into *segments*: each request gets a
contiguous range sized conservatively; on overflow the allocator

  1. **extends** the segment if the next range is free,
  2. **appends** an additional segment to the request's segment list,
  3. **waits** (request parked on a wait-list) if neither is possible.

Contiguous segments admit large effective block sizes (better accelerator
utilization than scattered small blocks) and give **prefix caching** for
free: a shared prompt prefix is just a refcounted segment list prefix.

This allocator is pure host logic over index ranges; the tensor itself
lives in the model's decode cache.  Unit + hypothesis property tests
assert: no two live segments overlap, free list is coalesced, waiters make
progress as segments free.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Segment:
    start: int
    length: int
    refcount: int = 1

    @property
    def end(self) -> int:
        return self.start + self.length


@dataclasses.dataclass
class Request:
    rid: int
    prompt_len: int
    max_new: int
    segments: List[Segment] = dataclasses.field(default_factory=list)
    used: int = 0                      # tokens written so far
    prefix_key: Optional[str] = None   # shared-prefix cache key

    @property
    def capacity(self) -> int:
        return sum(s.length for s in self.segments)

    def slot(self, token_idx: int) -> int:
        """Global cache row for this request's token_idx."""
        off = token_idx
        for s in self.segments:
            if off < s.length:
                return s.start + off
            off -= s.length
        raise IndexError(token_idx)


class SegmentCache:
    def __init__(self, max_tokens: int, initial_segment: int = 256,
                 extend_chunk: int = 256):
        self.max_tokens = max_tokens
        self.initial = initial_segment
        self.chunk = extend_chunk
        self.free: List[Tuple[int, int]] = [(0, max_tokens)]  # (start, len)
        self.requests: Dict[int, Request] = {}
        self.wait_list: Deque[int] = deque()
        self.prefix_index: Dict[str, List[Segment]] = {}
        self.stats = {"extends": 0, "appends": 0, "waits": 0,
                      "prefix_hits": 0}

    # -- free-list helpers --------------------------------------------------
    def _alloc_range(self, length: int) -> Optional[Tuple[int, int]]:
        for i, (start, flen) in enumerate(self.free):
            if flen >= length:
                if flen == length:
                    self.free.pop(i)
                else:
                    self.free[i] = (start + length, flen - length)
                return (start, length)
        return None

    def _release_range(self, start: int, length: int):
        self.free.append((start, length))
        self.free.sort()
        merged: List[Tuple[int, int]] = []
        for s, l in self.free:
            if merged and merged[-1][0] + merged[-1][1] == s:
                merged[-1] = (merged[-1][0], merged[-1][1] + l)
            else:
                merged.append((s, l))
        self.free = merged

    def _range_free_at(self, start: int, length: int) -> bool:
        for s, l in self.free:
            if s <= start and start + length <= s + l:
                return True
        return False

    # -- admission -----------------------------------------------------------
    def admit(self, rid: int, prompt_len: int, max_new: int,
              prefix_key: Optional[str] = None,
              conservative: bool = True) -> bool:
        """Allocate an initial segment.  With `conservative` (the paper's
        strategy for huge user-specified max_output_len), the first segment
        covers the prompt plus a modest chunk rather than prompt+max_new."""
        req = Request(rid, prompt_len, max_new, prefix_key=prefix_key)
        need = prompt_len
        if prefix_key and prefix_key in self.prefix_index:
            # prefix cache hit: share the refcounted prefix segments
            shared = self.prefix_index[prefix_key]
            for s in shared:
                s.refcount += 1
            req.segments.extend(shared)
            req.used = sum(s.length for s in shared)
            need = max(prompt_len - req.used, 0)
            self.stats["prefix_hits"] += 1
        grow = self.initial if conservative else max_new
        rng = self._alloc_range(need + grow)
        if rng is None:
            self.stats["waits"] += 1
            self.wait_list.append(rid)
            return False
        req.segments.append(Segment(*rng))
        self.requests[rid] = req
        return True

    def register_prefix(self, rid: int, key: str, upto_segment: int = 1):
        req = self.requests[rid]
        shared = req.segments[:upto_segment]
        for s in shared:
            s.refcount += 1
        self.prefix_index[key] = shared

    # -- token append ----------------------------------------------------------
    def ensure_capacity(self, rid: int, n_tokens: int) -> bool:
        """Grow the request to hold n_tokens; extend > append > wait."""
        req = self.requests[rid]
        while req.capacity < n_tokens:
            last = req.segments[-1]
            # 1. extend in place if the adjacent range is free
            if last.refcount == 1 and self._range_free_at(last.end,
                                                          self.chunk):
                # carve the adjacent chunk out of the free list
                for i, (s, l) in enumerate(self.free):
                    if s <= last.end < s + l:
                        before = last.end - s
                        after = l - before - self.chunk
                        repl = []
                        if before:
                            repl.append((s, before))
                        if after:
                            repl.append((last.end + self.chunk, after))
                        self.free[i:i + 1] = repl
                        break
                last.length += self.chunk
                self.stats["extends"] += 1
                continue
            # 2. append a new segment anywhere
            rng = self._alloc_range(self.chunk)
            if rng is not None:
                req.segments.append(Segment(*rng))
                self.stats["appends"] += 1
                continue
            # 3. wait
            self.stats["waits"] += 1
            self.wait_list.append(rid)
            return False
        return True

    def write_token(self, rid: int) -> Optional[int]:
        """Reserve the next cache row; None if the request must wait."""
        req = self.requests[rid]
        if not self.ensure_capacity(rid, req.used + 1):
            return None
        slot = req.slot(req.used)
        req.used += 1
        return slot

    def write_tokens(self, rid: int, n: int) -> Optional[List[int]]:
        """Multi-token advance (speculative decode commits n accepted
        tokens at once): reserve the next n rows atomically; None if the
        request must wait (nothing reserved on failure)."""
        req = self.requests[rid]
        if not self.ensure_capacity(rid, req.used + n):
            return None
        rows = [req.slot(req.used + i) for i in range(n)]
        req.used += n
        return rows

    def rewind(self, rid: int, n: int):
        """Multi-token rewind (rejected speculative drafts): forget the
        last n written rows.  Rows written beyond a shared prefix only —
        a consumer never writes into refcounted shared segments, so the
        floor is the shared capacity it attached at admission."""
        req = self.requests[rid]
        floor = sum(s.length for s in req.segments if s.refcount > 1)
        req.used = max(req.used - n, floor, req.prompt_len)

    # -- preemption ----------------------------------------------------------
    def preempt(self, rid: int) -> List[int]:
        """Evict a live request mid-generation (pool pressure): frees its
        ranges exactly like `release` (refcount-aware, waiters revived);
        the caller owns re-admission — `admit` the same rid again later
        and re-prefill.  Returns the revived waiter rids."""
        self.stats["preempts"] = self.stats.get("preempts", 0) + 1
        return self.release(rid)

    # -- release -------------------------------------------------------------
    def release(self, rid: int) -> List[int]:
        """Free a finished request; returns rids revived from the wait
        list."""
        req = self.requests.pop(rid)
        for s in req.segments:
            s.refcount -= 1
            if s.refcount == 0:
                self._release_range(s.start, s.length)
        revived = []
        still_waiting: Deque[int] = deque()
        while self.wait_list:
            w = self.wait_list.popleft()
            if w in self.requests:
                revived.append(w)       # parked mid-generation
            else:
                still_waiting.append(w)
        self.wait_list = still_waiting
        return revived

    # -- invariants (used by property tests) -----------------------------------
    def live_ranges(self) -> List[Tuple[int, int]]:
        seen = {}
        out = []
        for req in self.requests.values():
            for s in req.segments:
                if id(s) not in seen:
                    seen[id(s)] = True
                    out.append((s.start, s.length))
        return sorted(out)

    def check_invariants(self):
        ranges = self.live_ranges() + sorted(self.free)
        ranges.sort()
        pos = 0
        total = 0
        for s, l in ranges:
            assert s >= pos, f"overlap at {s} (pos={pos})"
            pos = s + l
            total += l
        assert pos <= self.max_tokens
        # free list coalesced
        for (s1, l1), (s2, _) in zip(self.free, self.free[1:]):
            assert s1 + l1 < s2, "free list not coalesced"


# ---------------------------------------------------------------------------
# Page-table allocator — the online engine's memory manager
# ---------------------------------------------------------------------------
#
# `SegmentCache` above is Flood's host-side bookkeeping over one contiguous
# token arena: segments are variable-length ranges and the device cache
# stays a dense tensor the host indexes into.  The *online* engine
# (serving/online.py) instead stores KV on device as a pool of fixed-size
# pages indexed by per-slot page tables, so this allocator is the
# page-granular refactor of the same responsibilities: admission,
# `ensure_capacity` growth, prefix-cache sharing (refcounted *pages*
# instead of refcounted segments), and preempt-and-requeue when the pool
# runs dry.  Fixed-size pages trade SegmentCache's large contiguous
# blocks for O(1) allocation and zero external fragmentation — the trade
# vLLM made, and the right one once the device side gathers pages anyway.
#
# On top of the page pool sits the **radix prefix cache**: a trie keyed
# by page-aligned token blocks, so a node's root-path spells the exact
# token prefix whose KV its page holds.  Requests attach matching pages
# at admission with no caller coordination (content addressing replaces
# the explicit `prefix_key` registry, which survives for legacy callers),
# full pages are *published* into the trie when a request finishes
# prefill / releases / is preempted, and a deterministic leaf-first LRU
# sweep evicts unreferenced cached pages only when an allocation would
# otherwise fail — caching can never cause an OOM an uncached run would
# not hit.


@dataclasses.dataclass
class RadixNode:
    """One cached KV page.  `key` is the page's own token block; the
    concatenated keys on the root path are the full token prefix the
    page's KV was computed under (depth == logical page index, so
    absolute positions match by construction)."""
    key: Tuple[int, ...]
    page: int
    parent: Optional["RadixNode"]
    node_id: int                     # creation order (LRU tie-break)
    children: Dict[Tuple[int, ...], "RadixNode"] = \
        dataclasses.field(default_factory=dict)
    last_used: int = 0


class PageAllocator:
    """Host-side physical-page allocator for the paged device KV pools.

    Page 0 is reserved as the device scratch page (masked lanes write
    there) and is never handed out; page ids in tables are therefore
    always >= 1 for allocated logical pages and 0 for "unallocated".
    Free pages are recycled LIFO from a deterministic stack so identical
    op sequences produce identical page tables (the compile-count and
    parity tests rely on this).
    """

    def __init__(self, n_pages: int, page_size: int, reserved: int = 1):
        if n_pages <= reserved:
            raise ValueError(f"n_pages={n_pages} <= reserved={reserved}")
        self.n_pages = n_pages
        self.page_size = page_size
        self.reserved = reserved
        self.free_list: List[int] = list(range(n_pages - 1, reserved - 1,
                                               -1))   # pop() -> lowest id
        self.refcount: Dict[int, int] = {}
        self.pages: Dict[int, List[int]] = {}         # rid -> logical order
        self.shared_len: Dict[int, int] = {}          # rid -> prefix tokens
        self.prefix_index: Dict[str, List[int]] = {}
        # radix prefix cache: trie over page-aligned token blocks; each
        # node holds one refcount on its page
        self.radix_root = RadixNode(key=(), page=-1, parent=None,
                                    node_id=0)
        self._clock = 0                # LRU timestamp (bumped per op)
        self._next_node_id = 1
        self.stats = {"allocs": 0, "frees": 0, "prefix_hits": 0,
                      "preempts": 0, "alloc_failures": 0, "trims": 0,
                      "radix_hit_tokens": 0, "published": 0, "dedups": 0,
                      "evictions": 0}
        # telemetry hook: called with the page id for every radix-cache
        # eviction (the OnlineEngine wires this to its request log /
        # metrics registry; see docs/observability.md).  Host-side only.
        self.on_evict: Optional[Callable[[int], None]] = None

    # -- queries --------------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self.free_list)

    @property
    def pages_in_use(self) -> int:
        """Allocatable pages currently held (by requests, the trie, or
        pinned prefixes) — the occupancy number the engine samples into
        its `page_pool_occupancy` counter track every tick."""
        return self.n_pages - self.reserved - len(self.free_list)

    def capacity(self, rid: int) -> int:
        """Tokens the request's current pages can hold."""
        return len(self.pages[rid]) * self.page_size

    def table_row(self, rid: int, width: int):
        """The request's page table padded to `width` logical pages with
        the 0 sentinel (ready to land in the device table)."""
        row = np.zeros((width,), np.int32)
        pages = self.pages[rid]
        if len(pages) > width:
            raise ValueError(f"request {rid} holds {len(pages)} pages > "
                             f"table width {width}")
        row[:len(pages)] = pages
        return row

    # -- radix trie helpers ---------------------------------------------------
    def _blocks(self, tokens) -> List[Tuple[int, ...]]:
        ps = self.page_size
        return [tuple(int(t) for t in tokens[i * ps:(i + 1) * ps])
                for i in range(len(tokens) // ps)]

    def _iter_radix(self):
        stack = list(self.radix_root.children.values())
        while stack:
            node = stack.pop()
            yield node
            stack.extend(node.children.values())

    @property
    def n_cached_pages(self) -> int:
        """Pages currently held by the radix trie (some may also be
        attached to live requests)."""
        return sum(1 for _ in self._iter_radix())

    def match_radix(self, tokens) -> List[RadixNode]:
        """Longest trie match over the page-aligned blocks of `tokens`
        (read-only: no refcounts or LRU stamps change)."""
        node, out = self.radix_root, []
        for key in self._blocks(tokens):
            child = node.children.get(key)
            if child is None:
                break
            out.append(child)
            node = child
        return out

    def publish_radix(self, rid: int, tokens) -> int:
        """Publish the request's leading full pages into the trie, keyed
        by the token content (`tokens` = the token whose KV each written
        row holds, in row order).  Content-duplicate pages — a second
        request that raced the same prefix through prefill — are deduped
        against the existing node, so identical prefixes are stored once
        no matter how many requests computed them.  Returns the number of
        pages newly published."""
        pages = self.pages[rid]
        n_full = min(len(tokens) // self.page_size, len(pages))
        self._clock += 1
        node, new = self.radix_root, 0
        for i, key in enumerate(self._blocks(tokens)[:n_full]):
            child = node.children.get(key)
            if child is None:
                child = RadixNode(key=key, page=pages[i], parent=node,
                                  node_id=self._next_node_id)
                self._next_node_id += 1
                node.children[key] = child
                self.refcount[pages[i]] += 1
                new += 1
                self.stats["published"] += 1
            elif child.page != pages[i]:
                # same content already cached under a different physical
                # page (the _prefill_tick auto-publish race, content-
                # addressed): keep the cached copy, the request's private
                # duplicate recycles normally on release
                self.stats["dedups"] += 1
            child.last_used = self._clock
            node = child
        return new

    def _drop_node(self, node: RadixNode):
        del node.parent.children[node.key]
        self._free_page_ref(node.page)

    def evict_radix(self, n: int) -> int:
        """Evict up to `n` unreferenced cached pages, deterministic
        leaf-first LRU: only childless nodes whose page no live request
        (or explicit prefix entry) still references are candidates; the
        least-recently-used goes first (node_id breaks ties).  Interior
        nodes become evictable as their subtrees drain, so a cold chain
        dies tail-first while its hot prefix survives."""
        freed = 0
        while freed < n:
            best = None
            for node in self._iter_radix():
                if node.children or self.refcount[node.page] != 1:
                    continue
                if (best is None
                        or (node.last_used, node.node_id)
                        < (best.last_used, best.node_id)):
                    best = node
            if best is None:
                return freed
            evicted_page = best.page
            self._drop_node(best)
            freed += 1
            self.stats["evictions"] += 1
            if self.on_evict is not None:
                self.on_evict(evicted_page)
        return freed

    def flush_radix(self) -> int:
        """Drop every cached trie entry (pages still attached to live
        requests survive until those release).  Returns nodes dropped."""
        n = 0
        for node in list(self._iter_radix()):
            self._free_page_ref(node.page)
            n += 1
        self.radix_root.children.clear()
        return n

    # -- admission ------------------------------------------------------------
    def admit(self, rid: int, prefix_key: Optional[str] = None,
              prompt_len: Optional[int] = None, tokens=None) -> int:
        """Bind a request; attach refcounted prefix pages on a hit.

        With `tokens` (the token sequence the request will prefill), the
        attach is **content-addressed**: the radix trie is walked with
        the page-aligned blocks of `tokens` and every matching cached
        page attaches automatically — no caller coordination.  The match
        is exact by construction, so no clamp is needed beyond full-page
        coverage of the request's own tokens.

        The legacy path attaches `prefix_key`'s published pages, capped
        by `prompt_len` — a consumer whose prompt is shorter than the
        published prefix must not attach (and later decode-write into)
        shared pages beyond it.

        Returns the number of tokens already covered (0 on a miss) —
        the engine starts prefilling there."""
        assert rid not in self.pages, f"rid {rid} already admitted"
        self.pages[rid] = []
        self.shared_len[rid] = 0
        if tokens is not None:
            matched = self.match_radix(tokens)
            self._clock += 1
            for node in matched:
                self.refcount[node.page] += 1
                node.last_used = self._clock
            self.pages[rid] = [n.page for n in matched]
            self.shared_len[rid] = len(matched) * self.page_size
            if matched:
                self.stats["prefix_hits"] += 1
                self.stats["radix_hit_tokens"] += self.shared_len[rid]
        elif prefix_key and prefix_key in self.prefix_index:
            shared = self.prefix_index[prefix_key]
            if prompt_len is not None:
                shared = shared[:prompt_len // self.page_size]
            for p in shared:
                self.refcount[p] += 1
            self.pages[rid] = list(shared)
            self.shared_len[rid] = len(shared) * self.page_size
            self.stats["prefix_hits"] += 1
        return self.shared_len[rid]

    def register_prefix(self, rid: int, key: str, n_tokens: int):
        """Publish the request's leading full pages as a shared prefix.
        Only complete pages are shared (a partial page would need
        copy-on-write for the writes that follow it).  Re-registering a
        key first releases the old entry's refcounts."""
        if key in self.prefix_index:
            self.drop_prefix(key)
        full = n_tokens // self.page_size
        shared = self.pages[rid][:full]
        for p in shared:
            self.refcount[p] += 1
        self.prefix_index[key] = shared

    # -- growth ---------------------------------------------------------------
    def ensure_capacity(self, rid: int, n_tokens: int) -> bool:
        """Grow the request to hold n_tokens; all-or-nothing so a failed
        grow never strands half an allocation.  When the free list is
        short, unreferenced radix-cached pages are evicted (leaf-first
        LRU) to cover the gap — cached pages never block an allocation
        an uncached run could satisfy.  False = pool genuinely exhausted
        (caller preempts a victim and retries, or parks the request)."""
        need = -(-n_tokens // self.page_size) - len(self.pages[rid])
        if need <= 0:
            return True
        if need > len(self.free_list):
            self.evict_radix(need - len(self.free_list))
        if need > len(self.free_list):
            self.stats["alloc_failures"] += 1
            return False
        for _ in range(need):
            p = self.free_list.pop()
            self.refcount[p] = 1
            self.pages[rid].append(p)
            self.stats["allocs"] += 1
        return True

    def trim(self, rid: int, n_tokens: int):
        """Rewind the page-table tail to exactly the pages n_tokens need
        (speculative decode: the verify pass grows a slot by k+1
        positions up front; rejected drafts hand the surplus pages
        back).  Tail pages pop back onto the LIFO free list in reverse,
        so an immediate regrow of the same slot reacquires the identical
        pages in the identical order — page-table determinism (and with
        it the compile-count/parity contracts) survives reject/regrow
        churn.  Never trims below the shared-prefix pages, and never
        reclaims a page something else still references (a published
        prefix tail)."""
        keep = -(-n_tokens // self.page_size)
        keep = max(keep, self.shared_len[rid] // self.page_size)
        pages = self.pages[rid]
        while len(pages) > keep:
            p = pages[-1]
            if self.refcount[p] > 1:
                break                    # published page: leave it bound
            pages.pop()
            del self.refcount[p]
            self.free_list.append(p)
            self.stats["frees"] += 1
            self.stats["trims"] += 1

    def _free_page_ref(self, p: int):
        self.refcount[p] -= 1
        if self.refcount[p] == 0:
            del self.refcount[p]
            self.free_list.append(p)
            self.stats["frees"] += 1

    def release(self, rid: int, tokens=None):
        """Free a finished request's pages (shared prefix pages survive
        while other holders — or the prefix index / radix trie — still
        reference them).  With `tokens` (the request's written token
        history), the leading full pages are *published* into the radix
        trie instead of recycled, so the next request with the same
        prefix attaches them for free."""
        if tokens is not None:
            self.publish_radix(rid, tokens)
        for p in self.pages.pop(rid):
            self._free_page_ref(p)
        del self.shared_len[rid]

    def preempt(self, rid: int, tokens=None):
        """Pool-pressure eviction: identical to release at the allocator
        level; the engine requeues the request for deterministic FCFS
        re-admission and re-prefills on its next turn.  With `tokens`
        the victim's full pages are published first, so re-admission
        re-attaches them (unless the sweep had to evict them in the
        meantime) and the re-prefill shrinks to the tail."""
        self.stats["preempts"] += 1
        self.release(rid, tokens=tokens)

    def drop_prefix(self, key: str):
        """Unpublish a shared prefix (its pages free once no request
        still holds them)."""
        for p in self.prefix_index.pop(key):
            self._free_page_ref(p)

    # -- invariants -----------------------------------------------------------
    def check_invariants(self):
        refs: Dict[int, int] = {}
        for pages in self.pages.values():
            for p in pages:
                refs[p] = refs.get(p, 0) + 1
        for pages in self.prefix_index.values():
            for p in pages:
                refs[p] = refs.get(p, 0) + 1
        cached = []
        for node in self._iter_radix():
            refs[node.page] = refs.get(node.page, 0) + 1
            cached.append(node.page)
        assert len(set(cached)) == len(cached), \
            "page cached at two trie nodes"
        assert refs == self.refcount, (refs, self.refcount)
        live = set(refs)
        free = set(self.free_list)
        assert len(free) == len(self.free_list), "free list has dupes"
        assert not (live & free), f"live∩free: {live & free}"
        assert not any(p < self.reserved for p in live | free), \
            "reserved page leaked into circulation"
        assert live | free == set(range(self.reserved, self.n_pages)), \
            "pages leaked"
        for pages in self.pages.values():
            assert len(set(pages)) == len(pages), "duplicate page in table"
