"""Flood — high-efficiency offline inference engine (paper §2.4, C12).

Paper design -> TPU/JAX adaptation (DESIGN.md §3):

  * **Fully pipeline-parallel** execution: the model's layers are split
    into `n_stages` jitted stage functions; micro-batches of requests flow
    through the stage pipeline so every stage computes each tick.
  * **N_stages + 1 in-flight micro-batches**: the paper keeps one extra
    process waiting on the first stage so the accelerator never idles —
    here the scheduler keeps `n_stages + 1` micro-batches circulating.
  * **Segment KV cache** with extend/append/wait + prefix caching
    (`segment_cache.py`).
  * The baseline for the Table-3-shaped comparison is a TP-style engine
    that runs one global batch synchronously per token (per-step global
    sync = the communication-heavy pattern the paper attributes to TP),
    implemented in `baseline_step_engine`.

The event-driven scheduler is real; per-stage timing uses either wall
clock (CPU execution) or a caller-supplied cost model (for the pipeline
utilization benchmark).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.segment_cache import SegmentCache


def quantize_microbatch(n: int, multiple: int) -> int:
    """Round a micro-batch size up to a multiple.  The expert-parallel
    MoE decode path (core/moe.py dispatch="ep") slices token ownership
    over the tp mesh axis, so decode batches must satisfy B % tp == 0 —
    the single place both the engine and its callers quantize from."""
    if multiple > 1 and n % multiple:
        n += multiple - n % multiple
    return n


@dataclasses.dataclass
class GenRequest:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    prefix_key: Optional[str] = None


@dataclasses.dataclass
class PipelineStats:
    ticks: int = 0
    stage_busy: Optional[np.ndarray] = None
    tokens_out: int = 0
    wall_s: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / max(self.wall_s, 1e-9)

    @property
    def utilization(self) -> float:
        if self.stage_busy is None or self.ticks == 0:
            return 0.0
        return float(self.stage_busy.mean() / self.ticks)


class FloodEngine:
    """Pipeline-parallel micro-batch scheduler.

    `stage_fns[i](micro_state) -> micro_state` carries a micro-batch's
    activations through stage i; `head_fn(micro_state) -> tokens` samples.
    For pure scheduling benchmarks, stage_fns may be cost-model stubs.
    """

    def __init__(self, stage_fns: Sequence[Callable], head_fn: Callable,
                 embed_fn: Callable, *, cache: Optional[SegmentCache] = None,
                 microbatch: int = 8, batch_multiple: int = 1):
        """`batch_multiple` quantizes the micro-batch size via
        `quantize_microbatch` (EP decode constraint: B % tp == 0); pass
        batch_multiple=tp and the scheduler rounds the micro-batch up
        (embed_fn pads the tail).  Callers that compile a fixed decode
        batch must quantize with the same helper."""
        self.stage_fns = list(stage_fns)
        self.head_fn = head_fn
        self.embed_fn = embed_fn
        self.S = len(self.stage_fns)
        self.micro = quantize_microbatch(microbatch, batch_multiple)
        self.cache = cache or SegmentCache(max_tokens=1 << 20)
        self.pending: Deque[GenRequest] = deque()
        self.stats = PipelineStats(stage_busy=np.zeros(self.S))

    def submit(self, reqs: Sequence[GenRequest]):
        for r in reqs:
            admitted = self.cache.admit(r.rid, len(r.prompt), r.max_new,
                                        prefix_key=r.prefix_key)
            self.pending.append(r)
            if not admitted:
                r.done = False  # parked; will retry on release

    def _make_micro(self) -> Optional[Dict[str, Any]]:
        batch = []
        while self.pending and len(batch) < self.micro:
            r = self.pending.popleft()
            if not r.done:
                batch.append(r)
        if not batch:
            return None
        return {"reqs": batch, "x": self.embed_fn(batch), "stage": 0}

    def run(self, max_ticks: int = 100000) -> PipelineStats:
        """Event-driven pipeline: n_stages+1 micro-batches in flight.

        One tick = one stage-time unit across ALL stages concurrently (the
        stages are distinct accelerators in deployment): each stage
        processes at most one micro-batch per tick; a micro-batch that
        clears the last stage emits tokens and loops back to stage 0 for
        its next decode step.
        """
        t0 = time.perf_counter()
        inflight: List[Dict] = []
        ticks = 0
        while ticks < max_ticks:
            # keep S+1 micro-batches circulating (the paper's extra
            # process waiting on stage 0)
            while len(inflight) < self.S + 1:
                mb = self._make_micro()
                if mb is None:
                    break
                inflight.append(mb)
            if not inflight and not self.pending:
                break
            ticks += 1
            # advance back-to-front: at most one micro-batch per stage
            for s in range(self.S - 1, -1, -1):
                for mb in inflight:
                    if mb["stage"] == s:
                        mb["x"] = self.stage_fns[s](mb["x"])
                        self.stats.stage_busy[s] += 1
                        mb["stage"] += 1
                        break
            # completions: emit a token, then loop back to stage 0
            for mb in list(inflight):
                if mb["stage"] < self.S:
                    continue
                toks = self.head_fn(mb["x"], mb["reqs"])
                for r, t in zip(mb["reqs"], toks):
                    if self.cache.write_token(r.rid) is None:
                        continue          # waiting on cache space
                    r.out.append(int(t))
                    self.stats.tokens_out += 1
                    if len(r.out) >= r.max_new:
                        r.done = True
                        self.cache.release(r.rid)
                alive = [r for r in mb["reqs"] if not r.done]
                if alive:
                    mb["reqs"] = alive
                    mb["x"] = self.embed_fn(alive)
                    mb["stage"] = 0
                else:
                    inflight.remove(mb)
        self.stats.ticks = ticks
        self.stats.wall_s = time.perf_counter() - t0
        return self.stats


# ---------------------------------------------------------------------------
# baseline: synchronous global-batch engine (TP-style pattern)
# ---------------------------------------------------------------------------


def baseline_step_engine(step_fn: Callable, embed_fn: Callable,
                         reqs: Sequence[GenRequest],
                         sync_overhead_s: float = 0.0) -> PipelineStats:
    """One global batch; every token step runs the whole model and pays a
    global synchronization (the TP communication pattern)."""
    stats = PipelineStats()
    t0 = time.perf_counter()
    alive = [r for r in reqs]
    while alive:
        x = embed_fn(alive)
        toks = step_fn(x, alive)
        if sync_overhead_s:
            time.sleep(sync_overhead_s)
        for r, t in zip(alive, toks):
            r.out.append(int(t))
            stats.tokens_out += 1
            if len(r.out) >= r.max_new:
                r.done = True
        alive = [r for r in alive if not r.done]
    stats.wall_s = time.perf_counter() - t0
    return stats
