"""Online continuous-batching serving engine over a paged device KV cache.

Flood (serving/flood.py) is the *offline* half of the paper's §2.4 story:
a fixed request set, dense (B, seq_len) caches, host-side segment
bookkeeping.  This module is the *online* half the ROADMAP north star
asks for — requests arrive over time, join a running batch, and stream
tokens out — built from three pieces:

  * **Fixed-shape jitted serve steps.**  `max_slots` request slots; one
    paged decode tick over all slots (`api.Runner.make_paged_decode_step`)
    and one chunked-prefill step for a single request
    (`api.Runner.make_paged_prefill`).  Slot membership, sequence
    lengths, and page bindings are *data* (int32/bool arrays of fixed
    shape), so admitting, finishing, or preempting a request never
    recompiles — a test drives churn across >= 3x max_slots requests and
    asserts exactly one prefill + one decode XLA compile.

  * **Paged device KV.**  KV lives in slot-agnostic pools
    (n_pages, page_size, KV, hd) — the in-page offset dim sharded 1/tp —
    indexed by per-slot page tables.  `segment_cache.PageAllocator` owns
    the physical pages: admission, `ensure_capacity` growth, refcounted
    prefix-page sharing, preempt-and-requeue on exhaustion.

  * **The scheduler.**  An arrival queue with FCFS admission into free
    slots; each tick runs at most ONE prefill chunk (the oldest admitted
    request with unprefilled prompt) plus one decode tick for every
    decode-ready slot, so a long prompt costs the running batch one
    chunk of latency per tick instead of a full-prompt stall.  On pool
    exhaustion the youngest admitted request is preempted (pages freed,
    request requeued at the arrival-queue head) and re-prefills its
    prompt *plus* its already-emitted tokens on re-admission — emitted
    tokens are never re-sampled, so preemption is invisible in the
    output stream.

The per-slot decode batch shares every MoE decode constraint with the
offline engine: `max_slots` and `prefill_chunk` must satisfy
`quantize_microbatch(n, tp) == n` (the EP all-to-all path slices token
ownership over tp), checked at construction.

`run_poisson_load` is the load generator: Poisson arrivals at a given
rate, per-request TTFT / inter-token latency / throughput percentiles —
`launch/serve.py --online` reports them into BENCH_serve_online.json.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.serving.flood import quantize_microbatch
from repro.serving.segment_cache import PageAllocator


@dataclasses.dataclass
class OnlineConfig:
    """Engine geometry.  `max_context` bounds prompt+generation per
    request (the page-table width); `n_pages` sizes the shared pool
    (default: every slot can hold a full context, +1 scratch page —
    shrink it to exercise preemption)."""
    max_slots: int
    max_context: int
    page_size: int = 16
    n_pages: Optional[int] = None
    prefill_chunk: int = 8
    donate: bool = True
    eos_id: Optional[int] = None

    @property
    def max_pages(self) -> int:
        return -(-self.max_context // self.page_size)

    def pool_pages(self) -> int:
        if self.n_pages is not None:
            return self.n_pages
        return self.max_slots * self.max_pages + 1


@dataclasses.dataclass
class OnlineRequest:
    rid: int
    prompt: np.ndarray
    max_new: int
    prefix_key: Optional[str] = None
    arrival_t: float = 0.0
    out: List[int] = dataclasses.field(default_factory=list)
    state: str = "queued"            # queued | prefill | decode | done
    admit_t: Optional[float] = None
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    token_times: List[float] = dataclasses.field(default_factory=list)
    n_preempted: int = 0
    # scheduler scratch (valid while the request holds a slot)
    fed: Optional[np.ndarray] = None   # tokens to prefill (prompt + out[:-1])
    prefill_pos: int = 0

    @property
    def done(self) -> bool:
        return self.state == "done"


class OnlineEngine:
    """Continuous-batching scheduler around the fixed-shape paged steps.

    `prefill_traces` / `decode_traces` count python re-traces of the
    jitted steps (== XLA compiles, the `StagedTrainStep` trace-counter
    pattern); the engine contract is that both stay at 1 across arbitrary
    admission / completion / preemption churn.
    """

    def __init__(self, runner, params, cfg: OnlineConfig):
        M.check_paged_support(runner.cfg)
        env = runner.env
        tp = env.tp
        if env.dp != 1:
            raise ValueError(f"online serving runs on a (1, tp) mesh; "
                             f"got dp={env.dp}")
        if quantize_microbatch(cfg.max_slots, tp) != cfg.max_slots:
            raise ValueError(
                f"max_slots={cfg.max_slots} violates the EP decode batch "
                f"constraint (max_slots % tp == 0 for tp={tp}); round up "
                f"with serving.flood.quantize_microbatch(max_slots, tp) = "
                f"{quantize_microbatch(cfg.max_slots, tp)}")
        if quantize_microbatch(cfg.prefill_chunk, tp) != cfg.prefill_chunk:
            raise ValueError(
                f"prefill_chunk={cfg.prefill_chunk} must satisfy "
                f"chunk % tp == 0 (tp={tp}) — the chunk rides the same "
                f"MoE dispatch path as the decode batch")
        if cfg.page_size % tp:
            raise ValueError(f"page_size={cfg.page_size} must be divisible "
                             f"by tp={tp} (in-page offset sharding)")
        n_pages = cfg.pool_pages()
        if n_pages - 1 < cfg.max_pages:
            raise ValueError(
                f"pool of {n_pages} pages (1 reserved) cannot hold even "
                f"one max_context={cfg.max_context} request "
                f"({cfg.max_pages} pages)")
        self.cfg = cfg
        self.runner = runner
        self.params = params
        self.alloc = PageAllocator(n_pages, cfg.page_size)
        self.pools = runner.init_paged_pools(n_pages, cfg.page_size)

        self.prefill_traces = 0
        self.decode_traces = 0
        raw_dec = runner.make_paged_decode_step(cfg.page_size)
        raw_pre = runner.make_paged_prefill(cfg.page_size)

        def dec_fn(params, pools, tok, pos, table, active):
            self.decode_traces += 1        # runs at trace time
            return raw_dec(params, pools, tok, pos, table, active)

        def pre_fn(params, pools, tokens, base, n_valid, table_row):
            self.prefill_traces += 1       # runs at trace time
            return raw_pre(params, pools, tokens, base, n_valid, table_row)

        donate = (1,) if cfg.donate else ()
        self._decode = jax.jit(dec_fn, donate_argnums=donate)
        self._prefill = jax.jit(pre_fn, donate_argnums=donate)

        # host-side slot state (device copies are cut fresh every call —
        # same shapes/dtypes, so never a recompile)
        S = cfg.max_slots
        self.slot_rid = np.full((S,), -1, np.int64)
        self.table = np.zeros((S, cfg.max_pages), np.int32)
        self.lens = np.zeros((S,), np.int32)
        self.active = np.zeros((S,), bool)
        self.tok = np.zeros((S,), np.int32)
        self.slot_seq = np.zeros((S,), np.int64)   # admission counter
        self._seq = 0

        self.queue: Deque[int] = deque()
        self.reqs: Dict[int, OnlineRequest] = {}
        self.admission_log: List[int] = []
        self.ticks = 0
        self.n_preemptions = 0

    # -- submission -----------------------------------------------------------
    def submit(self, req: OnlineRequest):
        total = len(req.prompt) + req.max_new
        if total > self.cfg.max_context:
            raise ValueError(f"request {req.rid}: prompt+max_new={total} "
                             f"exceeds max_context={self.cfg.max_context}")
        if req.max_new < 1:
            raise ValueError("max_new must be >= 1")
        if len(req.prompt) < 1:
            raise ValueError("prompt must hold at least one token")
        old = self.reqs.get(req.rid)
        if old is not None and not old.done:
            raise ValueError(f"rid {req.rid} is still in flight "
                             f"(state={old.state}); rids must be unique "
                             f"among live requests")
        self.reqs[req.rid] = req
        self.queue.append(req.rid)

    def submit_many(self, reqs: Sequence[OnlineRequest]):
        for r in reqs:
            self.submit(r)

    def register_prefix(self, rid: int, key: str, n_tokens: int):
        """Publish a live request's leading full pages for prefix reuse;
        later submissions carrying `prefix_key=key` skip prefilling the
        shared tokens (contract: their prompt starts with the same
        tokens)."""
        self.alloc.register_prefix(rid, key, n_tokens)

    # -- scheduling helpers ---------------------------------------------------
    def _free_slots(self) -> List[int]:
        return [int(s) for s in np.flatnonzero(self.slot_rid < 0)]

    def _busy_slots(self) -> List[int]:
        return [int(s) for s in np.flatnonzero(self.slot_rid >= 0)]

    def _admit(self, now: float):
        for slot in self._free_slots():
            if not self.queue:
                break
            rid = self.queue.popleft()
            r = self.reqs[rid]
            # cap prefix attachment at the request's ORIGINAL prompt:
            # generated tokens diverge from the publisher's continuation,
            # and shared pages must never receive this request's writes
            shared = self.alloc.admit(rid, prefix_key=r.prefix_key,
                                      prompt_len=len(r.prompt))
            # re-prefill prompt + already-emitted tokens minus the last,
            # which becomes the next decode input (never re-sampled)
            r.fed = (np.concatenate([r.prompt,
                                     np.asarray(r.out[:-1], np.int32)])
                     if r.out else np.asarray(r.prompt, np.int32)
                     ).astype(np.int32)
            r.prefill_pos = min(shared, max(len(r.fed) - 1, 0))
            r.state = "prefill"
            r.admit_t = now
            self.slot_rid[slot] = rid
            self.slot_seq[slot] = self._seq
            self._seq += 1
            self.table[slot] = self.alloc.table_row(rid, self.cfg.max_pages)
            self.lens[slot] = 0
            self.active[slot] = False
            self.tok[slot] = 0
            self.admission_log.append(rid)

    def _clear_slot(self, slot: int):
        self.slot_rid[slot] = -1
        self.table[slot] = 0
        self.lens[slot] = 0
        self.active[slot] = False
        self.tok[slot] = 0

    def _finish(self, slot: int, now: float):
        rid = int(self.slot_rid[slot])
        r = self.reqs[rid]
        self.alloc.release(rid)
        r.state = "done"
        r.finish_t = now
        r.fed = None
        self._clear_slot(slot)

    def _preempt_slot(self, slot: int):
        """Free a victim's pages and requeue it at the queue head (FCFS
        re-admission: when several are preempted youngest-first, each
        appendleft puts the older one ahead)."""
        rid = int(self.slot_rid[slot])
        r = self.reqs[rid]
        self.alloc.preempt(rid)
        r.state = "queued"
        r.n_preempted += 1
        r.fed = None
        self.queue.appendleft(rid)
        self._clear_slot(slot)
        self.n_preemptions += 1

    def _make_room(self, rid: int, n_tokens: int):
        """ensure_capacity with preempt-and-requeue: evict the youngest
        other resident until the grow fits.  Failing with no victims left
        means this request is the sole resident and STILL cannot fit —
        nothing will ever free (only pinned prefix pages and its own
        remain), so raise instead of letting the scheduler thrash through
        endless self-preemption."""
        while not self.alloc.ensure_capacity(rid, n_tokens):
            victims = [s for s in self._busy_slots()
                       if int(self.slot_rid[s]) != rid]
            if not victims:
                pinned = sum(len(p) for p in
                             self.alloc.prefix_index.values())
                raise RuntimeError(
                    f"request {rid} needs {n_tokens} tokens "
                    f"({-(-n_tokens // self.cfg.page_size)} pages) but the "
                    f"pool cannot satisfy it even empty: {self.alloc.n_free}"
                    f" free, {pinned} page refs pinned by registered "
                    f"prefixes (drop_prefix to release)")
            self._preempt_slot(max(victims, key=lambda s: self.slot_seq[s]))

    # -- prefill --------------------------------------------------------------
    def _prefill_target(self) -> Optional[int]:
        """Oldest admitted slot with unprefilled tokens."""
        cands = [s for s in self._busy_slots()
                 if self.reqs[int(self.slot_rid[s])].state == "prefill"]
        if not cands:
            return None
        return min(cands, key=lambda s: self.slot_seq[s])

    def _prefill_tick(self, now: float):
        slot = self._prefill_target()
        if slot is None:
            return
        rid = int(self.slot_rid[slot])
        r = self.reqs[rid]
        C = self.cfg.prefill_chunk
        n_valid = min(C, len(r.fed) - r.prefill_pos)
        self._make_room(rid, r.prefill_pos + n_valid)
        self.table[slot] = self.alloc.table_row(rid, self.cfg.max_pages)
        chunk = np.zeros((C,), np.int32)
        chunk[:n_valid] = r.fed[r.prefill_pos:r.prefill_pos + n_valid]
        nxt, self.pools = self._prefill(
            self.params, self.pools, jnp.asarray(chunk),
            jnp.int32(r.prefill_pos), jnp.int32(n_valid),
            jnp.asarray(self.table[slot]))
        r.prefill_pos += n_valid
        if r.prefill_pos < len(r.fed):
            return                      # more chunks to go
        # prompt (+ replayed tokens) fully written: enter decode state
        t = time.perf_counter()
        self.lens[slot] = len(r.fed)
        self.active[slot] = True
        r.state = "decode"
        if not r.out:
            tok = int(jax.device_get(nxt))
            r.out.append(tok)
            r.first_token_t = t
            r.token_times.append(t)
            if len(r.out) >= r.max_new or tok == self.cfg.eos_id:
                self._finish(slot, t)
                return
        self.tok[slot] = r.out[-1]

    # -- decode ---------------------------------------------------------------
    def _decode_tick(self, now: float):
        # grow every decode slot to hold its next position, oldest first
        # (the youngest is the preferred preemption victim, so growing in
        # age order never evicts a slot we already grew this tick)
        for slot in sorted(np.flatnonzero(self.active),
                           key=lambda s: self.slot_seq[s]):
            slot = int(slot)
            if not self.active[slot]:
                continue                # preempted by an earlier grow
            rid = int(self.slot_rid[slot])
            self._make_room(rid, int(self.lens[slot]) + 1)
            self.table[slot] = self.alloc.table_row(rid, self.cfg.max_pages)
        if not self.active.any():
            return
        nxt, self.pools = self._decode(
            self.params, self.pools, jnp.asarray(self.tok),
            jnp.asarray(self.lens), jnp.asarray(self.table),
            jnp.asarray(self.active))
        nxt = np.asarray(jax.device_get(nxt))
        t = time.perf_counter()
        for slot in np.flatnonzero(self.active):
            slot = int(slot)
            rid = int(self.slot_rid[slot])
            r = self.reqs[rid]
            tok = int(nxt[slot])
            r.out.append(tok)
            r.token_times.append(t)
            self.lens[slot] += 1
            self.tok[slot] = tok
            if len(r.out) >= r.max_new or tok == self.cfg.eos_id:
                self._finish(slot, t)

    def pop_done(self) -> List[OnlineRequest]:
        """Remove and return finished requests.  The engine retains
        completed `OnlineRequest` objects (token streams + latency
        timestamps) until the caller collects them — a long-lived server
        loop must call this periodically or host memory grows with every
        request ever served."""
        done = [r for r in self.reqs.values() if r.done]
        for r in done:
            del self.reqs[r.rid]
        return done

    # -- driver ---------------------------------------------------------------
    @property
    def idle(self) -> bool:
        return not self.queue and not self._busy_slots()

    def tick(self, now: Optional[float] = None):
        """One engine step: admission -> one prefill chunk -> one decode
        tick over the slot batch."""
        now = time.perf_counter() if now is None else now
        self.ticks += 1
        self._admit(now)
        self._prefill_tick(now)
        self._decode_tick(now)

    def run(self, max_ticks: int = 100_000):
        """Drive ticks until every submitted request is done."""
        for _ in range(max_ticks):
            if self.idle:
                return
            self.tick()
        raise RuntimeError(f"engine did not drain in {max_ticks} ticks "
                           f"(queue={len(self.queue)}, "
                           f"busy={self._busy_slots()})")


# ---------------------------------------------------------------------------
# Poisson load generator
# ---------------------------------------------------------------------------


def _pctl(xs: Sequence[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def run_poisson_load(engine: OnlineEngine, *, rate: float, n_requests: int,
                     prompt_len: int, max_new: int, vocab_size: int,
                     seed: int = 0, max_ticks: int = 1_000_000
                     ) -> Dict[str, Any]:
    """Open-loop Poisson arrivals at `rate` req/s against a live engine.

    Requests are submitted when their scheduled arrival time passes on
    the wall clock (the engine keeps ticking in between — arrivals join
    the running batch), so TTFT includes genuine queueing delay.
    Returns TTFT p50/p99, pooled inter-token latency p50/p99, sustained
    tok/s, and churn counters.
    """
    rs = np.random.RandomState(seed)
    gaps = rs.exponential(1.0 / rate, size=n_requests)
    arrivals = np.cumsum(gaps)
    prompts = [rs.randint(0, vocab_size, prompt_len).astype(np.int32)
               for _ in range(n_requests)]
    base = (max(engine.reqs) + 1) if engine.reqs else 0   # engine reuse
    ticks0, preempts0 = engine.ticks, engine.n_preemptions
    t0 = time.perf_counter()
    submitted = 0
    budget = max_ticks
    while submitted < n_requests or not engine.idle:
        budget -= 1
        if budget < 0:
            raise RuntimeError(f"load run did not drain in {max_ticks} "
                               f"ticks ({submitted}/{n_requests} submitted)")
        now = time.perf_counter()
        while (submitted < n_requests
               and arrivals[submitted] <= now - t0):
            r = OnlineRequest(rid=base + submitted,
                              prompt=prompts[submitted], max_new=max_new,
                              arrival_t=t0 + arrivals[submitted])
            engine.submit(r)
            submitted += 1
        if engine.idle and submitted < n_requests:
            time.sleep(min(arrivals[submitted] - (now - t0), 0.01))
            continue
        engine.tick(now)
    t_end = time.perf_counter()

    reqs = [engine.reqs[base + i] for i in range(n_requests)]
    assert all(r.done for r in reqs)
    engine.pop_done()              # keep the engine bounded across loads
    ttft = [r.first_token_t - r.arrival_t for r in reqs]
    itl: List[float] = []
    for r in reqs:
        itl.extend(b - a for a, b in zip(r.token_times, r.token_times[1:]))
    n_tokens = sum(len(r.out) for r in reqs)
    return {
        "rate_req_s": rate,
        "n_requests": n_requests,
        "prompt_len": prompt_len,
        "max_new": max_new,
        "wall_s": t_end - t0,
        "tokens_out": n_tokens,
        "tok_s": n_tokens / max(t_end - t0, 1e-9),
        "ttft_p50_ms": 1e3 * _pctl(ttft, 50),
        "ttft_p99_ms": 1e3 * _pctl(ttft, 99),
        "itl_p50_ms": 1e3 * _pctl(itl, 50),
        "itl_p99_ms": 1e3 * _pctl(itl, 99),
        "ticks": engine.ticks - ticks0,
        "preemptions": engine.n_preemptions - preempts0,
        "prefill_compiles": engine.prefill_traces,
        "decode_compiles": engine.decode_traces,
        "allocator": dict(engine.alloc.stats),
    }
