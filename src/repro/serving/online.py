"""Online continuous-batching serving engine over a paged device KV cache.

Flood (serving/flood.py) is the *offline* half of the paper's §2.4 story:
a fixed request set, dense (B, seq_len) caches, host-side segment
bookkeeping.  This module is the *online* half the ROADMAP north star
asks for — requests arrive over time, join a running batch, and stream
tokens out — built from three pieces:

  * **Fixed-shape jitted serve steps.**  `max_slots` request slots; one
    paged decode tick over all slots (`api.Runner.make_paged_decode_step`)
    and one chunked-prefill step for a single request
    (`api.Runner.make_paged_prefill`).  Slot membership, sequence
    lengths, and page bindings are *data* (int32/bool arrays of fixed
    shape), so admitting, finishing, or preempting a request never
    recompiles — a test drives churn across >= 3x max_slots requests and
    asserts exactly one prefill + one decode XLA compile.

  * **Paged device KV.**  KV lives in slot-agnostic pools
    (n_pages, page_size, KV, hd) — the in-page offset dim sharded 1/tp —
    indexed by per-slot page tables.  `segment_cache.PageAllocator` owns
    the physical pages: admission, `ensure_capacity` growth, refcounted
    prefix-page sharing, preempt-and-requeue on exhaustion.

  * **The scheduler.**  An arrival queue with FCFS admission into free
    slots; each tick runs at most ONE prefill chunk (the oldest admitted
    request with unprefilled prompt) plus one decode tick for every
    decode-ready slot, so a long prompt costs the running batch one
    chunk of latency per tick instead of a full-prompt stall.  On pool
    exhaustion the youngest admitted request is preempted (pages freed,
    request requeued at the arrival-queue head) and re-prefills its
    prompt *plus* its already-emitted tokens on re-admission — emitted
    tokens are never re-sampled, so preemption is invisible in the
    output stream.  A **policy layer** rides on top: `OnlineConfig.
    policy` picks the tick ordering ("fcfs" | "decode-priority" |
    "prefill-priority" — see `tick`), `max_queue` + `overload` form a
    saturation-aware admission gate (bounded queue, shed-or-defer), and
    `tenant_budgets` caps each tenant's admitted tokens.  All of it is
    host bookkeeping over the same compiled steps — switching policies
    never recompiles.

  * **The radix prefix cache** (`radix_cache=True`, the default).
    `PageAllocator` keeps a trie keyed by page-aligned token blocks:
    admission walks it with the request's exact prefill tokens and
    attaches every matching refcounted KV page automatically — repeated
    system prompts cost zero prefill with no caller-supplied
    `prefix_key`.  Full pages publish into the trie when prefill
    completes, when a request releases, and when it is preempted;
    unreferenced cached pages are LRU-evicted (leaf-first) only when an
    allocation would otherwise fail, so caching never causes an OOM an
    uncached run would not hit.  Cache on/off is bitwise-invisible in
    the token streams (greedy and seeded sampling alike).

The per-slot decode batch shares every MoE decode constraint with the
offline engine: `max_slots` and `prefill_chunk` must satisfy
`quantize_microbatch(n, tp) == n` (the EP all-to-all path slices token
ownership over tp), checked at construction.

Two newer layers ride the same fixed-shape contract:

  * **Real sampling.**  Per-slot temperature / top-p / top-k / seed are
    (B,)-shaped DATA into the jitted steps; draws use the counter-based
    (seed, position, stream) key schedule in `models.embedding`, so
    token streams are reproducible across preemption replay and match
    the offline engine's `make_decode_step(sample=True)` for equal
    seeds.  Temperature 0 is bitwise-equal to greedy argmax.

  * **Speculative decoding** (`spec_k > 0` + a `serving.draft` drafter).
    Each spec tick: the drafter proposes k tokens per slot (scan of
    sampled decode steps over its OWN paged pools, same page ids as the
    target), ONE target pass shaped like a k+1-query paged prefill
    scores all candidate positions, and standard spec-sampling
    accept/reject commits `n_acc + 1` tokens host-side —
    `PageAllocator.trim` rewinds rejected tail pages (LIFO, so regrow
    reacquires identical pages).  Greedy streams stay token-exact
    versus non-speculative decode; acceptance only changes *speed*
    (ticks per token), never the distribution.

`run_poisson_load` is the load generator: Poisson arrivals at a given
rate, per-request TTFT / inter-token latency / throughput percentiles —
`launch/serve.py --online` reports them into BENCH_serve_online.json.

**Telemetry** (docs/observability.md): every engine owns a
`telemetry.MetricsRegistry` (TTFT/ITL/tick histograms, churn counters,
occupancy gauges + per-tick counter-track series), a
`telemetry.RequestLog` recording the full request lifecycle
(enqueue -> admit -> prefill chunks -> first token -> decode ->
preempt/requeue -> complete/shed, with tick indices and timestamps),
and an `XPUTimer` spanning the scheduler phases of every tick — all of
it host-side bookkeeping under the zero-host-sync contract, exportable
to Perfetto via `telemetry.write_chrome_trace`.  `overload="slo"`
closes the loop: a `telemetry.SLOTracker` over the windowed histograms
vetoes admission when the configured TTFT/ITL p99 deadlines would be
breached (shedding at submit time keeps the *admitted* p99 inside the
deadline past the knee).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import contracts
from repro.models import layers as L
from repro.models import model as M
from repro.serving.flood import quantize_microbatch
from repro.serving.segment_cache import PageAllocator
from repro.telemetry.metrics import MetricsRegistry
from repro.telemetry.request_log import RequestLog
from repro.telemetry.slo import SLOConfig, SLOTracker
from repro.telemetry.xputimer import XPUTimer


POLICIES = ("fcfs", "decode-priority", "prefill-priority")
OVERLOAD = ("defer", "shed", "slo")


@dataclasses.dataclass
class OnlineConfig:
    """Engine geometry + default sampling/speculation knobs.

    `max_context` bounds prompt+generation per request; `n_pages` sizes
    the shared pool (default: every slot can hold a full context, +1
    scratch page — shrink it to exercise preemption).  The sampling
    fields are per-request DEFAULTS (an `OnlineRequest` can override any
    of them); temperature 0 is exact greedy.  `spec_k > 0` turns on
    speculative decoding (propose->verify->commit ticks) and requires a
    drafter at engine construction; the page-table width then carries
    `spec_k` extra positions of slack because the verify pass writes
    k+1 candidate KV rows before the host commits.

    `radix_cache` turns on the cross-request content-addressed prefix
    cache (docs/serving.md): matching KV pages attach at admission with
    no caller-supplied `prefix_key`, full pages publish into the trie on
    prefill completion / release / preemption, and unreferenced cached
    pages LRU-evict only when an allocation would otherwise fail.  The
    scheduler knobs are pure host data — `policy` picks the tick
    ordering ("fcfs" | "decode-priority" | "prefill-priority"),
    `max_queue` bounds the arrival queue (`overload` picks shed vs defer
    when it is full), and `tenant_budgets` caps each tenant's admitted
    prompt+max_new tokens — none of them change any jitted step shape,
    so switching policies at runtime never recompiles."""
    max_slots: int
    max_context: int
    page_size: int = 16
    n_pages: Optional[int] = None
    prefill_chunk: int = 8
    donate: bool = True
    eos_id: Optional[int] = None
    # sampling defaults (per-request overridable)
    temperature: float = 0.0
    top_p: float = 1.0
    top_k: int = 0
    seed: int = 0          # request seed defaults to (seed + rid) % 2**31
    # speculative decoding
    spec_k: int = 0
    # cross-request radix prefix cache
    radix_cache: bool = True
    # scheduler policy layer
    policy: str = "fcfs"
    max_queue: Optional[int] = None     # bounded arrival queue (None = inf)
    overload: str = "defer"             # gate response: defer | shed | slo
    tenant_budgets: Optional[Dict[str, int]] = None
    # SLO-aware admission (overload="slo"): shed at submit time when the
    # windowed latency view says admitting would breach a deadline
    # (telemetry.slo.SLOTracker — backward p99 + forward TTFT estimate)
    slo: Optional[SLOConfig] = None
    # per-request lifecycle log ring entries (telemetry.request_log)
    trace_ring: int = 65536
    # debug contracts (analysis.contracts): run every tick under a
    # device->host transfer_guard.  Default comes from REPRO_DEBUG_GUARDS
    # so CI legs can arm it without touching call sites.  None = env.
    debug_guards: Optional[bool] = None

    @property
    def max_pages(self) -> int:
        return -(-(self.max_context + self.spec_k) // self.page_size)

    def pool_pages(self) -> int:
        if self.n_pages is not None:
            return self.n_pages
        return self.max_slots * self.max_pages + 1


@dataclasses.dataclass
class OnlineRequest:
    rid: int
    prompt: np.ndarray
    max_new: int
    prefix_key: Optional[str] = None
    prefix_len: int = 0              # tokens to auto-publish under prefix_key
    tenant: Optional[str] = None     # admission-budget accounting key
    arrival_t: float = 0.0
    # sampling overrides (None -> the OnlineConfig default); the seed is
    # fixed per request, so preemption replay re-derives identical draws
    temperature: Optional[float] = None
    top_p: Optional[float] = None
    top_k: Optional[int] = None
    seed: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    state: str = "queued"        # queued | prefill | decode | done | shed
    admit_t: Optional[float] = None
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    token_times: List[float] = dataclasses.field(default_factory=list)
    n_preempted: int = 0
    n_decode_ticks: int = 0          # decode/spec ticks this slot rode
    # scheduler scratch (valid while the request holds a slot)
    fed: Optional[np.ndarray] = None   # tokens to prefill (prompt + out[:-1])
    prefill_pos: int = 0

    @property
    def done(self) -> bool:
        return self.state == "done"


class OnlineEngine:
    """Continuous-batching scheduler around the fixed-shape paged steps.

    `prefill_traces` / `decode_traces` count python re-traces of the
    jitted steps (== XLA compiles, the `StagedTrainStep` trace-counter
    pattern); the engine contract is that both stay at 1 across arbitrary
    admission / completion / preemption churn.
    """

    def __init__(self, runner, params, cfg: OnlineConfig, drafter=None,
                 registry: Optional[MetricsRegistry] = None,
                 request_log: Optional[RequestLog] = None,
                 timer: Optional[XPUTimer] = None):
        M.check_paged_support(runner.cfg)
        env = runner.env
        tp = env.tp
        if env.dp != 1:
            raise ValueError(f"online serving runs on a (1, tp) mesh; "
                             f"got dp={env.dp}")
        if quantize_microbatch(cfg.max_slots, tp) != cfg.max_slots:
            raise ValueError(
                f"max_slots={cfg.max_slots} violates the EP decode batch "
                f"constraint (max_slots % tp == 0 for tp={tp}); round up "
                f"with serving.flood.quantize_microbatch(max_slots, tp) = "
                f"{quantize_microbatch(cfg.max_slots, tp)}")
        if quantize_microbatch(cfg.prefill_chunk, tp) != cfg.prefill_chunk:
            raise ValueError(
                f"prefill_chunk={cfg.prefill_chunk} must satisfy "
                f"chunk % tp == 0 (tp={tp}) — the chunk rides the same "
                f"MoE dispatch path as the decode batch")
        if cfg.page_size % tp:
            raise ValueError(f"page_size={cfg.page_size} must be divisible "
                             f"by tp={tp} (in-page offset sharding)")
        n_pages = cfg.pool_pages()
        if n_pages - 1 < cfg.max_pages:
            raise ValueError(
                f"pool of {n_pages} pages (1 reserved) cannot hold even "
                f"one max_context={cfg.max_context} request "
                f"({cfg.max_pages} pages)")
        if cfg.policy not in POLICIES:
            raise ValueError(f"policy={cfg.policy!r} not in {POLICIES}")
        if cfg.overload not in OVERLOAD:
            raise ValueError(f"overload={cfg.overload!r} not in {OVERLOAD}")
        if cfg.max_queue is not None and cfg.max_queue < 1:
            raise ValueError(f"max_queue={cfg.max_queue} must be >= 1")
        if cfg.overload == "slo" and cfg.slo is None:
            raise ValueError(
                'overload="slo" needs deadlines: set OnlineConfig.slo to a '
                "telemetry.SLOConfig(ttft_p99_ms=...)")
        self.cfg = cfg
        self.runner = runner
        self.params = params
        # resolved paged-attention backend (RunFlags.paged_attn "auto"
        # settles at engine build time) — surfaced in load reports so a
        # bench row records which path it measured
        self.paged_attn = L.resolve_paged_attn(runner.flags.paged_attn)
        self.alloc = PageAllocator(n_pages, cfg.page_size)
        self.pools = runner.init_paged_pools(n_pages, cfg.page_size)

        # speculative decoding: build the drafter model over its OWN page
        # pools (same page ids / page size / pool count as the target, so
        # admission, growth, preemption, prefix sharing, and trim all
        # transfer to the drafter KV via the shared tables)
        self.spec = cfg.spec_k > 0
        self.drafter = drafter
        if self.spec:
            if drafter is None:
                raise ValueError(
                    f"spec_k={cfg.spec_k} > 0 requires a drafter (e.g. "
                    f"serving.draft.SelfDrafter(draft_layers=...))")
            if cfg.max_slots * (cfg.spec_k + 1) % tp:
                raise ValueError(
                    f"max_slots*(spec_k+1)={cfg.max_slots * (cfg.spec_k + 1)}"
                    f" must be divisible by tp={tp} (the verify pass rides "
                    f"the EP dispatch path with B*(k+1) tokens)")
            self.drunner, self.dparams = drafter.build(runner, params)
            if self.drunner.cfg.vocab_size != runner.cfg.vocab_size:
                raise ValueError(
                    f"drafter vocab_size={self.drunner.cfg.vocab_size} != "
                    f"target vocab_size={runner.cfg.vocab_size}")
            self.dpools = self.drunner.init_paged_pools(n_pages,
                                                        cfg.page_size)
        else:
            self.drunner = self.dparams = self.dpools = None

        # trace-time compile counting (analysis.contracts.CompileCounter):
        # the engine contract — exactly one compile per step family across
        # arbitrary churn — is asserted with contracts.compile_guard()
        # over these labels; prefill_traces/... stay as properties
        self.compiles = contracts.CompileCounter()
        self.debug_guards = (contracts.env_debug_guards()
                             if cfg.debug_guards is None
                             else cfg.debug_guards)
        self.spec_proposed = 0        # drafted tokens offered to verify
        self.spec_accepted = 0        # drafted tokens accepted
        # the engine always runs the *sampled* step variants — knobs are
        # (B,) data, temperature 0 is bitwise greedy, so one compiled
        # step serves greedy and stochastic slots alike
        raw_dec = runner.make_paged_decode_step(cfg.page_size, sample=True)
        raw_pre = runner.make_paged_prefill(cfg.page_size, sample=True)

        donate = cfg.donate
        self._decode = self.compiles.jit(
            "decode", raw_dec, donate_argnums=(1,) if donate else ())

        if self.spec:
            # fused prefill: one jitted step writes the chunk into BOTH
            # the target and drafter pools, preserving the "exactly one
            # prefill compile" contract in spec mode.  The drafter leg
            # is the plain (unsampled) prefill — only its KV writes
            # matter; its next-token output is discarded.
            raw_dpre = self.drunner.make_paged_prefill(cfg.page_size)

            def pre_fn(params, dparams, pools, dpools, tokens, base,
                       n_valid, table_row, seed, temp, top_p, top_k):
                nxt, pools = raw_pre(params, pools, tokens, base, n_valid,
                                     table_row, seed, temp, top_p, top_k)
                _, dpools = raw_dpre(dparams, dpools, tokens, base,
                                     n_valid, table_row)
                return nxt, pools, dpools

            self._prefill = self.compiles.jit(
                "prefill", pre_fn, donate_argnums=(2, 3) if donate else ())

            raw_draft = self.drunner.make_paged_draft_propose(
                cfg.page_size, cfg.spec_k)
            raw_verify = runner.make_paged_verify_step(
                cfg.page_size, cfg.spec_k)

            self._draft = self.compiles.jit(
                "draft", raw_draft, donate_argnums=(1,) if donate else ())
            self._verify = self.compiles.jit(
                "verify", raw_verify, donate_argnums=(1,) if donate else ())
        else:
            self._prefill = self.compiles.jit(
                "prefill", raw_pre, donate_argnums=(1,) if donate else ())

        # host-side slot state (device copies are cut fresh every call —
        # same shapes/dtypes, so never a recompile)
        S = cfg.max_slots
        self.slot_rid = np.full((S,), -1, np.int64)
        self.table = np.zeros((S, cfg.max_pages), np.int32)
        self.lens = np.zeros((S,), np.int32)
        self.active = np.zeros((S,), bool)
        self.tok = np.zeros((S,), np.int32)
        self.slot_seq = np.zeros((S,), np.int64)   # admission counter
        self._seq = 0
        # per-slot sampling knobs — DATA to the jitted steps, so mixing
        # greedy and stochastic requests in one batch never recompiles
        self.seeds = np.zeros((S,), np.int32)
        self.temps = np.zeros((S,), np.float32)
        self.topps = np.ones((S,), np.float32)
        self.topks = np.zeros((S,), np.int32)

        self.queue: Deque[int] = deque()
        self.reqs: Dict[int, OnlineRequest] = {}
        self.admission_log: List[int] = []
        self.ticks = 0
        self.n_preemptions = 0
        self.policy = cfg.policy
        self.n_shed = 0                  # saturation-gate rejections
        self.n_budget_skips = 0          # admissions deferred over budget

        # -- telemetry (docs/observability.md) ----------------------------
        # Everything below reads host scalars the scheduler already holds
        # (zero-host-sync contract): no metric call touches a jax value,
        # and the contract tests run ticks under compile_guard +
        # transfer_guard with all of this enabled.
        self.registry = registry if registry is not None else MetricsRegistry()
        self.rlog = (request_log if request_log is not None
                     else RequestLog(cfg.trace_ring))
        self.timer = (timer if timer is not None
                      else XPUTimer(registry=self.registry))
        if self.timer.registry is None:
            self.timer.registry = self.registry
        # SLOTracker first: it sizes the shared latency-histogram windows
        # (get-or-create below then returns the same children)
        self.slo = (SLOTracker(cfg.slo, self.registry)
                    if cfg.slo is not None else None)
        reg = self.registry
        self._m_ttft = reg.histogram(
            "serve_ttft_ms", "time to first token (admitted requests)")
        self._m_itl = reg.histogram(
            "serve_itl_ms", "inter-token latency (decode steps)")
        self._m_tick = reg.histogram(
            "serve_tick_ms", "engine tick wall time")
        self._m_enq = reg.counter(
            "serve_enqueued_total", "requests accepted into the queue")
        self._m_admit = reg.counter(
            "serve_admitted_total", "requests bound to a slot")
        self._m_done = reg.counter(
            "serve_completed_total", "requests finished")
        self._m_shed = reg.counter(
            "serve_shed_total", "requests rejected by the admission gate")
        self._m_preempt = reg.counter(
            "serve_preemptions_total", "slot preempt-and-requeue events")
        self._m_tokens = reg.counter(
            "serve_tokens_total", "tokens emitted across all requests")
        self._m_evict = reg.counter(
            "serve_cache_evictions_total", "radix-cache pages evicted")
        self._g_queue = reg.gauge("serve_queue_depth", "arrival queue depth")
        self._g_pages = reg.gauge(
            "serve_pages_in_use", "KV pool pages held (requests+cache)")
        self._g_slots = reg.gauge("serve_slots_active", "occupied slots")
        # per-tick samples -> Perfetto counter tracks (trace_export)
        self._s_pages = reg.series("page_pool_occupancy")
        self._s_queue = reg.series("queue_depth")
        self._s_radix = reg.series("radix_hit_rate")
        self._s_accept = reg.series("spec_acceptance") if self.spec else None
        self._admitted_tokens = 0        # prefill tokens ever admitted
        self.alloc.on_evict = self._on_evict

    def _on_evict(self, page: int):
        """PageAllocator hook: one radix-cache page evicted."""
        self._m_evict.inc()
        self.rlog.record("evict", -1, tick=self.ticks, arg=page)

    def set_policy(self, policy: str):
        """Switch the tick-ordering policy at runtime.  Pure host state —
        the jitted steps are untouched, so this never recompiles (the
        policy tests assert it)."""
        if policy not in POLICIES:
            raise ValueError(f"policy={policy!r} not in {POLICIES}")
        self.policy = policy

    # -- submission -----------------------------------------------------------
    def submit(self, req: OnlineRequest) -> bool:
        """Enqueue a request.  With a bounded queue (`max_queue`) a full
        queue triggers the saturation gate: "shed" marks the request
        shed and drops it (state="shed", counted in `n_shed`), "defer"
        returns False without touching it so the caller can retry after
        the engine drains.  With ``overload="slo"`` the SLOTracker also
        vetoes admission whenever its windowed latency view says this
        request could not meet the TTFT/ITL deadlines (a full queue
        sheds too).  Returns True when enqueued."""
        total = len(req.prompt) + req.max_new
        if total > self.cfg.max_context:
            raise ValueError(f"request {req.rid}: prompt+max_new={total} "
                             f"exceeds max_context={self.cfg.max_context}")
        if req.max_new < 1:
            raise ValueError("max_new must be >= 1")
        if len(req.prompt) < 1:
            raise ValueError("prompt must hold at least one token")
        old = self.reqs.get(req.rid)
        if old is not None and not old.done:
            raise ValueError(f"rid {req.rid} is still in flight "
                             f"(state={old.state}); rids must be unique "
                             f"among live requests")
        if req.arrival_t <= 0.0:
            req.arrival_t = time.perf_counter()
        if self.cfg.overload == "slo":
            queued_tokens = (sum(len(self.reqs[q].prompt)
                                 for q in self.queue) + len(req.prompt))
            reason = self.slo.should_shed(queued_tokens,
                                          self.cfg.prefill_chunk)
            if reason is not None:
                return self._shed(req)
        if (self.cfg.max_queue is not None
                and len(self.queue) >= self.cfg.max_queue):
            if self.cfg.overload in ("shed", "slo"):
                return self._shed(req)
            return False
        self.reqs[req.rid] = req
        self.queue.append(req.rid)
        self._m_enq.inc()
        self.rlog.record("enqueue", req.rid, tick=self.ticks)
        return True

    def _shed(self, req: OnlineRequest) -> bool:
        req.state = "shed"
        self.n_shed += 1
        self._m_shed.inc()
        if self.slo is not None:
            self.slo.on_shed()
        self.rlog.record("shed", req.rid, tick=self.ticks)
        return False

    def submit_many(self, reqs: Sequence[OnlineRequest]):
        for r in reqs:
            if not self.submit(r):
                raise RuntimeError(
                    f"rid {r.rid} rejected by the saturation gate "
                    f"(queue full at max_queue={self.cfg.max_queue}); "
                    f"submit_many is for unbounded batches — use submit "
                    f"and handle the False return")

    def register_prefix(self, rid: int, key: str, n_tokens: int):
        """Publish a live request's leading full pages for prefix reuse;
        later submissions carrying `prefix_key=key` skip prefilling the
        shared tokens (contract: their prompt starts with the same
        tokens)."""
        self.alloc.register_prefix(rid, key, n_tokens)

    # -- scheduling helpers ---------------------------------------------------
    def _free_slots(self) -> List[int]:
        return [int(s) for s in np.flatnonzero(self.slot_rid < 0)]

    def _busy_slots(self) -> List[int]:
        return [int(s) for s in np.flatnonzero(self.slot_rid >= 0)]

    def _tenant_usage(self) -> Dict[str, int]:
        usage: Dict[str, int] = {}
        for s in self._busy_slots():
            r = self.reqs[int(self.slot_rid[s])]
            if r.tenant is not None:
                usage[r.tenant] = (usage.get(r.tenant, 0)
                                   + len(r.prompt) + r.max_new)
        return usage

    def _admit(self, now: float):
        budgets = self.cfg.tenant_budgets or {}
        usage = self._tenant_usage() if budgets else {}
        skipped: List[int] = []
        for slot in self._free_slots():
            rid = None
            while self.queue:
                cand = self.queue.popleft()
                c = self.reqs[cand]
                budget = (budgets.get(c.tenant)
                          if c.tenant is not None else None)
                cost = len(c.prompt) + c.max_new
                if (budget is not None
                        and usage.get(c.tenant, 0) + cost > budget):
                    # over the tenant's admitted-token budget: hold it
                    # back (FCFS order preserved) and try the next rid
                    skipped.append(cand)
                    self.n_budget_skips += 1
                    continue
                rid = cand
                break
            if rid is None:
                break
            r = self.reqs[rid]
            if r.tenant is not None and budgets:
                usage[r.tenant] = (usage.get(r.tenant, 0)
                                   + len(r.prompt) + r.max_new)
            # re-prefill prompt + already-emitted tokens minus the last,
            # which becomes the next decode input (never re-sampled)
            r.fed = (np.concatenate([r.prompt,
                                     np.asarray(r.out[:-1], np.int32)])
                     if r.out else np.asarray(r.prompt, np.int32)
                     ).astype(np.int32)
            if self.cfg.radix_cache:
                # content-addressed attach: walk the radix trie with the
                # exact tokens this request will prefill (on re-admission
                # after a preempt that includes its own emitted tokens,
                # so a published victim re-attaches nearly everything)
                shared = self.alloc.admit(rid, tokens=r.fed)
            else:
                # legacy keyed attach, capped at the request's ORIGINAL
                # prompt: generated tokens diverge from the publisher's
                # continuation, and shared pages must never receive this
                # request's writes
                shared = self.alloc.admit(rid, prefix_key=r.prefix_key,
                                          prompt_len=len(r.prompt))
            r.prefill_pos = min(shared, max(len(r.fed) - 1, 0))
            r.state = "prefill"
            r.admit_t = now
            self.slot_rid[slot] = rid
            self.slot_seq[slot] = self._seq
            self._seq += 1
            self.table[slot] = self.alloc.table_row(rid, self.cfg.max_pages)
            self.lens[slot] = 0
            self.active[slot] = False
            self.tok[slot] = 0
            # resolve sampling knobs: request override > engine default.
            # The seed is a pure function of (cfg.seed, rid), so a
            # preempted request re-derives the identical draw stream
            cfg = self.cfg
            self.seeds[slot] = (r.seed if r.seed is not None
                                else (cfg.seed + rid) % (2 ** 31))
            self.temps[slot] = (r.temperature if r.temperature is not None
                                else cfg.temperature)
            self.topps[slot] = (r.top_p if r.top_p is not None
                                else cfg.top_p)
            self.topks[slot] = (r.top_k if r.top_k is not None
                                else cfg.top_k)
            self.admission_log.append(rid)
            self._m_admit.inc()
            self._admitted_tokens += len(r.fed)
            self.rlog.record("admit", rid, slot=slot, tick=self.ticks,
                             arg=len(r.fed))
        # over-budget holds return to the queue head in FCFS order
        for cand in reversed(skipped):
            self.queue.appendleft(cand)

    def _clear_slot(self, slot: int):
        self.slot_rid[slot] = -1
        self.table[slot] = 0
        self.lens[slot] = 0
        self.active[slot] = False
        self.tok[slot] = 0
        self.seeds[slot] = 0
        self.temps[slot] = 0.0
        self.topps[slot] = 1.0
        self.topks[slot] = 0

    def _written_tokens(self, slot: int) -> np.ndarray:
        """The token each written KV row holds, in row order — the
        invariant `row i holds KV of (prompt + out)[i]` is maintained by
        prefill (feeds prompt + out[:-1]), decode (feeds out[-1] at row
        `lens`), and spec commit (lens grows only over accepted rows).
        During prefill only `prefill_pos` rows are written."""
        rid = int(self.slot_rid[slot])
        r = self.reqs[rid]
        written = (r.prefill_pos if r.state == "prefill"
                   else int(self.lens[slot]))
        seq = (np.concatenate([r.prompt, np.asarray(r.out, np.int32)])
               if r.out else np.asarray(r.prompt, np.int32))
        return seq[:written].astype(np.int32)

    def _finish(self, slot: int, now: float):
        rid = int(self.slot_rid[slot])
        r = self.reqs[rid]
        if self.cfg.radix_cache:
            # publish-on-release: the request's full pages (prompt AND
            # generated tokens) enter the trie instead of recycling
            self.alloc.release(rid, tokens=self._written_tokens(slot))
        else:
            self.alloc.release(rid)
        r.state = "done"
        r.finish_t = now
        r.fed = None
        self._m_done.inc()
        self.rlog.record("complete", rid, slot=slot, tick=self.ticks,
                         arg=len(r.out))
        self._clear_slot(slot)

    def _preempt_slot(self, slot: int):
        """Free a victim's pages and requeue it at the queue head (FCFS
        re-admission: when several are preempted youngest-first, each
        appendleft puts the older one ahead).  With the radix cache the
        victim's full pages are published first — unless the sweep has
        to evict them, its re-prefill collapses to a cache hit."""
        rid = int(self.slot_rid[slot])
        r = self.reqs[rid]
        if self.cfg.radix_cache:
            self.alloc.preempt(rid, tokens=self._written_tokens(slot))
        else:
            self.alloc.preempt(rid)
        r.state = "queued"
        r.n_preempted += 1
        r.fed = None
        self.queue.appendleft(rid)
        self._clear_slot(slot)
        self.n_preemptions += 1
        self._m_preempt.inc()
        self.rlog.record("preempt", rid, slot=slot, tick=self.ticks)
        self.rlog.record("requeue", rid, tick=self.ticks)

    def _make_room(self, rid: int, n_tokens: int,
                   allow_preempt: bool = True) -> bool:
        """ensure_capacity with preempt-and-requeue: evict the youngest
        other resident until the grow fits (the allocator has already
        LRU-evicted unreferenced cached pages before reporting failure —
        eviction always precedes preemption).  Failing with no victims
        left means this request is the sole resident and STILL cannot
        fit — nothing will ever free (only pinned prefix pages and its
        own remain), so raise instead of letting the scheduler thrash
        through endless self-preemption.  With `allow_preempt=False`
        (decode-priority prefill) a grow that would need a victim
        returns False instead — the caller defers to a later tick."""
        while not self.alloc.ensure_capacity(rid, n_tokens):
            victims = [s for s in self._busy_slots()
                       if int(self.slot_rid[s]) != rid]
            if not victims:
                pinned = sum(len(p) for p in
                             self.alloc.prefix_index.values())
                raise RuntimeError(
                    f"request {rid} needs {n_tokens} tokens "
                    f"({-(-n_tokens // self.cfg.page_size)} pages) but the "
                    f"pool cannot satisfy it even empty: {self.alloc.n_free}"
                    f" free, {pinned} page refs pinned by registered "
                    f"prefixes (drop_prefix to release)")
            if not allow_preempt:
                return False
            self._preempt_slot(max(victims, key=lambda s: self.slot_seq[s]))
        return True

    # -- prefill --------------------------------------------------------------
    def _prefill_target(self) -> Optional[int]:
        """Oldest admitted slot with unprefilled tokens."""
        cands = [s for s in self._busy_slots()
                 if self.reqs[int(self.slot_rid[s])].state == "prefill"]
        if not cands:
            return None
        return min(cands, key=lambda s: self.slot_seq[s])

    def _prefill_tick(self, now: float) -> bool:
        """Run one prefill chunk for the oldest prefilling slot; returns
        True when it made progress (False: nothing to prefill, or the
        grow deferred under decode-priority)."""
        slot = self._prefill_target()
        if slot is None:
            return False
        rid = int(self.slot_rid[slot])
        r = self.reqs[rid]
        C = self.cfg.prefill_chunk
        n_valid = min(C, len(r.fed) - r.prefill_pos)
        # decode-priority: prefill never steals pages from in-flight
        # decode slots — if eviction can't cover the grow, defer the
        # chunk until decodes release naturally
        if not self._make_room(rid, r.prefill_pos + n_valid,
                               allow_preempt=(self.policy
                                              != "decode-priority")):
            return False
        self.table[slot] = self.alloc.table_row(rid, self.cfg.max_pages)
        chunk = np.zeros((C,), np.int32)
        chunk[:n_valid] = r.fed[r.prefill_pos:r.prefill_pos + n_valid]
        step_args = (jnp.asarray(chunk), jnp.int32(r.prefill_pos),
                     jnp.int32(n_valid), jnp.asarray(self.table[slot]),
                     jnp.int32(self.seeds[slot]),
                     jnp.float32(self.temps[slot]),
                     jnp.float32(self.topps[slot]),
                     jnp.int32(self.topks[slot]))
        if self.spec:
            nxt, self.pools, self.dpools = self._prefill(
                self.params, self.dparams, self.pools, self.dpools,
                *step_args)
        else:
            nxt, self.pools = self._prefill(self.params, self.pools,
                                            *step_args)
        r.prefill_pos += n_valid
        self.rlog.record("prefill_chunk", rid, slot=slot, tick=self.ticks,
                         arg=n_valid)
        if r.prefill_pos < len(r.fed):
            return True                 # more chunks to go
        # prompt (+ replayed tokens) fully written: enter decode state
        t = time.perf_counter()
        self.lens[slot] = len(r.fed)
        self.active[slot] = True
        r.state = "decode"
        self.rlog.record("prefill_done", rid, slot=slot, tick=self.ticks,
                         arg=len(r.fed))
        if self.cfg.radix_cache:
            # publish-on-prefill: the prompt's full pages enter the trie
            # the moment they are written, so concurrent arrivals with
            # the same prefix hit while this request is still decoding.
            # Content addressing dedupes same-prefix racers — no
            # prefix_key coordination, no double-publish
            self.alloc.publish_radix(rid, r.fed)
        elif (r.prefix_key and r.prefix_len > 0
                and r.prefix_key not in self.alloc.prefix_index):
            # legacy keyed auto-publish: first finisher wins; a same-key
            # racer's identical pages stay private (content-dedup needs
            # the radix path) and recycle on its release
            self.alloc.register_prefix(rid, r.prefix_key,
                                       min(r.prefix_len, len(r.prompt)))
        if not r.out:
            tok = int(jax.device_get(nxt))
            r.out.append(tok)
            r.first_token_t = t
            r.token_times.append(t)
            self._m_tokens.inc()
            self.rlog.record("first_token", rid, slot=slot, tick=self.ticks)
            if r.arrival_t > 0.0:
                self._m_ttft.observe((t - r.arrival_t) * 1e3)
            if len(r.out) >= r.max_new or tok == self.cfg.eos_id:
                self._finish(slot, t)
                return True
        self.tok[slot] = r.out[-1]
        return True

    # -- decode ---------------------------------------------------------------
    def _decode_tick(self, now: float):
        # grow every decode slot to hold its next position, oldest first
        # (the youngest is the preferred preemption victim, so growing in
        # age order never evicts a slot we already grew this tick)
        for slot in sorted(np.flatnonzero(self.active),
                           key=lambda s: self.slot_seq[s]):
            slot = int(slot)
            if not self.active[slot]:
                continue                # preempted by an earlier grow
            rid = int(self.slot_rid[slot])
            self._make_room(rid, int(self.lens[slot]) + 1)
            self.table[slot] = self.alloc.table_row(rid, self.cfg.max_pages)
        if not self.active.any():
            return
        nxt, self.pools = self._decode(
            self.params, self.pools, jnp.asarray(self.tok),
            jnp.asarray(self.lens), jnp.asarray(self.table),
            jnp.asarray(self.active), jnp.asarray(self.seeds),
            jnp.asarray(self.temps), jnp.asarray(self.topps),
            jnp.asarray(self.topks))
        nxt = np.asarray(jax.device_get(nxt))
        t = time.perf_counter()
        for slot in np.flatnonzero(self.active):
            slot = int(slot)
            rid = int(self.slot_rid[slot])
            r = self.reqs[rid]
            tok = int(nxt[slot])
            if r.token_times:
                self._m_itl.observe((t - r.token_times[-1]) * 1e3)
            r.out.append(tok)
            r.token_times.append(t)
            r.n_decode_ticks += 1
            self.lens[slot] += 1
            self.tok[slot] = tok
            self._m_tokens.inc()
            self.rlog.record("decode", rid, slot=slot, tick=self.ticks,
                             arg=1)
            if len(r.out) >= r.max_new or tok == self.cfg.eos_id:
                self._finish(slot, t)

    # -- speculative decode (propose -> verify -> commit) ----------------------
    def _spec_tick(self, now: float):
        """One speculative tick over the slot batch: the drafter proposes
        k tokens per slot (its KV advancing through its own pools), one
        target verify pass scores all k+1 positions, and the host commits
        `n_acc + 1` emitted tokens per slot — page-table tails rewound
        with `PageAllocator.trim` so rejected drafts hand their surplus
        pages straight back (LIFO: a regrow reacquires the identical
        pages, keeping page tables deterministic)."""
        K = self.cfg.spec_k
        # grow every slot to hold its k+1 candidate rows, oldest first
        for slot in sorted(np.flatnonzero(self.active),
                           key=lambda s: self.slot_seq[s]):
            slot = int(slot)
            if not self.active[slot]:
                continue                # preempted by an earlier grow
            rid = int(self.slot_rid[slot])
            self._make_room(rid, int(self.lens[slot]) + K + 1)
            self.table[slot] = self.alloc.table_row(rid, self.cfg.max_pages)
        if not self.active.any():
            return
        sample_args = (jnp.asarray(self.seeds), jnp.asarray(self.temps),
                       jnp.asarray(self.topps), jnp.asarray(self.topks))
        table = jnp.asarray(self.table)
        active = jnp.asarray(self.active)
        pos0 = jnp.asarray(self.lens)
        drafts, dprobs, self.dpools = self._draft(
            self.dparams, self.dpools, jnp.asarray(self.tok), pos0,
            table, active, *sample_args)
        tokens = jnp.concatenate(
            [jnp.asarray(self.tok)[:, None], drafts.astype(jnp.int32)],
            axis=1)                     # (B, k+1): pending token + drafts
        n_acc, out, self.pools = self._verify(
            self.params, self.pools, tokens, pos0, table, active, dprobs,
            *sample_args)
        n_acc = np.asarray(jax.device_get(n_acc))
        out = np.asarray(jax.device_get(out))
        t = time.perf_counter()
        for slot in np.flatnonzero(self.active):
            slot = int(slot)
            rid = int(self.slot_rid[slot])
            r = self.reqs[rid]
            na = int(n_acc[slot])
            self.spec_proposed += K
            self.spec_accepted += na
            r.n_decode_ticks += 1
            if r.token_times:
                self._m_itl.observe((t - r.token_times[-1]) * 1e3)
            # emit the accepted drafts + the bonus/residual token, cut
            # short by max_new / eos exactly like the plain decode path
            done = False
            kept = 0
            for tok in out[slot, :na + 1]:
                tok = int(tok)
                r.out.append(tok)
                r.token_times.append(t)
                kept += 1
                if len(r.out) >= r.max_new or tok == self.cfg.eos_id:
                    done = True
                    break
            self._m_tokens.inc(kept)
            self.rlog.record("decode", rid, slot=slot, tick=self.ticks,
                             arg=kept)
            if done:
                self._finish(slot, t)
                continue
            # commit: the pending token + na accepted drafts are now
            # written KV (kept == na + 1 rows starting at the old len);
            # the new pending token's KV lands next tick
            self.lens[slot] += kept
            self.tok[slot] = r.out[-1]
            self.alloc.trim(rid, int(self.lens[slot]))
            self.table[slot] = self.alloc.table_row(rid, self.cfg.max_pages)

    def pop_done(self) -> List[OnlineRequest]:
        """Remove and return finished requests.  The engine retains
        completed `OnlineRequest` objects (token streams + latency
        timestamps) until the caller collects them — a long-lived server
        loop must call this periodically or host memory grows with every
        request ever served."""
        done = [r for r in self.reqs.values() if r.done]
        for r in done:
            del self.reqs[r.rid]
        return done

    # -- driver ---------------------------------------------------------------
    # compile-count views over the shared CompileCounter (the names the
    # tests/benches have always used; the counter itself is the API for
    # contracts.compile_guard)
    @property
    def prefill_traces(self) -> int:
        return self.compiles["prefill"]

    @property
    def decode_traces(self) -> int:
        return self.compiles["decode"]

    @property
    def draft_traces(self) -> int:
        return self.compiles["draft"]

    @property
    def verify_traces(self) -> int:
        return self.compiles["verify"]

    @property
    def idle(self) -> bool:
        return not self.queue and not self._busy_slots()

    def tick(self, now: Optional[float] = None):
        """One engine step under the active scheduling policy:

        * ``fcfs`` — admission -> one prefill chunk -> one decode (or
          speculative propose/verify/commit) tick.  The balanced
          default: long prompts cost the batch one chunk per tick.
        * ``decode-priority`` — decode first, then at most one prefill
          chunk, and prefill growth never preempts a decoding slot
          (it defers until decodes release pages): in-flight requests
          are never starved or evicted by arriving prompts.
        * ``prefill-priority`` — drain EVERY pending prefill chunk
          before decoding, preempting decode slots for room if needed:
          the head-of-queue request reaches its first token within one
          tick of admission, bounding TTFT at the cost of decode ITL.

        All three drive the same compiled steps — switching policies
        never recompiles."""
        now = time.perf_counter() if now is None else now
        self.ticks += 1
        t_start = time.perf_counter()
        step_span = "spec" if self.spec else "decode"
        with self.timer.span("tick"), self._tick_guard():
            with self.timer.span("admit"):
                self._admit(now)
            step = self._spec_tick if self.spec else self._decode_tick
            if self.policy == "decode-priority":
                with self.timer.span(step_span):
                    step(now)
                with self.timer.span("prefill"):
                    self._prefill_tick(now)
            elif self.policy == "prefill-priority":
                with self.timer.span("prefill"):
                    while self._prefill_tick(now):
                        pass
                with self.timer.span(step_span):
                    step(now)
            else:                            # fcfs
                with self.timer.span("prefill"):
                    self._prefill_tick(now)
                with self.timer.span(step_span):
                    step(now)
        self._m_tick.observe((time.perf_counter() - t_start) * 1e3)
        self._sample_counters()

    def _sample_counters(self):
        """Per-tick host-scalar samples -> gauges + Perfetto counter
        tracks.  Every value is bookkeeping the scheduler already holds
        (allocator free-list length, queue length, cumulative stats) —
        nothing here can touch the device."""
        t_us = int(time.perf_counter() * 1e6)
        in_use = self.alloc.pages_in_use
        self._g_pages.set(in_use)
        self._s_pages.sample(in_use, t_us)
        depth = len(self.queue)
        self._g_queue.set(depth)
        self._s_queue.sample(depth, t_us)
        self._g_slots.set(int((self.slot_rid >= 0).sum()))
        hit_rate = (self.alloc.stats["radix_hit_tokens"]
                    / max(self._admitted_tokens, 1))
        self._s_radix.sample(hit_rate, t_us)
        if self._s_accept is not None:
            self._s_accept.sample(
                self.spec_accepted / max(self.spec_proposed, 1), t_us)

    def _tick_guard(self):
        """debug_guards mode: the whole tick runs under a device->host
        transfer_guard, so any sync the engine did not announce with an
        explicit jax.device_get is an error on guarded backends (TPU/GPU
        — the CPU backend never fires transfer guards)."""
        if self.debug_guards:
            return contracts.transfer_guard("disallow")
        return contextlib.nullcontext()

    def run(self, max_ticks: int = 100_000):
        """Drive ticks until every submitted request is done."""
        for _ in range(max_ticks):
            if self.idle:
                return
            self.tick()
        raise RuntimeError(f"engine did not drain in {max_ticks} ticks "
                           f"(queue={len(self.queue)}, "
                           f"busy={self._busy_slots()})")


# ---------------------------------------------------------------------------
# Poisson load generator
# ---------------------------------------------------------------------------


def _pctl(xs: Sequence[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def run_poisson_load(engine: OnlineEngine, *, rate: float, n_requests: int,
                     prompt_len: int, max_new: int, vocab_size: int,
                     seed: int = 0, max_ticks: int = 1_000_000,
                     shared_prefix_len: int = 0,
                     prefix_key: Optional[str] = None,
                     tenants: Optional[Sequence[str]] = None
                     ) -> Dict[str, Any]:
    """Open-loop Poisson arrivals at `rate` req/s against a live engine.

    Requests are submitted when their scheduled arrival time passes on
    the wall clock (the engine keeps ticking in between — arrivals join
    the running batch), so TTFT includes genuine queueing delay.
    Returns TTFT p50/p99, pooled inter-token latency p50/p99, sustained
    tok/s, and churn counters.

    With ``shared_prefix_len > 0`` every prompt starts with the same
    `shared_prefix_len`-token system prompt followed by a random suffix
    (the chat-serving hot-prefix shape).  With the radix cache on, the
    hits need **no coordination**: the first request to finish prefill
    publishes its full pages into the trie and later arrivals attach by
    content — the report's `prefix_hits` / `prefix_hit_rate` count how
    many did.  With the cache off the legacy `prefix_key` registry
    carries the sharing instead.  The cache is flushed before returning
    so repeated loads on one engine start cold.

    A bounded-queue engine may defer (submission retried while the
    arrival is late) or shed (request dropped, counted in `n_shed`)
    under overload; `tenants` round-robins the given tenant names onto
    requests so per-tenant admission budgets can be exercised."""
    rs = np.random.RandomState(seed)
    gaps = rs.exponential(1.0 / rate, size=n_requests)
    arrivals = np.cumsum(gaps)
    shared_prefix_len = min(shared_prefix_len, prompt_len)
    use_key = shared_prefix_len > 0 and not engine.cfg.radix_cache
    if use_key and prefix_key is None:
        prefix_key = f"poisson-load-{seed}"
    shared = rs.randint(0, vocab_size, shared_prefix_len).astype(np.int32)
    prompts = [np.concatenate([
        shared,
        rs.randint(0, vocab_size,
                   prompt_len - shared_prefix_len).astype(np.int32)])
        for _ in range(n_requests)]
    base = (max(engine.reqs) + 1) if engine.reqs else 0   # engine reuse
    ticks0, preempts0 = engine.ticks, engine.n_preemptions
    hits0 = engine.alloc.stats["prefix_hits"]
    hit_tok0 = engine.alloc.stats["radix_hit_tokens"]
    evict0 = engine.alloc.stats["evictions"]
    shed0, budget_skips0 = engine.n_shed, engine.n_budget_skips
    proposed0, accepted0 = engine.spec_proposed, engine.spec_accepted
    reqs = [OnlineRequest(rid=base + i, prompt=prompts[i], max_new=max_new,
                          prefix_key=(prefix_key if use_key else None),
                          prefix_len=(shared_prefix_len if use_key else 0),
                          tenant=(tenants[i % len(tenants)]
                                  if tenants else None))
            for i in range(n_requests)]
    t0 = time.perf_counter()
    submitted = 0
    budget = max_ticks
    while submitted < n_requests or not engine.idle:
        budget -= 1
        if budget < 0:
            raise RuntimeError(f"load run did not drain in {max_ticks} "
                               f"ticks ({submitted}/{n_requests} submitted)")
        now = time.perf_counter()
        while (submitted < n_requests
               and arrivals[submitted] <= now - t0):
            r = reqs[submitted]
            r.arrival_t = t0 + arrivals[submitted]
            if engine.submit(r):
                submitted += 1
            elif r.state == "shed":
                submitted += 1           # gate dropped it; move on
            else:
                break                    # deferred: retry next loop
        if engine.idle and submitted < n_requests:
            time.sleep(min(arrivals[submitted] - (now - t0), 0.01))
            continue
        engine.tick(now)
    t_end = time.perf_counter()

    served = [r for r in reqs if r.state != "shed"]
    n_shed = len(reqs) - len(served)
    assert all(r.done for r in served)
    engine.pop_done()              # keep the engine bounded across loads
    if prefix_key is not None and prefix_key in engine.alloc.prefix_index:
        engine.alloc.drop_prefix(prefix_key)
    engine.alloc.flush_radix()     # repeated loads start cache-cold
    ttft = [r.first_token_t - r.arrival_t for r in served]
    itl: List[float] = []
    for r in served:
        itl.extend(b - a for a, b in zip(r.token_times, r.token_times[1:]))
    n_tokens = sum(len(r.out) for r in served)
    # decode economics: the first token rides prefill, every later token
    # rides a decode/spec tick — speculative acceptance pushes
    # ticks-per-token below 1
    decode_ticks = sum(r.n_decode_ticks for r in served)
    decoded = sum(max(len(r.out) - 1, 0) for r in served)
    proposed = engine.spec_proposed - proposed0
    accepted = engine.spec_accepted - accepted0
    # SLO gate view (overload="slo"): windowed percentiles + deadlines at
    # end of run — ttft_p50/p99_ms above already cover ADMITTED requests
    # only (shed ones never reach a first token), which is the population
    # the deadline is defined over
    slo_view = engine.slo.snapshot() if engine.slo is not None else None
    return {
        "rate_req_s": rate,
        "n_requests": n_requests,
        "prompt_len": prompt_len,
        "max_new": max_new,
        "policy": engine.policy,
        "radix_cache": engine.cfg.radix_cache,
        "paged_attn": engine.paged_attn,
        "wall_s": t_end - t0,
        "tokens_out": n_tokens,
        "tok_s": n_tokens / max(t_end - t0, 1e-9),
        "ttft_p50_ms": 1e3 * _pctl(ttft, 50),
        "ttft_p99_ms": 1e3 * _pctl(ttft, 99),
        "itl_p50_ms": 1e3 * _pctl(itl, 50),
        "itl_p99_ms": 1e3 * _pctl(itl, 99),
        "ticks": engine.ticks - ticks0,
        "preemptions": engine.n_preemptions - preempts0,
        "shed": engine.n_shed - shed0,
        "budget_skips": engine.n_budget_skips - budget_skips0,
        "prefill_compiles": engine.prefill_traces,
        "decode_compiles": engine.decode_traces,
        "draft_compiles": engine.draft_traces,
        "verify_compiles": engine.verify_traces,
        "shared_prefix_len": shared_prefix_len,
        "prefix_hits": engine.alloc.stats["prefix_hits"] - hits0,
        "prefix_hit_rate": (engine.alloc.stats["prefix_hits"] - hits0)
        / max(n_requests, 1),
        "prefix_hit_tokens": (engine.alloc.stats["radix_hit_tokens"]
                              - hit_tok0),
        "cache_evictions": engine.alloc.stats["evictions"] - evict0,
        "spec_k": engine.cfg.spec_k,
        "acceptance_rate": accepted / max(proposed, 1),
        "decode_ticks_per_token": decode_ticks / max(decoded, 1),
        "allocator": dict(engine.alloc.stats),
        "overload": engine.cfg.overload,
        "slo": slo_view,
    }
