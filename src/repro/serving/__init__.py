"""repro subpackage."""
