"""Drafters for speculative decoding in the online engine.

A drafter is anything with ``build(runner, params) -> (draft_runner,
draft_params)`` where the returned runner/params drive
`api.Runner.make_paged_draft_propose` over the drafter's OWN page pools.
The engine gives the drafter the SAME page ids, page size, and pool
count as the target, so admission, growth, preemption, prefix sharing,
and trim all transfer to the drafter KV for free — the drafter pool is
just a second set of (n_pages, ps_loc, KV, hd) tensors indexed by the
same tables.

Two implementations:

  * **SelfDrafter** — truncated-layer self-draft (AquilaMoE-style reuse,
    no new weights): the draft model is the first `draft_layers` blocks
    of the target plus its own embedding / final norm / LM head.  Params
    are views into the target's (stacked leaves sliced, or the block
    list truncated), so HBM cost is only the drafter KV pool.
    `draft_layers == n_layers` degenerates to an exact copy of the
    target — q == p bitwise, every draft accepted — which is the upper
    bound the benchmarks calibrate against.

  * **ConfigDrafter** — any small paged-compatible config sharing the
    target's vocab (e.g. an adapted `h2o_danube_1_8b` smoke config)
    behind the same interface.  Params are loaded by the caller or
    randomly initialized (`init_seed`); `adapt_drafter_config` rewrites
    a foreign config to be pageable (swa -> attn) and vocab-aligned.

Acceptance-rate guidance lives in docs/serving.md — the short version:
the engine is correct for ANY drafter quality (greedy streams are
bitwise-exact regardless), but ticks/token only drops below 1 when the
drafter actually agrees with the target, so drafters that share the
target's weights (self-draft) or a distilled checkpoint are the ones
worth running.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax

from repro import api
from repro.configs.base import ModelConfig
from repro.models import model as M


def _draft_runner(cfg: ModelConfig, runner: "api.Runner") -> "api.Runner":
    return api.Runner(cfg, runner.mesh, flags=runner.flags,
                      fsdp=runner.fsdp, seq_parallel=False,
                      max_seq=runner.max_seq)


@dataclasses.dataclass
class SelfDrafter:
    """Truncated-layer self-draft: first `draft_layers` blocks of the
    target + its shared embedding/final-norm/head.  No new weights."""
    draft_layers: int
    name: str = "self"

    def build(self, runner: "api.Runner", params
              ) -> Tuple["api.Runner", dict]:
        cfg = runner.cfg
        L = int(self.draft_layers)
        if not 1 <= L <= cfg.n_layers:
            raise ValueError(f"draft_layers={L} out of range "
                             f"[1, {cfg.n_layers}] for {cfg.arch_id}")
        dcfg = dataclasses.replace(cfg, n_layers=L)
        M.check_paged_support(dcfg)
        blocks = params["blocks"]
        if isinstance(blocks, list):
            dblocks = blocks[:L]
        else:                        # uniform arch: stacked leading layer dim
            dblocks = jax.tree.map(lambda x: x[:L], blocks)
        dparams = {"embed": params["embed"],
                   "final_norm": params["final_norm"],
                   "blocks": dblocks}
        return _draft_runner(dcfg, runner), dparams


@dataclasses.dataclass
class ConfigDrafter:
    """Independent small-model drafter.  `cfg` must be paged-compatible
    and share the target's vocab_size (the accept math indexes one
    distribution with the other's tokens).  `params` holds a loaded
    checkpoint; when None, weights are randomly initialized from
    `init_seed` (useful for plumbing tests — a random drafter is
    correct, just rarely accepted)."""
    cfg: ModelConfig
    params: Optional[dict] = None
    init_seed: int = 0
    name: str = "config"

    def build(self, runner: "api.Runner", params
              ) -> Tuple["api.Runner", dict]:
        M.check_paged_support(self.cfg)
        if self.cfg.vocab_size != runner.cfg.vocab_size:
            raise ValueError(
                f"drafter vocab_size={self.cfg.vocab_size} != target "
                f"{runner.cfg.vocab_size}; align with adapt_drafter_config")
        drunner = _draft_runner(self.cfg, runner)
        dparams = (self.params if self.params is not None
                   else drunner.init_params(self.init_seed))
        return drunner, dparams


def adapt_drafter_config(cfg: ModelConfig,
                         target: ModelConfig) -> ModelConfig:
    """Rewrite a foreign config into a valid drafter for `target`:
    sliding-window blocks become plain 'attn' (the paged pools hold full
    context anyway at serving lengths) and the vocab is aligned so the
    spec accept math can index target distributions with drafter tokens.
    A checkpoint trained for the original config does NOT transfer
    losslessly through this rewrite — it is for plumbing fresh/distilled
    drafter weights, not for reusing off-the-shelf ones."""
    kinds = tuple("attn" if k == "swa" else k for k in cfg.block_pattern)
    return dataclasses.replace(cfg, block_pattern=kinds, attn_window=None,
                               vocab_size=target.vocab_size)
