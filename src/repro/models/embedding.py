"""Vocab-sharded embedding, LM head, and the sharded cross-entropy loss.

Vocab is padded to a multiple of (tp * 128) and sharded over the tp axis.
Embedding lookup and LM-head logits never materialize a replicated
(T, V) tensor: each rank handles its vocab slice and the softmax statistics
are combined with pmax/psum over tp.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import normhead
from repro.models import layers as L
from repro.sharding import AxisEnv, fsdp_spec, pad_to_multiple


def padded_vocab(cfg, env: AxisEnv) -> int:
    return pad_to_multiple(cfg.vocab_size, env.tp * 128)


def init_embedding(key, cfg, env: AxisEnv):
    vp = padded_vocab(cfg, env)
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    params = {"table": L.dense_init(k1, (vp, cfg.d_model), dt)}
    specs = {"table": fsdp_spec(env, 2, 1, 0)}   # vocab over tp, d over dp
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(k2, (vp, cfg.d_model), dt)
        specs["lm_head"] = fsdp_spec(env, 2, 1, 0)
    return params, specs


def embed_tokens(cfg, env: AxisEnv, params, ids: jax.Array) -> jax.Array:
    """ids (T,) replicated over tp -> SP activations (T_sp, d) via
    masked local lookup + reduce-scatter over tp."""
    table = env.gather_fsdp(params["table"], 1,
                            dtype=jnp.dtype(cfg.compute_dtype))
    v_loc = table.shape[0]
    r = env.tp_index()
    local = ids - r * v_loc
    in_range = (local >= 0) & (local < v_loc)
    rows = jnp.take(table, jnp.clip(local, 0, v_loc - 1), axis=0)
    partial = jnp.where(in_range[:, None], rows, 0.0)
    partial = partial.astype(jnp.dtype(cfg.compute_dtype))
    return env.sp_scatter(partial)


def lm_logits(cfg, env: AxisEnv, params, x: jax.Array) -> jax.Array:
    """x (T, d) -> vocab-local logits (T, V_loc) fp32 (NormHead per cfg)."""
    w = params["table"] if cfg.tie_embeddings else params["lm_head"]
    return normhead.normhead_logits(cfg, env, w, x)


def sharded_xent(cfg, env: AxisEnv, logits_loc: jax.Array, labels: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    """Cross-entropy over tp-sharded vocab.  labels (T,), -1 = ignore.
    Returns (mean loss over valid tokens globally, n_valid_local)."""
    v_loc = logits_loc.shape[-1]
    r = env.tp_index()
    # mask vocab padding rows (global id >= vocab_size)
    gid = r * v_loc + jnp.arange(v_loc)
    logits_loc = jnp.where(gid[None, :] < cfg.vocab_size, logits_loc, -1e30)

    m = env.pmax_tp(jax.lax.stop_gradient(jnp.max(logits_loc, axis=-1)))
    se = env.psum_tp(jnp.sum(jnp.exp(logits_loc - m[:, None]), axis=-1))
    lse = m + jnp.log(se)

    local = labels - r * v_loc
    in_range = (local >= 0) & (local < v_loc)
    picked = jnp.take_along_axis(
        logits_loc, jnp.clip(local, 0, v_loc - 1)[:, None], axis=-1)[:, 0]
    correct = env.psum_tp(jnp.where(in_range, picked, 0.0))

    valid = labels >= 0
    per_tok = jnp.where(valid, lse - correct, 0.0)
    n_valid = jnp.sum(valid.astype(jnp.float32))
    total = env.psum_dp(jnp.sum(per_tok))
    n_total = env.psum_dp(n_valid)
    return total / jnp.maximum(n_total, 1.0), n_valid


def sharded_argmax(env: AxisEnv, logits_loc: jax.Array) -> jax.Array:
    """Greedy sampling over tp-sharded vocab.  logits (T, V_loc) -> (T,)."""
    v_loc = logits_loc.shape[-1]
    r = env.tp_index()
    loc_idx = jnp.argmax(logits_loc, axis=-1)
    loc_max = jnp.take_along_axis(logits_loc, loc_idx[:, None], axis=-1)[:, 0]
    gmax = env.pmax_tp(loc_max)
    cand = jnp.where(loc_max >= gmax, r * v_loc + loc_idx,
                     jnp.iinfo(jnp.int32).max)
    return -env.pmax_tp(-cand)   # min over tp = lowest-id global argmax
