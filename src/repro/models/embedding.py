"""Vocab-sharded embedding, LM head, and the sharded cross-entropy loss.

Vocab is padded to a multiple of (tp * 128) and sharded over the tp axis.
Embedding lookup and LM-head logits never materialize a replicated
(T, V) tensor: each rank handles its vocab slice and the softmax statistics
are combined with pmax/psum over tp.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import normhead
from repro.models import layers as L
from repro.sharding import AxisEnv, fsdp_spec, pad_to_multiple


def padded_vocab(cfg, env: AxisEnv) -> int:
    return pad_to_multiple(cfg.vocab_size, env.tp * 128)


def init_embedding(key, cfg, env: AxisEnv):
    vp = padded_vocab(cfg, env)
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    params = {"table": L.dense_init(k1, (vp, cfg.d_model), dt)}
    specs = {"table": fsdp_spec(env, 2, 1, 0)}   # vocab over tp, d over dp
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(k2, (vp, cfg.d_model), dt)
        specs["lm_head"] = fsdp_spec(env, 2, 1, 0)
    return params, specs


def embed_tokens(cfg, env: AxisEnv, params, ids: jax.Array) -> jax.Array:
    """ids (T,) replicated over tp -> SP activations (T_sp, d) via
    masked local lookup + reduce-scatter over tp."""
    table = env.gather_fsdp(params["table"], 1,
                            dtype=jnp.dtype(cfg.compute_dtype))
    v_loc = table.shape[0]
    r = env.tp_index()
    local = ids - r * v_loc
    in_range = (local >= 0) & (local < v_loc)
    rows = jnp.take(table, jnp.clip(local, 0, v_loc - 1), axis=0)
    partial = jnp.where(in_range[:, None], rows, 0.0)
    partial = partial.astype(jnp.dtype(cfg.compute_dtype))
    return env.sp_scatter(partial)


def lm_logits(cfg, env: AxisEnv, params, x: jax.Array) -> jax.Array:
    """x (T, d) -> vocab-local logits (T, V_loc) fp32 (NormHead per cfg)."""
    w = params["table"] if cfg.tie_embeddings else params["lm_head"]
    return normhead.normhead_logits(cfg, env, w, x)


def sharded_xent(cfg, env: AxisEnv, logits_loc: jax.Array, labels: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    """Cross-entropy over tp-sharded vocab.  labels (T,), -1 = ignore.
    Returns (mean loss over valid tokens globally, n_valid_local)."""
    v_loc = logits_loc.shape[-1]
    r = env.tp_index()
    # mask vocab padding rows (global id >= vocab_size)
    gid = r * v_loc + jnp.arange(v_loc)
    logits_loc = jnp.where(gid[None, :] < cfg.vocab_size, logits_loc, -1e30)

    m = env.pmax_tp(jax.lax.stop_gradient(jnp.max(logits_loc, axis=-1)))
    se = env.psum_tp(jnp.sum(jnp.exp(logits_loc - m[:, None]), axis=-1))
    lse = m + jnp.log(se)

    local = labels - r * v_loc
    in_range = (local >= 0) & (local < v_loc)
    picked = jnp.take_along_axis(
        logits_loc, jnp.clip(local, 0, v_loc - 1)[:, None], axis=-1)[:, 0]
    correct = env.psum_tp(jnp.where(in_range, picked, 0.0))

    valid = labels >= 0
    per_tok = jnp.where(valid, lse - correct, 0.0)
    n_valid = jnp.sum(valid.astype(jnp.float32))
    total = env.psum_dp(jnp.sum(per_tok))
    n_total = env.psum_dp(n_valid)
    return total / jnp.maximum(n_total, 1.0), n_valid


def sharded_argmax(env: AxisEnv, logits_loc: jax.Array) -> jax.Array:
    """Greedy sampling over tp-sharded vocab.  logits (T, V_loc) -> (T,)."""
    v_loc = logits_loc.shape[-1]
    r = env.tp_index()
    loc_idx = jnp.argmax(logits_loc, axis=-1)
    loc_max = jnp.take_along_axis(logits_loc, loc_idx[:, None], axis=-1)[:, 0]
    gmax = env.pmax_tp(loc_max)
    cand = jnp.where(loc_max >= gmax, r * v_loc + loc_idx,
                     jnp.iinfo(jnp.int32).max)
    return -env.pmax_tp(-cand)   # min over tp = lowest-id global argmax


# ---------------------------------------------------------------------------
# Stochastic sampling (temperature / top-k / top-p) over the sharded vocab
# ---------------------------------------------------------------------------
#
# The serving engines sample with a counter-based key schedule: every draw
# is keyed by (seed, position, stream), where `seed` is per-request,
# `position` is the global sequence position of the *input* token the
# logits came from, and `stream` separates independent draw purposes.
# This makes sampling a pure function of (logits, seed, pos) — offline
# and online engines emit identical streams under a shared seed schedule,
# and preemption replay is exact (emitted tokens are never re-sampled;
# the next draw re-derives the same key).  All sampling knobs are DATA
# ((T,) arrays), so mixed-temperature batches share one compiled step.

STREAM_SAMPLE = 0     # canonical next-token draw (offline == online)
STREAM_DRAFT = 1      # drafter proposals (spec decode)
STREAM_ACCEPT = 2     # accept/reject uniforms (spec decode)
STREAM_RESID = 3      # residual/bonus draw on rejection (spec decode)


def sample_keys(seeds: jax.Array, pos: jax.Array, stream: int) -> jax.Array:
    """Per-row PRNG keys from the (seed, position, stream) schedule.
    seeds (T,) int32/uint32, pos (T,) int32 -> (T, 2) uint32 key data."""
    base = jax.random.PRNGKey(0)

    def one(s, p):
        k = jax.random.fold_in(base, s)
        k = jax.random.fold_in(k, p)
        return jax.random.fold_in(k, jnp.uint32(stream))

    return jax.vmap(one)(seeds.astype(jnp.uint32), pos.astype(jnp.uint32))


def transform_logits(full_logits: jax.Array, temperature: jax.Array,
                     top_p: jax.Array, top_k: jax.Array) -> jax.Array:
    """Full-vocab logits (T, V) -> sampling distribution (T, V) fp32.

    Pure per-row math (no collectives) so it unit-tests on plain arrays.
    Order: temperature scale -> top-k cut -> softmax -> top-p (nucleus)
    cut -> renormalize.  Knobs are per-row data: temperature <= 0 rows
    are returned as-is here (callers overwrite them with the exact
    argmax one-hot — see `sampled_probs`); top_k <= 0 and top_p >= 1
    disable their cuts.  Ties at the top-k/top-p boundary keep every
    equal-scoring token (documented caveat: the nucleus can hold a few
    more tokens than the minimal mass-covering set)."""
    T, V = full_logits.shape
    x = full_logits.astype(jnp.float32)
    t = jnp.maximum(temperature.astype(jnp.float32), 1e-6)[:, None]
    x = x / t
    # top-k: keep logits >= the kth largest (row-wise threshold)
    srt = jnp.sort(x, axis=-1)[:, ::-1]                    # descending
    kth = jnp.take_along_axis(
        srt, jnp.clip(top_k.astype(jnp.int32), 1, V)[:, None] - 1,
        axis=-1)
    x = jnp.where((top_k[:, None] > 0) & (x < kth), -jnp.inf, x)
    probs = jax.nn.softmax(x, axis=-1)
    # top-p: smallest prefix of the sorted probs with mass >= top_p;
    # exclusive cumsum < top_p keeps at least the top token
    ps = jnp.sort(probs, axis=-1)[:, ::-1]
    cum = jnp.cumsum(ps, axis=-1) - ps                     # exclusive
    keep_sorted = cum < jnp.minimum(top_p, 1.0)[:, None]
    # map back via the smallest kept probability as a threshold
    thr = jnp.min(jnp.where(keep_sorted, ps, jnp.inf), axis=-1)
    keep = (top_p[:, None] >= 1.0) | (probs >= thr[:, None])
    probs = jnp.where(keep, probs, 0.0)
    return probs / jnp.maximum(jnp.sum(probs, axis=-1, keepdims=True),
                               1e-30)


def sampled_probs(cfg, env: AxisEnv, logits_loc: jax.Array,
                  temperature: jax.Array, top_p: jax.Array,
                  top_k: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(T, V_loc) sharded logits -> (greedy (T,), probs (T, Vp) fp32).

    `probs` is the REPLICATED transformed sampling distribution over the
    padded vocab (identical on every tp rank: the gather is deterministic
    and the transforms are collective-free), with padding columns exactly
    0.  Rows with temperature <= 0 are exact one-hots of `sharded_argmax`
    — same lowest-global-id tie-break — so greedy spec-decode accept math
    degenerates to exact token comparison with no special-casing."""
    greedy = sharded_argmax(env, logits_loc).astype(jnp.int32)
    full = env.all_gather_tp(logits_loc, axis=1)           # (T, Vp)
    vp = full.shape[-1]
    gid = jnp.arange(vp)
    full = jnp.where(gid[None, :] < cfg.vocab_size, full, -jnp.inf)
    probs = transform_logits(full, temperature, top_p, top_k)
    onehot = jax.nn.one_hot(greedy, vp, dtype=jnp.float32)
    probs = jnp.where((temperature <= 0.0)[:, None], onehot, probs)
    return greedy, probs


def sharded_sample(cfg, env: AxisEnv, logits_loc: jax.Array, *,
                   seeds: jax.Array, pos: jax.Array, temperature: jax.Array,
                   top_p: jax.Array, top_k: jax.Array,
                   stream: int = STREAM_SAMPLE
                   ) -> Tuple[jax.Array, jax.Array]:
    """Temperature/top-k/top-p sampling over the tp-sharded vocab.

    logits (T, V_loc); all knobs (T,) per-row data.  Returns
    (token (T,) int32, probs (T, Vp) — the distribution actually sampled
    from, which spec-decode accept math consumes as p/q).  Rows with
    temperature <= 0 return the bitwise `sharded_argmax` token."""
    greedy, probs = sampled_probs(cfg, env, logits_loc, temperature,
                                  top_p, top_k)
    keys = sample_keys(seeds, pos, stream)
    cat = jax.vmap(lambda k, p: jax.random.categorical(k, jnp.log(p)))(
        keys, probs).astype(jnp.int32)
    tok = jnp.where(temperature <= 0.0, greedy, cat)
    return tok.astype(jnp.int32), probs
