"""RG-LRU recurrent block (RecurrentGemma / Griffin) [arXiv:2402.19427].

Real-Gated Linear Recurrent Unit:
    r_t = sigmoid(blockdiag(W_a) x_t + b_a)        (recurrence gate)
    i_t = sigmoid(blockdiag(W_x) x_t + b_x)        (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t),  c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

wrapped in the Griffin recurrent block:
    u  = W_in x           (width d_rnn = d_model)
    u' = causal_conv1d_4(u)
    h  = RGLRU(u')
    y  = W_out (h * gelu(W_gate x))

Sharding: channels sharded over tp; the gate projections are block-diagonal
with N_BLOCKS=32 blocks (as in the published model), so every gate block is
local to one rank — the recurrence needs zero collectives.  Only W_in /
W_gate (column) and W_out (row) touch the tp axis.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding import AxisEnv, fsdp_spec

N_BLOCKS = 32
CONV_WIDTH = 4
C_SCALE = 8.0


def dims(cfg, env: AxisEnv):
    dr = cfg.d_model                   # rnn width
    assert dr % N_BLOCKS == 0 and N_BLOCKS % env.tp == 0
    dr_loc = dr // env.tp
    blocks_loc = N_BLOCKS // env.tp
    return dr, dr_loc, blocks_loc, dr // N_BLOCKS


def init_rglru(key, cfg, env: AxisEnv):
    d = cfg.d_model
    dr, _, _, bd = dims(cfg, env)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    out_scale = 0.02 / max(cfg.n_layers, 1) ** 0.5
    params = {
        "w_in": L.dense_init(ks[0], (d, dr), dt),
        "w_gate": L.dense_init(ks[1], (d, dr), dt),
        "w_out": L.dense_init(ks[2], (dr, d), dt, out_scale),
        "conv_w": L.dense_init(ks[3], (CONV_WIDTH, dr), dt, 0.1),
        "conv_b": jnp.zeros((dr,), dt),
        # block-diagonal gate projections: (N_BLOCKS, bd, bd)
        "wa": L.dense_init(ks[4], (N_BLOCKS, bd, bd), dt),
        "wx": L.dense_init(ks[5], (N_BLOCKS, bd, bd), dt),
        "ba": jnp.zeros((dr,), dt),
        "bx": jnp.zeros((dr,), dt),
        # Lambda parametrized so a^c starts in (0.9, 0.999)
        "lam": jnp.linspace(2.0, 5.0, dr).astype(dt),
    }
    tpa = env.tp_axis
    specs = {
        "w_in": fsdp_spec(env, 2, 0, 1),
        "w_gate": fsdp_spec(env, 2, 0, 1),
        "w_out": fsdp_spec(env, 2, 1, 0),
        "conv_w": fsdp_spec(env, 2, None, 1),
        "conv_b": fsdp_spec(env, 1, None, 0),
        # block-diag gates are small (N_BLOCKS x bd x bd): tp-sharded on
        # the block dim only (bd need not divide the dp axis size)
        "wa": fsdp_spec(env, 3, None, 0),
        "wx": fsdp_spec(env, 3, None, 0),
        "ba": fsdp_spec(env, 1, None, 0),
        "bx": fsdp_spec(env, 1, None, 0),
        "lam": fsdp_spec(env, 1, None, 0),
    }
    return params, specs


def _gates(cfg, env, params, u):
    """u (..., dr_loc) -> (a_gate_logit, x_gate_logit) via block-diag proj."""
    _, dr_loc, blk_loc, bd = dims(cfg, env)
    cdt = u.dtype
    wa = params["wa"].astype(cdt)          # (blk_loc, bd, bd) tp-local
    wx = params["wx"].astype(cdt)
    ba = params["ba"].astype(cdt)          # tp-sharded, local
    bx = params["bx"].astype(cdt)
    ub = u.reshape(u.shape[:-1] + (blk_loc, bd))
    ga = jnp.einsum("...nb,nbc->...nc", ub, wa).reshape(u.shape) + ba
    gx = jnp.einsum("...nb,nbc->...nc", ub, wx).reshape(u.shape) + bx
    return ga, gx


def _log_a(params, env, r):
    lam = params["lam"].astype(jnp.float32)  # tp-sharded, local
    return -C_SCALE * jax.nn.softplus(lam) * r


def causal_conv(params, env, u, state: Optional[jax.Array] = None):
    """Per-channel causal conv, width 4.  u (B, S, dr_loc)."""
    w = params["conv_w"].astype(u.dtype)   # tp-sharded dim1, local
    b = params["conv_b"].astype(u.dtype)
    if state is None:
        pad = jnp.zeros(u.shape[:1] + (CONV_WIDTH - 1,) + u.shape[2:], u.dtype)
    else:
        pad = state
    up = jnp.concatenate([pad, u], axis=1)
    out = sum(w[j] * up[:, j:j + u.shape[1]] for j in range(CONV_WIDTH)) + b
    new_state = up[:, -(CONV_WIDTH - 1):]
    return out, new_state


def rglru_scan(a_log, gx, u, h0):
    """Reference linear recurrence.  a_log (B,S,dr) log decay; u inputs."""
    x_in = jax.nn.sigmoid(gx) * u
    a = jnp.exp(a_log)
    scaled = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * x_in

    def step(h, inp):
        at, xt = inp
        h = at * h + xt
        return h, h

    inputs = (jnp.moveaxis(a, 1, 0), jnp.moveaxis(scaled, 1, 0))
    h_last, hs = jax.lax.scan(step, h0, inputs)
    return jnp.moveaxis(hs, 0, 1), h_last


def recurrent_block(cfg, env: AxisEnv, params, x: jax.Array,
                    state: Optional[Dict] = None):
    """Train/prefill.  x (B, S, d) full per dp-shard ->
    (partial (B,S,d), state)."""
    B, S, d = x.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    w_in = env.gather_fsdp(params["w_in"], 0, dtype=cdt)
    w_gate = env.gather_fsdp(params["w_gate"], 0, dtype=cdt)
    w_out = env.gather_fsdp(params["w_out"], 1, dtype=cdt)

    u = x @ w_in                                        # (B,S,dr_loc)
    conv_state = state["conv"] if state else None
    u, conv_state = causal_conv(params, env, u, conv_state)
    ga, gx = _gates(cfg, env, params, u)
    a_log = _log_a(params, env, jax.nn.sigmoid(ga.astype(jnp.float32)))
    h0 = state["h"] if state else jnp.zeros((B, u.shape[-1]), jnp.float32)
    h, h_last = rglru_scan(a_log, gx.astype(jnp.float32),
                           u.astype(jnp.float32), h0)
    y = h.astype(cdt) * jax.nn.gelu(x @ w_gate)
    partial = y @ w_out
    return partial, {"h": h_last, "conv": conv_state}


def decode_step(cfg, env: AxisEnv, params, x: jax.Array, state: Dict):
    """x (B, d) one token; state {'h': (B,dr_loc), 'conv': (B,3,dr_loc)}."""
    cdt = jnp.dtype(cfg.compute_dtype)
    w_in = env.gather_fsdp(params["w_in"], 0, dtype=cdt)
    w_gate = env.gather_fsdp(params["w_gate"], 0, dtype=cdt)
    w_out = env.gather_fsdp(params["w_out"], 1, dtype=cdt)
    u = (x @ w_in)[:, None]                             # (B,1,dr_loc)
    u, conv_state = causal_conv(params, env, u, state["conv"])
    u = u[:, 0]
    ga, gx = _gates(cfg, env, params, u)
    a_log = _log_a(params, env, jax.nn.sigmoid(ga.astype(jnp.float32)))
    a = jnp.exp(a_log)
    x_in = jax.nn.sigmoid(gx.astype(jnp.float32)) * u.astype(jnp.float32)
    h = a * state["h"] + jnp.sqrt(jnp.maximum(1 - a * a, 1e-12)) * x_in
    y = h.astype(cdt) * jax.nn.gelu(x @ w_gate)
    return y @ w_out, {"h": h, "conv": conv_state}


def init_decode_state(cfg, env: AxisEnv, batch_local: int):
    _, dr_loc, _, _ = dims(cfg, env)
    cdt = jnp.dtype(cfg.compute_dtype)
    return {"h": jnp.zeros((batch_local, dr_loc), jnp.float32),
            "conv": jnp.zeros((batch_local, CONV_WIDTH - 1, dr_loc), cdt)}
