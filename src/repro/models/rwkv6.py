"""RWKV6 ("Finch") time-mix + channel-mix blocks [arXiv:2404.05892].

Attention-free linear-recurrence block with *data-dependent decay* — the
distinguishing RWKV6 feature: the per-channel decay w_t is produced from the
token itself through a low-rank (LoRA) projection.

Sharding: heads are column-sharded over tp (padded to a multiple of tp, like
attention); the WKV state (hd x hd per head) is head-local, so the recurrence
needs no collectives — only the output projection is row-parallel.  The
sequential scan here is the reference; `kernels/wkv6.py` holds the chunked
Pallas TPU kernel.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding import AxisEnv, fsdp_spec, pad_to_multiple

LORA_RANK = 32


def dims(cfg, env: AxisEnv):
    hd = cfg.rwkv_head_dim
    nh = cfg.d_model // hd
    nh_pad = pad_to_multiple(nh, env.tp)
    return nh, nh_pad, nh_pad // env.tp, hd


def init_time_mix(key, cfg, env: AxisEnv):
    d = cfg.d_model
    nh, nh_pad, nh_loc, hd = dims(cfg, env)
    dp = nh_pad * hd                      # padded projection width
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 10)
    out_scale = 0.02 / max(cfg.n_layers, 1) ** 0.5
    params = {
        # token-shift interpolation coefficients (static part of ddlerp)
        "mu": 0.5 * jnp.ones((5, d), dt),            # r,k,v,w,g
        "wr": L.dense_init(ks[0], (d, dp), dt),
        "wk": L.dense_init(ks[1], (d, dp), dt),
        "wv": L.dense_init(ks[2], (d, dp), dt),
        "wg": L.dense_init(ks[3], (d, dp), dt),
        "wo": L.dense_init(ks[4], (dp, d), dt, out_scale),
        # data-dependent decay LoRA: w_t = exp(-exp(w0 + tanh(x A) B))
        "w_lora_a": L.dense_init(ks[5], (d, LORA_RANK), dt),
        "w_lora_b": L.dense_init(ks[6], (LORA_RANK, dp), dt),
        "w0": -6.0 * jnp.ones((dp,), dt),
        "u": L.dense_init(ks[7], (dp,), dt, 0.5),    # bonus ("faaaa")
    }
    specs = {
        "mu": fsdp_spec(env, 2, 1),
        "wr": fsdp_spec(env, 2, 0, 1), "wk": fsdp_spec(env, 2, 0, 1),
        "wv": fsdp_spec(env, 2, 0, 1), "wg": fsdp_spec(env, 2, 0, 1),
        "wo": fsdp_spec(env, 2, 1, 0),
        "w_lora_a": fsdp_spec(env, 2, 0),
        "w_lora_b": fsdp_spec(env, 2, 0, 1),
        "w0": fsdp_spec(env, 1, None, 0),
        "u": fsdp_spec(env, 1, None, 0),
    }
    return params, specs


def wkv6_scan(r, k, v, w, u, state):
    """Reference WKV6 recurrence (the Pallas kernel oracle).

    r,k,v,w: (B, T, H, hd) — w in (0,1) per key-channel decay.
    u: (H, hd) bonus.  state: (B, H, hd, hd) carried KV matrix.
    Returns (y (B,T,H,hd), state').
      y_t = (S_{t-1} + diag(u*k_t) . v_t^T)^T r_t
      S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    def step(S, inp):
        rt, kt, vt, wt = inp              # (B,H,hd) each
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,hd,hd)
        y = jnp.einsum("bhkv,bhk->bhv", S + u[..., None] * kv, rt)
        S = wt[..., :, None] * S + kv
        return S, y

    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, inputs)
    return jnp.moveaxis(ys, 0, 1), state


def _projections(cfg, env, params, x, x_prev):
    """Shared by train and decode: token-shift mix + r,k,v,w,g projections.

    x (..., d); x_prev same shape (previous token's activations).
    """
    _, _, nh_loc, hd = dims(cfg, env)
    cdt = jnp.dtype(cfg.compute_dtype)
    mu = env.gather_fsdp(params["mu"], 1, dtype=cdt)
    dx = x_prev - x
    xr, xk, xv, xw, xg = (x + dx * mu[i] for i in range(5))

    def proj(name, inp):
        w = env.gather_fsdp(params[name], 0, dtype=cdt)
        out = inp @ w
        return out.reshape(out.shape[:-1] + (nh_loc, hd))

    r = proj("wr", xr)
    k = proj("wk", xk)
    v = proj("wv", xv)
    g = proj("wg", xg)
    # data-dependent decay (LoRA), fp32 for the exp-exp
    la = env.gather_fsdp(params["w_lora_a"], 0).astype(jnp.float32)
    lb = env.gather_fsdp(params["w_lora_b"], 0).astype(jnp.float32)
    w0 = params["w0"].astype(jnp.float32)   # tp-sharded, local
    dec = w0 + jnp.tanh(xw.astype(jnp.float32) @ la) @ lb
    w = jnp.exp(-jnp.exp(dec)).reshape(dec.shape[:-1] + (nh_loc, hd))
    u = params["u"].astype(jnp.float32).reshape(nh_loc, hd)  # tp-local
    return r, k, v, w, g, u


def wkv6_chunked(r, k, v, w, u, state, chunk: int):
    """Chunked-parallel WKV6: the TPU-shaped formulation of the recurrence
    (the jnp twin of kernels/wkv6.py's blocking strategy).

    Within a chunk of length L the per-channel decay products are
    prefix-cumulated in log space; the sequential dependency collapses to
    one (L x hd)@(hd x hd) state contraction + one masked (L x L) intra-
    chunk matmul per head — the elementwise T-step scan becomes T/L matmul
    steps, cutting the per-step HBM round-trips of the carried state by
    the chunk length (EXPERIMENTS.md §Perf, rwkv6 train_4k).

      Dc[t]   = prod_{s<=t} w_s              (exclusive of nothing)
      inter   = (r_t ⊙ Dc[t-1]) @ S_prev
      P[t,s]  = sum_k r_tk (Dc[t-1]/Dc[s])_k k_sk      (s < t, strictly)
      bonus   = diag: r_t ⊙ u ⊙ k_t
      y_t     = inter + sum_{s<t} P[t,s] v_s + (r_t·(u k_t)) v_t
      S_next  = Dc[L-1] ⊙ S_prev + sum_s (Dc[L-1]/Dc[s] ⊙ k_s) v_s^T
    """
    B, T, H, hd = r.shape
    L = chunk
    n = T // L
    rr = r.reshape(B, n, L, H, hd)
    kk = k.reshape(B, n, L, H, hd)
    vv = v.reshape(B, n, L, H, hd)
    lw = jnp.log(jnp.maximum(w, 1e-30)).reshape(B, n, L, H, hd)

    def chunk_step(S, inp):
        rc, kc, vc, lwc = inp                    # (B, L, H, hd)
        cum = jnp.cumsum(lwc, axis=1)            # log Dc[t]
        dc_prev = jnp.exp(cum - lwc)             # Dc[t-1] = Dc[t]/w_t
        dc_tot = jnp.exp(cum[:, -1])             # (B, H, hd)
        r_d = rc * dc_prev                       # exp(<=0): bounded
        inter = jnp.einsum("blhk,bhkv->blhv", r_d, S)
        # midpoint-shifted pair for the intra-chunk matmul: exp(cum-shift)
        # stays within exp(+-range/2) instead of exp(range) (f32 safety)
        shift = cum[:, L // 2][:, None]
        r_s = rc * jnp.exp(cum - lwc - shift)
        k_s = kc * jnp.exp(shift - cum)
        P = jnp.einsum("blhk,bmhk->bhlm", r_s, k_s)
        mask = jnp.tril(jnp.ones((L, L)), -1)    # strictly lower
        intra = jnp.einsum("bhlm,bmhv->blhv", P * mask, vc)
        bonus = jnp.einsum("blhk,blhk->blh", rc, u[None, None] * kc)
        y = inter + intra + bonus[..., None] * vc
        k_tail = kc * jnp.exp(cum[:, -1:] - cum)  # Dc[L-1]/Dc[s] ⊙ k_s
        S = dc_tot[..., None] * S + jnp.einsum("blhk,blhv->bhkv", k_tail, vc)
        return S, y

    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (rr, kk, vv, lw))
    state, ys = jax.lax.scan(chunk_step, state, inputs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, H, hd)
    return y, state


def time_mix(cfg, env: AxisEnv, params, x: jax.Array,
             state: Optional[Dict] = None, chunk: int = 0):
    """Train/prefill forward.  x (B, S, d) full per dp-shard.
    Returns (partial (B,S,d) to sp_scatter, final_state).
    chunk > 0 selects the chunked-parallel WKV form."""
    B, S, d = x.shape
    _, _, nh_loc, hd = dims(cfg, env)
    cdt = jnp.dtype(cfg.compute_dtype)
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, w, g, u = _projections(cfg, env, params, x, x_prev)
    S0 = jnp.zeros((B, nh_loc, hd, hd), jnp.float32)
    if chunk and S % chunk == 0 and S > chunk:
        y, S1 = wkv6_chunked(r.astype(jnp.float32), k.astype(jnp.float32),
                             v.astype(jnp.float32), w, u, S0, chunk)
    else:
        y, S1 = wkv6_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), w, u, S0)
    y = (y.astype(cdt) * jax.nn.silu(g)).reshape(B, S, nh_loc * hd)
    wo = env.gather_fsdp(params["wo"], 1, dtype=cdt)
    out_state = {"wkv": S1, "last_x": x[:, -1]}
    return y @ wo, out_state


def time_mix_decode(cfg, env: AxisEnv, params, x: jax.Array, state: Dict):
    """x (B, d) one token.  state: {'wkv': (B,H,hd,hd), 'last_x': (B,d)}."""
    _, _, nh_loc, hd = dims(cfg, env)
    cdt = jnp.dtype(cfg.compute_dtype)
    r, k, v, w, g, u = _projections(cfg, env, params, x, state["last_x"])
    S = state["wkv"]
    kt, vt, rt = (t.astype(jnp.float32) for t in (k, v, r))
    kv = kt[..., :, None] * vt[..., None, :]
    y = jnp.einsum("bhkv,bhk->bhv", S + u[..., None] * kv, rt)
    S = w[..., :, None] * S + kv
    y = (y.astype(cdt) * jax.nn.silu(g)).reshape(x.shape[0], nh_loc * hd)
    wo = env.gather_fsdp(params["wo"], 1, dtype=cdt)
    return y @ wo, {"wkv": S, "last_x": x}


def init_decode_state(cfg, env: AxisEnv, batch_local: int):
    _, _, nh_loc, hd = dims(cfg, env)
    return {"wkv": jnp.zeros((batch_local, nh_loc, hd, hd), jnp.float32),
            "last_x": jnp.zeros((batch_local, cfg.d_model),
                                jnp.dtype(cfg.compute_dtype))}


# ---------------------------------------------------------------------------
# channel mix
# ---------------------------------------------------------------------------


def init_channel_mix(key, cfg, env: AxisEnv):
    d, ff = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    out_scale = 0.02 / max(cfg.n_layers, 1) ** 0.5
    params = {
        "mu": 0.5 * jnp.ones((2, d), dt),       # k, r mixes
        "wk": L.dense_init(k1, (d, ff), dt),
        "wv": L.dense_init(k2, (ff, d), dt, out_scale),
        "wr": L.dense_init(k3, (d, d), dt),
    }
    specs = {"mu": fsdp_spec(env, 2, 1),
             "wk": fsdp_spec(env, 2, 0, 1),
             "wv": fsdp_spec(env, 2, 1, 0),
             "wr": fsdp_spec(env, 2, 0, None)}
    return params, specs


def channel_mix(cfg, env: AxisEnv, params, x: jax.Array,
                x_prev: jax.Array):
    """out = sigmoid(Wr xr) * (Wv relu(Wk xk)^2).

    x, x_prev: (T, d) flat tokens (full per dp-shard).  The receptance gate
    is applied by the caller *after* the tp combine (elementwise gating
    commutes with the partial sum over ranks), so the gate is computed only
    for this rank's SP token slice — no duplicated (d x d) matmul.
    Returns (partial_kv (T, d), gate (T_sp, d)).
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    T = x.shape[0]
    mu = env.gather_fsdp(params["mu"], 1, dtype=cdt)
    dx = x_prev - x
    xk = x + dx * mu[0]
    xr = x + dx * mu[1]
    wk = env.gather_fsdp(params["wk"], 0, dtype=cdt)
    wv = env.gather_fsdp(params["wv"], 1, dtype=cdt)
    wr = env.gather_fsdp(params["wr"], 0, dtype=cdt)
    h = jax.nn.relu(xk @ wk)
    partial = (h * h) @ wv
    if env.seq_parallel and env.tp > 1:
        t_sp = T // env.tp
        xr = jax.lax.dynamic_slice_in_dim(xr, env.tp_index() * t_sp, t_sp, 0)
    gate = jax.nn.sigmoid(xr @ wr)
    return partial, gate
