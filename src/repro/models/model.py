"""Composable model assembly: config -> init / train / prefill / decode.

All forward functions are written to run *inside* shard_map (manual
collectives via AxisEnv).  `repro.launch.dryrun` and the trainers wrap them
with jit(shard_map(...)) using the spec trees returned by `param_specs`.

Layer stacking: architectures with a uniform block pattern scan over stacked
layer params (keeps the HLO small for 80-layer models); mixed patterns
(recurrentgemma 2:1, whisper enc-dec) use a python loop with per-layer remat.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import moe as moe_lib
from repro.models import embedding as emb
from repro.models import layers as L
from repro.models import rglru as rglru_lib
from repro.models import rwkv6 as rwkv_lib
from repro.sharding import AxisEnv, batch_spec, fsdp_spec


@dataclasses.dataclass(frozen=True)
class RunFlags:
    """Static knobs for perf experiments (EXPERIMENTS.md §Perf)."""
    attn_schedule: str = "causal"      # "full" | "causal" | "window"
    remat: bool = True
    loss_chunk: int = 2048
    attn_block: int = 1024
    # "fused" | "ragged" | "batched" | "ep" | "auto".  "auto" defers to
    # the per-arch MoEConfig.dispatch knob, then the runtime heuristic
    # (interpret builds: fused at tp=1, expert-parallel all-to-all "ep"
    # at tp>1; real TPUs: ragged/batched) — see core/moe.py::moe_ffn.
    # Threaded through train, prefill AND decode (block_decode), so
    # serving batches exercise the same dispatch path as training.
    moe_dispatch: str = "auto"
    # "fused" | "gathered" | "auto".  Paged-attention backend for the
    # serving steps: "fused" walks the page table inside the Pallas
    # kernel (kernels/paged_attn.py — no gathered KV view in HBM),
    # "gathered" materializes the view via ops.paged_gather (parity
    # oracle).  "auto" mirrors moe_dispatch: fused on interpret builds,
    # gathered on real TPUs until the tile sweep (ROADMAP item 3).
    paged_attn: str = "auto"
    rwkv_chunk: int = 0                # >0: chunked-parallel WKV6


DEFAULT_FLAGS = RunFlags()


def _ffn_kind(cfg: ModelConfig, layer: int) -> str:
    if cfg.moe is not None and layer >= cfg.moe.first_dense_layers:
        return "moe"
    return "mlp"


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig, env: AxisEnv, kind: str,
               ffn: str, cross: bool = False):
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    params["norm1"], specs["norm1"] = L.init_norm(cfg, env)
    if kind in ("attn", "swa"):
        params["attn"], specs["attn"] = L.init_attention(ks[0], cfg, env)
    elif kind == "rwkv":
        params["tmix"], specs["tmix"] = rwkv_lib.init_time_mix(ks[0], cfg, env)
    elif kind == "rglru":
        params["rec"], specs["rec"] = rglru_lib.init_rglru(ks[0], cfg, env)
    else:
        raise ValueError(kind)
    if cross:
        params["norm_x"], specs["norm_x"] = L.init_norm(cfg, env)
        params["xattn"], specs["xattn"] = L.init_attention(
            ks[1], cfg, env, cross=True)
    params["norm2"], specs["norm2"] = L.init_norm(cfg, env)
    if kind == "rwkv":
        params["cmix"], specs["cmix"] = rwkv_lib.init_channel_mix(
            ks[2], cfg, env)
    elif ffn == "moe":
        params["moe"], specs["moe"] = moe_lib.init_moe(ks[2], cfg, env)
    else:
        params["mlp"], specs["mlp"] = L.init_mlp(ks[2], cfg, env)
    return params, specs


# ---------------------------------------------------------------------------
# per-layer forward (train / prefill)
# ---------------------------------------------------------------------------


def block_forward(cfg: ModelConfig, env: AxisEnv, params, x_sp, *,
                  B: int, S: int, kind: str, ffn: str,
                  step=None, rng=None, train: bool = True,
                  flags: RunFlags = DEFAULT_FLAGS,
                  causal: bool = True,
                  enc_out: Optional[jax.Array] = None,
                  want_cache: bool = False):
    """x_sp (T_sp, d) -> (x_sp, aux, cache_or_None)."""
    d = cfg.d_model
    cache = {}
    # ---- mixer sublayer ---------------------------------------------------
    h_sp = L.apply_norm(cfg, env, params["norm1"], x_sp)
    h = env.sp_gather(h_sp)                       # (T, d)
    hB = h.reshape(B, S, d)
    if kind in ("attn", "swa"):
        window = cfg.attn_window if kind == "swa" else None
        sched = flags.attn_schedule
        if kind == "swa" and sched != "full":
            sched = "window"
        partial, kv = L.apply_attention(
            cfg, env, params["attn"], hB, causal=causal, window=window,
            schedule=sched, block_target=flags.attn_block,
            return_cache=want_cache)
        if want_cache:
            cache["self"] = kv
        partial = partial.reshape(B * S, d)
        state_out = None
    elif kind == "rwkv":
        partial, state_out = rwkv_lib.time_mix(cfg, env, params["tmix"], hB,
                                               chunk=flags.rwkv_chunk)
        partial = partial.reshape(B * S, d)
        if want_cache:
            cache["rwkv"] = state_out
    elif kind == "rglru":
        partial, state_out = rglru_lib.recurrent_block(
            cfg, env, params["rec"], hB)
        partial = partial.reshape(B * S, d)
        if want_cache:
            cache["rglru"] = state_out
    else:
        raise ValueError(kind)
    x_sp = x_sp + env.sp_scatter(partial)

    # ---- cross attention (whisper decoder) --------------------------------
    if "xattn" in params:
        h_sp = L.apply_norm(cfg, env, params["norm_x"], x_sp)
        h = env.sp_gather(h_sp).reshape(B, S, d)
        partial, kv = L.apply_attention(
            cfg, env, params["xattn"], h, causal=False, kv_source=enc_out,
            use_rope=False, schedule="full", return_cache=want_cache)
        if want_cache:
            cache["cross"] = kv
        x_sp = x_sp + env.sp_scatter(partial.reshape(B * S, d))

    # ---- FFN sublayer ------------------------------------------------------
    aux = jnp.zeros((), jnp.float32)
    metrics: Dict[str, jax.Array] = {}
    h_sp = L.apply_norm(cfg, env, params["norm2"], x_sp)
    h = env.sp_gather(h_sp)
    if kind == "rwkv":
        hB = h.reshape(B, S, d)
        h_prev = jnp.pad(hB, ((0, 0), (1, 0), (0, 0)))[:, :-1].reshape(-1, d)
        partial, gate = rwkv_lib.channel_mix(cfg, env, params["cmix"],
                                             h, h_prev)
        x_sp = x_sp + gate * env.sp_scatter(partial)
        if want_cache:
            cache["cmix_prev"] = hB[:, -1]
    elif ffn == "moe":
        partial, aux, metrics = moe_lib.moe_ffn(
            cfg, env, params["moe"], h, step=step, rng=rng, train=train,
            dispatch=flags.moe_dispatch)
        x_sp = x_sp + env.sp_scatter(partial)
    else:
        partial = L.apply_mlp(cfg, env, params["mlp"], h)
        x_sp = x_sp + env.sp_scatter(partial)
    return x_sp, aux, metrics, (cache if want_cache else None)


# ---------------------------------------------------------------------------
# per-layer decode
# ---------------------------------------------------------------------------


def block_decode(cfg, env: AxisEnv, params, x, cache, pos, *, kind: str,
                 ffn: str, flags: RunFlags = DEFAULT_FLAGS):
    """x (B, d) replicated over tp; cache per-kind dict."""
    h = L.apply_norm(cfg, env, params["norm1"], x)
    if kind in ("attn", "swa"):
        window = cfg.attn_window if kind == "swa" else None
        partial, cache["self"] = L.decode_attention(
            cfg, env, params["attn"], h, cache["self"], pos, window=window)
    elif kind == "rwkv":
        partial, cache["rwkv"] = rwkv_lib.time_mix_decode(
            cfg, env, params["tmix"], h, cache["rwkv"])
    elif kind == "rglru":
        partial, cache["rglru"] = rglru_lib.decode_step(
            cfg, env, params["rec"], h, cache["rglru"])
    x = x + env.psum_tp(partial)

    if "xattn" in params:
        h = L.apply_norm(cfg, env, params["norm_x"], x)
        partial, _ = L.decode_attention(cfg, env, params["xattn"], h,
                                        cache["cross"], pos, cross=True)
        x = x + env.psum_tp(partial)

    h = L.apply_norm(cfg, env, params["norm2"], x)
    if kind == "rwkv":
        partial, gate = rwkv_lib.channel_mix(
            cfg, env, params["cmix"], h, cache["cmix_prev"])
        cache["cmix_prev"] = h
        x = x + gate * env.psum_tp(partial)
    elif ffn == "moe":
        partial, _, _ = moe_lib.moe_ffn(cfg, env, params["moe"], h,
                                        train=False,
                                        dispatch=flags.moe_dispatch)
        x = x + env.psum_tp(partial)
    else:
        x = x + env.psum_tp(L.apply_mlp(cfg, env, params["mlp"], h))
    return x, cache


def init_block_cache(cfg, env: AxisEnv, kind: str, B_loc: int, seq_len: int,
                     cross_len: int = 0):
    cache: Dict[str, Any] = {}
    if kind in ("attn", "swa"):
        window = cfg.attn_window if kind == "swa" else None
        cache["self"] = L.init_decode_cache(cfg, env, B_loc, seq_len, window)
    elif kind == "rwkv":
        cache["rwkv"] = rwkv_lib.init_decode_state(cfg, env, B_loc)
        cache["cmix_prev"] = jnp.zeros((B_loc, cfg.d_model),
                                       jnp.dtype(cfg.compute_dtype))
    elif kind == "rglru":
        cache["rglru"] = rglru_lib.init_decode_state(cfg, env, B_loc)
    if cfg.is_encoder_decoder:
        cache["cross"] = L.init_decode_cache(cfg, env, B_loc, cross_len)
    return cache


# ---------------------------------------------------------------------------
# whole model
# ---------------------------------------------------------------------------


def init_model(key, cfg: ModelConfig, env: AxisEnv, max_seq: int):
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    params["embed"], specs["embed"] = emb.init_embedding(ks[0], cfg, env)
    params["final_norm"], specs["final_norm"] = L.init_norm(cfg, env)

    if not cfg.use_rope and not cfg.is_encoder_decoder and \
            cfg.block_pattern != ("rwkv",):
        pass  # all assigned no-rope decoders are rwkv (no abs pos needed)

    def stacked(init_fn, n, key):
        keys = jax.random.split(key, n)
        p0, s0 = init_fn(keys[0])
        ps = jax.vmap(lambda k: init_fn(k)[0])(keys)
        ss = jax.tree.map(lambda s: P(*((None,) + tuple(s))), s0,
                          is_leaf=lambda x: isinstance(x, P))
        return ps, ss

    if cfg.is_encoder_decoder:
        dt = jnp.dtype(cfg.param_dtype)
        params["pos_enc"] = L.dense_init(ks[1], (cfg.encoder_seq_len,
                                                 cfg.d_model), dt)
        specs["pos_enc"] = P(None, None)
        params["pos_dec"] = L.dense_init(ks[2], (max_seq, cfg.d_model), dt)
        specs["pos_dec"] = P(None, None)
        params["enc_norm"], specs["enc_norm"] = L.init_norm(cfg, env)
        enc_blocks = []
        enc_specs = []
        for i in range(cfg.encoder_layers):
            p, s = init_block(jax.random.fold_in(ks[3], i), cfg, env,
                              "attn", "mlp")
            enc_blocks.append(p)
            enc_specs.append(s)
        params["enc_blocks"] = enc_blocks
        specs["enc_blocks"] = enc_specs
        dec_blocks, dec_specs = [], []
        for i in range(cfg.n_layers):
            p, s = init_block(jax.random.fold_in(ks[4], i), cfg, env,
                              "attn", "mlp", cross=True)
            dec_blocks.append(p)
            dec_specs.append(s)
        params["blocks"] = dec_blocks
        specs["blocks"] = dec_specs
    elif cfg.uniform_blocks:
        kind = cfg.block_pattern[0]
        ffn = _ffn_kind(cfg, cfg.n_layers - 1)
        params["blocks"], specs["blocks"] = stacked(
            lambda k: init_block(k, cfg, env, kind, ffn), cfg.n_layers, ks[3])
    else:
        blocks, bspecs = [], []
        for i in range(cfg.n_layers):
            p, s = init_block(jax.random.fold_in(ks[3], i), cfg, env,
                              cfg.block_kind(i), _ffn_kind(cfg, i))
            blocks.append(p)
            bspecs.append(s)
        params["blocks"] = blocks
        specs["blocks"] = bspecs
    return params, specs


def param_specs(cfg: ModelConfig, env: AxisEnv, max_seq: int):
    box = {}

    def f(key):
        p, s = init_model(key, cfg, env, max_seq)
        box["s"] = s
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return box["s"], shapes


# ---- forward (train / prefill) ---------------------------------------------


def _run_blocks(cfg, env, params, x_sp, *, B, S, step, rng, train, flags,
                want_cache=False, enc_out=None):
    aux0 = jnp.zeros((), jnp.float32)
    if cfg.uniform_blocks and not cfg.is_encoder_decoder:
        kind = cfg.block_pattern[0]
        ffn = _ffn_kind(cfg, cfg.n_layers - 1)
        keys = (jax.random.split(rng, cfg.n_layers) if rng is not None
                else jnp.zeros((cfg.n_layers, 2), jnp.uint32))

        def body(carry, inp):
            x_sp, aux = carry
            lp, lk = inp
            x_sp, a, metrics, cache = block_forward(
                cfg, env, lp, x_sp, B=B, S=S, kind=kind, ffn=ffn,
                step=step, rng=(lk if rng is not None else None),
                train=train, flags=flags, want_cache=want_cache,
                enc_out=enc_out)
            return (x_sp, aux + a), (cache, metrics)

        body_fn = jax.checkpoint(body) if flags.remat else body
        (x_sp, aux), (caches, metrics) = jax.lax.scan(
            body_fn, (x_sp, aux0), (params["blocks"], keys))
        metrics = jax.tree.map(lambda v: jnp.mean(v, axis=0), metrics)
        return x_sp, aux, metrics, caches
    # loop path (mixed patterns / enc-dec)
    aux = aux0
    caches = []
    metrics_all = []
    for i, lp in enumerate(params["blocks"]):
        kind = cfg.block_kind(i)
        ffn = _ffn_kind(cfg, i)
        lk = jax.random.fold_in(rng, i) if rng is not None else None
        base_fwd = functools.partial(
            block_forward, cfg, env, B=B, S=S, kind=kind, ffn=ffn,
            step=step, train=train, flags=flags, want_cache=want_cache,
            enc_out=enc_out)
        if flags.remat:
            x_sp, a, mets, cache = jax.checkpoint(
                lambda p, x, k: base_fwd(p, x, rng=k))(lp, x_sp, lk)
        else:
            x_sp, a, mets, cache = base_fwd(lp, x_sp, rng=lk)
        aux = aux + a
        if mets:
            metrics_all.append(mets)
        caches.append(cache)
    metrics = (jax.tree.map(lambda *v: jnp.mean(jnp.stack(v)), *metrics_all)
               if metrics_all else {})
    return x_sp, aux, metrics, caches


def _encode(cfg, env, params, frames, flags):
    """Whisper encoder over stub frame embeddings (B, S_enc, d)."""
    B, S_enc, d = frames.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    x = frames.astype(cdt) + params["pos_enc"].astype(cdt)[None]
    x_sp = x.reshape(B * S_enc, d)
    if env.seq_parallel and env.tp > 1:
        t_sp = (B * S_enc) // env.tp
        x_sp = jax.lax.dynamic_slice_in_dim(
            x_sp, env.tp_index() * t_sp, t_sp, 0)
    for i, lp in enumerate(params["enc_blocks"]):
        x_sp, _, _, _ = block_forward(
            cfg, env, lp, x_sp, B=B, S=S_enc, kind="attn", ffn="mlp",
            train=False, flags=flags, causal=False)
    x_sp = L.apply_norm(cfg, env, params["enc_norm"], x_sp)
    return env.sp_gather(x_sp).reshape(B, S_enc, d)


def forward(cfg: ModelConfig, env: AxisEnv, params, batch, *,
            step=None, rng=None, train=True, flags=DEFAULT_FLAGS,
            want_cache=False):
    """batch['tokens'] (B_loc, S) -> (x_final (T, d) gathered, aux, caches).

    Whisper additionally reads batch['enc_frames'] (B_loc, S_enc, d).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(cfg, env, params, batch["enc_frames"], flags)
    x_sp = emb.embed_tokens(cfg, env, params["embed"], tokens.reshape(-1))
    if cfg.is_encoder_decoder:
        pos = params["pos_dec"].astype(x_sp.dtype)[:S]
        pos_flat = jnp.tile(pos, (B, 1))
        if env.seq_parallel and env.tp > 1:
            t_sp = (B * S) // env.tp
            pos_flat = jax.lax.dynamic_slice_in_dim(
                pos_flat, env.tp_index() * t_sp, t_sp, 0)
        x_sp = x_sp + pos_flat
    x_sp, aux, metrics, caches = _run_blocks(
        cfg, env, params, x_sp, B=B, S=S, step=step, rng=rng, train=train,
        flags=flags, want_cache=want_cache, enc_out=enc_out)
    x_sp = L.apply_norm(cfg, env, params["final_norm"], x_sp)
    x = env.sp_gather(x_sp)                    # (T, d)
    return x, aux, metrics, caches


def loss_fn(cfg: ModelConfig, env: AxisEnv, params, batch, *,
            step=None, rng=None, flags=DEFAULT_FLAGS):
    """Training loss: chunked sharded cross entropy + MoE aux losses."""
    x, aux, block_metrics, _ = forward(cfg, env, params, batch, step=step,
                                       rng=rng, train=True, flags=flags)
    labels = batch["labels"].reshape(-1)
    T = x.shape[0]
    chunk = L.choose_block(T, flags.loss_chunk)
    n = T // chunk

    def chunk_loss(carry, idx):
        tot = carry
        xc = jax.lax.dynamic_slice_in_dim(x, idx * chunk, chunk, 0)
        lc = jax.lax.dynamic_slice_in_dim(labels, idx * chunk, chunk, 0)
        logits = emb.lm_logits(cfg, env, params["embed"], xc)
        # accumulate the *sum* over valid tokens (re-normalized below)
        v_loc = logits.shape[-1]
        r = env.tp_index()
        gid = r * v_loc + jnp.arange(v_loc)
        logits = jnp.where(gid[None, :] < cfg.vocab_size, logits, -1e30)
        m = env.pmax_tp(jax.lax.stop_gradient(jnp.max(logits, axis=-1)))
        se = env.psum_tp(jnp.sum(jnp.exp(logits - m[:, None]), axis=-1))
        lse = m + jnp.log(se)
        local = lc - r * v_loc
        in_range = (local >= 0) & (local < v_loc)
        picked = jnp.take_along_axis(
            logits, jnp.clip(local, 0, v_loc - 1)[:, None], axis=-1)[:, 0]
        correct = env.psum_tp(jnp.where(in_range, picked, 0.0))
        valid = lc >= 0
        tot = tot + jnp.sum(jnp.where(valid, lse - correct, 0.0))
        return tot, jnp.sum(valid.astype(jnp.float32))

    body = jax.checkpoint(chunk_loss) if flags.remat else chunk_loss
    total, nvalid = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                                 jnp.arange(n))
    n_total = env.psum_dp(jnp.sum(nvalid))
    ce = env.psum_dp(total) / jnp.maximum(n_total, 1.0)
    metrics = {"loss/ce": ce, "loss/aux": aux, **block_metrics}
    return ce + aux, metrics


# ---- decode ------------------------------------------------------------


def init_caches(cfg: ModelConfig, env: AxisEnv, B_loc: int, seq_len: int,
                cross_len: int = 0):
    if cfg.uniform_blocks and not cfg.is_encoder_decoder:
        kind = cfg.block_pattern[0]
        c0 = init_block_cache(cfg, env, kind, B_loc, seq_len, cross_len)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), c0)
    return [init_block_cache(cfg, env, cfg.block_kind(i), B_loc, seq_len,
                             cross_len)
            for i in range(cfg.n_layers)]


# ---- paged decode / chunked prefill (online serving) -----------------------
#
# The online continuous-batching engine (serving/online.py) replaces the
# dense (B, seq_len) decode caches with slot-agnostic page pools indexed by
# per-slot page tables, so request admission/completion/preemption are pure
# data updates on fixed-shape arrays — the jitted serve step compiles once.
# Supported for decoder-only all-attention architectures (the Ling family);
# recurrent-state blocks (rwkv/rglru), sliding windows, and enc-dec carry
# per-slot state the page abstraction does not cover yet (ROADMAP).


def check_paged_support(cfg: ModelConfig):
    kinds = {cfg.block_kind(i) for i in range(cfg.n_layers)}
    if kinds != {"attn"} or cfg.is_encoder_decoder:
        raise ValueError(
            f"paged online serving supports decoder-only all-'attn' "
            f"architectures; {cfg.arch_id} has blocks {sorted(kinds)}"
            f"{' (encoder-decoder)' if cfg.is_encoder_decoder else ''}")


def init_paged_caches(cfg: ModelConfig, env: AxisEnv, n_pages: int,
                      page_size: int):
    """GLOBAL per-layer paged KV pools (page 0 is the engine's scratch
    page).  Uniform archs carry a leading layer dim so the decode scan
    matches `init_caches`; see `api.paged_cache_specs` for sharding."""
    check_paged_support(cfg)
    c0 = {"self": L.init_paged_kv_pool(cfg, n_pages, page_size)}
    if cfg.uniform_blocks:
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), c0)
    return [jax.tree.map(jnp.array, c0) for _ in range(cfg.n_layers)]


def _pool_ps_loc(cfg, pools) -> int:
    """Per-rank page row count of the serve-step pools (uniform pools
    carry a leading layer dim)."""
    pool0 = pools["self"] if cfg.uniform_blocks else pools[0]["self"]
    k = pool0["k"]
    return k.shape[2] if cfg.uniform_blocks else k.shape[1]


def block_decode_paged(cfg, env: AxisEnv, params, x, pool, pos, table,
                       active, *, page_size: int, ffn: str,
                       flags: RunFlags = DEFAULT_FLAGS, valid=None):
    """Paged analogue of `block_decode` ('attn' blocks only): x (B, d)
    replicated over tp, pool the layer's page pool.  `valid` is the
    once-per-tick layer-invariant page mask (layers.paged_valid_mask)."""
    h = L.apply_norm(cfg, env, params["norm1"], x)
    partial, pool["self"] = L.paged_decode_attention(
        cfg, env, params["attn"], h, pool["self"], pos, table, active,
        page_size=page_size, paged_attn=flags.paged_attn, valid=valid)
    x = x + env.psum_tp(partial)

    h = L.apply_norm(cfg, env, params["norm2"], x)
    if ffn == "moe":
        partial, _, _ = moe_lib.moe_ffn(cfg, env, params["moe"], h,
                                        train=False,
                                        dispatch=flags.moe_dispatch)
        x = x + env.psum_tp(partial)
    else:
        x = x + env.psum_tp(L.apply_mlp(cfg, env, params["mlp"], h))
    return x, pool


def _paged_decode_logits(cfg: ModelConfig, denv: AxisEnv, params, pools,
                         token: jax.Array, pos: jax.Array, table: jax.Array,
                         active: jax.Array, *, page_size: int,
                         flags: RunFlags = DEFAULT_FLAGS):
    """Shared paged-decode body: one token per slot -> (logits, pools)."""
    x = emb.embed_tokens(cfg, denv, params["embed"], token)   # (B, d)
    ffn = _ffn_kind(cfg, cfg.n_layers - 1)
    # page-validity mask is identical across layers: compute once per
    # tick here instead of per layer inside the attention entry points.
    valid = L.paged_valid_mask(table, pos[:, None], page_size=page_size,
                               ps_loc=_pool_ps_loc(cfg, pools), env=denv)

    if cfg.uniform_blocks:
        def body(x, inp):
            lp, pool = inp
            x, pool = block_decode_paged(cfg, denv, lp, x, pool, pos,
                                         table, active,
                                         page_size=page_size, ffn=ffn,
                                         flags=flags, valid=valid)
            return x, pool

        x, pools = jax.lax.scan(body, x, (params["blocks"], pools))
    else:
        new_pools = []
        for i, lp in enumerate(params["blocks"]):
            x, p = block_decode_paged(cfg, denv, lp, x, pools[i], pos,
                                      table, active, page_size=page_size,
                                      ffn=_ffn_kind(cfg, i), flags=flags,
                                      valid=valid)
            new_pools.append(p)
        pools = new_pools
    x = L.apply_norm(cfg, denv, params["final_norm"], x)
    return emb.lm_logits(cfg, denv, params["embed"], x), pools


def paged_decode_step(cfg: ModelConfig, env: AxisEnv, params, pools,
                      token: jax.Array, pos: jax.Array, table: jax.Array,
                      active: jax.Array, *, page_size: int,
                      flags: RunFlags = DEFAULT_FLAGS, sample=None):
    """One decode tick over the slot batch.

    token (B,) input token per slot; pos (B,) position being written;
    table (B, n_lp) page table; active (B,) bool.  Inactive slots compute
    harmlessly (their writes land in the scratch page, their outputs are
    ignored by the host).  `sample=None` keeps the greedy path;
    `sample=(seeds, temperature, top_p, top_k)` — all (B,) arrays —
    draws from the transformed distribution under the (seed, pos,
    stream) key schedule, with temperature<=0 rows bitwise-equal to the
    greedy path.  Returns (next (B,), pools)."""
    denv = dataclasses.replace(env, seq_parallel=False)
    logits, pools = _paged_decode_logits(
        cfg, denv, params, pools, token, pos, table, active,
        page_size=page_size, flags=flags)
    if sample is None:
        return emb.sharded_argmax(denv, logits).astype(jnp.int32), pools
    seeds, temp, top_p, top_k = sample
    nxt, _ = emb.sharded_sample(cfg, denv, logits, seeds=seeds, pos=pos,
                                temperature=temp, top_p=top_p, top_k=top_k,
                                stream=emb.STREAM_SAMPLE)
    return nxt, pools


# ---- speculative decoding (draft proposals + one verify pass) --------------
#
# The drafter (serving/draft.py: truncated-layer self-draft or any small
# paged-compatible model sharing the target vocab) proposes k tokens per
# slot with `paged_draft_propose` — a scan of k+1 sampled decode steps
# over its OWN page pools (same page ids as the target's, so admission /
# preemption / prefix sharing transfer untouched; the +1 step back-fills
# the drafter KV at the last proposed position so a fully-accepted round
# leaves no hole).  `paged_verify_step` then scores all k+1 positions in
# one paged-prefill-shaped target pass and runs standard spec-sampling
# accept/reject ON DEVICE: accept draft d while u*q(d) < p(d), then one
# residual draw from (p - q)+ (the bonus draw from p when everything was
# accepted is the q=0 special case of the same formula).  temperature<=0
# rows use exact argmax one-hots for p and q, so greedy acceptance
# degenerates to token equality and the emitted stream is bitwise the
# non-speculative greedy stream.


def paged_draft_propose(cfg: ModelConfig, env: AxisEnv, params, pools,
                        token: jax.Array, pos0: jax.Array, table: jax.Array,
                        active: jax.Array, sample, *, k: int,
                        page_size: int, flags: RunFlags = DEFAULT_FLAGS):
    """Propose k draft tokens per slot with the drafter model.

    token (B,) the pending (last emitted, unwritten) token per slot; pos0
    (B,) its position.  Runs k+1 chained sampled decode steps (stream
    STREAM_DRAFT): steps 0..k-1 yield drafts d_1..d_k, step k only
    writes d_k's KV (its sample is discarded).  Returns
    (drafts (B, k), draft_probs (B, k, Vp), pools)."""
    denv = dataclasses.replace(env, seq_parallel=False)
    seeds, temp, top_p, top_k = sample

    def body(carry, i):
        tok, pools = carry
        pos = pos0 + i
        logits, pools = _paged_decode_logits(
            cfg, denv, params, pools, tok, pos, table, active,
            page_size=page_size, flags=flags)
        nxt, probs = emb.sharded_sample(
            cfg, denv, logits, seeds=seeds, pos=pos, temperature=temp,
            top_p=top_p, top_k=top_k, stream=emb.STREAM_DRAFT)
        return (nxt, pools), (nxt, probs)

    (_, pools), (toks, probs) = jax.lax.scan(
        body, (token, pools), jnp.arange(k + 1))
    drafts = jnp.transpose(toks[:k], (1, 0))               # (B, k)
    draft_probs = jnp.transpose(probs[:k], (1, 0, 2))      # (B, k, Vp)
    return drafts, draft_probs, pools


def block_verify_paged(cfg, env: AxisEnv, params, x, pool, pos, table,
                       active, *, B: int, Q: int, page_size: int, ffn: str,
                       flags: RunFlags = DEFAULT_FLAGS, valid=None):
    """One layer of the k+1-token verify pass: x (B*Q, d)."""
    h = L.apply_norm(cfg, env, params["norm1"], x)
    partial, pool["self"] = L.paged_verify_attention(
        cfg, env, params["attn"], h.reshape(B, Q, -1), pool["self"], pos,
        table, active, page_size=page_size, paged_attn=flags.paged_attn,
        valid=valid)
    x = x + env.psum_tp(partial)

    h = L.apply_norm(cfg, env, params["norm2"], x)
    if ffn == "moe":
        partial, _, _ = moe_lib.moe_ffn(cfg, env, params["moe"], h,
                                        train=False,
                                        dispatch=flags.moe_dispatch)
        x = x + env.psum_tp(partial)
    else:
        x = x + env.psum_tp(L.apply_mlp(cfg, env, params["mlp"], h))
    return x, pool


def paged_verify_step(cfg: ModelConfig, env: AxisEnv, params, pools,
                      tokens: jax.Array, pos0: jax.Array, table: jax.Array,
                      active: jax.Array, draft_probs: jax.Array, sample, *,
                      page_size: int, flags: RunFlags = DEFAULT_FLAGS):
    """Score k+1 candidate positions per slot and accept/reject drafts.

    tokens (B, K+1): column 0 the pending token, columns 1..K the drafts;
    pos0 (B,) the pending token's position; draft_probs (B, K, Vp) the
    drafter distributions each draft was sampled from; sample the
    (seeds, temperature, top_p, top_k) slot arrays.  Returns
    (n_acc (B,) int32 accepted drafts in [0, K],
     out (B, K+1) int32 — out[:, :n_acc] the accepted drafts and
     out[:, n_acc] the residual/bonus token; later columns are garbage —
     and the updated pools).  The target KV for ALL K+1 positions is
    written; the host commits n_acc+1 tokens and rewinds the page tail
    (`PageAllocator.trim`)."""
    denv = dataclasses.replace(env, seq_parallel=False)
    B, K1 = tokens.shape
    K = K1 - 1
    seeds, temp, top_p, top_k = sample
    pos = pos0[:, None] + jnp.arange(K1)[None, :]          # (B, K1)

    x = emb.embed_tokens(cfg, denv, params["embed"], tokens.reshape(-1))
    ffn = _ffn_kind(cfg, cfg.n_layers - 1)
    valid = L.paged_valid_mask(table, pos, page_size=page_size,
                               ps_loc=_pool_ps_loc(cfg, pools), env=denv)
    if cfg.uniform_blocks:
        def body(x, inp):
            lp, pool = inp
            x, pool = block_verify_paged(cfg, denv, lp, x, pool, pos,
                                         table, active, B=B, Q=K1,
                                         page_size=page_size, ffn=ffn,
                                         flags=flags, valid=valid)
            return x, pool

        x, pools = jax.lax.scan(body, x, (params["blocks"], pools))
    else:
        new_pools = []
        for i, lp in enumerate(params["blocks"]):
            x, p = block_verify_paged(cfg, denv, lp, x, pools[i], pos,
                                      table, active, B=B, Q=K1,
                                      page_size=page_size,
                                      ffn=_ffn_kind(cfg, i), flags=flags,
                                      valid=valid)
            new_pools.append(p)
        pools = new_pools
    x = L.apply_norm(cfg, denv, params["final_norm"], x)
    logits = emb.lm_logits(cfg, denv, params["embed"], x)  # (B*K1, v_loc)

    rep = lambda a: jnp.repeat(a, K1, axis=0)
    greedy, probs = emb.sampled_probs(cfg, denv, logits, rep(temp),
                                      rep(top_p), rep(top_k))
    vp = probs.shape[-1]
    greedy = greedy.reshape(B, K1)
    probs = probs.reshape(B, K1, vp)

    # -- accept/reject: u * q(d) < p(d), sequential via cumprod ------------
    d = tokens[:, 1:]                                      # (B, K)
    p_d = jnp.take_along_axis(probs[:, :K], d[..., None], axis=2)[..., 0]
    q_d = jnp.take_along_axis(draft_probs, d[..., None], axis=2)[..., 0]
    posd = pos[:, :K]
    ukeys = emb.sample_keys(rep(seeds).reshape(B, K1)[:, :K].reshape(-1),
                            posd.reshape(-1), emb.STREAM_ACCEPT)
    u = jax.vmap(jax.random.uniform)(ukeys).reshape(B, K)
    acc = (u * q_d < p_d) & active[:, None]
    live = jnp.cumprod(acc.astype(jnp.int32), axis=1)
    n_acc = jnp.sum(live, axis=1).astype(jnp.int32)        # (B,)

    # -- residual/bonus draw at position n_acc -----------------------------
    p_sel = jnp.take_along_axis(probs, n_acc[:, None, None],
                                axis=1)[:, 0]              # (B, Vp)
    q_pad = jnp.concatenate([draft_probs,
                             jnp.zeros((B, 1, vp), draft_probs.dtype)], 1)
    q_sel = jnp.take_along_axis(q_pad, n_acc[:, None, None], axis=1)[:, 0]
    res = jnp.maximum(p_sel - q_sel, 0.0)
    rsum = jnp.sum(res, axis=-1, keepdims=True)
    res = jnp.where(rsum > 0, res, p_sel)                  # numerical guard
    rkeys = emb.sample_keys(seeds, pos0 + n_acc, emb.STREAM_RESID)
    cat = jax.vmap(lambda kk, p: jax.random.categorical(kk, jnp.log(p)))(
        rkeys, res).astype(jnp.int32)
    g_sel = jnp.take_along_axis(greedy, n_acc[:, None], axis=1)[:, 0]
    extra = jnp.where(temp <= 0.0, g_sel, cat).astype(jnp.int32)

    j = jnp.arange(K1)[None, :]
    d_pad = jnp.concatenate([d, jnp.zeros((B, 1), d.dtype)], axis=1)
    out = jnp.where(j < n_acc[:, None], d_pad,
                    jnp.where(j == n_acc[:, None], extra[:, None], 0))
    return n_acc, out.astype(jnp.int32), pools


def block_prefill_paged(cfg, env: AxisEnv, params, x, pool, base, n_valid,
                        table_row, *, page_size: int, ffn: str,
                        flags: RunFlags = DEFAULT_FLAGS, valid=None):
    """One layer of chunked prefill for a single request: x (C, d)."""
    h = L.apply_norm(cfg, env, params["norm1"], x)
    partial, pool["self"] = L.paged_prefill_attention(
        cfg, env, params["attn"], h, pool["self"], base, n_valid,
        table_row, page_size=page_size, paged_attn=flags.paged_attn,
        valid=valid)
    x = x + env.psum_tp(partial)

    h = L.apply_norm(cfg, env, params["norm2"], x)
    if ffn == "moe":
        partial, _, _ = moe_lib.moe_ffn(cfg, env, params["moe"], h,
                                        train=False,
                                        dispatch=flags.moe_dispatch)
        x = x + env.psum_tp(partial)
    else:
        x = x + env.psum_tp(L.apply_mlp(cfg, env, params["mlp"], h))
    return x, pool


def paged_prefill_chunk(cfg: ModelConfig, env: AxisEnv, params, pools,
                        tokens: jax.Array, base: jax.Array,
                        n_valid: jax.Array, table_row: jax.Array, *,
                        page_size: int, flags: RunFlags = DEFAULT_FLAGS,
                        sample=None):
    """Prefill one chunk of one request's prompt into its pages.

    tokens (C,) the chunk (tail past n_valid is padding); base (scalar)
    tokens already written; table_row (n_lp,) the request's page table.
    Returns (next (scalar int32) — the token after the last valid chunk
    position, meaningful only on the request's final chunk — and the
    updated pools).  `sample=(seed, temperature, top_p, top_k)` scalars
    switches the returned token from greedy to the shared-key-schedule
    draw at position base + n_valid - 1 (bitwise greedy at temp<=0)."""
    denv = dataclasses.replace(env, seq_parallel=False)
    x = emb.embed_tokens(cfg, denv, params["embed"], tokens)  # (C, d)
    ffn = _ffn_kind(cfg, cfg.n_layers - 1)
    valid = L.paged_valid_mask(
        table_row[None], (base + jnp.arange(tokens.shape[0]))[None],
        page_size=page_size, ps_loc=_pool_ps_loc(cfg, pools), env=denv)

    if cfg.uniform_blocks:
        def body(x, inp):
            lp, pool = inp
            x, pool = block_prefill_paged(cfg, denv, lp, x, pool, base,
                                          n_valid, table_row,
                                          page_size=page_size, ffn=ffn,
                                          flags=flags, valid=valid)
            return x, pool

        x, pools = jax.lax.scan(body, x, (params["blocks"], pools))
    else:
        new_pools = []
        for i, lp in enumerate(params["blocks"]):
            x, p = block_prefill_paged(cfg, denv, lp, x, pools[i], base,
                                       n_valid, table_row,
                                       page_size=page_size,
                                       ffn=_ffn_kind(cfg, i), flags=flags,
                                       valid=valid)
            new_pools.append(p)
        pools = new_pools
    x = L.apply_norm(cfg, denv, params["final_norm"], x)
    last = jax.lax.dynamic_slice_in_dim(
        x, jnp.clip(n_valid - 1, 0, x.shape[0] - 1), 1, axis=0)
    logits = emb.lm_logits(cfg, denv, params["embed"], last)
    if sample is None:
        return emb.sharded_argmax(denv, logits)[0].astype(jnp.int32), pools
    seed, temp, top_p, top_k = sample
    one = lambda v, dt: jnp.reshape(v, (1,)).astype(dt)
    nxt, _ = emb.sharded_sample(
        cfg, denv, logits, seeds=one(seed, jnp.uint32),
        pos=one(base + n_valid - 1, jnp.int32),
        temperature=one(temp, jnp.float32), top_p=one(top_p, jnp.float32),
        top_k=one(top_k, jnp.int32), stream=emb.STREAM_SAMPLE)
    return nxt[0].astype(jnp.int32), pools


def decode_step(cfg: ModelConfig, env: AxisEnv, params, caches,
                token: jax.Array, pos: jax.Array,
                flags: RunFlags = DEFAULT_FLAGS, sample=None):
    """One decode step.  token (B_loc,) -> (next (B_loc,), caches).

    Greedy by default; `sample=(seeds, temperature, top_p, top_k)` —
    (B_loc,) arrays — draws under the SAME (seed, pos, stream) key
    schedule as the online paged path, so offline and online engines
    emit identical streams for matching seeds (bitwise greedy at
    temperature <= 0)."""
    denv = dataclasses.replace(env, seq_parallel=False)
    x = emb.embed_tokens(cfg, denv, params["embed"], token)   # (B, d)

    if cfg.is_encoder_decoder:
        pos_vec = jnp.take(params["pos_dec"], pos, axis=0).astype(x.dtype)
        x = x + pos_vec[None]

    if cfg.uniform_blocks and not cfg.is_encoder_decoder:
        kind = cfg.block_pattern[0]
        ffn = _ffn_kind(cfg, cfg.n_layers - 1)

        def body(x, inp):
            lp, cache = inp
            x, cache = block_decode(cfg, denv, lp, x, cache, pos,
                                    kind=kind, ffn=ffn, flags=flags)
            return x, cache

        x, caches = jax.lax.scan(body, x, (params["blocks"], caches))
    else:
        new_caches = []
        for i, lp in enumerate(params["blocks"]):
            x, c = block_decode(cfg, denv, lp, x, caches[i], pos,
                                kind=cfg.block_kind(i), ffn=_ffn_kind(cfg, i),
                                flags=flags)
            new_caches.append(c)
        caches = new_caches
    x = L.apply_norm(cfg, denv, params["final_norm"], x)
    logits = emb.lm_logits(cfg, denv, params["embed"], x)
    if sample is None:
        return emb.sharded_argmax(denv, logits).astype(jnp.int32), caches
    seeds, temp, top_p, top_k = sample
    B = token.shape[0]
    nxt, _ = emb.sharded_sample(
        cfg, denv, logits, seeds=seeds,
        pos=jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,)),
        temperature=temp, top_p=top_p, top_k=top_k,
        stream=emb.STREAM_SAMPLE)
    return nxt, caches
