"""Core transformer layers, written for manual sharding inside shard_map.

Conventions
-----------
* Every forward function receives *local* shards and an `AxisEnv`.
* Weight layout: FSDP (ZeRO-3) over the dp axes on one dim, tensor parallel
  over `model` on another.  Forward gathers FSDP dims; autodiff turns those
  gathers into reduce-scatters, so dp gradient reduction is automatic.
* Sequence parallel: block boundary activations are (T_sp, d) with tokens
  sharded over `model`; blocks gather to (T_dp, d), compute with TP, and
  reduce-scatter partial outputs back to SP.
* Attention: query heads padded up to a multiple of tp and column-sharded;
  K/V projections are replicated (computed on every tp rank) because several
  assigned architectures have fewer KV heads than tp=16.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding import AxisEnv, fsdp_spec, pad_to_multiple

Params = Dict[str, jax.Array]
Specs = Dict[str, P]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def _normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def dense_init(key, shape, dtype, scale: float = 0.02):
    return _normal(key, shape, scale, dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(cfg, env: AxisEnv) -> Tuple[Params, Specs]:
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    params: Params = {"scale": jnp.ones((d,), dt)}
    specs: Specs = {"scale": fsdp_spec(env, 1, 0)}
    if cfg.norm_type == "layernorm":
        params["bias"] = jnp.zeros((d,), dt)
        specs["bias"] = fsdp_spec(env, 1, 0)
    return params, specs


def apply_norm(cfg, env: AxisEnv, params: Params, x: jax.Array) -> jax.Array:
    scale = env.gather_fsdp(params["scale"], 0).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        bias = env.gather_fsdp(params["bias"], 0).astype(jnp.float32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + 1e-5) * scale + bias
    else:  # rmsnorm
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + 1e-6) * scale
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_angles(positions: jax.Array, head_dim: int, theta: float):
    """positions (...,) -> cos/sin of shape (..., head_dim/2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, hd); cos/sin (..., S, hd/2) broadcastable over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # broadcast over head axis
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (dense FFN) — column/row tensor parallel
# ---------------------------------------------------------------------------

GATED_ACTS = ("swiglu", "geglu")


def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "swiglu":
        return jax.nn.silu(x)
    if name == "geglu":
        return jax.nn.gelu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "squared_relu":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(name)


def init_mlp(key, cfg, env: AxisEnv, d_ff: Optional[int] = None,
             scale_out: float = 0.02) -> Tuple[Params, Specs]:
    d = cfg.d_model
    ff = d_ff if d_ff is not None else cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    params = {"w1": dense_init(k1, (d, ff), dt),
              "w2": dense_init(k2, (ff, d), dt, scale_out)}
    specs = {"w1": fsdp_spec(env, 2, 0, 1), "w2": fsdp_spec(env, 2, 1, 0)}
    if cfg.mlp_act in GATED_ACTS:
        params["w3"] = dense_init(k3, (d, ff), dt)
        specs["w3"] = fsdp_spec(env, 2, 0, 1)
    return params, specs


def apply_mlp(cfg, env: AxisEnv, params: Params, x: jax.Array,
              act: Optional[str] = None) -> jax.Array:
    """x (T, d) full per dp-shard -> partial (T, d): caller combines over tp."""
    act = act or cfg.mlp_act
    cdt = jnp.dtype(cfg.compute_dtype)
    w1 = env.gather_fsdp(params["w1"], 0, dtype=cdt)
    w2 = env.gather_fsdp(params["w2"], 1, dtype=cdt)
    h = x @ w1
    if act in GATED_ACTS:
        w3 = env.gather_fsdp(params["w3"], 0, dtype=cdt)
        h = _act(act, h) * (x @ w3)
    else:
        h = _act(act, h)
    return h @ w2


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnDims:
    n_heads: int          # logical query heads
    n_kv: int             # kv heads (replicated over tp)
    heads_padded: int     # padded to multiple of tp
    local_heads: int
    head_dim: int

    @classmethod
    def build(cls, cfg, env: AxisEnv) -> "AttnDims":
        hp = pad_to_multiple(cfg.n_heads, env.tp)
        return cls(cfg.n_heads, cfg.n_kv_heads, hp, hp // env.tp,
                   cfg.head_dim)


def init_attention(key, cfg, env: AxisEnv, cross: bool = False
                   ) -> Tuple[Params, Specs]:
    ad = AttnDims.build(cfg, env)
    d, hd = cfg.d_model, ad.head_dim
    dt = jnp.dtype(cfg.param_dtype)
    kq, kk, kv, ko = jax.random.split(key, 4)
    out_scale = 0.02 / max(cfg.n_layers, 1) ** 0.5
    params = {
        "wq": dense_init(kq, (d, ad.heads_padded * hd), dt),
        "wk": dense_init(kk, (d, ad.n_kv * hd), dt),
        "wv": dense_init(kv, (d, ad.n_kv * hd), dt),
        "wo": dense_init(ko, (ad.heads_padded * hd, d), dt, out_scale),
    }
    specs = {
        "wq": fsdp_spec(env, 2, 0, 1),       # column: heads sharded
        "wk": fsdp_spec(env, 2, 0, None),    # replicated over tp
        "wv": fsdp_spec(env, 2, 0, None),
        "wo": fsdp_spec(env, 2, 1, 0),       # row: heads sharded
    }
    return params, specs


def _kv_index_for_local_heads(ad: AttnDims, env: AxisEnv) -> jax.Array:
    """Global GQA mapping: query head g uses kv head g // (H/KV); padded
    heads reuse the last kv head.  Returns (local_heads,) traced indices."""
    r = env.tp_index()
    g = r * ad.local_heads + jnp.arange(ad.local_heads)
    group = max(ad.n_heads // ad.n_kv, 1)
    return jnp.minimum(g // group, ad.n_kv - 1)


def choose_block(s: int, target: int = 1024) -> int:
    """Largest divisor of s that is <= target (block sizes must tile S)."""
    if s <= target:
        return s
    best = 1
    for b in range(1, target + 1):
        if s % b == 0:
            best = b
    return best


def _schedule_pairs(nq: int, nk: int, bq: int, bk: int, schedule: str,
                    window: Optional[int]) -> Tuple[List[int], List[int]]:
    """Static (q_block, k_block) pair enumeration.

    'full'    all pairs (baseline; masks do the causal work, ~2x FLOP waste)
    'causal'  lower-triangular blocks only
    'window'  causal + within sliding-window band (linear in S)
    """
    qs, ks = [], []
    for qi in range(nq):
        for ki in range(nk):
            if schedule in ("causal", "window") and ki * bk > (qi + 1) * bq - 1:
                continue
            if schedule == "window" and window is not None:
                # k block [ki*bk, (ki+1)*bk) vs needed [qi*bq - window + 1, ..)
                if (ki + 1) * bk - 1 < qi * bq - window + 1:
                    continue
            qs.append(qi)
            ks.append(ki)
    return qs, ks


def _pair_mask(qi, ki, bq, bk, causal, window, q_offset):
    qpos = qi * bq + jnp.arange(bq) + q_offset
    kpos = ki * bk + jnp.arange(bk)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    return mask


def _flash_fwd(qT, kT, vT, pairs, *, bq, bk, nq, causal, window, q_offset):
    """Returns (out_T (B,H,Sq,hd) f32 normalized, m (nq,B,H,bq), l)."""
    B, H, Sq, hd = qT.shape
    scale = hd ** -0.5
    m0 = jnp.full((nq, B, H, bq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((nq, B, H, bq), jnp.float32)
    a0 = jnp.zeros((nq, B, H, bq, hd), jnp.float32)

    def step(carry, pair):
        m, l, acc = carry
        qi, ki = pair
        qb = jax.lax.dynamic_slice_in_dim(qT, qi * bq, bq, axis=2)
        kb = jax.lax.dynamic_slice_in_dim(kT, ki * bk, bk, axis=2)
        vb = jax.lax.dynamic_slice_in_dim(vT, ki * bk, bk, axis=2)
        s = jnp.einsum("bhqd,bhkd->bhqk", qb, kb,
                       preferred_element_type=jnp.float32) * scale
        mask = _pair_mask(qi, ki, bq, bk, causal, window, q_offset)
        s = jnp.where(mask, s, -jnp.inf)
        m_old = jax.lax.dynamic_index_in_dim(m, qi, 0, keepdims=False)
        l_old = jax.lax.dynamic_index_in_dim(l, qi, 0, keepdims=False)
        a_old = jax.lax.dynamic_index_in_dim(acc, qi, 0, keepdims=False)
        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_old, m_blk)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(mask, jnp.exp(s - m_safe[..., None]), 0.0)
        corr = jnp.where(jnp.isfinite(m_old), jnp.exp(m_old - m_safe), 0.0)
        l_new = l_old * corr + jnp.sum(p, axis=-1)
        a_new = (a_old * corr[..., None]
                 + jnp.einsum("bhqk,bhkd->bhqd", p, vb.astype(jnp.float32)))
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 0)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, qi, 0)
        return (m, l, acc), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), pairs)
    out = acc / jnp.maximum(l, 1e-20)[..., None]          # (nq,B,H,bq,hd)
    out_T = jnp.moveaxis(out, 0, 2).reshape(B, H, Sq, hd)
    return out_T, m, l


@functools.lru_cache(maxsize=None)
def _flash_attention(bq: int, bk: int, nq: int, nk: int,
                     pairs_key: Tuple[Tuple[int, ...], Tuple[int, ...]],
                     causal: bool, window: Optional[int], q_offset: int):
    """custom_vjp flash attention specialized to a static schedule.

    Residuals are only (q, k, v, out, m, l) — the backward *recomputes* the
    block probabilities pair by pair instead of saving O(S^2) score tensors
    (which is what makes 32k-sequence training fit in HBM; see
    EXPERIMENTS.md §Perf for the before/after).
    """
    import numpy as _np
    # numpy (not jnp!) constants: a jnp array built during one trace would
    # leak that trace's tracer into later traces via the lru_cache.
    pairs = (_np.asarray(pairs_key[0], _np.int32),
             _np.asarray(pairs_key[1], _np.int32))

    @jax.custom_vjp
    def attn(qT, kT, vT):
        out_T, _, _ = _flash_fwd(qT, kT, vT, pairs, bq=bq, bk=bk, nq=nq,
                                 causal=causal, window=window,
                                 q_offset=q_offset)
        return out_T.astype(qT.dtype)

    def fwd(qT, kT, vT):
        out_T, m, l = _flash_fwd(qT, kT, vT, pairs, bq=bq, bk=bk, nq=nq,
                                 causal=causal, window=window,
                                 q_offset=q_offset)
        return out_T.astype(qT.dtype), (qT, kT, vT, out_T, m, l)

    def bwd(res, g):
        qT, kT, vT, out_T, m, l = res
        B, H, Sq, hd = qT.shape
        scale = hd ** -0.5
        gf = g.astype(jnp.float32)
        # D = rowsum(dout * out) per query
        D = jnp.sum(gf * out_T, axis=-1)                  # (B,H,Sq)
        l_flat = jnp.moveaxis(l, 0, 2).reshape(B, H, Sq)  # match layout
        m_flat = jnp.moveaxis(m, 0, 2).reshape(B, H, Sq)

        dq0 = jnp.zeros(qT.shape, jnp.float32)
        dk0 = jnp.zeros(kT.shape, jnp.float32)
        dv0 = jnp.zeros(vT.shape, jnp.float32)

        def step(carry, pair):
            dq, dk, dv = carry
            qi, ki = pair
            qb = jax.lax.dynamic_slice_in_dim(qT, qi * bq, bq, axis=2)
            kb = jax.lax.dynamic_slice_in_dim(kT, ki * bk, bk, axis=2)
            vb = jax.lax.dynamic_slice_in_dim(vT, ki * bk, bk, axis=2)
            gb = jax.lax.dynamic_slice_in_dim(gf, qi * bq, bq, axis=2)
            Db = jax.lax.dynamic_slice_in_dim(D, qi * bq, bq, axis=2)
            mb = jax.lax.dynamic_slice_in_dim(m_flat, qi * bq, bq, axis=2)
            lb = jax.lax.dynamic_slice_in_dim(l_flat, qi * bq, bq, axis=2)
            s = jnp.einsum("bhqd,bhkd->bhqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = _pair_mask(qi, ki, bq, bk, causal, window, q_offset)
            m_safe = jnp.where(jnp.isfinite(mb), mb, 0.0)
            p = jnp.where(mask, jnp.exp(s - m_safe[..., None]), 0.0)
            p = p / jnp.maximum(lb, 1e-20)[..., None]     # normalized probs
            dvb = jnp.einsum("bhqk,bhqd->bhkd", p, gb)
            dp = jnp.einsum("bhqd,bhkd->bhqk", gb, vb.astype(jnp.float32))
            ds = p * (dp - Db[..., None])
            dqb = jnp.einsum("bhqk,bhkd->bhqd",
                             ds, kb.astype(jnp.float32)) * scale
            dkb = jnp.einsum("bhqk,bhqd->bhkd",
                             ds, qb.astype(jnp.float32)) * scale
            dq = jax.lax.dynamic_update_slice_in_dim(
                dq, jax.lax.dynamic_slice_in_dim(dq, qi * bq, bq, 2) + dqb,
                qi * bq, axis=2)
            dk = jax.lax.dynamic_update_slice_in_dim(
                dk, jax.lax.dynamic_slice_in_dim(dk, ki * bk, bk, 2) + dkb,
                ki * bk, axis=2)
            dv = jax.lax.dynamic_update_slice_in_dim(
                dv, jax.lax.dynamic_slice_in_dim(dv, ki * bk, bk, 2) + dvb,
                ki * bk, axis=2)
            return (dq, dk, dv), None

        (dq, dk, dv), _ = jax.lax.scan(step, (dq0, dk0, dv0), pairs)
        return (dq.astype(qT.dtype), dk.astype(kT.dtype),
                dv.astype(vT.dtype))

    attn.defvjp(fwd, bwd)
    return attn


def attention_core(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool, window: Optional[int],
                   q_offset: int = 0, schedule: str = "causal",
                   block_target: int = 1024) -> jax.Array:
    """Blockwise (flash-structured) attention in pure JAX.

    q (B, Sq, H, hd); k, v (B, Sk, H, hd)  [kv already expanded to H heads]
    Returns (B, Sq, H, hd).  Memory is O(S * block) instead of O(S^2) in
    BOTH directions: the custom_vjp recomputes block probabilities in the
    backward pass, so 32k-sequence steps are lowerable.  The (q_block,
    k_block) schedule is enumerated statically: 'causal' visits only
    lower-triangular tiles (~2x fewer FLOPs than 'full'+masks) and 'window'
    visits only the sliding-window band (linear in S).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    bq = choose_block(Sq, block_target)
    bk = choose_block(Sk, block_target)
    nq, nk = Sq // bq, Sk // bk
    if not causal:
        schedule = "full"
    qs_idx, ks_idx = _schedule_pairs(nq, nk, bq, bk, schedule,
                                     window if schedule == "window" else None)
    fn = _flash_attention(bq, bk, nq, nk, (tuple(qs_idx), tuple(ks_idx)),
                          causal, window, q_offset)
    out_T = fn(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
               jnp.swapaxes(v, 1, 2))
    return jnp.swapaxes(out_T, 1, 2)                      # (B,Sq,H,hd)


def apply_attention(cfg, env: AxisEnv, params: Params, x: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    kv_source: Optional[jax.Array] = None,
                    use_rope: Optional[bool] = None,
                    schedule: str = "causal",
                    block_target: int = 1024,
                    return_cache: bool = False):
    """Training/prefill attention.

    x (B, S, d) full per dp-shard (replicated over tp).  Returns partial
    output (B, S, d) to be sp_scatter'ed by the caller, plus (optionally)
    the tp-local slice of the KV cache for prefill.
    """
    ad = AttnDims.build(cfg, env)
    cdt = jnp.dtype(cfg.compute_dtype)
    B, S, d = x.shape
    kv_in = kv_source if kv_source is not None else x
    Skv = kv_in.shape[1]

    wq = env.gather_fsdp(params["wq"], 0, dtype=cdt)
    wk = env.gather_fsdp(params["wk"], 0, dtype=cdt)
    wv = env.gather_fsdp(params["wv"], 0, dtype=cdt)
    wo = env.gather_fsdp(params["wo"], 1, dtype=cdt)

    q = (x @ wq).reshape(B, S, ad.local_heads, ad.head_dim)
    k = (kv_in @ wk).reshape(B, Skv, ad.n_kv, ad.head_dim)
    v = (kv_in @ wv).reshape(B, Skv, ad.n_kv, ad.head_dim)

    rope_on = cfg.use_rope if use_rope is None else use_rope
    if rope_on:
        cos_q, sin_q = rope_angles(jnp.arange(S), ad.head_dim, cfg.rope_theta)
        q = apply_rope(q, cos_q, sin_q)
        cos_k, sin_k = rope_angles(jnp.arange(Skv), ad.head_dim,
                                   cfg.rope_theta)
        k = apply_rope(k, cos_k, sin_k)

    kv_idx = _kv_index_for_local_heads(ad, env)
    k_sel = jnp.take(k, kv_idx, axis=2)   # (B, Skv, local_heads, hd)
    v_sel = jnp.take(v, kv_idx, axis=2)

    out = attention_core(q, k_sel, v_sel, causal=causal, window=window,
                         schedule=schedule, block_target=block_target)
    partial = out.reshape(B, S, ad.local_heads * ad.head_dim) @ wo

    if not return_cache:
        return partial, None
    # prefill: emit the tp-local S-slice of the (all-kv-head) cache
    s_loc = Skv // env.tp
    r = env.tp_index()
    k_slice = jax.lax.dynamic_slice_in_dim(k, r * s_loc, s_loc, axis=1)
    v_slice = jax.lax.dynamic_slice_in_dim(v, r * s_loc, s_loc, axis=1)
    return partial, {"k": k_slice, "v": v_slice}


def init_decode_cache(cfg, env: AxisEnv, batch_local: int, seq_len: int,
                      window: Optional[int] = None) -> Dict[str, jax.Array]:
    """KV cache, S-sharded over tp.  SWA uses a rolling window-sized cache."""
    ad = AttnDims.build(cfg, env)
    s_total = min(window, seq_len) if window else seq_len
    s_loc = max(s_total // env.tp, 1)
    cdt = jnp.dtype(cfg.compute_dtype)
    shape = (batch_local, s_loc, ad.n_kv, ad.head_dim)
    return {"k": jnp.zeros(shape, cdt), "v": jnp.zeros(shape, cdt)}


def decode_attention(cfg, env: AxisEnv, params: Params, x: jax.Array,
                     cache: Dict[str, jax.Array], pos: jax.Array, *,
                     window: Optional[int] = None,
                     cross: bool = False):
    """Single-token decode with an S-sharded cache and online-softmax psum.

    x (B_loc, d) replicated over tp; cache k/v (B_loc, S_loc, KV, hd).
    Every tp rank computes *all* query heads (the per-token q vector is
    all-gathered — tiny), attends its S-slice, and the (num, den) pair is
    psum'ed over tp; this shards cache memory 1/tp with O(B*H*hd) traffic.
    Returns (partial_out (B_loc, d), new_cache).
    """
    ad = AttnDims.build(cfg, env)
    cdt = jnp.dtype(cfg.compute_dtype)
    B = x.shape[0]
    hd = ad.head_dim

    wq = env.gather_fsdp(params["wq"], 0, dtype=cdt)
    wk = env.gather_fsdp(params["wk"], 0, dtype=cdt)
    wv = env.gather_fsdp(params["wv"], 0, dtype=cdt)
    wo = env.gather_fsdp(params["wo"], 1, dtype=cdt)

    q_local = (x @ wq).reshape(B, ad.local_heads, hd)
    if cfg.use_rope and not cross:
        cos, sin = rope_angles(pos[None], hd, cfg.rope_theta)
        q_local = apply_rope(q_local[:, None], cos[None], sin[None])[:, 0]
    # assemble all padded heads on every rank (tiny: B x Hp x hd)
    q_all = env.all_gather_tp(q_local, axis=1)            # (B, Hp, hd)

    s_loc = cache["k"].shape[1]
    r = env.tp_index()
    if not cross:
        k_new = (x @ wk).reshape(B, ad.n_kv, hd)
        v_new = (x @ wv).reshape(B, ad.n_kv, hd)
        if cfg.use_rope:
            cos, sin = rope_angles(pos[None], hd, cfg.rope_theta)
            k_new = apply_rope(k_new[:, None], cos[None], sin[None])[:, 0]
        # rolling slot for SWA, plain slot otherwise; only the owning rank
        # actually lands the update (masked dynamic_update_slice).
        slot = pos % (s_loc * env.tp) if window else pos
        local_slot = jnp.clip(slot - r * s_loc, 0, s_loc - 1)
        owns = (slot >= r * s_loc) & (slot < (r + 1) * s_loc)
        def upd(buf, new):
            cur = jax.lax.dynamic_slice_in_dim(buf, local_slot, 1, axis=1)
            new = jnp.where(owns, new[:, None], cur)
            return jax.lax.dynamic_update_slice_in_dim(buf, new, local_slot,
                                                       axis=1)
        cache = {"k": upd(cache["k"], k_new.astype(cdt)),
                 "v": upd(cache["v"], v_new.astype(cdt))}

    # score all padded heads against the local S slice, then the shared
    # online-softmax combine (`_decode_scores_combine` — also the paged
    # serving path's tail, so dense/paged decode parity holds by
    # construction).
    kpos = r * s_loc + jnp.arange(s_loc)
    if cross:
        valid = jnp.ones((s_loc,), bool)
    elif window:
        # rolling cache: every written slot is within the window by
        # construction; valid slots are those already written.
        n_written = jnp.minimum(pos + 1, s_loc * env.tp)
        # slots are addressed mod total; slot w is valid if w < n_written
        valid = kpos < n_written
    else:
        valid = kpos <= pos
    attn = _decode_scores_combine(
        cfg, env, ad, q_all, cache["k"], cache["v"],
        jnp.broadcast_to(valid[None, :], (B, s_loc)), cdt)  # (B,Hp,hd)

    # row-parallel output projection on the local head slice
    lo = r * ad.local_heads
    local = jax.lax.dynamic_slice_in_dim(attn, lo, ad.local_heads, axis=1)
    partial = local.reshape(B, ad.local_heads * hd) @ wo
    return partial, cache


def expand_cache_from_prefill(prefill_cache):
    """Prefill emits (B, S_loc, KV, hd) slices already in decode layout."""
    return prefill_cache


# ---------------------------------------------------------------------------
# Paged KV attention (online serving)
# ---------------------------------------------------------------------------
#
# The online engine (serving/online.py) stores the decode KV cache as a
# slot-agnostic *page pool* instead of a dense (B, S) tensor: pool k/v are
# (n_pages, ps_loc, KV, hd) with the in-page offset dim sharded over tp
# (ps_loc = page_size // tp — rank r owns offsets [r*ps_loc, (r+1)*ps_loc)
# of every page, preserving the dense path's 1/tp cache-memory sharding).
# A per-slot page table maps logical page -> physical page; admission,
# completion, and preemption are pure table/mask updates, so the jitted
# step never recompiles.  Physical page 0 is reserved as a scratch page:
# masked lanes (inactive slots, non-owning ranks) land their writes there,
# which keeps every pool update a plain vectorized scatter.


def init_paged_kv_pool(cfg, n_pages: int, page_size: int
                       ) -> Dict[str, jax.Array]:
    """GLOBAL paged KV pool for one attention layer (zeros).  The serving
    Runner shards the page_size dim over tp via `api.paged_cache_specs`."""
    cdt = jnp.dtype(cfg.compute_dtype)
    hd = cfg.head_dim or cfg.d_model // cfg.n_heads
    shape = (n_pages, page_size, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, cdt), "v": jnp.zeros(shape, cdt)}


def paged_valid_mask(table, pos, *, page_size: int, ps_loc: int,
                     env: AxisEnv):
    """Once-per-tick paged-attention validity mask (B, Q, S_g).

    table (B, n_lp) physical page per logical page (0 = unallocated);
    pos (B, Q) int32 query positions (inclusive — a query attends to its
    own just-written row).  Pool row j of logical page i sits at global
    position i*page_size + r*ps_loc + j on rank r; a row is attendable
    iff its logical page is allocated AND its position is <= the query's.
    The mask is identical across layers, so models/model.py computes it
    once per serve tick and threads it through the layer scan instead of
    re-deriving the jnp.repeat + gpos comparison per layer; the layer
    entry points only recompute it when called standalone (valid=None).
    """
    n_lp = table.shape[-1]
    S_g = n_lp * ps_loc
    j = jnp.arange(S_g)
    gpos = ((j // ps_loc) * page_size + env.tp_index() * ps_loc
            + j % ps_loc)
    pvalid = jnp.repeat(table > 0, ps_loc, axis=-1)          # (B, S_g)
    return pvalid[:, None, :] & (gpos[None, None, :] <= pos[:, :, None])


def _paged_write(pool, k_new, v_new, pos, page_table, owns, *,
                 page_size: int, env: AxisEnv, cdt):
    """Scatter per-lane KV rows into their pages.

    pos (...,) int32 global positions; page_table broadcastable lookup of
    the physical page per lane (already resolved by the caller); owns
    (...,) bool — lanes that are inactive, unallocated, or whose in-page
    offset belongs to another tp rank write to scratch page 0 instead.
    """
    ps_loc = pool["k"].shape[1]
    r = env.tp_index()
    o = pos % page_size
    dest = jnp.where(owns, page_table, 0)
    o_loc = jnp.clip(o - r * ps_loc, 0, ps_loc - 1)
    return {"k": pool["k"].at[dest, o_loc].set(k_new.astype(cdt)),
            "v": pool["v"].at[dest, o_loc].set(v_new.astype(cdt))}


def _paged_scores_combine(cfg, env: AxisEnv, ad: AttnDims, q_all, k_g, v_g,
                          valid, cdt):
    """Query-batched attention tail over ONE shared cache view: masked
    scores + online-softmax (num, den) psum over tp + normalize.

    q_all (B, Q, Hp, hd); k_g/v_g (B, S, KV, hd) — read once, every
    query contracts against the same view via batched einsums (no
    per-query broadcast_to copy);  valid (B, Q, S).  Fast path: when no
    head padding happened and heads group evenly onto kv heads, q
    reshapes to (kv, group) and contracts against the cache directly —
    no expanded KV copy ever hits HBM (big decode-bandwidth win, see
    EXPERIMENTS.md §Perf); p stays in compute dtype for the PV
    contraction (flash-kernel convention) so no f32 copy of the
    cache-sized V materializes either.  Returns (B, Q, Hp, hd)."""
    hd = ad.head_dim
    B, Qn, S_g = valid.shape
    grouped = (ad.n_heads == ad.heads_padded
               and ad.heads_padded % ad.n_kv == 0)
    if grouped:
        g = ad.heads_padded // ad.n_kv
        q_g = q_all.reshape(B, Qn, ad.n_kv, g, hd)
        s = jnp.einsum("bqkgd,bskd->bqkgs", q_g, k_g,
                       preferred_element_type=jnp.float32) * hd ** -0.5
        s = s.reshape(B, Qn, ad.heads_padded, S_g)
    else:
        group = max(ad.n_heads // ad.n_kv, 1)
        hp_kv = jnp.minimum(jnp.arange(ad.heads_padded) // group,
                            ad.n_kv - 1)
        k_exp = jnp.take(k_g, hp_kv, axis=2)
        s = jnp.einsum("bqhd,bshd->bqhs", q_all, k_exp,
                       preferred_element_type=jnp.float32) * hd ** -0.5
    s = jnp.where(valid[:, :, None, :], s, -jnp.inf)
    m_loc = jnp.max(s, axis=-1)
    m = env.pmax_tp(m_loc)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(valid[:, :, None, :], jnp.exp(s - m_safe[..., None]), 0.0)
    p_c = p.astype(cdt)
    if grouped:
        p_g = p_c.reshape(B, Qn, ad.n_kv, ad.heads_padded // ad.n_kv, S_g)
        num = jnp.einsum("bqkgs,bskd->bqkgd", p_g, v_g,
                         preferred_element_type=jnp.float32)
        num = num.reshape(B, Qn, ad.heads_padded, hd)
    else:
        group = max(ad.n_heads // ad.n_kv, 1)
        hp_kv = jnp.minimum(jnp.arange(ad.heads_padded) // group,
                            ad.n_kv - 1)
        v_exp = jnp.take(v_g, hp_kv, axis=2)
        num = jnp.einsum("bqhs,bshd->bqhd", p_c, v_exp,
                         preferred_element_type=jnp.float32)
    den = jnp.sum(p, axis=-1)
    num, den = env.psum_tp((num, den))
    return (num / jnp.maximum(den, 1e-20)[..., None]).astype(cdt)


def _decode_scores_combine(cfg, env: AxisEnv, ad: AttnDims, q_all, k_g, v_g,
                           valid, cdt):
    """Single-query shim over `_paged_scores_combine` for the dense
    S-sharded decode cache: q_all (B, Hp, hd), valid (B, S)."""
    out = _paged_scores_combine(cfg, env, ad, q_all[:, None], k_g, v_g,
                                valid[:, None], cdt)
    return out[:, 0]


def resolve_paged_attn(mode: str) -> str:
    """RunFlags.paged_attn -> concrete mode.  "auto" follows the PR 1/2
    policy: the fused Pallas kernel on interpret builds, the gathered
    jnp oracle on real TPUs until the tile sweep (ROADMAP item 3)."""
    if mode == "auto":
        from repro.kernels import ops as kops
        return "fused" if kops.INTERPRET else "gathered"
    if mode not in ("fused", "gathered"):
        raise ValueError(f"paged_attn must be auto|fused|gathered: {mode}")
    return mode


def _paged_attention_core(cfg, env: AxisEnv, ad: AttnDims, q_all, pool,
                          table, valid, cdt, *, paged_attn: str):
    """Shared query-batched paged-attention core for all three callers
    (decode Q=1, chunked prefill Q=C, spec-decode verify Q=k+1).

    q_all (B, Q, Hp, hd); pool k/v (n_pages, ps_loc, KV, hd); table
    (B, n_lp); valid (B, Q, S_g).  "fused" walks the page table inside
    the Pallas kernel (kernels/paged_attn.py) and combines the local
    (num, m, den) partials over tp here — the gathered (B, S_g, KV, hd)
    view never touches HBM; "gathered" materializes it once per layer
    via `ops.paged_gather` (the parity oracle).  The fused kernel needs
    the grouped GQA layout; head-padded / unevenly-grouped archs fall
    back to gathered.  Returns (B, Q, Hp, hd)."""
    from repro.kernels import ops as kops
    hd = ad.head_dim
    B = q_all.shape[0]
    mode = resolve_paged_attn(paged_attn)
    grouped = (ad.n_heads == ad.heads_padded
               and ad.heads_padded % ad.n_kv == 0)
    if mode == "fused" and grouped:
        # max pass -> tp pmax -> accumulate pass: p is computed against
        # the GLOBAL max inside the kernel and rounded to cdt there, so
        # every softmax term matches the gathered oracle at any tp.
        m_loc = kops.paged_attention_scores_max(q_all, pool["k"], table,
                                                valid)
        m = env.pmax_tp(m_loc)
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        num, den = kops.paged_attention_accumulate(
            q_all, pool["k"], pool["v"], table, valid, m_safe)
        num, den = env.psum_tp((num, den))
        return (num / jnp.maximum(den, 1e-20)[..., None]).astype(cdt)
    S_g = valid.shape[-1]
    k_g = kops.paged_gather(pool["k"], table).reshape(B, S_g, ad.n_kv, hd)
    v_g = kops.paged_gather(pool["v"], table).reshape(B, S_g, ad.n_kv, hd)
    return _paged_scores_combine(cfg, env, ad, q_all, k_g, v_g, valid, cdt)


def paged_decode_attention(cfg, env: AxisEnv, params: Params, x: jax.Array,
                           pool: Dict[str, jax.Array], pos: jax.Array,
                           table: jax.Array, active: jax.Array, *,
                           page_size: int, paged_attn: str = "auto",
                           valid: Optional[jax.Array] = None):
    """Single-token decode against a paged KV pool.

    x (B, d) replicated over tp (B = max_slots, a fixed shape); pos (B,)
    int32 position being written per slot; table (B, n_lp) physical page
    per logical page (0 = unallocated); active (B,) bool.  Writes the new
    token's KV into its page (masked to the owning rank + scratch page for
    everyone else), then runs the shared `_paged_attention_core` (same
    (num, den)-psum online softmax as `decode_attention`; `paged_attn`
    picks fused-kernel vs gathered).  `valid` is the once-per-tick
    (B, 1, S_g) mask from `paged_valid_mask` (recomputed here when
    standalone).  Returns (partial (B, d), pool)."""
    ad = AttnDims.build(cfg, env)
    cdt = jnp.dtype(cfg.compute_dtype)
    B = x.shape[0]
    hd = ad.head_dim
    n_lp = table.shape[1]
    ps_loc = pool["k"].shape[1]
    r = env.tp_index()

    wq = env.gather_fsdp(params["wq"], 0, dtype=cdt)
    wk = env.gather_fsdp(params["wk"], 0, dtype=cdt)
    wv = env.gather_fsdp(params["wv"], 0, dtype=cdt)
    wo = env.gather_fsdp(params["wo"], 1, dtype=cdt)

    q_local = (x @ wq).reshape(B, ad.local_heads, hd)
    k_new = (x @ wk).reshape(B, ad.n_kv, hd)
    v_new = (x @ wv).reshape(B, ad.n_kv, hd)
    if cfg.use_rope:
        cos, sin = rope_angles(pos, hd, cfg.rope_theta)      # (B, hd/2)
        q_local = apply_rope(q_local[:, None], cos[:, None],
                             sin[:, None])[:, 0]
        k_new = apply_rope(k_new[:, None], cos[:, None], sin[:, None])[:, 0]
    q_all = env.all_gather_tp(q_local, axis=1)               # (B, Hp, hd)

    lp = jnp.clip(pos // page_size, 0, n_lp - 1)
    pp = jnp.take_along_axis(table, lp[:, None], axis=1)[:, 0]
    owns = active & (pp > 0) & ((pos % page_size) // ps_loc == r)
    pool = _paged_write(pool, k_new, v_new, pos, pp, owns,
                        page_size=page_size, env=env, cdt=cdt)

    if valid is None:
        valid = paged_valid_mask(table, pos[:, None], page_size=page_size,
                                 ps_loc=ps_loc, env=env)
    attn = _paged_attention_core(cfg, env, ad, q_all[:, None], pool, table,
                                 valid, cdt, paged_attn=paged_attn)[:, 0]

    lo = r * ad.local_heads
    local = jax.lax.dynamic_slice_in_dim(attn, lo, ad.local_heads, axis=1)
    partial = local.reshape(B, ad.local_heads * hd) @ wo
    return partial, pool


def paged_prefill_attention(cfg, env: AxisEnv, params: Params, x: jax.Array,
                            pool: Dict[str, jax.Array], base: jax.Array,
                            n_valid: jax.Array, table_row: jax.Array, *,
                            page_size: int, paged_attn: str = "auto",
                            valid: Optional[jax.Array] = None):
    """One chunked-prefill attention step for a single request.

    x (C, d) replicated over tp — the chunk's activations; base (scalar)
    tokens already written for this request; n_valid (scalar) real tokens
    in the chunk (the tail is padding); table_row (n_lp,) the request's
    page table.  Writes the chunk's KV into its pages, then each chunk
    query attends causally over the request's full written history
    through the shared `_paged_attention_core` (the whole chunk is one
    query batch — the cache view is read once, never per query).
    `valid` is the once-per-tick (1, C, S_g) mask.  Returns
    (partial (C, d), pool)."""
    ad = AttnDims.build(cfg, env)
    cdt = jnp.dtype(cfg.compute_dtype)
    C = x.shape[0]
    hd = ad.head_dim
    n_lp = table_row.shape[0]
    ps_loc = pool["k"].shape[1]
    r = env.tp_index()

    wq = env.gather_fsdp(params["wq"], 0, dtype=cdt)
    wk = env.gather_fsdp(params["wk"], 0, dtype=cdt)
    wv = env.gather_fsdp(params["wv"], 0, dtype=cdt)
    wo = env.gather_fsdp(params["wo"], 1, dtype=cdt)

    posq = base + jnp.arange(C)
    q_local = (x @ wq).reshape(C, ad.local_heads, hd)
    k_new = (x @ wk).reshape(C, ad.n_kv, hd)
    v_new = (x @ wv).reshape(C, ad.n_kv, hd)
    if cfg.use_rope:
        cos, sin = rope_angles(posq, hd, cfg.rope_theta)     # (C, hd/2)
        q_local = apply_rope(q_local[:, None], cos[:, None],
                             sin[:, None])[:, 0]
        k_new = apply_rope(k_new[:, None], cos[:, None], sin[:, None])[:, 0]
    q_all = env.all_gather_tp(q_local, axis=1)               # (C, Hp, hd)

    lp = jnp.clip(posq // page_size, 0, n_lp - 1)
    pp = jnp.take(table_row, lp)                             # (C,)
    owns = ((jnp.arange(C) < n_valid) & (pp > 0)
            & ((posq % page_size) // ps_loc == r))
    pool = _paged_write(pool, k_new, v_new, posq, pp, owns,
                        page_size=page_size, env=env, cdt=cdt)

    if valid is None:
        valid = paged_valid_mask(table_row[None], posq[None],
                                 page_size=page_size, ps_loc=ps_loc,
                                 env=env)
    attn = _paged_attention_core(cfg, env, ad, q_all[None], pool,
                                 table_row[None], valid, cdt,
                                 paged_attn=paged_attn)[0]

    lo = r * ad.local_heads
    local = jax.lax.dynamic_slice_in_dim(attn, lo, ad.local_heads, axis=1)
    partial = local.reshape(C, ad.local_heads * hd) @ wo
    return partial, pool


def paged_verify_attention(cfg, env: AxisEnv, params: Params, x: jax.Array,
                           pool: Dict[str, jax.Array], pos: jax.Array,
                           table: jax.Array, active: jax.Array, *,
                           page_size: int, paged_attn: str = "auto",
                           valid: Optional[jax.Array] = None):
    """Speculative-decode verify: Q consecutive tokens per slot in one
    paged-prefill-shaped pass over the slot batch.

    x (B, Q, d) replicated over tp — slot b's candidate tokens at
    positions pos[b, 0..Q-1] (consecutive: pos[b, j] = pos[b, 0] + j);
    table (B, n_lp); active (B,).  Writes all B*Q candidate KV rows
    (masked lanes -> scratch page 0), then each query attends causally
    over its slot's pages via `_paged_attention_core` — so verify logits
    at a position are the decode logits at that position by
    construction.  `valid` (B, Q, S_g) is the once-per-tick page mask
    from `paged_valid_mask` (recomputed here when None).  Returns
    (partial (B*Q, d), pool)."""
    ad = AttnDims.build(cfg, env)
    cdt = jnp.dtype(cfg.compute_dtype)
    B, Q, d = x.shape
    hd = ad.head_dim
    n_lp = table.shape[1]
    ps_loc = pool["k"].shape[1]
    r = env.tp_index()

    wq = env.gather_fsdp(params["wq"], 0, dtype=cdt)
    wk = env.gather_fsdp(params["wk"], 0, dtype=cdt)
    wv = env.gather_fsdp(params["wv"], 0, dtype=cdt)
    wo = env.gather_fsdp(params["wo"], 1, dtype=cdt)

    xf = x.reshape(B * Q, d)
    posf = pos.reshape(B * Q)
    q_local = (xf @ wq).reshape(B * Q, ad.local_heads, hd)
    k_new = (xf @ wk).reshape(B * Q, ad.n_kv, hd)
    v_new = (xf @ wv).reshape(B * Q, ad.n_kv, hd)
    if cfg.use_rope:
        cos, sin = rope_angles(posf, hd, cfg.rope_theta)   # (B*Q, hd/2)
        q_local = apply_rope(q_local[:, None], cos[:, None],
                             sin[:, None])[:, 0]
        k_new = apply_rope(k_new[:, None], cos[:, None], sin[:, None])[:, 0]
    q_all = env.all_gather_tp(q_local, axis=1)             # (B*Q, Hp, hd)

    lp = jnp.clip(pos // page_size, 0, n_lp - 1)           # (B, Q)
    pp = jnp.take_along_axis(table, lp, axis=1)            # (B, Q)
    owns = (active[:, None] & (pp > 0)
            & ((pos % page_size) // ps_loc == r))
    pool = _paged_write(pool, k_new.reshape(B, Q, ad.n_kv, hd),
                        v_new.reshape(B, Q, ad.n_kv, hd), pos, pp, owns,
                        page_size=page_size, env=env, cdt=cdt)

    if valid is None:
        valid = paged_valid_mask(table, pos, page_size=page_size,
                                 ps_loc=ps_loc, env=env)
    attn = _paged_attention_core(
        cfg, env, ad, q_all.reshape(B, Q, ad.heads_padded, hd), pool,
        table, valid, cdt,
        paged_attn=paged_attn).reshape(B * Q, ad.heads_padded, hd)

    lo = r * ad.local_heads
    local = jax.lax.dynamic_slice_in_dim(attn, lo, ad.local_heads, axis=1)
    partial = local.reshape(B * Q, ad.local_heads * hd) @ wo
    return partial, pool
