"""repro subpackage."""
