"""repro subpackage."""
