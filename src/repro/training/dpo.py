"""Direct Preference Optimization with the paper's data-packing strategy
(§4.2, C14).

The paper's claim: padding chosen/rejected pairs to max length wastes most
of the batch; their packing strategy keeps the chosen-rejected pairing
paradigm while packing sequences, a **3.7x** DPO throughput win.

Implemented here:
  * `dpo_loss` — vanilla DPO with the NLL regularization term (weight 0.05,
    §4.2 'Robustness optimization') that keeps chosen log-probs from
    collapsing;
  * format-masked DPO (§4.2 'DPO-format'): a token mask confines the loss
    to format-specific spans so shared reasoning isn't penalized;
  * `pack_pairs` vs `pad_pairs` — the two batch layouts; the benchmark
    measures tokens-of-useful-content per padded token for each, which is
    the paper's speedup lever (compute scales with padded tokens).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def sequence_logps(logits: jax.Array, labels: jax.Array,
                   mask: jax.Array) -> jax.Array:
    """Sum log p(label) over masked positions.  logits (B,S,V) fp32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.sum((picked - logz) * mask, axis=-1)


@dataclasses.dataclass(frozen=True)
class DPOConfig:
    beta: float = 0.1
    nll_weight: float = 0.05          # §4.2 NLL regularization


def dpo_loss(policy_chosen_lp, policy_rejected_lp,
             ref_chosen_lp, ref_rejected_lp,
             cfg: DPOConfig = DPOConfig(),
             chosen_token_count: Optional[jax.Array] = None):
    """Vanilla DPO + NLL regularization on the chosen responses."""
    ratio = (policy_chosen_lp - ref_chosen_lp
             - (policy_rejected_lp - ref_rejected_lp))
    dpo = -jnp.mean(jax.nn.log_sigmoid(cfg.beta * ratio))
    nll = -jnp.mean(policy_chosen_lp
                    / jnp.maximum(chosen_token_count, 1.0)
                    if chosen_token_count is not None
                    else policy_chosen_lp)
    loss = dpo + cfg.nll_weight * nll
    acc = jnp.mean((ratio > 0).astype(jnp.float32))
    return loss, {"dpo": dpo, "nll": nll, "preference_acc": acc}


# ---------------------------------------------------------------------------
# batch layouts: padded pairs vs packed pairs
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PairExample:
    prompt: np.ndarray
    chosen: np.ndarray
    rejected: np.ndarray
    format_mask_chosen: Optional[np.ndarray] = None   # DPO-format masking


def pad_pairs(examples: Sequence[PairExample], max_len: int
              ) -> Dict[str, np.ndarray]:
    """Baseline: each of chosen/rejected padded to max_len -> 2B rows."""
    B = len(examples)
    tokens = np.zeros((2 * B, max_len), np.int32)
    mask = np.zeros((2 * B, max_len), np.float32)
    for i, ex in enumerate(examples):
        for j, resp in ((0, ex.chosen), (1, ex.rejected)):
            seq = np.concatenate([ex.prompt, resp])[:max_len]
            row = 2 * i + j
            tokens[row, :len(seq)] = seq
            mask[row, len(ex.prompt):len(seq)] = 1.0
    return {"tokens": tokens, "resp_mask": mask,
            "useful_frac": float(mask.sum() / mask.size)}


def pack_pairs(examples: Sequence[PairExample], max_len: int
               ) -> Dict[str, np.ndarray]:
    """Paper strategy: pack multiple (prompt+chosen+rejected) groups into
    shared rows, keeping each pair's segments adjacent so the
    chosen-rejected pairing paradigm survives.  Segment ids fence attention
    and per-pair logp pooling."""
    rows: List[List[Tuple[int, np.ndarray, np.ndarray]]] = [[]]
    used = [0]
    pair_id = 0
    for ex in examples:
        group = []
        for j, resp in ((0, ex.chosen), (1, ex.rejected)):
            seq = np.concatenate([ex.prompt, resp])[:max_len]
            m = np.zeros(len(seq), np.float32)
            m[len(ex.prompt):] = 1.0
            group.append((2 * pair_id + j, seq, m))
        need = sum(len(s) for _, s, _ in group)
        if used[-1] + need > max_len and used[-1] > 0:
            rows.append([])
            used.append(0)
        rows[-1].extend(group)
        used[-1] += need
        pair_id += 1
    R = len(rows)
    tokens = np.zeros((R, max_len), np.int32)
    mask = np.zeros((R, max_len), np.float32)
    seg = np.full((R, max_len), -1, np.int32)
    for r, row in enumerate(rows):
        off = 0
        for sid, seq, m in row:
            n = len(seq)
            tokens[r, off:off + n] = seq
            mask[r, off:off + n] = m
            seg[r, off:off + n] = sid
            off += n
    return {"tokens": tokens, "resp_mask": mask, "segment_ids": seg,
            "n_pairs": pair_id,
            "useful_frac": float((seg >= 0).sum() / seg.size)}


def packing_speedup(examples: Sequence[PairExample], max_len: int) -> Dict:
    """Compute rows processed per pair under each layout: compute cost is
    ~ rows * max_len^2 (attention) + rows * max_len * d, so the row ratio
    is the throughput ratio (the paper's 3.7x)."""
    padded = pad_pairs(examples, max_len)
    packed = pack_pairs(examples, max_len)
    rows_padded = padded["tokens"].shape[0]
    rows_packed = packed["tokens"].shape[0]
    return {"rows_padded": rows_padded, "rows_packed": rows_packed,
            "speedup": rows_padded / rows_packed,
            "useful_frac_padded": padded["useful_frac"],
            "useful_frac_packed": packed["useful_frac"]}


def segment_pooled_logps(logits: jax.Array, tokens: jax.Array,
                         resp_mask: jax.Array, segment_ids: jax.Array,
                         n_pairs: int) -> Tuple[jax.Array, jax.Array]:
    """Per-(pair, chosen/rejected) summed log-probs from packed rows."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, tokens[..., None], axis=-1)[..., 0]
    tok_lp = (picked - logz) * resp_mask
    flat_lp = tok_lp.reshape(-1)
    flat_seg = segment_ids.reshape(-1)
    sums = jnp.zeros((2 * n_pairs,), jnp.float32).at[
        jnp.clip(flat_seg, 0)].add(jnp.where(flat_seg >= 0, flat_lp, 0.0))
    counts = jnp.zeros((2 * n_pairs,), jnp.float32).at[
        jnp.clip(flat_seg, 0)].add(
        jnp.where(flat_seg >= 0, resp_mask.reshape(-1), 0.0))
    chosen = sums[0::2]
    rejected = sums[1::2]
    return (chosen, rejected), counts[0::2]
