"""Mesh-native training engine wiring the paper's training recipe together:

  model (Runner) + AdamW + WSD schedule + microbatch grad accumulation
  + batch-size warmup via scheduled accumulation (§3.4.1: a staged
  compile cache swaps step functions at stage boundaries, see
  docs/training.md) + device-side loss-spike guard (C6, §3.4.4)
  + XPUTimer tracing (C9) + async PCache checkpointing with exact resume
  (C10), including mid-warmup stage carry-over.

Division of labour per §3.4.4 / §2.1 / §2.3.1:

  * the **jitted step** (`Runner.jit_train_step`) owns the fast path:
    sharded params + AdamW moments (EP-aware PartitionSpecs), fp32 grad
    accumulation over microbatches as a `lax.scan`, buffer donation so
    params/opt/guard update in place, and the spike commit-or-discard as a
    `jnp.where` driven by an EMA loss statistic carried in a tiny
    replicated device-side state — no per-step host round-trip;
  * the **host loop** owns the policy: per-step device metrics accumulate
    in a pending list and are drained (one transfer) every `log_every`
    steps, feeding the `SpikeDetector`'s narrow/wide classification, the
    sample-retry queue, and the LR-halving window; `DataPipeline` batches
    are prefetched on a background thread while the device runs; PCache
    saves the sharded pytrees with background I/O and `restore` resumes
    the run — params, opt, guard, pipeline stream, and detector state —
    exactly.

A consequence of the asynchronous drain: LR-halving after a wide spike
takes effect within `log_every` steps of the spike (instead of the next
step), matching the paper's monitoring-system latency rather than the
idealized synchronous loop.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import api, sharding
from repro.analysis import contracts
from repro.core import spikes as spikes_lib
from repro.core.spikes import SpikeConfig, SpikeDetector
from repro.data.pipeline import DataPipeline, Prefetcher
from repro.optim import adamw
from repro.optim.schedule import AccumWarmup, WSDSchedule
from repro.telemetry.metrics import MetricsRegistry
from repro.telemetry.xputimer import XPUTimer


@dataclasses.dataclass
class TrainConfig:
    n_steps: int = 100
    lr_schedule: WSDSchedule = dataclasses.field(
        default_factory=lambda: WSDSchedule(max_lr=1e-3, warmup_steps=20,
                                            total_steps=1000))
    opt: adamw.AdamWConfig = dataclasses.field(
        default_factory=adamw.AdamWConfig)
    spike: SpikeConfig = dataclasses.field(default_factory=SpikeConfig)
    accum_steps: int = 1               # microbatches per optimizer step
    bs_warmup: Optional[AccumWarmup] = None   # §3.4.1 scheduled accumulation
    donate: bool = True                # in-place params/opt/guard update
    prefetch_depth: int = 2            # batches packed ahead of the device
    log_every: int = 10                # metrics-drain (host sync) period
    checkpoint_every: int = 0          # 0 = off
    checkpoint_dir: Optional[str] = None
    seed: int = 0
    # run every step under contracts.transfer_guard so any implicit
    # device->host sync inside the hot loop raises (docs/analysis.md);
    # None = read the REPRO_DEBUG_GUARDS env var
    debug_guards: Optional[bool] = None


class Trainer:
    def __init__(self, runner: api.Runner, pipeline: DataPipeline,
                 cfg: TrainConfig, timer: Optional[XPUTimer] = None,
                 registry: Optional[MetricsRegistry] = None):
        self.runner = runner
        self.pipeline = pipeline
        self.cfg = cfg
        # metrics registry (docs/observability.md): XPUTimer publishes
        # span/counter/gauge mirrors into it, and the drain below feeds
        # loss/lr gauges — everything from values already on the host
        # (the drained floats), never an extra device sync
        self.registry = registry if registry is not None else MetricsRegistry()
        self.timer = timer or XPUTimer(registry=self.registry)
        if self.timer.registry is None:
            self.timer.registry = self.registry
        self._m_loss = self.registry.gauge(
            "train_loss", "last drained training loss")
        self._m_lr = self.registry.gauge(
            "train_lr", "last drained learning rate")
        self._m_steps = self.registry.counter(
            "train_steps_total", "optimizer steps drained")
        self.detector = SpikeDetector(cfg.spike)
        self.debug_guards = (contracts.env_debug_guards()
                             if cfg.debug_guards is None
                             else cfg.debug_guards)
        if cfg.bs_warmup is not None:
            # §3.4.1 batch-size warmup through the accumulation dim: the
            # microbatch shape is pinned to the pipeline's batch_size and
            # the staged cache compiles one step per distinct accum count
            assert cfg.bs_warmup.microbatch == pipeline.cfg.batch_size, (
                f"bs_warmup.microbatch={cfg.bs_warmup.microbatch} must "
                f"equal pipeline batch_size={pipeline.cfg.batch_size}")
            self.staged = runner.jit_train_step(
                pipeline.cfg.batch_size, cfg.opt,
                accum_steps=cfg.bs_warmup.stages(),
                spike_guard=cfg.spike, donate=cfg.donate)
            self._accum = cfg.bs_warmup.accum_for(0)
            self.step_fn = self.staged.for_accum(self._accum)
        else:
            self.staged = None
            self._accum = cfg.accum_steps
            self.step_fn = runner.jit_train_step(
                pipeline.cfg.batch_size, cfg.opt,
                accum_steps=cfg.accum_steps,
                spike_guard=cfg.spike, donate=cfg.donate)
        self.params = runner.init_params(cfg.seed)
        self.opt_state = adamw.init_opt_state(self.params)
        self.guard_state = spikes_lib.init_guard_state(cfg.spike)
        self.rng = jax.random.PRNGKey(cfg.seed)
        self.step = 0                  # next step index to execute
        self.history: List[Dict[str, float]] = []
        self.metric_drains = 0         # host metric transfers (tested)
        # one record per dispatched-but-undrained step
        self._pending: List[Any] = []  # (step, lr, device-metrics, accum,
                                       #  host batch for the retry lane)
        self._prefetcher: Optional[Prefetcher] = None
        self._preload: List[Dict] = []
        self.pcache = None
        if cfg.checkpoint_dir:
            from repro.checkpoint.pcache import PCache
            self.pcache = PCache(cfg.checkpoint_dir)

    # -- data ----------------------------------------------------------------
    def _accum_for(self, step: int) -> int:
        """Accumulation count scheduled for global step `step`."""
        if self.cfg.bs_warmup is not None:
            return self.cfg.bs_warmup.accum_for(step)
        return self.cfg.accum_steps

    def _ensure_prefetcher(self):
        if self._prefetcher is None:
            # the producer packs for step `step + len(preload) + k`: any
            # preloaded (restored) batches cover the steps in between, so
            # each prefetched macrobatch lands at the granularity the
            # warmup schedules for the step that will consume it
            produce_step = itertools.count(self.step + len(self._preload))
            self._prefetcher = Prefetcher(
                lambda: self.pipeline.next_macrobatch(
                    self._accum_for(next(produce_step))),
                depth=max(1, self.cfg.prefetch_depth),
                preload=self._preload)
            self._preload = []

    # -- main loop -----------------------------------------------------------
    def train(self, n_steps: Optional[int] = None) -> List[Dict[str, float]]:
        """Run until the *global* step counter reaches `n_steps` (default
        `cfg.n_steps`).  From a fresh trainer that is n_steps steps; after
        `restore` it is the remainder of the original schedule — resuming
        never overshoots the LR schedule's total."""
        cfg = self.cfg
        # explicit None check: train(0) is a no-op, not "run cfg.n_steps"
        end = cfg.n_steps if n_steps is None else n_steps
        if self.step >= end:
            return self.history
        self._ensure_prefetcher()
        while self.step < end:
            i = self.step
            accum = self._accum_for(i)
            if self.staged is not None and accum != self._accum:
                # warmup stage boundary: swap in the (cached) compiled
                # step for the new accum count — no recompilation when
                # the stage was already visited (e.g. after restore)
                self._accum = accum
                self.step_fn = self.staged.for_accum(accum)
            with self.timer.span("data"):
                batch = self._prefetcher.get()
                jbatch = {k: jnp.asarray(v) for k, v in batch.items()}
            sched = cfg.lr_schedule
            # PR-4 regression (FC-HOSTSYNC): float(sched(i)) here would
            # evaluate a jnp schedule on device and block the async
            # dispatch pipeline every step — schedules must expose a
            # host-side evaluator
            host_lr = getattr(sched, "host", None)
            if host_lr is None:
                raise TypeError(
                    f"{type(sched).__name__} has no .host(step) — LR "
                    f"schedules used by Trainer must evaluate host-side "
                    f"(see optim/schedule.py)")
            lr = host_lr(i) * self.detector.lr_scale_for(i)
            with self.timer.span("step"), self._step_guard():
                # async dispatch: no host sync here — the device decides
                # commit/discard itself, metrics stay on device.
                (self.params, self.opt_state, self.guard_state,
                 metrics) = self.step_fn(
                    self.params, self.opt_state, self.guard_state, jbatch,
                    jnp.int32(i), jax.random.fold_in(self.rng, i),
                    jnp.float32(lr))
            self._pending.append((i, lr, metrics, accum, batch))
            self.step += 1
            ckpt = bool(self.pcache is not None and cfg.checkpoint_every
                        and self.step % cfg.checkpoint_every == 0)
            # log_every=0 means "no periodic logging" (seed semantics), not
            # "no policy": fall back to per-step drains so spike
            # retry/LR-halving never starve and _pending stays bounded
            if (self.step % (cfg.log_every or 1) == 0
                    or ckpt or self.step >= end):
                self._drain()
            if ckpt:
                with self.timer.span("checkpoint"):
                    self.save(f"step_{self.step}")
        return self.history

    def _step_guard(self):
        """Armed (debug_guards) the step dispatch runs under a d2h
        transfer guard: metrics must stay on device until `_drain`."""
        if self.debug_guards:
            return contracts.transfer_guard("disallow")
        return contextlib.nullcontext()

    # -- async metrics drain ---------------------------------------------------
    def _drain(self):
        """One host transfer for every pending step's metrics; feeds the
        host-side spike policy (classification / retry / LR window)."""
        if not self._pending:
            return
        with self.timer.span("drain"):
            # one device_get for the whole window, converted to python
            # floats at the boundary — nothing downstream touches device
            # values (FC-HOSTSYNC stays structurally impossible below)
            host = [{k: float(v) for k, v in m.items()}
                    for m in jax.device_get(
                        [m for _, _, m, _, _ in self._pending])]
        self.metric_drains += 1
        self.timer.count("metric_drain")
        n_commit = 0
        for (i, lr, _, accum, batch), mh in zip(self._pending, host):
            loss = mh["loss"]
            committed = mh.get("commit", 1.0) >= 0.5
            # the batch payload lives only in the pipeline's retry lane —
            # the detector records the event, not the data (a second copy
            # would grow without bound and bloat every host checkpoint)
            self.detector.ingest(i, loss, skipped=not committed)
            if committed:
                n_commit += 1
            else:
                # §3.4.4: the update was already discarded on device;
                # host side re-injects the data later
                if batch is not None:
                    self.pipeline.push_retry(batch, accum)
                self.timer.count("spike_skipped")
            rec = {"step": i, "loss": loss, "lr": lr,
                   "skipped": not committed,
                   **{k: v for k, v in mh.items()
                      if k not in ("loss", "commit")}}
            self.history.append(rec)
            if self.cfg.log_every and i % self.cfg.log_every == 0:
                print(f"[train] step={i} loss={loss:.4f} lr={lr:.2e}"
                      f"{'' if committed else ' SKIP'}", flush=True)
        self.timer.gauge("commit_frac", n_commit / len(host))
        self._m_steps.inc(len(host))
        last = self.history[-1]
        self._m_loss.set(last["loss"])
        self._m_lr.set(last["lr"])
        self._pending.clear()

    # -- checkpointing ---------------------------------------------------------
    def save(self, name: str) -> str:
        """Async checkpoint: sharded device pytrees are fetched now (cheap
        sync; also a donation barrier) and written by PCache's dispersed
        background writers, plus a host sidecar (pipeline stream incl.
        prefetched batches, detector policy, step counter) so `restore`
        continues the run exactly."""
        assert self.pcache is not None, "TrainConfig.checkpoint_dir unset"
        self.pcache.wait()             # one background save in flight max
        if self._prefetcher is not None:
            with self._prefetcher.paused() as pending:
                pipe_state = self.pipeline.state_dict()
                prefetched = pending
        else:
            # restore() may have staged preloaded batches without a live
            # prefetcher yet; dropping them would skip stream positions
            pipe_state = self.pipeline.state_dict()
            prefetched = list(self._preload)
        self.pcache.save(name, {"params": self.params,
                                "opt": self.opt_state,
                                "guard": self.guard_state}, block=False)
        self.pcache.save_host(name, {
            "step": self.step,
            "accum_stage": self._accum_for(self.step),
            "pipeline": pipe_state,
            "prefetched": prefetched,
            "detector": self.detector.state_dict(),
        })
        return name

    def _reshard(self, tree, specs):
        mesh = self.runner.mesh
        spec_leaves = jax.tree.leaves(specs,
                                      is_leaf=lambda x: isinstance(x, P))
        leaves, treedef = jax.tree.flatten(tree)
        out = [jax.device_put(l, jax.sharding.NamedSharding(mesh, s))
               for l, s in zip(leaves, spec_leaves)]
        return jax.tree.unflatten(treedef, out)

    def restore(self, name: str = "latest") -> str:
        """Resume from a PCache checkpoint: device pytrees are re-sharded
        onto the runner's spec trees, the data stream continues from its
        saved position (including batches that were sitting in the
        prefetch queue), and the spike policy window carries over."""
        assert self.pcache is not None, "TrainConfig.checkpoint_dir unset"
        self.pcache.wait()
        if name == "latest":
            found = self.pcache.latest()
            assert found is not None, "no complete checkpoint found"
            name = found
        # quiesce the producer BEFORE touching pipeline state: the thread
        # mutates pipeline rng/buffer under its own lock only
        if self._prefetcher is not None:
            self._prefetcher.stop()
            self._prefetcher = None
        like = {"params": self.params, "opt": self.opt_state,
                "guard": self.guard_state}
        tree = self.pcache.load(name, like)
        pspecs = self.runner.specs
        self.params = self._reshard(tree["params"], pspecs)
        self.opt_state = self._reshard(tree["opt"],
                                       adamw.opt_state_specs(pspecs))
        self.guard_state = self._reshard(
            tree["guard"], sharding.replicated_specs(tree["guard"]))
        host = self.pcache.load_host(name)
        self.step = host["step"]
        if self.staged is not None:
            # resume mid-warmup at the exact stage: the sidecar carries
            # the accum count for the next step (falling back to the
            # schedule, which is deterministic in the step counter)
            self._accum = host.get("accum_stage",
                                   self._accum_for(self.step))
            self.step_fn = self.staged.for_accum(self._accum)
        self.pipeline.load_state_dict(host["pipeline"])
        self.detector.load_state_dict(host["detector"])
        self._preload = list(host["prefetched"])
        self._pending.clear()
        return name

    def close(self):
        """Stop the prefetch thread and flush async checkpoint writers."""
        if self._prefetcher is not None:
            self._prefetcher.stop()
            self._prefetcher = None
        if self.pcache is not None:
            self.pcache.wait()
