"""End-to-end trainer wiring the paper's training recipe together:

  model (Runner) + AdamW + WSD schedule + batch-size warmup
  + loss-spike skip & sample-retry (C6) + XPUTimer tracing (C9)
  + PCache checkpointing (C10).

The spike response is exactly §3.4.4: on a detected spike the update is
discarded (params/opt not committed), the batch goes to the retry queue for
random re-injection, and a persistent (wide) spike additionally halves the
LR for a window of steps.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core.spikes import SpikeConfig, SpikeDetector
from repro.data.pipeline import DataPipeline, PipelineConfig
from repro.optim import adamw
from repro.optim.schedule import WSDSchedule
from repro.telemetry.xputimer import XPUTimer


@dataclasses.dataclass
class TrainConfig:
    n_steps: int = 100
    lr_schedule: WSDSchedule = dataclasses.field(
        default_factory=lambda: WSDSchedule(max_lr=1e-3, warmup_steps=20,
                                            total_steps=1000))
    opt: adamw.AdamWConfig = dataclasses.field(
        default_factory=adamw.AdamWConfig)
    spike: SpikeConfig = dataclasses.field(default_factory=SpikeConfig)
    log_every: int = 10
    checkpoint_every: int = 0          # 0 = off
    checkpoint_dir: Optional[str] = None
    seed: int = 0


class Trainer:
    def __init__(self, runner: api.Runner, pipeline: DataPipeline,
                 cfg: TrainConfig, timer: Optional[XPUTimer] = None):
        self.runner = runner
        self.pipeline = pipeline
        self.cfg = cfg
        self.timer = timer or XPUTimer()
        self.detector = SpikeDetector(cfg.spike)
        self.step_fn = jax.jit(
            runner.make_train_step(pipeline.cfg.batch_size, cfg.opt))
        self.params = runner.init_params(cfg.seed)
        self.opt_state = adamw.init_opt_state(self.params)
        self.rng = jax.random.PRNGKey(cfg.seed)
        self.history: List[Dict[str, float]] = []
        self.pcache = None
        if cfg.checkpoint_dir:
            from repro.checkpoint.pcache import PCache
            self.pcache = PCache(cfg.checkpoint_dir)

    def train(self, n_steps: Optional[int] = None) -> List[Dict[str, float]]:
        n = n_steps or self.cfg.n_steps
        for i in range(n):
            with self.timer.span("data"):
                batch = self.pipeline.next_batch()
                jbatch = {k: jnp.asarray(v) for k, v in batch.items()}
            lr = float(self.cfg.lr_schedule(i))
            # spike-driven LR reduction applies before the step
            lr *= self.detector.cfg.lr_reduce_factor \
                if i <= self.detector.lr_reduced_until else 1.0
            with self.timer.span("step"):
                new_params, new_opt, metrics = self.step_fn(
                    self.params, self.opt_state, jbatch, jnp.int32(i),
                    jax.random.fold_in(self.rng, i), jnp.float32(lr))
                loss = float(metrics["loss"])
            with self.timer.span("spike_check"):
                verdict = self.detector.observe(i, loss, batch=batch)
            if verdict["skip"]:
                # §3.4.4: skip the update, re-inject the data later
                self.pipeline.push_retry(batch)
                self.timer.count("spike_skipped")
            else:
                self.params, self.opt_state = new_params, new_opt
            rec = {"step": i, "loss": loss, "lr": lr,
                   "skipped": bool(verdict["skip"]),
                   **{k: float(v) for k, v in metrics.items()
                      if k != "loss"}}
            self.history.append(rec)
            if self.cfg.log_every and i % self.cfg.log_every == 0:
                print(f"[train] step={i} loss={loss:.4f} lr={lr:.2e}"
                      f"{' SKIP' if verdict['skip'] else ''}", flush=True)
            if (self.pcache and self.cfg.checkpoint_every
                    and i and i % self.cfg.checkpoint_every == 0):
                with self.timer.span("checkpoint"):
                    self.pcache.save(f"step_{i}", {
                        "params": self.params, "opt": self.opt_state})
        return self.history
