"""repro subpackage."""
