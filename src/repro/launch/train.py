"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch ling-lite --smoke \
        --steps 200 --batch 8 --seq 256

Selects the architecture config (``--arch`` over the full registry,
``--smoke`` for the reduced same-family variant), builds the mesh over the
available devices, and runs the full recipe: AdamW + WSD + batch-size
warmup + spike skip/retry + XPUTimer + optional PCache checkpoints +
optional EDiT multi-worker mode (``--edit-workers K``).
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp

from repro import api
from repro.configs.base import get_config, get_smoke_config
from repro.core.edit import EDiTConfig, EDiTTrainer
from repro.data.pipeline import DataPipeline, PipelineConfig
from repro.launch.mesh import make_local_mesh
from repro.optim import adamw
from repro.optim.schedule import WSDSchedule
from repro.telemetry.xputimer import XPUTimer
from repro.training.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ling-lite")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--edit-workers", type=int, default=0,
                    help=">0 runs EDiT local-SGD with K workers")
    ap.add_argument("--report", default=None, help="write history JSON here")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_local_mesh(args.dp, args.tp)
    runner = api.Runner(cfg, mesh, max_seq=args.seq)
    pipe = DataPipeline(PipelineConfig(vocab_size=cfg.vocab_size,
                                       seq_len=args.seq,
                                       batch_size=args.batch))

    if args.edit_workers > 0:
        step = jax.jit(runner.make_train_step(args.batch))
        params = runner.init_params(0)

        def worker_step(w, opt, batch, i, lr):
            if opt is None:
                opt = adamw.init_opt_state(w)
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            w, opt, m = step(w, opt, jb, jnp.int32(i),
                             jax.random.PRNGKey(i), jnp.float32(lr))
            return w, opt, m["loss"]

        edit = EDiTTrainer(params, worker_step,
                           EDiTConfig(sync_every=4), args.edit_workers)
        rounds = max(1, args.steps // 4)
        for r in range(rounds):
            batches = [[pipe.next_batch() for _ in range(4)]
                       for _ in range(args.edit_workers)]
            rec = edit.round(batches, lr=args.lr)
            print(f"[edit] round={r} {rec}")
        history = edit.history
    else:
        tcfg = TrainConfig(
            n_steps=args.steps,
            lr_schedule=WSDSchedule(max_lr=args.lr, warmup_steps=20,
                                    total_steps=max(args.steps, 1)),
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=args.checkpoint_every)
        trainer = Trainer(runner, pipe, tcfg, timer=XPUTimer())
        history = trainer.train()
        print(json.dumps(trainer.timer.diagnose()["spans"], indent=1))

    if args.report:
        with open(args.report, "w") as f:
            json.dump(history, f, indent=1)
    print(f"final loss: {history[-1].get('loss', history[-1].get('mean_loss')):.4f}")


if __name__ == "__main__":
    main()
