"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch ling-lite --smoke \
        --steps 200 --batch 8 --seq 256

Selects the architecture config (``--arch`` over the full registry,
``--smoke`` for the reduced same-family variant), builds the mesh over the
available devices, and runs the mesh-native training engine: sharded
donated train step + microbatch accumulation (``--accum``) + batch-size
warmup via scheduled accumulation (``--bs-warmup start:end:steps``,
§3.4.1 — one compile per stage, never per-step) + device-side
spike guard + WSD schedule + prefetch + XPUTimer + optional async PCache
checkpoints (``--resume`` continues from the newest one) + optional EDiT
multi-worker mode (``--edit-workers K``).  ``--moe-dispatch ep`` selects
the expert-parallel all-to-all MoE path for training, matching the serve
CLI.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp

from repro import api
from repro.configs.base import get_config, get_smoke_config
from repro.core import spikes as spikes_lib
from repro.core.edit import EDiTConfig, EDiTTrainer
from repro.data.pipeline import DataPipeline, PipelineConfig
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.optim import adamw
from repro.optim.schedule import AccumWarmup, WSDSchedule
from repro.training.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ling-lite")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--accum", type=int, default=1,
                    help="microbatches accumulated per optimizer step")
    ap.add_argument("--bs-warmup", default=None, metavar="START:END:STEPS",
                    help="batch-size warmup (§3.4.1) through the "
                         "accumulation dim: global batch grows START->END "
                         "sequences over STEPS steps while the microbatch "
                         "stays --batch (START/END must be multiples of "
                         "--batch); overrides --accum, trainer path only")
    ap.add_argument("--moe-dispatch", default="auto",
                    choices=["auto", "fused", "ragged", "batched", "ep"],
                    help="MoE train dispatch; 'ep' routes tokens over the "
                         "mesh via the all-to-all expert-parallel path "
                         "(tp > 1)")
    ap.add_argument("--spike-gnorm-sigma", type=float, default=None,
                    metavar="SIGMA",
                    help="also key the device-side spike guard on the "
                         "grad norm (§3.4.4 fn2): skip the update when "
                         "grad_norm > EMA mean + SIGMA * std (default: "
                         "loss-only guard)")
    ap.add_argument("--no-donate", action="store_true",
                    help="disable params/opt buffer donation (debugging)")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="resume from the newest checkpoint in "
                         "--checkpoint-dir")
    ap.add_argument("--edit-workers", type=int, default=0,
                    help=">0 runs EDiT local-SGD with K workers")
    ap.add_argument("--report", default=None, help="write history JSON here")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace-event JSON of the run "
                         "(XPUTimer span tracks: data/step/drain/"
                         "checkpoint) viewable at https://ui.perfetto.dev; "
                         "trainer path only")
    args = ap.parse_args()

    bs_warmup = None
    if args.bs_warmup:
        if args.edit_workers > 0:
            ap.error("--bs-warmup is not supported with --edit-workers")
        try:
            start, end, steps = (int(x) for x in args.bs_warmup.split(":"))
            bs_warmup = AccumWarmup(microbatch=args.batch, start=start,
                                    end=end, warmup_steps=steps)
        except ValueError as e:
            ap.error(f"--bs-warmup: {e}")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_local_mesh(args.dp, args.tp)
    flags = M.RunFlags(moe_dispatch=args.moe_dispatch)
    spike_cfg = spikes_lib.SpikeConfig(
        gnorm_sigma_threshold=args.spike_gnorm_sigma)
    runner = api.Runner(cfg, mesh, max_seq=args.seq, flags=flags)
    pipe = DataPipeline(PipelineConfig(vocab_size=cfg.vocab_size,
                                       seq_len=args.seq,
                                       batch_size=args.batch))

    if args.edit_workers > 0:
        # EDiT workers reuse the same engine step builder as the trainer:
        # donated, spike-guarded, accumulation-aware.  Each worker's opaque
        # opt slot carries (adamw state, device guard state).
        step = runner.jit_train_step(args.batch, accum_steps=args.accum,
                                     spike_guard=spike_cfg,
                                     donate=not args.no_donate)
        params = runner.init_params(0)

        def worker_step(w, opt, batch, i, lr):
            if opt is None:
                opt = (adamw.init_opt_state(w),
                       spikes_lib.init_guard_state(spike_cfg))
            o, g = opt
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            w, o, g, m = step(w, o, g, jb, jnp.int32(i),
                              jax.random.PRNGKey(i), jnp.float32(lr))
            return w, (o, g), m["loss"]

        edit = EDiTTrainer(params, worker_step,
                           EDiTConfig(sync_every=4), args.edit_workers)
        rounds = max(1, args.steps // 4)
        for r in range(rounds):
            batches = [[pipe.next_macrobatch(args.accum) for _ in range(4)]
                       for _ in range(args.edit_workers)]
            rec = edit.round(batches, lr=args.lr)
            print(f"[edit] round={r} {rec}")
        history = edit.history
    else:
        tcfg = TrainConfig(
            n_steps=args.steps,
            lr_schedule=WSDSchedule(max_lr=args.lr, warmup_steps=20,
                                    total_steps=max(args.steps, 1)),
            spike=spike_cfg,
            accum_steps=args.accum,
            bs_warmup=bs_warmup,
            donate=not args.no_donate,
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=args.checkpoint_every)
        trainer = Trainer(runner, pipe, tcfg)
        if args.resume:
            name = trainer.restore("latest")
            print(f"[train] resumed from {name} at step {trainer.step}")
        history = trainer.train()
        trainer.close()
        print(json.dumps(trainer.timer.diagnose()["spans"], indent=1))
        if args.trace_out:
            from repro.telemetry import write_chrome_trace
            n = write_chrome_trace(args.trace_out, timer=trainer.timer,
                                   registry=trainer.registry)
            print(f"[train] trace ({n} events) -> {args.trace_out} "
                  f"(open at https://ui.perfetto.dev)")

    if args.report:
        with open(args.report, "w") as f:
            json.dump(history, f, indent=1)
    if history:
        last = history[-1]
        print(f"final loss: "
              f"{last.get('loss', last.get('mean_loss', float('nan'))):.4f}")
    else:
        print("final loss: n/a (no steps ran)")


if __name__ == "__main__":
    main()
