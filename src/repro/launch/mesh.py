"""Production mesh construction.

NOTE: functions only — importing this module never touches jax device
state.  The dry-run entrypoint sets XLA_FLAGS for 512 host devices *before*
any jax import; everything else sees the real (single-CPU) device set.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """TPU v5e production mesh: 16x16 = 256 chips per pod; 2 pods = 512."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(dp: int = 1, tp: int = 1) -> jax.sharding.Mesh:
    """Small mesh over the actually-present devices (smoke tests, examples)."""
    return jax.make_mesh((dp, tp), ("data", "model"))
