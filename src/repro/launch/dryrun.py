"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture x input-shape) pair against the
production meshes — 16x16=256 chips single-pod and 2x16x16=512 chips
multi-pod — using ShapeDtypeStruct inputs (no allocation), then records
memory_analysis, cost_analysis, and the parsed collective schedule for the
roofline table (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  python -m repro.launch.dryrun --arch phi3-mini-3.8b --shape train_4k
  python -m repro.launch.dryrun --arch all [--multi-pod] [--out DIR]
"""
# The dry-run (and ONLY the dry-run) needs 512 placeholder devices; jax
# locks the device count at first init, so this precedes every other import.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import gzip              # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import subprocess        # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import api, roofline           # noqa: E402
from repro.configs.base import (ARCH_IDS, INPUT_SHAPES, get_config,
                                supported_shapes)  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model as M       # noqa: E402
from repro.optim import adamw              # noqa: E402

ASSIGNED = [a for a in ARCH_IDS if a not in ("ling-lite", "ling-plus")]


def to_abstract(shapes_tree, specs_tree, mesh):
    """Attach NamedShardings to a ShapeDtypeStruct tree."""
    def mk(sd, spec):
        return jax.ShapeDtypeStruct(
            sd.shape, sd.dtype, sharding=NamedSharding(mesh, spec))
    return jax.tree.map(
        mk, shapes_tree,
        jax.tree.unflatten(jax.tree.structure(shapes_tree),
                           jax.tree.leaves(specs_tree,
                                           is_leaf=lambda x: isinstance(x, P))))


def input_specs(runner: api.Runner, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    shape = INPUT_SHAPES[shape_name]
    mesh, env, cfg = runner.mesh, runner.env, runner.cfg
    if shape.mode == "train":
        shapes = runner.train_batch_shapes(shape)
        return to_abstract(shapes, runner.train_batch_specs(
            shape.global_batch), mesh)
    if shape.mode == "prefill":
        shapes = {k: v for k, v in runner.train_batch_shapes(shape).items()
                  if k != "labels"}
        specs = {k: v for k, v in runner.train_batch_specs(
            shape.global_batch).items() if k != "labels"}
        return to_abstract(shapes, specs, mesh)
    # decode: one token per sequence + position scalar
    b = api.batch_sharding(env, shape.global_batch)
    tok = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32,
                               sharding=NamedSharding(mesh, P(b)))
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))
    return {"token": tok, "pos": pos}


def model_flops_per_chip(cfg, shape, n_chips: int) -> float:
    n_active = cfg.active_param_count()
    if shape.mode == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len / n_chips
    if shape.mode == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len / n_chips
    return 2.0 * n_active * shape.global_batch / n_chips


def run_pair(arch: str, shape_name: str, multi_pod: bool,
             flags: M.RunFlags = M.DEFAULT_FLAGS, *, sp_comm="native",
             gather_cast=True, cf=None, serve_fsdp=False):
    cfg = get_config(arch)
    if cf is not None and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=cf))
    shape = INPUT_SHAPES[shape_name]
    if shape_name not in supported_shapes(cfg):
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped",
                "reason": "long_500k requires sub-quadratic attention "
                          "(full-attention architecture; see DESIGN.md)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()

    if shape.mode == "train":
        runner = api.Runner(cfg, mesh, flags=flags, fsdp=True,
                            seq_parallel=True, max_seq=shape.seq_len,
                            sp_comm=sp_comm, gather_cast=gather_cast)
        step = runner.make_train_step(shape.global_batch)
        params = runner.abstract_params()
        opt = to_abstract(
            jax.eval_shape(adamw.init_opt_state, runner.shapes),
            adamw.opt_state_specs(runner.specs), mesh)
        batch = input_specs(runner, shape_name)
        rep = NamedSharding(mesh, P())
        step_i = jax.ShapeDtypeStruct((), jnp.int32, sharding=rep)
        lr = jax.ShapeDtypeStruct((), jnp.float32, sharding=rep)
        rng = jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=rep)
        lowered = jax.jit(step).lower(params, opt, batch, step_i, rng, lr)
    elif shape.mode == "prefill":
        scfg = dataclasses.replace(cfg, param_dtype="bfloat16")
        runner = api.Runner(scfg, mesh, flags=flags, fsdp=False,
                            seq_parallel=True, max_seq=shape.seq_len)
        fn = runner.make_prefill(shape.global_batch)
        params = runner.abstract_params()
        batch = input_specs(runner, shape_name)
        lowered = jax.jit(fn).lower(params, batch)
    else:  # decode
        scfg = dataclasses.replace(cfg, param_dtype="bfloat16")
        runner = api.Runner(scfg, mesh, flags=flags, fsdp=serve_fsdp,
                            seq_parallel=False, max_seq=shape.seq_len)
        fn, cache_specs = runner.make_decode_step(shape.global_batch,
                                                  shape.seq_len)
        params = runner.abstract_params()
        cache_shapes, b = runner.init_cache_shapes(shape.global_batch,
                                                   shape.seq_len)
        caches = to_abstract(cache_shapes, cache_specs, mesh)
        inp = input_specs(runner, shape_name)
        lowered = jax.jit(fn).lower(params, caches, inp["token"], inp["pos"])

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_d = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
    }
    hlo_text = compiled.as_text()
    rl = roofline.analyze_text(
        hlo_text, model_flops_per_chip=model_flops_per_chip(cfg, shape,
                                                            n_chips))
    if os.environ.get("DRYRUN_SAVE_HLO"):
        os.makedirs("experiments/hlo", exist_ok=True)
        tag = f"{arch}__{shape_name}__{'2x16x16' if multi_pod else '16x16'}"
        with gzip.open(f"experiments/hlo/{tag}.hlo.gz", "wt") as f:
            f.write(hlo_text)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok", "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": mem_d, "roofline": rl.to_dict(),
        "flags": {**dataclasses.asdict(flags), "sp_comm": sp_comm,
                  "gather_cast": gather_cast, "cf": cf},
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--schedule", default="causal",
                    choices=["full", "causal", "window"])
    ap.add_argument("--moe-dispatch", default="auto",
                    choices=["auto", "fused", "ragged", "batched"])
    ap.add_argument("--rwkv-chunk", type=int, default=0)
    ap.add_argument("--sp-comm", default="native",
                    choices=["native", "int8"])
    ap.add_argument("--no-gather-cast", action="store_true")
    ap.add_argument("--cf", type=float, default=None,
                    help="override MoE capacity factor")
    ap.add_argument("--attn-block", type=int, default=1024)
    ap.add_argument("--serve-fsdp", action="store_true",
                    help="shard serving params over dp too (290B-class)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = ASSIGNED if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)
    flags = dataclasses.replace(M.DEFAULT_FLAGS,
                                attn_schedule=args.schedule,
                                moe_dispatch=args.moe_dispatch,
                                rwkv_chunk=args.rwkv_chunk,
                                attn_block=args.attn_block)

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                if args.tag:
                    tag += f"__{args.tag}"
                path = os.path.join(args.out, tag + ".json")
                try:
                    rec = run_pair(arch, shape, mp, flags,
                                   sp_comm=args.sp_comm,
                                   gather_cast=not args.no_gather_cast,
                                   cf=args.cf, serve_fsdp=args.serve_fsdp)
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error", "error": repr(e)[:2000]}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" bottleneck={r['bottleneck']}"
                             f" compute={roofline.fmt_seconds(r['compute_s'])}"
                             f" mem={roofline.fmt_seconds(r['memory_s'])}"
                             f" coll={roofline.fmt_seconds(r['collective_s'])}"
                             f" useful={r['useful_ratio']:.2f}"
                             f" compile={rec['compile_s']}s")
                elif status == "error":
                    extra = " " + rec["error"][:160]
                print(f"[dryrun] {tag}: {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
