"""Serving launcher: offline (Flood) and online continuous-batching modes.

    # offline: Flood pipeline engine over a fixed request set
    PYTHONPATH=src python -m repro.launch.serve --arch ling-lite --smoke \
        --requests 16 --max-new 16

    # online: continuous batching + paged KV + Poisson load generator
    PYTHONPATH=src python -m repro.launch.serve --arch ling-lite --smoke \
        --online --rates 4,16 --requests 24 --max-new 8

Offline builds the model, splits its layers into pipeline stages, and
drives the FloodEngine (segment KV cache, S+1 in-flight micro-batches);
`--baseline` runs the synchronous global-batch engine instead for the
Table-3-shaped comparison.  Online drives the `OnlineEngine`
(docs/serving.md): slot-based continuous batching over a paged device KV
cache, measured under Poisson arrivals at each `--rates` entry — TTFT /
inter-token-latency percentiles and sustained tok/s land in
BENCH_serve_online.json (`--report` to relocate).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.configs.base import get_config, get_smoke_config
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.serving.draft import (ConfigDrafter, SelfDrafter,
                                 adapt_drafter_config)
from repro.serving.flood import (FloodEngine, GenRequest,
                                 baseline_step_engine, quantize_microbatch)
from repro.serving.online import (OnlineConfig, OnlineEngine,
                                  run_poisson_load)
from repro.serving.segment_cache import SegmentCache
from repro.telemetry import MetricsServer, SLOConfig, write_chrome_trace


def build_model_engine(cfg, mesh, n_stages: int, seq_len: int,
                       batch: int, flags: M.RunFlags = M.DEFAULT_FLAGS,
                       temperature: float = 0.0, top_p: float = 1.0,
                       top_k: int = 0, seed: int = 0):
    """Real-model Flood engine: layers split into n_stages jitted chunks.

    Stage state carries (x, caches_slice, pos); decode math is exactly the
    model's block_decode.  `flags.moe_dispatch` selects the MoE decode
    path — with tp > 1 and "ep" the decode batch routes tokens over the
    mesh through the same all-to-all dispatch training uses.

    Sampling knobs ride the sampled decode step as per-sequence data;
    each request draws under seed `(seed + rid) % 2**31` with the same
    counter-based (seed, position, stream) key schedule the online
    engine uses, so an offline run reproduces an online request's token
    stream for matching seeds/positions (temperature 0 = exact greedy).
    """
    runner = api.Runner(cfg, mesh, fsdp=False, seq_parallel=False,
                        max_seq=seq_len, flags=flags)
    params = runner.init_params(0)
    decode, _ = runner.make_decode_step(batch, seq_len, sample=True)
    decode = jax.jit(decode)
    caches = M.init_caches(cfg, runner.env, batch, seq_len,
                           cross_len=cfg.encoder_seq_len)
    state = {"caches": caches, "pos": 0}

    def embed_fn(reqs):
        toks = np.zeros((batch,), np.int32)
        seeds = np.zeros((batch,), np.int32)
        for i, r in enumerate(reqs[:batch]):
            toks[i] = (r.out[-1] if r.out else r.prompt[-1])
            seeds[i] = (seed + r.rid) % (2 ** 31)
        return {"tokens": jnp.asarray(toks), "seeds": jnp.asarray(seeds),
                "reqs": len(reqs)}

    def stage_fn(_i):
        def fn(x):
            return x  # layer stages fused into head_fn for the real model
        return fn

    knobs = (np.full((batch,), temperature, np.float32),
             np.full((batch,), top_p, np.float32),
             np.full((batch,), top_k, np.int32))

    def head_fn(x, reqs):
        nonlocal state
        nxt, state["caches"] = decode(
            params, state["caches"], x["tokens"], jnp.int32(state["pos"]),
            x["seeds"], jnp.asarray(knobs[0]), jnp.asarray(knobs[1]),
            jnp.asarray(knobs[2]))
        state["pos"] += 1
        return np.asarray(nxt)[:len(reqs)]

    return embed_fn, [stage_fn(i) for i in range(n_stages)], head_fn


def make_drafter(cfg, args):
    """Resolve the --draft-* flags into a serving.draft drafter (None
    when speculation is off)."""
    if args.spec_k <= 0:
        return None
    if args.draft_arch:
        dcfg = (get_smoke_config(args.draft_arch) if args.smoke
                else get_config(args.draft_arch))
        return ConfigDrafter(adapt_drafter_config(dcfg, cfg))
    return SelfDrafter(draft_layers=args.draft_layers)


def parse_tenant_budgets(spec):
    """'alice:128,bob:64' -> {'alice': 128, 'bob': 64} (None passes
    through)."""
    if not spec:
        return None
    out = {}
    for part in spec.split(","):
        name, _, tokens = part.partition(":")
        if not name or not tokens:
            raise ValueError(f"--tenant-budgets entry {part!r} is not "
                             f"name:tokens")
        out[name] = int(tokens)
    return out


def run_online(cfg, mesh, flags, args) -> None:
    """Online continuous batching under a Poisson load at each rate."""
    runner = api.Runner(cfg, mesh, fsdp=False, seq_parallel=False,
                        max_seq=args.seq, flags=flags)
    params = runner.init_params(0)
    budgets = parse_tenant_budgets(args.tenant_budgets)
    slo = None
    if args.overload == "slo":
        if args.slo_ttft_ms is None:
            raise SystemExit("--overload slo requires --slo-ttft-ms "
                             "(and optionally --slo-itl-ms)")
        slo = SLOConfig(ttft_p99_ms=args.slo_ttft_ms,
                        itl_p99_ms=args.slo_itl_ms)
    ocfg = OnlineConfig(
        max_slots=quantize_microbatch(args.slots, args.tp),
        max_context=args.seq, page_size=args.page_size,
        n_pages=args.pages,
        prefill_chunk=quantize_microbatch(args.prefill_chunk, args.tp),
        temperature=args.temperature, top_p=args.top_p, top_k=args.top_k,
        seed=args.seed, spec_k=args.spec_k,
        radix_cache=not args.no_radix_cache, policy=args.policy,
        max_queue=args.max_queue, overload=args.overload,
        tenant_budgets=budgets, slo=slo)
    eng = OnlineEngine(runner, params, ocfg, drafter=make_drafter(cfg, args))
    server = None
    if args.metrics_port is not None:
        # point-in-time Prometheus scrape on a background daemon thread
        # (docs/observability.md); port 0 binds an ephemeral port
        server = MetricsServer(eng.registry, port=args.metrics_port)
        print(f"[online] metrics -> "
              f"http://127.0.0.1:{server.start()}/metrics")
    # one engine serves every rate (the pool drains between loads); a
    # small warm-up load eats the XLA compiles so the reported
    # percentiles measure scheduling, not compilation
    run_poisson_load(eng, rate=100.0, n_requests=2,
                     prompt_len=args.prompt_len, max_new=2,
                     vocab_size=cfg.vocab_size, seed=7)
    tenants = list(budgets) if budgets else None
    cases = []
    for rate in (float(r) for r in args.rates.split(",")):
        rep = run_poisson_load(eng, rate=rate, n_requests=args.requests,
                               prompt_len=args.prompt_len,
                               max_new=args.max_new,
                               vocab_size=cfg.vocab_size,
                               shared_prefix_len=args.shared_prefix_len,
                               tenants=tenants)
        print(f"[online] rate={rate:g}/s tok/s={rep['tok_s']:.1f} "
              f"ttft p50/p99={rep['ttft_p50_ms']:.0f}/"
              f"{rep['ttft_p99_ms']:.0f}ms itl p50/p99="
              f"{rep['itl_p50_ms']:.1f}/{rep['itl_p99_ms']:.1f}ms "
              f"preempts={rep['preemptions']} shed={rep['shed']} "
              f"acc={rep['acceptance_rate']:.2f} "
              f"ticks/tok={rep['decode_ticks_per_token']:.2f} "
              f"prefix_hit_rate={rep['prefix_hit_rate']:.2f}")
        cases.append(rep)
    out = {
        "bench": "online continuous-batching serving (paged KV)",
        "arch": cfg.arch_id + (" smoke" if args.smoke else ""),
        "command": "PYTHONPATH=src python -m repro.launch.serve --online",
        # report the geometry the engine actually ran, not the raw CLI
        # values (slots/chunk are tp-quantized, n_pages defaulted)
        "engine": {"max_slots": ocfg.max_slots,
                   "page_size": ocfg.page_size,
                   "n_pages": ocfg.pool_pages(),
                   "prefill_chunk": ocfg.prefill_chunk,
                   "max_context": ocfg.max_context,
                   "temperature": ocfg.temperature, "top_p": ocfg.top_p,
                   "top_k": ocfg.top_k, "spec_k": ocfg.spec_k,
                   "drafter": (eng.drafter.name if eng.drafter else None),
                   "radix_cache": ocfg.radix_cache, "policy": ocfg.policy,
                   "max_queue": ocfg.max_queue, "overload": ocfg.overload,
                   "tenant_budgets": budgets,
                   "slo": dataclasses.asdict(slo) if slo else None,
                   "tp": args.tp, "moe_dispatch": args.moe_dispatch,
                   "paged_attn": args.paged_attn},
        "note": ("interpret-mode CPU wall clock - scheduling/latency "
                 "shape, NOT TPU performance"),
        "rates": cases,
    }
    with open(args.report, "w") as f:
        json.dump(out, f, indent=1)
    print(f"[online] report -> {args.report}")
    if args.trace_out:
        n = write_chrome_trace(args.trace_out, timer=eng.timer,
                               request_log=eng.rlog, registry=eng.registry)
        print(f"[online] trace ({n} events) -> {args.trace_out} "
              f"(open at https://ui.perfetto.dev)")
    if server is not None:
        if args.metrics_hold > 0:
            # keep /metrics scrapable after the load drains (CI curls a
            # post-run snapshot; a real deployment would serve forever)
            print(f"[online] holding /metrics for {args.metrics_hold:g}s")
            time.sleep(args.metrics_hold)
        server.stop()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ling-lite")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=4)
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--baseline", action="store_true")
    ap.add_argument("--online", action="store_true",
                    help="continuous-batching engine + Poisson load "
                         "generator (docs/serving.md)")
    ap.add_argument("--slots", type=int, default=4,
                    help="online: decode slots (rounded up to tp)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="online: KV page size in tokens")
    ap.add_argument("--pages", type=int, default=None,
                    help="online: physical page pool size (default: every "
                         "slot can hold a full --seq context)")
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="online: prompt tokens prefix-filled per tick")
    ap.add_argument("--rates", default="4,16",
                    help="online: comma-separated Poisson arrival rates "
                         "(req/s), one load run each")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = exact greedy)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = off)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k truncation (0 = off)")
    ap.add_argument("--seed", type=int, default=0,
                    help="sampling seed base; request rid r draws under "
                         "seed (seed + r) %% 2**31")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="online: speculative draft length per tick "
                         "(0 = off)")
    ap.add_argument("--draft-layers", type=int, default=1,
                    help="online: self-draft drafter depth (first N "
                         "target layers, no new weights)")
    ap.add_argument("--draft-arch", default=None,
                    help="online: use a separate small arch as the "
                         "drafter instead of self-draft (vocab aligned "
                         "via adapt_drafter_config; fresh weights)")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="online: tokens of shared system prompt per "
                         "request (hot-prefix workload; 0 = disjoint "
                         "prompts)")
    ap.add_argument("--policy", default="fcfs",
                    choices=["fcfs", "decode-priority", "prefill-priority"],
                    help="online: tick-ordering policy (decode-priority "
                         "never preempts decoders for arriving prompts; "
                         "prefill-priority drains all prefill chunks "
                         "before decoding to bound head-of-queue TTFT)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="online: bound the arrival queue (saturation "
                         "gate; default unbounded)")
    ap.add_argument("--overload", default="defer",
                    choices=["defer", "shed", "slo"],
                    help="online: full-queue response — 'defer' makes the "
                         "loadgen retry later, 'shed' drops the request "
                         "(counted in the report); 'slo' sheds whenever "
                         "the windowed latency view says admitting would "
                         "breach --slo-ttft-ms/--slo-itl-ms")
    ap.add_argument("--slo-ttft-ms", type=float, default=None,
                    help="online: windowed p99 TTFT deadline for "
                         "--overload slo (milliseconds)")
    ap.add_argument("--slo-itl-ms", type=float, default=None,
                    help="online: optional windowed p99 inter-token "
                         "latency deadline for --overload slo")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="online: serve a Prometheus text scrape at "
                         "http://127.0.0.1:PORT/metrics on a background "
                         "thread (0 = ephemeral port)")
    ap.add_argument("--metrics-hold", type=float, default=0.0,
                    help="online: keep /metrics up for SECONDS after the "
                         "loads finish (lets CI scrape a completed run)")
    ap.add_argument("--trace-out", default=None,
                    help="online: write a Chrome trace-event JSON of the "
                         "run (per-slot + scheduler-phase tracks, counter "
                         "tracks) viewable at https://ui.perfetto.dev")
    ap.add_argument("--no-radix-cache", action="store_true",
                    help="online: disable the content-addressed radix "
                         "prefix cache (on by default; token streams are "
                         "identical either way)")
    ap.add_argument("--tenant-budgets", default=None,
                    help="online: per-tenant admitted-token caps as "
                         "'name:tokens,name:tokens'; the loadgen round-"
                         "robins requests over the named tenants")
    ap.add_argument("--report", default="BENCH_serve_online.json",
                    help="online: where the load report JSON lands")
    ap.add_argument("--tp", type=int, default=1,
                    help="tp mesh width (needs that many jax devices, e.g. "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    ap.add_argument("--moe-dispatch", default="auto",
                    choices=["auto", "fused", "ragged", "batched", "ep"],
                    help="MoE decode dispatch; 'ep' routes decode batches "
                         "over the mesh via the all-to-all expert-parallel "
                         "path (requires microbatch %% tp == 0)")
    ap.add_argument("--paged-attn", default="auto",
                    choices=["auto", "fused", "gathered"],
                    help="online paged-attention backend: 'fused' walks the "
                         "page table inside the Pallas kernel (no gathered "
                         "KV view in HBM), 'gathered' materializes it via "
                         "paged_gather (parity oracle); 'auto' = fused on "
                         "interpret builds, gathered on real TPUs")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_local_mesh(1, args.tp)
    flags = M.RunFlags(moe_dispatch=args.moe_dispatch,
                       paged_attn=args.paged_attn)
    if args.online:
        run_online(cfg, mesh, flags, args)
        return
    rs = np.random.RandomState(0)
    reqs = [GenRequest(rid=i,
                       prompt=rs.randint(0, cfg.vocab_size,
                                         args.prompt_len).astype(np.int32),
                       max_new=args.max_new)
            for i in range(args.requests)]

    micro = quantize_microbatch(args.microbatch, args.tp)
    embed_fn, stage_fns, head_fn = build_model_engine(
        cfg, mesh, args.stages, args.seq, micro, flags=flags,
        temperature=args.temperature, top_p=args.top_p, top_k=args.top_k,
        seed=args.seed)

    if args.baseline:
        stats = baseline_step_engine(head_fn, embed_fn, reqs)
    else:
        eng = FloodEngine(stage_fns, head_fn, embed_fn,
                          cache=SegmentCache(max_tokens=1 << 16,
                                             initial_segment=32,
                                             extend_chunk=32),
                          microbatch=micro, batch_multiple=args.tp)
        eng.submit(reqs)
        stats = eng.run()
        print("cache stats:", eng.cache.stats)
    print(f"tokens={stats.tokens_out} wall={stats.wall_s:.2f}s "
          f"tok/s={stats.tokens_per_s:.1f}")


if __name__ == "__main__":
    main()
