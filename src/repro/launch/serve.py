"""Serving launcher: Flood offline inference over a model's decode step.

    PYTHONPATH=src python -m repro.launch.serve --arch ling-lite --smoke \
        --requests 16 --max-new 16

Builds the model, splits its layers into pipeline stages, and drives the
FloodEngine (segment KV cache, S+1 in-flight micro-batches).  A
`--baseline` flag runs the synchronous global-batch engine instead for the
Table-3-shaped comparison.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.configs.base import get_config, get_smoke_config
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.serving.flood import (FloodEngine, GenRequest,
                                 baseline_step_engine, quantize_microbatch)
from repro.serving.segment_cache import SegmentCache


def build_model_engine(cfg, mesh, n_stages: int, seq_len: int,
                       batch: int, flags: M.RunFlags = M.DEFAULT_FLAGS):
    """Real-model Flood engine: layers split into n_stages jitted chunks.

    Stage state carries (x, caches_slice, pos); decode math is exactly the
    model's block_decode.  `flags.moe_dispatch` selects the MoE decode
    path — with tp > 1 and "ep" the decode batch routes tokens over the
    mesh through the same all-to-all dispatch training uses.
    """
    runner = api.Runner(cfg, mesh, fsdp=False, seq_parallel=False,
                        max_seq=seq_len, flags=flags)
    params = runner.init_params(0)
    decode, _ = runner.make_decode_step(batch, seq_len)
    decode = jax.jit(decode)
    caches = M.init_caches(cfg, runner.env, batch, seq_len,
                           cross_len=cfg.encoder_seq_len)
    state = {"caches": caches, "pos": 0}

    def embed_fn(reqs):
        toks = np.zeros((batch,), np.int32)
        for i, r in enumerate(reqs[:batch]):
            toks[i] = (r.out[-1] if r.out else r.prompt[-1])
        return {"tokens": jnp.asarray(toks), "reqs": len(reqs)}

    def stage_fn(_i):
        def fn(x):
            return x  # layer stages fused into head_fn for the real model
        return fn

    def head_fn(x, reqs):
        nonlocal state
        nxt, state["caches"] = decode(params, state["caches"], x["tokens"],
                                      jnp.int32(state["pos"]))
        state["pos"] += 1
        return np.asarray(nxt)[:len(reqs)]

    return embed_fn, [stage_fn(i) for i in range(n_stages)], head_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ling-lite")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=4)
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--baseline", action="store_true")
    ap.add_argument("--tp", type=int, default=1,
                    help="tp mesh width (needs that many jax devices, e.g. "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    ap.add_argument("--moe-dispatch", default="auto",
                    choices=["auto", "fused", "ragged", "batched", "ep"],
                    help="MoE decode dispatch; 'ep' routes decode batches "
                         "over the mesh via the all-to-all expert-parallel "
                         "path (requires microbatch %% tp == 0)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_local_mesh(1, args.tp)
    flags = M.RunFlags(moe_dispatch=args.moe_dispatch)
    rs = np.random.RandomState(0)
    reqs = [GenRequest(rid=i,
                       prompt=rs.randint(0, cfg.vocab_size,
                                         args.prompt_len).astype(np.int32),
                       max_new=args.max_new)
            for i in range(args.requests)]

    micro = quantize_microbatch(args.microbatch, args.tp)
    embed_fn, stage_fns, head_fn = build_model_engine(
        cfg, mesh, args.stages, args.seq, micro, flags=flags)

    if args.baseline:
        stats = baseline_step_engine(head_fn, embed_fn, reqs)
    else:
        eng = FloodEngine(stage_fns, head_fn, embed_fn,
                          cache=SegmentCache(max_tokens=1 << 16,
                                             initial_segment=32,
                                             extend_chunk=32),
                          microbatch=micro, batch_multiple=args.tp)
        eng.submit(reqs)
        stats = eng.run()
        print("cache stats:", eng.cache.stats)
    print(f"tokens={stats.tokens_out} wall={stats.wall_s:.2f}s "
          f"tok/s={stats.tokens_per_s:.1f}")


if __name__ == "__main__":
    main()
