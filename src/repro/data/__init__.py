"""repro subpackage."""
