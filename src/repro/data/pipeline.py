"""Synthetic pre-training data pipeline (paper §3.1 mechanisms, real code).

The paper's 9T-token corpus is data-gated; what we reproduce is the
*pipeline machinery* it describes, operating on synthetic domain corpora:

  * multi-domain mixture sampling with adjustable weights ("data mixture");
  * quality tiers per domain with tier-weighted selection ("quality
    assessment framework" -> tiered selection);
  * **sample-level online deduplication** during mixing (§3.4.1), via
    content hashing;
  * sequence packing to fixed seq_len with document separators;
  * batch-size warmup (§3.4.1) — `next_macrobatch(accum)` serves the
    engine's scheduled-accumulation warmup at a fixed microbatch shape;
  * a retry lane for spike-affected batches (§3.4.4): saved samples are
    randomly re-injected into subsequent batches, regranulated when the
    warmup stage changed in between.

Each synthetic domain is a distinct Zipfian token distribution with
domain-specific n-gram structure, so mixture weights measurably change the
loss — enough signal for the data-ablation benchmark.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import threading
from collections import deque
from typing import Any, Callable, Deque, Dict, Iterator, List, Optional, \
    Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class DomainSpec:
    name: str
    weight: float
    quality: float = 1.0        # quality tier in [0, 1]
    zipf_a: float = 1.3         # token distribution skew
    seed: int = 0
    doc_len_mean: int = 512


class SyntheticDomain:
    """A stream of documents with a domain-specific token distribution."""

    def __init__(self, spec: DomainSpec, vocab_size: int):
        self.spec = spec
        self.vocab = vocab_size
        self.rng = np.random.RandomState(spec.seed)
        # domain signature: a fixed permutation makes token stats distinct
        self.perm = np.random.RandomState(spec.seed + 9999).permutation(
            vocab_size)

    def next_doc(self) -> np.ndarray:
        n = max(8, int(self.rng.exponential(self.spec.doc_len_mean)))
        # Zipf over a domain-permuted vocabulary + simple bigram structure
        raw = self.rng.zipf(self.spec.zipf_a, size=n)
        toks = self.perm[np.clip(raw, 1, self.vocab - 1)]
        # inject repetition structure (makes LM loss learnable)
        for i in range(2, n, 7):
            toks[i] = toks[i - 2]
        return toks.astype(np.int32)


class DedupFilter:
    """Sample-level online dedup (hash of token content)."""

    def __init__(self, max_entries: int = 1_000_000):
        self.seen: set = set()
        self.max = max_entries
        self.dropped = 0

    def admit(self, tokens: np.ndarray) -> bool:
        h = hashlib.blake2b(tokens.tobytes(), digest_size=8).digest()
        if h in self.seen:
            self.dropped += 1
            return False
        if len(self.seen) < self.max:
            self.seen.add(h)
        return True


@dataclasses.dataclass
class PipelineConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    domains: Sequence[DomainSpec] = ()
    dedup: bool = True
    seed: int = 0
    bos_token: int = 1
    retry_injection_prob: float = 0.25


def default_domains(seed: int = 0) -> List[DomainSpec]:
    return [
        DomainSpec("web", 0.5, quality=0.6, zipf_a=1.25, seed=seed + 1),
        DomainSpec("books", 0.15, quality=0.9, zipf_a=1.4, seed=seed + 2),
        DomainSpec("code", 0.2, quality=0.85, zipf_a=1.15, seed=seed + 3,
                   doc_len_mean=1024),
        DomainSpec("math", 0.1, quality=0.95, zipf_a=1.5, seed=seed + 4),
        DomainSpec("encyclopedia", 0.05, quality=0.9, zipf_a=1.35,
                   seed=seed + 5),
    ]


class DataPipeline:
    """All public methods are safe to call concurrently from the trainer's
    main thread and the `Prefetcher` worker: every mutation of the shared
    stream state (rng, packing buffer, dedup set, retry lane, stats) runs
    under one internal re-entrant lock.  Previously the worker held only
    the *prefetcher's* lock, so a main-thread `push_retry` (spike drain)
    or `state_dict` (non-prefetching checkpoint) raced the producer."""

    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        domains = list(cfg.domains) or default_domains(cfg.seed)
        self.domains = [SyntheticDomain(d, cfg.vocab_size) for d in domains]
        total = sum(d.weight * d.quality for d in domains)
        self.probs = np.array([d.weight * d.quality for d in domains]) / total
        self.rng = np.random.RandomState(cfg.seed)
        self.dedup = DedupFilter() if cfg.dedup else None
        self.buffer = np.zeros((0,), np.int32)
        # retry lane entries are (accum, batch): the accumulation count
        # the batch was packed for, so re-injection can replay at a
        # compatible granularity after a batch-size-warmup stage change
        self.retry_queue: Deque[Tuple[int, Dict[str, np.ndarray]]] = deque()
        self.stats = {"docs": 0, "dedup_dropped": 0, "retry_injected": 0}
        self._lock = threading.RLock()

    def set_mixture(self, weights: Dict[str, float]):
        """Adjust the data mixture live (§3.4.1 'adjustments to the mix')."""
        with self._lock:
            w = np.array([weights.get(d.spec.name, d.spec.weight)
                          * d.spec.quality for d in self.domains])
            self.probs = w / w.sum()

    def _fill(self, n_tokens: int):
        parts = [self.buffer]
        have = len(self.buffer)
        while have < n_tokens:
            di = self.rng.choice(len(self.domains), p=self.probs)
            doc = self.domains[di].next_doc()
            self.stats["docs"] += 1
            if self.dedup is not None and not self.dedup.admit(doc):
                self.stats["dedup_dropped"] += 1
                continue
            parts.append(np.array([self.cfg.bos_token], np.int32))
            parts.append(doc)
            have += len(doc) + 1
        self.buffer = np.concatenate(parts)

    def push_retry(self, batch: Dict[str, np.ndarray],
                   accum_steps: Optional[int] = None):
        """Queue a spike-skipped batch for later re-injection (§3.4.4).
        `accum_steps` is the granularity the batch was packed for;
        omitted, it is inferred from the leading macrobatch dim."""
        if accum_steps is None:
            t = batch["tokens"]
            accum_steps = int(t.shape[0]) if t.ndim == 3 else 1
        with self._lock:
            self.retry_queue.append((int(accum_steps), batch))

    def _pop_retry(self) -> Optional[Tuple[int, Dict[str, np.ndarray]]]:
        if (self.retry_queue
                and self.rng.rand() < self.cfg.retry_injection_prob):
            self.stats["retry_injected"] += 1
            return self.retry_queue.popleft()
        return None

    def _fresh_batch(self, batch_size: Optional[int] = None
                     ) -> Dict[str, np.ndarray]:
        """One freshly-packed (B, S) batch, bypassing the retry lane."""
        B = batch_size or self.cfg.batch_size
        S = self.cfg.seq_len
        need = B * (S + 1)
        self._fill(need)
        flat = self.buffer[:need].reshape(B, S + 1)
        self.buffer = self.buffer[need:]
        return {"tokens": flat[:, :-1].copy(),
                "labels": flat[:, 1:].copy()}

    @staticmethod
    def _split_micro(accum: int, batch: Dict[str, np.ndarray]
                     ) -> List[Dict[str, np.ndarray]]:
        if accum <= 1:
            return [batch]
        return [{k: v[i] for k, v in batch.items()} for i in range(accum)]

    @staticmethod
    def _stack_micro(mbs: List[Dict[str, np.ndarray]]
                     ) -> Dict[str, np.ndarray]:
        return {k: np.stack([m[k] for m in mbs]) for k in mbs[0]}

    def next_batch(self, batch_size: Optional[int] = None
                   ) -> Dict[str, np.ndarray]:
        """(B, S) packed tokens + next-token labels."""
        with self._lock:
            entry = self._pop_retry()
            if entry is not None:
                accum, batch = entry
                if accum <= 1:
                    return batch
                # macrobatch retry replayed at batch granularity: hand out
                # the first microbatch, requeue the remainder
                micros = self._split_micro(accum, batch)
                self._requeue(micros[1:])
                return micros[0]
            return self._fresh_batch(batch_size)

    def _requeue(self, micros: List[Dict[str, np.ndarray]]):
        if not micros:
            return
        if len(micros) == 1:
            self.retry_queue.appendleft((1, micros[0]))
        else:
            self.retry_queue.appendleft((len(micros),
                                         self._stack_micro(micros)))

    def next_macrobatch(self, accum_steps: int = 1) -> Dict[str, np.ndarray]:
        """Batch for one engine step.  ``accum_steps == 1`` is exactly
        `next_batch`; otherwise leaves gain a leading microbatch dim
        ``(accum, B, S)``.  Retry-lane entries remember the accum count
        they were packed for: an exact match replays whole; a mismatch
        (batch-size-warmup stage change between skip and re-injection) is
        regranulated — split into microbatches, topped up with fresh
        data, the overflow requeued — so no stream positions are lost."""
        A = max(1, int(accum_steps))
        if A == 1:
            return self.next_batch()
        with self._lock:
            entry = self._pop_retry()
            if entry is None:
                return self._stack_micro(
                    [self._fresh_batch() for _ in range(A)])
            accum, batch = entry
            if accum == A:
                return batch
            micros = self._split_micro(accum, batch)
            if len(micros) > A:
                self._requeue(micros[A:])
                micros = micros[:A]
            while len(micros) < A:
                micros.append(self._fresh_batch())
            return self._stack_micro(micros)

    # -- checkpoint resume (exact stream continuation) ----------------------
    def state_dict(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "rng": self.rng.get_state(),
                "buffer": self.buffer.copy(),
                "retry_queue": list(self.retry_queue),
                "stats": dict(self.stats),
                "dedup_seen": (set(self.dedup.seen) if self.dedup else None),
                "dedup_dropped": (self.dedup.dropped if self.dedup else 0),
                "domain_rngs": [d.rng.get_state() for d in self.domains],
                "probs": self.probs.copy(),
            }

    def load_state_dict(self, s: Dict[str, Any]):
        with self._lock:
            self.rng.set_state(s["rng"])
            self.buffer = s["buffer"].copy()
            self.retry_queue = deque(s["retry_queue"])
            self.stats = dict(s["stats"])
            if self.dedup is not None and s["dedup_seen"] is not None:
                self.dedup.seen = set(s["dedup_seen"])
                self.dedup.dropped = s["dedup_dropped"]
            for d, st in zip(self.domains, s["domain_rngs"]):
                d.rng.set_state(st)
            self.probs = s["probs"].copy()

    def batches(self, n: int, bs_schedule=None) -> Iterator[Dict]:
        for i in range(n):
            bs = bs_schedule(i) if bs_schedule else None
            yield self.next_batch(bs)


class Prefetcher:
    """Background-thread batch prefetch: host packing for step i+1..i+depth
    runs while the device executes step i (jax dispatch is async, so the
    trainer's `get()` typically returns a ready batch without blocking).

    The producer thread holds `lock` while calling `fn` (which mutates the
    pipeline's rng/buffer), so `snapshot()` can atomically capture
    (pipeline state, queued-but-unconsumed batches) for exact checkpoint
    resume — the queued batches are persisted and re-seeded via `preload`.
    """

    def __init__(self, fn: Callable[[], Dict[str, np.ndarray]],
                 depth: int = 2, preload: Optional[List[Dict]] = None):
        self.fn = fn
        self.lock = threading.Lock()
        self._q: Deque = deque(preload or [])
        self._items = threading.Semaphore(len(self._q))
        self._space = threading.Semaphore(max(0, depth - len(self._q)))
        self._stop = False
        self._error: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            self._space.acquire()
            if self._stop:
                return
            try:
                with self.lock:
                    if self._stop:
                        return
                    b = self.fn()
                    self._q.append(b)
            except BaseException as e:  # noqa: BLE001 — re-raised in get()
                self._error = e
                self._items.release()   # wake the consumer to see it
                return
            self._items.release()

    def get(self) -> Dict[str, np.ndarray]:
        self._items.acquire()
        if self._error is not None:
            self._items.release()   # keep later get() calls failing fast
            raise RuntimeError("prefetch producer failed") from self._error
        with self.lock:
            b = self._q.popleft()
        self._space.release()
        return b

    @contextlib.contextmanager
    def paused(self):
        """Context manager quiescing the producer; yields the queued
        (prefetched but unconsumed) batches.  Call the pipeline's
        `state_dict()` inside the block so checkpointed pipeline state and
        pending batches are mutually consistent."""
        with self.lock:
            yield list(self._q)

    def stop(self):
        """Blocks until the producer thread has fully exited — callers
        (e.g. Trainer.restore) mutate the pipeline right after."""
        # deliberately lock-free: a GIL-atomic bool flip the worker polls;
        # taking self.lock here could deadlock against a producer blocked
        # inside the locked produce section
        self._stop = True          # flopcheck: disable=FC-LOCK
        self._space.release()      # unblock the worker
        self._thread.join()
