"""Synthetic pre-training data pipeline (paper §3.1 mechanisms, real code).

The paper's 9T-token corpus is data-gated; what we reproduce is the
*pipeline machinery* it describes, operating on synthetic domain corpora:

  * multi-domain mixture sampling with adjustable weights ("data mixture");
  * quality tiers per domain with tier-weighted selection ("quality
    assessment framework" -> tiered selection);
  * **sample-level online deduplication** during mixing (§3.4.1), via
    content hashing;
  * sequence packing to fixed seq_len with document separators;
  * batch-size warmup (§3.4.1) — the iterator yields growing batches;
  * a retry lane for spike-affected batches (§3.4.4): saved samples are
    randomly re-injected into subsequent batches.

Each synthetic domain is a distinct Zipfian token distribution with
domain-specific n-gram structure, so mixture weights measurably change the
loss — enough signal for the data-ablation benchmark.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import deque
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class DomainSpec:
    name: str
    weight: float
    quality: float = 1.0        # quality tier in [0, 1]
    zipf_a: float = 1.3         # token distribution skew
    seed: int = 0
    doc_len_mean: int = 512


class SyntheticDomain:
    """A stream of documents with a domain-specific token distribution."""

    def __init__(self, spec: DomainSpec, vocab_size: int):
        self.spec = spec
        self.vocab = vocab_size
        self.rng = np.random.RandomState(spec.seed)
        # domain signature: a fixed permutation makes token stats distinct
        self.perm = np.random.RandomState(spec.seed + 9999).permutation(
            vocab_size)

    def next_doc(self) -> np.ndarray:
        n = max(8, int(self.rng.exponential(self.spec.doc_len_mean)))
        # Zipf over a domain-permuted vocabulary + simple bigram structure
        raw = self.rng.zipf(self.spec.zipf_a, size=n)
        toks = self.perm[np.clip(raw, 1, self.vocab - 1)]
        # inject repetition structure (makes LM loss learnable)
        for i in range(2, n, 7):
            toks[i] = toks[i - 2]
        return toks.astype(np.int32)


class DedupFilter:
    """Sample-level online dedup (hash of token content)."""

    def __init__(self, max_entries: int = 1_000_000):
        self.seen: set = set()
        self.max = max_entries
        self.dropped = 0

    def admit(self, tokens: np.ndarray) -> bool:
        h = hashlib.blake2b(tokens.tobytes(), digest_size=8).digest()
        if h in self.seen:
            self.dropped += 1
            return False
        if len(self.seen) < self.max:
            self.seen.add(h)
        return True


@dataclasses.dataclass
class PipelineConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    domains: Sequence[DomainSpec] = ()
    dedup: bool = True
    seed: int = 0
    bos_token: int = 1
    retry_injection_prob: float = 0.25


def default_domains(seed: int = 0) -> List[DomainSpec]:
    return [
        DomainSpec("web", 0.5, quality=0.6, zipf_a=1.25, seed=seed + 1),
        DomainSpec("books", 0.15, quality=0.9, zipf_a=1.4, seed=seed + 2),
        DomainSpec("code", 0.2, quality=0.85, zipf_a=1.15, seed=seed + 3,
                   doc_len_mean=1024),
        DomainSpec("math", 0.1, quality=0.95, zipf_a=1.5, seed=seed + 4),
        DomainSpec("encyclopedia", 0.05, quality=0.9, zipf_a=1.35,
                   seed=seed + 5),
    ]


class DataPipeline:
    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        domains = list(cfg.domains) or default_domains(cfg.seed)
        self.domains = [SyntheticDomain(d, cfg.vocab_size) for d in domains]
        total = sum(d.weight * d.quality for d in domains)
        self.probs = np.array([d.weight * d.quality for d in domains]) / total
        self.rng = np.random.RandomState(cfg.seed)
        self.dedup = DedupFilter() if cfg.dedup else None
        self.buffer = np.zeros((0,), np.int32)
        self.retry_queue: deque = deque()
        self.stats = {"docs": 0, "dedup_dropped": 0, "retry_injected": 0}

    def set_mixture(self, weights: Dict[str, float]):
        """Adjust the data mixture live (§3.4.1 'adjustments to the mix')."""
        w = np.array([weights.get(d.spec.name, d.spec.weight)
                      * d.spec.quality for d in self.domains])
        self.probs = w / w.sum()

    def _fill(self, n_tokens: int):
        parts = [self.buffer]
        have = len(self.buffer)
        while have < n_tokens:
            di = self.rng.choice(len(self.domains), p=self.probs)
            doc = self.domains[di].next_doc()
            self.stats["docs"] += 1
            if self.dedup is not None and not self.dedup.admit(doc):
                self.stats["dedup_dropped"] += 1
                continue
            parts.append(np.array([self.cfg.bos_token], np.int32))
            parts.append(doc)
            have += len(doc) + 1
        self.buffer = np.concatenate(parts)

    def push_retry(self, batch: Dict[str, np.ndarray]):
        self.retry_queue.append(batch)

    def next_batch(self, batch_size: Optional[int] = None
                   ) -> Dict[str, np.ndarray]:
        """(B, S) packed tokens + next-token labels."""
        if (self.retry_queue
                and self.rng.rand() < self.cfg.retry_injection_prob):
            self.stats["retry_injected"] += 1
            return self.retry_queue.popleft()
        B = batch_size or self.cfg.batch_size
        S = self.cfg.seq_len
        need = B * (S + 1)
        self._fill(need)
        flat = self.buffer[:need].reshape(B, S + 1)
        self.buffer = self.buffer[need:]
        return {"tokens": flat[:, :-1].copy(),
                "labels": flat[:, 1:].copy()}

    def batches(self, n: int, bs_schedule=None) -> Iterator[Dict]:
        for i in range(n):
            bs = bs_schedule(i) if bs_schedule else None
            yield self.next_batch(bs)
