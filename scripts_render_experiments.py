"""Render EXPERIMENTS.md §Dry-run/§Roofline tables from experiments/ JSONs.

    PYTHONPATH=src python scripts_render_experiments.py

Writes the generated tables into EXPERIMENTS.md between the AUTOGEN
markers, preserving hand-written analysis around them.
"""
import glob
import json

ARCHS = ["phi3-mini-3.8b", "rwkv6-3b", "chameleon-34b", "h2o-danube-1.8b",
         "deepseek-moe-16b", "granite-moe-3b-a800m", "moonshot-v1-16b-a3b",
         "whisper-tiny", "recurrentgemma-2b", "nemotron-4-15b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt(s):
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s*1e3:.1f}ms"
    return f"{s*1e6:.0f}us"


def load(dirname):
    recs = {}
    for f in glob.glob(f"experiments/{dirname}/*.json"):
        r = json.load(open(f))
        tag = f.split("__")[-1].replace(".json", "")
        key = (r["arch"], r["shape"], r["mesh"],
               tag if dirname == "perf" else "")
        recs[key] = r
    return recs


def roofline_table():
    recs = load("dryrun")
    lines = ["| arch | shape | mesh | compute | memory | collective | "
             "bottleneck | useful | compile |",
             "|---|---|---|---|---|---|---|---|---|"]
    n_ok = n_skip = 0
    for a in ARCHS:
        for sh in SHAPES:
            for mesh in ("16x16", "2x16x16"):
                r = recs.get((a, sh, mesh, ""))
                if r is None:
                    continue
                if r["status"] == "skipped":
                    if mesh == "16x16":
                        lines.append(f"| {a} | {sh} | both | — | — | — | "
                                     f"*skipped (full attention)* | — | — |")
                        n_skip += 1
                    continue
                if r["status"] != "ok":
                    lines.append(f"| {a} | {sh} | {mesh} | ERROR |||||")
                    continue
                n_ok += 1
                rl = r["roofline"]
                lines.append(
                    f"| {a} | {sh} | {mesh} | {fmt(rl['compute_s'])} | "
                    f"{fmt(rl['memory_s'])} | {fmt(rl['collective_s'])} | "
                    f"{rl['bottleneck']} | {rl['useful_ratio']:.2f} | "
                    f"{r['compile_s']:.0f}s |")
    lines.append("")
    lines.append(f"*{n_ok} (arch × shape × mesh) combinations lowered and "
                 f"compiled; {n_skip} designed long_500k skips "
                 f"(full-attention architectures, run on both meshes).*")
    return "\n".join(lines)


def perf_table():
    recs = load("perf")
    base = load("dryrun")
    lines = ["| pair | iteration | compute | memory | collective | "
             "bottleneck | useful |",
             "|---|---|---|---|---|---|---|"]
    for arch in ("deepseek-moe-16b", "rwkv6-3b", "nemotron-4-15b"):
        b = base.get((arch, "train_4k", "16x16", ""))
        if b and b["status"] == "ok":
            rl = b["roofline"]
            lines.append(
                f"| {arch} × train_4k | **baseline (paper-faithful)** | "
                f"{fmt(rl['compute_s'])} | {fmt(rl['memory_s'])} | "
                f"{fmt(rl['collective_s'])} | {rl['bottleneck']} | "
                f"{rl['useful_ratio']:.2f} |")
        tags = [t for t in ("base_recheck", "it0_full_sched", "it1_batched",
                            "it1_chunk64", "it1_int8sp", "it2_cf125",
                            "it2_chunk256", "it2_bf16gather",
                            "it3_bf16gather", "it3_chunk128_int8",
                            "it4_int8sp")
                if (arch, "train_4k", "16x16", t) in recs]
        for t in tags:
            r = recs[(arch, "train_4k", "16x16", t)]
            if r["status"] != "ok":
                lines.append(f"| {arch} × train_4k | {t} | ERROR |||||")
                continue
            rl = r["roofline"]
            lines.append(
                f"| {arch} × train_4k | {t} | {fmt(rl['compute_s'])} | "
                f"{fmt(rl['memory_s'])} | {fmt(rl['collective_s'])} | "
                f"{rl['bottleneck']} | {rl['useful_ratio']:.2f} |")
    return "\n".join(lines)


def main():
    doc = open("EXPERIMENTS.md").read()
    for marker, content in (("ROOFLINE", roofline_table()),
                            ("PERF", perf_table())):
        start = f"<!-- AUTOGEN:{marker} -->"
        end = f"<!-- /AUTOGEN:{marker} -->"
        i, j = doc.index(start), doc.index(end)
        doc = doc[:i + len(start)] + "\n" + content + "\n" + doc[j:]
    open("EXPERIMENTS.md", "w").write(doc)
    print("rendered")


if __name__ == "__main__":
    main()
