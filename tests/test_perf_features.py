"""Tests for the §Perf optimizations: chunked-parallel WKV6, batched MoE
dispatch, int8 SP communication, bf16 FSDP gathers."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_smoke_config
from repro.core import moe as moe_lib
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.models.rwkv6 import wkv6_chunked, wkv6_scan
from repro.sharding import _quant_rows
from repro import api
from util import smap_env


@pytest.mark.parametrize("chunk,wlo", [(32, 0.9), (64, 0.5), (16, 0.2)])
def test_wkv6_chunked_matches_scan(chunk, wlo):
    rs = np.random.RandomState(0)
    B, T, H, hd = 2, 128, 2, 16
    r = jnp.asarray(rs.randn(B, T, H, hd), jnp.float32)
    k = jnp.asarray(rs.randn(B, T, H, hd) * 0.3, jnp.float32)
    v = jnp.asarray(rs.randn(B, T, H, hd) * 0.3, jnp.float32)
    w = jnp.asarray(rs.uniform(wlo, 0.999, (B, T, H, hd)), jnp.float32)
    u = jnp.asarray(rs.randn(H, hd) * 0.2, jnp.float32)
    s0 = jnp.asarray(rs.randn(B, H, hd, hd) * 0.1, jnp.float32)
    y1, s1 = wkv6_scan(r, k, v, w, u, s0)
    y2, s2 = wkv6_chunked(r, k, v, w, u, s0, chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4,
                               atol=1e-4)


def test_wkv6_chunked_grads():
    rs = np.random.RandomState(1)
    B, T, H, hd = 1, 64, 1, 8
    args = [jnp.asarray(rs.randn(B, T, H, hd) * 0.3, jnp.float32)
            for _ in range(3)]
    w = jnp.asarray(rs.uniform(0.6, 0.99, (B, T, H, hd)), jnp.float32)
    u = jnp.asarray(rs.randn(H, hd) * 0.2, jnp.float32)
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    g1 = jax.grad(lambda *a: wkv6_scan(*a, w, u, s0)[0].sum(),
                  argnums=(0, 1, 2))(*args)
    g2 = jax.grad(lambda *a: wkv6_chunked(*a, w, u, s0, 16)[0].sum(),
                  argnums=(0, 1, 2))(*args)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3,
                                   atol=1e-3)


def test_moe_batched_dispatch_matches_ragged_tp1():
    cfg = get_smoke_config("deepseek-moe-16b")

    def fn(env, x):
        params, _ = moe_lib.init_moe(jax.random.PRNGKey(3), cfg, env)
        y1, _, m1 = moe_lib.moe_ffn(cfg, env, params, x, train=False,
                                    dispatch="ragged")
        y2, _, m2 = moe_lib.moe_ffn(cfg, env, params, x, train=False,
                                    dispatch="batched")
        return (y1.astype(jnp.float32), y2.astype(jnp.float32),
                m1["moe/dropped_frac"], m2["moe/dropped_frac"])

    call, _ = smap_env(fn, out_specs=(P(),) * 4)
    x = jnp.asarray(np.random.RandomState(2).randn(96, cfg.d_model) * 0.3,
                    jnp.float32)
    y1, y2, d1, d2 = call(x)
    assert float(d1) == 0.0
    # batched path uses per-expert capacity: with cf=2 on near-uniform
    # routing nothing drops, so outputs must agree
    assert float(d2) < 0.02, d2
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=0.06,
                               atol=0.06)


def test_int8_quant_roundtrip():
    rs = np.random.RandomState(3)
    x = jnp.asarray(rs.randn(32, 256) * 3.0, jnp.bfloat16)
    q, s = _quant_rows(x)
    deq = q.astype(jnp.float32) * s
    err = np.abs(np.asarray(deq) - np.asarray(x, np.float32))
    scale = np.abs(np.asarray(x, np.float32)).max(axis=1, keepdims=True)
    assert (err <= scale / 127 + 1e-6).all()


def test_rwkv_model_chunk_flag_end_to_end():
    """Full rwkv6 train step with chunked WKV matches the scan version."""
    cfg = get_smoke_config("rwkv6-3b")
    mesh = make_local_mesh(1, 1)
    B, S = 2, 128
    rs = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rs.randint(0, cfg.vocab_size, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rs.randint(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    losses = {}
    for chunk in (0, 32):
        flags = dataclasses.replace(M.DEFAULT_FLAGS, rwkv_chunk=chunk)
        r = api.Runner(cfg, mesh, flags=flags, max_seq=S)
        params = r.init_params(0)
        # one jit per flag config under comparison, two iterations total
        fn = jax.jit(r.make_loss_and_grad(global_batch=B))  # flopcheck: disable=FC-RECOMPILE
        loss, _, _ = fn(params, batch, jnp.int32(10 ** 6),
                        jax.random.PRNGKey(1))
        losses[chunk] = float(loss)  # flopcheck: disable=FC-HOSTSYNC
    assert losses[0] == pytest.approx(losses[32], rel=2e-3), losses
