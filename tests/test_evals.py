"""Evaluation-efficiency subsystem (paper §5.1.2) tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs.base import get_smoke_config
from repro.evals import harness as H
from repro.launch.mesh import make_local_mesh
from repro.models import model as M


def _oracle_score_fn(stride_hint=None):
    """A perfect 'model': scores a continuation by how well it continues
    the arithmetic pattern of the context (no NN needed for unit tests)."""
    def score(seq, mask):
        idx = np.where(mask > 0)[0]
        if len(idx) == 0:
            return 0.0
        # infer stride from the unmasked prefix
        prefix = seq[:idx[0]]
        stride = int(prefix[1] - prefix[0]) if len(prefix) > 1 else 1
        want = (prefix[-1] + stride * (1 + np.arange(len(idx)))) % 512
        return -float(np.sum(seq[idx] != want))
    return score


def test_mc_content_eval_with_oracle():
    items = H.make_mc_dataset(40, vocab=512, seed=0)
    rep = H.ppl_eval_content(items, _oracle_score_fn())
    assert rep["accuracy"] > 0.95
    assert any(k.startswith("ability/") for k in rep)


def test_gen_eval_with_oracle():
    items = H.make_gen_dataset(20, vocab=512)

    def decode(prompt, max_new):
        stride = int(prompt[1] - prompt[0])
        return (prompt[-1] + stride * (1 + np.arange(max_new))) % 512

    rep = H.gen_eval(items, decode, max_new=6)
    assert rep["accuracy"] == 1.0


def test_consistency_and_attribution():
    a = {"accuracy": 0.70, "ability/math": 0.6, "ability/code": 0.8}
    b = {"accuracy": 0.703, "ability/math": 0.597, "ability/code": 0.801}
    c = H.consistency(a, b)
    assert c["mean_abs_deviation"] < 0.005          # paper: <0.5%
    after = {"ability/math": 0.40, "ability/code": 0.79}
    rep = H.attribute_regression(a, after)
    assert rep.regressed_abilities == ["math"]
    assert "math" in rep.suspect_domains


def test_score_fn_against_model():
    """Runner.make_score_fn returns higher scores for model-likely text."""
    cfg = get_smoke_config("phi3-mini-3.8b")
    mesh = make_local_mesh(1, 1)
    runner = api.Runner(cfg, mesh, fsdp=False, seq_parallel=False,
                        max_seq=32)
    params = runner.init_params(0)
    score = jax.jit(runner.make_score_fn(batch_size=2, seq_len=24))

    rs = np.random.RandomState(0)
    toks = jnp.asarray(rs.randint(0, cfg.vocab_size, (2, 24)), jnp.int32)
    mask = jnp.ones((2, 24), jnp.float32)
    out = score(params, toks, mask)
    assert out.shape == (2,)
    assert bool(jnp.all(out < 0))          # log-probs
    # masking fewer positions gives higher (less negative) totals
    mask2 = mask.at[:, 12:].set(0.0)
    out2 = score(params, toks, mask2)
    assert bool(jnp.all(out2 >= out))


def test_content_vs_label_stability_shape():
    """The paper's Fig. 18 claim in miniature: with a weak (early-training)
    scorer, content-based MC accuracy is above chance while label-based
    stays at chance."""
    rs = np.random.RandomState(0)
    items = H.make_mc_dataset(60, vocab=512, seed=3)

    def weak_score(seq, mask):
        # oracle + heavy noise = weak early-training model
        return _oracle_score_fn()(seq, mask) + rs.randn() * 1.0

    content = H.ppl_eval_content(items, weak_score)
    label = H.ppl_eval_label(items, weak_score, label_tokens=[1, 2, 3, 4])
    assert content["accuracy"] > 0.5           # discriminative signal
    assert abs(label["accuracy"] - 0.25) < 0.2  # ~chance
