"""FC-DONATE fixtures: donated-buffer reuse after the donating call."""
import jax

train_step = jax.jit(lambda p, o, b: (p, o), donate_argnums=(0, 1))
maybe_step = jax.jit(lambda p, o, b: (p, o),
                     donate_argnums=(0, 1) if True else ())


def bad_read_after_donate(params, opt, batch):
    new_p, new_o = train_step(params, opt, batch)
    drift = params  # EXPECT: FC-DONATE
    return new_p, new_o, drift


def bad_read_after_donate_ifexp(params, opt, batch):
    new_p, new_o = maybe_step(params, opt, batch)
    return new_p, new_o, opt  # EXPECT: FC-DONATE


def good_rebind(params, opt, batch):
    params, opt = train_step(params, opt, batch)
    return params, opt


def good_fresh_names(params, opt, batches):
    for b in batches:
        params, opt = train_step(params, opt, b)
    return params, opt


def good_non_donated_arg(params, opt, batch):
    new_p, new_o = train_step(params, opt, batch)
    return new_p, new_o, batch         # batch was not donated
