"""FC-HOSTSYNC fixtures: per-step host syncs on jitted-step outputs.

The bad shapes reproduce real history: the per-step metric conversion
PR 3 designed away, and the PR-4 hidden LR sync (`float(sched(i))` in
the Trainer hot loop).
"""
import jax
import jax.numpy as jnp
import numpy as np

step = jax.jit(lambda p, b: (p, {"loss": jnp.sum(b)}))


def bad_float_per_step(params, batches):
    losses = []
    for b in batches:
        params, metrics = step(params, b)
        losses.append(float(metrics["loss"]))  # EXPECT: FC-HOSTSYNC
    return losses


def bad_item_per_step(params, batches):
    out = []
    for b in batches:
        params, metrics = step(params, b)
        out.append(metrics["loss"].item())  # EXPECT: FC-HOSTSYNC
    return out


def bad_asarray_per_step(params, batches):
    toks = []
    for b in batches:
        tok, _ = step(params, b)
        toks.append(np.asarray(tok))  # EXPECT: FC-HOSTSYNC
    return toks


class Trainer:
    """The PR-4 regression: eager LR evaluation in the per-step loop."""

    def __init__(self, sched, step_fn):
        self.sched = sched
        self.step_fn = step_fn
        self.params = None

    def train(self, n_steps):
        for i in range(n_steps):
            lr = float(self.sched(i))  # EXPECT: FC-HOSTSYNC
            self.params = self.step_fn(self.params, lr)

    def train_host_side(self, n_steps):
        for i in range(n_steps):
            lr = float(self.sched.host(i))     # host eval: fine
            self.params = self.step_fn(self.params, lr)


def good_batched_drain(params, batches):
    pending = []
    for b in batches:
        params, metrics = step(params, b)
        pending.append(metrics)                # stays on device
    return [float(m["loss"]) for m in jax.device_get(pending)]


def good_explicit_device_get(params, batches):
    out = []
    for b in batches:
        tok, _ = step(params, b)
        out.append(int(jax.device_get(tok)))   # announced transfer: fine
    return out


def good_outside_loop(params, batch):
    params, metrics = step(params, batch)
    return float(metrics["loss"])              # one-off, not per-step
