"""FC-TELEMETRY fixtures: host clocks and metrics writes inside
jit-traced bodies.

Both run ONCE at trace time: the compiled step replays a baked-in
constant timestamp forever, and the metric object never sees another
update.  The sanctioned pattern times and records on the host AROUND
the jitted call (XPUTimer.span / registry writes after the drain).
"""
import functools
import random
import time
from time import perf_counter

import jax
import jax.numpy as jnp

from repro.telemetry import MetricsRegistry

REG = MetricsRegistry()
HIST = REG.histogram("step_ms", "per-step wall ms")
TOKENS = REG.counter("tokens_total", "tokens emitted")


@jax.jit
def bad_decorated_step(x):
    t0 = time.time()  # EXPECT: FC-TELEMETRY
    return x * t0


@functools.partial(jax.jit, static_argnames=("n",))
def bad_partial_step(x, n):
    HIST.observe(float(n))  # EXPECT: FC-TELEMETRY
    return x + n


def bad_wrapped_step(x):
    dt = perf_counter()  # EXPECT: FC-TELEMETRY
    TOKENS.inc(1)  # EXPECT: FC-TELEMETRY
    return x * dt


bad_handle = jax.jit(bad_wrapped_step)


def make_bad_step(hist):
    def step(params, batch):
        hist.observe(1.0)  # EXPECT: FC-TELEMETRY
        return params

    return step


def good_host_loop(step, x, n_steps):
    """Clocks and metric writes OUTSIDE the traced body: the idiom."""
    for _ in range(n_steps):
        t0 = time.perf_counter()
        x = step(x)
        HIST.observe((time.perf_counter() - t0) * 1e3)
        TOKENS.inc(1)
    return x


@jax.jit
def good_random_sample(key, x):
    # `.sample` on random/np receivers is NOT a metrics write
    idx = random.sample(range(4), 2)
    return x[jnp.asarray(idx)]


def good_untraced_helper(x):
    # never jitted anywhere in this module: host code, clocks are fine
    return x, time.monotonic()
