"""FC-LOCK fixtures: guarded attributes written without the lock.

`Pipeline.set_mixture` reproduces the PR-4 DataPipeline race: a public
method mutating state the rest of the class only touches under its
RLock.
"""
import threading


class Pipeline:
    def __init__(self):
        self._lock = threading.RLock()
        self._q = []
        self._mix = {}
        self.seed = 0                  # never lock-guarded anywhere

    def push(self, item):
        with self._lock:
            self._q.append(item)

    def set_mixture(self, mix):
        self._mix = mix  # EXPECT: FC-LOCK

    def drop(self, item):
        self._q.remove(item)  # EXPECT: FC-LOCK

    def snapshot(self):
        with self._lock:
            return list(self._q), dict(self._mix)

    def set_seed(self, seed):
        self.seed = seed               # unguarded attr: fine

    def _fill(self, item):
        self._q.append(item)           # private helper: assumed locked


class NoLock:
    """No lock attr at all: the rule never applies."""

    def __init__(self):
        self.items = []

    def add(self, x):
        self.items.append(x)
