"""FC-DEPRECATED fixtures: removed jax APIs."""
import functools

import jax


def bad_tree_map(fn, tree):
    return jax.tree_map(fn, tree)  # EXPECT: FC-DEPRECATED


def bad_tree_map_reference(fn):
    return functools.partial(jax.tree_map, fn)  # EXPECT: FC-DEPRECATED


def bad_tree_flatten(tree):
    return jax.tree_flatten(tree)  # EXPECT: FC-DEPRECATED


def good_tree_map(fn, tree):
    return jax.tree.map(fn, tree)


def good_tree_util(fn, tree):
    return jax.tree_util.tree_map(fn, tree)
