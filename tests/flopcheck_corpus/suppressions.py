"""Suppression-syntax fixtures: every violation here is disabled with an
explicit `# flopcheck: disable=` comment (inline and standalone forms),
so the file reports suppressed findings only."""
import jax

step = jax.jit(lambda p, b: (p, b))


def inline_suppressed(params, batches):
    out = []
    for b in batches:
        params, m = step(params, b)
        out.append(float(m))  # flopcheck: disable=FC-HOSTSYNC
    return out


def standalone_suppressed(params, batches):
    out = []
    for b in batches:
        params, m = step(params, b)
        # flopcheck: disable=FC-HOSTSYNC
        out.append(float(m))
    return out


def multi_rule_suppressed(fns, x):
    for f in fns:
        jf = jax.jit(f)  # flopcheck: disable=FC-RECOMPILE,FC-HOSTSYNC
        x = jf(x)
    return x
