"""FC-RECOMPILE fixtures: compile-cache-defeating call patterns."""
import dataclasses
import functools

import jax

matmul = jax.jit(lambda x, block: x, static_argnums=(1,))


@functools.partial(jax.jit, static_argnames=("bm", "bk"))
def tiled(x, bm, bk=8):
    return x


@dataclasses.dataclass
class MutableTile:          # no frozen/__hash__: unhashable instances
    bm: int = 8


@dataclasses.dataclass(frozen=True)
class FrozenTile:
    bm: int = 8


def bad_jit_in_loop(fns, x):
    out = []
    for f in fns:
        jf = jax.jit(f)  # EXPECT: FC-RECOMPILE
        out.append(jf(x))
    return out


def bad_static_list(x):
    return matmul(x, [8, 8])  # EXPECT: FC-RECOMPILE


def bad_static_lambda(x):
    return tiled(x, bm=lambda: 8)  # EXPECT: FC-RECOMPILE


def bad_static_positional_dict(x):
    return tiled(x, {"bm": 8})  # EXPECT: FC-RECOMPILE


def bad_static_dataclass(x):
    return tiled(x, bm=MutableTile())  # EXPECT: FC-RECOMPILE


def good_static_frozen(x):
    return tiled(x, bm=FrozenTile())   # hashable: caches fine


def good_static_scalar(x):
    return tiled(x, bm=128, bk=16)


def good_handle_table(stages):
    # bounded handle table built once, before the hot loop — the repo
    # idiom (StagedTrainStep); comprehensions do not count as loops here
    return {a: jax.jit(lambda x: x) for a in stages}


def good_jit_outside_loop(f, xs):
    jf = jax.jit(f)
    return [jf(x) for x in xs]
