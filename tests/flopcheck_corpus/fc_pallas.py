"""FC-PALLAS fixtures: kernel tracing pitfalls.

`bad_when_kernel` reproduces the PR-1 bug verbatim: `pl.program_id`
read inside a `pl.when` region, where the interpret-mode evaluator does
not substitute it.
"""
import time

import jax
from jax.experimental import pallas as pl


def bad_when_kernel(o_ref):
    @pl.when(pl.program_id(0) == 0)    # condition evaluates outside: fine
    def _():
        k = pl.program_id(2)  # EXPECT: FC-PALLAS
        o_ref[...] = k


def bad_print_kernel(x_ref, o_ref):
    i = pl.program_id(0)
    print("tracing block", i)  # EXPECT: FC-PALLAS
    o_ref[...] = x_ref[...]


def bad_timed_kernel(x_ref, o_ref):
    i = pl.program_id(0)
    t0 = time.time()  # EXPECT: FC-PALLAS
    o_ref[i] = x_ref[i] * t0


def bad_call_no_interpret(x, shape):
    return pl.pallas_call(bad_print_kernel, out_shape=shape)(x)  # EXPECT: FC-PALLAS


def good_kernel(o_ref):
    k = pl.program_id(2)               # read at the top level

    @pl.when(k == 0)
    def _():
        o_ref[...] = k                 # closes over the value: fine


def good_debug_print_kernel(x_ref, o_ref):
    i = pl.program_id(0)
    pl.debug_print("block {}", i)      # the sanctioned debug channel
    o_ref[...] = x_ref[...]


def good_call(x, shape, interpret=False):
    return pl.pallas_call(good_kernel, out_shape=shape,
                          interpret=interpret)(x)
