"""Fused paged-attention kernel (kernels/paged_attn.py): parity sweep
against the gathered jnp oracle (uneven page counts incl. single partial
pages, scratch-page masking with inactive lanes, GQA n_kv < heads, bf16
pools, decode/prefill/verify query shapes), engine-level fused-vs-
gathered greedy token parity at tp=1 (incl. the spec-decode verify
path and the 1-prefill/1-draft/1-verify/1-decode compile contract), the
flag-validation guards, and a tp=2 EP subprocess leg."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs.base import get_smoke_config
from repro.kernels import ops as kops
from repro.launch.mesh import make_local_mesh
from repro.models import layers as L
from repro.models import model as M
from repro.serving.draft import SelfDrafter
from repro.serving.online import OnlineConfig, OnlineEngine, OnlineRequest


# ---------------------------------------------------------------------------
# kernel parity sweep vs the gathered oracle
# ---------------------------------------------------------------------------


def _gathered_ref(q, k_pool, v_pool, table, mask, cdt):
    """tp=1 reference reproducing `_paged_scores_combine`'s gathered
    math exactly: grouped einsum scores, softmax vs the global row max,
    p rounded to the compute dtype for the PV contraction."""
    B, Qn, Hp, hd = q.shape
    n_pages, ps_loc, KV, _ = k_pool.shape
    S_g = table.shape[1] * ps_loc
    g = Hp // KV
    k_g = kops.paged_gather(k_pool, table).reshape(B, S_g, KV, hd)
    v_g = kops.paged_gather(v_pool, table).reshape(B, S_g, KV, hd)
    q_g = q.reshape(B, Qn, KV, g, hd)
    s = jnp.einsum("bqkgd,bskd->bqkgs", q_g, k_g,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    s = s.reshape(B, Qn, Hp, S_g)
    s = jnp.where(mask[:, :, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(mask[:, :, None, :], jnp.exp(s - m_safe[..., None]), 0.0)
    p_g = p.astype(cdt).reshape(B, Qn, KV, g, S_g)
    num = jnp.einsum("bqkgs,bskd->bqkgd", p_g, v_g,
                     preferred_element_type=jnp.float32)
    num = num.reshape(B, Qn, Hp, hd)
    den = jnp.sum(p, axis=-1)
    return num / jnp.maximum(den, 1e-20)[..., None]


def _make_case(rng, *, B, Qn, n_lp, ps_loc, Hp, KV, hd, page_counts,
               dtype, n_pages=None):
    """Random pools/table/mask with page_counts[b] allocated logical
    pages per slot and per-slot query positions placing the last query
    inside the final (possibly partial) page.  The scratch page 0 is
    filled with large garbage so any masking hole shows up loudly."""
    n_pages = n_pages or (1 + sum(page_counts))
    q = jnp.asarray(rng.normal(size=(B, Qn, Hp, hd)), dtype)
    kp = jnp.asarray(rng.normal(size=(n_pages, ps_loc, KV, hd)), dtype)
    vp = jnp.asarray(rng.normal(size=(n_pages, ps_loc, KV, hd)), dtype)
    kp = kp.at[0].set(100.0)
    vp = vp.at[0].set(-100.0)
    table = np.zeros((B, n_lp), np.int32)
    nxt = 1
    for b, c in enumerate(page_counts):
        table[b, :c] = np.arange(nxt, nxt + c)
        nxt += c
    table = jnp.asarray(table)
    # last query lands mid-way through the last allocated page (partial
    # tail page); earlier queries are the preceding positions
    pos = np.zeros((B, Qn), np.int32)
    for b, c in enumerate(page_counts):
        last = max(c, 1) * ps_loc - ps_loc // 2 - 1
        pos[b] = np.maximum(np.arange(last - Qn + 1, last + 1), 0)
    env1 = _Tp1Env()
    valid = L.paged_valid_mask(table, jnp.asarray(pos), page_size=ps_loc,
                               ps_loc=ps_loc, env=env1)
    return q, kp, vp, table, valid


class _Tp1Env:
    """Minimal AxisEnv stand-in for tp=1 mask construction outside
    shard_map."""
    def tp_index(self):
        return jnp.int32(0)


def _fused_out(q, kp, vp, table, mask):
    # the tp=1 compose of the two-pass fused path: max walk, safe max,
    # accumulate walk, normalize (layers._paged_attention_core with the
    # pmax/psum collectives dropping out at tp=1)
    m = kops.paged_attention_scores_max(q, kp, table, mask)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    num, den = kops.paged_attention_accumulate(q, kp, vp, table, mask,
                                               m_safe)
    return num / jnp.maximum(den, 1e-20)[..., None]


CASES = [
    # name, B, Qn, n_lp, ps_loc, Hp, KV, hd, page_counts, dtype, tol
    ("decode_uneven", 4, 1, 5, 8, 8, 8, 16, [5, 1, 3, 2],
     jnp.float32, 1e-5),
    ("decode_single_partial_page", 2, 1, 4, 8, 4, 4, 8, [1, 1],
     jnp.float32, 1e-5),
    ("prefill_chunk", 1, 8, 6, 8, 8, 8, 16, [4], jnp.float32, 1e-5),
    ("verify_k_plus_1", 3, 3, 4, 8, 8, 8, 16, [4, 2, 1],
     jnp.float32, 1e-5),
    ("gqa_grouped", 3, 2, 4, 8, 8, 2, 16, [3, 1, 4], jnp.float32, 1e-5),
    ("bf16_pools", 4, 2, 5, 16, 8, 2, 16, [5, 2, 1, 3],
     jnp.bfloat16, 2e-3),
]


@pytest.mark.parametrize(
    "name,B,Qn,n_lp,ps_loc,Hp,KV,hd,page_counts,dtype,tol", CASES,
    ids=[c[0] for c in CASES])
def test_kernel_matches_gathered_oracle(name, B, Qn, n_lp, ps_loc, Hp, KV,
                                        hd, page_counts, dtype, tol):
    """The fused kernel agrees with the gathered einsum oracle to f32
    summation-order noise — the two-phase max walk plus the
    round-p-at-the-same-point convention make every softmax term match
    the oracle's, so only cross-page accumulation order differs."""
    rng = np.random.default_rng(hash(name) % 2**31)
    q, kp, vp, table, valid = _make_case(
        rng, B=B, Qn=Qn, n_lp=n_lp, ps_loc=ps_loc, Hp=Hp, KV=KV, hd=hd,
        page_counts=page_counts, dtype=dtype)
    out = _fused_out(q, kp, vp, table, valid)
    ref = _gathered_ref(q, kp, vp, table, valid, dtype)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=tol, rtol=tol)


def test_kernel_scratch_page_and_inactive_lanes():
    """Slots whose table is all-zero (inactive lanes parked on the
    scratch page) and fully-masked queries return exact zeros — the
    ±100 garbage planted in page 0 never leaks through the mask."""
    rng = np.random.default_rng(7)
    q, kp, vp, table, valid = _make_case(
        rng, B=3, Qn=2, n_lp=4, ps_loc=8, Hp=8, KV=2, hd=16,
        page_counts=[3, 0, 2], dtype=jnp.float32)
    assert int(jnp.sum(table[1])) == 0          # inactive lane
    assert not bool(jnp.any(valid[1]))
    out = _fused_out(q, kp, vp, table, valid)
    assert np.all(np.isfinite(np.asarray(out)))
    np.testing.assert_array_equal(np.asarray(out[1]), 0.0)
    ref = _gathered_ref(q, kp, vp, table, valid, jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_resolve_and_flag_validation():
    """Unknown modes are rejected at the layer resolver and at the
    Runner's pool-init choke point before any step traces."""
    assert L.resolve_paged_attn("fused") == "fused"
    assert L.resolve_paged_attn("gathered") == "gathered"
    assert L.resolve_paged_attn("auto") in ("fused", "gathered")
    with pytest.raises(ValueError, match="paged_attn"):
        L.resolve_paged_attn("turbo")
    cfg = get_smoke_config("ling-lite")
    runner = api.Runner(cfg, make_local_mesh(1, 1), fsdp=False,
                        seq_parallel=False, max_seq=32,
                        flags=M.RunFlags(paged_attn="turbo"))
    with pytest.raises(ValueError, match="paged_attn"):
        runner.init_paged_pools(8, 16)


# ---------------------------------------------------------------------------
# engine-level fused vs gathered parity (tp=1)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def cfg_params():
    cfg = get_smoke_config("ling-lite")
    runner = api.Runner(cfg, make_local_mesh(1, 1), fsdp=False,
                        seq_parallel=False, max_seq=64)
    return cfg, runner.init_params(0)


def _engine_tokens(cfg, params, prompts, mode, *, spec_k=0, max_new=5):
    runner = api.Runner(cfg, make_local_mesh(1, 1), fsdp=False,
                        seq_parallel=False, max_seq=64,
                        flags=M.RunFlags(paged_attn=mode))
    ocfg = OnlineConfig(max_slots=len(prompts), max_context=64,
                        page_size=16, prefill_chunk=4, spec_k=spec_k)
    drafter = SelfDrafter(draft_layers=1) if spec_k else None
    eng = OnlineEngine(runner, params, ocfg, drafter=drafter)
    eng.submit_many([OnlineRequest(rid=i, prompt=prompts[i],
                                   max_new=max_new)
                     for i in range(len(prompts))])
    eng.run(max_ticks=1000)
    return [list(eng.reqs[i].out) for i in range(len(prompts))], eng


def test_engine_fused_vs_gathered_token_parity(cfg_params):
    """Greedy OnlineEngine streams are identical under
    paged_attn="fused" and "gathered" at tp=1, and the compile-count
    contract (1 prefill + 1 decode) holds for both."""
    cfg, params = cfg_params
    rs = np.random.RandomState(0)
    prompts = [rs.randint(0, cfg.vocab_size, 6).astype(np.int32)
               for _ in range(4)]
    fused, ef = _engine_tokens(cfg, params, prompts, "fused")
    gathered, eg = _engine_tokens(cfg, params, prompts, "gathered")
    assert fused == gathered
    for e in (ef, eg):
        assert e.prefill_traces == 1 and e.decode_traces == 1
    assert ef.paged_attn == "fused" and eg.paged_attn == "gathered"


def test_engine_fused_vs_gathered_spec_decode(cfg_params):
    """The spec-decode verify path (Q=k+1 batched queries) emits the
    same greedy stream fused vs gathered, with 1 draft + 1 verify
    compile each."""
    cfg, params = cfg_params
    rs = np.random.RandomState(1)
    prompts = [rs.randint(0, cfg.vocab_size, 6).astype(np.int32)
               for _ in range(4)]
    fused, ef = _engine_tokens(cfg, params, prompts, "fused", spec_k=2)
    gathered, eg = _engine_tokens(cfg, params, prompts, "gathered",
                                  spec_k=2)
    assert fused == gathered
    for e in (ef, eg):
        assert e.prefill_traces == 1 and e.draft_traces == 1
        assert e.verify_traces == 1


# ---------------------------------------------------------------------------
# tp=2 expert-parallel subprocess leg
# ---------------------------------------------------------------------------


_TP2_FUSED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro import api
    from repro.configs.base import get_smoke_config
    from repro.launch.mesh import make_local_mesh
    from repro.models import model as M
    from repro.serving.draft import SelfDrafter
    from repro.serving.online import (OnlineConfig, OnlineEngine,
                                      OnlineRequest)

    cfg = get_smoke_config("ling-lite")
    mesh = make_local_mesh(1, 2)
    runner = api.Runner(cfg, mesh, fsdp=False, seq_parallel=False,
                        max_seq=32,
                        flags=M.RunFlags(moe_dispatch="ep",
                                         paged_attn="fused"))
    params = runner.init_params(0)
    B, P, NEW, S = 4, 6, 5, 32
    rs = np.random.RandomState(0)
    prompts = rs.randint(0, cfg.vocab_size, (B, P)).astype(np.int32)

    # dense fixed-batch reference (paged_attn only touches paged steps)
    decode, _ = runner.make_decode_step(global_batch=B, seq_len=S)
    decode = jax.jit(decode)
    caches = M.init_caches(cfg, runner.env, B, S,
                           cross_len=cfg.encoder_seq_len)
    tok = None
    for pos in range(P):
        tok, caches = decode(params, caches, jnp.asarray(prompts[:, pos]),
                             jnp.int32(pos))
    ref = [np.asarray(tok)]
    for pos in range(P, P + NEW - 1):
        tok, caches = decode(params, caches, tok, jnp.int32(pos))
        ref.append(np.asarray(tok))
    ref = np.stack(ref, 1)

    # fused paged attention on the tp=2 EP path: the kernel sees each
    # rank's ps_loc page slice and the (num, m, den) partials combine
    # over tp outside — token streams must match the dense path
    eng = OnlineEngine(runner, params,
                       OnlineConfig(max_slots=B, max_context=S,
                                    page_size=8, prefill_chunk=4))
    assert eng.paged_attn == "fused"
    eng.submit_many([OnlineRequest(rid=i, prompt=prompts[i], max_new=NEW)
                     for i in range(B)])
    eng.run(max_ticks=500)
    out = np.stack([np.asarray(eng.reqs[i].out) for i in range(B)])
    np.testing.assert_array_equal(out, ref)
    assert eng.prefill_traces == 1 and eng.decode_traces == 1

    # spec-decode verify (Q=k+1) through the fused kernel on tp=2
    seng = OnlineEngine(runner, params,
                        OnlineConfig(max_slots=B, max_context=S,
                                     page_size=8, prefill_chunk=4,
                                     spec_k=2),
                        drafter=SelfDrafter(draft_layers=1))
    seng.submit_many([OnlineRequest(rid=i, prompt=prompts[i], max_new=NEW)
                      for i in range(B)])
    seng.run(max_ticks=500)
    sout = np.stack([np.asarray(seng.reqs[i].out) for i in range(B)])
    np.testing.assert_array_equal(sout, ref)
    assert seng.draft_traces == 1 and seng.verify_traces == 1
    print("PAGED FUSED TP2 EP PARITY OK")
""")


def test_fused_paged_attn_tp2_ep():
    """2-device leg: online serving token parity with paged_attn="fused"
    on the expert-parallel dispatch path — each rank's kernel walks its
    own ps_loc page slices and the tp combine happens outside."""
    env = dict(os.environ)
    env["PYTHONPATH"] = ("src" + os.pathsep + env.get("PYTHONPATH", "")
                         ).rstrip(os.pathsep)
    res = subprocess.run(
        [sys.executable, "-c", _TP2_FUSED_SCRIPT], capture_output=True,
        text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert res.returncode == 0, res.stdout + res.stderr
    assert "PAGED FUSED TP2 EP PARITY OK" in res.stdout
