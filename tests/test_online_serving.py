"""Online continuous-batching engine: compile-count contract under churn
(admission / completion / preemption / re-admission across >= 3x max_slots
requests with exactly one prefill + one decode XLA compile), token-for-token
greedy parity against the fixed-batch dense decode path (incl. a 2-device
tp=2 EP subprocess case), the EP batch-divisibility guard, and prefix-cache
page sharing."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.analysis.contracts import compile_guard
from repro.configs.base import get_smoke_config
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.serving.online import (OnlineConfig, OnlineEngine, OnlineRequest,
                                  run_poisson_load)


@pytest.fixture(scope="module")
def runner_params():
    cfg = get_smoke_config("ling-lite")
    runner = api.Runner(cfg, make_local_mesh(1, 1), fsdp=False,
                        seq_parallel=False, max_seq=64)
    return runner, runner.init_params(0)


def _dense_greedy(runner, params, prompts: np.ndarray, n_new: int,
                  seq_len: int) -> np.ndarray:
    """Reference: the fixed-batch make_decode_step path, prompt fed
    token-by-token (the contract the online engine must reproduce)."""
    B, P = prompts.shape
    decode, _ = runner.make_decode_step(global_batch=B, seq_len=seq_len)
    decode = jax.jit(decode)
    caches = M.init_caches(runner.cfg, runner.env, B, seq_len,
                           cross_len=runner.cfg.encoder_seq_len)
    tok = None
    for pos in range(P):
        tok, caches = decode(params, caches, jnp.asarray(prompts[:, pos]),
                             jnp.int32(pos))
    out = [tok]
    for pos in range(P, P + n_new - 1):
        tok, caches = decode(params, caches, tok, jnp.int32(pos))
        out.append(tok)    # device until the loop ends (FC-HOSTSYNC)
    return np.stack(jax.device_get(out), 1)       # (B, n_new)


def test_online_matches_fixed_batch_decode(runner_params):
    """Greedy online serving (chunked prefill + paged decode) emits
    token-for-token what the dense fixed-batch path emits — bitwise at
    tp=1 because the page gather reproduces the dense position order."""
    runner, params = runner_params
    B, P, NEW, S = 4, 6, 5, 64
    rs = np.random.RandomState(0)
    prompts = rs.randint(0, runner.cfg.vocab_size, (B, P)).astype(np.int32)
    ref = _dense_greedy(runner, params, prompts, NEW, S)

    # page_size * max_pages == dense seq_len -> identical gathered length
    eng = OnlineEngine(runner, params,
                       OnlineConfig(max_slots=B, max_context=S,
                                    page_size=16, prefill_chunk=4))
    eng.submit_many([OnlineRequest(rid=i, prompt=prompts[i], max_new=NEW)
                     for i in range(B)])
    with compile_guard({"prefill": 1, "decode": 1}, eng.compiles,
                       exact=True):
        eng.run(max_ticks=500)
    out = np.stack([np.asarray(eng.reqs[i].out) for i in range(B)])
    np.testing.assert_array_equal(out, ref)


def test_online_compile_count_under_churn(runner_params):
    """>= 3x max_slots requests with ragged prompts/lengths through a
    pool sized to force preemption: every request completes, pages never
    leak, the run is deterministic, and the engine still compiled exactly
    one prefill and one decode step."""
    runner, params = runner_params
    ocfg = OnlineConfig(max_slots=4, max_context=32, page_size=8,
                        n_pages=7, prefill_chunk=4)

    def drive():
        eng = OnlineEngine(runner, params, ocfg)
        rs = np.random.RandomState(1)
        reqs = [OnlineRequest(
                    rid=i,
                    prompt=rs.randint(0, runner.cfg.vocab_size,
                                      4 + (i % 5)).astype(np.int32),
                    max_new=8 + (i % 9))
                for i in range(13)]                  # > 3 * max_slots
        eng.submit_many(reqs)
        # the 1-prefill/1-decode contract under churn, via the shared
        # contracts layer (raises CompileGuardError on any retrace)
        with compile_guard({"prefill": 1, "decode": 1}, eng.compiles,
                           exact=True):
            eng.run(max_ticks=3000)
        return eng, reqs

    eng, reqs = drive()
    assert eng.n_preemptions > 0, "pool was sized to force preemption"
    for r in reqs:
        assert r.done and len(r.out) == r.max_new, (r.rid, r.state)
    eng.alloc.check_invariants()
    # released pages are *published* into the radix cache, not freed;
    # flushing the cache must hand every page back to the pool
    eng.alloc.flush_radix()
    eng.alloc.check_invariants()
    assert eng.alloc.n_free == eng.alloc.n_pages - eng.alloc.reserved

    # deterministic re-admission order and outputs across identical runs
    eng2, reqs2 = drive()
    assert eng2.admission_log == eng.admission_log
    assert eng2.n_preemptions == eng.n_preemptions
    for a, b in zip(reqs, reqs2):
        assert a.out == b.out, (a.rid, a.out, b.out)


def test_online_prefix_sharing(runner_params):
    """Legacy keyed prefix path (radix_cache=False): a second request
    carrying the prefix key skips prefilling the shared full pages and
    still produces exactly the no-sharing outputs; pages free only once
    the index is dropped."""
    runner, params = runner_params
    S, P, NEW = 64, 16, 4
    rs = np.random.RandomState(3)
    prompt = rs.randint(0, runner.cfg.vocab_size, P).astype(np.int32)
    ocfg = OnlineConfig(max_slots=4, max_context=S, page_size=8,
                        prefill_chunk=8, radix_cache=False)

    eng = OnlineEngine(runner, params, ocfg)
    a = OnlineRequest(rid=0, prompt=prompt, max_new=NEW)
    eng.submit(a)
    # prefill request 0 fully, then publish its prompt as a shared prefix
    while a.state != "decode":
        eng.tick()
    eng.register_prefix(0, "sys", P)
    eng.run(max_ticks=200)

    b = OnlineRequest(rid=1, prompt=prompt, max_new=NEW, prefix_key="sys")
    eng.submit(b)
    eng.run(max_ticks=200)
    assert eng.alloc.stats["prefix_hits"] == 1
    assert b.out == a.out                      # same prompt, greedy decode
    # the shared pages outlive both requests via the index...
    held = len(eng.alloc.prefix_index["sys"])
    assert held == P // ocfg.page_size
    assert (eng.alloc.n_free
            == eng.alloc.n_pages - eng.alloc.reserved - held)
    # ...and return to the pool when dropped
    eng.alloc.drop_prefix("sys")
    eng.alloc.check_invariants()
    assert eng.alloc.n_free == eng.alloc.n_pages - eng.alloc.reserved


def test_online_radix_prefix_sharing(runner_params):
    """Radix twin of the keyed test: NO caller-supplied prefix_key
    anywhere.  The first request's prompt pages are published into the
    content-addressed trie on release; a second request with the same
    prompt attaches them automatically, emits identical greedy output,
    and flushing the cache returns every page to the pool."""
    runner, params = runner_params
    S, P, NEW = 64, 16, 4
    rs = np.random.RandomState(3)
    prompt = rs.randint(0, runner.cfg.vocab_size, P).astype(np.int32)
    eng = OnlineEngine(runner, params,
                       OnlineConfig(max_slots=4, max_context=S,
                                    page_size=8, prefill_chunk=8))

    a = OnlineRequest(rid=0, prompt=prompt, max_new=NEW)
    eng.submit(a)
    eng.run(max_ticks=200)
    assert a.done
    # the full prompt pages are cached (published on prefill completion)
    assert eng.alloc.n_cached_pages >= P // 8

    b = OnlineRequest(rid=1, prompt=prompt, max_new=NEW)
    eng.submit(b)
    eng.run(max_ticks=200)
    assert eng.alloc.stats["prefix_hits"] >= 1
    assert eng.alloc.stats["radix_hit_tokens"] >= P
    assert b.out == a.out                      # same prompt, greedy decode
    eng.alloc.check_invariants()
    eng.alloc.flush_radix()
    eng.alloc.check_invariants()
    assert eng.alloc.n_free == eng.alloc.n_pages - eng.alloc.reserved


def _run_stream(runner, params, reqs_fn, **cfg_kw):
    """Drive a fresh engine over a request stream; return per-rid outputs
    and the engine (for stats)."""
    eng = OnlineEngine(runner, params, OnlineConfig(**cfg_kw))
    reqs = reqs_fn()
    eng.submit_many(reqs)
    eng.run(max_ticks=5000)
    for r in reqs:
        assert r.done, (r.rid, r.state)
    return [list(r.out) for r in reqs], eng


def test_radix_parity_greedy_and_sampled(runner_params):
    """Token-exactness: the same request stream with the radix cache on
    vs off is bitwise identical, under greedy AND seeded sampling (the
    counter-based key schedule depends only on (seed, pos), never on
    which pages held the KV)."""
    runner, params = runner_params
    rs = np.random.RandomState(5)
    sys_prompt = rs.randint(0, runner.cfg.vocab_size, 16).astype(np.int32)

    def make_reqs():
        rs2 = np.random.RandomState(9)
        reqs = []
        for i in range(6):
            tail = rs2.randint(0, runner.cfg.vocab_size,
                               3 + (i % 4)).astype(np.int32)
            prompt = np.concatenate([sys_prompt, tail]) if i % 2 == 0 \
                else tail
            # even rids: greedy; odd rids: seeded sampling
            kw = {} if i % 2 == 0 else dict(temperature=0.8, top_p=0.9,
                                            top_k=40, seed=100 + i)
            reqs.append(OnlineRequest(rid=i, prompt=prompt, max_new=5,
                                      **kw))
        return reqs

    geo = dict(max_slots=4, max_context=64, page_size=8, prefill_chunk=4)
    out_on, eng_on = _run_stream(runner, params, make_reqs,
                                 radix_cache=True, **geo)
    out_off, _ = _run_stream(runner, params, make_reqs,
                             radix_cache=False, **geo)
    assert out_on == out_off
    # the shared system prompt must actually have produced hits
    assert eng_on.alloc.stats["prefix_hits"] >= 1
    assert eng_on.prefill_traces == 1 and eng_on.decode_traces == 1


def test_radix_parity_eviction_reprefill(runner_params):
    """Eviction leg: a pool sized to force LRU eviction and preemption
    mid-stream (cached prefixes get evicted, preempted requests
    re-prefill and re-attach) still yields bitwise-identical tokens with
    the cache on vs off."""
    runner, params = runner_params
    rs = np.random.RandomState(13)
    sys_prompt = rs.randint(0, runner.cfg.vocab_size, 8).astype(np.int32)

    def make_reqs():
        rs2 = np.random.RandomState(21)
        reqs = []
        for i in range(13):
            tail = rs2.randint(0, runner.cfg.vocab_size,
                               1 + (i % 5)).astype(np.int32)
            prompt = np.concatenate([sys_prompt, tail]) if i % 3 else tail
            kw = {} if i % 2 == 0 else dict(temperature=0.7, seed=i)
            reqs.append(OnlineRequest(rid=i, prompt=prompt,
                                      max_new=6 + (i % 7), **kw))
        return reqs

    geo = dict(max_slots=4, max_context=32, page_size=8, n_pages=7,
               prefill_chunk=4)
    out_on, eng_on = _run_stream(runner, params, make_reqs,
                                 radix_cache=True, **geo)
    out_off, eng_off = _run_stream(runner, params, make_reqs,
                                   radix_cache=False, **geo)
    assert out_on == out_off
    # the tight pool must actually have exercised the eviction sweep —
    # caching never causes an OOM, it just gets swept when space is tight
    assert eng_on.alloc.stats["evictions"] > 0
    assert eng_on.prefill_traces == 1 and eng_on.decode_traces == 1
    eng_on.alloc.check_invariants()


def test_legacy_same_key_racer_regression(runner_params):
    """Legacy keyed path regression (the bug the radix cache fixes): two
    same-key requests racing through prefill — only the first finisher
    publishes; the second's identical pages must stay private and
    recycle on its release (no leak, no double-registration), and a
    later keyed request still hits the published copy."""
    runner, params = runner_params
    S, P = 64, 16
    rs = np.random.RandomState(3)
    prompt = rs.randint(0, runner.cfg.vocab_size, P).astype(np.int32)
    eng = OnlineEngine(runner, params,
                       OnlineConfig(max_slots=4, max_context=S,
                                    page_size=8, prefill_chunk=8,
                                    radix_cache=False))
    # both admitted before either finishes prefill: neither hits at
    # admission (index empty), both race to the publish point
    eng.submit_many([OnlineRequest(rid=0, prompt=prompt, max_new=3,
                                   prefix_key="sys", prefix_len=P),
                     OnlineRequest(rid=1, prompt=prompt, max_new=3,
                                   prefix_key="sys", prefix_len=P)])
    eng.run(max_ticks=300)
    assert eng.alloc.stats["prefix_hits"] == 0
    held = len(eng.alloc.prefix_index["sys"])
    assert held == P // 8                      # registered exactly once
    # the loser's duplicate pages recycled on release — only the
    # published copy outlives the pair
    eng.alloc.check_invariants()
    assert eng.alloc.n_free == eng.alloc.n_pages - eng.alloc.reserved - held

    c = OnlineRequest(rid=2, prompt=prompt, max_new=3, prefix_key="sys",
                      prefix_len=P)
    eng.submit(c)
    eng.run(max_ticks=200)
    assert eng.alloc.stats["prefix_hits"] == 1
    eng.alloc.drop_prefix("sys")
    assert eng.alloc.n_free == eng.alloc.n_pages - eng.alloc.reserved


def test_radix_same_prefix_racer_dedupes(runner_params):
    """Radix counterpart: two same-prompt racers both publish on prefill
    completion; content addressing keeps exactly one cached copy (the
    dedups stat counts the collision) and the invariant checker proves
    no page is cached at two nodes."""
    runner, params = runner_params
    S, P = 64, 16
    rs = np.random.RandomState(3)
    prompt = rs.randint(0, runner.cfg.vocab_size, P).astype(np.int32)
    eng = OnlineEngine(runner, params,
                       OnlineConfig(max_slots=4, max_context=S,
                                    page_size=8, prefill_chunk=8))
    eng.submit_many([OnlineRequest(rid=0, prompt=prompt, max_new=3),
                     OnlineRequest(rid=1, prompt=prompt, max_new=3)])
    eng.run(max_ticks=300)
    assert eng.alloc.stats["dedups"] > 0
    eng.alloc.check_invariants()
    eng.alloc.flush_radix()
    assert eng.alloc.n_free == eng.alloc.n_pages - eng.alloc.reserved


def test_loadgen_report_fields_pinned(runner_params):
    """The loadgen report schema is an interface (benchmarks, the serve
    CLI and CI dashboards key into it): pin the churn-counter fields to
    exact values on a deterministic burst-arrival run, and pin that the
    deterministic subset reproduces across identical runs."""
    runner, params = runner_params

    def load():
        # rate=1e9 -> the whole arrival schedule spans ~6ns, far below
        # the loop's own perf_counter overhead, so all 6 requests are
        # already due at the first clock read and submit in one burst
        # before any tick: the bounded queue (2) sheds exactly 4
        eng = OnlineEngine(runner, params,
                           OnlineConfig(max_slots=2, max_context=32,
                                        page_size=8, prefill_chunk=4,
                                        max_queue=2, overload="shed"))
        return run_poisson_load(eng, rate=1e9, n_requests=6, prompt_len=8,
                                max_new=4, vocab_size=runner.cfg.vocab_size,
                                seed=11)

    rep = load()
    expected_keys = {
        "rate_req_s", "n_requests", "prompt_len", "max_new", "policy",
        "radix_cache", "paged_attn", "wall_s", "tokens_out", "tok_s",
        "ttft_p50_ms", "ttft_p99_ms", "itl_p50_ms", "itl_p99_ms",
        "ticks", "preemptions", "shed", "budget_skips",
        "prefill_compiles", "decode_compiles", "draft_compiles",
        "verify_compiles", "shared_prefix_len", "prefix_hits",
        "prefix_hit_rate", "prefix_hit_tokens", "cache_evictions",
        "spec_k", "acceptance_rate", "decode_ticks_per_token",
        "allocator", "overload", "slo",
    }
    assert expected_keys <= set(rep), expected_keys - set(rep)

    assert rep["shed"] == 4                    # 6 arrivals, queue holds 2
    assert rep["tokens_out"] == 2 * 4          # the 2 admitted complete
    assert rep["budget_skips"] == 0
    assert rep["preemptions"] == 0
    assert rep["prefix_hit_tokens"] == 0       # no shared prefix
    assert rep["cache_evictions"] == 0
    assert rep["acceptance_rate"] == 0.0       # spec off: nothing proposed
    # every post-first token rides exactly one decode tick when spec is off
    assert rep["decode_ticks_per_token"] == 1.0
    assert rep["overload"] == "shed"
    assert rep["slo"] is None                  # populated only under "slo"
    assert rep["ttft_p99_ms"] > 0 and rep["tok_s"] > 0

    # the wall-clock-free subset is bit-identical across identical runs
    rep2 = load()
    pinned = ("n_requests", "tokens_out", "shed", "budget_skips",
              "preemptions", "prefix_hit_tokens", "cache_evictions",
              "acceptance_rate", "decode_ticks_per_token", "overload",
              "slo", "prefill_compiles", "decode_compiles")
    assert {k: rep[k] for k in pinned} == {k: rep2[k] for k in pinned}


def test_online_rejects_unpageable_arch():
    cfg = get_smoke_config("rwkv6-3b")
    runner = api.Runner(cfg, make_local_mesh(1, 1), fsdp=False,
                        seq_parallel=False, max_seq=32)
    with pytest.raises(ValueError, match="all-'attn'"):
        OnlineEngine(runner, None, OnlineConfig(max_slots=2,
                                                max_context=32))


_TP2_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro import api
    from repro.analysis.contracts import compile_guard
    from repro.configs.base import get_smoke_config
    from repro.launch.mesh import make_local_mesh
    from repro.models import model as M
    from repro.serving.online import (OnlineConfig, OnlineEngine,
                                      OnlineRequest)

    cfg = get_smoke_config("ling-lite")
    mesh = make_local_mesh(1, 2)
    runner = api.Runner(cfg, mesh, fsdp=False, seq_parallel=False,
                        max_seq=32, flags=M.RunFlags(moe_dispatch="ep"))
    params = runner.init_params(0)
    B, P, NEW, S = 4, 6, 5, 32
    rs = np.random.RandomState(0)
    prompts = rs.randint(0, cfg.vocab_size, (B, P)).astype(np.int32)

    decode, _ = runner.make_decode_step(global_batch=B, seq_len=S)
    decode = jax.jit(decode)
    caches = M.init_caches(cfg, runner.env, B, S,
                           cross_len=cfg.encoder_seq_len)
    tok = None
    for pos in range(P):
        tok, caches = decode(params, caches, jnp.asarray(prompts[:, pos]),
                             jnp.int32(pos))
    ref = [np.asarray(tok)]
    for pos in range(P, P + NEW - 1):
        tok, caches = decode(params, caches, tok, jnp.int32(pos))
        ref.append(np.asarray(tok))
    ref = np.stack(ref, 1)

    eng = OnlineEngine(runner, params,
                       OnlineConfig(max_slots=B, max_context=S,
                                    page_size=8, prefill_chunk=4))
    # explicit temperature=0.0 must ride the sampled step and still be
    # bitwise greedy on the tp=2 EP path
    eng.submit_many([OnlineRequest(rid=i, prompt=prompts[i], max_new=NEW,
                                   temperature=0.0, seed=i)
                     for i in range(B)])
    with compile_guard({"prefill": 1, "decode": 1}, eng.compiles,
                       exact=True):
        eng.run(max_ticks=500)
    out = np.stack([np.asarray(eng.reqs[i].out) for i in range(B)])
    np.testing.assert_array_equal(out, ref)

    # speculative decoding on tp=2: the B*(k+1)-token verify batch rides
    # the same EP dispatch; greedy spec output stays token-exact
    from repro.serving.draft import SelfDrafter
    seng = OnlineEngine(runner, params,
                        OnlineConfig(max_slots=B, max_context=S,
                                     page_size=8, prefill_chunk=4,
                                     spec_k=2),
                        drafter=SelfDrafter(draft_layers=1))
    seng.submit_many([OnlineRequest(rid=i, prompt=prompts[i], max_new=NEW)
                      for i in range(B)])
    with compile_guard({"draft": 1, "verify": 1}, seng.compiles,
                       exact=True):
        seng.run(max_ticks=500)
    sout = np.stack([np.asarray(seng.reqs[i].out) for i in range(B)])
    np.testing.assert_array_equal(sout, ref)

    # radix prefix cache on the tp=2 EP path: a stream sharing a full
    # page of prompt is bitwise identical with the cache on vs off, and
    # the cache actually hits (pages are split across tp ranks; the
    # trie only tracks page ids, so sharding is invisible to it)
    shared = rs.randint(0, cfg.vocab_size, 8).astype(np.int32)
    def radix_stream(radix):
        # 2 slots so the second wave admits AFTER the first wave
        # publishes -> real cross-request hits
        e = OnlineEngine(runner, params,
                         OnlineConfig(max_slots=2, max_context=S,
                                      page_size=8, prefill_chunk=4,
                                      radix_cache=radix))
        rr = [OnlineRequest(rid=i, prompt=np.concatenate(
                  [shared, rs2.randint(0, cfg.vocab_size, 2
                                       ).astype(np.int32)]),
                  max_new=4)
              for i in range(B)]
        e.submit_many(rr)
        e.run(max_ticks=500)
        return [list(r.out) for r in rr], e
    rs2 = np.random.RandomState(17)
    on_out, on_eng = radix_stream(True)
    rs2 = np.random.RandomState(17)
    off_out, _ = radix_stream(False)
    assert on_out == off_out, "radix on/off diverged on tp=2 EP"
    assert on_eng.alloc.stats["prefix_hits"] >= 1

    # EP decode-batch constraint: max_slots % tp != 0 must be rejected
    try:
        OnlineEngine(runner, params,
                     OnlineConfig(max_slots=3, max_context=32, page_size=8))
        raise SystemExit("expected ValueError for max_slots=3 on tp=2")
    except ValueError as e:
        assert "quantize_microbatch" in str(e), e
    # ...and so must a page size the tp ranks cannot split
    try:
        OnlineEngine(runner, params,
                     OnlineConfig(max_slots=4, max_context=32, page_size=9))
        raise SystemExit("expected ValueError for page_size=9 on tp=2")
    except ValueError as e:
        assert "page_size" in str(e), e
    print("ONLINE TP2 EP PARITY OK")
""")


def test_online_parity_tp2_ep():
    """2-device case: online engine vs dense fixed-batch decode, both on
    the expert-parallel all-to-all MoE dispatch path, plus the EP
    divisibility guards (quantize_microbatch contract)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = ("src" + os.pathsep + env.get("PYTHONPATH", "")
                         ).rstrip(os.pathsep)
    res = subprocess.run(
        [sys.executable, "-c", _TP2_SCRIPT], capture_output=True,
        text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert res.returncode == 0, res.stdout + res.stderr
    assert "ONLINE TP2 EP PARITY OK" in res.stdout
