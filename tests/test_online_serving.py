"""Online continuous-batching engine: compile-count contract under churn
(admission / completion / preemption / re-admission across >= 3x max_slots
requests with exactly one prefill + one decode XLA compile), token-for-token
greedy parity against the fixed-batch dense decode path (incl. a 2-device
tp=2 EP subprocess case), the EP batch-divisibility guard, and prefix-cache
page sharing."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs.base import get_smoke_config
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.serving.online import OnlineConfig, OnlineEngine, OnlineRequest


@pytest.fixture(scope="module")
def runner_params():
    cfg = get_smoke_config("ling-lite")
    runner = api.Runner(cfg, make_local_mesh(1, 1), fsdp=False,
                        seq_parallel=False, max_seq=64)
    return runner, runner.init_params(0)


def _dense_greedy(runner, params, prompts: np.ndarray, n_new: int,
                  seq_len: int) -> np.ndarray:
    """Reference: the fixed-batch make_decode_step path, prompt fed
    token-by-token (the contract the online engine must reproduce)."""
    B, P = prompts.shape
    decode, _ = runner.make_decode_step(global_batch=B, seq_len=seq_len)
    decode = jax.jit(decode)
    caches = M.init_caches(runner.cfg, runner.env, B, seq_len,
                           cross_len=runner.cfg.encoder_seq_len)
    tok = None
    for pos in range(P):
        tok, caches = decode(params, caches, jnp.asarray(prompts[:, pos]),
                             jnp.int32(pos))
    out = [np.asarray(tok)]
    for pos in range(P, P + n_new - 1):
        tok, caches = decode(params, caches, tok, jnp.int32(pos))
        out.append(np.asarray(tok))
    return np.stack(out, 1)                       # (B, n_new)


def test_online_matches_fixed_batch_decode(runner_params):
    """Greedy online serving (chunked prefill + paged decode) emits
    token-for-token what the dense fixed-batch path emits — bitwise at
    tp=1 because the page gather reproduces the dense position order."""
    runner, params = runner_params
    B, P, NEW, S = 4, 6, 5, 64
    rs = np.random.RandomState(0)
    prompts = rs.randint(0, runner.cfg.vocab_size, (B, P)).astype(np.int32)
    ref = _dense_greedy(runner, params, prompts, NEW, S)

    # page_size * max_pages == dense seq_len -> identical gathered length
    eng = OnlineEngine(runner, params,
                       OnlineConfig(max_slots=B, max_context=S,
                                    page_size=16, prefill_chunk=4))
    eng.submit_many([OnlineRequest(rid=i, prompt=prompts[i], max_new=NEW)
                     for i in range(B)])
    eng.run(max_ticks=500)
    out = np.stack([np.asarray(eng.reqs[i].out) for i in range(B)])
    np.testing.assert_array_equal(out, ref)
    assert eng.prefill_traces == 1 and eng.decode_traces == 1


def test_online_compile_count_under_churn(runner_params):
    """>= 3x max_slots requests with ragged prompts/lengths through a
    pool sized to force preemption: every request completes, pages never
    leak, the run is deterministic, and the engine still compiled exactly
    one prefill and one decode step."""
    runner, params = runner_params
    ocfg = OnlineConfig(max_slots=4, max_context=32, page_size=8,
                        n_pages=7, prefill_chunk=4)

    def drive():
        eng = OnlineEngine(runner, params, ocfg)
        rs = np.random.RandomState(1)
        reqs = [OnlineRequest(
                    rid=i,
                    prompt=rs.randint(0, runner.cfg.vocab_size,
                                      4 + (i % 5)).astype(np.int32),
                    max_new=8 + (i % 9))
                for i in range(13)]                  # > 3 * max_slots
        eng.submit_many(reqs)
        eng.run(max_ticks=3000)
        return eng, reqs

    eng, reqs = drive()
    assert eng.prefill_traces == 1, eng.prefill_traces
    assert eng.decode_traces == 1, eng.decode_traces
    assert eng.n_preemptions > 0, "pool was sized to force preemption"
    for r in reqs:
        assert r.done and len(r.out) == r.max_new, (r.rid, r.state)
    eng.alloc.check_invariants()
    assert eng.alloc.n_free == eng.alloc.n_pages - eng.alloc.reserved

    # deterministic re-admission order and outputs across identical runs
    eng2, reqs2 = drive()
    assert eng2.admission_log == eng.admission_log
    assert eng2.n_preemptions == eng.n_preemptions
    for a, b in zip(reqs, reqs2):
        assert a.out == b.out, (a.rid, a.out, b.out)


def test_online_prefix_sharing(runner_params):
    """Refcounted prefix pages: a second request carrying the prefix key
    skips prefilling the shared full pages and still produces exactly the
    no-sharing outputs; pages free only once the index is dropped."""
    runner, params = runner_params
    S, P, NEW = 64, 16, 4
    rs = np.random.RandomState(3)
    prompt = rs.randint(0, runner.cfg.vocab_size, P).astype(np.int32)
    ocfg = OnlineConfig(max_slots=4, max_context=S, page_size=8,
                        prefill_chunk=8)

    eng = OnlineEngine(runner, params, ocfg)
    a = OnlineRequest(rid=0, prompt=prompt, max_new=NEW)
    eng.submit(a)
    # prefill request 0 fully, then publish its prompt as a shared prefix
    while a.state != "decode":
        eng.tick()
    eng.register_prefix(0, "sys", P)
    eng.run(max_ticks=200)

    b = OnlineRequest(rid=1, prompt=prompt, max_new=NEW, prefix_key="sys")
    eng.submit(b)
    eng.run(max_ticks=200)
    assert eng.alloc.stats["prefix_hits"] == 1
    assert b.out == a.out                      # same prompt, greedy decode
    # the shared pages outlive both requests via the index...
    held = len(eng.alloc.prefix_index["sys"])
    assert held == P // ocfg.page_size
    assert (eng.alloc.n_free
            == eng.alloc.n_pages - eng.alloc.reserved - held)
    # ...and return to the pool when dropped
    eng.alloc.drop_prefix("sys")
    eng.alloc.check_invariants()
    assert eng.alloc.n_free == eng.alloc.n_pages - eng.alloc.reserved


def test_online_rejects_unpageable_arch():
    cfg = get_smoke_config("rwkv6-3b")
    runner = api.Runner(cfg, make_local_mesh(1, 1), fsdp=False,
                        seq_parallel=False, max_seq=32)
    with pytest.raises(ValueError, match="all-'attn'"):
        OnlineEngine(runner, None, OnlineConfig(max_slots=2,
                                                max_context=32))


_TP2_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro import api
    from repro.configs.base import get_smoke_config
    from repro.launch.mesh import make_local_mesh
    from repro.models import model as M
    from repro.serving.online import (OnlineConfig, OnlineEngine,
                                      OnlineRequest)

    cfg = get_smoke_config("ling-lite")
    mesh = make_local_mesh(1, 2)
    runner = api.Runner(cfg, mesh, fsdp=False, seq_parallel=False,
                        max_seq=32, flags=M.RunFlags(moe_dispatch="ep"))
    params = runner.init_params(0)
    B, P, NEW, S = 4, 6, 5, 32
    rs = np.random.RandomState(0)
    prompts = rs.randint(0, cfg.vocab_size, (B, P)).astype(np.int32)

    decode, _ = runner.make_decode_step(global_batch=B, seq_len=S)
    decode = jax.jit(decode)
    caches = M.init_caches(cfg, runner.env, B, S,
                           cross_len=cfg.encoder_seq_len)
    tok = None
    for pos in range(P):
        tok, caches = decode(params, caches, jnp.asarray(prompts[:, pos]),
                             jnp.int32(pos))
    ref = [np.asarray(tok)]
    for pos in range(P, P + NEW - 1):
        tok, caches = decode(params, caches, tok, jnp.int32(pos))
        ref.append(np.asarray(tok))
    ref = np.stack(ref, 1)

    eng = OnlineEngine(runner, params,
                       OnlineConfig(max_slots=B, max_context=S,
                                    page_size=8, prefill_chunk=4))
    # explicit temperature=0.0 must ride the sampled step and still be
    # bitwise greedy on the tp=2 EP path
    eng.submit_many([OnlineRequest(rid=i, prompt=prompts[i], max_new=NEW,
                                   temperature=0.0, seed=i)
                     for i in range(B)])
    eng.run(max_ticks=500)
    out = np.stack([np.asarray(eng.reqs[i].out) for i in range(B)])
    np.testing.assert_array_equal(out, ref)
    assert eng.prefill_traces == 1 and eng.decode_traces == 1

    # speculative decoding on tp=2: the B*(k+1)-token verify batch rides
    # the same EP dispatch; greedy spec output stays token-exact
    from repro.serving.draft import SelfDrafter
    seng = OnlineEngine(runner, params,
                        OnlineConfig(max_slots=B, max_context=S,
                                     page_size=8, prefill_chunk=4,
                                     spec_k=2),
                        drafter=SelfDrafter(draft_layers=1))
    seng.submit_many([OnlineRequest(rid=i, prompt=prompts[i], max_new=NEW)
                      for i in range(B)])
    seng.run(max_ticks=500)
    sout = np.stack([np.asarray(seng.reqs[i].out) for i in range(B)])
    np.testing.assert_array_equal(sout, ref)
    assert seng.draft_traces == 1 and seng.verify_traces == 1

    # EP decode-batch constraint: max_slots % tp != 0 must be rejected
    try:
        OnlineEngine(runner, params,
                     OnlineConfig(max_slots=3, max_context=32, page_size=8))
        raise SystemExit("expected ValueError for max_slots=3 on tp=2")
    except ValueError as e:
        assert "quantize_microbatch" in str(e), e
    # ...and so must a page size the tp ranks cannot split
    try:
        OnlineEngine(runner, params,
                     OnlineConfig(max_slots=4, max_context=32, page_size=9))
        raise SystemExit("expected ValueError for page_size=9 on tp=2")
    except ValueError as e:
        assert "page_size" in str(e), e
    print("ONLINE TP2 EP PARITY OK")
""")


def test_online_parity_tp2_ep():
    """2-device case: online engine vs dense fixed-batch decode, both on
    the expert-parallel all-to-all MoE dispatch path, plus the EP
    divisibility guards (quantize_microbatch contract)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = ("src" + os.pathsep + env.get("PYTHONPATH", "")
                         ).rstrip(os.pathsep)
    res = subprocess.run(
        [sys.executable, "-c", _TP2_SCRIPT], capture_output=True,
        text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert res.returncode == 0, res.stdout + res.stderr
    assert "ONLINE TP2 EP PARITY OK" in res.stdout
