"""Training-stack tests: AdamW, schedules, spike handling, EDiT math,
data pipeline (dedup/mixture/packing), trainer integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from util import given, settings, st   # hypothesis, or a skip shim

from repro.core.edit import (EDiTConfig, edit_sync, init_ema,
                             init_outer_momentum, simulate_sync_timeline)
from repro.core.spikes import SpikeConfig, SpikeDetector
from repro.data.pipeline import DataPipeline, PipelineConfig
from repro.optim import adamw
from repro.optim.schedule import BatchSizeWarmup, InvSqrtAnnealing, WSDSchedule


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def test_adamw_matches_reference():
    """One AdamW step vs a hand-rolled numpy reference."""
    rs = np.random.RandomState(0)
    p = {"w": jnp.asarray(rs.randn(4, 3), jnp.float32)}
    g = {"w": jnp.asarray(rs.randn(4, 3), jnp.float32)}
    st_ = adamw.init_opt_state(p)
    cfg = adamw.AdamWConfig(weight_decay=0.1)
    newp, st2 = adamw.apply_updates(p, g, st_, jnp.float32(1e-2), cfg)

    gw = np.asarray(g["w"])
    m = 0.1 * gw
    v = 0.05 * gw * gw
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.95)
    want = np.asarray(p["w"]) - 1e-2 * (
        mhat / (np.sqrt(vhat) + 1e-8) + 0.1 * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(newp["w"]), want, rtol=1e-5)
    assert int(st2["count"]) == 1


def test_adamw_converges_quadratic():
    p = {"w": jnp.ones((8,), jnp.float32) * 5}
    st_ = adamw.init_opt_state(p)
    cfg = adamw.AdamWConfig(weight_decay=0.0)
    for _ in range(300):
        g = {"w": 2 * p["w"]}
        p, st_ = adamw.apply_updates(p, g, st_, jnp.float32(0.05), cfg)
    assert float(jnp.abs(p["w"]).max()) < 0.1


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def test_wsd_schedule():
    s = WSDSchedule(max_lr=1e-3, warmup_steps=100, halve_frac=0.6,
                    total_steps=1000)
    assert float(s(0)) == 0.0
    assert float(s(50)) == pytest.approx(5e-4)
    assert float(s(100)) == pytest.approx(1e-3)
    assert float(s(500)) == pytest.approx(1e-3)      # stable
    assert float(s(700)) == pytest.approx(5e-4)      # halved at 60%


def test_annealing_endpoints():
    s = InvSqrtAnnealing(lr_start=1.2e-4, lr_end=1.2e-8, steps=1000)
    assert float(s(0)) == pytest.approx(1.2e-4)
    assert float(s(1000)) == pytest.approx(1.2e-8, rel=0.01)
    lrs = [float(s(t)) for t in range(0, 1001, 100)]
    assert all(a >= b for a, b in zip(lrs, lrs[1:]))   # monotone


def test_batch_warmup():
    b = BatchSizeWarmup(start=2560, end=8960, warmup_steps=100)
    assert b(0) == 2560
    assert b(100) == 8960
    sizes = [b(i) for i in range(0, 101, 10)]
    assert all(x <= y for x, y in zip(sizes, sizes[1:]))
    assert all(s % 256 == 0 for s in sizes)


# ---------------------------------------------------------------------------
# spikes (§3.4.4)
# ---------------------------------------------------------------------------


def test_spike_detection_and_retry():
    det = SpikeDetector(SpikeConfig(warmup_steps=5, wide_after=3))
    rs = np.random.RandomState(0)
    losses = list(4.0 - 0.001 * np.arange(100) + 0.01 * rs.randn(100))
    skipped = []
    for i, l in enumerate(losses):
        if i == 50:
            l += 3.0  # narrow spike
        v = det.observe(i, l, batch={"id": i})
        if v["skip"]:
            skipped.append(i)
    assert skipped == [50]
    assert det.events[0].kind == "narrow"
    assert det.pop_retry() == {"id": 50}      # sample retry (§3.4.4)
    assert det.pop_retry() is None


def test_wide_spike_reduces_lr():
    det = SpikeDetector(SpikeConfig(warmup_steps=5, wide_after=3,
                                    lr_reduce_steps=20))
    for i in range(30):
        det.observe(i, 4.0)
    for j in range(5):  # persistent spike
        v = det.observe(30 + j, 8.0)
        assert v["skip"]
    assert v["kind"] == "wide"
    assert v["lr_scale"] == 0.5
    assert det.lr_reduced_until > 34
    # spiking losses never polluted the running stats
    assert det.mean == pytest.approx(4.0, abs=0.1)


# ---------------------------------------------------------------------------
# EDiT (§2.2)
# ---------------------------------------------------------------------------


def _toy_params(val):
    return {"layer": jnp.full((4,), val, jnp.float32)}


def test_edit_sync_averages():
    base = _toy_params(1.0)
    workers = [_toy_params(0.9), _toy_params(0.8)]
    newp, ema, om, info = edit_sync(base, workers, init_ema(2),
                                    init_outer_momentum(base),
                                    EDiTConfig(clip_norm=1e9,
                                               outer_momentum=0.0))
    # pseudo grads 0.1 and 0.2; weights ~ (1/0.1, 1/0.2) normalized
    w = np.asarray(info["weights"])
    assert w[0] == pytest.approx(2 / 3, rel=1e-3)
    avg_pg = w[0] * 0.1 + w[1] * 0.2
    np.testing.assert_allclose(np.asarray(newp["layer"]),
                               1.0 - avg_pg, rtol=1e-4)


def test_edit_anomaly_elimination():
    base = _toy_params(1.0)
    cfg = EDiTConfig(anomaly_sigma=1.5, ema_decay=0.0, clip_norm=1e9,
                     outer_momentum=0.0)
    ema = init_ema(4)
    om = init_outer_momentum(base)
    # build EMA history with normal workers
    for _ in range(5):
        workers = [_toy_params(0.9)] * 4
        _, ema, _, _ = edit_sync(base, workers, ema, om, cfg)
    # now worker 3 diverges wildly
    workers = [_toy_params(0.9)] * 3 + [_toy_params(-50.0)]
    newp, ema, om, info = edit_sync(base, workers, ema, om, cfg)
    kept = np.asarray(info["kept"])
    assert kept[:3].all() and not kept[3]
    # the diverged worker contributed nothing
    np.testing.assert_allclose(np.asarray(newp["layer"]), 0.9, atol=1e-4)


def test_edit_clipping():
    base = _toy_params(0.0)
    workers = [_toy_params(-100.0)]
    cfg = EDiTConfig(clip_norm=1.0, outer_momentum=0.0, anomaly_sigma=1e9)
    newp, *_ = edit_sync(base, workers, init_ema(1),
                         init_outer_momentum(base), cfg)
    assert float(jnp.linalg.norm(newp["layer"])) <= 1.0 + 1e-5


def test_edit_timeline_speedup():
    """Fig-8 shape: speedup grows with worker count, up to the paper's
    ~66% regime under heavy straggling."""
    sp = [simulate_sync_timeline(n, 400, straggler_frac=0.05,
                                 straggler_slowdown=4.0, sync_cost_s=0.4,
                                 seed=1)["speedup"]
          for n in (4, 16, 64, 256)]
    assert sp[-1] > sp[0], sp             # grows with scale (Fig. 8 trend)
    assert max(sp) > 1.5 and all(x > 1.0 for x in sp), sp


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_pipeline_shapes_and_labels():
    p = DataPipeline(PipelineConfig(vocab_size=1000, seq_len=64,
                                    batch_size=4))
    b = p.next_batch()
    assert b["tokens"].shape == (4, 64)
    # labels are next-token shifted (within each packed row)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_pipeline_dedup_drops_duplicates():
    from repro.data.pipeline import DedupFilter
    d = DedupFilter()
    doc = np.arange(50, dtype=np.int32)
    assert d.admit(doc)
    assert not d.admit(doc.copy())
    assert d.admit(doc + 1)
    assert d.dropped == 1


def test_pipeline_mixture_changes_distribution():
    cfg = PipelineConfig(vocab_size=5000, seq_len=256, batch_size=4, seed=1)
    p1 = DataPipeline(cfg)
    p1.set_mixture({"web": 1.0, "books": 0, "code": 0, "math": 0,
                    "encyclopedia": 0})
    p2 = DataPipeline(cfg)
    p2.set_mixture({"code": 1.0, "web": 0, "books": 0, "math": 0,
                    "encyclopedia": 0})
    t1 = p1.next_batch()["tokens"].reshape(-1)
    t2 = p2.next_batch()["tokens"].reshape(-1)
    # different domain permutations -> token histograms must differ a lot
    h1 = np.bincount(t1, minlength=5000)
    h2 = np.bincount(t2, minlength=5000)
    overlap = np.minimum(h1, h2).sum() / max(h1.sum(), 1)
    assert overlap < 0.5, overlap


def test_pipeline_retry_injection():
    p = DataPipeline(PipelineConfig(vocab_size=100, seq_len=16,
                                    batch_size=2, retry_injection_prob=1.0))
    marker = {"tokens": np.full((2, 16), 7, np.int32),
              "labels": np.full((2, 16), 7, np.int32)}
    p.push_retry(marker)
    b = p.next_batch()
    assert (b["tokens"] == 7).all()
    assert p.stats["retry_injected"] == 1


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 64), st.integers(1, 8))
def test_pipeline_packing_property(seq_len, batch):
    p = DataPipeline(PipelineConfig(vocab_size=500, seq_len=seq_len,
                                    batch_size=batch, dedup=False))
    b = p.next_batch()
    assert b["tokens"].shape == (batch, seq_len)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 500
