"""Blockwise flash attention vs naive oracle: fwd + bwd, all schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import _schedule_pairs, attention_core, choose_block


def naive(q, k, v, causal=True, window=None):
    B, S, H, hd = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * hd ** -0.5
    qpos = jnp.arange(S)
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((S, k.shape[1]), bool)
    if causal:
        mask &= kpos[None] <= qpos[:, None]
    if window:
        mask &= kpos[None] > qpos[:, None] - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))


CASES = [
    (256, "causal", None),
    (256, "full", None),
    (256, "window", 64),
    (96, "causal", None),        # non-power-of-two block
    (128, "window", 32),
]


@pytest.mark.parametrize("S,sched,win", CASES)
def test_fwd_bwd_matches_naive(S, sched, win):
    rs = np.random.RandomState(0)
    q = jnp.asarray(rs.randn(2, S, 3, 32), jnp.float32)
    k = jnp.asarray(rs.randn(2, S, 3, 32), jnp.float32)
    v = jnp.asarray(rs.randn(2, S, 3, 32), jnp.float32)

    def f1(q, k, v):
        return attention_core(q, k, v, causal=True, window=win,
                              schedule=sched, block_target=64).sum()

    def f2(q, k, v):
        return naive(q, k, v, causal=True, window=win).sum()

    o1 = attention_core(q, k, v, causal=True, window=win, schedule=sched,
                        block_target=64)
    o2 = naive(q, k, v, causal=True, window=win)
    np.testing.assert_allclose(np.asarray(o1, np.float32), np.asarray(o2),
                               rtol=3e-5, atol=3e-5)
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4)


def test_noncausal_encoder():
    rs = np.random.RandomState(1)
    q = jnp.asarray(rs.randn(1, 64, 2, 16), jnp.float32)
    k = jnp.asarray(rs.randn(1, 96, 2, 16), jnp.float32)
    v = jnp.asarray(rs.randn(1, 96, 2, 16), jnp.float32)
    o1 = attention_core(q, k, v, causal=False, window=None, schedule="full",
                        block_target=32)
    o2 = naive(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o1, np.float32), np.asarray(o2),
                               rtol=3e-5, atol=3e-5)


def test_schedule_pair_counts():
    """causal visits ~half the tiles; window visits O(S*window) tiles."""
    nq = nk = 32
    full = _schedule_pairs(nq, nk, 128, 128, "full", None)
    causal = _schedule_pairs(nq, nk, 128, 128, "causal", None)
    window = _schedule_pairs(nq, nk, 128, 128, "window", 256)
    assert len(full[0]) == nq * nk
    assert len(causal[0]) == nq * (nq + 1) // 2
    # band: each q block touches <= ceil(window/bk)+1 k blocks
    assert len(window[0]) <= nq * 4
    # schedules must cover the diagonal
    assert all(q >= k for q, k in zip(*causal))


def test_choose_block_divides():
    for s in [64, 96, 1500, 1504, 4096, 32768]:
        b = choose_block(s, 1024)
        assert s % b == 0 and b <= 1024
