"""Batch-size warmup through the engine (§3.4.1): AccumWarmup schedule,
staged compile cache (≤ one XLA compile per accum stage), trajectory
parity vs fixed-big-batch runs, mid-warmup checkpoint restore with stage
carry-over, retry-lane regranulation, pipeline thread safety, and the
schedule/trainer edge-case regressions fixed alongside."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.analysis.contracts import compile_guard
from repro.configs.base import get_smoke_config
from repro.data.pipeline import DataPipeline, PipelineConfig
from repro.launch.mesh import make_local_mesh
from repro.optim import adamw
from repro.optim.schedule import AccumWarmup, BatchSizeWarmup, WSDSchedule
from repro.training.trainer import TrainConfig, Trainer


def _runner(arch="ling-lite", seq=32):
    return api.Runner(get_smoke_config(arch), make_local_mesh(1, 1),
                      max_seq=seq)


def _trainer(tmp_path=None, *, steps=6, log_every=2, ckpt_every=0,
             seq=32, batch=2, seed=0, bs_warmup=None, accum=1):
    runner = _runner(seq=seq)
    pipe = DataPipeline(PipelineConfig(
        vocab_size=runner.cfg.vocab_size, seq_len=seq, batch_size=batch,
        seed=seed))
    cfg = TrainConfig(
        n_steps=steps,
        lr_schedule=WSDSchedule(max_lr=1e-3, warmup_steps=4,
                                total_steps=100),
        accum_steps=accum, bs_warmup=bs_warmup, log_every=log_every,
        checkpoint_every=ckpt_every,
        checkpoint_dir=(str(tmp_path) if tmp_path else None),
        seed=seed)
    return Trainer(runner, pipe, cfg)


# ---------------------------------------------------------------------------
# schedule regressions
# ---------------------------------------------------------------------------


def test_wsd_halving_clamped_to_post_warmup():
    """With small total_steps the 60% point lands mid-warmup; the ramp
    must stay monotone (halving clamped to warmup end)."""
    s = WSDSchedule(max_lr=1e-3, warmup_steps=50, total_steps=60)
    ramp = [float(s(i)) for i in range(50)]
    assert all(a <= b for a, b in zip(ramp, ramp[1:])), "non-monotone ramp"
    assert ramp[-1] == pytest.approx(1e-3 * 49 / 50)
    # halving still happens, at the clamped (post-warmup) point
    assert float(s(50)) == pytest.approx(5e-4)
    # large total_steps: paper behavior unchanged
    big = WSDSchedule(max_lr=1e-3, warmup_steps=100, total_steps=1000)
    assert float(big(500)) == pytest.approx(1e-3)
    assert float(big(700)) == pytest.approx(5e-4)


def test_batch_warmup_small_start_not_pinned():
    """start < 256 (every test config) must still grow: the rounding
    multiple derives from the endpoints instead of a hard-coded 256."""
    b = BatchSizeWarmup(start=4, end=16, warmup_steps=8)
    assert b.multiple == 4
    sizes = [b(i) for i in range(9)]
    assert sizes[0] == 4 and sizes[-1] == 16
    assert len(set(sizes)) > 2, sizes                  # actually grows
    assert all(s % 4 == 0 for s in sizes)
    assert all(x <= y for x, y in zip(sizes, sizes[1:]))


def test_batch_warmup_multiple_configurable():
    b = BatchSizeWarmup(start=6, end=24, warmup_steps=6, increments=3,
                        round_multiple=6)
    assert {b(i) for i in range(7)} <= {6, 12, 18, 24}
    # paper default still rounds to 256
    assert BatchSizeWarmup().multiple == 256


def test_accum_warmup_stages_and_validation():
    w = AccumWarmup(microbatch=2, start=2, end=8, warmup_steps=4,
                    increments=2)
    assert [w.accum_for(i) for i in range(6)] == [1, 1, 2, 2, 4, 4]
    assert w.stages() == (1, 2, 4)
    assert w.batch_for(5) == 8
    with pytest.raises(ValueError, match="multiple of"):
        AccumWarmup(microbatch=3, start=4, end=8, warmup_steps=4)
    with pytest.raises(ValueError, match="end"):
        AccumWarmup(microbatch=2, start=8, end=4, warmup_steps=4)


# ---------------------------------------------------------------------------
# staged compile cache
# ---------------------------------------------------------------------------


def test_staged_step_one_compile_per_stage():
    """Revisiting a stage must reuse its compiled step: trace counts stay
    at one per declared stage over a full warmup traversal."""
    runner = _runner()
    B, S = 2, 32
    staged = runner.jit_train_step(B, accum_steps=(1, 2), donate=False)
    assert staged.stages == (1, 2)
    params = runner.init_params(0)
    opt = adamw.init_opt_state(params)
    rs = np.random.RandomState(0)

    def batch(accum):
        shape = (B, S) if accum == 1 else (accum, B, S)
        return {k: jnp.asarray(rs.randint(0, runner.cfg.vocab_size, shape),
                               jnp.int32) for k in ("tokens", "labels")}

    # one compile per declared stage, revisits free — the invariant is
    # the shared contracts.compile_guard over the staged CompileCounter
    with compile_guard({"accum1": 1, "accum2": 1}, staged.compiles,
                       exact=True):
        for t, accum in enumerate([1, 1, 2, 2, 1, 2]):  # revisits both ways
            params, opt, _ = staged.for_accum(accum)(
                params, opt, batch(accum), jnp.int32(t),
                jax.random.PRNGKey(t), jnp.float32(1e-3))
    assert staged.trace_counts == {1: 1, 2: 1}
    assert staged.n_compiles == len(staged.stages)
    with pytest.raises(ValueError, match="not in declared stages"):
        staged.for_accum(4)


# ---------------------------------------------------------------------------
# trajectory parity: scheduled accumulation vs fixed big batches
# ---------------------------------------------------------------------------


def test_accum_warmup_parity_vs_fixed_big_batch():
    """Driving the warmup through the accumulation dim must track the
    equivalent fixed-big-batch steps: same loss at each stage's batch
    size, coinciding param trajectory (dense config: exact CE mean)."""
    cfg = get_smoke_config("nemotron-4-15b")
    S, Bm = 32, 2
    warm = AccumWarmup(microbatch=Bm, start=Bm, end=4 * Bm, warmup_steps=4,
                       increments=2)
    accums = [warm.accum_for(i) for i in range(6)]
    assert accums == [1, 1, 2, 2, 4, 4]
    runner = api.Runner(cfg, make_local_mesh(1, 1), max_seq=S)
    params = runner.init_params(0)
    staged = runner.jit_train_step(Bm, accum_steps=warm.stages(),
                                   donate=False)
    big_steps = {a: jax.jit(runner.make_train_step(a * Bm))
                 for a in set(accums)}
    rs = np.random.RandomState(0)
    pa, oa = params, adamw.init_opt_state(params)
    pb, ob = params, adamw.init_opt_state(params)
    losses_a, losses_b = [], []
    for t, a in enumerate(accums):
        toks = rs.randint(0, cfg.vocab_size, (a * Bm, S))
        labs = rs.randint(0, cfg.vocab_size, (a * Bm, S))
        flat = {"tokens": jnp.asarray(toks, jnp.int32),
                "labels": jnp.asarray(labs, jnp.int32)}
        if a == 1:
            acc = flat
        else:
            acc = {"tokens": jnp.asarray(toks.reshape(a, Bm, S), jnp.int32),
                   "labels": jnp.asarray(labs.reshape(a, Bm, S), jnp.int32)}
        pa, oa, ma = staged.for_accum(a)(
            pa, oa, acc, jnp.int32(10**6 + t), jax.random.PRNGKey(1),
            jnp.float32(1e-3))
        pb, ob, mb = big_steps[a](
            pb, ob, flat, jnp.int32(10**6 + t), jax.random.PRNGKey(1),
            jnp.float32(1e-3))
        losses_a.append(ma["loss"])
        losses_b.append(mb["loss"])
    # drain once after the loop (FC-HOSTSYNC: no per-step host syncs)
    losses_a, losses_b = jax.device_get((losses_a, losses_b))
    assert losses_a[0] == pytest.approx(losses_b[0], rel=1e-6)
    for a, b in zip(losses_a[1:], losses_b[1:]):
        assert a == pytest.approx(b, rel=2e-3)
    num = sum(float(jnp.sum((x - y).astype(jnp.float32) ** 2))
              for x, y in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)))
    den = sum(float(jnp.sum(x.astype(jnp.float32) ** 2))
              for x in jax.tree.leaves(pa))
    assert np.sqrt(num / max(den, 1e-9)) < 1e-3
    # the whole warmup cost exactly one compile per stage
    assert staged.trace_counts == {1: 1, 2: 1, 4: 1}


# ---------------------------------------------------------------------------
# trainer integration: mid-warmup restore with stage carry-over
# ---------------------------------------------------------------------------


def test_trainer_warmup_restore_mid_warmup(tmp_path):
    """Checkpoint inside the warmup, restore into a fresh trainer: the
    stage carries over (sidecar), the resumed losses are bitwise equal to
    the unbroken run, and no stage compiles more than once."""
    bw = AccumWarmup(microbatch=2, start=2, end=8, warmup_steps=4,
                     increments=2)
    steps, every = 6, 3                  # save at step 3: mid-warmup
    ck = tmp_path / "ck"
    tr_a = _trainer(ck, steps=steps, ckpt_every=every, bs_warmup=bw)
    hist_a = tr_a.train()
    tr_a.close()
    assert tr_a.staged.trace_counts == {1: 1, 2: 1, 4: 1}

    tr_b = _trainer(ck, steps=steps, ckpt_every=every, bs_warmup=bw)
    assert tr_b.restore(f"step_{every}") == f"step_{every}"
    assert tr_b.step == every
    assert tr_b._accum == bw.accum_for(every) == 2   # stage carried over
    hist_b = tr_b.train(steps)
    tr_b.close()
    tail_a = [h["loss"] for h in hist_a if h["step"] >= every]
    assert [h["loss"] for h in hist_b] == tail_a     # bitwise resume
    # restore landed mid-stage: stages 2 and 4 compile once, stage 1 never
    assert tr_b.staged.trace_counts == {2: 1, 4: 1}


def test_trainer_train_zero_steps_is_noop():
    """train(0) must be a no-op returning history, not cfg.n_steps."""
    tr = _trainer(steps=4)
    assert tr.train(0) == []
    assert tr.step == 0 and tr._prefetcher is None
    hist = tr.train()                    # default still runs cfg.n_steps
    tr.close()
    assert len(hist) == 4


# ---------------------------------------------------------------------------
# retry-lane regranulation + pipeline thread safety
# ---------------------------------------------------------------------------


def test_retry_lane_regranulates_across_stages():
    p = DataPipeline(PipelineConfig(vocab_size=100, seq_len=16,
                                    batch_size=2,
                                    retry_injection_prob=1.0))
    mb = p.next_macrobatch(4)
    assert mb["tokens"].shape == (4, 2, 16)
    p.push_retry(mb)                     # accum inferred from the shape
    # replay at accum=2: first two microbatches, remainder requeued
    first = p.next_macrobatch(2)
    np.testing.assert_array_equal(first["tokens"], mb["tokens"][:2])
    second = p.next_macrobatch(2)
    np.testing.assert_array_equal(second["tokens"], mb["tokens"][2:])
    assert not p.retry_queue
    # replay a macrobatch at batch granularity (stage shrank to 1)
    p.push_retry(mb)
    got = p.next_batch()
    np.testing.assert_array_equal(got["tokens"], mb["tokens"][0])
    # growing stage: stored microbatches are topped up with fresh data
    grown = p.next_macrobatch(4)
    np.testing.assert_array_equal(grown["tokens"][:3], mb["tokens"][1:])
    assert grown["tokens"].shape == (4, 2, 16)
    assert not p.retry_queue


def test_pipeline_threaded_stress_consistency():
    """Producer + retry-pusher + snapshotter hammering one pipeline: all
    batches stay well-formed and state_dict stays internally consistent
    (the pipeline's own lock serializes mutations)."""
    p = DataPipeline(PipelineConfig(vocab_size=200, seq_len=8,
                                    batch_size=2, dedup=False,
                                    retry_injection_prob=0.5))
    errors, snapshots = [], []
    start = threading.Barrier(3)
    helpers_done = threading.Event()

    # the consumer runs until BOTH helpers finish their fixed iteration
    # budgets, so the three threads are guaranteed to overlap regardless
    # of scheduling (a stop-flag design let the consumer finish before
    # the snapshotter's first iteration and flake)
    def consume():
        try:
            start.wait()
            i = 0
            while not helpers_done.is_set() or i < 50:
                a = 1 + i % 3
                b = p.next_macrobatch(a)
                want = (2, 8) if a == 1 else (a, 2, 8)
                assert b["tokens"].shape == want, b["tokens"].shape
                assert b["tokens"].dtype == np.int32
                i += 1
        except BaseException as e:       # noqa: BLE001 — surfaced below
            errors.append(e)

    def retry_push():
        try:
            start.wait()
            for _ in range(200):
                p.push_retry({"tokens": np.zeros((2, 2, 8), np.int32),
                              "labels": np.zeros((2, 2, 8), np.int32)})
        except BaseException as e:       # noqa: BLE001
            errors.append(e)

    def snapshot():
        try:
            start.wait()
            for _ in range(100):
                s = p.state_dict()
                # buffer must be a coherent copy, stats a plain dict
                assert s["buffer"].ndim == 1
                snapshots.append(len(s["retry_queue"]))
        except BaseException as e:       # noqa: BLE001
            errors.append(e)

    threads = {f.__name__: threading.Thread(target=f)
               for f in (consume, retry_push, snapshot)}
    for t in threads.values():
        t.start()
    threads["retry_push"].join(timeout=60)
    threads["snapshot"].join(timeout=60)
    helpers_done.set()
    threads["consume"].join(timeout=60)
    assert not any(t.is_alive() for t in threads.values()), "stress hung"
    assert not errors, errors
    assert len(snapshots) == 100
    # a post-stress snapshot still round-trips into a working pipeline
    p2 = DataPipeline(p.cfg)
    p2.load_state_dict(p.state_dict())
    assert p2.next_macrobatch(2)["tokens"].shape == (2, 2, 8)
