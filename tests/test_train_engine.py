"""Mesh-native training-engine tests: device-side spike guard (commit
flag, no per-step host sync), microbatch grad accumulation parity, async
metric drains, checkpoint save -> restore exact resume, and the spike
LR-reduction window."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs.base import get_smoke_config
from repro.core import spikes as spikes_lib
from repro.core.spikes import SpikeConfig, SpikeDetector
from repro.data.pipeline import DataPipeline, PipelineConfig
from repro.launch.mesh import make_local_mesh
from repro.optim import adamw
from repro.optim.schedule import WSDSchedule
from repro.training.trainer import TrainConfig, Trainer


def _runner(arch="ling-lite", seq=32):
    return api.Runner(get_smoke_config(arch), make_local_mesh(1, 1),
                      max_seq=seq)


def _trainer(tmp_path=None, *, steps=8, accum=1, log_every=4,
             ckpt_every=0, seq=32, batch=2, seed=0):
    runner = _runner(seq=seq)
    pipe = DataPipeline(PipelineConfig(
        vocab_size=runner.cfg.vocab_size, seq_len=seq, batch_size=batch,
        seed=seed))
    cfg = TrainConfig(
        n_steps=steps,
        lr_schedule=WSDSchedule(max_lr=1e-3, warmup_steps=4,
                                total_steps=100),
        accum_steps=accum, log_every=log_every,
        checkpoint_every=ckpt_every,
        checkpoint_dir=(str(tmp_path) if tmp_path else None),
        seed=seed)
    return Trainer(runner, pipe, cfg)


# ---------------------------------------------------------------------------
# device-side guard unit behaviour
# ---------------------------------------------------------------------------


def test_guard_commit_matches_host_detector():
    cfg = SpikeConfig(warmup_steps=3)
    state = spikes_lib.init_guard_state()
    det = SpikeDetector(cfg)
    losses = [4.0, 4.1, 3.9, 4.0, 3.95, 8.0, 3.9]   # spike at index 5
    for i, l in enumerate(losses):
        commit, state = spikes_lib.guard_commit(cfg, state,
                                                jnp.float32(l))
        v = det.observe(i, l)
        assert bool(commit) == (not v["skip"]), (i, l)
    # spiking loss did not pollute the device stats either
    assert float(state["mean"]) == pytest.approx(det.mean, rel=1e-5)
    assert float(state["var"]) == pytest.approx(det.var, rel=1e-4)


def test_guard_rejects_nonfinite_loss():
    cfg = SpikeConfig(warmup_steps=0)
    state = spikes_lib.init_guard_state()
    commit, state = spikes_lib.guard_commit(cfg, state, jnp.float32(4.0))
    assert bool(commit)
    commit, state2 = spikes_lib.guard_commit(cfg, state,
                                             jnp.float32(np.nan))
    assert not bool(commit)
    # NaN must not enter the running stats
    assert float(state2["mean"]) == float(state["mean"])


def test_guard_nonfinite_first_loss_does_not_poison_seed():
    """A NaN on the very first step must neither seed the EMA nor block a
    later finite loss from seeding it."""
    cfg = SpikeConfig(warmup_steps=0)
    state = spikes_lib.init_guard_state()
    commit, state = spikes_lib.guard_commit(cfg, state,
                                            jnp.float32(np.nan))
    assert not bool(commit) and int(state["seeded"]) == 0
    commit, state = spikes_lib.guard_commit(cfg, state, jnp.float32(4.0))
    assert bool(commit)
    assert float(state["mean"]) == pytest.approx(4.0)
    assert int(state["seeded"]) == 1


def test_engine_step_discards_spike_on_device():
    """End-to-end: a guard state whose EMA says 'spike' must leave params,
    moments, and the opt count untouched — decided entirely on device."""
    runner = _runner()
    B, S = 2, 32
    step = runner.jit_train_step(B, spike_guard=SpikeConfig(),
                                 donate=False)
    params = runner.init_params(0)
    opt = adamw.init_opt_state(params)
    rs = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rs.randint(0, runner.cfg.vocab_size,
                                              (B, S)), jnp.int32),
             "labels": jnp.asarray(rs.randint(0, runner.cfg.vocab_size,
                                              (B, S)), jnp.int32)}
    # EMA far below the actual loss and past warmup -> certain spike
    guard = {"mean": jnp.float32(0.1), "var": jnp.float32(1e-4),
             "n": jnp.int32(1000), "seeded": jnp.int32(1)}
    p2, o2, g2, m = step(params, opt, guard, batch, jnp.int32(0),
                         jax.random.PRNGKey(0), jnp.float32(1e-3))
    assert float(m["commit"]) == 0.0
    assert int(o2["count"]) == 0
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # stats were not polluted by the spiking loss
    assert float(g2["mean"]) == pytest.approx(0.1)
    # normal guard state on the same batch commits
    p3, o3, g3, m3 = step(params, opt, spikes_lib.init_guard_state(),
                          batch, jnp.int32(0), jax.random.PRNGKey(0),
                          jnp.float32(1e-3))
    assert float(m3["commit"]) == 1.0
    assert int(o3["count"]) == 1
    deltas = [float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p3))]
    assert max(deltas) > 0


# ---------------------------------------------------------------------------
# grad-norm-keyed guard (§3.4.4 fn2)
# ---------------------------------------------------------------------------


def test_guard_gnorm_vetoes_commit():
    """With gnorm_sigma_threshold set the guard carries a second EMA and
    vetoes the commit on a grad-norm spike even when the loss is calm;
    rejected steps pollute neither statistic."""
    cfg = SpikeConfig(warmup_steps=0, gnorm_sigma_threshold=4.0)
    state = spikes_lib.init_guard_state(cfg)
    assert "gmean" in state and "gvar" in state
    for i, (l, g) in enumerate([(4.0, 1.0), (4.1, 1.1), (3.9, 0.9)]):
        commit, state = spikes_lib.guard_commit(cfg, state, jnp.float32(l),
                                                gnorm=jnp.float32(g))
        assert bool(commit), i
    mean_before = float(state["gmean"])
    # calm loss, exploding grad norm -> skip
    commit, state = spikes_lib.guard_commit(cfg, state, jnp.float32(4.0),
                                            gnorm=jnp.float32(50.0))
    assert not bool(commit)
    assert float(state["gmean"]) == pytest.approx(mean_before)
    # non-finite grad norm -> skip even though the loss is finite
    commit, state = spikes_lib.guard_commit(cfg, state, jnp.float32(4.0),
                                            gnorm=jnp.float32(np.nan))
    assert not bool(commit)
    # back to normal -> commit resumes
    commit, state = spikes_lib.guard_commit(cfg, state, jnp.float32(4.0),
                                            gnorm=jnp.float32(1.0))
    assert bool(commit)


def test_guard_gnorm_off_keeps_legacy_state_and_decisions():
    """Default config: 4-leaf state, and passing gnorm changes nothing
    (existing checkpoints and the loss-only parity tests stay valid)."""
    cfg = SpikeConfig(warmup_steps=3)
    assert set(spikes_lib.init_guard_state(cfg)) == {"mean", "var", "n",
                                                     "seeded"}
    s_a = spikes_lib.init_guard_state()
    s_b = spikes_lib.init_guard_state(cfg)
    for l in [4.0, 4.1, 3.9, 8.0, 4.0]:
        ca, s_a = spikes_lib.guard_commit(cfg, s_a, jnp.float32(l))
        cb, s_b = spikes_lib.guard_commit(cfg, s_b, jnp.float32(l),
                                          gnorm=jnp.float32(1e9))
        assert bool(ca) == bool(cb)
    for k in s_a:
        assert float(s_a[k]) == float(s_b[k]), k


def test_engine_step_discards_gnorm_spike_on_device():
    """End-to-end: a guard state whose grad-norm EMA says 'spike' leaves
    params/opt untouched even though the loss statistic is calm."""
    runner = _runner()
    B, S = 2, 32
    cfg = SpikeConfig(gnorm_sigma_threshold=4.0)
    step = runner.jit_train_step(B, spike_guard=cfg, donate=False)
    params = runner.init_params(0)
    opt = adamw.init_opt_state(params)
    rs = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rs.randint(0, runner.cfg.vocab_size,
                                              (B, S)), jnp.int32),
             "labels": jnp.asarray(rs.randint(0, runner.cfg.vocab_size,
                                              (B, S)), jnp.int32)}
    # loss EMA sits far ABOVE the real loss (no loss spike possible) while
    # the gnorm EMA sits far below the real grad norm -> certain veto
    guard = {"mean": jnp.float32(100.0), "var": jnp.float32(1.0),
             "n": jnp.int32(1000), "seeded": jnp.int32(1),
             "gmean": jnp.float32(1e-6), "gvar": jnp.float32(1e-12)}
    p2, o2, g2, m = step(params, opt, guard, batch, jnp.int32(0),
                         jax.random.PRNGKey(0), jnp.float32(1e-3))
    assert float(m["commit"]) == 0.0
    assert int(o2["count"]) == 0
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(g2["gmean"]) == pytest.approx(1e-6)
    # a fresh (unseeded) guard on the same batch commits normally
    p3, o3, g3, m3 = step(params, opt, spikes_lib.init_guard_state(cfg),
                          batch, jnp.int32(0), jax.random.PRNGKey(0),
                          jnp.float32(1e-3))
    assert float(m3["commit"]) == 1.0
    assert float(g3["gmean"]) == pytest.approx(float(m3["grad_norm"]),
                                               rel=1e-5)


# ---------------------------------------------------------------------------
# grad accumulation parity
# ---------------------------------------------------------------------------


def test_accum_parity_vs_big_batch():
    """accum_steps=4 over microbatches of 2 must track one batch of 8:
    identical loss, matching trajectory on the next step."""
    cfg = get_smoke_config("nemotron-4-15b")     # dense: exact CE parity
    S, A, Bm = 32, 4, 2
    runner = api.Runner(cfg, make_local_mesh(1, 1), max_seq=S)
    params = runner.init_params(0)
    rs = np.random.RandomState(0)
    toks = rs.randint(0, cfg.vocab_size, (A * Bm, S))
    labs = rs.randint(0, cfg.vocab_size, (A * Bm, S))
    big = {"tokens": jnp.asarray(toks, jnp.int32),
           "labels": jnp.asarray(labs, jnp.int32)}
    acc = {"tokens": jnp.asarray(toks.reshape(A, Bm, S), jnp.int32),
           "labels": jnp.asarray(labs.reshape(A, Bm, S), jnp.int32)}

    step_big = jax.jit(runner.make_train_step(A * Bm))
    step_acc = jax.jit(runner.make_train_step(Bm, accum_steps=A))
    pb, ob = params, adamw.init_opt_state(params)
    pa, oa = params, adamw.init_opt_state(params)
    losses_b, losses_a = [], []
    for t in range(2):
        pb, ob, mb = step_big(pb, ob, big, jnp.int32(10**6 + t),
                              jax.random.PRNGKey(1), jnp.float32(1e-3))
        pa, oa, ma = step_acc(pa, oa, acc, jnp.int32(10**6 + t),
                              jax.random.PRNGKey(1), jnp.float32(1e-3))
        losses_b.append(mb["loss"])
        losses_a.append(ma["loss"])
    # drain once after the loop (FC-HOSTSYNC: no per-step host syncs)
    losses_a, losses_b = jax.device_get((losses_a, losses_b))
    # step-0 losses are computed on identical params: exact match
    assert losses_a[0] == pytest.approx(losses_b[0], rel=1e-6)
    # step-1 losses see the (bf16-noise-separated) updated params
    assert losses_a[1] == pytest.approx(losses_b[1], rel=2e-3)
    # param trajectories coincide in norm (sign flips of the first Adam
    # step at ~zero grads keep this from being exact elementwise)
    num = sum(float(jnp.sum((x - y).astype(jnp.float32) ** 2))
              for x, y in zip(jax.tree.leaves(pb), jax.tree.leaves(pa)))
    den = sum(float(jnp.sum(x.astype(jnp.float32) ** 2))
              for x in jax.tree.leaves(pb))
    assert np.sqrt(num / den) < 1e-3


# ---------------------------------------------------------------------------
# async drains
# ---------------------------------------------------------------------------


def test_trainer_drains_at_most_n_over_log_every():
    """N steps with drain period L => <= ceil(N/L) host metric transfers
    (the acceptance bound), while every step still lands in history."""
    N, L = 8, 4
    tr = _trainer(steps=N, log_every=L)
    hist = tr.train()
    tr.close()
    assert len(hist) == N
    assert [h["step"] for h in hist] == list(range(N))
    assert tr.metric_drains <= -(-N // L)
    assert tr.metric_drains == tr.timer.counters["metric_drain"]
    # smoke config at lr=1e-3 trains clean: everything committed
    assert not any(h["skipped"] for h in hist)
    assert tr.timer.gauges["commit_frac"] == 1.0


# ---------------------------------------------------------------------------
# checkpoint save -> restore exact resume
# ---------------------------------------------------------------------------


def test_checkpoint_resume_reproduces_losses(tmp_path):
    steps, every = 8, 4
    ck = tmp_path / "ck"
    tr_a = _trainer(ck, steps=steps, ckpt_every=every)
    hist_a = tr_a.train()
    tr_a.close()

    tr_b = _trainer(ck, steps=steps, ckpt_every=every)
    name = tr_b.restore(f"step_{every}")
    assert name == f"step_{every}"
    assert tr_b.step == every
    # train() targets the GLOBAL step count: a resumed run completes the
    # original schedule instead of overshooting it
    hist_b = tr_b.train(steps)
    tr_b.close()
    assert tr_b.step == steps

    tail_a = [h["loss"] for h in hist_a if h["step"] >= every]
    tail_b = [h["loss"] for h in hist_b]
    assert [h["step"] for h in hist_b] == list(range(every, steps))
    assert tail_b == tail_a          # bitwise-identical resumed losses
    # restore("latest") picks the newest complete checkpoint
    tr_c = _trainer(ck, steps=steps, ckpt_every=every)
    assert tr_c.restore("latest") == f"step_{steps}"
    tr_c.close()


# ---------------------------------------------------------------------------
# spike LR window (host policy half)
# ---------------------------------------------------------------------------


def test_lr_scale_defined_before_first_observation():
    det = SpikeDetector(SpikeConfig())
    assert det.lr_reduced_until == -1
    assert det.lr_scale_for(0) == 1.0


def test_lr_window_applies_and_expires():
    cfg = SpikeConfig(warmup_steps=0, wide_after=2, lr_reduce_steps=10,
                      lr_reduce_factor=0.5)
    det = SpikeDetector(cfg)
    for i in range(5):
        det.ingest(i, 4.0, skipped=False)
    det.ingest(5, 9.0, skipped=True)             # narrow
    assert det.lr_scale_for(6) == 1.0
    det.ingest(6, 9.0, skipped=True)             # second consecutive: wide
    assert det.events[-1].kind == "wide"
    assert det.lr_reduced_until == 6 + 10
    for s in range(7, 17):
        assert det.lr_scale_for(s) == 0.5, s     # window active
    assert det.lr_scale_for(17) == 1.0           # expired
    # a committed step closes the consecutive run
    det.ingest(17, 4.0, skipped=False)
    assert det.consecutive == 0


def test_detector_ingest_queues_retry_batch():
    det = SpikeDetector(SpikeConfig())
    det.ingest(3, 9.0, skipped=True, batch={"id": 3})
    assert det.pop_retry() == {"id": 3}
    assert det.pop_retry() is None


# ---------------------------------------------------------------------------
# pipeline macrobatch + state round-trip
# ---------------------------------------------------------------------------


def test_macrobatch_shapes_and_retry_lane():
    p = DataPipeline(PipelineConfig(vocab_size=100, seq_len=16,
                                    batch_size=2,
                                    retry_injection_prob=1.0))
    mb = p.next_macrobatch(3)
    assert mb["tokens"].shape == (3, 2, 16)
    p.push_retry(mb)
    again = p.next_macrobatch(3)
    np.testing.assert_array_equal(again["tokens"], mb["tokens"])
    assert p.stats["retry_injected"] == 1


def test_prefetcher_propagates_producer_errors():
    from repro.data.pipeline import Prefetcher

    def boom():
        raise ValueError("stream broken")

    pf = Prefetcher(boom, depth=1)
    with pytest.raises(RuntimeError, match="prefetch producer failed"):
        pf.get()
    with pytest.raises(RuntimeError):   # later calls fail fast, no hang
        pf.get()
    pf.stop()


def test_pcache_latest_prefers_newest_step(tmp_path):
    from repro.checkpoint.pcache import PCache
    pc = PCache(str(tmp_path))
    pc.save("init", {"x": np.zeros(2)})
    pc.save("run_v999", {"x": np.zeros(2)})      # digit tail, not a step
    pc.save("step_20", {"x": np.zeros(2)})
    pc.save("step_100", {"x": np.zeros(2)})
    assert pc.latest() == "step_100"


def test_log_every_zero_still_applies_policy_per_step():
    """log_every=0 silences periodic prints but must not starve the host
    spike policy: the trainer falls back to per-step drains."""
    N = 3
    tr = _trainer(steps=N, log_every=0)
    hist = tr.train()
    tr.close()
    assert len(hist) == N
    assert tr.metric_drains == N
    assert not tr._pending


def test_pipeline_state_roundtrip_continues_stream():
    cfg = PipelineConfig(vocab_size=300, seq_len=32, batch_size=2, seed=3)
    p1 = DataPipeline(cfg)
    p1.next_batch()
    state = p1.state_dict()
    want = [p1.next_batch() for _ in range(3)]
    p2 = DataPipeline(cfg)
    p2.load_state_dict(state)
    got = [p2.next_batch() for _ in range(3)]
    for a, b in zip(want, got):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
