"""Infrastructure tests: PCache, Babel, XPUTimer, hetero cost model,
scaling laws, DPO packing, Flood engine."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import babel as B
from repro.checkpoint import pcache as PC
from repro.core import hetero, scaling_laws as SL
from repro.serving.flood import FloodEngine, GenRequest, baseline_step_engine
from repro.serving.segment_cache import SegmentCache
from repro.telemetry.xputimer import XPUTimer
from repro.training import dpo


# ---------------------------------------------------------------------------
# PCache
# ---------------------------------------------------------------------------


def test_pcache_roundtrip(tmp_path):
    pc = PC.PCache(str(tmp_path))
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.int32)}}
    pc.save("step_10", tree)
    like = jax.tree.map(lambda x: None, tree,
                        is_leaf=lambda x: x is None) if False else tree
    out = pc.load("step_10", tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert pc.list_checkpoints() == ["step_10"]
    # metadata cache: second manifest read hits the cache
    pc.manifest("step_10")
    assert "step_10" in pc._meta_cache


def test_pcache_async(tmp_path):
    pc = PC.PCache(str(tmp_path))
    tree = {"w": jnp.ones((64, 64))}
    pc.save("a", tree, block=False)
    pc.wait()
    out = pc.load("a", tree)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.ones((64, 64)))


def test_writer_dispersal_balances_nodes():
    """The AI-co-design claim: rank-0 writers pile up on the first nodes;
    dispersed writers spread evenly -> the Table-2-shaped win."""
    kw = dict(n_dp_groups=16, ranks_per_group=8, n_nodes=16,
              ranks_per_node=8)
    concentrated = PC.assign_writers(disperse=False, **kw)
    dispersed = PC.assign_writers(disperse=True, **kw)
    load_c = PC.node_load(concentrated, 8)
    load_d = PC.node_load(dispersed, 8)
    assert max(load_c.values()) > max(load_d.values())
    assert max(load_d.values()) == 1
    t_c = PC.simulate_checkpoint_write(disperse=False,
                                       bytes_per_group=1e9, **kw)
    t_d = PC.simulate_checkpoint_write(disperse=True,
                                       bytes_per_group=1e9, **kw)
    assert t_c / t_d >= 2.0            # paper: ~50% latency reduction


# ---------------------------------------------------------------------------
# Babel
# ---------------------------------------------------------------------------


def _make_tree(root, n_dirs=4, files_per=6, size=2000):
    rs = np.random.RandomState(0)
    for d in range(n_dirs):
        p = os.path.join(root, f"shard_{d}")
        os.makedirs(p, exist_ok=True)
        for f in range(files_per):
            with open(os.path.join(p, f"f{f}.bin"), "wb") as fh:
                fh.write(rs.bytes(size))


def test_babel_listing_parallel_equals_serial(tmp_path):
    _make_tree(str(tmp_path))
    assert B.list_parallel(str(tmp_path)) == B.list_serial(str(tmp_path))


def test_babel_sync_and_verify(tmp_path):
    src = str(tmp_path / "src")
    dst = str(tmp_path / "dst")
    os.makedirs(src)
    _make_tree(src)
    rep = B.Babel(verify="sampled").sync(src, dst)
    assert rep.files_copied == rep.files_total == 24
    assert not rep.verify_failures
    # idempotent: second sync copies nothing
    rep2 = B.Babel(verify="off").sync(src, dst)
    assert rep2.files_copied == 0
    # corrupt a destination file -> verification catches it
    victim = os.path.join(dst, "shard_0", "f0.bin")
    data = bytearray(open(victim, "rb").read())
    data[10] ^= 0xFF
    open(victim, "wb").write(bytes(data))
    os.utime(victim, (0, 0))  # make it look in-sync
    os.utime(os.path.join(src, "shard_0", "f0.bin"), (0, 0))
    rep3 = B.Babel(verify="sampled").sync(src, dst)
    assert "shard_0/f0.bin" in rep3.verify_failures


def test_babel_sharded_large_file(tmp_path):
    src = str(tmp_path / "s")
    dst = str(tmp_path / "d")
    os.makedirs(src)
    big = np.random.RandomState(1).bytes(3 << 20)
    with open(os.path.join(src, "big.bin"), "wb") as f:
        f.write(big)
    B.Babel(chunk_bytes=1 << 20, verify="full").sync(src, dst)
    assert open(os.path.join(dst, "big.bin"), "rb").read() == big


def test_crc_sampled_is_size_independent():
    # cost should not scale with file size (the 100GB-in-3s claim shape)
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        small = os.path.join(d, "s")
        large = os.path.join(d, "l")
        open(small, "wb").write(os.urandom(1 << 16))
        open(large, "wb").write(os.urandom(1 << 24))
        t0 = time.perf_counter()
        B.crc_sampled(small)
        t_small = time.perf_counter() - t0
        t0 = time.perf_counter()
        B.crc_sampled(large)
        t_large = time.perf_counter() - t0
        assert t_large < max(t_small, 1e-3) * 50   # ~O(1) in file size


# ---------------------------------------------------------------------------
# XPUTimer
# ---------------------------------------------------------------------------


def test_xputimer_spans_and_diagnosis():
    t = XPUTimer()
    for i in range(50):
        with t.span("step"):
            time.sleep(0.0002 if i != 25 else 0.01)   # one straggler
        with t.span("data"):
            pass
    rep = t.diagnose(slow_sigma=3.0)
    assert rep["spans"]["step"]["count"] == 50
    assert rep["dominant_span"]["name"] == "step"
    assert any(a["span"] == "step" for a in rep["anomalies"])
    # compressed log is far smaller than full tracing of the same events
    assert rep["log_bytes"] < 20 * rep["full_tracing_bytes"]


def test_xputimer_selective_tracing_and_errors():
    t = XPUTimer(traced_apis=["important"])
    with t.span("ignored"):
        pass
    assert "ignored" not in t.stats
    with pytest.raises(ValueError):
        with t.span("important"):
            raise ValueError("boom")
    assert t.errors[0]["span"] == "important"     # O(1) attribution


# ---------------------------------------------------------------------------
# hetero cost model
# ---------------------------------------------------------------------------


def test_hetero_reproduces_paper_costs():
    rep = hetero.savings_report()
    assert rep["high_perf_cost_mrmb"] == pytest.approx(6.35, rel=0.05)
    assert 0.15 <= rep["savings_frac"] <= 0.35     # ~20% claim band


def test_hetero_constraints():
    d = hetero.best_single_device(need_fp8=True)
    assert d.supports_fp8
    d2 = hetero.best_single_device(memory_needed_gb=90)
    assert d2.memory_gb >= 90


# ---------------------------------------------------------------------------
# scaling laws
# ---------------------------------------------------------------------------


def test_power_law_fit_recovery():
    c = np.logspace(18, 21, 8)
    b = 0.42 * c ** 0.33
    A, alpha = SL.fit_power_law(c, b)
    assert A == pytest.approx(0.42, rel=1e-3)
    assert alpha == pytest.approx(0.33, rel=1e-3)


def test_loss_law_and_lever():
    c = np.logspace(18, 21, 10)
    moe = SL.LossLaw(a=2e3, b=0.2, l_inf=1.5)
    dense = SL.LossLaw(a=2e3 * 3 ** 0.2, b=0.2, l_inf=1.5)  # exactly 3x
    fit = SL.LossLaw.fit(c, moe(c))
    np.testing.assert_allclose(fit(c), moe(c), rtol=1e-3)
    lever = SL.efficiency_lever(moe, dense, 1e20)
    assert lever == pytest.approx(3.0, rel=1e-2)


# ---------------------------------------------------------------------------
# DPO packing
# ---------------------------------------------------------------------------


def _pairs(n=16, seed=0):
    rs = np.random.RandomState(seed)
    out = []
    for i in range(n):
        pl_ = rs.randint(5, 20)
        out.append(dpo.PairExample(
            prompt=rs.randint(0, 99, pl_).astype(np.int32),
            chosen=rs.randint(0, 99, rs.randint(10, 60)).astype(np.int32),
            rejected=rs.randint(0, 99, rs.randint(10, 60)).astype(np.int32)))
    return out


def test_dpo_packing_speedup():
    rep = dpo.packing_speedup(_pairs(64), max_len=1024)
    assert rep["speedup"] > 2.0                    # 3.7x-claim shape
    assert rep["useful_frac_packed"] > rep["useful_frac_padded"] * 2


def test_dpo_loss_prefers_chosen():
    lp_c = jnp.asarray([-5.0, -6.0])
    lp_r = jnp.asarray([-9.0, -10.0])
    good, mg = dpo.dpo_loss(lp_c, lp_r, lp_c * 0 - 7, lp_r * 0 - 7)
    bad, mb = dpo.dpo_loss(lp_r, lp_c, lp_c * 0 - 7, lp_r * 0 - 7)
    assert float(good[0] if isinstance(good, tuple) else good) < \
        float(bad[0] if isinstance(bad, tuple) else bad)
    assert float(mg["preference_acc"]) == 1.0


def test_segment_pooling_matches_per_sequence():
    """Packed-layout pooled log-probs == unpacked per-sequence log-probs."""
    rs = np.random.RandomState(3)
    pairs = _pairs(4, seed=3)
    packed = dpo.pack_pairs(pairs, max_len=512)
    V = 100
    logits = jnp.asarray(rs.randn(*packed["tokens"].shape, V), jnp.float32)
    (chosen, rejected), counts = dpo.segment_pooled_logps(
        logits, jnp.asarray(packed["tokens"]),
        jnp.asarray(packed["resp_mask"]), jnp.asarray(packed["segment_ids"]),
        packed["n_pairs"])
    # reference: recompute from flat rows
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(
        logits, jnp.asarray(packed["tokens"])[..., None], axis=-1)[..., 0]
    tok_lp = np.asarray((picked - logz) * packed["resp_mask"])
    seg = packed["segment_ids"]
    for pid in range(packed["n_pairs"]):
        want_c = tok_lp[seg == 2 * pid].sum()
        want_r = tok_lp[seg == 2 * pid + 1].sum()
        assert float(chosen[pid]) == pytest.approx(want_c, rel=1e-4)
        assert float(rejected[pid]) == pytest.approx(want_r, rel=1e-4)


# ---------------------------------------------------------------------------
# Flood engine (scheduling level)
# ---------------------------------------------------------------------------


def _stub_engine(n_stages=4, micro=4):
    def embed_fn(reqs):
        return {"n": len(reqs)}

    def stage_fn(x):
        return x

    def head_fn(x, reqs):
        return [r.rid % 50 for r in reqs]

    return embed_fn, [stage_fn] * n_stages, head_fn


def test_flood_completes_all_requests():
    embed, stages, head = _stub_engine()
    eng = FloodEngine(stages, head, embed,
                      cache=SegmentCache(4096, 16, 16), microbatch=4)
    reqs = [GenRequest(i, np.arange(4, dtype=np.int32), max_new=5)
            for i in range(12)]
    eng.submit(reqs)
    stats = eng.run()
    assert all(len(r.out) == 5 for r in reqs)
    assert stats.tokens_out == 60
    eng.cache.check_invariants()


def test_flood_beats_sync_baseline_with_sync_overhead():
    """With per-step global-sync cost (the TP pattern), the pipeline engine
    sustains higher token throughput — the Table-3 direction."""
    embed, stages, head = _stub_engine()
    reqs_a = [GenRequest(i, np.arange(4, dtype=np.int32), max_new=8)
              for i in range(16)]
    reqs_b = [GenRequest(i, np.arange(4, dtype=np.int32), max_new=8)
              for i in range(16)]
    eng = FloodEngine(stages, head, embed,
                      cache=SegmentCache(1 << 16, 16, 16), microbatch=4)
    eng.submit(reqs_a)
    flood = eng.run()
    base = baseline_step_engine(lambda x, r: head(x, r), embed, reqs_b,
                                sync_overhead_s=0.002)
    assert flood.tokens_out == base.tokens_out
    assert flood.tokens_per_s > base.tokens_per_s
