"""Scheduler-policy layer of the online engine: decode-priority never
starves in-flight slots, prefill-priority bounds head-of-queue TTFT,
per-tenant token budgets gate admission, the bounded-queue saturation
gate sheds/defers exactly at the limit, and switching policies at
runtime causes zero recompiles (policies are pure host bookkeeping over
the same jitted steps)."""
import numpy as np
import pytest

from repro import api
from repro.configs.base import get_smoke_config
from repro.launch.mesh import make_local_mesh
from repro.serving.online import OnlineConfig, OnlineEngine, OnlineRequest


@pytest.fixture(scope="module")
def runner_params():
    cfg = get_smoke_config("ling-lite")
    runner = api.Runner(cfg, make_local_mesh(1, 1), fsdp=False,
                        seq_parallel=False, max_seq=64)
    return runner, runner.init_params(0)


def _prompt(seed, n, vocab):
    return np.random.RandomState(seed).randint(0, vocab, n).astype(np.int32)


def _starvation_run(runner, params, policy):
    """A decoding long request vs an arriving page-hungry prompt in a
    pool too small for both to grow freely."""
    v = runner.cfg.vocab_size
    eng = OnlineEngine(runner, params,
                       OnlineConfig(max_slots=2, max_context=32,
                                    page_size=8, n_pages=5,
                                    prefill_chunk=4, policy=policy))
    a = OnlineRequest(rid=0, prompt=_prompt(0, 6, v), max_new=16)
    eng.submit(a)
    while a.state != "decode":
        eng.tick()
    # B's 23+1 tokens fill exactly 3 pages at prefill time — in the
    # 4-usable-page pool only its PREFILL growth can collide with A
    b = OnlineRequest(rid=1, prompt=_prompt(1, 23, v), max_new=1)
    eng.submit(b)
    eng.run(max_ticks=500)
    assert a.done and b.done
    assert len(a.out) == 16 and len(b.out) == 1
    return a, b, eng


def test_decode_priority_never_starves_decoders(runner_params):
    """Under fcfs the arriving prompt's growth preempts the in-flight
    decoder; under decode-priority prefill growth defers instead — the
    decoder is NEVER preempted by an arrival, it just finishes first."""
    runner, params = runner_params
    a_f, _, _ = _starvation_run(runner, params, "fcfs")
    assert a_f.n_preempted > 0, \
        "scenario must be tight enough that fcfs preempts the decoder"
    a_d, b_d, eng = _starvation_run(runner, params, "decode-priority")
    assert a_d.n_preempted == 0
    # the arriving prompt may itself be preempted by the decoder's
    # growth (decoders win both ways), but never the other way around
    assert eng.n_preemptions == b_d.n_preempted
    # both policies emit identical tokens for the decoder (preemption
    # replay never re-samples) — priority changes latency, not content
    assert a_d.out == a_f.out


def _ttft_ticks(runner, params, policy):
    """Fill every slot with decoders, then count engine ticks from
    submission of a long-prompt head request to its first token."""
    v = runner.cfg.vocab_size
    # one slot stays free so the head request ADMITS immediately — the
    # measured difference is pure chunk scheduling, not slot wait
    eng = OnlineEngine(runner, params,
                       OnlineConfig(max_slots=4, max_context=64,
                                    page_size=8, prefill_chunk=4,
                                    policy=policy))
    decoders = [OnlineRequest(rid=i, prompt=_prompt(i, 2, v), max_new=40)
                for i in range(3)]
    eng.submit_many(decoders)
    while not all(d.state == "decode" for d in decoders):
        eng.tick()
    head = OnlineRequest(rid=10, prompt=_prompt(10, 16, v), max_new=2)
    eng.submit(head)
    ticks = 0
    while not head.out:
        eng.tick()
        ticks += 1
        assert ticks < 100
    eng.run(max_ticks=1000)
    return ticks


def test_prefill_priority_bounds_head_of_queue_ttft(runner_params):
    """prefill-priority drains ALL of the head request's chunks in one
    tick (admission + 4 chunks + first token), so TTFT is bounded by ~1
    tick; fcfs spreads the 4 chunks across 4 ticks."""
    runner, params = runner_params
    fcfs = _ttft_ticks(runner, params, "fcfs")
    pp = _ttft_ticks(runner, params, "prefill-priority")
    assert pp <= 2, pp
    assert fcfs >= 4, fcfs
    assert pp < fcfs


def test_tenant_budgets_enforced_at_admission(runner_params):
    """A tenant over its admitted prompt+max_new token budget is held in
    the queue (FCFS order preserved) while other tenants admit past it;
    the held request admits once the tenant's earlier work finishes."""
    runner, params = runner_params
    v = runner.cfg.vocab_size
    eng = OnlineEngine(runner, params,
                       OnlineConfig(max_slots=4, max_context=32,
                                    page_size=8, prefill_chunk=4,
                                    tenant_budgets={"t1": 24}))
    # four t1 requests of cost 8 each (budget fits 3) + one t2 behind
    reqs = [OnlineRequest(rid=i, prompt=_prompt(i, 4, v), max_new=4,
                          tenant="t1") for i in range(4)]
    reqs.append(OnlineRequest(rid=9, prompt=_prompt(9, 4, v), max_new=4,
                              tenant="t2"))
    eng.submit_many(reqs)
    eng.tick()
    # rid 3 (over budget) was skipped; rid 9 (other tenant) admitted
    assert eng.admission_log == [0, 1, 2, 9]
    assert eng.n_budget_skips >= 1
    assert reqs[3].state == "queued"
    eng.run(max_ticks=500)
    assert all(r.done for r in reqs)
    # the held request admitted only after budget freed up
    assert eng.admission_log.index(3) > eng.admission_log.index(9)


def test_saturation_gate_sheds_exactly_at_max_queue(runner_params):
    """overload="shed": the first submit past max_queue is marked shed
    and dropped; everything enqueued before the limit completes."""
    runner, params = runner_params
    v = runner.cfg.vocab_size
    eng = OnlineEngine(runner, params,
                       OnlineConfig(max_slots=2, max_context=32,
                                    page_size=8, prefill_chunk=4,
                                    max_queue=2, overload="shed"))
    oks = [eng.submit(OnlineRequest(rid=i, prompt=_prompt(i, 4, v),
                                    max_new=2))
           for i in range(3)]
    assert oks == [True, True, False]
    assert eng.n_shed == 1
    shed = OnlineRequest(rid=99, prompt=_prompt(99, 4, v), max_new=2)
    assert not eng.submit(shed)
    assert shed.state == "shed" and eng.n_shed == 2
    assert 99 not in eng.reqs            # shed requests never enter
    eng.run(max_ticks=200)
    assert eng.reqs[0].done and eng.reqs[1].done


def test_saturation_gate_defer_allows_retry(runner_params):
    """overload="defer": a full queue returns False WITHOUT shedding —
    the caller retries after the engine drains and the request then
    completes normally."""
    runner, params = runner_params
    v = runner.cfg.vocab_size
    eng = OnlineEngine(runner, params,
                       OnlineConfig(max_slots=2, max_context=32,
                                    page_size=8, prefill_chunk=4,
                                    max_queue=1, overload="defer"))
    assert eng.submit(OnlineRequest(rid=0, prompt=_prompt(0, 4, v),
                                    max_new=2))
    late = OnlineRequest(rid=1, prompt=_prompt(1, 4, v), max_new=2)
    assert not eng.submit(late)
    assert late.state == "queued" and eng.n_shed == 0
    while not eng.submit(late):          # retry until the queue drains
        eng.tick()
    eng.run(max_ticks=200)
    assert late.done


def test_policy_switch_zero_recompiles(runner_params):
    """One engine cycles through every policy under churn (admission,
    preemption, radix eviction, completion) and still compiles exactly
    one prefill + one decode step — policy and cache state are host
    data, never trace inputs."""
    runner, params = runner_params
    v = runner.cfg.vocab_size
    eng = OnlineEngine(runner, params,
                       OnlineConfig(max_slots=4, max_context=32,
                                    page_size=8, n_pages=7,
                                    prefill_chunk=4))
    rid = 0
    for policy in ("fcfs", "decode-priority", "prefill-priority", "fcfs"):
        eng.set_policy(policy)
        reqs = [OnlineRequest(rid=rid + i,
                              prompt=_prompt(rid + i, 4 + i % 5, v),
                              max_new=4 + i % 5)
                for i in range(6)]
        rid += 6
        eng.submit_many(reqs)
        eng.run(max_ticks=2000)
        assert all(r.done for r in reqs)
    assert eng.prefill_traces == 1, eng.prefill_traces
    assert eng.decode_traces == 1, eng.decode_traces
    eng.alloc.check_invariants()
    with pytest.raises(ValueError, match="policy"):
        eng.set_policy("sjf")


def test_invalid_policy_and_gate_config_rejected(runner_params):
    runner, params = runner_params
    with pytest.raises(ValueError, match="policy"):
        OnlineEngine(runner, params,
                     OnlineConfig(max_slots=2, max_context=32,
                                  policy="round-robin"))
    with pytest.raises(ValueError, match="overload"):
        OnlineEngine(runner, params,
                     OnlineConfig(max_slots=2, max_context=32,
                                  overload="drop"))
    with pytest.raises(ValueError, match="max_queue"):
        OnlineEngine(runner, params,
                     OnlineConfig(max_slots=2, max_context=32,
                                  max_queue=0))
