"""Docs health: internal links resolve, the repo map is complete, and the
code blocks in README.md actually import/run against this tree."""
import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_check_docs_clean():
    res = subprocess.run(
        [sys.executable, os.path.join(ROOT, "scripts", "check_docs.py")],
        capture_output=True, text=True, timeout=60)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "check_docs: OK" in res.stdout


def _code_blocks(md_path, lang):
    text = open(md_path).read()
    return re.findall(rf"```{lang}\n(.*?)```", text, re.DOTALL)


def test_readme_imports_resolve():
    """Every module referenced by README code blocks (python -m targets
    and `from repro...` imports) must be importable with PYTHONPATH=src."""
    targets = set()
    for block in _code_blocks(os.path.join(ROOT, "README.md"), "sh"):
        for m in re.findall(r"-m\s+([\w.]+)", block):
            targets.add(m)
    for block in _code_blocks(os.path.join(ROOT, "README.md"), "python"):
        for m in re.findall(r"^\s*(?:from|import)\s+([\w.]+)", block,
                            re.MULTILINE):
            targets.add(m)
    assert targets, "README has no runnable references to check"
    src = ("import importlib.util, sys\n"
           "mods = sys.argv[1:]\n"
           "missing = [m for m in mods if importlib.util.find_spec(m) is "
           "None]\n"
           "assert not missing, missing\n"
           "print('IMPORTS OK', len(mods))\n")
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    res = subprocess.run([sys.executable, "-c", src, *sorted(targets)],
                         capture_output=True, text=True, timeout=120,
                         env=env, cwd=ROOT)
    assert res.returncode == 0, res.stdout + res.stderr


def test_readme_quickstart_files_exist():
    """Scripts the README tells users to run must exist."""
    readme = open(os.path.join(ROOT, "README.md")).read()
    for rel in re.findall(r"(?:python|PYTHONPATH=src python)\s+"
                          r"((?:examples|scripts)/[\w/]+\.py)", readme):
        assert os.path.exists(os.path.join(ROOT, rel)), rel
