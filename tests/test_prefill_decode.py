"""Prefill -> decode consistency: prefilling a prompt and decoding from the
emitted caches must produce the same tokens as feeding the prompt through
decode_step one token at a time (the serving engine's correctness
contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs.base import get_smoke_config
from repro.launch.mesh import make_local_mesh
from repro.models import model as M

B, S_PROMPT, S_MAX = 2, 16, 64


@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "h2o-danube-1.8b",
                                  "rwkv6-3b", "deepseek-moe-16b"])
def test_prefill_then_decode_matches_stepwise(arch):
    cfg = get_smoke_config(arch)
    mesh = make_local_mesh(1, 1)
    runner = api.Runner(cfg, mesh, fsdp=False, seq_parallel=False,
                        max_seq=S_MAX)
    params = runner.init_params(0)
    decode, _ = runner.make_decode_step(global_batch=B, seq_len=S_MAX)
    decode = jax.jit(decode)

    rs = np.random.RandomState(0)
    prompt = jnp.asarray(rs.randint(0, cfg.vocab_size, (B, S_PROMPT)),
                         jnp.int32)

    # path A: feed the prompt token-by-token through decode, then generate
    caches = M.init_caches(cfg, runner.env, B, S_MAX,
                           cross_len=cfg.encoder_seq_len)
    nxt = None
    for pos in range(S_PROMPT):
        nxt, caches = decode(params, caches, prompt[:, pos], jnp.int32(pos))
    gen_a = [nxt]
    tok = nxt
    for pos in range(S_PROMPT, S_PROMPT + 4):
        tok, caches = decode(params, caches, tok, jnp.int32(pos))
        gen_a.append(tok)   # device until the loop ends (FC-HOSTSYNC)
    gen_a = [np.asarray(g) for g in jax.device_get(gen_a)]

    # path B: prefill emits the caches wholesale, then decode continues.
    # (smoke configs run at tp=1 so the prefill cache S-slice is the full
    # sequence; pad the prompt buffer region to S_MAX for cache layout)
    prefill = jax.jit(runner.make_prefill(global_batch=B))
    batch = {"tokens": prompt}
    if cfg.is_encoder_decoder:
        batch["enc_frames"] = jnp.zeros((B, cfg.encoder_seq_len,
                                         cfg.d_model), jnp.bfloat16)
    first, pcaches = prefill(params, batch)

    # prefill caches cover S_PROMPT positions; grow attention caches to
    # S_MAX by padding the sequence axis (positions beyond are masked by
    # the decode validity rule)
    def grow(leaf_path, leaf, ref):
        if leaf.shape == ref.shape:
            return leaf
        pads = [(0, r - l) for l, r in zip(leaf.shape, ref.shape)]
        return jnp.pad(leaf, pads)

    ref_caches = M.init_caches(cfg, runner.env, B, S_MAX,
                               cross_len=cfg.encoder_seq_len)
    pcaches = jax.tree.map(lambda l, r: grow(None, l, r), pcaches,
                           ref_caches)

    assert np.array_equal(np.asarray(first), gen_a[0]), \
        (np.asarray(first), gen_a[0])
    tok = first
    gen_b = []
    for pos in range(S_PROMPT, S_PROMPT + 4):
        tok, pcaches = decode(params, pcaches, tok, jnp.int32(pos))
        gen_b.append(tok)   # device until the loop ends (FC-HOSTSYNC)
    for i, tok_b in enumerate(jax.device_get(gen_b)):
        np.testing.assert_array_equal(tok_b, gen_a[i + 1])
