"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs the
pure-jnp oracles in repro/kernels/ref.py (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# grouped_matmul
# ---------------------------------------------------------------------------

GM_CASES = [
    # (M, K, N, group_sizes, dtype)
    (64, 32, 48, [16, 32, 16], jnp.float32),
    (128, 64, 64, [0, 100, 28], jnp.float32),
    (96, 16, 32, [96], jnp.float32),
    (256, 128, 128, [7, 120, 1, 100, 28], jnp.float32),
    (64, 32, 32, [10, 20, 30], jnp.bfloat16),
    (200, 64, 96, [50, 0, 0, 150], jnp.float32),   # empty groups
]


@pytest.mark.parametrize("M,K,N,gs,dtype", GM_CASES)
def test_grouped_matmul(M, K, N, gs, dtype):
    rs = np.random.RandomState(0)
    G = len(gs)
    lhs = jnp.asarray(rs.randn(M, K), dtype)
    rhs = jnp.asarray(rs.randn(G, K, N) * 0.1, dtype)
    sizes = jnp.asarray(gs, jnp.int32)
    got = ops.grouped_matmul(lhs, rhs, sizes, bm=32, interpret=True)
    want = ref.grouped_matmul_ref(lhs, rhs, sizes)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_grouped_matmul_vs_ragged_dot():
    rs = np.random.RandomState(1)
    lhs = jnp.asarray(rs.randn(128, 64), jnp.float32)
    rhs = jnp.asarray(rs.randn(4, 64, 32) * 0.1, jnp.float32)
    sizes = jnp.asarray([30, 50, 8, 40], jnp.int32)
    got = ops.grouped_matmul(lhs, rhs, sizes, bm=32, interpret=True)
    want = jax.lax.ragged_dot(lhs, rhs, sizes)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# fused MoE FFN pipeline (gather -> grouped two-GEMM FFN -> combine)
# ---------------------------------------------------------------------------

FUSED_CASES = [
    # (T, d, ff, group_sizes, cap, gated, dtype) — cap > sum(gs) means the
    # trailing slots are overflow/dropped rows
    (48, 32, 48, [10, 22, 16], 48, True, jnp.float32),     # uneven, exact
    (64, 32, 64, [0, 40, 0, 15], 64, True, jnp.float32),   # empty groups
    (50, 32, 48, [13, 0, 25, 7, 20], 70, True, jnp.float32),  # overflow
    (48, 32, 48, [10, 22, 16], 48, False, jnp.float32),    # non-gated
    (48, 32, 64, [18, 30], 60, True, jnp.bfloat16),        # bf16 in
    (32, 16, 32, [32], 32, True, jnp.float32),             # single group
]


def _fused_inputs(T, d, ff, gs, cap, gated, dtype, seed=7):
    rs = np.random.RandomState(seed)
    G = len(gs)
    x = jnp.asarray(rs.randn(T, d), dtype)
    w1 = jnp.asarray(rs.randn(G, d, ff) * 0.1, dtype)
    w2 = jnp.asarray(rs.randn(G, ff, d) * 0.1, dtype)
    w3 = jnp.asarray(rs.randn(G, d, ff) * 0.1, dtype) if gated else None
    tok = jnp.asarray(rs.randint(0, T, cap), jnp.int32)
    gate = jnp.asarray(rs.rand(cap), jnp.float32)
    sizes = jnp.asarray(gs, jnp.int32)
    return x, w1, w2, w3, tok, gate, sizes


@pytest.mark.parametrize("T,d,ff,gs,cap,gated,dtype", FUSED_CASES)
def test_fused_moe_ffn_vs_oracle(T, d, ff, gs, cap, gated, dtype):
    x, w1, w2, w3, tok, gate, sizes = _fused_inputs(T, d, ff, gs, cap,
                                                    gated, dtype)
    act = "swiglu" if gated else "gelu"
    got = ops.moe_fused_ffn(x, w1, w2, w3, tok, gate, sizes, act=act,
                            bm=16, bf=16, interpret=True)
    want = ref.fused_moe_ffn_ref(x, w1, w2, w3, tok, gate, sizes, act=act)
    assert got.dtype == jnp.float32            # fp32 accumulation out
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_fused_moe_ffn_vs_ragged_dot_composition():
    """Fused pipeline == gather + jax.lax.ragged_dot FFN + scatter-add
    (the unfused reference the dispatch modes fall back to)."""
    T, d, ff, gs, cap = 50, 32, 48, [13, 0, 25, 7, 20], 70
    x, w1, w2, w3, tok, gate, sizes = _fused_inputs(T, d, ff, gs, cap,
                                                    True, jnp.float32)
    got = ops.moe_fused_ffn(x, w1, w2, w3, tok, gate, sizes, bm=16, bf=16,
                            interpret=True)
    xs = jnp.take(x, tok, axis=0)
    h = jax.nn.silu(jax.lax.ragged_dot(xs, w1, sizes)) \
        * jax.lax.ragged_dot(xs, w3, sizes)
    out = jax.lax.ragged_dot(h, w2, sizes) * gate[:, None]
    want = jnp.zeros((T, d), jnp.float32).at[tok].add(out)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_fused_moe_ffn_bf16_fp32_accum():
    """bf16 inputs accumulate in fp32: the fused result must sit closer to
    the fp32 oracle than pure-bf16 compute would."""
    T, d, ff, gs, cap = 48, 32, 64, [18, 30], 48
    x, w1, w2, w3, tok, gate, sizes = _fused_inputs(T, d, ff, gs, cap,
                                                    True, jnp.bfloat16)
    got = ops.moe_fused_ffn(x, w1, w2, w3, tok, gate, sizes, bm=16, bf=16,
                            interpret=True)
    want = ref.fused_moe_ffn_ref(x, w1, w2, w3, tok, gate, sizes)
    err = np.max(np.abs(np.asarray(got) - np.asarray(want)))
    assert err < 1e-5                # identical fp32 math, just bf16 inputs


def test_fused_moe_ffn_all_dropped():
    """gate == 0 everywhere (or empty buffer) must produce exact zeros."""
    T, d, ff = 32, 16, 32
    x, w1, w2, w3, tok, gate, sizes = _fused_inputs(T, d, ff, [20], 32,
                                                    True, jnp.float32)
    got = ops.moe_fused_ffn(x, w1, w2, w3, tok, jnp.zeros_like(gate),
                            sizes, bm=16, bf=16, interpret=True)
    assert float(jnp.max(jnp.abs(got))) == 0.0


# ---------------------------------------------------------------------------
# normhead
# ---------------------------------------------------------------------------

NH_CASES = [
    (64, 128, 256, jnp.float32),
    (32, 64, 96, jnp.float32),
    (128, 256, 512, jnp.bfloat16),
    (16, 32, 64, jnp.float32),
]


@pytest.mark.parametrize("T,d,V,dtype", NH_CASES)
def test_normhead(T, d, V, dtype):
    rs = np.random.RandomState(2)
    x = jnp.asarray(rs.randn(T, d), dtype)
    w = jnp.asarray(rs.randn(V, d), dtype)
    got = ops.normhead_logits(x, w, bt=16, bv=32, bk=32, interpret=True)
    want = ref.normhead_ref(x, w)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_normhead_rows_unit_norm_effect():
    """Scaling a row of W must not change its logits (Eq. 4 property)."""
    rs = np.random.RandomState(3)
    x = jnp.asarray(rs.randn(8, 64), jnp.float32)
    w = jnp.asarray(rs.randn(32, 64), jnp.float32)
    w2 = w.at[5].multiply(37.0)
    a = ops.normhead_logits(x, w, interpret=True)
    b = ops.normhead_logits(x, w2, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------

WKV_CASES = [
    (1, 32, 2, 16, 16),
    (2, 64, 3, 32, 32),
    (2, 128, 2, 64, 64),
    (1, 48, 1, 64, 16),   # chunk not dividing T -> shrinks
]


@pytest.mark.parametrize("B,T,H,hd,chunk", WKV_CASES)
def test_wkv6(B, T, H, hd, chunk):
    rs = np.random.RandomState(4)
    r = jnp.asarray(rs.randn(B, T, H, hd), jnp.float32)
    k = jnp.asarray(rs.randn(B, T, H, hd) * 0.3, jnp.float32)
    v = jnp.asarray(rs.randn(B, T, H, hd) * 0.3, jnp.float32)
    w = jnp.asarray(rs.uniform(0.6, 0.99, (B, T, H, hd)), jnp.float32)
    u = jnp.asarray(rs.randn(H, hd) * 0.2, jnp.float32)
    s0 = jnp.asarray(rs.randn(B, H, hd, hd) * 0.1, jnp.float32)
    y, sT = ops.wkv6(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    y_ref, sT_ref = ref.wkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_ref),
                               rtol=2e-4, atol=2e-4)


def test_wkv6_matches_model_scan():
    """The model's wkv6_scan and the kernel agree (same oracle)."""
    from repro.models.rwkv6 import wkv6_scan
    rs = np.random.RandomState(5)
    B, T, H, hd = 2, 32, 2, 16
    r = jnp.asarray(rs.randn(B, T, H, hd), jnp.float32)
    k = jnp.asarray(rs.randn(B, T, H, hd) * 0.3, jnp.float32)
    v = jnp.asarray(rs.randn(B, T, H, hd) * 0.3, jnp.float32)
    w = jnp.asarray(rs.uniform(0.6, 0.99, (B, T, H, hd)), jnp.float32)
    u = jnp.asarray(rs.randn(H, hd) * 0.2, jnp.float32)
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    y1, s1 = wkv6_scan(r, k, v, w, u, s0)
    y2, s2 = ops.wkv6(r, k, v, w, u, s0, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4,
                               atol=2e-4)
