"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs the
pure-jnp oracles in repro/kernels/ref.py (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# grouped_matmul
# ---------------------------------------------------------------------------

GM_CASES = [
    # (M, K, N, group_sizes, dtype)
    (64, 32, 48, [16, 32, 16], jnp.float32),
    (128, 64, 64, [0, 100, 28], jnp.float32),
    (96, 16, 32, [96], jnp.float32),
    (256, 128, 128, [7, 120, 1, 100, 28], jnp.float32),
    (64, 32, 32, [10, 20, 30], jnp.bfloat16),
    (200, 64, 96, [50, 0, 0, 150], jnp.float32),   # empty groups
]


@pytest.mark.parametrize("M,K,N,gs,dtype", GM_CASES)
def test_grouped_matmul(M, K, N, gs, dtype):
    rs = np.random.RandomState(0)
    G = len(gs)
    lhs = jnp.asarray(rs.randn(M, K), dtype)
    rhs = jnp.asarray(rs.randn(G, K, N) * 0.1, dtype)
    sizes = jnp.asarray(gs, jnp.int32)
    got = ops.grouped_matmul(lhs, rhs, sizes, bm=32, interpret=True)
    want = ref.grouped_matmul_ref(lhs, rhs, sizes)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_grouped_matmul_vs_ragged_dot():
    rs = np.random.RandomState(1)
    lhs = jnp.asarray(rs.randn(128, 64), jnp.float32)
    rhs = jnp.asarray(rs.randn(4, 64, 32) * 0.1, jnp.float32)
    sizes = jnp.asarray([30, 50, 8, 40], jnp.int32)
    got = ops.grouped_matmul(lhs, rhs, sizes, bm=32, interpret=True)
    want = jax.lax.ragged_dot(lhs, rhs, sizes)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# normhead
# ---------------------------------------------------------------------------

NH_CASES = [
    (64, 128, 256, jnp.float32),
    (32, 64, 96, jnp.float32),
    (128, 256, 512, jnp.bfloat16),
    (16, 32, 64, jnp.float32),
]


@pytest.mark.parametrize("T,d,V,dtype", NH_CASES)
def test_normhead(T, d, V, dtype):
    rs = np.random.RandomState(2)
    x = jnp.asarray(rs.randn(T, d), dtype)
    w = jnp.asarray(rs.randn(V, d), dtype)
    got = ops.normhead_logits(x, w, bt=16, bv=32, bk=32, interpret=True)
    want = ref.normhead_ref(x, w)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_normhead_rows_unit_norm_effect():
    """Scaling a row of W must not change its logits (Eq. 4 property)."""
    rs = np.random.RandomState(3)
    x = jnp.asarray(rs.randn(8, 64), jnp.float32)
    w = jnp.asarray(rs.randn(32, 64), jnp.float32)
    w2 = w.at[5].multiply(37.0)
    a = ops.normhead_logits(x, w, interpret=True)
    b = ops.normhead_logits(x, w2, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------

WKV_CASES = [
    (1, 32, 2, 16, 16),
    (2, 64, 3, 32, 32),
    (2, 128, 2, 64, 64),
    (1, 48, 1, 64, 16),   # chunk not dividing T -> shrinks
]


@pytest.mark.parametrize("B,T,H,hd,chunk", WKV_CASES)
def test_wkv6(B, T, H, hd, chunk):
    rs = np.random.RandomState(4)
    r = jnp.asarray(rs.randn(B, T, H, hd), jnp.float32)
    k = jnp.asarray(rs.randn(B, T, H, hd) * 0.3, jnp.float32)
    v = jnp.asarray(rs.randn(B, T, H, hd) * 0.3, jnp.float32)
    w = jnp.asarray(rs.uniform(0.6, 0.99, (B, T, H, hd)), jnp.float32)
    u = jnp.asarray(rs.randn(H, hd) * 0.2, jnp.float32)
    s0 = jnp.asarray(rs.randn(B, H, hd, hd) * 0.1, jnp.float32)
    y, sT = ops.wkv6(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    y_ref, sT_ref = ref.wkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_ref),
                               rtol=2e-4, atol=2e-4)


def test_wkv6_matches_model_scan():
    """The model's wkv6_scan and the kernel agree (same oracle)."""
    from repro.models.rwkv6 import wkv6_scan
    rs = np.random.RandomState(5)
    B, T, H, hd = 2, 32, 2, 16
    r = jnp.asarray(rs.randn(B, T, H, hd), jnp.float32)
    k = jnp.asarray(rs.randn(B, T, H, hd) * 0.3, jnp.float32)
    v = jnp.asarray(rs.randn(B, T, H, hd) * 0.3, jnp.float32)
    w = jnp.asarray(rs.uniform(0.6, 0.99, (B, T, H, hd)), jnp.float32)
    u = jnp.asarray(rs.randn(H, hd) * 0.2, jnp.float32)
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    y1, s1 = wkv6_scan(r, k, v, w, u, s0)
    y2, s2 = ops.wkv6(r, k, v, w, u, s0, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4,
                               atol=2e-4)
