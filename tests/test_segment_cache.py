"""Segment KV cache: unit tests + hypothesis property tests on the
allocator invariants (no overlap, coalesced free list, waiter progress)."""
import numpy as np
import pytest
from util import given, settings, st   # hypothesis, or a skip shim

from repro.serving.segment_cache import SegmentCache


def test_admit_and_write():
    c = SegmentCache(max_tokens=1024, initial_segment=16, extend_chunk=16)
    assert c.admit(1, prompt_len=8, max_new=100)
    slots = [c.write_token(1) for _ in range(30)]
    assert all(s is not None for s in slots)
    assert len(set(slots)) == 30                  # distinct cache rows
    c.check_invariants()
    assert c.stats["extends"] >= 1                # grew past the first seg


def test_extend_prefers_adjacent():
    c = SegmentCache(max_tokens=1024, initial_segment=8, extend_chunk=8)
    c.admit(1, 4, 100)
    for _ in range(40):
        c.write_token(1)
    # single request: all growth should be in-place extension
    assert c.stats["appends"] == 0
    assert len(c.requests[1].segments) == 1
    c.check_invariants()


def test_append_when_blocked():
    c = SegmentCache(max_tokens=256, initial_segment=32, extend_chunk=32)
    c.admit(1, 8, 200)
    c.admit(2, 8, 200)     # sits right after request 1 -> blocks extension
    for _ in range(80):
        assert c.write_token(1) is not None
    assert c.stats["appends"] >= 1
    c.check_invariants()


def test_wait_and_revive():
    c = SegmentCache(max_tokens=80, initial_segment=32, extend_chunk=32)
    assert c.admit(1, 8, 100)           # 40 tokens
    assert c.admit(2, 8, 100)           # 40 tokens -> cache full
    # exhaust request 1's capacity; extension and append both impossible
    got_none = False
    for _ in range(200):
        if c.write_token(1) is None:
            got_none = True
            break
    assert got_none, "cache should eventually be exhausted"
    assert c.stats["waits"] >= 1
    revived = c.release(2)
    assert 1 in revived                 # waiter makes progress
    assert c.write_token(1) is not None
    c.check_invariants()


def test_prefix_caching_shares_segments():
    c = SegmentCache(max_tokens=4096, initial_segment=64, extend_chunk=64)
    c.admit(1, 32, 10)
    c.register_prefix(1, "sys-prompt")
    before_free = sum(l for _, l in c.free)
    c.admit(2, 32, 10, prefix_key="sys-prompt")
    c.admit(3, 32, 10, prefix_key="sys-prompt")
    assert c.stats["prefix_hits"] == 2
    # shared prefix: requests 2,3 allocated less fresh memory than req 1
    seg1 = c.requests[1].segments[0]
    assert seg1.refcount >= 3
    c.release(2)
    c.release(3)
    assert seg1.refcount >= 1           # still owned by request 1 + index
    c.check_invariants()


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(1, 40)),
                min_size=1, max_size=60),
       st.integers(128, 512))
def test_allocator_invariants(ops, max_tokens):
    """Random admit/write/release sequences never violate the allocator
    invariants."""
    c = SegmentCache(max_tokens=max_tokens, initial_segment=16,
                     extend_chunk=16)
    rid = 0
    live = []
    for kind, arg in ops:
        if kind == 0:  # admit
            rid += 1
            if c.admit(rid, prompt_len=arg % 16 + 1, max_new=arg):
                live.append(rid)
        elif kind == 1 and live:  # write tokens
            r = live[arg % len(live)]
            for _ in range(arg):
                if c.write_token(r) is None:
                    break
        elif kind == 2 and live:  # release
            r = live.pop(arg % len(live))
            c.release(r)
        c.check_invariants()
    # drain
    for r in list(live):
        c.release(r)
    c.check_invariants()
    assert sum(l for _, l in c.free) == max_tokens   # all memory returned
