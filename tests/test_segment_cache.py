"""Segment KV cache: unit tests + hypothesis property tests on the
allocator invariants (no overlap, coalesced free list, waiter progress),
a randomized admit/extend/release/preempt churn stress, and the
page-granular `PageAllocator` the online serving engine uses."""
import numpy as np
import pytest
from util import given, settings, st   # hypothesis, or a skip shim

from repro.serving.segment_cache import PageAllocator, SegmentCache


def test_admit_and_write():
    c = SegmentCache(max_tokens=1024, initial_segment=16, extend_chunk=16)
    assert c.admit(1, prompt_len=8, max_new=100)
    slots = [c.write_token(1) for _ in range(30)]
    assert all(s is not None for s in slots)
    assert len(set(slots)) == 30                  # distinct cache rows
    c.check_invariants()
    assert c.stats["extends"] >= 1                # grew past the first seg


def test_extend_prefers_adjacent():
    c = SegmentCache(max_tokens=1024, initial_segment=8, extend_chunk=8)
    c.admit(1, 4, 100)
    for _ in range(40):
        c.write_token(1)
    # single request: all growth should be in-place extension
    assert c.stats["appends"] == 0
    assert len(c.requests[1].segments) == 1
    c.check_invariants()


def test_append_when_blocked():
    c = SegmentCache(max_tokens=256, initial_segment=32, extend_chunk=32)
    c.admit(1, 8, 200)
    c.admit(2, 8, 200)     # sits right after request 1 -> blocks extension
    for _ in range(80):
        assert c.write_token(1) is not None
    assert c.stats["appends"] >= 1
    c.check_invariants()


def test_wait_and_revive():
    c = SegmentCache(max_tokens=80, initial_segment=32, extend_chunk=32)
    assert c.admit(1, 8, 100)           # 40 tokens
    assert c.admit(2, 8, 100)           # 40 tokens -> cache full
    # exhaust request 1's capacity; extension and append both impossible
    got_none = False
    for _ in range(200):
        if c.write_token(1) is None:
            got_none = True
            break
    assert got_none, "cache should eventually be exhausted"
    assert c.stats["waits"] >= 1
    revived = c.release(2)
    assert 1 in revived                 # waiter makes progress
    assert c.write_token(1) is not None
    c.check_invariants()


def test_prefix_caching_shares_segments():
    c = SegmentCache(max_tokens=4096, initial_segment=64, extend_chunk=64)
    c.admit(1, 32, 10)
    c.register_prefix(1, "sys-prompt")
    before_free = sum(l for _, l in c.free)
    c.admit(2, 32, 10, prefix_key="sys-prompt")
    c.admit(3, 32, 10, prefix_key="sys-prompt")
    assert c.stats["prefix_hits"] == 2
    # shared prefix: requests 2,3 allocated less fresh memory than req 1
    seg1 = c.requests[1].segments[0]
    assert seg1.refcount >= 3
    c.release(2)
    c.release(3)
    assert seg1.refcount >= 1           # still owned by request 1 + index
    c.check_invariants()


def _churn(seed: int, n_ops: int = 400, max_tokens: int = 256):
    """One deterministic admit/extend/release/preempt churn run.  Returns
    (cache, admission order, revived-waiter log) for cross-run
    comparison."""
    rs = np.random.RandomState(seed)
    c = SegmentCache(max_tokens=max_tokens, initial_segment=16,
                     extend_chunk=16)
    live, admitted, revived_log = [], [], []
    next_rid = 0
    for _ in range(n_ops):
        op = rs.randint(4)
        if op == 0:                                   # admit
            next_rid += 1
            if c.admit(next_rid, prompt_len=int(rs.randint(1, 16)),
                       max_new=int(rs.randint(1, 64))):
                live.append(next_rid)
                admitted.append(next_rid)
        elif op == 1 and live:                        # extend (write run)
            rid = live[rs.randint(len(live))]
            for _ in range(int(rs.randint(1, 24))):
                if c.write_token(rid) is None:
                    break
        elif op == 2 and live:                        # release
            rid = live.pop(rs.randint(len(live)))
            revived_log.append(tuple(c.release(rid)))
        elif op == 3 and live:                        # preempt
            rid = live.pop(rs.randint(len(live)))
            revived_log.append(tuple(c.preempt(rid)))
        c.check_invariants()
    for rid in list(live):
        c.release(rid)
    c.check_invariants()
    return c, admitted, revived_log


def test_churn_stress_admit_extend_release_preempt():
    """Randomized churn (incl. the new preempt path) never violates the
    allocator invariants, leaks no ranges, and replays identically —
    admissions AND the order waiters are revived in are deterministic."""
    for seed in (0, 1, 2):
        c, admitted, revived = _churn(seed)
        assert sum(l for _, l in c.free) == c.max_tokens   # nothing leaked
        assert not c.requests
        assert c.stats["preempts"] >= 1, "churn never preempted"
        c2, admitted2, revived2 = _churn(seed)
        assert admitted2 == admitted
        assert revived2 == revived


def test_preempt_frees_and_allows_readmission():
    c = SegmentCache(max_tokens=96, initial_segment=32, extend_chunk=32)
    assert c.admit(1, 8, 100)
    assert c.admit(2, 8, 100)
    for _ in range(20):
        assert c.write_token(1) is not None
    c.preempt(1)
    assert 1 not in c.requests
    assert c.stats["preempts"] == 1
    c.check_invariants()
    assert c.admit(1, 8, 100)          # deterministic re-admission works
    assert c.write_token(1) is not None
    c.check_invariants()


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(1, 40)),
                min_size=1, max_size=60),
       st.integers(128, 512))
def test_allocator_invariants(ops, max_tokens):
    """Random admit/write/release sequences never violate the allocator
    invariants."""
    c = SegmentCache(max_tokens=max_tokens, initial_segment=16,
                     extend_chunk=16)
    rid = 0
    live = []
    for kind, arg in ops:
        if kind == 0:  # admit
            rid += 1
            if c.admit(rid, prompt_len=arg % 16 + 1, max_new=arg):
                live.append(rid)
        elif kind == 1 and live:  # write tokens
            r = live[arg % len(live)]
            for _ in range(arg):
                if c.write_token(r) is None:
                    break
        elif kind == 2 and live:  # release
            r = live.pop(arg % len(live))
            c.release(r)
        c.check_invariants()
    # drain
    for r in list(live):
        c.release(r)
    c.check_invariants()
    assert sum(l for _, l in c.free) == max_tokens   # all memory returned


# ---------------------------------------------------------------------------
# PageAllocator (online serving)
# ---------------------------------------------------------------------------


def test_page_allocator_admit_grow_release():
    a = PageAllocator(n_pages=9, page_size=8)
    assert a.admit(1) == 0
    assert a.ensure_capacity(1, 20)           # 3 pages
    assert a.capacity(1) == 24
    assert a.n_free == 5
    row = a.table_row(1, width=6)
    assert list(row[:3]) == a.pages[1] and not row[3:].any()
    assert 0 not in a.pages[1]                # scratch page never allocated
    a.check_invariants()
    a.release(1)
    assert a.n_free == 8
    a.check_invariants()


def test_page_allocator_all_or_nothing_and_preempt():
    a = PageAllocator(n_pages=5, page_size=8)   # 4 usable pages
    a.admit(1)
    a.admit(2)
    assert a.ensure_capacity(1, 24)             # 3 pages
    before = list(a.pages[2])
    assert not a.ensure_capacity(2, 24)         # needs 3, only 1 free
    assert a.pages[2] == before                 # failed grow allocated nothing
    assert a.stats["alloc_failures"] == 1
    a.preempt(1)
    assert a.stats["preempts"] == 1
    assert a.ensure_capacity(2, 24)             # victim's pages recycled
    a.check_invariants()


def test_page_allocator_prefix_sharing_refcounts():
    a = PageAllocator(n_pages=12, page_size=8)
    a.admit(1)
    a.ensure_capacity(1, 20)                    # 2 full pages + 1 partial
    a.register_prefix(1, "sys", 16)             # only FULL pages shared
    assert len(a.prefix_index["sys"]) == 2
    shared = a.admit(2, prefix_key="sys")
    assert shared == 16
    assert a.pages[2][:2] == a.pages[1][:2]
    assert a.stats["prefix_hits"] == 1
    a.ensure_capacity(2, 24)                    # private growth page
    assert a.pages[2][2] != a.pages[1][2]
    a.check_invariants()
    a.release(1)                                # shared pages stay (index+2)
    a.check_invariants()
    a.release(2)
    held = len(a.prefix_index["sys"])
    assert a.n_free == a.n_pages - a.reserved - held
    a.drop_prefix("sys")
    assert a.n_free == a.n_pages - a.reserved
    a.check_invariants()


def test_page_allocator_deterministic_recycling():
    """Identical op sequences hand out identical page ids (the engine's
    parity and compile-count tests rely on this)."""
    def run():
        a = PageAllocator(n_pages=8, page_size=4)
        ids = []
        a.admit(1); a.ensure_capacity(1, 10)
        a.admit(2); a.ensure_capacity(2, 6)
        ids.append(list(a.pages[1]) + list(a.pages[2]))
        a.preempt(1)
        a.admit(3); a.ensure_capacity(3, 12)
        ids.append(list(a.pages[3]))
        return ids
    assert run() == run()


def test_page_allocator_prefix_clamped_to_consumer_prompt():
    """A consumer whose prompt is shorter than the published prefix must
    not attach shared pages beyond its own prompt — its decode would
    write new-token KV straight into pages other requests attend."""
    a = PageAllocator(n_pages=12, page_size=8)
    a.admit(1)
    a.ensure_capacity(1, 24)
    a.register_prefix(1, "sys", 24)             # 3 full pages published
    shared = a.admit(2, prefix_key="sys", prompt_len=16)
    assert shared == 16                          # clamped, not 24
    assert len(a.pages[2]) == 2
    assert a.pages[2] == a.pages[1][:2]
    a.check_invariants()


def test_page_allocator_reregister_prefix_releases_old():
    """Re-registering a key must drop the old entry's refcounts — the
    old pages return to the pool instead of leaking forever."""
    a = PageAllocator(n_pages=12, page_size=8)
    a.admit(1)
    a.ensure_capacity(1, 16)
    a.register_prefix(1, "sys", 16)
    a.admit(2)
    a.ensure_capacity(2, 16)
    a.register_prefix(2, "sys", 16)             # replaces the entry
    a.check_invariants()
    a.release(1)
    a.release(2)
    held = len(a.prefix_index["sys"])
    assert a.n_free == a.n_pages - a.reserved - held
    a.drop_prefix("sys")
    assert a.n_free == a.n_pages - a.reserved   # nothing leaked
    a.check_invariants()
