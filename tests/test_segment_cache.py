"""Segment KV cache: unit tests + hypothesis property tests on the
allocator invariants (no overlap, coalesced free list, waiter progress),
a randomized admit/extend/release/preempt churn stress, and the
page-granular `PageAllocator` the online serving engine uses."""
import numpy as np
import pytest
from util import given, settings, st   # hypothesis, or a skip shim

from repro.serving.segment_cache import PageAllocator, SegmentCache


def test_admit_and_write():
    c = SegmentCache(max_tokens=1024, initial_segment=16, extend_chunk=16)
    assert c.admit(1, prompt_len=8, max_new=100)
    slots = [c.write_token(1) for _ in range(30)]
    assert all(s is not None for s in slots)
    assert len(set(slots)) == 30                  # distinct cache rows
    c.check_invariants()
    assert c.stats["extends"] >= 1                # grew past the first seg


def test_extend_prefers_adjacent():
    c = SegmentCache(max_tokens=1024, initial_segment=8, extend_chunk=8)
    c.admit(1, 4, 100)
    for _ in range(40):
        c.write_token(1)
    # single request: all growth should be in-place extension
    assert c.stats["appends"] == 0
    assert len(c.requests[1].segments) == 1
    c.check_invariants()


def test_append_when_blocked():
    c = SegmentCache(max_tokens=256, initial_segment=32, extend_chunk=32)
    c.admit(1, 8, 200)
    c.admit(2, 8, 200)     # sits right after request 1 -> blocks extension
    for _ in range(80):
        assert c.write_token(1) is not None
    assert c.stats["appends"] >= 1
    c.check_invariants()


def test_wait_and_revive():
    c = SegmentCache(max_tokens=80, initial_segment=32, extend_chunk=32)
    assert c.admit(1, 8, 100)           # 40 tokens
    assert c.admit(2, 8, 100)           # 40 tokens -> cache full
    # exhaust request 1's capacity; extension and append both impossible
    got_none = False
    for _ in range(200):
        if c.write_token(1) is None:
            got_none = True
            break
    assert got_none, "cache should eventually be exhausted"
    assert c.stats["waits"] >= 1
    revived = c.release(2)
    assert 1 in revived                 # waiter makes progress
    assert c.write_token(1) is not None
    c.check_invariants()


def test_prefix_caching_shares_segments():
    c = SegmentCache(max_tokens=4096, initial_segment=64, extend_chunk=64)
    c.admit(1, 32, 10)
    c.register_prefix(1, "sys-prompt")
    before_free = sum(l for _, l in c.free)
    c.admit(2, 32, 10, prefix_key="sys-prompt")
    c.admit(3, 32, 10, prefix_key="sys-prompt")
    assert c.stats["prefix_hits"] == 2
    # shared prefix: requests 2,3 allocated less fresh memory than req 1
    seg1 = c.requests[1].segments[0]
    assert seg1.refcount >= 3
    c.release(2)
    c.release(3)
    assert seg1.refcount >= 1           # still owned by request 1 + index
    c.check_invariants()


def _churn(seed: int, n_ops: int = 400, max_tokens: int = 256):
    """One deterministic admit/extend/release/preempt churn run.  Returns
    (cache, admission order, revived-waiter log) for cross-run
    comparison."""
    rs = np.random.RandomState(seed)
    c = SegmentCache(max_tokens=max_tokens, initial_segment=16,
                     extend_chunk=16)
    live, admitted, revived_log = [], [], []
    next_rid = 0
    for _ in range(n_ops):
        op = rs.randint(4)
        if op == 0:                                   # admit
            next_rid += 1
            if c.admit(next_rid, prompt_len=int(rs.randint(1, 16)),
                       max_new=int(rs.randint(1, 64))):
                live.append(next_rid)
                admitted.append(next_rid)
        elif op == 1 and live:                        # extend (write run)
            rid = live[rs.randint(len(live))]
            for _ in range(int(rs.randint(1, 24))):
                if c.write_token(rid) is None:
                    break
        elif op == 2 and live:                        # release
            rid = live.pop(rs.randint(len(live)))
            revived_log.append(tuple(c.release(rid)))
        elif op == 3 and live:                        # preempt
            rid = live.pop(rs.randint(len(live)))
            revived_log.append(tuple(c.preempt(rid)))
        c.check_invariants()
    for rid in list(live):
        c.release(rid)
    c.check_invariants()
    return c, admitted, revived_log


def test_churn_stress_admit_extend_release_preempt():
    """Randomized churn (incl. the new preempt path) never violates the
    allocator invariants, leaks no ranges, and replays identically —
    admissions AND the order waiters are revived in are deterministic."""
    for seed in (0, 1, 2):
        c, admitted, revived = _churn(seed)
        assert sum(l for _, l in c.free) == c.max_tokens   # nothing leaked
        assert not c.requests
        assert c.stats["preempts"] >= 1, "churn never preempted"
        c2, admitted2, revived2 = _churn(seed)
        assert admitted2 == admitted
        assert revived2 == revived


def test_preempt_frees_and_allows_readmission():
    c = SegmentCache(max_tokens=96, initial_segment=32, extend_chunk=32)
    assert c.admit(1, 8, 100)
    assert c.admit(2, 8, 100)
    for _ in range(20):
        assert c.write_token(1) is not None
    c.preempt(1)
    assert 1 not in c.requests
    assert c.stats["preempts"] == 1
    c.check_invariants()
    assert c.admit(1, 8, 100)          # deterministic re-admission works
    assert c.write_token(1) is not None
    c.check_invariants()


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(1, 40)),
                min_size=1, max_size=60),
       st.integers(128, 512))
def test_allocator_invariants(ops, max_tokens):
    """Random admit/write/release sequences never violate the allocator
    invariants."""
    c = SegmentCache(max_tokens=max_tokens, initial_segment=16,
                     extend_chunk=16)
    rid = 0
    live = []
    for kind, arg in ops:
        if kind == 0:  # admit
            rid += 1
            if c.admit(rid, prompt_len=arg % 16 + 1, max_new=arg):
                live.append(rid)
        elif kind == 1 and live:  # write tokens
            r = live[arg % len(live)]
            for _ in range(arg):
                if c.write_token(r) is None:
                    break
        elif kind == 2 and live:  # release
            r = live.pop(arg % len(live))
            c.release(r)
        c.check_invariants()
    # drain
    for r in list(live):
        c.release(r)
    c.check_invariants()
    assert sum(l for _, l in c.free) == max_tokens   # all memory returned


# ---------------------------------------------------------------------------
# PageAllocator (online serving)
# ---------------------------------------------------------------------------


def test_page_allocator_admit_grow_release():
    a = PageAllocator(n_pages=9, page_size=8)
    assert a.admit(1) == 0
    assert a.ensure_capacity(1, 20)           # 3 pages
    assert a.capacity(1) == 24
    assert a.n_free == 5
    row = a.table_row(1, width=6)
    assert list(row[:3]) == a.pages[1] and not row[3:].any()
    assert 0 not in a.pages[1]                # scratch page never allocated
    a.check_invariants()
    a.release(1)
    assert a.n_free == 8
    a.check_invariants()


def test_page_allocator_all_or_nothing_and_preempt():
    a = PageAllocator(n_pages=5, page_size=8)   # 4 usable pages
    a.admit(1)
    a.admit(2)
    assert a.ensure_capacity(1, 24)             # 3 pages
    before = list(a.pages[2])
    assert not a.ensure_capacity(2, 24)         # needs 3, only 1 free
    assert a.pages[2] == before                 # failed grow allocated nothing
    assert a.stats["alloc_failures"] == 1
    a.preempt(1)
    assert a.stats["preempts"] == 1
    assert a.ensure_capacity(2, 24)             # victim's pages recycled
    a.check_invariants()


def test_page_allocator_prefix_sharing_refcounts():
    a = PageAllocator(n_pages=12, page_size=8)
    a.admit(1)
    a.ensure_capacity(1, 20)                    # 2 full pages + 1 partial
    a.register_prefix(1, "sys", 16)             # only FULL pages shared
    assert len(a.prefix_index["sys"]) == 2
    shared = a.admit(2, prefix_key="sys")
    assert shared == 16
    assert a.pages[2][:2] == a.pages[1][:2]
    assert a.stats["prefix_hits"] == 1
    a.ensure_capacity(2, 24)                    # private growth page
    assert a.pages[2][2] != a.pages[1][2]
    a.check_invariants()
    a.release(1)                                # shared pages stay (index+2)
    a.check_invariants()
    a.release(2)
    held = len(a.prefix_index["sys"])
    assert a.n_free == a.n_pages - a.reserved - held
    a.drop_prefix("sys")
    assert a.n_free == a.n_pages - a.reserved
    a.check_invariants()


def test_page_allocator_deterministic_recycling():
    """Identical op sequences hand out identical page ids (the engine's
    parity and compile-count tests rely on this)."""
    def run():
        a = PageAllocator(n_pages=8, page_size=4)
        ids = []
        a.admit(1); a.ensure_capacity(1, 10)
        a.admit(2); a.ensure_capacity(2, 6)
        ids.append(list(a.pages[1]) + list(a.pages[2]))
        a.preempt(1)
        a.admit(3); a.ensure_capacity(3, 12)
        ids.append(list(a.pages[3]))
        return ids
    assert run() == run()


def test_page_allocator_prefix_clamped_to_consumer_prompt():
    """A consumer whose prompt is shorter than the published prefix must
    not attach shared pages beyond its own prompt — its decode would
    write new-token KV straight into pages other requests attend."""
    a = PageAllocator(n_pages=12, page_size=8)
    a.admit(1)
    a.ensure_capacity(1, 24)
    a.register_prefix(1, "sys", 24)             # 3 full pages published
    shared = a.admit(2, prefix_key="sys", prompt_len=16)
    assert shared == 16                          # clamped, not 24
    assert len(a.pages[2]) == 2
    assert a.pages[2] == a.pages[1][:2]
    a.check_invariants()


# ---------------------------------------------------------------------------
# Radix prefix cache (content-addressed, LRU-evicted)
# ---------------------------------------------------------------------------


def test_radix_publish_match_attach():
    """Publish-on-release puts a request's full pages in the trie; a
    same-prefix admit attaches them refcounted; a diverging admit only
    matches the common page-aligned blocks."""
    a = PageAllocator(n_pages=12, page_size=4)
    toks = list(range(10))                      # 2 full pages + 2 tail
    a.admit(1, tokens=toks)
    assert a.shared_len[1] == 0                 # cold trie: no match
    a.ensure_capacity(1, len(toks))
    a.release(1, tokens=toks)
    assert a.n_cached_pages == 2                # only FULL pages published
    assert a.stats["published"] == 2

    shared = a.admit(2, tokens=toks)
    assert shared == 8                          # both cached pages attach
    assert a.stats["prefix_hits"] == 1
    assert a.stats["radix_hit_tokens"] == 8
    a.check_invariants()

    div = toks[:4] + [99, 98, 97, 96]           # diverges in block 2
    assert a.admit(3, tokens=div) == 4          # only block 1 matches
    a.ensure_capacity(3, len(div))              # private divergent page
    a.check_invariants()
    a.release(2, tokens=toks)                   # re-publish: pure dedup
    a.release(3, tokens=div)
    assert a.n_cached_pages == 3                # block1, block2, divergent
    a.flush_radix()
    assert a.n_free == a.n_pages - a.reserved
    a.check_invariants()


def test_radix_eviction_lru_leaf_first():
    """The sweep only takes childless unreferenced nodes, least recently
    used first — a chain dies tail-first, and touching a chain (via a
    fresh match) protects it over an untouched one."""
    a = PageAllocator(n_pages=12, page_size=2)
    chain_a = [1, 2, 3, 4, 5, 6]                # 3 pages
    chain_b = [7, 8, 9, 10]                     # 2 pages
    for rid, toks in ((1, chain_a), (2, chain_b)):
        a.admit(rid, tokens=toks)
        a.ensure_capacity(rid, len(toks))
        a.release(rid, tokens=toks)
    assert a.n_cached_pages == 5
    # touch chain_a -> chain_b is now the LRU chain
    a.admit(3, tokens=chain_a)
    a.release(3)
    assert a.evict_radix(1) == 1                # takes chain_b's LEAF
    assert [n.key for n in a.match_radix(chain_b)] == [(7, 8)]
    assert a.match_radix(chain_a) and len(a.match_radix(chain_a)) == 3
    a.check_invariants()
    # interior nodes become evictable as the subtree drains
    assert a.evict_radix(10) == 4               # everything else
    assert a.n_cached_pages == 0
    a.check_invariants()


def test_radix_eviction_mid_attach_adversarial():
    """Adversarial: a request is mid-flight holding attached cached pages
    (refcount 2) when pool pressure forces a full sweep.  The sweep may
    only take the unreferenced tail — the attached pages must survive,
    stay in the holder's table, AND stay in the trie."""
    a = PageAllocator(n_pages=8, page_size=2)   # 7 usable pages
    chain = list(range(10))                     # 5 pages
    a.admit(1, tokens=chain)
    a.ensure_capacity(1, len(chain))
    a.release(1, tokens=chain)
    assert a.n_cached_pages == 5

    b = a.admit(2, tokens=chain[:4])            # attach first 2 pages
    assert b == 4
    held = list(a.pages[2])
    # pool pressure: a new request wants 4 pages; only 2 are free, so
    # ensure_capacity sweeps the 3 unreferenced tail nodes
    a.admit(3, tokens=[50, 51])
    assert a.ensure_capacity(3, 8)
    assert a.stats["evictions"] == 2            # evicted only what it needed
    assert a.pages[2] == held                   # holder untouched
    assert [n.page for n in a.match_radix(chain[:4])] == held
    a.check_invariants()
    # the survivor keeps serving hits after the sweep
    assert a.admit(4, tokens=chain[:4]) == 4
    a.check_invariants()
    # drain everything; flush returns the pool to empty
    for rid in (2, 3, 4):
        a.release(rid)
    a.flush_radix()
    assert a.n_free == a.n_pages - a.reserved
    a.check_invariants()


def _radix_churn(seed: int, n_ops: int = 300, n_pages: int = 17,
                 page_size: int = 4):
    """One deterministic radix-churn run mirroring engine usage: admit
    with content tokens (hot prefixes collide), grow, emit, publish on
    release/preempt, sweep under pressure, occasional flush.  Invariants
    after EVERY op plus explicit page accounting.  Returns a trace for
    replay comparison."""
    rs = np.random.RandomState(seed)
    a = PageAllocator(n_pages=n_pages, page_size=page_size)
    hot = [list(rs.randint(0, 7, 8)), list(rs.randint(0, 7, 12)), []]
    live, trace, next_rid = {}, [], 0

    def account():
        a.check_invariants()
        attached = {p for pages in a.pages.values() for p in pages}
        cached = {n.page for n in a._iter_radix()}
        assert len(attached | cached) + a.n_free \
            == a.n_pages - a.reserved, "cached+live+free != pool"

    for _ in range(n_ops):
        op = rs.randint(6)
        if op <= 1:                                   # admit + grow
            next_rid += 1
            toks = (hot[rs.randint(3)]
                    + list(rs.randint(0, 7, rs.randint(1, 10))))
            shared = a.admit(next_rid, tokens=toks)
            trace.append(("admit", next_rid, shared))
            if a.ensure_capacity(next_rid, len(toks)):
                live[next_rid] = toks
            else:                                     # pool full: preempt
                victim = max(live) if live else None
                if victim is not None:
                    a.preempt(victim, tokens=live.pop(victim))
                    trace.append(("preempt", victim))
                a.release(next_rid)
                trace.append(("reject", next_rid))
        elif op == 2 and live:                        # decode-emit + grow
            rid = list(live)[rs.randint(len(live))]
            live[rid] = live[rid] + list(rs.randint(0, 7,
                                                    rs.randint(1, 6)))
            ok = a.ensure_capacity(rid, len(live[rid]))
            trace.append(("grow", rid, ok))
            if not ok:
                a.preempt(rid, tokens=live.pop(rid))
        elif op == 3 and live:                        # release-publish
            rid = list(live)[rs.randint(len(live))]
            a.release(rid, tokens=live.pop(rid))
            trace.append(("release", rid))
        elif op == 4 and live:                        # preempt-publish
            rid = list(live)[rs.randint(len(live))]
            a.preempt(rid, tokens=live.pop(rid))
            trace.append(("preempt", rid))
        elif op == 5:                                 # explicit sweep
            if rs.randint(4) == 0:
                trace.append(("flush", a.flush_radix()))
            else:
                trace.append(("evict", a.evict_radix(rs.randint(1, 4))))
        account()
    for rid in sorted(live):
        a.release(rid, tokens=live[rid])
        account()
    trace.append(("end", sorted(a.stats.items()), list(a.free_list)))
    return a, trace


def test_radix_churn_stress_and_replay():
    """Randomized radix churn: invariants + exact page accounting after
    every op, nothing leaks after a final flush, eviction/dedup paths
    actually exercised, and the whole run replays bit-identically from
    the seed (trace includes final stats AND free-list order)."""
    for seed in (0, 1, 2):
        a, trace = _radix_churn(seed)
        a.flush_radix()
        a.check_invariants()
        assert a.n_free == a.n_pages - a.reserved     # nothing leaked
        assert a.stats["prefix_hits"] > 0, "hot prefixes never hit"
        assert a.stats["evictions"] > 0, "churn never swept"
        _, trace2 = _radix_churn(seed)
        assert trace2 == trace, f"seed {seed} replay diverged"


def test_radix_ensure_capacity_evicts_before_failing():
    """Cached pages never cause an allocation failure an uncached run
    would not hit: ensure_capacity sweeps exactly the shortfall before
    reporting False."""
    a = PageAllocator(n_pages=6, page_size=2)   # 5 usable
    a.admit(1, tokens=list(range(8)))
    a.ensure_capacity(1, 8)                     # 4 pages
    a.release(1, tokens=list(range(8)))
    assert a.n_free == 1 and a.n_cached_pages == 4
    a.admit(2, tokens=[90, 91])
    assert a.ensure_capacity(2, 6)              # needs 3: sweeps 2 cached
    assert a.stats["evictions"] == 2
    assert a.stats["alloc_failures"] == 0
    a.check_invariants()
    # now ask for more than the whole pool: sweep everything, THEN fail
    assert not a.ensure_capacity(2, 99)
    assert a.n_cached_pages == 0
    assert a.stats["alloc_failures"] == 1
    a.check_invariants()


def test_page_allocator_reregister_prefix_releases_old():
    """Re-registering a key must drop the old entry's refcounts — the
    old pages return to the pool instead of leaking forever."""
    a = PageAllocator(n_pages=12, page_size=8)
    a.admit(1)
    a.ensure_capacity(1, 16)
    a.register_prefix(1, "sys", 16)
    a.admit(2)
    a.ensure_capacity(2, 16)
    a.register_prefix(2, "sys", 16)             # replaces the entry
    a.check_invariants()
    a.release(1)
    a.release(2)
    held = len(a.prefix_index["sys"])
    assert a.n_free == a.n_pages - a.reserved - held
    a.drop_prefix("sys")
    assert a.n_free == a.n_pages - a.reserved   # nothing leaked
    a.check_invariants()
