"""Sharded-execution parity: the full DP x TP x SP x FSDP (+EP) stack on
4 fake devices must reproduce single-device losses, two-step trajectories,
and grad norms.  Runs in a subprocess because the device count must be
forced before jax initializes."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.base import get_smoke_config
    from repro.launch.mesh import make_local_mesh
    from repro import api
    from repro.optim import adamw
    import sys

    arch, sp_comm = sys.argv[1], sys.argv[2]
    cfg = get_smoke_config(arch)
    B, S = 4, 64
    rs = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rs.randint(0, cfg.vocab_size, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rs.randint(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    if cfg.is_encoder_decoder:
        batch["enc_frames"] = jnp.asarray(
            rs.randn(B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    out = {}
    for dp, tp in [(1, 1), (2, 2), (1, 4)]:
        mesh = make_local_mesh(dp, tp)
        r = api.Runner(cfg, mesh, max_seq=S,
                       sp_comm=(sp_comm if tp > 1 else "native"))
        params = r.init_params(0)
        step = jax.jit(r.make_train_step(global_batch=B))
        opt = adamw.init_opt_state(params)
        p2, o2, m = step(params, opt, batch, jnp.int32(10**6),
                         jax.random.PRNGKey(1), jnp.float32(1e-3))
        p2, o2, m2 = step(p2, o2, batch, jnp.int32(10**6 + 1),
                          jax.random.PRNGKey(2), jnp.float32(1e-3))
        out[(dp, tp)] = (float(m["loss/ce"]), float(m2["loss/ce"]),
                         float(m["grad_norm"]))
    ref = out[(1, 1)]
    tol = 0.05 if sp_comm == "native" else 0.08
    for k, v in out.items():
        for a, b in zip(ref, v):
            assert abs(a - b) / max(abs(a), 1e-3) < tol, (k, ref, v)
    print("PARITY OK", arch, sp_comm)
""")


@pytest.mark.parametrize("arch,sp_comm", [
    ("deepseek-moe-16b", "native"),
    ("nemotron-4-15b", "int8"),
])
def test_sharded_parity(arch, sp_comm):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT, arch, sp_comm],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-3000:]
    assert "PARITY OK" in res.stdout


# ---------------------------------------------------------------------------
# Expert-parallel (dispatch="ep") MoE-level parity sweep: the all-to-all
# dispatch on a 2-device mesh must reproduce the tp=1 fused Pallas path —
# outputs AND grads — including under adversarially skewed routing (empty
# expert groups on one shard) and with deterministic capacity drops.
# ---------------------------------------------------------------------------

EP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs.base import get_smoke_config
    from repro.launch.mesh import make_local_mesh
    from repro import sharding
    from repro.sharding import make_axis_env
    from repro.core import moe as moe_lib

    cfg0 = get_smoke_config("deepseek-moe-16b")
    T = 64
    rs = np.random.RandomState(0)
    x = jnp.asarray(rs.randn(T, cfg0.d_model) * 0.3, jnp.float32)

    def run(cfg, tp, dispatch, xx, skew=False):
        mesh = make_local_mesh(1, tp)
        env = make_axis_env(mesh)
        params, specs = moe_lib.init_moe(jax.random.PRNGKey(3), cfg, env)
        if skew:   # every token -> expert 0: shard 1's groups all empty
            wr = params["router"]["wr"]
            params["router"]["wr"] = wr.at[:, 0].set(5.0).at[:, 1:].set(0.0)

        def fwd(p, xx):
            y, aux, mets = moe_lib.moe_ffn(cfg, env, p, xx, train=False,
                                           dispatch=dispatch)
            return (env.sp_scatter(y.astype(jnp.float32)), aux,
                    mets["moe/dropped_frac"])

        fcall = sharding.shard_map(fwd, mesh=mesh, in_specs=(specs, P()),
                                   out_specs=(P("model"), P(), P()))
        y, aux, drop = fcall(params, xx)

        def gfn(p, xx):
            def loss(p, xx):
                y, aux, _ = moe_lib.moe_ffn(cfg, env, p, xx, train=False,
                                            dispatch=dispatch)
                y_sp = env.sp_scatter(y.astype(jnp.float32))
                return jnp.sum(y_sp * y_sp) * 1e4
            gp, gx = jax.grad(loss, argnums=(0, 1))(p, xx)
            # wr and x are replicated over tp: sum the per-rank partials
            return (gp["we1"], gp["we2"], env.psum_tp(gp["router"]["wr"]),
                    env.psum_tp(gx))

        gcall = sharding.shard_map(gfn, mesh=mesh, in_specs=(specs, P()),
                                   out_specs=(specs["we1"], specs["we2"],
                                              P(), P()))
        grads = gcall(params, xx)
        return ([np.asarray(v) for v in (y, aux, drop)],
                [np.asarray(g) for g in grads])

    def close(a, b, tol, what):
        rel = np.linalg.norm(a - b) / max(np.linalg.norm(a), 1e-9)
        assert rel < tol, (what, rel)

    # -- output + grad parity, uneven (natural random) routing -------------
    (y1, aux1, dr1), g1 = run(cfg0, 1, "fused", x)
    (y2, aux2, dr2), g2 = run(cfg0, 2, "ep", x)
    assert dr1 == 0.0 and dr2 == 0.0, (dr1, dr2)
    np.testing.assert_allclose(aux1, aux2, rtol=1e-5)
    close(y1, y2, 5e-3, "out")                 # bf16 compute tolerance
    for name, a, b in zip(("we1", "we2", "wr", "dx"), g1, g2):
        close(a, b, 2e-2, "grad_" + name)

    # -- empty expert groups on shard 1 (all tokens -> expert 0) -----------
    (ys1, _, drs1), gs1 = run(cfg0, 1, "fused", x, skew=True)
    (ys2, _, drs2), gs2 = run(cfg0, 2, "ep", x, skew=True)
    assert drs2 == 0.0, drs2       # cf=2.0 @ tp=2 keeps full skew dropless
    close(ys1, ys2, 5e-3, "skew_out")
    for name, a, b in zip(("we1", "we2", "wr", "dx"), gs1, gs2):
        close(a, b, 2e-2, "skew_grad_" + name)

    # -- capacity drops: deterministic and accounted -----------------------
    cfg_drop = dataclasses.replace(
        cfg0, moe=dataclasses.replace(cfg0.moe, capacity_factor=0.5))
    (yd1, _, drd1), _ = run(cfg_drop, 2, "ep", x)
    (yd2, _, drd2), _ = run(cfg_drop, 2, "ep", x)
    assert drd1 > 0.0, drd1
    assert drd1 == drd2
    np.testing.assert_array_equal(yd1, yd2)

    print("EP PARITY OK")
""")


def test_ep_dispatch_parity():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run(
        [sys.executable, "-c", EP_SCRIPT],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-3000:]
    assert "EP PARITY OK" in res.stdout


# ---------------------------------------------------------------------------
# Mesh-native training-engine parity: the donated, spike-guarded,
# grad-accumulating train step on a 2-device mesh (dp=2 and tp=2, the
# latter taking the EP all-to-all MoE dispatch) must reproduce the tp=1
# loss/param trajectory — including under adversarially skewed expert
# routing (all tokens -> expert 0, shard 1's groups empty).
# Params are initialized OUTSIDE the shard_map'ed step (init_params) and
# passed in with their spec trees, per the PR-2 tp>1 parity gotcha.
# ---------------------------------------------------------------------------

ENGINE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.base import get_smoke_config
    from repro.launch.mesh import make_local_mesh
    from repro import api
    from repro.core import spikes
    from repro.optim import adamw

    cfg = get_smoke_config("deepseek-moe-16b")
    B, S, A = 4, 32, 2
    rs = np.random.RandomState(0)
    toks = rs.randint(0, cfg.vocab_size, (A, B, S))
    labs = rs.randint(0, cfg.vocab_size, (A, B, S))
    batch = {"tokens": jnp.asarray(toks, jnp.int32),
             "labels": jnp.asarray(labs, jnp.int32)}

    def run(dp, tp, skew=False):
        mesh = make_local_mesh(dp, tp)
        r = api.Runner(cfg, mesh, max_seq=S)
        params = r.init_params(0)
        if skew:   # every token -> expert 0: tp=2's shard 1 is all empty
            wr = params["blocks"]["moe"]["router"]["wr"]
            params["blocks"]["moe"]["router"]["wr"] = (
                (wr * 0).at[..., 0].set(3.0))
        step = r.jit_train_step(B, accum_steps=A,
                                spike_guard=spikes.SpikeConfig(),
                                donate=False)
        opt = adamw.init_opt_state(params)
        guard = spikes.init_guard_state()
        losses, gnorms = [], []
        for t in range(2):
            params, opt, guard, m = step(
                params, opt, guard, batch, jnp.int32(10**6 + t),
                jax.random.PRNGKey(1), jnp.float32(1e-3))
            losses.append(float(m["loss"]))
            gnorms.append(float(m["grad_norm"]))
            assert float(m["commit"]) == 1.0, (dp, tp, t)
        pnorm = float(jnp.sqrt(sum(
            jnp.sum(jnp.asarray(jax.device_get(l), jnp.float32) ** 2)
            for l in jax.tree.leaves(params))))
        return losses, gnorms, pnorm

    for skew in (False, True):
        ref = run(1, 1, skew)
        for dp, tp in [(2, 1), (1, 2)]:
            got = run(dp, tp, skew)
            for a, b in zip(np.ravel(ref[0] + ref[1] + [ref[2]]),
                            np.ravel(got[0] + got[1] + [got[2]])):
                rel = abs(a - b) / max(abs(a), 1e-3)
                assert rel < 0.05, (skew, dp, tp, ref, got)
        print("ENGINE", "skew" if skew else "plain", "ref", ref[0])
    print("ENGINE PARITY OK")
""")


def test_engine_step_parity_2dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run(
        [sys.executable, "-c", ENGINE_SCRIPT],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-3000:]
    assert "ENGINE PARITY OK" in res.stdout


# ---------------------------------------------------------------------------
# Batch-size warmup (§3.4.1) parity on a 2-device mesh: the staged
# scheduled-accumulation engine (accum 1 -> 2 at fixed microbatch) on dp=2
# must track single-device fixed-big-batch steps at every stage, under
# adversarially skewed expert routing — and must compile once per stage.
# ---------------------------------------------------------------------------

WARMUP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.base import get_smoke_config
    from repro.launch.mesh import make_local_mesh
    from repro import api
    from repro.optim import adamw
    from repro.optim.schedule import AccumWarmup

    cfg = get_smoke_config("deepseek-moe-16b")
    S, Bm = 32, 2
    warm = AccumWarmup(microbatch=Bm, start=Bm, end=2 * Bm,
                       warmup_steps=2, increments=1)
    accums = [warm.accum_for(t) for t in range(4)]
    assert accums == [1, 1, 2, 2], accums
    rs = np.random.RandomState(0)
    data = [(rs.randint(0, cfg.vocab_size, (a * Bm, S)),
             rs.randint(0, cfg.vocab_size, (a * Bm, S))) for a in accums]

    def skew_params(r):
        params = r.init_params(0)
        wr = params["blocks"]["moe"]["router"]["wr"]
        params["blocks"]["moe"]["router"]["wr"] = (
            (wr * 0).at[..., 0].set(3.0))   # all tokens -> expert 0
        return params

    def run_staged(dp, tp):
        r = api.Runner(cfg, make_local_mesh(dp, tp), max_seq=S)
        params, opt = skew_params(r), None
        opt = adamw.init_opt_state(params)
        staged = r.jit_train_step(Bm, accum_steps=warm.stages(),
                                  donate=False)
        losses, gnorms = [], []
        for t, a in enumerate(accums):
            toks, labs = data[t]
            shape = (Bm, S) if a == 1 else (a, Bm, S)
            b = {"tokens": jnp.asarray(toks.reshape(shape), jnp.int32),
                 "labels": jnp.asarray(labs.reshape(shape), jnp.int32)}
            params, opt, m = staged.for_accum(a)(
                params, opt, b, jnp.int32(10**6 + t),
                jax.random.PRNGKey(1), jnp.float32(1e-3))
            losses.append(float(m["loss"]))
            gnorms.append(float(m["grad_norm"]))
        assert staged.trace_counts == {1: 1, 2: 1}, staged.trace_counts
        pnorm = float(jnp.sqrt(sum(
            jnp.sum(jnp.asarray(jax.device_get(l), jnp.float32) ** 2)
            for l in jax.tree.leaves(params))))
        return losses, gnorms, pnorm

    def run_big(dp, tp):
        r = api.Runner(cfg, make_local_mesh(dp, tp), max_seq=S)
        params = skew_params(r)
        opt = adamw.init_opt_state(params)
        steps = {a: jax.jit(r.make_train_step(a * Bm))
                 for a in set(accums)}
        losses, gnorms = [], []
        for t, a in enumerate(accums):
            toks, labs = data[t]
            b = {"tokens": jnp.asarray(toks, jnp.int32),
                 "labels": jnp.asarray(labs, jnp.int32)}
            params, opt, m = steps[a](params, opt, b, jnp.int32(10**6 + t),
                                      jax.random.PRNGKey(1),
                                      jnp.float32(1e-3))
            losses.append(float(m["loss"]))
            gnorms.append(float(m["grad_norm"]))
        pnorm = float(jnp.sqrt(sum(
            jnp.sum(jnp.asarray(jax.device_get(l), jnp.float32) ** 2)
            for l in jax.tree.leaves(params))))
        return losses, gnorms, pnorm

    ref = run_big(1, 1)
    for dp, tp in [(2, 1), (1, 2)]:
        got = run_staged(dp, tp)
        for a, b in zip(np.ravel(ref[0] + [ref[2]]),
                        np.ravel(got[0] + [got[2]])):
            rel = abs(a - b) / max(abs(a), 1e-3)
            assert rel < 0.05, (dp, tp, ref, got)
        # grad norms are much noisier than losses once bf16 updates
        # accumulate over four steps through a different dispatch path
        # (tp=2 takes the EP all-to-all); bound them loosely
        for a, b in zip(ref[1], got[1]):
            rel = abs(a - b) / max(abs(a), 1e-3)
            assert rel < 0.15, (dp, tp, ref, got)
        print("WARMUP", (dp, tp), "tracks big-batch", got[0])
    print("WARMUP PARITY OK")
""")


def test_accum_warmup_parity_2dev_skewed():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run(
        [sys.executable, "-c", WARMUP_SCRIPT],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-3000:]
    assert "WARMUP PARITY OK" in res.stdout
