"""Sharded-execution parity: the full DP x TP x SP x FSDP (+EP) stack on
4 fake devices must reproduce single-device losses, two-step trajectories,
and grad norms.  Runs in a subprocess because the device count must be
forced before jax initializes."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.base import get_smoke_config
    from repro.launch.mesh import make_local_mesh
    from repro import api
    from repro.optim import adamw
    import sys

    arch, sp_comm = sys.argv[1], sys.argv[2]
    cfg = get_smoke_config(arch)
    B, S = 4, 64
    rs = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rs.randint(0, cfg.vocab_size, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rs.randint(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    if cfg.is_encoder_decoder:
        batch["enc_frames"] = jnp.asarray(
            rs.randn(B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    out = {}
    for dp, tp in [(1, 1), (2, 2), (1, 4)]:
        mesh = make_local_mesh(dp, tp)
        r = api.Runner(cfg, mesh, max_seq=S,
                       sp_comm=(sp_comm if tp > 1 else "native"))
        params = r.init_params(0)
        step = jax.jit(r.make_train_step(global_batch=B))
        opt = adamw.init_opt_state(params)
        p2, o2, m = step(params, opt, batch, jnp.int32(10**6),
                         jax.random.PRNGKey(1), jnp.float32(1e-3))
        p2, o2, m2 = step(p2, o2, batch, jnp.int32(10**6 + 1),
                          jax.random.PRNGKey(2), jnp.float32(1e-3))
        out[(dp, tp)] = (float(m["loss/ce"]), float(m2["loss/ce"]),
                         float(m["grad_norm"]))
    ref = out[(1, 1)]
    tol = 0.05 if sp_comm == "native" else 0.08
    for k, v in out.items():
        for a, b in zip(ref, v):
            assert abs(a - b) / max(abs(a), 1e-3) < tol, (k, ref, v)
    print("PARITY OK", arch, sp_comm)
""")


@pytest.mark.parametrize("arch,sp_comm", [
    ("deepseek-moe-16b", "native"),
    ("nemotron-4-15b", "int8"),
])
def test_sharded_parity(arch, sp_comm):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT, arch, sp_comm],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-3000:]
    assert "PARITY OK" in res.stdout
