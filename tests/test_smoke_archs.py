"""Per-architecture smoke tests (deliverable f).

For every assigned architecture (plus the paper's own Ling configs) a
REDUCED same-family variant (<=2 layers, d_model<=512, <=4 experts) runs one
train step and a short decode on CPU, asserting output shapes and no NaNs.
The FULL configs are exercised via the dry-run (launch/dryrun.py) only.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs.base import ARCH_IDS, get_smoke_config
from repro.launch.mesh import make_local_mesh
from repro.optim import adamw

B, S = 2, 64


def make_batch(cfg, rng=0):
    rs = np.random.RandomState(rng)
    batch = {
        "tokens": jnp.asarray(rs.randint(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rs.randint(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.is_encoder_decoder:
        batch["enc_frames"] = jnp.asarray(
            rs.randn(B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.fixture(scope="module")
def mesh():
    return make_local_mesh(1, 1)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch, mesh):
    cfg = get_smoke_config(arch)
    r = api.Runner(cfg, mesh, max_seq=S)
    params = r.init_params(0)
    opt = adamw.init_opt_state(params)
    step = jax.jit(r.make_train_step(global_batch=B))
    batch = make_batch(cfg)
    p2, o2, m = step(params, opt, batch, jnp.int32(0),
                     jax.random.PRNGKey(1), jnp.float32(1e-3))
    assert np.isfinite(float(m["loss"])), m
    assert float(m["loss"]) > 0
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.sum(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), params, p2))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_steps(arch, mesh):
    cfg = get_smoke_config(arch)
    r = api.Runner(cfg, mesh, fsdp=False, seq_parallel=False, max_seq=S)
    params = r.init_params(0)
    decode, cache_specs = r.make_decode_step(global_batch=B, seq_len=S)
    decode = jax.jit(decode)
    from repro.models import model as M
    caches = M.init_caches(cfg, r.env, B, S,
                           cross_len=cfg.encoder_seq_len)
    tok = jnp.zeros((B,), jnp.int32)
    for pos in range(3):
        tok, caches = decode(params, caches, tok, jnp.int32(pos))
    assert tok.shape == (B,)
    assert ((tok >= 0) & (tok < cfg.vocab_size)).all(), tok


@pytest.mark.parametrize("arch", ["deepseek-moe-16b", "ling-lite"])
def test_loss_decreases(arch, mesh):
    """Overfit one tiny batch for a few steps — loss must drop."""
    cfg = get_smoke_config(arch)
    r = api.Runner(cfg, mesh, max_seq=S)
    params = r.init_params(0)
    opt = adamw.init_opt_state(params)
    step = jax.jit(r.make_train_step(global_batch=B))
    batch = make_batch(cfg)
    first = None
    for i in range(6):
        params, opt, m = step(params, opt, batch, jnp.int32(i),
                              jax.random.PRNGKey(i), jnp.float32(1e-3))
        if first is None:
            first = m["loss/ce"]   # stays on device until the loop ends
    first, last = jax.device_get((first, m["loss/ce"]))
    assert last < first * 0.8, (first, last)
