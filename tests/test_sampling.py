"""Real sampling: transform_logits truncation semantics (pure unit
tests), per-slot key independence through the online engine, explicit
temperature-0 == default greedy bitwise, and offline-vs-online stream
parity at nonzero temperature under the shared (seed, position, stream)
key schedule."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs.base import get_smoke_config
from repro.launch.mesh import make_local_mesh
from repro.models import embedding as emb
from repro.models import model as M
from repro.serving.online import OnlineConfig, OnlineEngine, OnlineRequest


@pytest.fixture(scope="module")
def runner_params():
    cfg = get_smoke_config("ling-lite")
    runner = api.Runner(cfg, make_local_mesh(1, 1), fsdp=False,
                        seq_parallel=False, max_seq=64)
    return runner, runner.init_params(0)


# -- transform_logits unit tests (pure per-row math, no mesh) ----------------

def test_top_k_truncates_support():
    rs = np.random.RandomState(0)
    logits = jnp.asarray(rs.randn(3, 32).astype(np.float32))
    for k in (1, 4, 9):
        probs = np.asarray(emb.transform_logits(
            logits, jnp.ones((3,)), jnp.ones((3,)),
            jnp.full((3,), k, jnp.int32)))
        assert (np.sum(probs > 0, axis=-1) == k).all()
        np.testing.assert_allclose(probs.sum(-1), 1.0, rtol=1e-5)
        # the survivors are exactly the k largest logits per row
        for r in range(3):
            top = np.argsort(np.asarray(logits)[r])[-k:]
            assert set(np.flatnonzero(probs[r])) == set(top)


def test_top_p_mass_truncation():
    rs = np.random.RandomState(1)
    logits = jnp.asarray(rs.randn(4, 64).astype(np.float32))
    full = np.asarray(jax.nn.softmax(logits, axis=-1))
    for p in (0.3, 0.7, 0.95):
        probs = np.asarray(emb.transform_logits(
            logits, jnp.ones((4,)), jnp.full((4,), p, jnp.float32),
            jnp.zeros((4,), jnp.int32)))
        np.testing.assert_allclose(probs.sum(-1), 1.0, rtol=1e-5)
        for r in range(4):
            kept = probs[r] > 0
            mass = full[r][kept].sum()
            assert mass >= p - 1e-6, (p, mass)
            # minimal covering set: dropping the smallest kept token
            # must fall below the target mass
            assert mass - full[r][kept].min() < p + 1e-6, (p, mass)
            # kept set is a prefix of the probability ordering
            assert full[r][kept].min() >= full[r][~kept].max()


def test_top_p_one_and_top_k_zero_are_identity():
    rs = np.random.RandomState(2)
    logits = jnp.asarray(rs.randn(2, 16).astype(np.float32))
    probs = np.asarray(emb.transform_logits(
        logits, jnp.ones((2,)), jnp.ones((2,)), jnp.zeros((2,), jnp.int32)))
    np.testing.assert_allclose(
        probs, np.asarray(jax.nn.softmax(logits, -1)), rtol=1e-5)


def test_temperature_sharpens():
    logits = jnp.asarray([[0.0, 1.0, 2.0]])
    hot = np.asarray(emb.transform_logits(
        logits, jnp.asarray([2.0]), jnp.ones((1,)),
        jnp.zeros((1,), jnp.int32)))
    cold = np.asarray(emb.transform_logits(
        logits, jnp.asarray([0.5]), jnp.ones((1,)),
        jnp.zeros((1,), jnp.int32)))
    assert cold[0, 2] > hot[0, 2]
    assert cold[0, 0] < hot[0, 0]


def test_sample_keys_distinct_per_position_and_stream():
    seeds = jnp.asarray([7, 7, 8], jnp.int32)
    pos = jnp.asarray([3, 4, 3], jnp.int32)
    ks = np.asarray(emb.sample_keys(seeds, pos, emb.STREAM_SAMPLE))
    kd = np.asarray(emb.sample_keys(seeds, pos, emb.STREAM_DRAFT))
    assert not (ks[0] == ks[1]).all()      # position feeds the key
    assert not (ks[0] == ks[2]).all()      # seed feeds the key
    assert not (ks == kd).any(axis=-1).all()   # stream feeds the key


# -- engine-level sampling behavior ------------------------------------------

def _run_engine(runner, params, prompts, max_new, *, ocfg=None, **knobs):
    eng = OnlineEngine(runner, params, ocfg or OnlineConfig(
        max_slots=len(prompts), max_context=64, page_size=16,
        prefill_chunk=4))
    eng.submit_many([
        OnlineRequest(rid=i, prompt=prompts[i], max_new=max_new, **knobs)
        for i in range(len(prompts))])
    eng.run(max_ticks=1000)
    return [list(eng.reqs[i].out) for i in range(len(prompts))], eng


def test_explicit_temp0_is_default_greedy(runner_params):
    """temperature=0 passed explicitly is bitwise the default greedy
    engine output (the sampled step's argmax branch is exact)."""
    runner, params = runner_params
    rs = np.random.RandomState(0)
    prompts = [rs.randint(0, runner.cfg.vocab_size, 6).astype(np.int32)
               for _ in range(4)]
    ref, _ = _run_engine(runner, params, prompts, 5)
    out, eng = _run_engine(runner, params, prompts, 5,
                           temperature=0.0, top_p=0.9, top_k=5, seed=123)
    assert out == ref
    assert eng.prefill_traces == 1 and eng.decode_traces == 1


def test_per_slot_key_independence(runner_params):
    """Same prompt, different seeds -> streams diverge at high
    temperature; same seed -> identical streams (and a rerun of the
    whole engine reproduces them bitwise)."""
    runner, params = runner_params
    rs = np.random.RandomState(4)
    prompt = rs.randint(0, runner.cfg.vocab_size, 6).astype(np.int32)
    prompts = [prompt.copy() for _ in range(4)]
    seeds = [11, 11, 97, 500]
    eng = OnlineEngine(runner, params, OnlineConfig(
        max_slots=4, max_context=64, page_size=16, prefill_chunk=4))
    eng.submit_many([
        OnlineRequest(rid=i, prompt=prompts[i], max_new=8,
                      temperature=1.5, top_p=1.0, top_k=0, seed=seeds[i])
        for i in range(4)])
    eng.run(max_ticks=1000)
    outs = [list(eng.reqs[i].out) for i in range(4)]
    assert outs[0] == outs[1]              # same seed => same tokens
    # different seeds diverge (smoke vocab=512 at temp 1.5: collision of
    # whole 8-token streams is ~impossible; assert pairwise difference)
    assert outs[0] != outs[2] or outs[0] != outs[3]

    out2, _ = _run_engine(runner, params, prompts, 8,
                          temperature=1.5, seed=11)
    assert out2[0] == outs[0]              # reproducible across engines


def test_offline_online_parity_at_nonzero_temp(runner_params):
    """The offline dense decode path (make_decode_step(sample=True))
    reproduces the online engine's sampled stream for the same seed:
    both draw under the (seed, position, STREAM_SAMPLE) key schedule."""
    runner, params = runner_params
    B, P, NEW, S = 4, 6, 5, 64
    rs = np.random.RandomState(5)
    prompts = rs.randint(0, runner.cfg.vocab_size, (B, P)).astype(np.int32)
    seeds = np.asarray([3, 14, 15, 92], np.int32)
    temp, top_p, top_k = 0.9, 0.95, 0

    decode, _ = runner.make_decode_step(global_batch=B, seq_len=S,
                                        sample=True)
    decode = jax.jit(decode)
    caches = M.init_caches(runner.cfg, runner.env, B, S,
                           cross_len=runner.cfg.encoder_seq_len)
    knobs = (jnp.asarray(seeds), jnp.full((B,), temp, jnp.float32),
             jnp.full((B,), top_p, jnp.float32),
             jnp.full((B,), top_k, jnp.int32))
    tok = None
    for pos in range(P):
        tok, caches = decode(params, caches, jnp.asarray(prompts[:, pos]),
                             jnp.int32(pos), *knobs)
    ref = [tok]
    for pos in range(P, P + NEW - 1):
        tok, caches = decode(params, caches, tok, jnp.int32(pos), *knobs)
        ref.append(tok)    # device until the loop ends (FC-HOSTSYNC)
    ref = np.stack(jax.device_get(ref), 1)

    eng = OnlineEngine(runner, params, OnlineConfig(
        max_slots=B, max_context=S, page_size=16, prefill_chunk=4))
    eng.submit_many([
        OnlineRequest(rid=i, prompt=prompts[i], max_new=NEW,
                      temperature=temp, top_p=top_p, top_k=top_k,
                      seed=int(seeds[i]))
        for i in range(B)])
    eng.run(max_ticks=500)
    out = np.stack([np.asarray(eng.reqs[i].out) for i in range(B)])
    np.testing.assert_array_equal(out, ref)


def test_engine_defaults_apply_from_config(runner_params):
    """OnlineConfig-level sampling defaults reach slots that don't
    override them; per-request overrides win."""
    runner, params = runner_params
    rs = np.random.RandomState(6)
    prompt = rs.randint(0, runner.cfg.vocab_size, 6).astype(np.int32)
    ocfg = OnlineConfig(max_slots=2, max_context=64, page_size=16,
                        prefill_chunk=4, temperature=1.5, seed=77)
    eng = OnlineEngine(runner, params, ocfg)
    eng.submit_many([
        OnlineRequest(rid=0, prompt=prompt.copy(), max_new=6),
        OnlineRequest(rid=1, prompt=prompt.copy(), max_new=6,
                      temperature=0.0),
    ])
    eng.run(max_ticks=500)
    hot = list(eng.reqs[0].out)

    # rid 1 overrode to greedy: must match a pure-greedy engine
    ref, _ = _run_engine(runner, params, [prompt.copy()], 6)
    assert list(eng.reqs[1].out) == ref[0]

    # default seed schedule is (cfg.seed + rid): an explicit matching
    # seed reproduces the config-default stream
    eng2 = OnlineEngine(runner, params, ocfg)
    eng2.submit(OnlineRequest(rid=5, prompt=prompt.copy(), max_new=6,
                              temperature=1.5, seed=77))
    eng2.run(max_ticks=500)
    assert list(eng2.reqs[5].out) == hot
