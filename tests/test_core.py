"""Core paper-technique tests: router (warmup/balance), MoE (dropless
semantics vs dense oracle), NormHead stability properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_smoke_config
from repro.core import moe as moe_lib
from repro.core import router as router_lib
from repro.core.normhead import normalize_rows
from util import smap_env


@pytest.fixture(scope="module")
def moe_cfg():
    return get_smoke_config("deepseek-moe-16b")


def _router_params(cfg, env):
    p, _ = router_lib.init_router(jax.random.PRNGKey(0), cfg, env)
    return p


def test_stochastic_warmup_balances_early_routing(moe_cfg):
    """At step 0 warmup noise dominates -> near-uniform expert load even
    with an adversarially skewed router; by step >> W the learned (skewed)
    routing wins.  This is Eq. (3)'s whole point."""
    cfg = moe_cfg
    E = cfg.moe.n_experts

    def fn(env, x, step, rng):
        params = _router_params(cfg, env)
        # adversarial: consistent mean-shift toward expert 0 (x >= 0 below)
        params = {"wr": params["wr"].at[:, 0].add(0.1)}
        top_w, top_i, aux, m = router_lib.route(cfg, env, params, x,
                                                step=step, rng=rng,
                                                train=True)
        hits = jax.nn.one_hot(top_i, E).sum(axis=(0, 1))
        return hits / hits.sum()

    call, _ = smap_env(fn)
    x = jnp.asarray(np.abs(np.random.RandomState(0).randn(512, cfg.d_model)),
                    jnp.float32)
    early = call(x, jnp.int32(0), jax.random.PRNGKey(1))
    late = call(x, jnp.int32(10_000), jax.random.PRNGKey(1))
    # k=2 of 4 experts: uniform hit share is 0.25
    assert float(early.max()) < 0.35, early
    # learned routing always puts expert 0 in the top-2 -> share ~0.5
    assert float(late[0]) > 0.45, late


def test_balance_loss_uniform_is_minimal(moe_cfg):
    """The Switch balance loss is minimized (=1) by uniform routing."""
    cfg = moe_cfg

    def fn(env, x):
        params = _router_params(cfg, env)
        _, _, _, m = router_lib.route(cfg, env, params, x, train=False)
        return m["router/balance_loss"]

    call, _ = smap_env(fn)
    x = jnp.asarray(np.random.RandomState(1).randn(2048, cfg.d_model) * 0.01,
                    jnp.float32)
    near_uniform = float(call(x))
    assert near_uniform == pytest.approx(1.0, rel=0.15)


def test_moe_matches_dense_oracle(moe_cfg):
    """tp=1 MoE (dropless ragged path) == explicit dense top-k mixture."""
    cfg = moe_cfg
    m = cfg.moe

    def fn(env, x):
        params, _ = moe_lib.init_moe(jax.random.PRNGKey(3), cfg, env)
        y, aux, _ = moe_lib.moe_ffn(cfg, env, params, x, train=False)

        # oracle: run every expert densely, combine with top-k gates
        wr = params["router"]["wr"].astype(jnp.float32)
        probs = jax.nn.softmax(x.astype(jnp.float32) @ wr, axis=-1)
        top_w, top_i = jax.lax.top_k(probs, m.top_k)
        w1 = params["we1"].astype(jnp.bfloat16)
        w2 = params["we2"].astype(jnp.bfloat16)
        w3 = params["we3"].astype(jnp.bfloat16)
        xb = x.astype(jnp.bfloat16)
        outs = []
        for e in range(m.n_experts):
            h = jax.nn.silu(xb @ w1[e]) * (xb @ w3[e])
            outs.append(h @ w2[e])
        dense = jnp.stack(outs, axis=1)                  # (T, E, d)
        gate = jnp.zeros(probs.shape).at[
            jnp.arange(x.shape[0])[:, None], top_i].add(top_w)
        want = jnp.einsum("ted,te->td", dense.astype(jnp.float32), gate)
        if m.n_shared_experts:
            from repro.models import layers as L
            want = want + L.apply_mlp(cfg, env, params["shared"],
                                      xb).astype(jnp.float32)
        return y.astype(jnp.float32), want

    call, _ = smap_env(fn, out_specs=(P(), P()))
    x = jnp.asarray(np.random.RandomState(2).randn(64, cfg.d_model) * 0.3,
                    jnp.float32)
    got, want = call(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0.08, atol=0.08)   # bf16 compute


def test_moe_dropless_at_tp1(moe_cfg):
    """tp=1: capacity == T*k, so dropped_frac must be exactly 0."""
    cfg = moe_cfg

    def fn(env, x):
        params, _ = moe_lib.init_moe(jax.random.PRNGKey(4), cfg, env)
        _, _, metrics = moe_lib.moe_ffn(cfg, env, params, x, train=False)
        return metrics["moe/dropped_frac"]

    call, _ = smap_env(fn)
    x = jnp.asarray(np.random.RandomState(3).randn(128, cfg.d_model),
                    jnp.float32)
    assert float(call(x)) == 0.0


def test_moe_fused_matches_ragged(moe_cfg):
    """dispatch="fused" (one Pallas kernel) == dispatch="ragged" reference
    to fp32 precision on the same routing decisions."""
    cfg = dataclasses.replace(moe_cfg, compute_dtype="float32")

    def fn(env, x):
        params, _ = moe_lib.init_moe(jax.random.PRNGKey(3), cfg, env)
        yf, _, _ = moe_lib.moe_ffn(cfg, env, params, x, train=False,
                                   dispatch="fused")
        yr, _, _ = moe_lib.moe_ffn(cfg, env, params, x, train=False,
                                   dispatch="ragged")
        return yf, yr

    call, _ = smap_env(fn, out_specs=(P(), P()))
    x = jnp.asarray(np.random.RandomState(7).randn(96, cfg.d_model) * 0.3,
                    jnp.float32)
    yf, yr = call(x)
    scale = float(jnp.max(jnp.abs(yr))) + 1e-9
    rel = float(jnp.max(jnp.abs(yf - yr))) / scale
    assert rel <= 1e-4


def test_moe_fused_grads_match_ragged(moe_cfg):
    """The fused path's custom-vjp backward (ragged recompute) produces
    the same parameter and input grads as differentiating the ragged
    path directly."""
    cfg = dataclasses.replace(moe_cfg, compute_dtype="float32")

    def fn(env, x):
        params, _ = moe_lib.init_moe(jax.random.PRNGKey(5), cfg, env)

        def loss(p, xx, mode):
            y, aux, _ = moe_lib.moe_ffn(cfg, env, p, xx, train=False,
                                        dispatch=mode)
            return jnp.sum(y * y) + aux

        gf, gxf = jax.grad(loss, argnums=(0, 1))(params, x, "fused")
        gr, gxr = jax.grad(loss, argnums=(0, 1))(params, x, "ragged")
        return gf["we1"], gr["we1"], gf["we2"], gr["we2"], gxf, gxr

    call, _ = smap_env(fn, out_specs=tuple(P() for _ in range(6)))
    x = jnp.asarray(np.random.RandomState(8).randn(64, cfg.d_model) * 0.3,
                    jnp.float32)
    g1f, g1r, g2f, g2r, gxf, gxr = call(x)
    for got, want in ((g1f, g1r), (g2f, g2r), (gxf, gxr)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_normhead_scale_invariance():
    w = jnp.asarray(np.random.RandomState(5).randn(16, 8), jnp.float32)
    wn = normalize_rows(w)
    wn2 = normalize_rows(w * 123.0)
    np.testing.assert_allclose(np.asarray(wn), np.asarray(wn2), rtol=1e-5)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(wn), axis=1), 1.0,
                               rtol=1e-5)


def test_normhead_bounds_logits():
    """With unit-norm rows, |logit| <= ||x|| — weight growth cannot blow up
    the softmax (the §3.2.3 stability argument)."""
    rs = np.random.RandomState(6)
    x = jnp.asarray(rs.randn(32, 64), jnp.float32)
    w = jnp.asarray(rs.randn(100, 64) * 50.0, jnp.float32)  # huge weights
    logits = x @ normalize_rows(w).T
    xnorm = jnp.linalg.norm(x, axis=1, keepdims=True)
    assert bool(jnp.all(jnp.abs(logits) <= xnorm * 1.0001))
